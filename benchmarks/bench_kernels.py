"""Kernel microbenchmarks: the fused route+aggregate hot path vs the jnp
reference paths on CPU, plus the kernels' modelled TPU arithmetic.

NOTE: Pallas interpret-mode wall time is NOT TPU performance (it executes
the kernel body op-by-op in Python); the numbers that matter for the
roofline are the compiled-XLA fused path and the bytes/flops model printed
alongside.  All timed rows land in BENCH_kernels.json.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.run import median_ms
from repro.core import events as ev
from repro.kernels import ops
from repro.snn.lif import LIFParams, init_state


def main(report):
    smoke = getattr(report, "smoke", False)
    N, D, C = 4096, 64, 128
    k = jax.random.PRNGKey(0)
    words = ev.pack(jax.random.randint(k, (N,), 0, 1 << 12),
                    jax.random.randint(k, (N,), 0, 1 << 15))
    dests = jax.random.randint(jax.random.fold_in(k, 1), (N,), 0, D)

    # aggregate impl sweep at a second capacity point (bench_aggregation
    # owns the C=256 acceptance shape; one shared helper, two shapes)
    from benchmarks.bench_aggregation import impl_walltimes
    impl_walltimes(report, N, D, C)

    # fused Pallas placement kernel (interpret on CPU -- correctness path;
    # compiled on TPU); keep the shape tiny in smoke mode, it is slow.
    if not smoke:
        np_, dp, cp = 512, 16, 32
        wp = words[:np_]
        dp_arr = dests[:np_] % dp
        ms = median_ms(jax.jit(lambda: ops.fused_scatter(
            wp, dp_arr, jnp.zeros((np_,), jnp.int32), dp, cp)), iters=3)
        report.bench("kernels", "fused_scatter_pallas_interpret",
                     f"N{np_}_D{dp}_C{cp}", ms, events_per_s=np_ / ms * 1e3,
                     notes="interpret mode, NOT TPU perf")

    # kernel VMEM/arithmetic model (TPU target) for the fused path:
    # sort O(N log N) + per-dest dynamic-slice placement O(D*C)
    vmem_kb = (N * 4 * 2 + 8 * C * 8) / 1024
    report("kernels/fused_route_bucket_vmem_KiB", round(vmem_kb, 1),
           "sorted window + guid LUT resident + (D_TILE,C) out block")
    work = int(N * np.log2(max(N, 2)) + D * C)
    report("kernels/fused_route_bucket_work", work,
           "sort compares + placement slots (was N*D*C one-hot reduce)")
    report("kernels/bucket_scatter_work_legacy", N * D * C,
           "seed kernel select-reduce ops, kept as cross-check")

    n = 4096 if smoke else 65536
    p = LIFParams()
    st = init_state(n, p, jax.random.PRNGKey(1))
    exc = jax.random.uniform(jax.random.PRNGKey(2), (n,)) * 1000
    inh = jnp.zeros((n,))
    from repro.snn import lif as lif_mod
    ms_ref = median_ms(jax.jit(lambda s: lif_mod.step(s, p, exc, inh)), st)
    report.bench("kernels", "lif_step_ref", f"N{n}", ms_ref,
                 events_per_s=n / ms_ref * 1e3, notes="fused jnp")
    hbm_bytes = n * 4 * (4 + 2 + 5)       # read 4 state + 2 input, write 5
    report("kernels/lif_step_hbm_bytes", hbm_bytes,
           f"-> {hbm_bytes / 819e9 * 1e9:.1f} ns roofline on v5e HBM")
