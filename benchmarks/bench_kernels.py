"""Kernel microbenchmarks: Pallas (interpret) correctness-path cost vs the
jnp reference paths on CPU, plus the kernels' modelled TPU arithmetic.

NOTE: interpret-mode wall time is NOT TPU performance; the number that
matters for the roofline is the bytes/flops model printed alongside.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregator as agg
from repro.core import events as ev
from repro.kernels import ops
from repro.snn.lif import LIFParams, init_state


def wall(fn, *args, iters=5):
    jax.tree_util.tree_leaves(fn(*args))[0].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.tree_util.tree_leaves(out)[0].block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def main(report):
    N, D, C = 4096, 64, 128
    k = jax.random.PRNGKey(0)
    words = ev.pack(jax.random.randint(k, (N,), 0, 1 << 12),
                    jax.random.randint(k, (N,), 0, 1 << 15))
    dests = jax.random.randint(jax.random.fold_in(k, 1), (N,), 0, D)
    guids = jnp.zeros((N,), jnp.int32)

    us_sort = wall(jax.jit(lambda: agg.aggregate(words, dests, guids, D, C,
                                                 impl="sort")))
    us_oh = wall(jax.jit(lambda: agg.aggregate(words, dests, guids, D, C,
                                               impl="onehot")))
    report("kernels/aggregate_sort_us", round(us_sort, 1), f"N={N} D={D}")
    report("kernels/aggregate_onehot_us", round(us_oh, 1), f"N={N} D={D}")
    # kernel VMEM/arithmetic model (TPU target)
    vmem_kb = (N * 4 * 3 + 8 * C * 8) / 1024
    report("kernels/bucket_scatter_vmem_KiB", round(vmem_kb, 1),
           "events+dests+guids resident + (D_TILE,C) out block")
    report("kernels/bucket_scatter_work", N * D * C,
           "select-reduce ops (VPU int32)")

    n = 65536
    p = LIFParams()
    st = init_state(n, p, jax.random.PRNGKey(1))
    exc = jax.random.uniform(jax.random.PRNGKey(2), (n,)) * 1000
    inh = jnp.zeros((n,))
    from repro.snn import lif as lif_mod
    us_ref = wall(jax.jit(lambda s: lif_mod.step(s, p, exc, inh)), st)
    report("kernels/lif_ref_us", round(us_ref, 1), f"N={n} fused jnp")
    hbm_bytes = n * 4 * (4 + 2 + 5)       # read 4 state + 2 input, write 5
    report("kernels/lif_step_hbm_bytes", hbm_bytes,
           f"-> {hbm_bytes / 819e9 * 1e9:.1f} ns roofline on v5e HBM")
