"""Paper §4 — the named target workload: multi-wafer cortical microcircuit.

Measures the single-process simulation rate of the windowed simulator (one
shard, no collective — wall time per biological second at reduced scale)
and the communication profile (events, wire bytes, aggregation efficiency)
per flush window.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregator as agg
from repro.snn import lif, microcircuit as mc, network


def main(report):
    spec = mc.MicrocircuitSpec(scale=0.004)
    w, is_inh = spec.weight_matrix()
    n = spec.n_neurons
    report("microcircuit/neurons", n, f"scale={spec.scale}")
    report("microcircuit/synapses", int((w != 0).sum()), "")

    # single-shard LIF loop throughput (jit, steady state)
    p = lif.LIFParams()
    w_exc = jnp.asarray(np.where(~is_inh[None, :], w, 0.0))
    w_inh = jnp.asarray(np.where(is_inh[None, :], w, 0.0))
    bg = jnp.asarray(spec.bg_rates())

    @jax.jit
    def step(state, key):
        exc_in = w_exc @ state[-1] + lif.poisson_input(key, n, bg, 87.8, p.dt)
        inh_in = w_inh @ state[-1]
        st = lif.LIFState(*state[:4])
        st, spk = lif.step(st, p, exc_in, inh_in)
        return (st.v, st.i_exc, st.i_inh, st.refrac,
                spk.astype(jnp.float32)), spk

    state = lif.init_state(n, p, jax.random.PRNGKey(0))
    carry = (state.v, state.i_exc, state.i_inh, state.refrac,
             jnp.zeros(n))
    # warmup + timed
    for i in range(10):
        carry, _ = step(carry, jax.random.PRNGKey(i))
    jax.block_until_ready(carry)
    t0 = time.perf_counter()
    spikes = 0
    T = 200
    for i in range(T):
        carry, spk = step(carry, jax.random.PRNGKey(100 + i))
        spikes += int(spk.sum())
    jax.block_until_ready(carry)
    dt_wall = time.perf_counter() - t0
    us_per_step = dt_wall / T * 1e6
    bio_ms = T * p.dt
    report("microcircuit/us_per_dt_step", round(us_per_step, 1),
           f"{dt_wall / (bio_ms / 1e3):.1f}x slower than biology at "
           f"scale={spec.scale} (CPU)")
    rate = spikes / (n * T * p.dt * 1e-3)
    report("microcircuit/mean_rate_hz", round(rate, 1),
           "reduced-scale dynamics (communication test, not rate-faithful)")

    # communication profile per flush window (8 steps)
    part = network.build_partition(w, is_inh, n_shards=4)
    rates = np.full(part.n_neurons, rate)
    traffic = network.traffic_matrix(part, rates)
    report("microcircuit/cross_shard_Bps", round(float(traffic.sum()), 1),
           f"4 shards; max pair={traffic.max():.1f}")
    # window aggregation efficiency at this rate
    ev_per_window = rate * 1e-3 * 0.8 * part.n_neurons  # 0.8ms window
    counts = np.random.default_rng(0).multinomial(
        max(int(ev_per_window), 1), np.ones(4) / 4)
    cost = agg.window_cost(jnp.asarray(counts))
    un = agg.unaggregated_cost(int(ev_per_window))
    report("microcircuit/window_wire_eff", round(float(cost.efficiency), 3),
           f"vs unaggregated {float(un.efficiency):.3f}")
