"""Paper §4 — the named target workload: multi-wafer cortical microcircuit.

Runs the full windowed simulator (LIF dynamics + fused route/aggregate +
credit-throttled torus3d exchange) on the reduced-scale cortical
microcircuit over 8 forced host devices arranged as a 2x2x2 wafer torus,
under a **fault matrix**: no-fault baseline, one cable permanently dead,
a flapping cable, and a dropped wafer node (``repro.fabric.faults``).
Each row of ``BENCH_microcircuit.json`` carries the measured
biological-real-time slowdown, the delivery ratio, detour (reroute)
counts and the p99 latency degradation against the no-fault baseline —
the chaos-engineering counterpart of the paper's commissioning runs.

Needs 8 devices, so the timed work runs in a subprocess with
``xla_force_host_platform_device_count=8`` (the harness process has
already initialized single-device jax), like ``bench_transport``.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r'''
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))
import json, sys, time
import jax, numpy as np
from repro.fabric import healthy, link_fault, link_flap, node_fault
from repro.snn import microcircuit as mc, network, simulator as sim

params = json.loads(sys.argv[1])
scale, n_win, iters = params["scale"], params["windows"], params["iters"]
cap, cred = params["capacity"], params["credits"]
spec = mc.MicrocircuitSpec(scale=scale)
w, is_inh = spec.weight_matrix()
part = network.build_partition(w, is_inh, n_shards=8)
mesh = jax.make_mesh((8,), ("wafer",))
dims = (2, 2, 2)
cfg = sim.SimConfig(n_shards=8, per_shard=part.per_shard,
                    max_fan=part.fanout.shape[1], window=8, ring_len=32,
                    e_max=512, capacity=cap, transport="torus3d",
                    torus_nx=dims[0], torus_ny=dims[1], torus_nz=dims[2],
                    link_credits=cred, notify_latency=2)
# faults start at window 2 so the pipeline is warm when the cable dies
matrix = [
    ("no_fault",  healthy(dims, n_win)),
    ("link_down", link_fault(dims, n_win, 0, 0, start=2)),
    ("link_flap", link_flap(dims, n_win, 0, 0, period=2, start=2)),
    ("node_down", node_fault(dims, n_win, 3, start=2)),
]
bio_s = n_win * cfg.window * cfg.params.dt * 1e-3     # dt is ms
trace_dir = params.get("trace_dir")
rows = []
for name, sched in matrix:
    init, run = sim.build_sharded_sim(mesh, "wafer", cfg, part,
                                      spec.bg_rates(),
                                      fault_schedule=sched)
    st, stats = run(init(0), n_win)                   # compile + warmup
    jax.block_until_ready((st, stats))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        st, stats = run(init(0), n_win)
        jax.block_until_ready((st, stats))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    med_s = ts[len(ts) // 2]
    s = jax.tree_util.tree_map(np.asarray, stats)
    link = s.link
    offered = int(link.offered_events.sum())
    delivered = int(link.delivered_events.sum())
    rows.append({
        "fault": name,
        "mesh": "%dx%dx%d" % dims,
        "shape": "S=8 scale=%g W=%d C=%d credits=%d" % (scale, n_win,
                                                        cap, cred),
        "median_ms": med_s * 1e3 / n_win,
        "events_per_s": delivered / med_s if med_s > 0 else 0.0,
        "bio_slowdown": round(med_s / bio_s, 1),
        "spikes": int(s.spikes.sum()),
        "delivery_ratio": round(delivered / max(offered, 1), 4),
        "rerouted": int(link.rerouted.sum()),
        "parked": int(link.parked_events.sum()),
        "deferred": int(link.deferred_events.sum()),
        "deadline_miss": int(s.deadline_miss.sum()),
        "latency_p99_us": round(float(s.latency.p99_us.max()), 3),
    })
    if trace_dir:
        # untimed flight-recorder pass: same config + fault schedule with
        # the telemetry ring in the carry, decoded into an observability
        # run directory (render: python -m repro.obs.report <dir>)
        from repro import obs
        from repro.fabric import faults as fabric_faults
        from repro.obs import metrics as obs_metrics
        from repro.obs import report as obs_report
        init_r, run_r = sim.build_sharded_sim(
            mesh, "wafer", cfg, part, spec.bg_rates(), fault_schedule=sched,
            recorder=obs.RecorderConfig(depth=max(n_win, 8)))
        st_r, stats_r, ring = run_r(init_r(0), n_win)
        reg = obs_metrics.Registry()
        obs_metrics.export_link_stats(
            reg, jax.tree_util.tree_map(np.asarray, stats_r.link),
            backend="torus3d")
        obs_report.write_run_dir(
            os.path.join(trace_dir, "obs_microcircuit_%s" % name),
            meta={"kind": "microcircuit", "dims": list(dims),
                  "n_shards": 8, "fault": name, "windows": n_win,
                  "window_us": cfg.window * cfg.params.dt * 1e3,
                  "link_credits": cred},
            recorder_rows=obs.global_rows(ring, 8),
            fault_events=fabric_faults.transitions(sched),
            registry=reg)
base = rows[0]
for r in rows:
    r["p99_degradation"] = round(
        r["latency_p99_us"] / max(base["latency_p99_us"], 1e-9), 3)
    r["delivery_vs_healthy"] = round(
        r["delivery_ratio"] / max(base["delivery_ratio"], 1e-9), 4)
print("BENCH_JSON " + json.dumps(rows))
'''


def main(report) -> None:
    from repro.snn import microcircuit as mc
    params = {
        "scale": 0.003 if report.smoke else 0.01,
        "windows": 8 if report.smoke else 40,
        "iters": 1 if report.smoke else 3,
        "capacity": 32 if report.smoke else 48,
    }
    # throttled to the bucket capacity: the admission invariant's floor
    # and low enough that faults actually contend for detour credits
    params["credits"] = params["capacity"]
    if report.trace_dir:
        params["trace_dir"] = os.path.abspath(report.trace_dir)
    spec = mc.MicrocircuitSpec(scale=params["scale"])
    report("microcircuit/neurons", spec.n_neurons, f"scale={spec.scale}")
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT, json.dumps(params)],
        capture_output=True, text=True, timeout=2400, env=env)
    if out.returncode != 0:
        raise RuntimeError(
            f"bench_microcircuit subprocess failed:\n"
            f"{out.stdout}\n{out.stderr}")
    line = [l for l in out.stdout.splitlines()
            if l.startswith("BENCH_JSON ")][0]
    for row in json.loads(line[len("BENCH_JSON "):]):
        extra = {k: row[k] for k in (
            "fault", "mesh", "bio_slowdown", "spikes", "delivery_ratio",
            "delivery_vs_healthy", "rerouted", "parked", "deferred",
            "deadline_miss", "latency_p99_us", "p99_degradation")}
        report.bench(
            "microcircuit", row["fault"],
            f"mesh={row['mesh']} {row['shape']}",
            row["median_ms"], row["events_per_s"],
            notes=(f"bio x{row['bio_slowdown']} "
                   f"delivery={row['delivery_ratio']} "
                   f"rerouted={row['rerouted']}"),
            extra=extra)
