"""Paper §3.1 — event-aggregation throughput vs bucket size.

Reproduces the paper's central quantitative claim: single 30-bit events can
only be shifted out at one event per two 210 MHz clocks due to header
overhead, while events arrive at up to one per clock; bucket aggregation
(up to 124 events / 496 B per Extoll packet) restores line rate.

Columns: events/packet, wire efficiency, drain rate (events/cycle),
sustainable input rate, plus a closed-loop cycle-model measurement of
delivered throughput with/without aggregation, plus wall-clock of the
window-aggregation impls (onehot reference vs fused sort-based hot path)
recorded into BENCH_kernels.json.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregator as agg
from repro.core import bucket as bk
from repro.core import events as ev


def analytic_rows():
    rows = []
    for n in (1, 2, 4, 8, 16, 31, 62, 124):
        eff = float(ev.wire_efficiency(n))
        cyc = int(ev.wire_cycles(n))
        rows.append({
            "events_per_packet": n,
            "wire_bytes": int(ev.packet_bytes(n)),
            "wire_efficiency": round(eff, 4),
            "drain_events_per_cycle": round(n / cyc, 3),
        })
    return rows


def model_throughput(aggregatable: bool, T: int = 2000, rate: float = 1.0,
                     seed: int = 0):
    """Closed-loop cycle model: offered load `rate` events/cycle; measure
    delivered events/cycle. aggregatable=False -> every event to a distinct
    destination (no aggregation possible), the paper's problem case."""
    n_dest = 256 if not aggregatable else 4
    cfg = bk.BucketConfig(n_buckets=8, capacity=124, n_dest=n_dest,
                          flush_margin=8 if aggregatable else 10_000,
                          queue=8)
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    if aggregatable:
        dests = jax.random.randint(k1, (T, 1), 0, n_dest)
        ts = (jnp.arange(T).reshape(T, 1) + 300) & ev.TS_MASK
    else:
        dests = (jnp.arange(T).reshape(T, 1) * 97) % n_dest   # all distinct
        ts = jnp.full((T, 1), 1, jnp.int32)                   # instantly due
    valid = jax.random.bernoulli(k2, rate, (T, 1))
    words = ev.pack(dests, ts, valid)
    st, out = bk.run_trace(cfg, words, dests)
    delivered = int(out.sent_count.sum())
    offered = int(np.asarray(ev.is_valid(words)).sum())
    stalled = int(out.stalled.sum())
    return delivered / T, offered / T, stalled / max(offered, 1)


def impl_walltimes(report, n: int = 4096, d: int = 64, c: int = 256):
    """Wall-clock of the aggregation impls at flush-window scale.

    The fused sort-based path must beat the seed ``onehot`` impl by >= 2x
    at (N=4096, D=64, C=256) on CPU — the PR-level acceptance bar; actual
    measured margin is far larger (see BENCH_kernels.json).
    """
    from benchmarks.run import median_ms
    k = jax.random.PRNGKey(0)
    words = ev.pack(jax.random.randint(k, (n,), 0, 1 << 12),
                    jax.random.randint(k, (n,), 0, 1 << 15))
    dests = jax.random.randint(jax.random.fold_in(k, 1), (n,), 0, d)
    guids = jnp.zeros((n,), jnp.int32)
    shape = f"N{n}_D{d}_C{c}"
    ms = {}
    for impl in ("onehot", "sort", "fused"):
        fn = jax.jit(lambda impl=impl: agg.aggregate(
            words, dests, guids, d, c, impl=impl))
        ms[impl] = median_ms(fn)
        report.bench("kernels", f"aggregate_{impl}", shape, ms[impl],
                     events_per_s=n / ms[impl] * 1e3)
    report(f"aggregation/impl/fused_speedup_vs_onehot/{shape}",
           round(ms["onehot"] / max(ms["fused"], 1e-9), 2),
           "acceptance bar: >= 2x on CPU backend")
    return ms


def main(report):
    for row in analytic_rows():
        report(f"aggregation/analytic/n={row['events_per_packet']}",
               row["drain_events_per_cycle"],
               f"eff={row['wire_efficiency']} bytes={row['wire_bytes']}")

    impl_walltimes(report)

    T = 400 if getattr(report, "smoke", False) else 2000
    t0 = time.perf_counter()
    thr_un, off_un, stall_un = model_throughput(False, T=T)
    t1 = time.perf_counter()
    thr_ag, off_ag, stall_ag = model_throughput(True, T=T)
    t2 = time.perf_counter()
    report("aggregation/model/unaggregated_events_per_cycle",
           round(thr_un, 4),
           f"offered={off_un:.2f}/cyc stallfrac={stall_un:.3f} "
           f"({(t1 - t0) * 1e6:.0f}us)")
    report("aggregation/model/aggregated_events_per_cycle",
           round(thr_ag, 4),
           f"offered={off_ag:.2f}/cyc stallfrac={stall_ag:.3f} "
           f"({(t2 - t1) * 1e6:.0f}us)")
    report("aggregation/model/speedup", round(thr_ag / max(thr_un, 1e-9), 2),
           "paper claim: >= 2x (1/2 evt/clk -> ~1 evt/clk)")

    # vectorized window path cost: same traffic, window aggregation
    N, D = 4096, 64
    k = jax.random.PRNGKey(0)
    words = ev.pack(jax.random.randint(k, (N,), 0, 1 << 12),
                    jax.random.randint(k, (N,), 0, 1 << 15))
    dests = jax.random.randint(jax.random.fold_in(k, 1), (N,), 0, D)
    b = agg.aggregate(words, dests, None, D, 256, impl="sort")
    cost = agg.window_cost(b.counts)
    un = agg.unaggregated_cost(N)
    report("aggregation/window/bytes_aggregated", int(cost.bytes),
           f"eff={float(cost.efficiency):.3f}")
    report("aggregation/window/bytes_unaggregated", int(un.bytes),
           f"eff={float(un.efficiency):.3f}")
    report("aggregation/window/byte_reduction",
           round(int(un.bytes) / max(int(cost.bytes), 1), 2),
           "headers amortized across 124-event packets")
