"""Paper §1 — Extoll link budget and BrainScaleS topology load.

The paper gives the raw numbers (12 lanes x 8.4 Gbit/s per link, 7 links
per Tourmalet, 48 FPGAs -> 8 concentrators per wafer) but no load analysis;
this bench derives one: what biological real-time factor the interconnect
sustains for the full-scale cortical microcircuit spread over N wafers,
with and without aggregation.
"""
from __future__ import annotations

import numpy as np

from repro.core import events as ev
from repro.core import torus
from repro.snn import microcircuit as mc


def exchange_walltime(report, n_events: int = 4096, capacity: int = 256):
    """Wall-clock of one full software flush window (fused route+aggregate
    + packed single all_to_all + multicast decode) on the local mesh."""
    import jax
    import jax.numpy as jnp
    from benchmarks.run import median_ms
    from repro.core import routing as rt
    from repro.core.exchange import make_exchange

    n_shards = 1                           # in-process mesh: 1 host device
    n_addr = 1 << 12
    mesh = jax.make_mesh((n_shards,), ("wafer",))
    projs = [rt.Projection(a, a + 1, dest_node=a % n_shards,
                           dest_links=[a % 8]) for a in range(n_addr)]
    t = rt.build_tables(n_addr, projs)
    tabs = rt.RoutingTables(t.dest_of_addr[None], t.guid_of_addr[None],
                            t.mcast_of_guid[None])
    k = jax.random.PRNGKey(0)
    words = ev.pack(jax.random.randint(k, (n_shards, n_events), 0, n_addr),
                    jax.random.randint(jax.random.fold_in(k, 1),
                                       (n_shards, n_events), 0, 1 << 15))
    run = make_exchange(mesh, "wafer", n_shards=n_shards, capacity=capacity,
                        n_addr_per_shard=n_addr)
    ms = median_ms(lambda: run(words, tabs))
    report.bench("link", "exchange_window",
                 f"S{n_shards}_N{n_events}_C{capacity}", ms,
                 events_per_s=n_events / ms * 1e3,
                 notes="fused route+aggregate, one packed all_to_all")


def main(report):
    link_bytes = torus.LINK_GBYTES * 1e9
    report("link/raw_GBps", round(torus.LINK_GBYTES, 2),
           "12 lanes x 8.4 Gbit/s")

    exchange_walltime(report)

    # full-scale microcircuit: 77k neurons, mean rate ~4 Hz biological;
    # BrainScaleS runs at 1e3-1e4 x biological speedup.
    n_neurons = int(mc.FULL_SIZES.sum())
    mean_rate_bio = 4.0
    for speedup in (1e3, 1e4):
        ev_per_s = n_neurons * mean_rate_bio * speedup
        # inter-wafer fraction ~ connections leaving a wafer (2 wafers,
        # random split: ~50% of the 0.3B synapses cross)
        cross_frac = 0.5
        cross_events = ev_per_s * cross_frac
        for aggregated, n_pkt in (("no", 1), ("yes", 124)):
            bytes_per_event = float(ev.packet_bytes(n_pkt)) / n_pkt
            gbytes = cross_events * bytes_per_event / 1e9
            links_needed = gbytes * 1e9 / link_bytes
            report(
                f"link/microcircuit/speedup={speedup:.0e}/agg={aggregated}",
                round(gbytes, 2),
                f"GB/s cross-wafer; {links_needed:.1f} links' worth",
            )

    # torus link load for the wafer topology (paper Fig. 1)
    for n_wafers in (2, 4, 8):
        t = torus.wafer_topology(n_wafers)
        traffic = torus.microcircuit_traffic(
            t.n_nodes, events_per_s=n_neurons * mean_rate_bio * 1e4)
        max_load = t.max_link_load(traffic)
        report(f"link/torus/wafers={n_wafers}/max_link_GBps",
               round(max_load / 1e9, 3),
               f"nodes={t.n_nodes} mean_hops={t.mean_hops():.2f} "
               f"bisection={t.bisection_gbytes():.0f}GB/s")
