"""Streaming multi-tenant serving engine under open-loop Poisson load.

Runs the spike serving engine (``repro.serve.spike_engine``) on 8 forced
host devices in a subprocess (the ``bench_transport``/``bench_wire``
pattern): 2 tenants multiplexed onto one credit-partitioned ``torus3d``
fabric, seeded open-loop Poisson traffic with a bursty saturating hot
tenant next to a quiet reserved-slice tenant.

Rows in ``BENCH_serve.json``:

* ``engine/sustained`` — end-to-end sustained delivered events/s across
  all tenants (ingest thread + staging + windowed device segments +
  drain), wall-clock measured after a compile warmup.
* ``tenant/<name>`` — per-tenant delivered events/s and latency digest
  (p50/p99/max/mean us from the merged log-bin histogram), plus the
  conservation fields (injected/delivered/shed/clipped).
* ``qos/quiet_p99`` — the isolation claim as a number: the quiet
  tenant's p99 with the hot co-tenant saturating the fabric, divided by
  its p99 from a solo run offered IDENTICAL traffic (per-(tenant,
  window) RNG substreams make the two runs event-for-event comparable).
  The factor must stay within ``QOS_P99_BOUND``; the bench fails loudly
  otherwise, so a committed artifact always carries a passing QoS row.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

# quiet-tenant p99 under a saturating co-tenant may not exceed its solo
# p99 by more than this factor (2 log-2 histogram bins: the bounded
# queueing-dwell coupling, never whole deferred windows)
QOS_P99_BOUND = 4.0

SCRIPT = r'''
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))
import json, sys
import numpy as np
import jax
from jax.sharding import Mesh

from repro.serve.loadgen import PoissonLoadGen, TenantProfile
from repro.serve.spike_engine import EngineConfig, SpikeEngine
from repro.serve.tenancy import TenantSpec, guaranteed_epw

params = json.loads(sys.argv[1])
C = params["capacity"]
segments = params["segments"]
n = 8
mesh = Mesh(np.array(jax.devices()[:n]), ("w",))
cfg = EngineConfig(capacity=C, link_credits=params["link_credits"],
                   notify_latency=2, window_us=100.0,
                   seg_windows=params["seg_windows"], nx=2, ny=2, nz=2)
tenants = [TenantSpec("quiet", reserve=params["quiet_reserve"],
                      rate_epw=params["quiet_rate"]),
           TenantSpec("hot", reserve=params["hot_reserve"],
                      rate_epw=params["hot_rate"])]

def run(hot_rate, instrument=False):
    profiles = [TenantProfile("quiet", params["quiet_rate"]),
                TenantProfile("hot", hot_rate, burst_factor=3.0,
                              burst_prob=0.25)]
    src = PoissonLoadGen(params["seed"], profiles, n, C)
    kw = {}
    if instrument:
        from repro.obs import recorder as obs_recorder
        from repro.obs import spans as obs_spans
        kw = dict(recorder=obs_recorder.RecorderConfig(
                      depth=max(segments * params["seg_windows"] + 16, 32)),
                  tracer=obs_spans.Tracer())
    eng = SpikeEngine(mesh, "w", tenants, cfg, src, **kw)
    eng.warmup()
    return eng, eng.run(segments)

_, solo = run(0.0)                  # quiet tenant alone on the fabric
_, rep = run(params["hot_rate"])    # + saturating bursty co-tenant

trace_dir = params.get("trace_dir")
if trace_dir:
    # untimed instrumented re-run of the contended case: flight recorder
    # in the device carry + Perfetto span tracing on the host threads,
    # decoded into an observability run directory
    from repro.obs import report as obs_report
    eng_t, rep_t = run(params["hot_rate"], instrument=True)
    obs_report.write_engine_run(
        os.path.join(trace_dir, "obs_serve_contended"), eng_t, rep_t)

rows = []
shape = "S=8 T=2 C={} W={}".format(C, rep.windows)
wall_ms = rep.wall_s * 1e3
rows.append({
    "op": "engine/sustained", "shape": shape,
    "median_ms": wall_ms / max(rep.windows, 1),
    "events_per_s": rep.events_per_s,
    "windows": rep.windows, "drain_windows": rep.drain_windows,
    "mesh": "2x2x2", "link_credits": params["link_credits"],
    "notify_latency": 2,
    "conservation": "injected==delivered+shed (checked)",
})
for t, d in enumerate(rep.tenants):
    rows.append({
        "op": "tenant/" + d.name, "shape": shape,
        "median_ms": wall_ms / max(rep.windows, 1),
        "events_per_s": d.delivered / rep.wall_s,
        "reserve": tenants[t].reserve,
        "guaranteed_epw_per_link": guaranteed_epw(tenants[t], 2),
        "offered_epw": (params["quiet_rate"], params["hot_rate"])[t],
        "injected": int(rep.injected[t]), "delivered": int(rep.delivered[t]),
        "shed": int(rep.shed[t]), "clipped": int(rep.clipped[t]),
        "latency_p50_us": d.p50_us, "latency_p99_us": d.p99_us,
        "latency_max_us": round(d.max_us, 3),
        "latency_mean_us": round(d.mean_us, 3),
    })

q_solo = solo.tenants[0]
q_cont = rep.tenants[0]
factor = q_cont.p99_us / max(q_solo.p99_us, 1e-9)
rows.append({
    "op": "qos/quiet_p99", "shape": shape, "median_ms": 0.0,
    "solo_p99_us": q_solo.p99_us, "contended_p99_us": q_cont.p99_us,
    "solo_p50_us": q_solo.p50_us, "contended_p50_us": q_cont.p50_us,
    "factor": round(factor, 3), "bound": params["bound"],
    "hot_offered_epw": params["hot_rate"],
    "identical_quiet_traffic": bool(
        solo.injected[0] == rep.injected[0]),
})
assert solo.injected[0] == rep.injected[0], "quiet substream diverged"
assert factor <= params["bound"], (
    "QoS violated: quiet p99 %.1fus contended vs %.1fus solo "
    "(factor %.2f > bound %.1f)" % (q_cont.p99_us, q_solo.p99_us,
                                    factor, params["bound"]))
print("BENCH_JSON " + json.dumps(rows))
'''


def main(report) -> None:
    # the p99 bound is a contract about a tenant whose offered load fits
    # its guaranteed slice: quiet's reserve must cover its per-link BURST
    # load (Poisson tails, multiplied by multi-hop credit spend), not
    # just its mean — solo it could borrow burst room from the shared
    # pool, contended the hot tenant owns that pool
    params = {
        "capacity": 16 if report.smoke else 32,
        "seg_windows": 4 if report.smoke else 8,
        "segments": 3 if report.smoke else 24,
        "link_credits": 64,
        "quiet_reserve": 32,
        "hot_reserve": 8,
        "quiet_rate": 40.0,
        "hot_rate": 200.0 if report.smoke else 600.0,
        "seed": 7,
        "bound": QOS_P99_BOUND,
    }
    if report.trace_dir:
        params["trace_dir"] = os.path.abspath(report.trace_dir)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT, json.dumps(params)],
        capture_output=True, text=True, timeout=1800, env=env)
    if out.returncode != 0:
        raise RuntimeError(
            f"bench_serve subprocess failed:\n{out.stdout}\n{out.stderr}")
    line = [l for l in out.stdout.splitlines()
            if l.startswith("BENCH_JSON ")][0]
    for row in json.loads(line[len("BENCH_JSON "):]):
        extra = {k: row[k] for k in row
                 if k not in ("op", "median_ms", "events_per_s", "shape")}
        notes = ""
        if row["op"].startswith("tenant/"):
            notes = (f"p99={row['latency_p99_us']}us "
                     f"shed={row['shed']}")
        elif row["op"].startswith("qos/"):
            notes = f"factor={row['factor']} bound={row['bound']}"
        report.bench("serve", row["op"], row["shape"], row["median_ms"],
                     row.get("events_per_s"), notes=notes, extra=extra)
