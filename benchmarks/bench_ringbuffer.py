"""Paper §2.1 — ring-buffer credit flow control: throughput vs buffer size
and notification latency (the sizing curve the hardware team needs)."""
from __future__ import annotations

from repro.core import flow_control as fc


def main(report):
    steps = 2000
    for lat in (4, 8, 16):
        for size in (2, 4, 8, 16, 32, 64):
            _, stats = fc.run(fc.RingConfig(size=size, notify_latency=lat),
                              steps, produce_rate=1.0, consume_rate=1)
            thr = int(stats.produced) / steps
            bound = min(1.0, size / (lat + 1))
            report(f"ringbuffer/lat={lat}/size={size}", round(thr, 3),
                   f"credit-loop bound~{bound:.2f} stalls={int(stats.stalls)}")

    # notification batching trade-off (fewer notifications vs credit lag)
    for batch in (1, 4, 16):
        _, stats = fc.run(
            fc.RingConfig(size=32, notify_latency=8, notify_batch=batch),
            steps, produce_rate=1.0, consume_rate=1)
        report(f"ringbuffer/notify_batch={batch}",
               round(int(stats.produced) / steps, 3),
               "batched notifications amortize PCIe writes")
