"""Wire-layer head-to-head: extoll vs ethernet protocol profiles on every
transport backend (the paper's §1 claim, now quantitative).

For each (backend, profile) pair one full exchange window runs on 8
forced host devices (subprocess, like ``bench_transport``): fused
route+aggregate, 64-bit wire-word codec, transport, multicast decode.
Each row reports median wall-clock, events/s, the frame-exact
``bytes_on_wire``, wire efficiency (= event payload bytes / bytes on
wire, per traversed hop) and the per-window wire-latency percentiles
from ``ExchangeOut.latency`` — so ``BENCH_wire.json`` holds the
Ethernet-vs-Extoll comparison as machine-readable numbers: the extoll
profile must show strictly higher wire efficiency and lower latency on
every backend.

A codec microbenchmark row (pack+unpack round-trip wall-clock) rides
along, since the codec is new hot-path work the exchange now pays, and
a congested ``torus3d+credits`` multi-window row (FabricState threaded
through a ``lax.scan``) measures the latency model's congestion terms:
its p99 must sit strictly above the uncongested torus3d row's while the
uncongested p50 is untouched.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks._fabric_study import STUDY_SNIPPET

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r'''
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))
import json, sys, time
import jax, jax.numpy as jnp, numpy as np
from repro import wire
from repro.core import events as ev, routing as rt
from repro.core.exchange import make_exchange
from repro.launch.mesh import make_wafer_mesh, wafer_torus_shape

params = json.loads(sys.argv[1])
n_shards, n_addr = 8, 1024
N, C, iters = params["n"], params["c"], params["iters"]
mesh = make_wafer_mesh(n_shards)
nx, ny = wafer_torus_shape(n_shards)
n3 = wafer_torus_shape(n_shards, ndim=3)
tabs = []
for s in range(n_shards):
    projs = [rt.Projection(a, a + 1, dest_node=(a * 7 + s) % n_shards,
                           dest_links=[a % 3]) for a in range(n_addr)]
    tabs.append(rt.build_tables(n_addr, projs, n_guid=64))
stacked = rt.RoutingTables(
    dest_of_addr=jnp.stack([t.dest_of_addr for t in tabs]),
    guid_of_addr=jnp.stack([t.guid_of_addr for t in tabs]),
    mcast_of_guid=jnp.stack([t.mcast_of_guid for t in tabs]))
words = ev.pack(
    jax.random.randint(jax.random.PRNGKey(0), (n_shards, N), 0, n_addr),
    jax.random.randint(jax.random.PRNGKey(1), (n_shards, N), 0, 1000))

def median_ms(fn, *args):
    jax.tree_util.tree_leaves(fn(*args))[0].block_until_ready()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.tree_util.tree_leaves(out)[0].block_until_ready()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e3

def hops_matrix(backend, meshdims):
    ids = np.arange(n_shards)
    if backend == "alltoall":
        return (ids[:, None] != ids[None, :]).astype(np.int64)
    from repro.core.torus import Torus
    pad = tuple(meshdims) + (1,) * (3 - len(meshdims))
    host = Torus(nx=pad[0], ny=pad[1], nz=pad[2])
    return host.hops(ids[:, None], ids[None, :]).astype(np.int64)

rows = []
cases = [("alltoall", None, (), "crossbar"),
         ("torus2d", {"nx": nx, "ny": ny}, (nx, ny), "%dx%d" % (nx, ny)),
         ("torus3d", {"nx": n3[0], "ny": n3[1], "nz": n3[2]}, n3,
          "%dx%dx%d" % n3)]
for backend, opts, meshdims, meshname in cases:
    for profile in ("extoll", "ethernet"):
        run = make_exchange(mesh, "wafer", n_shards=n_shards, capacity=C,
                            n_addr_per_shard=n_addr, transport=backend,
                            transport_opts=dict(opts) if opts else None,
                            wire_format=profile)
        out = run(words, stacked)
        med = median_ms(run, words, stacked)
        sent = int(np.asarray(out.link.sent_events).sum())
        on_wire = int(np.asarray(out.link.bytes_on_wire).sum())
        # every traversed hop re-serializes the row's 8-byte words, so
        # wire efficiency = per-hop payload bytes / frame-exact wire bytes
        cnt = (np.asarray(out.sent_counts)
               * np.asarray(out.sent_mask)).astype(np.int64)
        payload = int((cnt * hops_matrix(backend, meshdims)).sum()) * 8
        rows.append({
            "backend": backend,
            "wire_format": profile,
            "mesh": meshname,
            "shape": "S=8 N={} C={}".format(N, C),
            "median_ms": med,
            "events_per_s": sent / (med * 1e-3) if med > 0 else 0.0,
            "bytes_on_wire": on_wire,
            "wire_efficiency": round(payload / max(on_wire, 1), 4),
            "latency_p50_us": round(
                float(np.asarray(out.latency.p50_us).max()), 3),
            "latency_p99_us": round(
                float(np.asarray(out.latency.p99_us).max()), 3),
            "latency_max_us": round(
                float(np.asarray(out.latency.max_us).max()), 3),
        })

# congestion row: torus3d under sustained credit-throttled windows (the
# FabricState threads a lax.scan), extoll profile — parked rows resume
# mid-route and the queueing term pushes p99 up while the uncongested
# p50 above stays at the serialization-only charge
''' + STUDY_SNIPPET + r'''
cr = max(N // 8, C)
run_c = make_study("torus3d", {"nx": n3[0], "ny": n3[1], "nz": n3[2],
                               "link_credits": cr,
                               "wire_format": "extoll"})
link, lat = run_c()
med = median_ms(run_c)
link = jax.tree_util.tree_map(np.asarray, link)
sent = int(link.sent_events.sum() + link.unparked_events.sum())
rows.append({
    "backend": "torus3d+credits*%dwin" % N_WIN,
    "wire_format": "extoll",
    "mesh": "%dx%dx%d" % n3,
    "shape": "S=8 N={} C={} W={}".format(N, C, N_WIN),
    "median_ms": med / N_WIN,
    "events_per_s": sent / (med * 1e-3) if med > 0 else 0.0,
    "bytes_on_wire": int(link.bytes_on_wire.sum()),
    "parked": int(link.parked_events.sum()),
    "unparked": int(link.unparked_events.sum()),
    "dwell_us": round(float(link.queue_dwell_us.sum()), 3),
    # worst delivering window: late saturated windows may deliver nothing
    # at all (empty digest), so take the max over windows
    "latency_p50_us": round(float(np.asarray(lat.p50_us).max()), 3),
    "latency_p99_us": round(float(np.asarray(lat.p99_us).max()), 3),
    "latency_max_us": round(float(np.asarray(lat.max_us).max()), 3),
})

# codec microbenchmark: pack+unpack round trip at window scale
meta = jnp.arange(n_shards * N, dtype=jnp.int32).reshape(n_shards, N)
rt_fn = jax.jit(lambda w, m: wire.decode_planar(wire.encode_planar(w, m)))
med = median_ms(rt_fn, words, meta)
rows.append({
    "backend": "codec", "wire_format": "64bit-word",
    "mesh": "-", "shape": "S=8 N={}".format(N), "median_ms": med,
    "events_per_s": n_shards * N / (med * 1e-3) if med > 0 else 0.0,
})
print("BENCH_JSON " + json.dumps(rows))
'''


def main(report) -> None:
    params = {
        "n": 512 if report.smoke else 4096,
        "c": 64 if report.smoke else 256,
        "iters": 5 if report.smoke else 15,
        "windows": 4 if report.smoke else 6,
    }
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT, json.dumps(params)],
        capture_output=True, text=True, timeout=1800, env=env)
    if out.returncode != 0:
        raise RuntimeError(
            f"bench_wire subprocess failed:\n{out.stdout}\n{out.stderr}")
    line = [l for l in out.stdout.splitlines()
            if l.startswith("BENCH_JSON ")][0]
    for row in json.loads(line[len("BENCH_JSON "):]):
        op = f"{row['backend']}/{row['wire_format']}"
        extra = {k: row[k] for k in row
                 if k not in ("median_ms", "events_per_s", "shape")}
        notes = ""
        if "wire_efficiency" in row:
            notes = (f"eff={row['wire_efficiency']} "
                     f"p50={row['latency_p50_us']}us")
        report.bench(
            "wire", op, f"mesh={row['mesh']} {row['shape']}",
            row["median_ms"], row["events_per_s"], notes=notes, extra=extra)
