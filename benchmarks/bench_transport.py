"""Flush-window transport head-to-head: alltoall vs torus2d vs torus3d
(paper §1/§3).

One exchange window (fused route+aggregate + ship + multicast) per
backend on 8 shards — crossbar, (2, 4) 2-D torus and (2, 2, 2) 3-D torus
— plus credit-throttled torus variants so the hop-by-hop stall path is
exercised, plus a multi-window congestion study (FabricState threaded
across a scan of sustained windows) so the in-fabric transit buffers
show rows parking mid-route AND resuming: the study row carries
``parked`` / ``unparked`` / ``hop0_reentries`` / ``dwell_us`` /
``latency_p99_us``.  Needs 8 devices, so the timed work runs in a
subprocess with ``xla_force_host_platform_device_count=8`` (the harness
process has already initialized single-device jax); results feed
``BENCH_transport.json`` with backend, mesh shape, median_ms,
events_per_s and credit_stalls per row (see docs/benchmarks.md for the
full schema).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks._fabric_study import STUDY_SNIPPET

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r'''
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))
import json, sys, time
import jax, jax.numpy as jnp, numpy as np
from repro.core import events as ev, routing as rt
from repro.core.exchange import make_exchange
from repro.launch.mesh import make_wafer_mesh, wafer_torus_shape

params = json.loads(sys.argv[1])
n_shards, n_addr = 8, 1024
N, C, iters = params["n"], params["c"], params["iters"]
mesh = make_wafer_mesh(n_shards)
nx, ny = wafer_torus_shape(n_shards)
n3 = wafer_torus_shape(n_shards, ndim=3)
tabs = []
for s in range(n_shards):
    projs = [rt.Projection(a, a + 1, dest_node=(a * 7 + s) % n_shards,
                           dest_links=[a % 3]) for a in range(n_addr)]
    tabs.append(rt.build_tables(n_addr, projs, n_guid=64))
stacked = rt.RoutingTables(
    dest_of_addr=jnp.stack([t.dest_of_addr for t in tabs]),
    guid_of_addr=jnp.stack([t.guid_of_addr for t in tabs]),
    mcast_of_guid=jnp.stack([t.mcast_of_guid for t in tabs]))
words = ev.pack(
    jax.random.randint(jax.random.PRNGKey(0), (n_shards, N), 0, n_addr),
    jax.random.randint(jax.random.PRNGKey(1), (n_shards, N), 0, 1000))

def median_ms(fn, *args):
    jax.tree_util.tree_leaves(fn(*args))[0].block_until_ready()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.tree_util.tree_leaves(out)[0].block_until_ready()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e3

rows = []
cr = params["credits"]
cases = [("alltoall", None, "", "crossbar"),
         ("torus2d", {"nx": nx, "ny": ny}, "", "%dx%d" % (nx, ny)),
         ("torus2d", {"nx": nx, "ny": ny, "link_credits": cr},
          "+credits", "%dx%d" % (nx, ny)),
         ("torus3d", {"nx": n3[0], "ny": n3[1], "nz": n3[2]}, "",
          "%dx%dx%d" % n3),
         ("torus3d", {"nx": n3[0], "ny": n3[1], "nz": n3[2],
                      "link_credits": cr}, "+credits", "%dx%dx%d" % n3)]
for backend, opts, tag, meshname in cases:
    run = make_exchange(mesh, "wafer", n_shards=n_shards, capacity=C,
                        n_addr_per_shard=n_addr, transport=backend,
                        transport_opts=opts)
    out = run(words, stacked)
    med = median_ms(run, words, stacked)
    sent = int(np.asarray(out.link.sent_events).sum())
    sbh = np.asarray(out.link.stalled_by_hop).sum(0)
    rows.append({
        "backend": backend + tag,
        "mesh": meshname,
        "shape": "S=8 N={} C={}".format(N, C),
        "median_ms": med,
        "events_per_s": sent / (med * 1e-3) if med > 0 else 0.0,
        "credit_stalls": int(np.asarray(out.link.credit_stalls).sum()),
        "hops": int(np.asarray(out.link.hops)[0]),
        "forwarded_bytes": int(np.asarray(out.link.forwarded_bytes).sum()),
        "stalled_by_hop": [int(v) for v in sbh],
        "parked": int(np.asarray(out.link.parked_events).sum()),
        "dwell_us": round(
            float(np.asarray(out.link.queue_dwell_us).sum()), 3),
    })

# congestion study: thread the FabricState across a scan of sustained
# windows so parked rows actually RESUME mid-route (a one-shot window can
# park but never unpark); stats are summed over windows, timing is the
# whole scan divided by n_windows
''' + STUDY_SNIPPET + r'''

study_opts = {"nx": n3[0], "ny": n3[1], "nz": n3[2], "link_credits": cr}
study_mesh = "%dx%dx%d" % n3
base_med = None
# the recorder variant threads the flight-recorder ring (+stall
# attribution) through the same scan; its events_per_s against the plain
# study row is the observability overhead bound docs/observability.md
# cites (<5%)
for depth, tag in [(None, ""), (N_WIN, "+recorder")]:
    run = make_study("torus3d", study_opts, recorder_depth=depth)
    out = run()
    link, lat = out[0], out[1]
    med = median_ms(run)
    link = jax.tree_util.tree_map(np.asarray, link)
    sent = int(link.sent_events.sum() + link.unparked_events.sum())
    sbh = link.stalled_by_hop.sum((0, 1))
    row = {
        "backend": "torus3d+credits%s*%dwin" % (tag, N_WIN),
        "mesh": study_mesh,
        "shape": "S=8 N={} C={} W={}".format(N, C, N_WIN),
        "median_ms": med / N_WIN,
        "events_per_s": sent / (med * 1e-3) if med > 0 else 0.0,
        "credit_stalls": int(link.credit_stalls.sum()),
        "hops": int(link.hops[0].sum()),
        "forwarded_bytes": int(link.forwarded_bytes.sum()),
        "stalled_by_hop": [int(v) for v in sbh],
        "parked": int(link.parked_events.sum()),
        "unparked": int(link.unparked_events.sum()),
        "hop0_reentries": int(link.deferred_events.sum()),
        "dwell_us": round(float(link.queue_dwell_us.sum()), 3),
        # worst delivering window: late saturated windows may deliver
        # nothing at all (empty digest), so take the max over windows
        "latency_p99_us": round(float(np.asarray(lat.p99_us).max()), 3),
    }
    if depth is None:
        base_med = med
    else:
        ring = jax.tree_util.tree_map(np.asarray, out[2])
        row["ring_windows"] = int(ring.cursor[0])
        row["recorder_overhead_pct"] = round(
            (med - base_med) / base_med * 100.0, 2) if base_med else 0.0
    rows.append(row)
print("BENCH_JSON " + json.dumps(rows))
'''


def main(report) -> None:
    params = {
        "n": 512 if report.smoke else 4096,
        "c": 64 if report.smoke else 256,
        "iters": 5 if report.smoke else 15,
        "windows": 4 if report.smoke else 6,
    }
    # throttle to roughly half the typical per-link demand so stalls
    # occur, but never below the bucket capacity (admission invariant)
    params["credits"] = max(params["n"] // 8, params["c"])
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT, json.dumps(params)],
        capture_output=True, text=True, timeout=1200, env=env)
    if out.returncode != 0:
        raise RuntimeError(
            f"bench_transport subprocess failed:\n{out.stdout}\n{out.stderr}")
    line = [l for l in out.stdout.splitlines()
            if l.startswith("BENCH_JSON ")][0]
    for row in json.loads(line[len("BENCH_JSON "):]):
        extra = {k: row[k] for k in (
            "backend", "mesh", "credit_stalls", "hops", "forwarded_bytes",
            "stalled_by_hop", "parked", "dwell_us", "unparked",
            "hop0_reentries", "latency_p99_us", "ring_windows",
            "recorder_overhead_pct") if k in row}
        report.bench(
            "transport", row["backend"], f"mesh={row['mesh']} {row['shape']}",
            row["median_ms"], row["events_per_s"],
            notes=f"stalls={row['credit_stalls']} parked={row['parked']}",
            extra=extra)
