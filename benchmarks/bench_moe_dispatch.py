"""Beyond-paper — the paper's bucket aggregation as MoE token dispatch.

Compares dispatch strategies at the deepseek-moe-16b geometry (64 experts,
top-6) on CPU wall-time at reduced width, and reports the modelled wire
cost of the two EP strategies at full scale:

  * gather-weights  (FSDP-style: all-gather expert weights to the tokens)
  * bucket-a2a      (paper-style: aggregate tokens by destination expert,
                     one all_to_all each way)

The crossing point is exactly the paper's insight: ship the small sparse
payloads (events/tokens), not the bulk (weights).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import MoEConfig
from repro.models import moe as M


def wall(fn, *args, iters=20):
    fn(*args)[0].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.tree_util.tree_leaves(out)[0].block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def main(report):
    # CPU-measurable reduced geometry
    moe = MoEConfig(n_experts=16, top_k=4, expert_ff=64, capacity_factor=1.5)
    d, T = 128, 1024
    key = jax.random.PRNGKey(0)
    params = {
        "router": 0.3 * jax.random.normal(key, (d, moe.n_experts)),
        "w_gate": jax.random.normal(jax.random.fold_in(key, 1),
                                    (moe.n_experts, d, moe.expert_ff)),
        "w_up": jax.random.normal(jax.random.fold_in(key, 2),
                                  (moe.n_experts, d, moe.expert_ff)),
        "w_down": jax.random.normal(jax.random.fold_in(key, 3),
                                    (moe.n_experts, moe.expert_ff, d)),
    }
    x = jax.random.normal(jax.random.fold_in(key, 4), (T, d))

    local = jax.jit(lambda x: M.moe_layer_local(x, params, moe))
    us = wall(local, x)
    y, stats = local(x)
    report("moe/local_dispatch_us", round(us, 1),
           f"T={T} E={moe.n_experts} k={moe.top_k} "
           f"dropped={float(stats.dropped):.3f}")

    # dense compute-all-experts baseline (what dispatch avoids)
    def dense_all(x):
        h = jax.nn.silu(jnp.einsum("td,edf->tef", x, params["w_gate"]))
        h = h * jnp.einsum("td,edf->tef", x, params["w_up"])
        y_all = jnp.einsum("tef,efd->ted", h, params["w_down"])
        probs, _ = M.router_probs(x, params["router"])
        return jnp.einsum("ted,te->td", y_all, probs), None

    us_dense = wall(jax.jit(dense_all), x)
    report("moe/dense_all_experts_us", round(us_dense, 1),
           f"computes all {moe.n_experts} experts per token")
    report("moe/dispatch_speedup", round(us_dense / us, 2),
           "capacity-binned dispatch vs dense")

    # full-scale wire model (deepseek-moe-16b on 16-way EP)
    cfg = get_config("deepseek_moe_16b")
    m = cfg.moe
    tokens_per_chip = 4096 * 16            # train_4k, data=16
    d_model = cfg.d_model
    a2a_bytes = 2 * tokens_per_chip * m.top_k * d_model * 2   # there + back
    w_bytes = (cfg.n_layers - m.first_dense) * 3 * d_model * m.expert_ff \
        * m.n_experts * 2 // 16 * 15 // 16   # gather 15/16 of expert weights
    report("moe/wire/bucket_a2a_GB_per_layer",
           round(a2a_bytes / 1e9, 3),
           f"tokens x top{m.top_k} x d{d_model} bf16, both directions")
    report("moe/wire/gather_weights_GB_per_layer",
           round(3 * d_model * m.expert_ff * m.n_experts * 2 * (15 / 16) / 1e9, 3),
           "all-gather 64 experts' mlps to every chip")
    report("moe/wire/bucket_advantage",
           round((3 * d_model * m.expert_ff * m.n_experts * 2 * (15 / 16))
                 / a2a_bytes, 2),
           "x fewer bytes moving tokens instead of weights (paper's insight)")
