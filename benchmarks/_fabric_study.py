"""Shared congestion-study harness for the benchmark subprocess scripts.

``STUDY_SNIPPET`` is spliced into the ``-c`` SCRIPT strings of
``bench_transport.py`` and ``bench_wire.py`` (both subprocesses define
``mesh``, ``words``, ``stacked``, ``n_shards``, ``C`` and ``params``
before it runs).  It builds ``make_study(backend, opts)`` — a jitted
shard_map whose ``lax.scan`` threads the transport's ``FabricState``
across ``N_WIN`` sustained windows of the same offered load, so parked
rows resume mid-route and the congestion terms of the latency model are
actually measured.  Keeping the harness in one place means the two BENCH
files can never diverge on the study methodology.
"""

STUDY_SNIPPET = r'''
from jax.sharding import PartitionSpec as _StudyP
from jax.experimental.shard_map import shard_map as _study_shard_map
from repro import transport as _study_tp
from repro.core.exchange import exchange_window as _study_xw
from repro.core.routing import RoutingTables as _StudyRT

N_WIN = params["windows"]

def make_study(backend, opts):
    """Jitted multi-window exchange scan -> (LinkStats, LatencySummary)
    stacked (n_shards, N_WIN, ...); stats summed over windows by callers."""
    tb = _study_tp.create(backend, n_shards=n_shards, max_row_events=C,
                          **opts)
    def body(w, d, g, m):
        tables = _StudyRT(d[0], g[0], m[0])
        def win(lstate, _):
            out = _study_xw(w[0], tables, axis_name="wafer",
                            n_shards=n_shards, capacity=C,
                            transport=tb, link_state=lstate)
            return out.link_state, (out.link, out.latency)
        _, stats = jax.lax.scan(win, tb.init_state(2 * C), None,
                                length=N_WIN)
        return jax.tree_util.tree_map(lambda x: x[None], stats)
    spec = _StudyP("wafer")
    fn = _study_shard_map(body, mesh=mesh, in_specs=(spec,) * 4,
                          out_specs=spec, check_rep=False)
    return jax.jit(lambda: fn(words, stacked.dest_of_addr,
                              stacked.guid_of_addr, stacked.mcast_of_guid))
'''
