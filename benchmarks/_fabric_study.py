"""Shared congestion-study harness for the benchmark subprocess scripts.

``STUDY_SNIPPET`` is spliced into the ``-c`` SCRIPT strings of
``bench_transport.py`` and ``bench_wire.py`` (both subprocesses define
``mesh``, ``words``, ``stacked``, ``n_shards``, ``C`` and ``params``
before it runs).  It builds ``make_study(backend, opts)`` — a jitted
shard_map whose ``lax.scan`` threads the transport's ``FabricState``
across ``N_WIN`` sustained windows of the same offered load, so parked
rows resume mid-route and the congestion terms of the latency model are
actually measured.  Keeping the harness in one place means the two BENCH
files can never diverge on the study methodology.

``make_study(..., recorder_depth=D)`` additionally threads a
``repro.obs.recorder`` telemetry ring through the scan (transport built
with ``stall_attribution=True``) and returns it as a third output — the
flight-recorder overhead row of ``BENCH_transport.json`` times exactly
this against the uninstrumented study.
"""

STUDY_SNIPPET = r'''
from jax.sharding import PartitionSpec as _StudyP
from jax.experimental.shard_map import shard_map as _study_shard_map
from repro import transport as _study_tp
from repro import wire as _study_wire
from repro.core.exchange import exchange_window as _study_xw
from repro.core.routing import RoutingTables as _StudyRT
from repro.obs import recorder as _study_rec

N_WIN = params["windows"]

def make_study(backend, opts, recorder_depth=None):
    """Jitted multi-window exchange scan -> (LinkStats, LatencySummary)
    stacked (n_shards, N_WIN, ...); stats summed over windows by callers.
    With recorder_depth set, the flight-recorder ring rides the carry and
    is returned third (stall attribution on)."""
    kw = dict(opts)
    if recorder_depth is not None:
        kw["stall_attribution"] = True
    tb = _study_tp.create(backend, n_shards=n_shards, max_row_events=C,
                          **kw)
    def body(w, d, g, m):
        tables = _StudyRT(d[0], g[0], m[0])
        if recorder_depth is None:
            def win(lstate, _):
                out = _study_xw(w[0], tables, axis_name="wafer",
                                n_shards=n_shards, capacity=C,
                                transport=tb, link_state=lstate)
                return out.link_state, (out.link, out.latency)
            _, stats = jax.lax.scan(win, tb.init_state(2 * C), None,
                                    length=N_WIN)
            return jax.tree_util.tree_map(lambda x: x[None], stats)
        def win(carry, i):
            lstate, ring = carry
            out = _study_xw(w[0], tables, axis_name="wafer",
                            n_shards=n_shards, capacity=C,
                            transport=tb, link_state=lstate)
            ring = _study_rec.record(ring, i, out.link, out.link_state,
                                     out.latency.hist)
            return (out.link_state, ring), (out.link, out.latency)
        lstate0 = tb.init_state(2 * C)
        ring0 = _study_rec.ring_init(
            recorder_depth, lstate0, (),
            (_study_wire.N_LATENCY_BINS,), lstate0.bank.credits.shape[0])
        (_, ring), stats = jax.lax.scan(win, (lstate0, ring0),
                                        jnp.arange(N_WIN))
        lift = lambda t: jax.tree_util.tree_map(lambda x: x[None], t)
        return lift(stats) + (lift(ring),)
    spec = _StudyP("wafer")
    n_out = 2 if recorder_depth is None else 3
    fn = _study_shard_map(body, mesh=mesh, in_specs=(spec,) * 4,
                          out_specs=(spec,) * n_out if n_out == 3 else spec,
                          check_rep=False)
    return jax.jit(lambda: fn(words, stacked.dest_of_addr,
                              stacked.guid_of_addr, stacked.mcast_of_guid))
'''
