"""Benchmark harness — one module per paper table/claim.

Usage:  PYTHONPATH=src python -m benchmarks.run [--only <name>]
Output: ``name,value,notes`` CSV rows on stdout.

Modules:
  bench_aggregation  paper §3.1 throughput claims (the central table)
  bench_link         paper §1 link budget / wafer torus loads
  bench_ringbuffer   paper §2.1 credit flow-control sizing
  bench_renaming     paper §3.1 bucket renaming pressure
  bench_microcircuit paper §4 target workload
  bench_moe_dispatch beyond-paper: bucket dispatch as MoE EP
  bench_kernels      Pallas kernel cost models
"""
from __future__ import annotations

import argparse
import sys
import time

MODULES = [
    "bench_aggregation",
    "bench_link",
    "bench_ringbuffer",
    "bench_renaming",
    "bench_microcircuit",
    "bench_moe_dispatch",
    "bench_kernels",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    def report(name, value, notes=""):
        print(f"{name},{value},{notes}")
        sys.stdout.flush()

    print("name,value,notes")
    for mod_name in MODULES:
        if args.only and args.only not in mod_name:
            continue
        mod = __import__(f"benchmarks.{mod_name}", fromlist=["main"])
        t0 = time.perf_counter()
        mod.main(report)
        report(f"{mod_name}/_wall_s", round(time.perf_counter() - t0, 1))


if __name__ == "__main__":
    main()
