"""Benchmark harness — one module per paper table/claim.

Usage:  PYTHONPATH=src python -m benchmarks.run [--only <name>] [--smoke]
                                                [--out-dir DIR]

For stable numbers, source the environment tuning first::

    . tools/env.sh && PYTHONPATH=src python -m benchmarks.run

``tools/env.sh`` preloads tcmalloc when present, pins OpenMP threading,
silences TF/XLA logging and sets ``--xla_step_marker_location`` so
profiles attribute time per flush window; everything in it is gated and
append-only, so it is safe on any machine.  The harness reports whether
it was sourced (the ``REPRO_BENCH_ENV`` sentinel) in the CSV header.
Output: ``name,value,notes`` CSV rows on stdout, plus machine-readable
``BENCH_<group>.json`` files (one JSON list of
``{op, shape, median_ms, events_per_s, ..., provenance}`` rows per group,
currently ``kernels``, ``link``, ``transport``, ``wire``, ``serve`` and
``microcircuit``) so the perf trajectory across PRs can be diffed without
parsing the CSV.  Every row carries a ``provenance`` block (git SHA +
dirty flag, jax/jaxlib versions, device count/platform, whether
``tools/env.sh`` was sourced); ``tools/check_docs.py`` rejects committed
artifacts without one.  ``--trace`` additionally writes observability
run directories (``repro.obs``: flight-recorder rows, Perfetto trace,
Prometheus metrics) for the modules that support it.

``--smoke`` runs a reduced module set with shrunk shapes — fast enough for
the tier-1 time budget while still producing all the JSON files.  Smoke
rows are stamped ``"smoke": true`` and must NEVER be committed: the
committed ``BENCH_*.json`` are full-shape numbers, and
``tools/check_docs.py`` fails CI if a smoke-stamped (or known
smoke-shaped) artifact lands in the repo root.  As a second belt,
``--smoke`` defaults ``--out-dir`` to ``/tmp/bench`` — a smoke run
executed from the repo root can no longer clobber the committed
artifacts unless the caller explicitly points it there.

Modules:
  bench_aggregation  paper §3.1 throughput claims (the central table)
  bench_link         paper §1 link budget / wafer torus loads
  bench_ringbuffer   paper §2.1 credit flow-control sizing
  bench_renaming     paper §3.1 bucket renaming pressure
  bench_microcircuit paper §4 target workload: the cortical microcircuit
                     on a credit-throttled 2x2x2 wafer torus under a
                     fault matrix (no-fault / link down / link flap /
                     node down) — bio-real-time slowdown, delivery ratio
                     and p99 degradation per fault case
  bench_moe_dispatch beyond-paper: bucket dispatch as MoE EP
  bench_kernels      Pallas kernel cost models
  bench_transport    alltoall vs torus2d vs torus3d flush-window backends
                     head-to-head (8 forced host devices in a subprocess;
                     rows carry backend, mesh shape, credit_stalls and the
                     hop-by-hop stall breakdown)
  bench_wire         extoll vs ethernet wire profiles on every backend:
                     frame-exact bytes_on_wire, wire efficiency and
                     latency percentiles (+ codec round-trip row)
  bench_serve        streaming multi-tenant serving engine under open-loop
                     Poisson load: sustained events/s, per-tenant latency
                     digests and the quiet-tenant p99 QoS isolation row
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from repro.obs import log as obs_log

MODULES = [
    "bench_aggregation",
    "bench_link",
    "bench_ringbuffer",
    "bench_renaming",
    "bench_microcircuit",
    "bench_moe_dispatch",
    "bench_kernels",
    "bench_transport",
    "bench_wire",
    "bench_serve",
]

SMOKE_MODULES = ["bench_aggregation", "bench_link", "bench_kernels",
                 "bench_transport", "bench_wire", "bench_serve",
                 "bench_microcircuit"]


def median_ms(fn, *args, iters: int = 15) -> float:
    """Median wall-clock of ``fn(*args)`` in ms (one warmup, then iters)."""
    import jax
    jax.tree_util.tree_leaves(fn(*args))[0].block_until_ready()
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.tree_util.tree_leaves(out)[0].block_until_ready()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e3


def provenance() -> dict:
    """The provenance block stamped into every BENCH_*.json row: enough
    to answer "what produced this number" when diffing the committed perf
    trajectory across PRs.  Computed once per harness run
    (``tools/check_docs.py`` rejects committed rows missing it)."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

    def git(*args: str) -> str:
        try:
            out = subprocess.run(["git", *args], capture_output=True,
                                 text=True, cwd=root, timeout=10)
            return out.stdout.strip() if out.returncode == 0 else ""
        except OSError:
            return ""

    import jax
    import jaxlib
    return {
        "git_sha": git("rev-parse", "--short=12", "HEAD") or "unknown",
        "git_dirty": bool(git("status", "--porcelain")),
        "jax": jax.__version__,
        "jaxlib": jaxlib.__version__,
        "devices": jax.device_count(),
        "platform": jax.default_backend(),
        "env_tuned": os.environ.get("REPRO_BENCH_ENV", "0") != "0",
    }


class Reporter:
    """CSV reporter (the historical ``report(name, value, notes)`` callable)
    plus a structured ``bench()`` collector feeding BENCH_<group>.json.
    Modules consult ``.smoke`` to shrink their workload and ``.trace_dir``
    (non-None when ``--trace`` is set) to write observability run
    directories next to the JSON artifacts."""

    def __init__(self, smoke: bool = False, trace_dir: str | None = None):
        self.smoke = smoke
        self.trace_dir = trace_dir
        self.provenance = provenance()
        self._groups: dict[str, list[dict]] = {}

    def __call__(self, name, value, notes=""):
        print(f"{name},{value},{notes}")
        sys.stdout.flush()

    def bench(self, group: str, op: str, shape: str, med_ms: float,
              events_per_s: float | None = None, notes: str = "",
              extra: dict | None = None):
        row = {"op": op, "shape": shape, "median_ms": round(med_ms, 6)}
        if self.smoke:
            row["smoke"] = True     # tools/check_docs.py refuses these
        if events_per_s is not None:
            row["events_per_s"] = round(events_per_s)
        if notes:
            row["notes"] = notes
        if extra:
            row.update(extra)
        row["provenance"] = self.provenance
        self._groups.setdefault(group, []).append(row)
        note = f"{row.get('events_per_s', '')} ev/s {notes}".strip()
        self(f"{group}/{op}/{shape}/median_ms", round(med_ms, 4), note)

    def dump(self, out_dir: str):
        log = obs_log.get_logger(__name__)
        os.makedirs(out_dir, exist_ok=True)
        for group, rows in self._groups.items():
            path = os.path.join(out_dir, f"BENCH_{group}.json")
            with open(path, "w") as f:
                json.dump(rows, f, indent=1)
                f.write("\n")
            log.info("wrote %s (%d rows)", path, len(rows))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="fast reduced run (tier-1 time budget)")
    ap.add_argument("--out-dir", default=None,
                    help="directory for BENCH_<group>.json files "
                         "(default: repo root for full runs, /tmp/bench "
                         "for --smoke so toy numbers can never clobber "
                         "the committed full-shape artifacts)")
    ap.add_argument("--trace", action="store_true",
                    help="write observability run directories (flight-"
                         "recorder rows, Perfetto trace, metrics) next to "
                         "the JSON artifacts for modules that support it")
    obs_log.add_log_args(ap)
    args = ap.parse_args()
    if args.out_dir is None:
        args.out_dir = "/tmp/bench" if args.smoke else "."
    # progress lines (module wall times, artifact writes) default to INFO
    # on stderr; stdout carries only the CSV / BENCH_JSON protocols
    obs_log.setup_logging("INFO", quiet=args.quiet, verbose=args.verbose)

    report = Reporter(smoke=args.smoke,
                      trace_dir=args.out_dir if args.trace else None)
    modules = SMOKE_MODULES if args.smoke else MODULES

    print("name,value,notes")
    report("env/tuned", int(os.environ.get("REPRO_BENCH_ENV", "0") != "0"),
           "1 when tools/env.sh was sourced (tcmalloc, OMP pinning, "
           "XLA step markers)")
    for mod_name in modules:
        if args.only and args.only not in mod_name:
            continue
        mod = __import__(f"benchmarks.{mod_name}", fromlist=["main"])
        t0 = time.perf_counter()
        mod.main(report)
        report(f"{mod_name}/_wall_s", round(time.perf_counter() - t0, 1))
    report.dump(args.out_dir)


if __name__ == "__main__":
    main()
