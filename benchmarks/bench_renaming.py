"""Paper §3.1 — bucket renaming pressure: physical buckets vs destination
spread.  The FPGA has few physical buckets but 2^16 possible destinations;
this sweep measures delivered throughput and mean packet size as the
destination working set grows past the bucket count (eviction pressure),
for the deadline margins the renaming logic must respect."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bucket as bk
from repro.core import events as ev


def run(n_buckets, n_dest_active, margin, T=1200, seed=0):
    cfg = bk.BucketConfig(n_buckets=n_buckets, capacity=124,
                          n_dest=max(n_dest_active, 4), flush_margin=margin,
                          queue=8)
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    dests = jax.random.randint(k1, (T, 1), 0, n_dest_active)
    ts = (jnp.arange(T).reshape(T, 1) + 400) & ev.TS_MASK
    words = ev.pack(dests, ts)
    st, out = bk.run_trace(cfg, words, dests)
    sent = int(out.sent_count.sum())
    pkts = int((np.asarray(out.sent_dest) >= 0).sum())
    miss = int(out.deadline_miss.sum())
    return sent / T, (sent / pkts if pkts else 0.0), miss


def main(report):
    for n_buckets in (4, 16):
        for n_dest in (2, 8, 32, 128):
            thr, mean_pkt, miss = run(n_buckets, n_dest, margin=16)
            report(
                f"renaming/buckets={n_buckets}/dests={n_dest}",
                round(thr, 3),
                f"mean_packet={mean_pkt:.1f}ev misses={miss}",
            )
    # deadline-margin sweep: tighter deadlines -> smaller packets
    for margin in (2, 8, 32, 128):
        thr, mean_pkt, miss = run(16, 16, margin=margin)
        report(f"renaming/margin={margin}", round(mean_pkt, 1),
               f"mean packet size (events); thr={thr:.3f} misses={miss}")
