"""Per-event latency model — when does a pulse actually arrive?

The quantity the follow-up papers measure (pulse latency distributions
between chips) decomposes, for a store-and-forward fabric, into exactly
four charges per delivered event:

1. **waiting time** — systemtime spent parked before the transport
   admitted the event's bucket row: the tail of its flush window, plus
   one full window per credit-stall re-offer and per residue round-trip,
   plus one full window per window the row spent PARKED in an in-fabric
   transit buffer (``FabricState``) waiting for a congested downstream
   link.  The simulator derives this from the *injection timestamp* each
   event carries in its wire word's meta lane (:mod:`repro.wire.codec`),
   so deferred AND parked rows accumulate waiting time across re-offers
   and resume windows with no extra bookkeeping.
2. **serialization** — ``frame_bytes(row) / bytes_per_us`` per traversed
   link: a store-and-forward hop cannot cut a frame through, it re-clocks
   the whole frame onto the next link.
3. **switch latency** — ``switch_latency_us`` per traversed link.
4. **queueing** — :func:`queueing_latency_us`: the serialization time of
   the traffic already parked in the egress buffers along the row's
   route, which must drain ahead of it.  This is the congestion term the
   serialization-only model lacked: an uncontended link charges nothing
   extra (the term vanishes with empty buffers), a saturated one charges
   the frame train of everything queued ahead.

Charges 2–4 are per *row* (all events of a bucket row share one frame
train and one route), so the per-window summary works on row-granular
latencies weighted by row event counts.  The summary is a fixed-bin
log-spaced histogram plus weighted p50/p99/max/mean — jit-safe, scan-able
(``WindowStats.latency``), and cheap: one sort over the per-source rows
of a window.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.wire.framing import WireFormat, frame_bytes

# Log-spaced bin edges in microseconds: 16 bins covering 0.25 us (an
# uncontended Extoll hop) to > 4 ms (a congested GbE path); bin b holds
# latencies in [edge[b-1], edge[b]), open-ended at both ends.
LATENCY_BIN_EDGES_US = tuple(float(2.0 ** e) for e in range(-2, 13))
N_LATENCY_BINS = len(LATENCY_BIN_EDGES_US) + 1


class LatencySummary(NamedTuple):
    """Per-window event-latency digest (all scalars f32, hist i32)."""

    p50_us: jax.Array          # () weighted median
    p99_us: jax.Array          # () weighted 99th percentile
    max_us: jax.Array          # () slowest delivered event
    mean_us: jax.Array         # () weighted mean
    hist: jax.Array            # (N_LATENCY_BINS,) events per latency bin


def zero_latency_summary() -> LatencySummary:
    z = jnp.zeros((), jnp.float32)
    return LatencySummary(z, z, z, z,
                          jnp.zeros((N_LATENCY_BINS,), jnp.int32))


def hop_latency_us(fmt: WireFormat, counts, hops) -> jax.Array:
    """Wire-time of a bucket row: per traversed link, one switch plus one
    full re-serialization of the row's frame train (store-and-forward).

    counts/hops broadcast together; returns f32 microseconds.
    """
    counts = jnp.asarray(counts, jnp.int32)
    hops = jnp.asarray(hops, jnp.int32)
    ser = frame_bytes(fmt, counts).astype(jnp.float32) / fmt.bytes_per_us
    return hops.astype(jnp.float32) * (fmt.switch_latency_us + ser)


def queueing_latency_us(fmt: WireFormat, queued_events) -> jax.Array:
    """Congestion dwell of a row: before it can cross its route's links,
    the events already parked in those links' store-and-forward buffers
    (``FabricState.parked_by_link``, gathered over the row's route) must
    serialize out ahead of it — one full frame train of the queued
    traffic at the link's bandwidth.  Empty buffers charge exactly 0, so
    an uncongested run keeps the serialization-only latency unchanged.

    ``queued_events`` broadcasts; returns f32 microseconds.
    """
    q = jnp.asarray(queued_events, jnp.int32)
    return frame_bytes(fmt, q).astype(jnp.float32) / fmt.bytes_per_us


def percentile_from_hist(hist, q: float) -> float:
    """Host-side quantile estimate from a ``LATENCY_BIN_EDGES_US``
    histogram (run-level digests: per-window histograms merge by
    addition, exact percentiles do not).

    Returns the UPPER edge of the bin holding the ``ceil(q * total)``-th
    event — a conservative over-estimate, tight to one 2x log bin.  The
    open top bin reports twice the last edge; an empty histogram 0.
    """
    hist = np.asarray(hist)
    total = int(hist.sum())
    if total == 0:
        return 0.0
    thresh = max(int(np.ceil(q * total)), 1)
    b = int(np.argmax(np.cumsum(hist) >= thresh))
    edges = LATENCY_BIN_EDGES_US
    return float(edges[b]) if b < len(edges) else float(edges[-1] * 2)


def summarize_latency(lat_us: jax.Array, weights: jax.Array) -> LatencySummary:
    """Weighted digest of per-row (or per-event) latencies.

    ``weights`` are event counts (0 rows are ignored); an all-zero weight
    vector yields the zero summary.  Percentile semantics: the smallest
    latency whose cumulative event weight reaches ``ceil(q * total)`` —
    the value an exact sorted-event percentile would return.
    """
    lat = lat_us.reshape(-1).astype(jnp.float32)
    w = weights.reshape(-1).astype(jnp.int32)
    total = jnp.sum(w)
    order = jnp.argsort(lat)
    lat_s = lat[order]
    cw = jnp.cumsum(w[order])

    def pct(q: float):
        thresh = jnp.ceil(q * total).astype(cw.dtype)
        idx = jnp.argmax(cw >= jnp.maximum(thresh, 1))
        return jnp.where(total > 0, lat_s[idx], 0.0).astype(jnp.float32)

    edges = jnp.asarray(LATENCY_BIN_EDGES_US, jnp.float32)
    bins = jnp.searchsorted(edges, lat, side="right")
    hist = jnp.zeros((N_LATENCY_BINS,), jnp.int32).at[bins].add(w)
    return LatencySummary(
        p50_us=pct(0.5),
        p99_us=pct(0.99),
        max_us=jnp.max(jnp.where(w > 0, lat, 0.0)).astype(jnp.float32),
        mean_us=jnp.where(
            total > 0,
            jnp.sum(lat * w.astype(jnp.float32)) / jnp.maximum(total, 1),
            0.0).astype(jnp.float32),
        hist=hist,
    )
