"""Extoll wire subsystem — what a spike event costs ON THE WIRE.

The layers above this package move abstract bucket rows; the paper's core
claim (§1, and the follow-up "Demonstrating BrainScaleS-2 Inter-Chip
Pulse-Communication using EXTOLL") is about the *wire*: a low-overhead
packet protocol format and low per-hop latency are why Extoll beats
Gigabit-Ethernet for pulse traffic.  This package makes those two
quantities first-class between aggregation and transport:

* :mod:`repro.wire.codec`    — pack spike events into 64-bit wire words
  (timestamp + routable label + 32-bit meta lane, field widths from
  config; Pallas pack/unpack kernel with XLA fallback, bit-exact
  round-trip).
* :mod:`repro.wire.framing`  — aggregate words into frames with a
  configurable cell size / MTU and per-frame header+CRC overhead, so
  ``LinkStats.bytes_on_wire`` is exact per protocol profile.
* :mod:`repro.wire.profiles` — the two :class:`WireFormat` protocol
  profiles the paper compares: ``extoll`` (64-byte cells, low header
  tax, sub-µs switches) and ``ethernet`` (1500-byte MTU, full
  Eth+IP+UDP header stack, store-and-forward switches).
* :mod:`repro.wire.latency`  — the per-event latency model: per-hop
  serialization (frame bytes / link bandwidth) + switch latency per
  traversed link + window-quantized waiting time, summarized per flush
  window as a histogram and p50/p99/max (``WindowStats.latency``).
"""
from __future__ import annotations

from repro.wire.codec import (DEFAULT_WORD, WireWordFormat, decode_planar,
                              decode_words, encode_planar, encode_words)
from repro.wire.framing import (WireFormat, frame_bytes, frame_count,
                                frame_overhead_bytes, wire_efficiency)
from repro.wire.latency import (LATENCY_BIN_EDGES_US, N_LATENCY_BINS,
                                LatencySummary, hop_latency_us,
                                percentile_from_hist, queueing_latency_us,
                                summarize_latency, zero_latency_summary)
from repro.wire.profiles import ETHERNET, EXTOLL, PROFILES, get_profile

__all__ = [
    "DEFAULT_WORD", "WireWordFormat", "encode_words", "decode_words",
    "encode_planar", "decode_planar",
    "WireFormat", "frame_bytes", "frame_count", "frame_overhead_bytes",
    "wire_efficiency",
    "LATENCY_BIN_EDGES_US", "N_LATENCY_BINS", "LatencySummary",
    "hop_latency_us", "percentile_from_hist",
    "queueing_latency_us", "summarize_latency", "zero_latency_summary",
    "EXTOLL", "ETHERNET", "PROFILES", "get_profile",
]
