"""64-bit spike wire-word codec (the Extoll pulse-event format).

The follow-up paper ("Demonstrating BrainScaleS-2 Inter-Chip
Pulse-Communication using EXTOLL") ships each pulse event as one 64-bit
wire word: a systemtime timestamp plus a routable neuron label, with spare
bits for protocol use.  This module is that word, configurable:

``WireWordFormat`` lays fields LSB-first into a 64-bit space::

    [0, ts_bits)                         timestamp  (event deadline)
    [ts_bits, +label_bits)               label      (routable pulse address)
    [.., +meta_bits)                     meta       (guid OR injection step)
    [ts_bits+label_bits+meta_bits]       valid flag
    remaining bits                       reserved (zero)

The ``meta`` lane is what makes the word load-bearing beyond the 30-bit
internal event word (``repro.core.events``): the exchange path carries the
destination GUID in it (so the multicast LUT key rides the wire instead of
a parallel bitcast array), and the simulator carries the event's
*injection systemtime step*, which is how per-event latency survives the
flush-window scan, transport deferral and residue re-offers.

JAX has no portable uint64 on the default x64-disabled CPU path and TPU
Pallas has no 64-bit integer lanes, so a wire word is represented as two
``uint32`` lanes ``(lo, hi)`` — ``word = (hi << 32) | lo``.  Fields
straddle the lane boundary (the default layout puts meta at bit 29), so
the codec is real 64-bit bit-packing, not a reshuffle.

Pack/unpack run as a Pallas TPU kernel (elementwise VPU bit ops, tiled
1-D grid) with the pure-XLA formulation of the same math auto-selected
off-TPU via ``repro.kernels.dispatch`` — identical policy to the fused
placement kernel.  Round-trip is bit-exact for every well-formed event
word (reserved bits zero, see ``events.pack``) and any 32-bit meta value
when ``meta_bits == 32``; tests pin both backends against each other.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.core import events as ev
from repro.kernels import dispatch

L_TILE = 512                      # 1-D codec tile (events per grid step)

_U32 = 0xFFFFFFFF


class WireWordFormat(NamedTuple):
    """Field widths of the 64-bit wire word (LSB-first, see module doc).

    ``ts_bits``/``label_bits`` must cover the internal event word's
    timestamp/address fields for a bit-exact round trip (15/14);
    ``meta_bits == 32`` keeps any i32 meta value exact via bitcast.
    """

    ts_bits: int = ev.TS_BITS          # 15
    label_bits: int = ev.ADDR_BITS     # 14
    meta_bits: int = 32

    @property
    def valid_bit(self) -> int:
        return self.ts_bits + self.label_bits + self.meta_bits

    @property
    def word_bytes(self) -> int:
        return 8

    def validate(self) -> "WireWordFormat":
        if not (1 <= self.ts_bits <= 32 and 1 <= self.label_bits <= 32
                and 0 <= self.meta_bits <= 32):
            raise ValueError(f"field widths out of range: {self}")
        if self.valid_bit > 63:
            raise ValueError(
                f"wire word overflows 64 bits: ts {self.ts_bits} + label "
                f"{self.label_bits} + meta {self.meta_bits} + valid > 64")
        return self


DEFAULT_WORD = WireWordFormat().validate()


def _mask(width: int) -> int:
    return ((1 << width) - 1) & _U32


def _deposit(lo, hi, v, offset: int, width: int):
    """OR field ``v`` (pre-masked, uint32) into bits [offset, offset+width)
    of the (lo, hi) lane pair.  ``offset``/``width`` are static, so every
    shift count is a Python int < 32 (jnp shifts >= lane width are UB)."""
    if width == 0:
        return lo, hi
    if offset < 32:
        lo = lo | (v << offset)            # uint32 wraps: keeps low bits
        if offset + width > 32:
            hi = hi | (v >> (32 - offset))
    else:
        hi = hi | (v << (offset - 32))
    return lo, hi


def _extract(lo, hi, offset: int, width: int):
    """Inverse of :func:`_deposit` -> uint32 field value."""
    if width == 0:
        return jnp.zeros_like(lo)
    if offset < 32:
        v = lo >> offset
        if offset + width > 32:
            v = v | (hi << (32 - offset))
    else:
        v = hi >> (offset - 32)
    return v & jnp.uint32(_mask(width))


def _encode_math(word, meta, fmt: WireWordFormat):
    """uint32 event word + uint32 meta -> (lo, hi) lanes.  Pure bit ops —
    shared verbatim by the Pallas kernel body and the XLA path."""
    ts = word & jnp.uint32(ev.TS_MASK & _mask(fmt.ts_bits))
    label = (word >> ev.TS_BITS) & jnp.uint32(ev.ADDR_MASK
                                              & _mask(fmt.label_bits))
    valid = (word >> (ev.TS_BITS + ev.ADDR_BITS)) & jnp.uint32(1)
    meta = meta & jnp.uint32(_mask(fmt.meta_bits)) if fmt.meta_bits else meta
    lo = jnp.zeros_like(word)
    hi = jnp.zeros_like(word)
    lo, hi = _deposit(lo, hi, ts, 0, fmt.ts_bits)
    lo, hi = _deposit(lo, hi, label, fmt.ts_bits, fmt.label_bits)
    lo, hi = _deposit(lo, hi, meta, fmt.ts_bits + fmt.label_bits,
                      fmt.meta_bits)
    lo, hi = _deposit(lo, hi, valid, fmt.valid_bit, 1)
    return lo, hi


def _decode_math(lo, hi, fmt: WireWordFormat):
    """(lo, hi) lanes -> (uint32 event word, uint32 meta)."""
    ts = _extract(lo, hi, 0, fmt.ts_bits) & jnp.uint32(ev.TS_MASK)
    label = (_extract(lo, hi, fmt.ts_bits, fmt.label_bits)
             & jnp.uint32(ev.ADDR_MASK))
    meta = _extract(lo, hi, fmt.ts_bits + fmt.label_bits, fmt.meta_bits)
    valid = _extract(lo, hi, fmt.valid_bit, 1)
    word = ts | (label << ev.TS_BITS) | (valid << (ev.TS_BITS + ev.ADDR_BITS))
    return word, meta


# ---------------------------------------------------------------------------
# Pallas kernels — the same math over 1-D VMEM tiles.
# ---------------------------------------------------------------------------

def _encode_kernel(word_ref, meta_ref, lo_ref, hi_ref, *, fmt):
    lo, hi = _encode_math(word_ref[...], meta_ref[...], fmt)
    lo_ref[...] = lo
    hi_ref[...] = hi


def _decode_kernel(lo_ref, hi_ref, word_ref, meta_ref, *, fmt):
    word, meta = _decode_math(lo_ref[...], hi_ref[...], fmt)
    word_ref[...] = word
    meta_ref[...] = meta


def _pallas_map2(kernel, a, b, fmt, interpret: bool):
    """Run an elementwise 2-in/2-out codec kernel over flat uint32 arrays."""
    n = a.shape[0]
    n_pad = max(-(-n // L_TILE) * L_TILE, L_TILE)
    a = jnp.pad(a, (0, n_pad - n))
    b = jnp.pad(b, (0, n_pad - n))
    tile = lambda i: (i,)
    o1, o2 = pl.pallas_call(
        functools.partial(kernel, fmt=fmt),
        grid=(n_pad // L_TILE,),
        in_specs=[pl.BlockSpec((L_TILE,), tile), pl.BlockSpec((L_TILE,), tile)],
        out_specs=(pl.BlockSpec((L_TILE,), tile), pl.BlockSpec((L_TILE,), tile)),
        out_shape=(jax.ShapeDtypeStruct((n_pad,), jnp.uint32),
                   jax.ShapeDtypeStruct((n_pad,), jnp.uint32)),
        interpret=interpret,
    )(a, b)
    return o1[:n], o2[:n]


def _dispatch2(kernel, math_fn, a, b, fmt, use_pallas, interpret):
    if use_pallas is None:
        use_pallas = dispatch.use_pallas()
    if interpret is None:
        interpret = dispatch.default_interpret()
    shape = a.shape
    if use_pallas:
        o1, o2 = _pallas_map2(kernel, a.reshape(-1), b.reshape(-1), fmt,
                              interpret)
        return o1.reshape(shape), o2.reshape(shape)
    return math_fn(a, b, fmt)


# ---------------------------------------------------------------------------
# Public API.
# ---------------------------------------------------------------------------

def _as_u32(x) -> jax.Array:
    x = jnp.asarray(x)
    if x.dtype == jnp.uint32:
        return x
    if x.dtype == jnp.int32:
        return lax.bitcast_convert_type(x, jnp.uint32)
    return x.astype(jnp.uint32)


def encode_words(events, meta, fmt: WireWordFormat = DEFAULT_WORD, *,
                 use_pallas: bool | None = None,
                 interpret: bool | None = None):
    """Pack event words + meta into 64-bit wire words -> (lo, hi) u32.

    ``meta`` may be i32 (bitcast, exact at ``meta_bits == 32``) or u32;
    shapes broadcast-free (events and meta must match).
    """
    events = _as_u32(events)
    meta = _as_u32(meta)
    if events.shape != meta.shape:
        raise ValueError(f"events {events.shape} != meta {meta.shape}")
    return _dispatch2(_encode_kernel, _encode_math, events, meta, fmt,
                      use_pallas, interpret)


def decode_words(lo, hi, fmt: WireWordFormat = DEFAULT_WORD, *,
                 use_pallas: bool | None = None,
                 interpret: bool | None = None):
    """Inverse of :func:`encode_words` -> (events u32, meta i32)."""
    word, meta = _dispatch2(_decode_kernel, _decode_math, _as_u32(lo),
                            _as_u32(hi), fmt, use_pallas, interpret)
    return word, lax.bitcast_convert_type(meta, jnp.int32)


def encode_planar(events, meta, fmt: WireWordFormat = DEFAULT_WORD, *,
                  use_pallas: bool | None = None,
                  interpret: bool | None = None) -> jax.Array:
    """(..., C) events + meta -> one (..., 2C) u32 wire buffer.

    Lane-planar layout: ``buf[..., :C]`` are the lo lanes, ``buf[..., C:]``
    the hi lanes of word j — the transport payload stays a single opaque
    u32 buffer exactly as wide as the old events|guids concat.
    """
    lo, hi = encode_words(events, meta, fmt, use_pallas=use_pallas,
                          interpret=interpret)
    return jnp.concatenate([lo, hi], axis=-1)


def decode_planar(buf: jax.Array, fmt: WireWordFormat = DEFAULT_WORD, *,
                  use_pallas: bool | None = None,
                  interpret: bool | None = None):
    """Inverse of :func:`encode_planar` -> (events u32, meta i32)."""
    c = buf.shape[-1] // 2
    return decode_words(buf[..., :c], buf[..., c:], fmt,
                        use_pallas=use_pallas, interpret=interpret)
