"""Frame-level byte accounting — what a bucket row costs on a real link.

A flush window hands the transport one bucket row per destination; on the
wire that row is a stream of *frames* of a concrete protocol.  This module
makes the per-frame overhead exact instead of the payload-only estimate
the transports used before:

* payload per frame is capped at ``mtu_payload`` bytes and padded up to a
  multiple of ``cell_bytes`` (Extoll's network layer moves 64-byte cells;
  Ethernet is byte-granular but enforces a 64-byte minimum frame);
* every frame pays ``header_bytes + crc_bytes`` protocol overhead, is
  clamped to ``min_frame_bytes`` on the wire, and is followed by
  ``gap_bytes`` of mandatory line idle (Ethernet preamble + inter-frame
  gap; zero for Extoll cells);
* ``bytes_per_us`` (serialization bandwidth) and ``switch_latency_us``
  (per-hop forwarding delay) are the link-timing half of the profile,
  consumed by :mod:`repro.wire.latency`.

All accounting functions are pure int32 jnp math — jit-safe, shape
polymorphic over per-destination event counts, and property-tested against
an independent scalar Python oracle (``tests/test_wire.py``):
``frames * cell_size >= payload`` and
``overhead == frames * (header + crc [+ gap, + min-frame pad])`` hold for
every count and profile.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class WireFormat(NamedTuple):
    """One wire protocol profile (framing geometry + link timing)."""

    name: str
    mtu_payload: int          # max payload bytes per frame (multiple of word)
    cell_bytes: int           # frame payload padded up to this granularity
    header_bytes: int         # per-frame protocol header
    crc_bytes: int            # per-frame checksum
    min_frame_bytes: int      # minimum header+payload+crc on the wire
    gap_bytes: int            # preamble + inter-frame gap per frame
    bytes_per_us: float       # link serialization bandwidth
    switch_latency_us: float  # per-hop switch/forwarding latency
    word_bytes: int = 8       # one encoded spike event (64-bit wire word)

    @property
    def events_per_frame(self) -> int:
        return self.mtu_payload // self.word_bytes

    def validate(self) -> "WireFormat":
        if self.mtu_payload % self.word_bytes:
            raise ValueError(
                f"{self.name}: mtu_payload {self.mtu_payload} must be a "
                f"multiple of word_bytes {self.word_bytes} (events never "
                f"straddle frames)")
        if min(self.mtu_payload, self.cell_bytes, self.word_bytes) <= 0:
            raise ValueError(f"{self.name}: non-positive geometry: {self}")
        if self.bytes_per_us <= 0 or self.switch_latency_us < 0:
            raise ValueError(f"{self.name}: bad link timing: {self}")
        return self


def _frame_wire_bytes(fmt: WireFormat, payload_bytes: jax.Array) -> jax.Array:
    """On-wire cost of ONE frame carrying ``payload_bytes`` of payload."""
    p = jnp.asarray(payload_bytes, jnp.int32)
    cells = -(-p // fmt.cell_bytes) * fmt.cell_bytes
    frame = jnp.maximum(cells + fmt.header_bytes + fmt.crc_bytes,
                        fmt.min_frame_bytes)
    return frame + fmt.gap_bytes


def frame_count(fmt: WireFormat, n_events) -> jax.Array:
    """Frames needed for ``n_events`` events (0 events -> 0 frames)."""
    n = jnp.asarray(n_events, jnp.int32)
    return -(-n // fmt.events_per_frame)


def frame_bytes(fmt: WireFormat, n_events) -> jax.Array:
    """Exact on-wire bytes for ``n_events`` events (headers, CRC, cell
    padding, min-frame clamp and inter-frame gaps included)."""
    n = jnp.asarray(n_events, jnp.int32)
    epf = fmt.events_per_frame
    full = n // epf
    rem = n % epf
    total = full * _frame_wire_bytes(fmt, jnp.int32(fmt.mtu_payload))
    total = total + jnp.where(
        rem > 0, _frame_wire_bytes(fmt, rem * fmt.word_bytes), 0)
    return total.astype(jnp.int32)


def frame_overhead_bytes(fmt: WireFormat, n_events) -> jax.Array:
    """Non-payload bytes: :func:`frame_bytes` minus the raw event payload."""
    n = jnp.asarray(n_events, jnp.int32)
    return frame_bytes(fmt, n) - n * fmt.word_bytes


def wire_efficiency(fmt: WireFormat, n_events) -> jax.Array:
    """Payload fraction of the on-wire bytes (the paper's protocol-tax
    curve: ~1 for a full Extoll cell train, far lower for a lone event in
    a minimum-size Ethernet frame)."""
    n = jnp.asarray(n_events, jnp.int32)
    total = frame_bytes(fmt, n)
    return jnp.where(total > 0,
                     (n * fmt.word_bytes) / jnp.maximum(total, 1),
                     0.0).astype(jnp.float32)
