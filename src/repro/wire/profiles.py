"""The two wire protocol profiles the paper compares (§1).

``extoll`` — the Tourmalet link layer: 64-byte network cells, a small
cell header and CRC, no mandatory line idle between cells, ~100 Gbit/s
serialization and sub-microsecond cut-through switches.  The low
per-frame tax is the paper's headline: even a lightly filled cell train
wastes little of the link.

``ethernet`` — the Gigabit-Ethernet baseline BrainScaleS-1 used: a full
Eth+IP+UDP header stack on every frame (14 + 20 + 8 bytes), 4-byte FCS,
the 64-byte minimum frame size, 8 bytes of preamble plus 12 bytes of
inter-frame gap of line idle per frame, 1500-byte MTU, 1 Gbit/s, and
store-and-forward switching latency in the many-microsecond range.

The Extoll profile's wire efficiency strictly dominates Ethernet's for
> 97% of bucket-row sizes in 1..4096 — the lone event (80 B vs 84 B, a
padded minimum frame plus preamble/gap), full frames (0.970 per cell
train vs 0.957 per max-size Ethernet frame) and every row past ~550
events — and on any realistic flush-window aggregate (the ordering is
pinned in tests and visible in ``BENCH_wire.json``; e.g. 0.9697 vs
0.9394 on the benchmark's ~512-event rows).  The exceptions are small
rows whose trailing
64-byte cell is mostly padding (n ≡ 1 mod 8 and friends: ≥ 24 B of the
last cell wasted), where Ethernet's byte-granular frames win a few
percent locally; meanwhile Ethernet serializes 100x slower and its
switches forward store-and-forward, which is where the latency model
buries it at EVERY row size.
"""
from __future__ import annotations

from repro.wire.framing import WireFormat

# Tourmalet: 12 lanes x 8.4 Gbit/s ~ 100 Gbit/s -> 12.5 GB/s = 12500 B/us.
EXTOLL = WireFormat(
    name="extoll",
    mtu_payload=512,            # 64 events of 8 B per cell train
    cell_bytes=64,
    header_bytes=8,
    crc_bytes=8,
    min_frame_bytes=0,
    gap_bytes=0,
    bytes_per_us=12500.0,
    switch_latency_us=0.6,
).validate()

# GbE: 125 B/us on the wire; 42 B L2-L4 headers, 4 B FCS, 64 B minimum
# frame, 20 B preamble+IFG, store-and-forward switches.
ETHERNET = WireFormat(
    name="ethernet",
    mtu_payload=1456,           # 182 events; fits the 1458 B UDP payload
    cell_bytes=1,
    header_bytes=42,
    crc_bytes=4,
    min_frame_bytes=64,
    gap_bytes=20,
    bytes_per_us=125.0,
    switch_latency_us=10.0,
).validate()

PROFILES: dict[str, WireFormat] = {p.name: p for p in (EXTOLL, ETHERNET)}


def get_profile(fmt: str | WireFormat) -> WireFormat:
    """Resolve a config value (profile name or explicit format) to a
    :class:`WireFormat`."""
    if isinstance(fmt, WireFormat):
        return fmt
    try:
        return PROFILES[fmt]
    except KeyError:
        raise ValueError(
            f"unknown wire format {fmt!r} (want one of "
            f"{sorted(PROFILES)} or a WireFormat)") from None
