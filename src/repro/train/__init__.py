"""Training: optimizers, step factory, fault-tolerant trainer."""
from repro.train import optimizer, step, trainer  # noqa: F401
