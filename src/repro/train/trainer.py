"""Fault-tolerant training loop.

Production behaviours implemented (and exercised by tests/examples):

* **checkpoint/restart** — periodic atomic checkpoints of the *entire*
  job state (params, moments, step, data cursor, PRNG); ``Trainer.run``
  always resumes from the latest committed checkpoint if one exists.
* **straggler mitigation** — the paper's deadline-flush idea applied to
  steps: a per-step wall-clock budget (p95 of recent steps x margin);
  steps exceeding it are counted and surfaced; on a real multi-host job
  the hook triggers within-step recovery (skip / re-shard); here it feeds
  the metrics and the elasticity test.
* **elastic scaling** — mesh shape comes from the environment
  (``make_production_mesh``/test mesh); restore reshards state onto
  whatever mesh the restarted job has (see ``checkpoint.Checkpointer``).
* **crash injection** — ``fail_at_step`` simulates a node failure so the
  restart path is tested, not just written.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import DataConfig, RingPrefetcher, shard_batch
from repro.models.model import Model
from repro.models.transformer import Runtime
from repro.obs import spans as obs_spans
from repro.train import step as step_lib


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    log_every: int = 10
    straggler_margin: float = 3.0      # x median step time
    fail_at_step: int | None = None    # crash injection for tests


class Trainer:
    def __init__(self, model: Model, tcfg: step_lib.TrainConfig,
                 dcfg: DataConfig, run_cfg: TrainerConfig,
                 rt: Runtime | None = None, mesh=None,
                 state_shardings=None,
                 tracer: obs_spans.Tracer | None = None):
        self.model = model
        # the span API replaces the raw perf_counter pair: a disabled
        # (NULL) tracer still times the step for the straggler check
        self.tracer = tracer if tracer is not None else obs_spans.NULL
        self.tcfg = tcfg
        self.dcfg = dcfg
        self.cfg = run_cfg
        self.rt = rt or Runtime(mesh=mesh)
        self.mesh = mesh
        self.ckpt = Checkpointer(run_cfg.ckpt_dir)
        self.train_step = step_lib.make_train_step(model, tcfg, self.rt)
        if mesh is not None:
            self.train_step = jax.jit(self.train_step,
                                      donate_argnums=(0,))
        else:
            self.train_step = jax.jit(self.train_step, donate_argnums=(0,))
        self.state_shardings = state_shardings
        self.step_times: list = []
        self.straggler_events = 0

    # -- state ------------------------------------------------------------
    def init_or_restore(self, seed: int = 0):
        template = jax.eval_shape(
            lambda k: step_lib.init_train_state(self.model, k, self.tcfg),
            jax.random.PRNGKey(seed))
        template = jax.tree_util.tree_map(
            lambda s: np.zeros(s.shape, s.dtype), template)
        latest = self.ckpt.latest_step()
        if latest is not None:
            state = self.ckpt.restore(template, latest,
                                      shardings=self.state_shardings)
            start = int(np.asarray(state["step"]))
            return state, start
        state = step_lib.init_train_state(
            self.model, jax.random.PRNGKey(seed), self.tcfg)
        if self.state_shardings is not None:
            state = jax.tree_util.tree_map(
                lambda v, s: jax.device_put(v, s), state,
                self.state_shardings)
        return state, 0

    # -- loop ---------------------------------------------------------------
    def run(self, seed: int = 0, extra_batch: Callable | None = None):
        state, start = self.init_or_restore(seed)
        data = RingPrefetcher(self.dcfg, start_step=start)
        history = []
        try:
            for i in range(start, self.cfg.steps):
                with self.tracer.span("train/step", track="train",
                                      step=i) as sp:
                    step_idx, batch = data.next()
                    if extra_batch is not None:
                        batch.update(extra_batch(self.model.cfg, batch))
                    if self.mesh is not None:
                        batch = shard_batch(batch, self.mesh)
                    if (self.cfg.fail_at_step is not None
                            and i == self.cfg.fail_at_step):
                        raise RuntimeError("injected node failure")
                    state, metrics = self.train_step(state, batch)
                dt = sp.dur_s
                self._straggler_check(dt)
                if (i + 1) % self.cfg.log_every == 0 or i == start:
                    m = {k: float(np.asarray(v)) for k, v in metrics.items()}
                    m.update(step=i + 1, dt=dt, **data.stats())
                    history.append(m)
                if (i + 1) % self.cfg.ckpt_every == 0:
                    self.ckpt.save(i + 1, jax.device_get(state))
        finally:
            data.close()
        return state, history

    def _straggler_check(self, dt: float):
        self.step_times.append(dt)
        if len(self.step_times) >= 8:
            med = float(np.median(self.step_times[-32:]))
            if dt > self.cfg.straggler_margin * med:
                self.straggler_events += 1
