"""Optimizers and LR schedules (optax is unavailable offline).

* AdamW — default for every arch that fits; moments live with the params
  and inherit their sharding (ZeRO via the FSDP rules).
* Adafactor — factored second moment + bf16 momentum, for arctic-480b
  where full f32 Adam moments (3.8 TB) cannot fit 16 GB/chip at one pod.
* Schedules — linear warmup into {cosine, WSD}.  WSD (warmup-stable-decay)
  is MiniCPM's schedule, reproduced here because minicpm-2b is assigned.

All functions are pure pytree->pytree; state is a NamedTuple of trees so it
checkpoints like anything else.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ScheduleConfig:
    kind: str = "cosine"            # cosine | wsd | constant
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    decay_frac: float = 0.1         # WSD: final fraction spent decaying
    min_ratio: float = 0.1


def learning_rate(cfg: ScheduleConfig, step):
    s = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.kind == "constant":
        return cfg.peak_lr * warm
    if cfg.kind == "cosine":
        t = jnp.clip((s - cfg.warmup_steps)
                     / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
        cos = 0.5 * (1 + jnp.cos(np.pi * t))
        return cfg.peak_lr * warm * (cfg.min_ratio + (1 - cfg.min_ratio) * cos)
    if cfg.kind == "wsd":
        decay_start = cfg.total_steps * (1 - cfg.decay_frac)
        t = jnp.clip((s - decay_start)
                     / max(cfg.total_steps - decay_start, 1), 0, 1)
        # MiniCPM uses exponential-ish anneal; linear-in-log approximation
        stable = jnp.where(s < decay_start, 1.0,
                           cfg.min_ratio ** t)
        return cfg.peak_lr * warm * stable
    raise ValueError(cfg.kind)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    kind: str = "adamw"             # adamw | adafactor
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    schedule: ScheduleConfig = ScheduleConfig()
    momentum_dtype: str = "float32"     # adafactor: "bfloat16" to halve it


class AdamWState(NamedTuple):
    m: dict
    v: dict
    count: jax.Array


class AdafactorState(NamedTuple):
    m: dict            # momentum (possibly bf16)
    vr: dict           # row stats  (reduced over last dim)
    vc: dict           # col stats  (reduced over second-to-last dim)
    v: dict            # full stats for <2D params
    count: jax.Array


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm):
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree_util.tree_map(
        lambda t: (t * scale).astype(t.dtype), grads), g


def adamw_init(params) -> AdamWState:
    zeros = lambda t: jax.tree_util.tree_map(
        lambda p: jnp.zeros_like(p, jnp.float32), t)
    return AdamWState(m=zeros(params), v=zeros(params),
                      count=jnp.zeros((), jnp.int32))


def adamw_update(grads, state: AdamWState, params, cfg: OptimizerConfig):
    c = state.count + 1
    b1, b2 = cfg.b1, cfg.b2
    lr = learning_rate(cfg.schedule, c)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1 ** c.astype(jnp.float32))
        vh = v / (1 - b2 ** c.astype(jnp.float32))
        step = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:                       # no decay on norms/biases
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    out = jax.tree_util.tree_map(upd, grads, state.m, state.v, params)
    new_p = jax.tree_util.tree_map(lambda o: o[0], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree_util.tree_map(lambda o: o[1], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree_util.tree_map(lambda o: o[2], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    return new_p, AdamWState(new_m, new_v, c), {"lr": lr, "grad_norm": gnorm}


# ---------------------------------------------------------------------------
# Adafactor (factored second moment)
# ---------------------------------------------------------------------------

def adafactor_init(params, cfg: OptimizerConfig) -> AdafactorState:
    mdt = jnp.bfloat16 if cfg.momentum_dtype == "bfloat16" else jnp.float32

    def rowcol(p):
        if p.ndim >= 2:
            return (jnp.zeros(p.shape[:-1], jnp.float32),
                    jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                    jnp.zeros((1,), jnp.float32))
        return (jnp.zeros((1,), jnp.float32), jnp.zeros((1,), jnp.float32),
                jnp.zeros_like(p, jnp.float32))

    trip = jax.tree_util.tree_map(rowcol, params)
    pick = lambda i: jax.tree_util.tree_map(
        lambda t: t[i], trip, is_leaf=lambda x: isinstance(x, tuple))
    m = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, mdt), params)
    return AdafactorState(m=m, vr=pick(0), vc=pick(1), v=pick(2),
                          count=jnp.zeros((), jnp.int32))


def adafactor_update(grads, state: AdafactorState, params,
                     cfg: OptimizerConfig):
    c = state.count + 1
    lr = learning_rate(cfg.schedule, c)
    beta2 = 1.0 - c.astype(jnp.float32) ** -0.8
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)

    def upd(g, m, vr, vc, v, p):
        g = g.astype(jnp.float32)
        g2 = g * g + 1e-30
        if p.ndim >= 2:
            vr = beta2 * vr + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc = beta2 * vc + (1 - beta2) * jnp.mean(g2, axis=-2)
            r = vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), 1e-30)
            denom = jnp.sqrt(r[..., None] * vc[..., None, :])
        else:
            v = beta2 * v + (1 - beta2) * g2
            denom = jnp.sqrt(v)
        u = g / jnp.maximum(denom, 1e-30)
        # update clipping (Adafactor RMS rule)
        rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
        u = u / jnp.maximum(1.0, rms)
        mu = 0.9 * m.astype(jnp.float32) + 0.1 * u
        step = mu + cfg.weight_decay * p.astype(jnp.float32) * (p.ndim >= 2)
        newp = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return newp, mu.astype(m.dtype), vr, vc, v

    out = jax.tree_util.tree_map(upd, grads, state.m, state.vr, state.vc,
                                 state.v, params)
    g = lambda i: jax.tree_util.tree_map(
        lambda o: o[i], out, is_leaf=lambda x: isinstance(x, tuple))
    return g(0), AdafactorState(g(1), g(2), g(3), g(4), c), {
        "lr": lr, "grad_norm": gnorm}


# ---------------------------------------------------------------------------

def init_opt(params, cfg: OptimizerConfig):
    if cfg.kind == "adafactor":
        return adafactor_init(params, cfg)
    return adamw_init(params)


def apply_opt(grads, state, params, cfg: OptimizerConfig):
    if cfg.kind == "adafactor":
        return adafactor_update(grads, state, params, cfg)
    return adamw_update(grads, state, params, cfg)
