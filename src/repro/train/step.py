"""Loss + train-step factory.

The cross-entropy is computed in *sequence chunks* with ``jax.checkpoint``
on the chunk function, so the (B, S, vocab) logits tensor never exists in
memory — at gemma2's 256k vocab that tensor would be ~4 GB/device at train
shape.  The logits chunk is sharded over the model axis (vocab dim), and
the logsumexp reduction lets GSPMD insert one small all-reduce per chunk.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import layers as Lyr
from repro.models.model import Model
from repro.models.transformer import Runtime
from repro.train import optimizer as opt


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: opt.OptimizerConfig = opt.OptimizerConfig()
    aux_weight: float = 0.01        # MoE load-balance loss weight
    z_weight: float = 1e-4          # logit z-loss
    microbatch: int = 0             # 0 = no gradient accumulation
    remat: bool = True


def chunked_xent(params, hidden, labels, cfg: ModelConfig, rt: Runtime,
                 chunk: int | None = None):
    """Mean NLL over tokens, never materializing full logits.

    hidden: (B, S, d) bf16; labels: (B, S) int32 (-1 = masked).
    """
    B, S, d = hidden.shape
    chunk = chunk or min(rt.logits_chunk, S)
    nc = S // chunk
    assert S % chunk == 0, (S, chunk)
    # leave the (possibly sequence-sharded) residual layout behind: the
    # loss chunks along S, so re-shard to batch-only once, here.
    hidden = rt.wsc(hidden, P(rt.batch_axes, None, None))
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]

    @jax.checkpoint
    def one(h_c, y_c):
        logits = h_c @ w.astype(h_c.dtype)            # (B, c, V)
        logits = logits * cfg.logit_scale
        logits = Lyr.softcap(logits.astype(jnp.float32), cfg.logit_softcap)
        logits = rt.wsc(logits, P(rt.batch_axes, None, rt.model_axis))
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(y_c, 0)[..., None], axis=-1)[..., 0]
        mask = (y_c >= 0).astype(jnp.float32)
        nll = (lse - gold) * mask
        zsq = (lse * lse) * mask
        return nll.sum(), zsq.sum(), mask.sum()

    def body(carry, xs):
        nll, zsq, n = carry
        h_c, y_c = xs
        a, b, c = one(h_c, y_c)
        return (nll + a, zsq + b, n + c), None

    hs = jnp.moveaxis(hidden.reshape(B, nc, chunk, d), 1, 0)
    ys = jnp.moveaxis(labels.reshape(B, nc, chunk), 1, 0)
    (nll, zsq, n), _ = jax.lax.scan(
        body, (jnp.zeros(()), jnp.zeros(()), jnp.zeros(())), (hs, ys))
    n = jnp.maximum(n, 1.0)
    return nll / n, zsq / n


def make_loss_fn(model: Model, tcfg: TrainConfig, rt: Runtime):
    cfg = model.cfg

    def loss_fn(params, batch):
        hidden, aux = model.hidden(params, batch, rt)
        nll, zsq = chunked_xent(params, hidden, batch["labels"], cfg, rt)
        loss = nll + tcfg.aux_weight * aux + tcfg.z_weight * zsq
        metrics = {"loss": loss, "nll": nll, "aux": aux, "z": zsq}
        return loss, metrics

    return loss_fn


def make_train_step(model: Model, tcfg: TrainConfig, rt: Runtime):
    """Returns train_step(state_dict, batch) -> (state_dict, metrics).

    state_dict = {"params": ..., "opt": ..., "step": i32}. Microbatching
    (gradient accumulation) splits the batch on the leading axis.
    """
    loss_fn = make_loss_fn(model, tcfg, rt)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if tcfg.microbatch and tcfg.microbatch > 1:
            mb = tcfg.microbatch
            split = jax.tree_util.tree_map(
                lambda t: t.reshape((mb, t.shape[0] // mb) + t.shape[1:]),
                batch)

            def acc(carry, b):
                g_sum, m_sum = carry
                (_, m), g = grad_fn(params, b)
                g_sum = jax.tree_util.tree_map(jnp.add, g_sum, g)
                m_sum = jax.tree_util.tree_map(jnp.add, m_sum, m)
                return (g_sum, m_sum), None

            zeros_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            zeros_m = {"loss": 0.0, "nll": 0.0, "aux": 0.0, "z": 0.0}
            zeros_m = jax.tree_util.tree_map(jnp.float32, zeros_m)
            (g, m), _ = jax.lax.scan(acc, (zeros_g, zeros_m), split)
            g = jax.tree_util.tree_map(lambda t: t / mb, g)
            m = jax.tree_util.tree_map(lambda t: t / mb, m)
            return g, m
        (_, m), g = grad_fn(params, batch)
        return g, m

    def train_step(state, batch):
        params = state["params"]
        grads, metrics = compute_grads(params, batch)
        if rt.grad_specs is not None:
            # pin gradients to the parameter sharding: the backward matmul
            # partials then reduce-scatter (each rank keeps its shard)
            # instead of all-reducing the full dW
            grads = jax.tree_util.tree_map(
                lambda g, sh: jax.lax.with_sharding_constraint(g, sh),
                grads, rt.grad_specs)
        new_params, opt_state, om = opt.apply_opt(
            grads, state["opt"], params, tcfg.optimizer)
        metrics.update(om)
        return {"params": new_params, "opt": opt_state,
                "step": state["step"] + 1}, metrics

    return train_step


def init_train_state(model: Model, key, tcfg: TrainConfig,
                     param_dtype=None) -> dict:
    params = model.init(key, param_dtype)
    return {"params": params, "opt": opt.init_opt(params, tcfg.optimizer),
            "step": jnp.zeros((), jnp.int32)}


def abstract_train_state(model: Model, tcfg: TrainConfig, param_dtype=None):
    """ShapeDtypeStruct tree of the train state (dry-run, no allocation)."""
    params = model.abstract(param_dtype)
    opt_state = jax.eval_shape(
        lambda p: opt.init_opt(p, tcfg.optimizer), params)
    return {"params": params, "opt": opt_state,
            "step": jax.ShapeDtypeStruct((), jnp.int32)}
