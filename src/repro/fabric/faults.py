"""Fault schedules: link/node failures at flush-window granularity.

The BrainScaleS commissioning line of work catalogues the hardware
faults a multi-wafer Extoll fabric must survive — dead cables, dropped
wafers, flapping channels.  This module is the *schedule* side of the
fault-injection layer: a :class:`FaultSchedule` is a static-shape
``(n_windows, K)`` boolean table over the fabric's ``K = n_shards * 2 *
ndim`` directed egress links (node-major, directions ordered ``x+, x-,
y+, y-, z+, z-`` — the same link ids as ``core.flow_control`` /
``core.torus.link_loads``), so it can be closed over by a jitted
``lax.scan`` and indexed per window with :func:`mask_at`.

The *consumption* side lives in ``repro.transport.torus``: the caller
stamps the window's mask onto the carried fabric state
(``state._replace(link_down=mask_at(sched, w))``) before ``exchange``,
and the transport treats dead links as zero-credit, evicts parked rows
whose remaining route (or held arrival link) died, and walks each ring
the long way around a dead link (``docs/architecture.md``).

All constructors here are host-side numpy; a directed link dies with
its physical cable — killing ``(u, x+)`` also kills the reverse channel
``(v, x-)`` of the neighboring node ``v`` — because an unplugged or
broken cable takes both directions with it (`cable_links`).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class FaultSchedule(NamedTuple):
    """Window-granular link-down table, ``(n_windows, K)`` bool.

    Row ``w`` is the set of dead directed egress links during flush
    window ``w``; windows beyond the table clamp to the last row (a
    permanent fault stays dead, a healed fabric stays healed).  The
    table is a plain array so a jitted scan can close over it and
    ``mask_at`` stays a static-shape gather.
    """

    link_down: jax.Array

    @property
    def n_windows(self) -> int:
        return int(self.link_down.shape[0])

    @property
    def n_links(self) -> int:
        return int(self.link_down.shape[1])

    def at(self, window) -> jax.Array:
        return mask_at(self, window)


def mask_at(schedule: FaultSchedule, window) -> jax.Array:
    """(K,) bool link-down mask of ``window`` (clamped to the table)."""
    w = jnp.clip(window, 0, schedule.link_down.shape[0] - 1)
    return jnp.take(schedule.link_down, w, axis=0)


# -- link-id math (host) ----------------------------------------------------

def n_fabric_links(dims) -> int:
    """K: directed egress links of a ``dims`` torus fabric."""
    dims = tuple(int(d) for d in dims)
    return math.prod(dims) * 2 * len(dims)


def link_id(dims, node: int, direction: int) -> int:
    """Directed egress link id: ``node * 2 * ndim + direction``."""
    dims = tuple(int(d) for d in dims)
    nl = 2 * len(dims)
    if not 0 <= direction < nl:
        raise ValueError(f"direction {direction} out of range for {dims}")
    if not 0 <= node < math.prod(dims):
        raise ValueError(f"node {node} out of range for {dims}")
    return node * nl + direction


def _coords(dims, node: int):
    out = []
    for d in dims:
        out.append(node % d)
        node //= d
    return out


def _node_id(dims, coords) -> int:
    node, stride = 0, 1
    for c, d in zip(coords, dims):
        node += (c % d) * stride
        stride *= d
    return node


def cable_links(dims, node: int, direction: int) -> tuple[int, int]:
    """The two directed link ids sharing one physical cable.

    ``(node, axis±)`` and the neighbor's reverse channel ``(v, axis∓)``
    ride the same cable, so a cable fault kills both.  On a 2-ring the
    + and - cables of a node pair are still distinct (the ring wraps),
    which is why detours work even there.
    """
    dims = tuple(int(d) for d in dims)
    axis, sign = direction // 2, direction % 2
    c = _coords(dims, node)
    c[axis] = (c[axis] + (1 if sign == 0 else -1)) % dims[axis]
    v = _node_id(dims, c)
    reverse = axis * 2 + (1 - sign)
    return (link_id(dims, node, direction), link_id(dims, v, reverse))


# -- constructors -----------------------------------------------------------

def healthy(dims, n_windows: int) -> FaultSchedule:
    """No faults, ever."""
    return FaultSchedule(
        jnp.zeros((max(int(n_windows), 1), n_fabric_links(dims)), bool))


def _window_range(n_windows: int, start: int, stop: int | None):
    stop = n_windows if stop is None else min(int(stop), n_windows)
    return max(int(start), 0), stop


def link_fault(dims, n_windows: int, node: int, direction: int, *,
               start: int = 0, stop: int | None = None) -> FaultSchedule:
    """One cable dead over windows ``[start, stop)`` (default: forever)."""
    down = np.zeros((max(int(n_windows), 1), n_fabric_links(dims)), bool)
    lo, hi = _window_range(down.shape[0], start, stop)
    for l in cable_links(dims, node, direction):
        down[lo:hi, l] = True
    return FaultSchedule(jnp.asarray(down))


def link_flap(dims, n_windows: int, node: int, direction: int, *,
              period: int = 2, start: int = 0) -> FaultSchedule:
    """A flapping cable: dead for ``period`` windows, alive for
    ``period``, repeating from ``start`` — the degraded-channel failure
    mode of the off-wafer characterization."""
    period = max(int(period), 1)
    down = np.zeros((max(int(n_windows), 1), n_fabric_links(dims)), bool)
    links = cable_links(dims, node, direction)
    for w in range(max(int(start), 0), down.shape[0]):
        if ((w - start) // period) % 2 == 0:
            for l in links:
                down[w, l] = True
    return FaultSchedule(jnp.asarray(down))


def node_fault(dims, n_windows: int, node: int, *, start: int = 0,
               stop: int | None = None) -> FaultSchedule:
    """A dropped node (wafer concentrator off the fabric): every cable
    incident to ``node`` — all its egress links AND every neighbor's
    channel into it — dead over ``[start, stop)``."""
    dims = tuple(int(d) for d in dims)
    down = np.zeros((max(int(n_windows), 1), n_fabric_links(dims)), bool)
    lo, hi = _window_range(down.shape[0], start, stop)
    for direction in range(2 * len(dims)):
        for l in cable_links(dims, node, direction):
            down[lo:hi, l] = True
    return FaultSchedule(jnp.asarray(down))


AXIS_NAMES = "xyz"


def link_label(dims, lid: int) -> str:
    """Human label of a directed link id, e.g. ``"n3:x+"``.

    Inverse of :func:`link_id` for display: node-major ids, directions
    ordered ``x+, x-, y+, y-, z+, z-`` — what the observability report
    (``repro.obs.report``) prints for its top-congested-links table.
    """
    dims = tuple(int(d) for d in dims)
    nl = 2 * len(dims)
    node, direction = divmod(int(lid), nl)
    axis, sign = divmod(direction, 2)
    return f"n{node}:{AXIS_NAMES[axis]}{'+' if sign == 0 else '-'}"


def transitions(schedule: FaultSchedule) -> list[dict]:
    """Host-side fault timeline: one event per link state CHANGE.

    Diffs consecutive mask rows (window 0 against an all-healthy fabric)
    into JSON-serializable events the observability report merges onto
    the window timeline::

        {"window": w, "event": "link_down" | "link_up",
         "links": [lid, ...]}

    A healthy schedule yields ``[]``; a flap yields alternating
    down/up pairs.  Link ids decode with :func:`link_label`.
    """
    down = np.asarray(schedule.link_down, bool)
    prev = np.zeros((down.shape[1],), bool)
    events: list[dict] = []
    for w in range(down.shape[0]):
        died = np.flatnonzero(down[w] & ~prev)
        healed = np.flatnonzero(~down[w] & prev)
        if died.size:
            events.append({"window": int(w), "event": "link_down",
                           "links": died.astype(int).tolist()})
        if healed.size:
            events.append({"window": int(w), "event": "link_up",
                           "links": healed.astype(int).tolist()})
        prev = down[w]
    return events


def chaos(dims, n_windows: int, seed: int, *,
          revive_p: float = 0.5) -> FaultSchedule:
    """Seeded chaos: every window kills one uniformly random cable, and
    each already-dead cable revives with probability ``revive_p`` first.

    Randomness comes from the repo's single audited traffic-seeding
    path (``repro.serve.loadgen.traffic_rng``) so chaos runs are exactly
    reproducible from ``(dims, n_windows, seed)``.
    """
    from repro.serve.loadgen import traffic_rng
    dims = tuple(int(d) for d in dims)
    n_nodes, nl = math.prod(dims), 2 * len(dims)
    rng = traffic_rng(seed, 0xFA)
    down = np.zeros((max(int(n_windows), 1), n_fabric_links(dims)), bool)
    dead: dict[tuple[int, int], None] = {}
    for w in range(down.shape[0]):
        dead = {cab: None for cab in dead if rng.random() >= revive_p}
        node = int(rng.integers(0, n_nodes))
        direction = int(rng.integers(0, nl))
        dead[cable_links(dims, node, direction)] = None
        for cab in dead:
            for l in cab:
                down[w, l] = True
    return FaultSchedule(jnp.asarray(down))
