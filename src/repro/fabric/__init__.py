"""Fabric-level fault modelling: link/node failures and reroute-around.

``repro.fabric.faults`` builds static-shape, scan-compatible
:class:`~repro.fabric.faults.FaultSchedule` objects that the torus
transports consume through ``FabricState.link_down`` — see
``docs/architecture.md`` (fault injection section).
"""
from repro.fabric.faults import (  # noqa: F401
    FaultSchedule,
    cable_links,
    chaos,
    healthy,
    link_fault,
    link_flap,
    link_id,
    mask_at,
    n_fabric_links,
    node_fault,
)
