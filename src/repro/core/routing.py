"""Lookup-table routing: source event -> (network destination, GUID) and
GUID -> multicast mask (paper §3).

An event arriving at an FPGA carries only its local pulse address; it does
not define a destination in the overall network.  The *source* table is
indexed by pulse address and yields the 16-bit Extoll destination node plus
a Global Unique Identifier (GUID).  The GUID travels with the event.  At the
destination, a second table is indexed by GUID and yields a multicast mask
that selects which of the local HICANN links the event is replayed on.

Both tables are plain device arrays so lookups are ``jnp.take`` (gather) and
the whole path stays inside jit.  Builders construct the tables from a
population-level connectivity description.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import events as ev

DEST_BITS = 16          # Extoll: 16-bit destination address in the header
MAX_DESTS = 1 << DEST_BITS
NO_ROUTE = jnp.int32(-1)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class RoutingTables:
    """Device-resident routing state for one FPGA/shard.

    Attributes:
      dest_of_addr:  (n_addr,) int32 — network destination per source pulse
                     address, ``NO_ROUTE`` for unconnected sources.
      guid_of_addr:  (n_addr,) int32 — GUID transmitted with the event.
      mcast_of_guid: (n_guid,) uint32 — destination-side multicast mask,
                     bit i = replay on local HICANN link i (8 links/FPGA,
                     up to 32 modelled populations per shard here).
    """

    dest_of_addr: jax.Array
    guid_of_addr: jax.Array
    mcast_of_guid: jax.Array

    # -- pytree plumbing ------------------------------------------------
    def tree_flatten(self):
        return (self.dest_of_addr, self.guid_of_addr, self.mcast_of_guid), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    # -- lookups ---------------------------------------------------------
    def route(self, event_words: jax.Array):
        """Source-side lookup for a window of packed events.

        Returns (dest, guid, valid): invalid or unrouted events get
        dest == NO_ROUTE and valid False.
        """
        addr, _, valid = ev.unpack(event_words)
        idx = jnp.minimum(addr.astype(jnp.int32), self.dest_of_addr.shape[0] - 1)
        dest = jnp.take(self.dest_of_addr, idx, axis=0)
        guid = jnp.take(self.guid_of_addr, idx, axis=0)
        routed = valid & (dest != NO_ROUTE)
        return jnp.where(routed, dest, NO_ROUTE), guid, routed

    def multicast(self, guids: jax.Array) -> jax.Array:
        """Destination-side lookup: GUID -> multicast mask (uint32)."""
        idx = jnp.clip(guids, 0, self.mcast_of_guid.shape[0] - 1)
        mask = jnp.take(self.mcast_of_guid, idx, axis=0)
        return jnp.where(guids >= 0, mask, jnp.uint32(0))


@dataclasses.dataclass(frozen=True)
class Projection:
    """Population-level connection used to build routing tables.

    src_addr_lo/hi: half-open range of source pulse addresses on this shard.
    dest_node:      16-bit network destination (torus node id).
    dest_links:     which HICANN links at the destination replay the event.
    """

    src_addr_lo: int
    src_addr_hi: int
    dest_node: int
    dest_links: Sequence[int]


def build_tables(
    n_addr: int,
    projections: Sequence[Projection],
    *,
    n_guid: int | None = None,
) -> RoutingTables:
    """Build per-shard tables from projections (host-side, numpy).

    Each distinct (dest_node, dest_links) pair gets one GUID; sources in a
    projection share that GUID.  Later projections overwrite earlier ones on
    address overlap (same as reprogramming the FPGA LUT).
    """
    dest = np.full((n_addr,), -1, np.int32)
    guid = np.zeros((n_addr,), np.int32)
    guid_map: dict[tuple[int, tuple[int, ...]], int] = {}
    masks: list[int] = []
    for p in projections:
        links = tuple(sorted(set(p.dest_links)))
        key = (p.dest_node, links)
        if key not in guid_map:
            guid_map[key] = len(masks)
            masks.append(sum(1 << l for l in links))
        g = guid_map[key]
        dest[p.src_addr_lo : p.src_addr_hi] = p.dest_node
        guid[p.src_addr_lo : p.src_addr_hi] = g
    n_guid = n_guid or max(len(masks), 1)
    mcast = np.zeros((n_guid,), np.uint32)
    mcast[: len(masks)] = np.asarray(masks, np.uint32)
    return RoutingTables(
        dest_of_addr=jnp.asarray(dest),
        guid_of_addr=jnp.asarray(guid),
        mcast_of_guid=jnp.asarray(mcast),
    )


def expand_multicast(event_words: jax.Array, masks: jax.Array, n_links: int):
    """Replay events onto local links per multicast mask.

    Returns (n_links, window) event words: link i receives the event iff
    bit i of its mask is set; other slots are INVALID_EVENT.
    """
    bits = (masks[None, :] >> jnp.arange(n_links, dtype=jnp.uint32)[:, None]) & 1
    return jnp.where(bits.astype(bool), event_words[None, :], ev.INVALID_EVENT)
