"""3D-torus topology model (paper §1).

Extoll Tourmalet nodes are "usually connected in a 3D-Torus topology, which
offers good scaling characteristics"; routing is dimension-ordered on a
16-bit destination address.  The BrainScaleS arrangement gathers 6 FPGAs at
each of 8 concentrator nodes per wafer module (48 FPGAs/wafer), and the
concentrators are torus nodes.

This module provides the host-side analysis used by the benchmarks and the
dry-run reports: address<->coordinate mapping, dimension-ordered route
enumeration, per-link load for a traffic matrix, hop statistics and
bisection capacity.  It is also the bridge to the TPU analogy: a TPU pod's
ICI *is* a 3D torus, so `launch/mesh.py` maps the (data, model) mesh onto
the same coordinates and the collective-bytes term of the roofline is
divided by the same per-link bandwidth this model reasons about.

numpy (host) — this is analysis code, not a jitted path.
"""
from __future__ import annotations

import dataclasses
import itertools

import numpy as np

# paper constants
FPGAS_PER_WAFER = 48
CONCENTRATORS_PER_WAFER = 8
FPGAS_PER_CONCENTRATOR = 6
HICANNS_PER_FPGA = 8
LANES_PER_LINK = 12
GBIT_PER_LANE = 8.4
LINK_GBYTES = LANES_PER_LINK * GBIT_PER_LANE / 8.0   # 12.6 GB/s per link
LINKS_PER_NODE = 7                                    # Tourmalet: 7 links


@dataclasses.dataclass(frozen=True)
class Torus:
    """A (nx, ny, nz) 3D torus of Extoll nodes."""

    nx: int
    ny: int
    nz: int

    @property
    def n_nodes(self) -> int:
        return self.nx * self.ny * self.nz

    def coords(self, node: np.ndarray | int):
        node = np.asarray(node)
        x = node % self.nx
        y = (node // self.nx) % self.ny
        z = node // (self.nx * self.ny)
        return x, y, z

    def node_id(self, x, y, z) -> np.ndarray:
        return (np.asarray(z) * self.ny + np.asarray(y)) * self.nx + np.asarray(x)

    # -- dimension-ordered routing ---------------------------------------
    def _axis_steps(self, a: int, b: int, n: int):
        """Shortest signed ring walk a->b on an n-ring; returns list of nodes."""
        fwd = (b - a) % n
        bwd = (a - b) % n
        step = 1 if fwd <= bwd else -1
        dist = min(fwd, bwd)
        return [(a + step * i) % n for i in range(1, dist + 1)]

    def route(self, src: int, dst: int):
        """Dimension-ordered (X then Y then Z) route; list of node ids."""
        sx, sy, sz = (int(v) for v in self.coords(src))
        dx, dy, dz = (int(v) for v in self.coords(dst))
        path = [src]
        for x in self._axis_steps(sx, dx, self.nx):
            path.append(int(self.node_id(x, sy, sz)))
        for y in self._axis_steps(sy, dy, self.ny):
            path.append(int(self.node_id(dx, y, sz)))
        for z in self._axis_steps(sz, dz, self.nz):
            path.append(int(self.node_id(dx, dy, z)))
        return path

    def link_dir(self, u: int, v: int) -> int:
        """Direction index 0..5 (x+, x-, y+, y-, z+, z-) of the single ring
        hop u -> v; raises if the nodes are not ring neighbors."""
        ux, uy, uz = (int(c) for c in self.coords(u))
        vx, vy, vz = (int(c) for c in self.coords(v))
        if (uy, uz) == (vy, vz) and ux != vx:
            return 0 if (vx - ux) % self.nx == 1 else 1
        if (ux, uz) == (vx, vz) and uy != vy:
            return 2 if (vy - uy) % self.ny == 1 else 3
        if (ux, uy) == (vx, vy) and uz != vz:
            return 4 if (vz - uz) % self.nz == 1 else 5
        raise ValueError(f"{u} -> {v} is not a single ring hop")

    def route_links(self, src: int, dst: int) -> list:
        """The dimension-ordered route as ordered (node, direction) egress
        links — the per-hop credit accounting unit of the torus transports
        (``repro.transport.torus`` spends ``count`` credits on every one of
        these links to admit a bucket row)."""
        path = self.route(src, dst)
        return [(u, self.link_dir(u, v)) for u, v in zip(path[:-1], path[1:])]

    # -- fault-aware detours ----------------------------------------------
    def _ring_walk(self, a: int, b: int, n: int, longway: bool = False):
        """Signed ring walk a->b: (step, dist).  ``longway`` reverses the
        shortest direction and walks the other ``n - dist`` hops around
        the ring (the detour around a dead link on the short arc)."""
        fwd = (b - a) % n
        bwd = (a - b) % n
        step = 1 if fwd <= bwd else -1            # same tie-break as route
        dist = min(fwd, bwd)
        if longway and dist > 0:
            step, dist = -step, n - dist
        return step, dist

    def axis_segment_links(self, src: int, dst: int, axis: int,
                           longway: bool = False) -> list:
        """The (node, direction) egress links of the ``axis`` segment of
        the dimension-ordered route src -> dst, short arc or the long way
        around.  Directions come from the walk's step sign (NOT from
        coordinate deltas — on a 2-ring both neighbors are one hop away
        in either direction, and the + and - cables are distinct).

        The segment is the same whichever arcs earlier axes took: axis
        ``a`` always starts at coords ``(d_0..d_{a-1}, s_a, .., s_2)``.
        """
        sc = [int(v) for v in self.coords(src)]
        dc = [int(v) for v in self.coords(dst)]
        dims = (self.nx, self.ny, self.nz)
        at = list(dc[:axis]) + list(sc[axis:])    # segment start coords
        step, dist = self._ring_walk(sc[axis], dc[axis], dims[axis], longway)
        direction = 2 * axis + (0 if step > 0 else 1)
        links = []
        c = sc[axis]
        for _ in range(dist):
            at[axis] = c
            links.append((int(self.node_id(*at)), direction))
            c = (c + step) % dims[axis]
        return links

    def route_links_detour(self, src: int, dst: int,
                           flips=(False, False, False)) -> list:
        """Dimension-ordered route as (node, direction) links with each
        flipped axis walking its ring the long way around; ``flips`` all
        False reproduces :meth:`route_links` exactly."""
        return [l for a in range(3)
                for l in self.axis_segment_links(src, dst, a, flips[a])]

    def route_links_avoiding(self, src: int, dst: int, down):
        """Fault-aware route: per axis, detour the long way around when
        the short arc crosses a link in ``down`` (a set of (node,
        direction) pairs) and the long arc is clean.  Returns ``(links,
        flips)``, or ``None`` when some axis is dead both ways — the
        host oracle for the transport's in-scan reroute decision.
        """
        down = set(down)
        flips = []
        for a in range(3):
            short = self.axis_segment_links(src, dst, a, longway=False)
            if not any(l in down for l in short):
                flips.append(False)
                continue
            if any(l in down
                   for l in self.axis_segment_links(src, dst, a, True)):
                return None
            flips.append(True)
        flips = tuple(flips)
        return self.route_links_detour(src, dst, flips), flips

    def hops(self, src, dst) -> np.ndarray:
        """Vectorized hop count (sum of shortest ring distances per axis)."""
        sx, sy, sz = self.coords(np.asarray(src))
        dx, dy, dz = self.coords(np.asarray(dst))

        def ring(a, b, n):
            f = (b - a) % n
            return np.minimum(f, n - f)

        return ring(sx, dx, self.nx) + ring(sy, dy, self.ny) + ring(sz, dz, self.nz)

    def mean_hops(self) -> float:
        ids = np.arange(self.n_nodes)
        s, d = np.meshgrid(ids, ids, indexing="ij")
        return float(self.hops(s.ravel(), d.ravel()).mean())

    # -- link loads -------------------------------------------------------
    def link_loads_scalar(self, traffic: np.ndarray) -> dict:
        """Reference implementation: route every pair with :meth:`route`.

        O(n²) Python — kept as the oracle for :meth:`link_loads`; use the
        vectorized version for anything beyond a handful of wafers.
        """
        loads: dict = {}
        n = self.n_nodes
        for s, d in itertools.product(range(n), range(n)):
            b = float(traffic[s, d])
            if b <= 0 or s == d:
                continue
            path = self.route(s, d)
            for u, v in zip(path[:-1], path[1:]):
                loads[(u, v)] = loads.get((u, v), 0.0) + b
        return loads

    def _ring_segment(self, loads, a, target, n_ring, bytes_, node_of,
                      dir_base: int):
        """Accumulate one dimension-ordered ring walk into ``loads``.

        a/target: (P,) ring coordinates per pair; node_of(coord, mask) maps
        a ring coordinate back to a node id; dir_base indexes the axis'
        [+, -] columns of the (n_nodes, 6) accumulator.
        """
        fwd = (target - a) % n_ring
        bwd = (a - target) % n_ring
        step = np.where(fwd <= bwd, 1, -1)          # same tie-break as route
        dist = np.minimum(fwd, bwd)
        for i in range(int(dist.max(initial=0))):   # <= n_ring // 2 rounds
            m = dist > i
            u = (a[m] + step[m] * i) % n_ring
            np.add.at(loads, (node_of(u, m), dir_base + (step[m] < 0)),
                      bytes_[m])

    def link_loads(self, traffic: np.ndarray) -> dict:
        """Route a (n_nodes, n_nodes) byte traffic matrix; per-link loads.

        Returns {(u, v): bytes} for every directed link used.  Routing is
        dimension-ordered, so this reproduces the congestion an Extoll
        network would actually see (no adaptive routing modelled).

        Vectorized over all pairs: each axis' ring walk is batched with
        numpy (at most ``ring/2`` accumulation rounds per axis instead of
        a Python loop over ``n_nodes**2`` routes); exact-equivalent to
        :meth:`link_loads_scalar`, which tests use as the oracle.
        """
        t = np.asarray(traffic, dtype=float)
        n = self.n_nodes
        mask = t > 0
        np.fill_diagonal(mask, False)
        src, dst = np.nonzero(mask)
        bytes_ = t[src, dst]
        sx, sy, sz = self.coords(src)
        dx, dy, dz = self.coords(dst)

        # (node, direction) accumulator; directions: x+, x-, y+, y-, z+, z-
        loads = np.zeros((n, 6))
        self._ring_segment(loads, sx, dx, self.nx, bytes_,
                           lambda u, m: self.node_id(u, sy[m], sz[m]), 0)
        self._ring_segment(loads, sy, dy, self.ny, bytes_,
                           lambda u, m: self.node_id(dx[m], u, sz[m]), 2)
        self._ring_segment(loads, sz, dz, self.nz, bytes_,
                           lambda u, m: self.node_id(dx[m], dy[m], u), 4)

        ids = np.arange(n)
        x, y, z = self.coords(ids)
        neighbor = [
            self.node_id((x + 1) % self.nx, y, z),
            self.node_id((x - 1) % self.nx, y, z),
            self.node_id(x, (y + 1) % self.ny, z),
            self.node_id(x, (y - 1) % self.ny, z),
            self.node_id(x, y, (z + 1) % self.nz),
            self.node_id(x, y, (z - 1) % self.nz),
        ]
        out: dict = {}
        for d in range(6):
            for u in np.nonzero(loads[:, d])[0]:
                key = (int(u), int(neighbor[d][u]))
                out[key] = out.get(key, 0.0) + loads[u, d]
        return out

    def max_link_load(self, traffic: np.ndarray) -> float:
        loads = self.link_loads(traffic)
        return max(loads.values()) if loads else 0.0

    def bisection_links(self) -> int:
        """Directed links crossing the X mid-plane bisection (torus: 2 per
        ring crossing x2 wrap)."""
        return 2 * 2 * self.ny * self.nz

    def bisection_gbytes(self) -> float:
        return self.bisection_links() * LINK_GBYTES


def wafer_topology(n_wafers: int) -> Torus:
    """The paper's arrangement: 8 concentrator torus-nodes per wafer.

    We lay wafers along Z with each wafer's 8 concentrators forming a 2x4
    XY-face, matching Figure 1's intent of keeping intra-wafer traffic on
    short rings.
    """
    return Torus(nx=2, ny=4, nz=max(n_wafers, 1))


def microcircuit_traffic(n_nodes: int, events_per_s: float,
                         locality: float = 0.7) -> np.ndarray:
    """Synthetic traffic matrix: `locality` fraction stays on-node-group,
    rest uniform — roughly the Potjans-Diesmann connectivity footprint."""
    m = np.full((n_nodes, n_nodes), (1 - locality) / max(n_nodes - 1, 1))
    np.fill_diagonal(m, 0.0)
    m = m / max(m.sum(), 1e-9) * events_per_s * 4.0   # 4 B/event payload
    return m
