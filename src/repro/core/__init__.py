"""The paper's primary contribution: destination-bucketed spike-event
communication (Extoll-style) as composable JAX modules.

Layers (bottom-up):
  events        packed 30-bit event wire format + packet cost model
  routing       source LUT (addr -> dest, GUID) and GUID -> multicast mask
  bucket        faithful cycle-level bucket state machine (the oracle)
  aggregator    vectorized window aggregation (TPU path; Pallas option)
  flow_control  credit-based ring buffer (host<->device discipline)
  torus         3D-torus topology / link-load analysis
  exchange      shard_map all_to_all spike fabric tying it all together
"""
from repro.core import (  # noqa: F401
    aggregator,
    bucket,
    events,
    flow_control,
    routing,
    torus,
)
