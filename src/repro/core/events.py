"""Packed spike-event words — the wire format of the BrainScaleS/Extoll link.

The paper (§3) describes events leaving a HICANN as a 12-bit source neuron
pulse address plus a 15-bit timestamp stating an arrival *deadline* in
systemtime units.  On the wire a single event occupies a 30-bit word; we
round up to a ``uint32`` lane ("events are deserialised to groups of four",
i.e. 4 events per 16-byte network word).

Bit layout used here (LSB first)::

    [ 0:15)  timestamp  (15 bits, systemtime units, wraps)
    [15:29)  address    (14 bits: 12-bit pulse address + 2-bit link id,
                         so a full FPGA's 8 HICANNs x 64 sources fit)
    [29:30)  valid flag
    [30:32)  reserved

All functions are shape-polymorphic and jit-safe; events travel through the
system as ``uint32`` arrays so they can be bucketed, shuffled through
``all_to_all`` and multicast without structure-of-arrays bookkeeping.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# --- wire-format constants (faithful to the paper) ----------------------
TS_BITS = 15
ADDR_BITS = 14          # 12-bit pulse address + 2 spare (link id)
TS_MASK = (1 << TS_BITS) - 1
ADDR_MASK = (1 << ADDR_BITS) - 1
VALID_BIT = 1 << (TS_BITS + ADDR_BITS)      # bit 29
EVENT_BITS = 30                              # "single 30 bit events"
EVENT_BYTES = 4                              # rounded to a uint32 lane

# Extoll packet geometry (§3.1): max payload 496 B == 124 events.
PACKET_PAYLOAD_BYTES = 496
PACKET_MAX_EVENTS = PACKET_PAYLOAD_BYTES // EVENT_BYTES   # == 124
# Tourmalet cell-header overhead for a minimal RMA put: modelled as one
# 16-byte network word.  With a 16-byte/cycle datapath at the 210 MHz FPGA
# clock this reproduces the paper's bottleneck exactly: a single-event
# message costs header (1 cycle) + one deserialisation group (1 cycle)
# = 2 cycles -> "one event every two clocks", while events arrive at up to
# one per clock.  A full 124-event packet costs 32 cycles -> 3.875
# events/cycle of drain headroom.
PACKET_HEADER_BYTES = 16
DATAPATH_BYTES_PER_CYCLE = 16                # FPGA->link datapath width
DESERIAL_GROUP = 4                           # events per network word

INVALID_EVENT = jnp.uint32(0)                # valid bit clear


def pack(address: jax.Array, timestamp: jax.Array, valid=None) -> jax.Array:
    """Pack (address, timestamp[, valid]) into uint32 event words."""
    address = jnp.asarray(address)
    timestamp = jnp.asarray(timestamp)
    word = ((address.astype(jnp.uint32) & ADDR_MASK) << TS_BITS) | (
        timestamp.astype(jnp.uint32) & TS_MASK
    )
    if valid is None:
        valid = jnp.ones_like(word, dtype=bool)
    return jnp.where(valid, word | VALID_BIT, jnp.uint32(0))


def address(event: jax.Array) -> jax.Array:
    return (event >> TS_BITS) & ADDR_MASK


def timestamp(event: jax.Array) -> jax.Array:
    return event & TS_MASK


def is_valid(event: jax.Array) -> jax.Array:
    return (event & VALID_BIT) != 0


def unpack(event: jax.Array):
    """-> (address, timestamp, valid)."""
    return address(event), timestamp(event), is_valid(event)


def ts_before(a: jax.Array, b: jax.Array) -> jax.Array:
    """Wrap-aware 'deadline a is earlier than deadline b' on 15-bit time.

    Uses the standard serial-number-arithmetic trick: a precedes b iff
    (a - b) mod 2^15 is in the upper half of the ring.
    """
    d = (a.astype(jnp.int32) - b.astype(jnp.int32)) & TS_MASK
    return d > (TS_MASK >> 1)


def ts_slack(deadline: jax.Array, now: jax.Array) -> jax.Array:
    """Signed systemtime units until ``deadline`` (negative = missed)."""
    d = (deadline.astype(jnp.int32) - now.astype(jnp.int32)) & TS_MASK
    return jnp.where(d > (TS_MASK >> 1), d - (TS_MASK + 1), d)


def packet_bytes(n_events) -> jax.Array:
    """Wire bytes for a packet carrying ``n_events`` events (header incl.).

    Events are deserialised to groups of four (16-byte network words), so
    the payload is rounded up to the group size.  A zero-event packet costs
    nothing (no packet is emitted).
    """
    n = jnp.asarray(n_events, jnp.int32)
    groups = (n + (DESERIAL_GROUP - 1)) // DESERIAL_GROUP
    payload = groups * DESERIAL_GROUP * EVENT_BYTES
    return jnp.where(n > 0, payload + PACKET_HEADER_BYTES, 0)


def wire_cycles(n_events) -> jax.Array:
    """FPGA cycles the output port is busy shifting a packet of n events."""
    b = packet_bytes(n_events)
    return (b + (DATAPATH_BYTES_PER_CYCLE - 1)) // DATAPATH_BYTES_PER_CYCLE


def wire_efficiency(n_events) -> jax.Array:
    """Fraction of packet bytes that are event payload (the paper's
    header-amortization curve; == ~0.5 at n=1, -> 496/512 at n=124)."""
    n = jnp.asarray(n_events, jnp.int32)
    useful = n * EVENT_BYTES
    total = packet_bytes(n)
    return jnp.where(total > 0, useful / jnp.maximum(total, 1), 0.0)
