"""Credit-based ring-buffer flow control (paper §2.1).

FPGAs write result data into a pre-registered ring buffer in host memory via
RMA put; no per-message handshake is needed because the producer tracks the
free space itself through a *space register* that is replenished by
*notifications* from the consumer ("FPGAs exchange notifications with the
software, informing each other about the amount of data written to or
processed from memory. This implements a kind of credit based flow
control.").

This module models that discipline functionally:

* ``RingState`` — write pointer, read pointer, producer-visible credits and
  a notification-delay line (credits spent by the consumer only become
  visible to the producer ``notify_latency`` steps later, which is what
  makes the buffer-sizing trade-off real: sustained throughput =
  min(produce_rate, consume_rate, size / notify_latency)).
* ``producer_step`` / ``consumer_step`` — one step of each side.
* ``run`` — closed-loop scan for benchmarks.

The same discipline is used at three places in the framework: the
host→device data-pipeline prefetch (``repro.data.pipeline``), the serving
engine's response ring (``repro.serve.engine``), and — vectorized over the
egress links of *every* torus node via ``CreditBank`` — the hop-by-hop
link flow control of the torus transport backends
(``repro.transport.torus``).

Credit / notification-delay semantics (the §2.1 contract every user of
this module relies on):

* A link starts with ``limit`` credits and credits NEVER exceed that
  initial limit — there is no credit creation, only circulation.
* Spending is synchronous and may never overdraw: callers must ensure
  ``spent <= credits`` at the moment of the spend (the transports enforce
  this by refusing — deferring — any row that does not fit).
* Spent credits are not destroyed; they enter a delay line of length
  ``notify_latency`` and return to the producer that many steps later
  (the FPGA's notification round-trip).  ``notify_latency=0`` means the
  notification is instantaneous: the refund lands within the same
  :func:`credit_tick`, i.e. credits are only a rate limit *within* one
  window, never across windows.
* Conservation: ``credits + pending.sum()`` is invariant under
  :func:`credit_tick` — every credit is either available or in flight as
  a notification.  Tests pin this identity.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class RingConfig(NamedTuple):
    size: int = 64              # ring slots
    notify_latency: int = 8     # steps before consumed slots return as credit
    notify_batch: int = 1       # consumer notifies every k processed slots


class RingState(NamedTuple):
    wr: jax.Array              # () i32 producer write pointer (monotonic)
    rd: jax.Array              # () i32 consumer read pointer (monotonic)
    credits: jax.Array         # () i32 slots the producer may still write
    pending: jax.Array         # (L,) i32 credit notifications in flight
    unnotified: jax.Array      # () i32 consumed but not yet notified slots
    data: jax.Array            # (size,) payload (slot contents)


def init_ring(cfg: RingConfig, dtype=jnp.uint32) -> RingState:
    return RingState(
        wr=jnp.int32(0), rd=jnp.int32(0),
        credits=jnp.int32(cfg.size),
        pending=jnp.zeros((cfg.notify_latency,), jnp.int32),
        unnotified=jnp.int32(0),
        data=jnp.zeros((cfg.size,), dtype),
    )


def producer_step(state: RingState, want: jax.Array, payload: jax.Array,
                  cfg: RingConfig):
    """Try to write ``want`` (0/1 here; slot-granular) items.

    Returns (state, written:int32). Writes stall when credits == 0 — the
    producer never overruns the consumer (the paper's correctness property).
    """
    can = jnp.minimum(want.astype(jnp.int32), state.credits)
    slot = state.wr % cfg.size
    data = jnp.where(can > 0, state.data.at[slot].set(payload), state.data)
    return state._replace(
        wr=state.wr + can, credits=state.credits - can, data=data
    ), can


def consumer_step(state: RingState, rate: jax.Array, cfg: RingConfig):
    """Consume up to ``rate`` available items; emit batched notifications.

    Returns (state, consumed:int32).
    """
    avail = state.wr - state.rd
    take = jnp.minimum(rate.astype(jnp.int32), avail)
    unnot = state.unnotified + take
    notify = (unnot // cfg.notify_batch) * cfg.notify_batch
    unnot = unnot - notify
    # enqueue the notification at the tail of the delay line
    pending = state.pending.at[-1].add(notify)
    return state._replace(rd=state.rd + take, unnotified=unnot,
                          pending=pending), take


def tick(state: RingState) -> RingState:
    """Advance the notification delay line one step; deliver head credits."""
    arrived = state.pending[0]
    pending = jnp.roll(state.pending, -1, 0).at[-1].set(0)
    return state._replace(credits=state.credits + arrived, pending=pending)


# ---------------------------------------------------------------------------
# Vectorized credit bank — the ring discipline above, over K independent
# links with a shared notification latency.  Used per torus-node egress link.
# ---------------------------------------------------------------------------

class CreditBank(NamedTuple):
    """Producer-visible credits for K links + their notification delay lines.

    credits: (K,) i32 — units the producer may still inject per link
    pending: (K, L) i32 — spent units travelling back as notifications;
             column 0 is delivered by the next :func:`credit_tick`.
    epoch:   () i32 — count of past ticks on which anything was spent (a
             "progress round").  Arbiters key fairness rotation off this
             rather than wall-clock windows so the rotation cannot
             phase-lock with the credit refund cycle (see the round-robin
             admission of ``repro.transport.torus``).
    """

    credits: jax.Array
    pending: jax.Array
    epoch: jax.Array


def init_credits(n_links: int, limit: int, notify_latency: int) -> CreditBank:
    """Fresh bank: ``limit`` credits on each of ``n_links`` links.

    ``notify_latency=0`` yields a zero-length delay line — notifications
    are instantaneous and :func:`credit_tick` refunds the spend within the
    same call (credits still cap a single window's traffic, but nothing
    carries over between windows).
    """
    return CreditBank(
        credits=jnp.full((n_links,), limit, jnp.int32),
        pending=jnp.zeros((n_links, max(notify_latency, 0)), jnp.int32),
        epoch=jnp.int32(0),
    )


def credit_tick(bank: CreditBank, spent: jax.Array,
                notify: jax.Array | None = None) -> CreditBank:
    """One window: spend ``spent`` (K,) units and advance the delay lines.

    The consumer's notification for this window's data is enqueued at the
    tail and returns as producer credit ``notify_latency`` windows later —
    the same producer/consumer/tick cycle as ``RingState``, batched to one
    call per flush window.  Callers must ensure ``spent <= credits``.

    ``notify`` (default: ``spent``) is the amount entering the
    notification delay line this window.  The two differ only for callers
    that model in-fabric transit buffers (``repro.transport.torus``): a
    unit spent by a row that then *parks* in the downstream buffer is
    HELD — subtracted from credits but not notified until the row departs
    — and a departing parked row *releases* its held unit into the delay
    line without a fresh spend.  So ``notify = spent - newly_held +
    released`` and the conservation identity becomes ``credits +
    pending.sum() + held == limit`` with ``held`` tracked by the caller
    (``FabricState.parked_by_link``); with no holds it degenerates to the
    original ``credits + pending.sum() == limit``.
    """
    spent = spent.astype(jnp.int32)
    notify = spent if notify is None else notify.astype(jnp.int32)
    epoch = bank.epoch + (jnp.sum(spent) > 0).astype(jnp.int32)
    if bank.pending.shape[-1] == 0:      # notify_latency == 0: refund now
        return bank._replace(credits=bank.credits - spent + notify,
                             epoch=epoch)
    arrived = bank.pending[:, 0]
    pending = jnp.roll(bank.pending, -1, axis=1).at[:, -1].set(notify)
    credits = bank.credits - spent + arrived
    return CreditBank(credits=credits, pending=pending, epoch=epoch)


# ---------------------------------------------------------------------------
# Per-tenant credit partitioning — multi-tenant QoS layered on CreditBank.
#
# A fabric serving T concurrent experiments splits each physical link's
# ``limit`` credits into T guaranteed slices (one per tenant) plus one
# shared best-effort pool.  The split is realised WITHOUT changing the
# bank mechanics: a partitioned bank is an ordinary ``CreditBank`` with
# ``(T + 1) * K`` slots for K physical links —
#
#   slot  t * K + l   : tenant ``t``'s reserved slice of link ``l``
#   slot  T * K + l   : link ``l``'s shared pool (the last slot group)
#
# so ``credit_tick`` / the conservation identity / the notification delay
# lines all apply per *slot* unmodified.  Spending discipline (enforced by
# the tenant-aware admission in ``repro.transport.torus``): a tenant's row
# draws reserved-first, shared-second at every link it crosses, and is
# admitted only if reserved + shared cover the row at every link up to the
# stall point.  Since no other tenant can draw from slice ``t``, tenant
# ``t`` is guaranteed ``reserve[t] / max(notify_latency, 1)`` events per
# link per window of sustained admission no matter how saturated the
# shared pool is — that is the QoS floor the serve benchmarks pin.
# ---------------------------------------------------------------------------

class CreditPartition(NamedTuple):
    """Static QoS split of each link's credit budget across tenants.

    reserve: per-tenant guaranteed credits per link (len T tuple)
    shared:  best-effort credits per link, drawn by any tenant after its
             own slice is exhausted
    """

    reserve: tuple[int, ...]
    shared: int

    @property
    def n_tenants(self) -> int:
        return len(self.reserve)

    @property
    def limit(self) -> int:
        """Total credits per physical link (== the unpartitioned limit)."""
        return sum(self.reserve) + self.shared

    @property
    def n_slots_per_link(self) -> int:
        return self.n_tenants + 1


def make_partition(link_credits: int, reserve) -> CreditPartition:
    """Build a partition of ``link_credits`` with per-tenant ``reserve``.

    ``reserve`` is a sequence of per-tenant guaranteed slices; whatever is
    left over becomes the shared pool.  Rejects oversubscription — the
    guarantee would be a lie if the slices did not physically exist.
    """
    reserve = tuple(int(r) for r in reserve)
    if not reserve:
        raise ValueError("need at least one tenant")
    if any(r < 0 for r in reserve):
        raise ValueError(f"negative reserve: {reserve}")
    total = sum(reserve)
    if total > link_credits:
        raise ValueError(
            f"oversubscribed: sum(reserve)={total} > link_credits={link_credits}")
    return CreditPartition(reserve=reserve, shared=link_credits - total)


def partition_limits(part: CreditPartition, n_links: int) -> jax.Array:
    """Per-slot initial credits, ((T+1)*K,) i32, slot layout as above."""
    per_link = list(part.reserve) + [part.shared]
    limits = jnp.asarray(per_link, jnp.int32)[:, None]
    return jnp.broadcast_to(limits, (part.n_slots_per_link, n_links)).reshape(-1)


def init_credits_from_limits(limits: jax.Array,
                             notify_latency: int) -> CreditBank:
    """Fresh bank with per-slot (non-uniform) initial credits."""
    limits = jnp.asarray(limits, jnp.int32)
    return CreditBank(
        credits=limits,
        pending=jnp.zeros((limits.shape[0], max(notify_latency, 0)),
                          jnp.int32),
        epoch=jnp.int32(0),
    )


def init_partitioned_credits(part: CreditPartition, n_links: int,
                             notify_latency: int) -> CreditBank:
    """Partitioned bank over ``n_links`` physical links: ``(T+1)*n_links``
    slots, tenant slices first, shared pool last."""
    return init_credits_from_limits(partition_limits(part, n_links),
                                    notify_latency)


class RunStats(NamedTuple):
    produced: jax.Array
    consumed: jax.Array
    stalls: jax.Array          # producer steps blocked on credits


def run(cfg: RingConfig, steps: int, produce_rate: float = 1.0,
        consume_rate: int = 1, seed: int = 0):
    """Closed-loop simulation: Bernoulli producer vs fixed-rate consumer."""
    keys = jax.random.split(jax.random.PRNGKey(seed), steps)

    def step(state, key):
        want = (jax.random.uniform(key) < produce_rate).astype(jnp.int32)
        state, wrote = producer_step(state, want, jnp.uint32(1), cfg)
        state, took = consumer_step(state, jnp.int32(consume_rate), cfg)
        state = tick(state)
        return state, RunStats(wrote, took, (want - wrote))

    state, stats = jax.lax.scan(step, init_ring(cfg), keys)
    return state, RunStats(*(jnp.sum(x) for x in stats))
