"""Multi-shard spike exchange — the JAX-native Extoll fabric (paper §3).

One "wafer shard" per mesh device along a named axis.  A flush window is:

  1. **route+aggregate** — the fused window kernel
                   (``repro.kernels.fused_route_bucket``): source LUT
                   lookup (§3, LUT 1) and destination-bucketed binning with
                   static capacity (§3.1) in one sort-based pass
  2. **all_to_all** — ONE collective per window ships every bucket to its
                   owner: events, guids and counts are packed into a single
                   (n_shards, 2·capacity+1) u32 buffer so the latency-bound
                   ICI hop is paid once, exactly like the paper amortizes
                   the Extoll packet header across a full bucket
  3. **multicast** — destination-side GUID lookup -> multicast mask,
                   replaying events onto local HICANN links       (§3, LUT 2)

All stages run inside ``shard_map`` so the collective is explicit — the
lowered HLO contains exactly one all-to-all per flush window, and the
roofline's collective term can be read straight off it.

Overflow policy: events beyond a bucket's capacity in one window are
*carried over* to the next window through a per-shard residue buffer —
functionally the FPGA's back-pressure on the HICANN links.  Tests assert no
event is ever lost (conservation), matching the bucket model oracle.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import aggregator, events as ev
from repro.core.routing import RoutingTables


def pack_buckets(data: jax.Array, guids: jax.Array,
                 counts: jax.Array) -> jax.Array:
    """Pack (D, C) events + (D, C) guids + (D,) counts into one u32 buffer.

    Layout per destination row: ``[data | guids | count]`` -> (D, 2C+1).
    Bitcasts (not converts) keep negative guid sentinels exact on the wire.
    """
    gu = jax.lax.bitcast_convert_type(guids, jnp.uint32)
    cn = jax.lax.bitcast_convert_type(counts, jnp.uint32)[:, None]
    return jnp.concatenate([data, gu, cn], axis=1)


def unpack_buckets(buf: jax.Array, capacity: int):
    """Inverse of :func:`pack_buckets` -> (data, guids, counts)."""
    data = buf[:, :capacity]
    guids = jax.lax.bitcast_convert_type(buf[:, capacity:2 * capacity],
                                         jnp.int32)
    counts = jax.lax.bitcast_convert_type(buf[:, 2 * capacity], jnp.int32)
    return data, guids, counts


class ExchangeOut(NamedTuple):
    """Per-shard result of one flush window (shapes are per-shard)."""

    recv_events: jax.Array   # (n_shards, C) u32 events received per source
    recv_guids: jax.Array    # (n_shards, C) i32
    recv_counts: jax.Array   # (n_shards,) i32
    link_events: jax.Array   # (n_links, n_shards*C) u32 after multicast
    sent_counts: jax.Array   # (n_shards,) i32 events sent per destination
    overflow: jax.Array      # () i32 events deferred to the next window
    wire_bytes: jax.Array    # () i32 off-shard bytes this window


def exchange_window(
    words: jax.Array,                 # (N,) u32 this shard's new events
    tables: RoutingTables,
    *,
    axis_name: str,
    n_shards: int,
    capacity: int,
    n_links: int = 8,
    impl: str = "auto",
) -> ExchangeOut:
    """One flush window of the spike fabric; call inside shard_map."""
    my = jax.lax.axis_index(axis_name)

    # 1. fused route + aggregate (the paper's LUT 1 + §3.1 buckets)
    if impl in ("auto", "fused", "pallas"):
        from repro.kernels import fused_route_bucket as frb
        use_pallas = None if impl == "auto" else (impl == "pallas")
        b = frb.fused_route_aggregate(
            words, tables.dest_of_addr, tables.guid_of_addr, n_shards,
            capacity, use_pallas=use_pallas).buckets
    else:   # reference impls, route + aggregate staged separately
        dest, guid, routed = tables.route(words)
        words = jnp.where(routed, words, ev.INVALID_EVENT)
        b = aggregator.aggregate(words, dest, guid, n_shards, capacity,
                                 impl=impl)

    # 2. ONE all_to_all ships every bucket (events+guids+counts packed)
    packed = pack_buckets(b.data, b.guids, b.counts)
    recv = jax.lax.all_to_all(packed, axis_name, 0, 0, tiled=True)
    recv = recv.reshape(n_shards, 2 * capacity + 1)
    recv_events, recv_guids, recv_counts = unpack_buckets(recv, capacity)

    # mask out slots beyond the per-source count
    slot = jnp.arange(capacity)[None, :]
    live = slot < recv_counts[:, None]
    recv_events = jnp.where(live, recv_events, ev.INVALID_EVENT)

    # 3. destination-side GUID -> multicast mask -> local links
    flat_ev = recv_events.reshape(-1)
    flat_gu = jnp.where(live, recv_guids, -1).reshape(-1)
    masks = tables.multicast(flat_gu)
    bits = (masks[None, :] >> jnp.arange(n_links, dtype=jnp.uint32)[:, None]) & 1
    link_events = jnp.where(bits.astype(bool), flat_ev[None, :], ev.INVALID_EVENT)

    # wire cost: only off-shard buckets pay Extoll packets
    off = jnp.where(jnp.arange(n_shards) == my, 0, b.counts)
    cost = aggregator.window_cost(off)

    return ExchangeOut(
        recv_events=recv_events,
        recv_guids=recv_guids,
        recv_counts=recv_counts,
        link_events=link_events,
        sent_counts=b.counts,
        overflow=b.overflow,
        wire_bytes=cost.bytes,
    )


def make_exchange(mesh, axis_name: str, *, n_shards: int, capacity: int,
                  n_addr_per_shard: int, n_links: int = 8, impl: str = "auto"):
    """Build the jitted multi-shard exchange.

    Returns f(words[(n_shards, N)], tables[stacked over shard dim]) ->
    ExchangeOut with a leading shard dimension.  ``tables`` is a
    RoutingTables whose arrays carry a leading (n_shards,) dim.
    """
    from jax.experimental.shard_map import shard_map

    def body(words, dest_t, guid_t, mcast_t):
        tables = RoutingTables(dest_t[0], guid_t[0], mcast_t[0])
        return exchange_window(
            words[0], tables, axis_name=axis_name, n_shards=n_shards,
            capacity=capacity, n_links=n_links, impl=impl,
        )

    spec = P(axis_name)
    fn = shard_map(
        lambda w, d, g, m: jax.tree_util.tree_map(
            lambda x: x[None], body(w, d, g, m)
        ),
        mesh=mesh,
        in_specs=(spec, spec, spec, spec),
        out_specs=spec,
        check_rep=False,
    )

    @jax.jit
    def run(words, tables: RoutingTables):
        return fn(words, tables.dest_of_addr, tables.guid_of_addr,
                  tables.mcast_of_guid)

    return run

