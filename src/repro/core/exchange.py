"""Multi-shard spike exchange — the JAX-native Extoll fabric (paper §3).

One "wafer shard" per mesh device along a named axis.  A flush window is:

  1. **route+aggregate** — the fused window kernel
                   (``repro.kernels.fused_route_bucket``): source LUT
                   lookup (§3, LUT 1) and destination-bucketed binning with
                   static capacity (§3.1) in one sort-based pass
  2. **transport**  — a pluggable backend (``repro.transport``) ships every
                   bucket to its owner; each (event, guid) pair is one
                   64-bit wire word (``repro.wire.codec``), and the
                   backend's ``WireFormat`` profile prices the window
                   (frame-exact ``bytes_on_wire``, per-hop latency):

                   * ``"alltoall"`` — wire words|counts packed into ONE
                     ``(n_shards, 2·capacity+1)`` u32 buffer, one global
                     ``all_to_all`` per window; the fabric as a crossbar,
                     paying the latency-bound hop once, exactly like the
                     paper amortizes the Extoll packet header over a bucket.
                   * ``"torus2d"`` / ``"torus3d"`` — torus-faithful: shards
                     fold onto a 2-D (x, y) or 3-D (x, y, z) device torus
                     and each window travels via dimension-ordered neighbor
                     ``ppermute`` hops (X rings, then Y, then Z — the wafer
                     axis) through store-and-forward buffers, governed by
                     hop-by-hop credit-based link flow control (§2.1's
                     notification credits, on EVERY egress link of the
                     route — transit links included).  The lowered HLO
                     contains only neighbor collective-permutes — per-link
                     hop latency, bandwidth and mid-route back-pressure
                     become visible (``LinkStats``) instead of being
                     averaged away by a global collective.

  3. **multicast** — destination-side GUID lookup -> multicast mask,
                   replaying events onto local HICANN links       (§3, LUT 2)

All stages run inside ``shard_map`` so the collectives are explicit and the
roofline's collective term can be read straight off the lowered HLO.

Overflow and back-pressure share one policy: events beyond a bucket's
capacity — and, under the torus backends, whole buckets refused by a
congested link anywhere on their route (``sent_mask``) — are *deferred* to
the next window through the
caller's residue machinery rather than buffered unboundedly in the fabric.
Tests assert conservation at both levels: aggregation
(``offered == sent + deferred + dropped``) and transport
(``offered == sent + deferred``, globally ``sum(sent) == sum(delivered)``).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import transport as tp
from repro import wire
from repro.core import aggregator, events as ev
from repro.core.routing import RoutingTables


class ExchangeOut(NamedTuple):
    """Per-shard result of one flush window (shapes are per-shard)."""

    recv_events: jax.Array   # (n_shards, C) u32 events received per source
    recv_guids: jax.Array    # (n_shards, C) i32
    recv_counts: jax.Array   # (n_shards,) i32
    link_events: jax.Array   # (n_links, n_shards*C) u32 after multicast
    sent_counts: jax.Array   # (n_shards,) i32 events sent per destination
    overflow: jax.Array      # () i32 events beyond bucket capacity
    wire_bytes: jax.Array    # () i32 off-shard bytes this window (all hops)
    sent_mask: jax.Array     # (n_shards,) bool False = bucket row deferred
                             #   by link flow control (re-offer next window)
    link: tp.LinkStats       # per-window link-level stats (incl. the exact
                             #   frame-level bytes_on_wire of the backend's
                             #   WireFormat profile)
    link_state: tp.LinkState  # advanced credit state (thread across windows)
    latency: wire.LatencySummary  # wire-latency digest of this shard's
                             #   off-shard rows DELIVERED this window: per
                             #   traversed link, switch latency + frame
                             #   serialization, plus the queueing dwell
                             #   behind parked in-fabric traffic
                             #   (repro.wire.latency; no waiting term — a
                             #   one-shot window has none)


def exchange_window(
    words: jax.Array,                 # (N,) u32 this shard's new events
    tables: RoutingTables,
    *,
    axis_name: str,
    n_shards: int,
    capacity: int,
    n_links: int = 8,
    impl: str = "auto",
    transport: tp.Transport | None = None,
    link_state: tp.LinkState | None = None,
    wire_format: str | wire.WireFormat = "extoll",
) -> ExchangeOut:
    """One flush window of the spike fabric; call inside shard_map.

    ``wire_format`` selects the frame profile of the default transport;
    an explicitly passed ``transport`` keeps its own profile (the single
    source of truth for byte and latency accounting).
    """

    # 1. fused route + aggregate (the paper's LUT 1 + §3.1 buckets)
    if impl in ("auto", "fused", "pallas"):
        from repro.kernels import fused_route_bucket as frb
        use_pallas = None if impl == "auto" else (impl == "pallas")
        b = frb.fused_route_aggregate(
            words, tables.dest_of_addr, tables.guid_of_addr, n_shards,
            capacity, use_pallas=use_pallas).buckets
    else:   # reference impls, route + aggregate staged separately
        dest, guid, routed = tables.route(words)
        words = jnp.where(routed, words, ev.INVALID_EVENT)
        b = aggregator.aggregate(words, dest, guid, n_shards, capacity,
                                 impl=impl)

    # 2. transport ships every bucket; each (event, guid) pair is one
    #    64-bit wire word (repro.wire.codec: deadline | label | guid meta
    #    lane | valid), lane-planar in a single u32 buffer so alltoall
    #    still lowers to exactly ONE all_to_all
    if transport is None:
        transport = tp.create("alltoall", n_shards=n_shards,
                              wire_format=wire_format)
    payload = wire.encode_planar(b.data, b.guids)
    if link_state is None:
        link_state = transport.init_state(payload.shape[-1])
    out = transport.exchange(link_state, payload, b.counts,
                             axis_name=axis_name)
    recv_events, recv_guids = wire.decode_planar(out.recv_payload)
    recv_counts = out.recv_counts

    # mask out slots beyond the per-source count
    slot = jnp.arange(capacity)[None, :]
    live = slot < recv_counts[:, None]
    recv_events = jnp.where(live, recv_events, ev.INVALID_EVENT)

    # 3. destination-side GUID -> multicast mask -> local links
    flat_ev = recv_events.reshape(-1)
    flat_gu = jnp.where(live, recv_guids, -1).reshape(-1)
    masks = tables.multicast(flat_gu)
    bits = (masks[None, :] >> jnp.arange(n_links, dtype=jnp.uint32)[:, None]) & 1
    link_events = jnp.where(bits.astype(bool), flat_ev[None, :], ev.INVALID_EVENT)

    # per-event wire latency of the rows THIS shard delivered: every
    # traversed link charges switch latency + one re-serialization of the
    # row's frame train (store-and-forward), plus the queueing dwell
    # behind traffic parked along the route and — for rows the fabric
    # delivers from its transit buffers — the park dwell accumulated
    # while waiting there (repro.wire.latency's congestion terms; both
    # exactly zero on an uncontended fabric).  Rows parked mid-route this
    # window are excluded (``sent_now``) — their latency is charged by
    # the window that finally delivers them, custody counts and all.
    my = jax.lax.axis_index(axis_name)
    hops_row = transport.route_hops()[my]
    c_row = jnp.where(out.unparked_now > 0, out.unparked_now, b.counts)
    lat_us = (wire.hop_latency_us(transport.wire_fmt, c_row, hops_row)
              + out.queue_us[my] + out.park_wait_us[my])
    lat_w = (jnp.where((jnp.arange(n_shards) != my) & out.sent_now,
                       b.counts, 0) + out.unparked_now)
    latency = wire.summarize_latency(lat_us, lat_w)

    return ExchangeOut(
        recv_events=recv_events,
        recv_guids=recv_guids,
        recv_counts=recv_counts,
        link_events=link_events,
        sent_counts=b.counts,
        overflow=b.overflow,
        wire_bytes=out.stats.forwarded_bytes,
        sent_mask=out.sent_mask,
        link=out.stats,
        link_state=out.state,
        latency=latency,
    )


def make_exchange(mesh, axis_name: str, *, n_shards: int, capacity: int,
                  n_addr_per_shard: int, n_links: int = 8, impl: str = "auto",
                  transport: str = "alltoall",
                  transport_opts: dict | None = None,
                  wire_format: str | wire.WireFormat = "extoll"):
    """Build the jitted multi-shard exchange.

    ``transport`` selects the backend
    (``"alltoall" | "torus2d" | "torus3d"``);
    ``transport_opts`` are forwarded to :func:`repro.transport.create`
    (torus mesh shape, link credits...).  ``wire_format`` (or an explicit
    ``transport_opts["wire_format"]``) selects the frame-accounting /
    latency profile (``"extoll"`` | ``"ethernet"``).  Returns
    f(words[(n_shards, N)], tables[stacked over shard dim]) -> ExchangeOut
    with a leading shard dimension.  ``tables`` is a RoutingTables whose
    arrays carry a leading (n_shards,) dim.  Link-flow-control state starts
    fresh each call (one-shot window; thread ``exchange_window`` manually
    for multi-window credit dynamics).
    """
    from jax.experimental.shard_map import shard_map

    transport_opts = dict(transport_opts or {})
    transport_opts.setdefault("wire_format", wire_format)
    if transport in ("torus2d", "torus3d"):
        # a bucket row holds up to `capacity` events; the backend raises
        # if link_credits could never admit a full row (livelock guard)
        transport_opts.setdefault("max_row_events", capacity)
    backend = tp.create(transport, n_shards=n_shards, **transport_opts)

    def body(words, dest_t, guid_t, mcast_t):
        tables = RoutingTables(dest_t[0], guid_t[0], mcast_t[0])
        return exchange_window(
            words[0], tables, axis_name=axis_name, n_shards=n_shards,
            capacity=capacity, n_links=n_links, impl=impl,
            transport=backend,
        )

    spec = P(axis_name)
    fn = shard_map(
        lambda w, d, g, m: jax.tree_util.tree_map(
            lambda x: x[None], body(w, d, g, m)
        ),
        mesh=mesh,
        in_specs=(spec, spec, spec, spec),
        out_specs=spec,
        check_rep=False,
    )

    @jax.jit
    def run(words, tables: RoutingTables):
        return fn(words, tables.dest_of_addr, tables.guid_of_addr,
                  tables.mcast_of_guid)

    return run
