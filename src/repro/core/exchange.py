"""Multi-shard spike exchange — the JAX-native Extoll fabric (paper §3).

One "wafer shard" per mesh device along a named axis.  A flush window is:

  1. **route**   — per-shard source lookup: pulse address -> (destination
                   shard, GUID)                                   (§3, LUT 1)
  2. **aggregate** — destination-bucketed binning with static capacity
                   (the paper's buckets; capacity = multiples of the 124
                   event Extoll payload)                          (§3.1)
  3. **all_to_all** — one collective ships every bucket to its owner; this
                   is the TPU ICI playing the Extoll torus's role
  4. **multicast** — destination-side GUID lookup -> multicast mask,
                   replaying events onto local HICANN links       (§3, LUT 2)

All four stages run inside ``shard_map`` so the collective is explicit and
the roofline's collective term can be read straight off the HLO.

Overflow policy: events beyond a bucket's capacity in one window are
*carried over* to the next window through a per-shard residue buffer —
functionally the FPGA's back-pressure on the HICANN links.  Tests assert no
event is ever lost (conservation), matching the bucket model oracle.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import aggregator, events as ev
from repro.core.routing import RoutingTables


class ExchangeOut(NamedTuple):
    """Per-shard result of one flush window (shapes are per-shard)."""

    recv_events: jax.Array   # (n_shards, C) u32 events received per source
    recv_guids: jax.Array    # (n_shards, C) i32
    recv_counts: jax.Array   # (n_shards,) i32
    link_events: jax.Array   # (n_links, n_shards*C) u32 after multicast
    sent_counts: jax.Array   # (n_shards,) i32 events sent per destination
    overflow: jax.Array      # () i32 events deferred to the next window
    wire_bytes: jax.Array    # () i32 off-shard bytes this window


def exchange_window(
    words: jax.Array,                 # (N,) u32 this shard's new events
    tables: RoutingTables,
    *,
    axis_name: str,
    n_shards: int,
    capacity: int,
    n_links: int = 8,
    impl: str = "auto",
) -> ExchangeOut:
    """One flush window of the spike fabric; call inside shard_map."""
    my = jax.lax.axis_index(axis_name)

    # 1. route (source LUT)
    dest, guid, routed = tables.route(words)
    words = jnp.where(routed, words, ev.INVALID_EVENT)

    # 2. aggregate into per-destination buckets (the paper's §3.1)
    b = aggregator.aggregate(words, dest, guid, n_shards, capacity, impl=impl)

    # 3. one all_to_all ships every bucket to its owner shard
    recv_events = jax.lax.all_to_all(b.data, axis_name, 0, 0, tiled=True)
    recv_events = recv_events.reshape(n_shards, capacity)
    recv_guids = jax.lax.all_to_all(b.guids, axis_name, 0, 0, tiled=True)
    recv_guids = recv_guids.reshape(n_shards, capacity)
    recv_counts = jax.lax.all_to_all(
        b.counts.reshape(n_shards, 1), axis_name, 0, 0, tiled=True
    ).reshape(n_shards)

    # mask out slots beyond the per-source count
    slot = jnp.arange(capacity)[None, :]
    live = slot < recv_counts[:, None]
    recv_events = jnp.where(live, recv_events, ev.INVALID_EVENT)

    # 4. destination-side GUID -> multicast mask -> local links
    flat_ev = recv_events.reshape(-1)
    flat_gu = jnp.where(live, recv_guids, -1).reshape(-1)
    masks = tables.multicast(flat_gu)
    bits = (masks[None, :] >> jnp.arange(n_links, dtype=jnp.uint32)[:, None]) & 1
    link_events = jnp.where(bits.astype(bool), flat_ev[None, :], ev.INVALID_EVENT)

    # wire cost: only off-shard buckets pay Extoll packets
    off = jnp.where(jnp.arange(n_shards) == my, 0, b.counts)
    cost = aggregator.window_cost(off)

    return ExchangeOut(
        recv_events=recv_events,
        recv_guids=recv_guids,
        recv_counts=recv_counts,
        link_events=link_events,
        sent_counts=b.counts,
        overflow=b.overflow,
        wire_bytes=cost.bytes,
    )


def make_exchange(mesh, axis_name: str, *, n_shards: int, capacity: int,
                  n_addr_per_shard: int, n_links: int = 8, impl: str = "auto"):
    """Build the jitted multi-shard exchange.

    Returns f(words[(n_shards, N)], tables[stacked over shard dim]) ->
    ExchangeOut with a leading shard dimension.  ``tables`` is a
    RoutingTables whose arrays carry a leading (n_shards,) dim.
    """
    from jax.experimental.shard_map import shard_map

    def body(words, dest_t, guid_t, mcast_t):
        tables = RoutingTables(dest_t[0], guid_t[0], mcast_t[0])
        return exchange_window(
            words[0], tables, axis_name=axis_name, n_shards=n_shards,
            capacity=capacity, n_links=n_links, impl=impl,
        )

    spec = P(axis_name)
    fn = shard_map(
        lambda w, d, g, m: jax.tree_util.tree_map(
            lambda x: x[None], body(w, d, g, m)
        ),
        mesh=mesh,
        in_specs=(spec, spec, spec, spec),
        out_specs=spec,
        check_rep=False,
    )

    @jax.jit
    def run(words, tables: RoutingTables):
        return fn(words, tables.dest_of_addr, tables.guid_of_addr,
                  tables.mcast_of_guid)

    return run

