"""Cycle-level functional model of the event-aggregation buckets (paper §3.1).

This is the "simulation model of the event aggregation buckets" the paper
names as its next step.  It models, per FPGA:

* a **map table** binding network destinations to physical buckets,
* a **free-bucket list** (functionally: lowest-index free bucket),
* **bucket renaming**: when an event addresses a destination with no bound
  bucket and no bucket is free, the *most urgent* bucket is flushed and its
  binding is stolen (paper: "If no bucket is free the next appropriate one
  is flushed"),
* **deadline flushing**: a bucket is flushed when its most urgent timestamp
  deadline minus the configured margin is reached, or when it is full,
  or on external trigger,
* **concurrent flushing and aggregation** via the two-counter scheme: at
  flush-trigger time the accumulation side is handed to the drain engine and
  the bucket immediately continues accumulating from zero (the functional
  equivalent of swapping the increment/decrement counters),
* a serial **output port** that drains one packet at a time at the link
  datapath rate (16 B/cycle), which is what makes header overhead visible:
  un-aggregated single events drain at 1/2 event per cycle while input
  arrives at up to `events_per_cycle` per cycle.

Everything is pure-functional and `lax.scan`-able so the same model runs
under jit for long traffic traces, and serves as the oracle for the
vectorized aggregator and the Pallas kernel.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import events as ev

NO_BUCKET = jnp.int32(-1)
NO_DEST = jnp.int32(-1)
_BIG = jnp.int32(1 << 20)


class BucketConfig(NamedTuple):
    n_buckets: int = 8
    capacity: int = ev.PACKET_MAX_EVENTS       # 124 events / 496 B
    n_dest: int = 64                            # destinations this shard talks to
    flush_margin: int = 64                      # systemtime units of slack kept
    queue: int = 4                              # flush requests the port can hold


class BucketState(NamedTuple):
    """All per-FPGA aggregation state. Shapes: B=n_buckets, C=capacity."""

    map_table: jax.Array      # (n_dest,) i32: dest -> bucket | NO_BUCKET
    bucket_dest: jax.Array    # (B,) i32: bucket -> dest | NO_DEST (free)
    fill: jax.Array           # (B,) i32 accumulation-side counter
    deadline: jax.Array       # (B,) i32 most urgent ts (ring); _BIG if empty
    storage: jax.Array        # (B, C) u32 packed events
    # drain engine: a small queue of triggered packets + port busy counter
    q_dest: jax.Array         # (Q,) i32
    q_count: jax.Array        # (Q,) i32
    q_events: jax.Array       # (Q, C) u32
    q_len: jax.Array          # () i32
    port_busy: jax.Array      # () i32 cycles until port free
    now: jax.Array            # () i32 systemtime


class CycleOut(NamedTuple):
    """Per-cycle observable outputs (for stats / verification)."""

    sent_dest: jax.Array      # () i32 dest of packet leaving the port (-1)
    sent_count: jax.Array     # () i32 events in that packet
    sent_events: jax.Array    # (C,) u32 its payload
    stalled: jax.Array        # () i32 input events refused this cycle
    deadline_miss: jax.Array  # () i32 events whose deadline passed pre-send


def init_state(cfg: BucketConfig) -> BucketState:
    B, C, Q = cfg.n_buckets, cfg.capacity, cfg.queue
    return BucketState(
        map_table=jnp.full((cfg.n_dest,), NO_BUCKET),
        bucket_dest=jnp.full((B,), NO_DEST),
        fill=jnp.zeros((B,), jnp.int32),
        deadline=jnp.full((B,), _BIG),
        storage=jnp.zeros((B, C), jnp.uint32),
        q_dest=jnp.full((Q,), NO_DEST),
        q_count=jnp.zeros((Q,), jnp.int32),
        q_events=jnp.zeros((Q, C), jnp.uint32),
        q_len=jnp.int32(0),
        port_busy=jnp.int32(0),
        now=jnp.int32(0),
    )


def _urgency(state: BucketState, cfg: BucketConfig) -> jax.Array:
    """Slack (in systemtime units) per bucket; empty buckets -> +BIG."""
    slack = ev.ts_slack(state.deadline & ev.TS_MASK, state.now & ev.TS_MASK)
    return jnp.where(state.fill > 0, slack, _BIG)


def _trigger_flush(state: BucketState, b: jax.Array, cfg: BucketConfig):
    """Hand bucket b's accumulation side to the drain queue ('counter swap').

    The bucket keeps its destination binding but restarts from fill=0, so
    aggregation continues concurrently with the drain — the observable
    behaviour of the paper's two-counter swap.  Returns (state, ok): ok is
    False when the drain queue is full (flush request must retry; the
    caller treats this as back-pressure).
    """
    q_free = state.q_len < state.q_dest.shape[0]
    do = q_free & (state.fill[b] > 0)

    slot = state.q_len
    q_dest = jnp.where(do, state.q_dest.at[slot].set(state.bucket_dest[b]), state.q_dest)
    q_count = jnp.where(do, state.q_count.at[slot].set(state.fill[b]), state.q_count)
    q_events = jnp.where(do, state.q_events.at[slot].set(state.storage[b]), state.q_events)
    q_len = jnp.where(do, state.q_len + 1, state.q_len)

    fill = jnp.where(do, state.fill.at[b].set(0), state.fill)
    deadline = jnp.where(do, state.deadline.at[b].set(_BIG), state.deadline)
    return state._replace(
        q_dest=q_dest, q_count=q_count, q_events=q_events, q_len=q_len,
        fill=fill, deadline=deadline,
    ), do | ~(state.fill[b] > 0)


def _unbind(state: BucketState, b: jax.Array) -> BucketState:
    """Release bucket b back to the free list."""
    old_dest = state.bucket_dest[b]
    map_table = jnp.where(
        old_dest >= 0,
        state.map_table.at[jnp.maximum(old_dest, 0)].set(NO_BUCKET),
        state.map_table,
    )
    return state._replace(
        map_table=map_table, bucket_dest=state.bucket_dest.at[b].set(NO_DEST)
    )


def _accept_event(state: BucketState, word: jax.Array, dest: jax.Array,
                  cfg: BucketConfig):
    """Route one event through map-table lookup / renaming / append.

    Returns (state, stalled:int32, full_flush_needed bucket id or -1).
    """
    valid = ev.is_valid(word) & (dest >= 0)
    dest_c = jnp.clip(dest, 0, cfg.n_dest - 1)
    b = state.map_table[dest_c]
    bound = valid & (b != NO_BUCKET)

    # --- renaming path: need a bucket for a new destination -------------
    free_mask = state.bucket_dest == NO_DEST
    any_free = jnp.any(free_mask)
    free_b = jnp.argmax(free_mask).astype(jnp.int32)          # lowest free

    # no free bucket: flush the most urgent bound one and steal it
    need_steal = valid & ~bound & ~any_free
    victim = jnp.argmin(_urgency(state, cfg)).astype(jnp.int32)
    state2, ok = _trigger_flush(state, victim, cfg)
    # steal only if the flush was accepted by the queue
    can_steal = need_steal & ok
    state2 = jax.lax.cond(can_steal, lambda s: _unbind(s, victim), lambda s: s, state2)
    state = jax.tree_util.tree_map(
        lambda a, c: jnp.where(need_steal, c, a), state, state2
    )
    free_after = jnp.where(can_steal, victim, free_b)
    have_bucket = bound | (valid & ~bound & (any_free | can_steal))
    tgt = jnp.where(bound, b, free_after)
    stalled = (valid & ~have_bucket).astype(jnp.int32)

    # --- bind if new ------------------------------------------------------
    newly = valid & ~bound & have_bucket
    map_table = jnp.where(
        newly, state.map_table.at[dest_c].set(tgt), state.map_table
    )
    bucket_dest = jnp.where(
        newly, state.bucket_dest.at[tgt].set(dest_c), state.bucket_dest
    )

    # --- append ----------------------------------------------------------
    tgt_c = jnp.clip(tgt, 0, cfg.n_buckets - 1)
    pos = jnp.clip(state.fill[tgt_c], 0, cfg.capacity - 1)
    do_app = have_bucket
    storage = jnp.where(
        do_app, state.storage.at[tgt_c, pos].set(word), state.storage
    )
    new_fill = state.fill[tgt_c] + 1
    fill = jnp.where(do_app, state.fill.at[tgt_c].set(new_fill), state.fill)
    ts = ev.timestamp(word).astype(jnp.int32)
    cur = state.deadline[tgt_c]
    more_urgent = (cur == _BIG) | ev.ts_before(ts, cur & ev.TS_MASK)
    deadline = jnp.where(
        do_app & more_urgent, state.deadline.at[tgt_c].set(ts), state.deadline
    )
    state = state._replace(
        map_table=map_table, bucket_dest=bucket_dest,
        storage=storage, fill=fill, deadline=deadline,
    )
    full_b = jnp.where(do_app & (new_fill >= cfg.capacity), tgt_c, NO_BUCKET)
    return state, stalled, full_b


def cycle(state: BucketState, words: jax.Array, dests: jax.Array,
          cfg: BucketConfig, force_flush: jax.Array | None = None):
    """Advance the model by one FPGA clock.

    words/dests: (E,) packed events + routed destinations arriving this
    cycle (invalid-flagged slots are ignored).  force_flush: optional ()
    bool external flush trigger (flushes the most urgent bucket).
    Returns (state, CycleOut).
    """
    stalled = jnp.int32(0)
    # 1. accept this cycle's arrivals (pipeline order, E is small+static)
    pending_full = jnp.full((words.shape[0],), NO_BUCKET)
    for i in range(words.shape[0]):
        state, s, fb = _accept_event(state, words[i], dests[i], cfg)
        stalled = stalled + s
        pending_full = pending_full.at[i].set(fb)

    # 2. flush triggers: full buckets first, then deadline, then external
    for i in range(pending_full.shape[0]):
        fb = pending_full[i]
        state = jax.lax.cond(
            fb >= 0,
            lambda s: _trigger_flush(s, jnp.maximum(fb, 0), cfg)[0],
            lambda s: s,
            state,
        )

    urg = _urgency(state, cfg)
    most_urgent = jnp.argmin(urg).astype(jnp.int32)
    deadline_due = urg[most_urgent] <= cfg.flush_margin
    ext = jnp.bool_(False) if force_flush is None else force_flush
    state = jax.lax.cond(
        deadline_due | ext,
        lambda s: _trigger_flush(s, most_urgent, cfg)[0],
        lambda s: s,
        state,
    )

    # 3. port: start next packet if idle, shift one datapath word per cycle
    def start(s: BucketState):
        n = s.q_count[0]
        out = CycleOut(
            sent_dest=s.q_dest[0], sent_count=n, sent_events=s.q_events[0],
            stalled=jnp.int32(0), deadline_miss=jnp.int32(0),
        )
        busy = ev.wire_cycles(n).astype(jnp.int32)
        s = s._replace(
            q_dest=jnp.roll(s.q_dest, -1, 0).at[-1].set(NO_DEST),
            q_count=jnp.roll(s.q_count, -1, 0).at[-1].set(0),
            q_events=jnp.roll(s.q_events, -1, 0).at[-1].set(0),
            q_len=s.q_len - 1,
            port_busy=busy,
        )
        return s, out

    def idle(s: BucketState):
        out = CycleOut(
            sent_dest=NO_DEST, sent_count=jnp.int32(0),
            sent_events=jnp.zeros((cfg.capacity,), jnp.uint32),
            stalled=jnp.int32(0), deadline_miss=jnp.int32(0),
        )
        return s, out

    can_start = (state.port_busy <= 0) & (state.q_len > 0)
    state, out = jax.lax.cond(can_start, start, idle, state)

    # deadline misses: events leaving the port later than their deadline
    miss = jnp.sum(
        jnp.where(
            (jnp.arange(cfg.capacity) < out.sent_count)
            & (ev.ts_slack(ev.timestamp(out.sent_events),
                           state.now & ev.TS_MASK) < 0),
            1, 0,
        )
    ).astype(jnp.int32)

    state = state._replace(
        port_busy=jnp.maximum(state.port_busy - 1, 0), now=state.now + 1
    )
    return state, out._replace(stalled=stalled, deadline_miss=miss)


def run_trace(cfg: BucketConfig, words: jax.Array, dests: jax.Array):
    """Scan the model over a (T, E) trace. Returns (final_state, CycleOut/T)."""
    state = init_state(cfg)

    def step(s, xs):
        w, d = xs
        return cycle(s, w, d, cfg)

    return jax.lax.scan(step, state, (words, dests))
