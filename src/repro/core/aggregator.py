"""Vectorized window aggregation — the TPU-native form of the paper's buckets.

An FPGA pipelines one event per clock through the renaming logic; a TPU is a
throughput machine, so we aggregate a *window* of events at once: all events
produced during one flush window (whose length is bounded by the minimum
timestamp slack, i.e. the paper's deadline-flush condition) are binned by
network destination into fixed-capacity buckets, which then feed a single
``all_to_all``.  This is the same capacity-bounded binning MoE dispatch
uses, and `repro.models.moe` reuses exactly this code with experts as
destinations.

Implementations with identical semantics (checked against each other and
against the cycle model in tests):

* ``aggregate_onehot`` — O(N·D) one-hot cumsum; tiny and fusion-friendly,
  the original reference formulation.
* ``aggregate_sort``   — O(N log N) argsort by destination; kept as an
  independently-written cross-check.
* ``impl="fused"``     — the fast path: one stable multi-operand
  ``lax.sort`` + gather placement (``repro.kernels.fused_route_bucket``),
  ~an order of magnitude faster than ``onehot`` on CPU at window scale.
* ``impl="pallas"``    — same math with the placement stage in the Pallas
  TPU kernel (compiled on TPU, interpret elsewhere).

``impl="auto"`` picks ``pallas`` where the kernel compiles (TPU) and
``fused`` everywhere else.

Semantics: events are processed in window order; for each destination the
first ``capacity`` events are placed at slots 0..k-1 of its bucket, events
beyond capacity are *overflow* (counted; the caller either sizes capacity
for zero overflow or re-offers them next window — both modes are used, see
``repro.core.exchange``).  Invalid events (valid bit clear or dest < 0) are
ignored.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import events as ev


class Buckets(NamedTuple):
    """Result of one aggregation window.

    data:     (D, C) uint32 packed events (slot j < counts[d] is valid)
    guids:    (D, C) int32 GUIDs travelling with the events (or zeros)
    counts:   (D,)   int32 events accepted per destination
    overflow: ()     int32 events dropped because a bucket was full
    """

    data: jax.Array
    guids: jax.Array
    counts: jax.Array
    overflow: jax.Array


def _positions_onehot(dest: jax.Array, valid: jax.Array, n_dest: int):
    """Slot index of each event within its destination bucket (window order)."""
    oh = jax.nn.one_hot(jnp.where(valid, dest, n_dest), n_dest + 1,
                        dtype=jnp.int32)[:, :n_dest]          # (N, D)
    pos = jnp.cumsum(oh, axis=0) - oh                          # exclusive
    return jnp.sum(pos * oh, axis=1), jnp.sum(oh, axis=0)      # (N,), (D,)


def aggregate_onehot(words: jax.Array, dest: jax.Array, guids: jax.Array,
                     n_dest: int, capacity: int) -> Buckets:
    valid = ev.is_valid(words) & (dest >= 0) & (dest < n_dest)
    pos, counts = _positions_onehot(dest, valid, n_dest)
    keep = valid & (pos < capacity)
    # out-of-range destination index + mode="drop" discards rejected events
    data = jnp.zeros((n_dest, capacity), jnp.uint32).at[
        jnp.where(keep, dest, n_dest), jnp.where(keep, pos, 0)
    ].set(words, mode="drop")
    gui = jnp.zeros((n_dest, capacity), jnp.int32).at[
        jnp.where(keep, dest, n_dest), jnp.where(keep, pos, 0)
    ].set(guids, mode="drop")
    accepted = jnp.minimum(counts, capacity)
    overflow = jnp.sum(counts - accepted).astype(jnp.int32)
    return Buckets(data, gui, accepted, overflow)


def aggregate_sort(words: jax.Array, dest: jax.Array, guids: jax.Array,
                   n_dest: int, capacity: int) -> Buckets:
    n = words.shape[0]
    valid = ev.is_valid(words) & (dest >= 0) & (dest < n_dest)
    key = jnp.where(valid, dest, n_dest)                      # invalid last
    order = jnp.argsort(key, stable=True)
    skey = key[order]
    swords = words[order]
    sguids = guids[order]
    # slot within group: index - index-of-first-with-same-key
    idx = jnp.arange(n)
    first = jnp.searchsorted(skey, skey, side="left")
    pos = idx - first
    counts = jnp.bincount(jnp.where(valid, dest, 0),
                          weights=valid.astype(jnp.int32),
                          length=n_dest).astype(jnp.int32)
    keep = (skey < n_dest) & (pos < capacity)
    data = jnp.zeros((n_dest, capacity), jnp.uint32).at[
        jnp.where(keep, skey, n_dest), jnp.where(keep, pos, 0)
    ].set(swords, mode="drop")
    gui = jnp.zeros((n_dest, capacity), jnp.int32).at[
        jnp.where(keep, skey, n_dest), jnp.where(keep, pos, 0)
    ].set(sguids, mode="drop")
    accepted = jnp.minimum(counts, capacity)
    overflow = jnp.sum(counts - accepted).astype(jnp.int32)
    return Buckets(data, gui, accepted, overflow)


def aggregate(words: jax.Array, dest: jax.Array, guids: jax.Array | None,
              n_dest: int, capacity: int, impl: str = "auto") -> Buckets:
    """Bin a window of events into per-destination buckets.

    impl: "onehot" | "sort" | "fused" | "pallas" | "auto".
    "auto" selects the compiled Pallas kernel on TPU and the fused
    sort-based XLA path elsewhere (both beat onehot/sort by a wide margin
    at window scale; the quadratic impls remain as cross-check oracles).
    """
    if guids is None:
        guids = jnp.zeros_like(words, dtype=jnp.int32)
    dest = dest.astype(jnp.int32)
    if impl == "auto":
        from repro.kernels import dispatch
        impl = "pallas" if dispatch.use_pallas() else "fused"
    if impl == "onehot":
        return aggregate_onehot(words, dest, guids, n_dest, capacity)
    if impl == "sort":
        return aggregate_sort(words, dest, guids, n_dest, capacity)
    if impl == "fused":
        from repro.kernels import fused_route_bucket as frb
        return frb.fused_aggregate(words, dest, guids, n_dest, capacity,
                                   use_pallas=False).buckets
    if impl == "pallas":
        from repro.kernels import ops as kops
        return kops.fused_scatter(words, dest, guids, n_dest, capacity)
    raise ValueError(f"unknown impl {impl!r}")


def overflow_mask(words: jax.Array, dest: jax.Array, n_dest: int,
                  capacity: int) -> jax.Array:
    """True for events NOT accepted this window (bucket already full).

    Callers re-offer these next window (the FPGA's back-pressure on the
    HICANN links); with the cycle model this yields exact conservation.
    """
    valid = ev.is_valid(words) & (dest >= 0) & (dest < n_dest)
    pos, _ = _positions_onehot(dest.astype(jnp.int32), valid, n_dest)
    return valid & (pos >= capacity)


# ---------------------------------------------------------------------------
# Wire-cost model for a flush window (used by benchmarks / roofline).
# ---------------------------------------------------------------------------

class WindowCost(NamedTuple):
    packets: jax.Array      # () i32 packets emitted
    bytes: jax.Array        # () i32 wire bytes (headers + padded payload)
    cycles: jax.Array       # () i32 serial port cycles to drain the window
    efficiency: jax.Array   # () f32 useful payload fraction


def window_cost(counts: jax.Array,
                max_events_per_packet: int = ev.PACKET_MAX_EVENTS) -> WindowCost:
    """Cost of flushing buckets with ``counts`` events to the wire.

    A destination with more than 124 accepted events emits multiple packets
    (ceil(count/124)); each packet pays the header.
    """
    c = counts.astype(jnp.int32)
    full = c // max_events_per_packet
    rem = c % max_events_per_packet
    packets = full + (rem > 0)
    bytes_full = full * ev.packet_bytes(max_events_per_packet)
    bytes_rem = jnp.where(rem > 0, ev.packet_bytes(rem), 0)
    total_bytes = jnp.sum(bytes_full + bytes_rem)
    cycles = (total_bytes + ev.DATAPATH_BYTES_PER_CYCLE - 1) // ev.DATAPATH_BYTES_PER_CYCLE
    useful = jnp.sum(c) * ev.EVENT_BYTES
    effic = jnp.where(total_bytes > 0, useful / jnp.maximum(total_bytes, 1), 0.0)
    return WindowCost(jnp.sum(packets).astype(jnp.int32),
                      total_bytes.astype(jnp.int32),
                      cycles.astype(jnp.int32),
                      effic.astype(jnp.float32))


def unaggregated_cost(n_events: jax.Array) -> WindowCost:
    """Cost of the no-aggregation baseline: one packet per event."""
    n = jnp.asarray(n_events, jnp.int32)
    per = ev.packet_bytes(1)
    total_bytes = n * per
    cycles = n * ev.wire_cycles(1)
    eff = jnp.where(n > 0, (n * ev.EVENT_BYTES) / jnp.maximum(total_bytes, 1), 0.0)
    return WindowCost(n, total_bytes, cycles.astype(jnp.int32),
                      eff.astype(jnp.float32))
