"""Fault-tolerant checkpointing: atomic, resharding-on-restore, retention.

Design (single-process container standing in for a multi-host job):

* **Atomicity** — write into ``step_<N>.tmp/`` then ``os.rename`` to
  ``step_<N>/``; a crash mid-write never corrupts the latest checkpoint
  (rename is atomic on POSIX).  ``latest`` discovery scans committed dirs.
* **Contents** — the full pytree (params + optimizer moments + step + data
  pipeline cursor + PRNG key), flattened to path-keyed ``.npy`` files plus
  a manifest; nothing is re-derivable state, so restart is exact.
* **Elastic restore** — values are ``jax.device_put`` against the *current*
  mesh's shardings, so a job restarted on a different mesh shape (e.g.
  512 -> 256 chips after losing a pod) resumes with resharded state; on a
  real cluster each host would read only its shards (the manifest carries
  the logical shapes needed to do that).
* **Retention** — keep the last ``keep`` checkpoints, delete older.
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
        return out
    if isinstance(tree, (tuple, list)) and not hasattr(tree, "_fields"):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
        return out
    if hasattr(tree, "_fields"):                    # NamedTuple
        for k in tree._fields:
            out.update(_flatten(getattr(tree, k), f"{prefix}{k}/"))
        return out
    out[prefix.rstrip("/")] = tree
    return out


def _unflatten_into(template, flat, prefix=""):
    if isinstance(template, dict):
        return {k: _unflatten_into(v, flat, f"{prefix}{k}/")
                for k, v in template.items()}
    if hasattr(template, "_fields"):
        return type(template)(*(
            _unflatten_into(getattr(template, k), flat, f"{prefix}{k}/")
            for k in template._fields))
    if isinstance(template, (tuple, list)):
        vals = [_unflatten_into(v, flat, f"{prefix}{i}/")
                for i, v in enumerate(template)]
        return type(template)(vals)
    return flat[prefix.rstrip("/")]


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # -- save --------------------------------------------------------------
    def save(self, step: int, state) -> str:
        flat = _flatten(state)
        tmp = os.path.join(self.dir, f"step_{step:010d}.tmp")
        final = os.path.join(self.dir, f"step_{step:010d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {}
        for k, v in flat.items():
            arr = np.asarray(jax.device_get(v))
            fname = k.replace("/", "__") + ".npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest[k] = {"file": fname, "shape": list(arr.shape),
                           "dtype": str(arr.dtype)}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "arrays": manifest}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                       # atomic commit
        self._gc()
        return final

    # -- restore -------------------------------------------------------------
    def latest_step(self) -> int | None:
        steps = [int(d.split("_")[1]) for d in os.listdir(self.dir)
                 if d.startswith("step_") and not d.endswith(".tmp")]
        return max(steps) if steps else None

    def restore(self, template, step: int | None = None, shardings=None):
        """Restore into ``template``'s structure; optionally device_put with
        ``shardings`` (same structure) for elastic mesh-reshape restore."""
        step = self.latest_step() if step is None else step
        if step is None:
            return None
        path = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)["arrays"]
        flat = {k: np.load(os.path.join(path, m["file"]))
                for k, m in manifest.items()}
        state = _unflatten_into(template, flat)
        if shardings is not None:
            state = jax.tree_util.tree_map(
                lambda v, s: jax.device_put(v, s), state, shardings)
        return state

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.dir)
            if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)
