"""Atomic, reshard-on-restore checkpointing."""
from repro.checkpoint import checkpointer  # noqa: F401
