"""Pallas TPU kernels for the paper's compute hot-spots:
fused_route_bucket (fused routing + event aggregation §3/§3.1, the hot
path), bucket_scatter (legacy one-hot aggregation kernel, cross-check) and
lif_step (workload inner loop).  Each has a pure-jnp oracle in ref.py;
backend dispatch (compiled TPU vs interpret/XLA fallback) is centralised in
dispatch.py."""
from repro.kernels import dispatch, ops, ref  # noqa: F401
