"""Pallas TPU kernels for the paper's compute hot-spots:
bucket_scatter (event aggregation §3.1) and lif_step (workload inner loop).
Each has a pure-jnp oracle in ref.py; validated in interpret mode on CPU."""
from repro.kernels import ops, ref  # noqa: F401
