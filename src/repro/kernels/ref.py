"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

These are small, obviously-correct implementations used by the kernel
tests' ``assert_allclose`` sweeps — independent from the optimized
``core.aggregator`` paths, which are themselves tested against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.snn.lif import LIFParams, LIFState
from repro.snn import lif as lif_mod


def bucket_scatter_ref(words, dests, guids, n_dest: int, capacity: int):
    """O(N * D * C) reference binning, window order, capacity-clipped.

    Returns (data (D, C) u32, guids (D, C) i32, raw_counts (D,) i32).
    """
    n = words.shape[0]
    d_ids = jnp.arange(n_dest)
    mask = dests[None, :] == d_ids[:, None]                 # (D, N)
    mask_i = mask.astype(jnp.int32)
    pos = jnp.cumsum(mask_i, axis=1) - mask_i               # exclusive
    onehot = mask[:, :, None] & (pos[:, :, None]
                                 == jnp.arange(capacity)[None, None, :])
    data = jnp.sum(jnp.where(onehot, words.astype(jnp.int32)[None, :, None],
                             0), axis=1).astype(jnp.uint32)
    gout = jnp.sum(jnp.where(onehot, guids[None, :, None], 0), axis=1)
    counts = jnp.sum(mask_i, axis=1)
    return data, gout.astype(jnp.int32), counts


def fused_route_aggregate_ref(words, dest_lut, guid_lut, n_dest: int,
                              capacity: int):
    """Obviously-correct oracle for the fused route+aggregate kernel.

    Routes via the clamped-index LUT semantics of ``RoutingTables.route``
    and reuses the O(N·D·C) binning oracle above.  Returns
    (data (D, C) u32, guids (D, C) i32, raw_counts (D,) i32).
    """
    from repro.core import events as ev
    addr = ev.address(words).astype(jnp.int32)
    idx = jnp.minimum(addr, dest_lut.shape[0] - 1)
    dest = jnp.take(dest_lut, idx)
    guid = jnp.take(guid_lut, idx).astype(jnp.int32)
    valid = ev.is_valid(words) & (dest >= 0) & (dest < n_dest)
    dm = jnp.where(valid, dest, -1)
    wm = jnp.where(valid, words, jnp.uint32(0))
    return bucket_scatter_ref(wm, dm, guid, n_dest, capacity)


def lif_step_ref(state: LIFState, p: LIFParams, exc_in, inh_in, i_ext):
    """The SNN substrate's own step function is the oracle."""
    st, spk = lif_mod.step(state, p, exc_in, inh_in, i_ext)
    return st, spk.astype(jnp.int32)


def ssd_chunk_ref(x, dt, A, B, C, s_prev):
    """Pure-jnp oracle for one SSD chunk (all (batch,head) pairs).

    Same math as models/ssm.ssd_chunked's chunk_step, flattened to (BH,).
    """
    da = dt * A[:, None]                                  # (BH, c)
    cum = jnp.cumsum(da, axis=1)
    seg = cum[:, -1]
    c_len = x.shape[1]
    causal = jnp.tril(jnp.ones((c_len, c_len), bool))
    diff = cum[:, :, None] - cum[:, None, :]
    decay = jnp.where(causal[None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("gin,gjn->gij", C, B)
    y = jnp.einsum("gij,gij,gj,gjp->gip", scores, decay, dt, x)
    y += jnp.einsum("gin,gpn,gi->gip", C, s_prev, jnp.exp(cum))
    w = jnp.exp(seg[:, None] - cum) * dt
    s_loc = jnp.einsum("gjp,gjn,gj->gpn", x, B, w)
    s_new = s_prev * jnp.exp(seg)[:, None, None] + s_loc
    return y, s_new
