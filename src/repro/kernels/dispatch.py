"""Backend dispatch for the Pallas kernels.

One policy, used by every kernel wrapper:

* On TPU the kernels compile (``interpret=False``) — that is the whole
  point of writing them in Pallas.
* On CPU/GPU hosts the Pallas bodies run in *interpret* mode, which is a
  correctness tool, not a fast path; performance-sensitive call sites
  therefore auto-select a pure-XLA implementation of the same math
  (``use_pallas() is False``) and only exercise interpret mode in tests.

``REPRO_PALLAS_INTERPRET`` overrides both decisions (``0`` forces compiled
Pallas, ``1`` forces interpret mode) so a TPU host can still run the
interpreter for debugging and CI can pin behaviour.
"""
from __future__ import annotations

import os

import jax


def _env_override() -> bool | None:
    v = os.environ.get("REPRO_PALLAS_INTERPRET")
    if v is None:
        return None
    return v != "0"


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def default_interpret() -> bool:
    """Interpret Pallas kernel bodies? Compiled on TPU, interpret elsewhere."""
    env = _env_override()
    if env is not None:
        return env
    return not on_tpu()


def use_pallas() -> bool:
    """Should auto-dispatch route hot paths through the Pallas kernels?

    True only where the kernel actually compiles: TPU, or an explicit
    ``REPRO_PALLAS_INTERPRET=0`` override.
    """
    env = _env_override()
    if env is not None:
        return not env
    return on_tpu()
