"""Fused route+aggregate flush-window kernel (paper §3, §3.1 in one pass).

The seed hot path was three separate stages — routing-LUT gather, then an
O(N·D·C) per-destination one-hot reduce (``bucket_scatter.py``), then the
collective — and the Pallas kernel only ever ran in interpret mode.  This
module replaces the compute side with a sort-based formulation:

  1. **route**   — ``dest = dest_lut[addr]`` gather, validity from the
                   event's valid bit and ``NO_ROUTE`` (LUT 1 of the paper)
  2. **rank**    — one stable multi-operand ``lax.sort`` by destination
                   groups each destination's events contiguously in window
                   order: O(N log N), and the slot of an event is simply its
                   offset from the first event of its destination
  3. **place**   — each destination's bucket row is a *dynamic slice* of
                   the sorted window (O(D·C) total, no scatter); the
                   destination-GUID lookup (LUT 1's second output) is fused
                   into placement so only the ≤ C accepted events per
                   destination are gathered, not all N
  4. **residue** — events beyond a bucket's capacity are compacted into a
                   fixed-size carry buffer re-offered next window (the
                   FPGA's back-pressure on the HICANN links)

Stage 3 is a Pallas TPU kernel (grid over destination tiles, per-row
``pl.ds`` loads from the VMEM-resident sorted window, in-kernel guid-LUT
gather).  Backend dispatch is automatic (``kernels.dispatch``): compiled
Pallas on TPU, pure-XLA placement on CPU/GPU where interpret mode would be
a correctness tool rather than a fast path; tests exercise the interpret
path explicitly against the ``ref.py`` oracle.

The destination gather (stage 1) stays in XLA because it *produces the sort
key*; fusing it into the placement kernel would force the sort inside the
kernel, which TPU Pallas cannot lower.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.core import events as ev
from repro.core.aggregator import Buckets
from repro.kernels import dispatch

D_TILE = 8


class FusedWindow(NamedTuple):
    """Result of one fused route+aggregate window.

    buckets:  the standard ``aggregator.Buckets`` (data/guids/counts/overflow)
    residue:  (residue_len,) u32 deferred events, window-grouped, INVALID-padded
    deferred: () i32 events carried to the next window via ``residue``
    dropped:  () i32 overflow events that did not fit the residue buffer
    offered:  () i32 valid routed events offered this window
    residue_meta: (residue_len,) i32 the deferred events' meta values (the
              ``guids`` operand, e.g. the simulator's per-event injection
              timestamps), aligned with ``residue``; None unless requested
              via ``with_residue_meta`` (explicit-meta path only)
    """

    buckets: Buckets
    residue: jax.Array
    deferred: jax.Array
    dropped: jax.Array
    offered: jax.Array
    residue_meta: jax.Array | None = None


# ---------------------------------------------------------------------------
# Pallas placement kernels — stage 3.
# ---------------------------------------------------------------------------

def _row(words_ref, start, capacity):
    return words_ref[pl.ds(start, capacity)].reshape(1, capacity)


def _place_kernel(first_ref, counts_ref, words_ref, guids_ref,
                  data_ref, gout_ref, *, capacity: int, d_tile: int):
    """Explicit per-event guids travelled through the sort with the words."""
    slot = lax.broadcasted_iota(jnp.int32, (1, capacity), 1)
    for d in range(d_tile):
        start = first_ref[d]
        live = slot < jnp.minimum(counts_ref[d], capacity)
        w = _row(words_ref, start, capacity)
        g = _row(guids_ref, start, capacity)
        data_ref[d, :] = jnp.where(live, w, jnp.uint32(0)).reshape(capacity)
        gout_ref[d, :] = jnp.where(live, g, 0).reshape(capacity)


def _place_route_kernel(first_ref, counts_ref, words_ref, lut_ref,
                        data_ref, gout_ref, *, capacity: int, d_tile: int):
    """Guid-LUT variant: the LUT gather happens *inside* the kernel and only
    touches the ≤ capacity accepted events of each destination row."""
    slot = lax.broadcasted_iota(jnp.int32, (1, capacity), 1)
    n_lut = lut_ref.shape[0]
    for d in range(d_tile):
        start = first_ref[d]
        live = slot < jnp.minimum(counts_ref[d], capacity)
        w = jnp.where(live, _row(words_ref, start, capacity), jnp.uint32(0))
        addr = ((w >> ev.TS_BITS) & ev.ADDR_MASK).astype(jnp.int32)
        g = jnp.take(lut_ref[...], jnp.minimum(addr, n_lut - 1).reshape(capacity))
        data_ref[d, :] = w.reshape(capacity)
        gout_ref[d, :] = jnp.where(live.reshape(capacity), g, 0)


def _placement_pallas(first, counts, swords_pad, aux, n_dest: int,
                      capacity: int, *, routed: bool, interpret: bool):
    """Launch the placement kernel over ceil(n_dest / D_TILE) dest tiles."""
    d_pad = -(-n_dest // D_TILE) * D_TILE
    first = jnp.pad(first, (0, d_pad - n_dest))
    counts = jnp.pad(counts, (0, d_pad - n_dest))
    n_pad = swords_pad.shape[0]
    kernel = functools.partial(
        _place_route_kernel if routed else _place_kernel,
        capacity=capacity, d_tile=D_TILE)
    tile = lambda i: (i,)
    full = lambda i: (0,)
    data, gout = pl.pallas_call(
        kernel,
        grid=(d_pad // D_TILE,),
        in_specs=[
            pl.BlockSpec((D_TILE,), tile),
            pl.BlockSpec((D_TILE,), tile),
            pl.BlockSpec((n_pad,), full),
            pl.BlockSpec((aux.shape[0],), full),
        ],
        out_specs=(
            pl.BlockSpec((D_TILE, capacity), lambda i: (i, 0)),
            pl.BlockSpec((D_TILE, capacity), lambda i: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((d_pad, capacity), jnp.uint32),
            jax.ShapeDtypeStruct((d_pad, capacity), jnp.int32),
        ),
        interpret=interpret,
    )(first, counts, swords_pad, aux)
    return data[:n_dest], gout[:n_dest]


# ---------------------------------------------------------------------------
# XLA placement — same math, used where Pallas would only interpret.
# ---------------------------------------------------------------------------

def _placement_jnp(first, counts, swords_pad, aux, n_dest: int, capacity: int,
                   *, routed: bool):
    slot = jnp.arange(capacity)[None, :]
    live = slot < jnp.minimum(counts, capacity)[:, None]
    idx = first[:, None] + slot                      # swords_pad absorbs idx<=n+C
    data = jnp.where(live, swords_pad[idx], jnp.uint32(0))
    if routed:
        addr = ev.address(data).astype(jnp.int32)
        g = jnp.take(aux, jnp.minimum(addr, aux.shape[0] - 1))
    else:
        g = aux[idx]
    return data, jnp.where(live, g, 0)


# ---------------------------------------------------------------------------
# Fused op.
# ---------------------------------------------------------------------------

def _finish(skey, swords, aux, n_dest: int, capacity: int, residue_len: int,
            *, routed: bool, use_pallas: bool | None, interpret: bool | None,
            with_residue_meta: bool = False):
    n = swords.shape[0]
    edges = jnp.searchsorted(skey, jnp.arange(n_dest + 1, dtype=skey.dtype))
    first = edges[:-1].astype(jnp.int32)
    counts = (edges[1:] - edges[:-1]).astype(jnp.int32)
    swords_pad = jnp.concatenate(
        [swords, jnp.full((capacity,), ev.INVALID_EVENT)])
    if use_pallas is None:
        use_pallas = dispatch.use_pallas()
    if interpret is None:
        interpret = dispatch.default_interpret()
    if with_residue_meta and routed:
        raise ValueError("with_residue_meta needs per-event meta (the "
                         "explicit-guids path), not a routed guid LUT")
    smeta = aux if not routed else None          # (n,) sorted per-event meta
    if not routed:
        aux = jnp.concatenate([aux, jnp.zeros((capacity,), aux.dtype)])
    if use_pallas:
        data, gui = _placement_pallas(first, counts, swords_pad, aux, n_dest,
                                      capacity, routed=routed,
                                      interpret=interpret)
    else:
        data, gui = _placement_jnp(first, counts, swords_pad, aux, n_dest,
                                   capacity, routed=routed)
    accepted = jnp.minimum(counts, capacity)
    offered = jnp.sum(counts).astype(jnp.int32)
    overflow = (offered - jnp.sum(accepted)).astype(jnp.int32)
    buckets = Buckets(data, gui, accepted, overflow)

    res_meta = None
    if residue_len:
        # overflow events = sorted index >= first-of-dest + capacity
        first_of = jnp.take(first, jnp.minimum(skey, n_dest - 1))
        pos = jnp.arange(n, dtype=jnp.int32) - first_of
        ovf = (skey < n_dest) & (pos >= capacity)
        ovfkey = jnp.where(ovf, 0, 1).astype(jnp.int32)
        r = min(residue_len, n)
        deferred = jnp.minimum(overflow, r)
        live_r = jnp.arange(r) < deferred
        if with_residue_meta:
            _, rwords, rmeta = lax.sort(
                (ovfkey, swords, smeta.astype(jnp.int32)),
                num_keys=1, is_stable=True)
            res_meta = jnp.where(live_r, rmeta[:r], 0)
            if residue_len > n:
                res_meta = jnp.concatenate(
                    [res_meta, jnp.zeros((residue_len - n,), jnp.int32)])
        else:
            _, rwords = lax.sort((ovfkey, swords), num_keys=1, is_stable=True)
        res = jnp.where(live_r, rwords[:r], ev.INVALID_EVENT)
        if residue_len > n:
            res = jnp.concatenate(
                [res, jnp.full((residue_len - n,), ev.INVALID_EVENT)])
        dropped = overflow - deferred
    else:
        res = jnp.zeros((0,), jnp.uint32)
        if with_residue_meta:
            res_meta = jnp.zeros((0,), jnp.int32)
        deferred = jnp.zeros((), jnp.int32)
        dropped = overflow
    return FusedWindow(buckets, res, deferred.astype(jnp.int32),
                       dropped.astype(jnp.int32), offered, res_meta)


def fused_aggregate(words, dest, guids, n_dest: int, capacity: int, *,
                    residue_len: int = 0, use_pallas: bool | None = None,
                    interpret: bool | None = None,
                    with_residue_meta: bool = False) -> FusedWindow:
    """Sort-based aggregation with explicit per-event destinations/guids.

    Drop-in (via ``.buckets``) for ``aggregator.aggregate`` semantics:
    window order within each destination, capacity clip, invalid events
    (valid bit clear or dest out of range) ignored.  ``guids`` is an
    arbitrary i32 meta value riding with each event (destination GUID —
    or the simulator's injection timestamp); ``with_residue_meta`` also
    carries it for the deferred events (``FusedWindow.residue_meta``),
    so meta survives overflow re-offer round-trips.
    """
    dest = dest.astype(jnp.int32)
    valid = ev.is_valid(words) & (dest >= 0) & (dest < n_dest)
    key = jnp.where(valid, dest, n_dest)
    skey, swords, sguids = lax.sort((key, words, guids.astype(jnp.int32)),
                                    num_keys=1, is_stable=True)
    return _finish(skey, swords, sguids, n_dest, capacity, residue_len,
                   routed=False, use_pallas=use_pallas, interpret=interpret,
                   with_residue_meta=with_residue_meta)


def fused_route_aggregate(words, dest_lut, guid_lut, n_dest: int,
                          capacity: int, *, residue_len: int = 0,
                          use_pallas: bool | None = None,
                          interpret: bool | None = None) -> FusedWindow:
    """Routing-LUT gather + capacity-bounded binning in one fused pass.

    ``dest_lut``/``guid_lut`` are ``RoutingTables.dest_of_addr`` /
    ``.guid_of_addr`` (same clamped-index semantics as ``tables.route``).
    The guid gather runs inside the placement kernel over accepted events
    only.
    """
    addr = ev.address(words).astype(jnp.int32)
    dest = jnp.take(dest_lut, jnp.minimum(addr, dest_lut.shape[0] - 1))
    valid = ev.is_valid(words) & (dest >= 0) & (dest < n_dest)
    key = jnp.where(valid, dest, n_dest).astype(jnp.int32)
    skey, swords = lax.sort((key, words), num_keys=1, is_stable=True)
    return _finish(skey, swords, guid_lut.astype(jnp.int32), n_dest, capacity,
                   residue_len, routed=True, use_pallas=use_pallas,
                   interpret=interpret)
