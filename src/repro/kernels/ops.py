"""Jitted public wrappers around the Pallas kernels.

``interpret`` is auto-selected per backend (``kernels.dispatch``): the
kernels compile on TPU; on CPU/GPU hosts the interpreter executes the
kernel body in Python for correctness validation.  Override with
``REPRO_PALLAS_INTERPRET`` (``0`` forces compiled, ``1`` forces interpret).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import events as ev
from repro.core.aggregator import Buckets
from repro.kernels.bucket_scatter import bucket_scatter_pallas
from repro.kernels.dispatch import default_interpret
from repro.kernels.lif_step import lif_step_pallas

# NOTE: default_interpret() is called lazily inside each wrapper, never at
# module scope — importing repro.kernels must not initialize the JAX
# backend (callers may still want jax.distributed.initialize() etc.), and
# a late REPRO_PALLAS_INTERPRET change should affect every path alike.


@functools.partial(jax.jit, static_argnums=(3, 4))
def bucket_scatter(words, dests, guids, n_dest: int, capacity: int) -> Buckets:
    """Legacy O(N·D·C) one-hot kernel, kept as an independent cross-check."""
    valid = ev.is_valid(words) & (dests >= 0) & (dests < n_dest)
    dests_m = jnp.where(valid, dests, -1).astype(jnp.int32)
    data, gout, raw = bucket_scatter_pallas(
        words, dests_m, guids, n_dest, capacity, interpret=default_interpret())
    accepted = jnp.minimum(raw, capacity)
    overflow = jnp.sum(raw - accepted).astype(jnp.int32)
    return Buckets(data, gout, accepted, overflow)


@functools.partial(jax.jit, static_argnums=(3, 4))
def fused_scatter(words, dests, guids, n_dest: int, capacity: int) -> Buckets:
    """Drop-in for ``core.aggregator.aggregate`` (impl='pallas'): sort-based
    slot assignment with the placement stage in the fused Pallas kernel."""
    from repro.kernels import fused_route_bucket as frb
    return frb.fused_aggregate(words, dests, guids, n_dest, capacity,
                               use_pallas=True,
                               interpret=default_interpret()).buckets


@jax.jit
def ssd_chunk(x, dt, A, B, C, s_prev):
    """One Mamba-2 SSD chunk via the Pallas kernel (f32 outputs)."""
    from repro.kernels.ssd_chunk import ssd_chunk_pallas
    return ssd_chunk_pallas(x, dt, A, B, C, s_prev,
                            interpret=default_interpret())


@functools.partial(jax.jit, static_argnums=(1,))
def lif_step(state, params, exc_in, inh_in, i_ext=0.0):
    """Fused LIF step; pads N to the tile size and unpads the result."""
    from repro.snn.lif import LIFState
    n = state.v.shape[0]
    from repro.kernels.lif_step import N_TILE
    pad = (-n) % N_TILE
    if pad:
        pz = lambda t, c=0: jnp.pad(t, (0, pad), constant_values=c)
        state = LIFState(pz(state.v), pz(state.i_exc), pz(state.i_inh),
                         pz(state.refrac, 1))
        exc_in, inh_in = pz(exc_in), pz(inh_in)
    st, spk = lif_step_pallas(state, params, exc_in, inh_in, i_ext,
                              interpret=default_interpret())
    if pad:
        st = LIFState(st.v[:n], st.i_exc[:n], st.i_inh[:n], st.refrac[:n])
        spk = spk[:n]
    return st, spk
