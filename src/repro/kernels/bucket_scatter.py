"""Pallas TPU kernel: per-destination capacity-bounded event binning.

This is the compute hot-spot of the paper's §3.1 on TPU: a window of N
packed events must be binned into (n_dest, capacity) buckets in window
order.  The FPGA does it one event/clock through a renaming pipeline; the
TPU-native formulation below processes a whole window per grid step with
vector compares + reductions (VPU work, no MXU needed), tiled so each
program owns a D_TILE slice of destinations:

  grid          = (n_dest // D_TILE,)
  events/dests  : full (N,) arrays resident in VMEM (a 4k-event window is
                  16 KiB — far under the ~16 MiB VMEM budget)
  out blocks    : (D_TILE, C) events + guids, (D_TILE, 1) counts

Per destination d in the tile:
  mask   = dests == d                      (N,)
  pos    = exclusive-cumsum(mask)          (N,)  window-order slot
  onehot = mask & (pos == c) & (pos < C)   (N, C)
  row_c  = sum_n onehot * words            -- integer select-reduce, exact
           (a float MXU matmul would corrupt 30-bit event words, so the
            reduction stays in int32 on the VPU)

The kernel is validated in interpret mode against ``ref.py`` (pure jnp) and
against ``core.aggregator`` across shape/dtype sweeps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

D_TILE = 8


def _kernel(words_ref, dests_ref, guids_ref,
            out_ref, gout_ref, counts_ref, *, capacity: int, d_tile: int):
    tile = pl.program_id(0)
    words = words_ref[...].astype(jnp.int32)      # (N,)
    dests = dests_ref[...]                        # (N,) int32
    guids = guids_ref[...]                        # (N,) int32
    n = words.shape[0]
    cap_ids = jax.lax.iota(jnp.int32, capacity)   # (C,)

    for d in range(d_tile):
        dest_id = tile * d_tile + d
        mask = dests == dest_id                   # (N,)
        mask_i = mask.astype(jnp.int32)
        pos = jnp.cumsum(mask_i) - mask_i         # exclusive slot index
        onehot = (mask[:, None]
                  & (pos[:, None] == cap_ids[None, :]))     # (N, C)
        row = jnp.sum(jnp.where(onehot, words[:, None], 0), axis=0)
        grow = jnp.sum(jnp.where(onehot, guids[:, None], 0), axis=0)
        out_ref[d, :] = row.astype(jnp.uint32)
        gout_ref[d, :] = grow
        counts_ref[d, 0] = jnp.sum(mask_i)


def bucket_scatter_pallas(words, dests, guids, n_dest: int, capacity: int,
                          interpret: bool | None = None):
    """Raw kernel launch. Returns (data (D,C) u32, guids (D,C) i32,
    raw_counts (D,) i32 — counts are pre-clip, overflow = counts - clip).

    ``interpret=None`` auto-selects: compiled on TPU, interpret elsewhere.
    """
    if interpret is None:
        from repro.kernels.dispatch import default_interpret
        interpret = default_interpret()
    n = words.shape[0]
    d_pad = -(-n_dest // D_TILE) * D_TILE
    grid = (d_pad // D_TILE,)
    out_shapes = (
        jax.ShapeDtypeStruct((d_pad, capacity), jnp.uint32),
        jax.ShapeDtypeStruct((d_pad, capacity), jnp.int32),
        jax.ShapeDtypeStruct((d_pad, 1), jnp.int32),
    )
    full = lambda i: (0,)
    fn = pl.pallas_call(
        functools.partial(_kernel, capacity=capacity, d_tile=D_TILE),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n,), full),
            pl.BlockSpec((n,), full),
            pl.BlockSpec((n,), full),
        ],
        out_specs=(
            pl.BlockSpec((D_TILE, capacity), lambda i: (i, 0)),
            pl.BlockSpec((D_TILE, capacity), lambda i: (i, 0)),
            pl.BlockSpec((D_TILE, 1), lambda i: (i, 0)),
        ),
        out_shape=out_shapes,
        interpret=interpret,
    )
    data, gout, counts = fn(words, dests.astype(jnp.int32),
                            guids.astype(jnp.int32))
    return data[:n_dest], gout[:n_dest], counts[:n_dest, 0]
