"""Pallas TPU kernel: fused LIF neuron update (one dt for a neuron tile).

The microcircuit's inner loop is elementwise over N neurons: decay +
integrate + threshold + reset + refractory countdown.  Unfused, that's 6+
HBM round-trips of (N,) tensors per step; fused it is one read + one write
per state array — the classic memory-bound fusion win, so it's the second
kernel the paper's workload justifies.

Tiling: 1D grid over neuron tiles of 1024 (8 x 128 lanes); all state blocks
live in VMEM for the step.  Validated in interpret mode against
``repro.snn.lif.step`` (the pure-jnp oracle) over shape/param sweeps.
"""
from __future__ import annotations

import functools

import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.snn.lif import LIFParams, LIFState

N_TILE = 1024


def _host_propagators(p: LIFParams):
    """Host-side (python float) propagator constants — the kernel bakes
    them in as compile-time scalars."""
    pm = math.exp(-p.dt / p.tau_m)
    ps = math.exp(-p.dt / p.tau_syn)
    tau_r = p.tau_syn * p.tau_m / (p.tau_m - p.tau_syn)
    pv = (tau_r / p.c_m) * (pm - ps)
    ref_steps = int(round(p.t_ref / p.dt))
    return pm, ps, pv, ref_steps


def _kernel(v_ref, ie_ref, ii_ref, rf_ref, exc_ref, inh_ref, ext_ref,
            v_out, ie_out, ii_out, rf_out, spk_out,
            *, pm: float, ps: float, pv: float, ref_steps: int,
            e_l: float, v_th: float, v_reset: float, tau_c: float):
    v = v_ref[...]
    ie = ie_ref[...]
    ii = ii_ref[...]
    rf = rf_ref[...]
    active = rf <= 0
    i_tot = ie + ii
    v_new = jnp.where(
        active,
        e_l + (v - e_l) * pm + pv * i_tot + tau_c * ext_ref[...],
        v)
    ie_out[...] = ie * ps + exc_ref[...]
    ii_out[...] = ii * ps + inh_ref[...]
    spk = active & (v_new >= v_th)
    v_out[...] = jnp.where(spk, v_reset, v_new)
    rf_out[...] = jnp.where(spk, ref_steps, jnp.maximum(rf - 1, 0))
    spk_out[...] = spk.astype(jnp.int32)


def lif_step_pallas(state: LIFState, p: LIFParams, exc_in, inh_in, i_ext,
                    interpret: bool = True):
    """Fused LIF step. Shapes all (N,) with N % N_TILE == 0 (pad outside).

    Returns (LIFState, spikes int32 (N,)).
    """
    pm, ps, pv, ref_steps = _host_propagators(p)
    n = state.v.shape[0]
    grid = (n // N_TILE,)
    blk = pl.BlockSpec((N_TILE,), lambda i: (i,))
    out_shapes = (
        jax.ShapeDtypeStruct((n,), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.int32),
        jax.ShapeDtypeStruct((n,), jnp.int32),
    )
    fn = pl.pallas_call(
        functools.partial(
            _kernel, pm=float(pm), ps=float(ps), pv=float(pv),
            ref_steps=int(ref_steps), e_l=p.e_l, v_th=p.v_th,
            v_reset=p.v_reset, tau_c=float(p.tau_m / p.c_m * (1.0 - pm))),
        grid=grid,
        in_specs=[blk] * 7,
        out_specs=(blk,) * 5,
        out_shape=out_shapes,
        interpret=interpret,
    )
    i_ext_arr = jnp.broadcast_to(jnp.asarray(i_ext, jnp.float32), (n,))
    v, ie, ii, rf, spk = fn(state.v, state.i_exc, state.i_inh, state.refrac,
                            exc_in, inh_in, i_ext_arr)
    return LIFState(v, ie, ii, rf), spk
