"""Pallas TPU kernel: one Mamba-2 SSD chunk step (the ssm-family hot spot).

Per (batch, head) the chunk step is three small matmuls plus elementwise
decay math on (c x c) tiles — ideal MXU shape when c = 128/256:

    scores = C · Bᵀ                     (c, N) x (N, c) -> (c, c)
    y      = (scores ⊙ decay ⊙ dt) · x  (c, c) x (c, P) -> (c, P)
    y     += (C ⊙ exp(cum)) · S_prev    (c, N) x (N, P) -> (c, P)
    S_new  = exp(seg) · S_prev + (w ⊙ B)ᵀ · x   (N, c) x (c, P) -> (N, P)

Grid: (batch x heads,); every program owns one (b, h) pair with all chunk
tiles resident in VMEM — for c=256, N=128, P=64 the working set is
(c·P + 2·c·N + c + 2·N·P) · 4 B ≈ 0.7 MiB, far under budget, and all three
matmuls hit the 128-aligned MXU path.

The inter-chunk recurrence stays a ``lax.scan`` in JAX (`models/ssm.py`);
this kernel is its body.  Oracle: ``ref.ssd_chunk_ref`` (== the pure-jnp
math in ``models/ssm.ssd_chunked``), validated over shape sweeps in
interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, sprev_ref,
            y_ref, snew_ref):
    x = x_ref[0].astype(jnp.float32)          # (c, P)
    dt = dt_ref[0].astype(jnp.float32)        # (c,)
    a = a_ref[0, 0]                           # () decay rate (negative)
    B = b_ref[0].astype(jnp.float32)          # (c, N)
    C = c_ref[0].astype(jnp.float32)          # (c, N)
    S = sprev_ref[0].astype(jnp.float32)      # (P, N)

    da = dt * a                               # (c,)
    cum = jnp.cumsum(da)                      # within-chunk log-decay
    seg = cum[-1]
    c_len = x.shape[0]

    # intra-chunk: scores (c,c) on the MXU, causal decay mask elementwise
    scores = jnp.dot(C, B.T, preferred_element_type=jnp.float32)
    diff = cum[:, None] - cum[None, :]
    causal = jax.lax.iota(jnp.int32, c_len)[:, None] >= \
        jax.lax.iota(jnp.int32, c_len)[None, :]
    decay = jnp.where(causal, jnp.exp(diff), 0.0)
    y = jnp.dot(scores * decay * dt[None, :], x,
                preferred_element_type=jnp.float32)

    # inter-chunk: contribution of the carried state
    y += jnp.dot(C * jnp.exp(cum)[:, None], S.T,
                 preferred_element_type=jnp.float32)

    # state update
    w = jnp.exp(seg - cum) * dt               # (c,)
    s_loc = jnp.dot(x.T, B * w[:, None],
                    preferred_element_type=jnp.float32)   # (P, N)
    snew_ref[0] = S * jnp.exp(seg) + s_loc
    y_ref[0] = y


def ssd_chunk_pallas(x, dt, A, B, C, s_prev, interpret: bool = True):
    """One chunk for all (batch, head) pairs.

    x: (BH, c, P); dt: (BH, c); A: (BH,) negative rates;
    B, C: (BH, c, N); s_prev: (BH, P, N).
    Returns (y (BH, c, P) f32, s_new (BH, P, N) f32).
    """
    BH, c, P = x.shape
    N = B.shape[2]
    grid = (BH,)
    blk = lambda *shape: pl.BlockSpec((1,) + shape, lambda i: (i,) + (0,) * len(shape))
    out_shapes = (
        jax.ShapeDtypeStruct((BH, c, P), jnp.float32),
        jax.ShapeDtypeStruct((BH, P, N), jnp.float32),
    )
    fn = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            blk(c, P),
            blk(c),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            blk(c, N),
            blk(c, N),
            blk(P, N),
        ],
        out_specs=(blk(c, P), blk(P, N)),
        out_shape=out_shapes,
        interpret=interpret,
    )
    return fn(x, dt, A.reshape(BH, 1), B, C, s_prev)
