"""Data: synthetic LM pipeline + ring-buffer prefetch (paper §2.1)."""
from repro.data import pipeline  # noqa: F401
