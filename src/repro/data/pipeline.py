"""Data pipeline: deterministic synthetic LM batches behind a ring-buffer
prefetcher with credit-based flow control — the paper's §2.1 host<->device
discipline applied to input feeding.

The producer (host "FPGA" role) fills a bounded ring of prepared batches;
the consumer (training loop) drains it and returns credits.  Because batch
generation is a pure function of ``(seed, step)``, the pipeline cursor in a
checkpoint is just the step counter — exact restart, no data replay log.
"""
from __future__ import annotations

import dataclasses
import threading
import queue as _q

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    ring_slots: int = 4          # prefetch depth (credits)


def synthetic_batch(cfg: DataConfig, step: int):
    """Deterministic (seed, step) -> batch. Zipf-ish unigram over the vocab
    so MoE routing / vocab gathers see a realistic skew, plus shifted
    next-token labels."""
    rng = np.random.default_rng(np.uint64(cfg.seed) + np.uint64(step) * 9973)
    # zipf-like: sample ranks then map through a permutation of the vocab
    z = rng.zipf(1.3, size=(cfg.global_batch, cfg.seq_len + 1))
    tokens = (z % (cfg.vocab - 2)).astype(np.int32) + 1
    return {
        "tokens": jnp.asarray(tokens[:, :-1]),
        "labels": jnp.asarray(tokens[:, 1:].astype(np.int32)),
    }


class RingPrefetcher:
    """Bounded prefetch ring with explicit credit accounting.

    Credits mirror ``repro.core.flow_control``: the producer thread may
    only produce while it holds credits (= free slots); the consumer
    returns a credit per batch taken.  ``stats()`` exposes stall counts so
    the bench can show the throughput/slots trade-off from the paper.
    """

    def __init__(self, cfg: DataConfig, start_step: int = 0,
                 make=synthetic_batch):
        self.cfg = cfg
        self.step = start_step
        self.make = make
        self.ring: _q.Queue = _q.Queue(maxsize=cfg.ring_slots)
        self.produced = 0
        self.consumed = 0
        self.producer_stalls = 0
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self.step
        while not self._stop.is_set():
            batch = self.make(self.cfg, step)
            while not self._stop.is_set():
                try:
                    self.ring.put((step, batch), timeout=0.05)
                    break
                except _q.Full:
                    self.producer_stalls += 1
            self.produced += 1
            step += 1

    def next(self):
        step, batch = self.ring.get()
        self.consumed += 1
        return step, batch

    def stats(self):
        return {"produced": self.produced, "consumed": self.consumed,
                "producer_stalls": self.producer_stalls,
                "in_flight": self.ring.qsize()}

    def close(self):
        self._stop.set()
        try:
            while True:
                self.ring.get_nowait()
        except _q.Empty:
            pass
        self._thread.join(timeout=1.0)


def shard_batch(batch, mesh, batch_axes=("data",)):
    """Place a host batch onto the mesh (batch dim over the data axes)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = NamedSharding(mesh, P(batch_axes))

    def put(t):
        spec = P(batch_axes, *([None] * (t.ndim - 1)))
        return jax.device_put(t, NamedSharding(mesh, spec))

    return jax.tree_util.tree_map(put, batch)
