"""Global-collective transport: ONE packed ``all_to_all`` per flush window.

This is the original hot path of ``repro.core.exchange``, extracted behind
the :class:`~repro.transport.base.Transport` API: the per-destination
payload rows and their counts are packed into a single
``(n_shards, W + 1)`` u32 buffer so the latency-bound ICI hop is paid once
per window — the same way the paper amortizes the Extoll packet header
across a full bucket.  The lowered HLO contains exactly one all-to-all per
window (asserted in tests).

No per-link model: the fabric is treated as a full crossbar, every bucket
is always admitted (``sent_mask`` all True) and ``LinkStats`` carries only
the off-shard wire-byte cost — both the legacy Extoll packet estimate
(``forwarded_bytes``) and the exact frame-level accounting of the
configured :class:`~repro.wire.framing.WireFormat` (``bytes_on_wire``);
every off-shard row crosses exactly one link (``route_hops``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import aggregator
from repro.transport import base
from repro.transport.base import pack_payload, unpack_payload
from repro.wire import framing as wire_framing


class AllToAllTransport(base.Transport):
    """One global packed collective per window; no link-level state."""

    name = "alltoall"

    def exchange(self, state: base.LinkState, payload: jax.Array,
                 counts: jax.Array, *, axis_name: str,
                 enforce_credits: bool = True) -> base.TransportOut:
        n = self.n_shards
        w = payload.shape[1]
        packed = pack_payload(payload, counts)
        recv = jax.lax.all_to_all(packed, axis_name, 0, 0, tiled=True)
        recv_payload, recv_counts = unpack_payload(recv.reshape(n, w + 1))

        my = jax.lax.axis_index(axis_name)
        off = jnp.where(jnp.arange(n) == my, 0, counts)
        offered = jnp.sum(counts).astype(jnp.int32)
        stats = base.zero_link_stats()._replace(
            offered_events=offered,
            sent_events=offered,
            delivered_events=jnp.sum(recv_counts).astype(jnp.int32),
            forwarded_bytes=aggregator.window_cost(off).bytes,
            bytes_on_wire=jnp.sum(
                wire_framing.frame_bytes(self.wire_fmt, off)).astype(jnp.int32),
        )
        return base.TransportOut(
            state=state,
            recv_payload=recv_payload,
            recv_counts=recv_counts,
            sent_mask=jnp.ones((n,), bool),
            stats=stats,
            sent_now=jnp.ones((n,), bool),
            queue_us=jnp.zeros((n, n), jnp.float32),
            unparked_now=jnp.zeros((n,), jnp.int32),
            park_wait_us=jnp.zeros((n, n), jnp.float32),
        )
