"""Transport API — how a flush window's buckets reach their owners.

A :class:`Transport` moves one flush window of per-destination bucket rows
between the shards of a 1-D ``shard_map`` axis.  The caller hands over an
opaque ``payload`` row per destination shard (packed u32 — events, or
events|guids; the transport never looks inside) plus the per-row event
``counts``, and receives the rows every other shard addressed to it, in
source order — the same contract as ``jax.lax.all_to_all(..., tiled=True)``
row semantics, which is exactly what the ``alltoall`` backend is.

Backends:

* ``alltoall`` (``repro.transport.alltoall``) — the packed single-collective
  path extracted from ``repro.core.exchange``: one global ``all_to_all``
  per window, no per-link model.
* ``torus2d`` / ``torus3d`` (``repro.transport.torus``) — torus-faithful:
  shards are mapped onto a 2-D (x, y) or 3-D (x, y, z) device torus and
  every window travels via dimension-ordered neighbor ``ppermute`` hops
  (X rings, then Y, then Z — the Z rings are the wafer axis) with
  store-and-forward buffers and hop-by-hop credit-based link flow
  control.  A row refused at its SOURCE egress link is *deferred* —
  ``sent_mask`` tells the caller which rows must be re-offered next
  window through the overflow-residue machinery; a row refused at a
  transit link *parks* in the fabric's bounded in-fabric buffers
  (:class:`FabricState`) and resumes from its current hop in a later
  window, exactly like a congested Extoll switch holding cells instead
  of ejecting them to the source NIC.

All backends are pure functions of ``(state, payload, counts)`` so they
can live inside a jitted ``lax.scan`` carry; ``LinkState`` is the carried
per-link flow-control state (empty for ``alltoall``) and ``LinkStats`` the
per-window observability record ridden alongside ``WindowStats``.

Credit / notification-delay semantics (§2.1, shared with
``repro.core.flow_control`` — the authoritative statement of the
discipline): each directed egress link of each torus node holds
``link_credits`` credits; admitting a bucket row spends the row's event
count on every link of its dimension-ordered route as it crosses it,
and a spent credit re-arms only ``notify_latency`` windows later, when
the consumer-side notification lands — unless the row parks in the
downstream buffer, in which case the arrival link's credit is HELD
(``FabricState.parked_by_link``) until the row departs.  Credits never
exceed their initial limit and ``credits + pending + parked_by_link``
is conserved by every window, so back-pressure — not data loss — is the
only possible response to sustained overload.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.flow_control import CreditBank
from repro.wire import framing as wire_framing
from repro.wire.profiles import get_profile


class FabricState(NamedTuple):
    """Carried fabric state: credit bank + in-fabric transit buffers.

    The Extoll fabric buffers cells *at each hop*: a congested egress
    link delays traffic inside the switch, it does not eject it back to
    the source NIC.  ``FabricState`` models that with a bounded,
    static-shape occupancy table keyed by (source, destination) bucket
    row — at most ONE parked row per pair, the per-flow in-order
    constraint of a real link FIFO:

    * ``parked_count[s, d]`` — events of the (s, d) row currently parked
      mid-route (0 = no row in fabric for that pair); global, replicated
      on every shard like the credit bank.
    * ``parked_hop[s, d]`` — the route hop the row is blocked at: it has
      traversed hops ``0..h-1`` and waits for credits on hop ``h`` (so
      ``h >= 1`` whenever ``parked_count > 0`` — a row refused at hop 0
      never entered the fabric and is *deferred*, not parked).
    * ``parked_by_link[l]`` — events holding link ``l``'s credits: rows
      whose last traversed link is ``l`` occupy its downstream
      store-and-forward buffer, so the credit spent on ``l`` is neither
      available nor in the notification delay line until the row departs.
      Per-link boundedness falls out of the credit identity::

          credits + pending.sum(-1) + parked_by_link == limit   (per link)

    * ``parked_payload[d]`` — THIS shard's parked rows' wire words (the
      only per-shard field: a shard holds payload custody of its own
      rows; the descriptor tables above are replicated global state so
      admission stays a deterministic replay on every shard).

    ``alltoall`` and unthrottled torus runs carry zero-size tables; the
    pytree *structure* stays uniform across backends.

    The multi-tenant transport (``repro.transport.torus.
    TenantTorusTransport``) reuses the same structure with a leading
    tenant axis on the row tables — ``parked_count[t, s, d]`` — a bank of
    ``(T+1) * K`` partition slots (``repro.core.flow_control.
    CreditPartition``), and one extra table: ``parked_hold_shared[t, s,
    d]`` records how many of the row's held arrival-link units were drawn
    from the shared best-effort pool rather than tenant ``t``'s reserved
    slice, so releasing the hold refunds the right partition slot.
    Single-tenant fabrics keep the table all-zero (everything is "the one
    slice").
    """

    bank: CreditBank
    parked_count: jax.Array     # (n, n) i32 events parked per (src, dst)
    parked_hop: jax.Array       # (n, n) i32 next hop to traverse (>= 1)
    parked_age: jax.Array       # (n, n) i32 windows spent parked so far
                                #   (1 on entry; drives the park-dwell
                                #   latency charge at delivery)
    parked_by_link: jax.Array   # (K,) i32 events holding each link's credits
                                #   (one slot per PARTITION slot when
                                #   multi-tenant: ``(T+1)*K``)
    parked_payload: jax.Array   # (n, W) u32 my rows' parked wire words
    parked_hold_shared: jax.Array  # i32, ``parked_count``-shaped: units of
                                #   the held credit drawn from the shared
                                #   pool (multi-tenant only; else zeros)
    link_down: jax.Array | None = None  # (K,) bool: this WINDOW's dead
                                #   directed links (``repro.fabric.faults``).
                                #   Not part of the carried state proper:
                                #   the caller stamps it right before
                                #   ``exchange`` and the transport resets
                                #   it to None on the state it returns, so
                                #   a scan carry keeps a stable pytree
                                #   structure whether or not faults are
                                #   injected.  A dead link admits nothing
                                #   (zero effective credit), parked rows
                                #   blocked on/behind it are evicted back
                                #   to re-route, and each ring phase walks
                                #   the long way around it.


# Carried per-link flow-control state.  ``alltoall`` uses a zero-link bank
# and zero-size transit tables so the pytree structure is uniform across
# backends.
LinkState = FabricState


def init_fabric_state(bank: CreditBank, n_rows: int = 0,
                      payload_width: int = 0) -> FabricState:
    n_links = bank.credits.shape[0]
    return FabricState(
        bank=bank,
        parked_count=jnp.zeros((n_rows, n_rows), jnp.int32),
        parked_hop=jnp.zeros((n_rows, n_rows), jnp.int32),
        parked_age=jnp.zeros((n_rows, n_rows), jnp.int32),
        parked_by_link=jnp.zeros((n_links,), jnp.int32),
        parked_payload=jnp.zeros((n_rows, payload_width), jnp.uint32),
        parked_hold_shared=jnp.zeros((n_rows, n_rows), jnp.int32),
    )


class LinkStats(NamedTuple):
    """Per-window link-level observability (per shard; scalars are () i32).

    The conservation identities, per shard and window::

        offered_events == sent_events + deferred_events + parked_events
        deferred_events == stalled_by_hop.sum()

    and globally (summed over the axis)
    ``sum(sent) + sum(unparked) == sum(delivered)`` — every event that
    completes its route arrives the same window; deferred events are
    re-offered by the caller, parked events sit in the fabric's bounded
    transit buffers (``FabricState``) and resume from their current hop
    in a later window (``unparked_events`` counts the window they finally
    deliver).  Nothing is ever silently dropped: offered events are
    delivered, deferred, or parked.  The array fields are the hop-by-hop
    breakdowns: which hop refused each *deferred* row (always hop 0 under
    the transit-buffer model — a row short of credits on a transit link
    parks there instead of re-entering at the source), where this shard's
    *parked* rows currently wait (``parked_by_hop``), and the peak
    store-and-forward occupancy of each dimension-ordered ring phase.
    Their lengths are backend-static (``max_hops`` / ``ndim`` for the
    torus backends, 0 for ``alltoall``).
    """

    offered_events: jax.Array    # events presented to the transport
    sent_events: jax.Array       # events admitted into the fabric
    deferred_events: jax.Array   # events credit-stalled (rows in sent_mask)
    delivered_events: jax.Array  # events received by this shard
    credit_stalls: jax.Array     # bucket rows deferred for lack of credits
    hops: jax.Array              # neighbor hops executed this window
    forwarded_bytes: jax.Array   # wire bytes shipped over links (all hops),
                                 #   legacy Extoll packet model (events.py)
    bytes_on_wire: jax.Array     # exact frame-level bytes per the backend's
                                 #   WireFormat profile (header+CRC+cell
                                 #   padding+min-frame+gap, every hop pays;
                                 #   see repro.wire.framing)
    max_in_flight: jax.Array     # peak store-and-forward buffer occupancy
    stalled_by_hop: jax.Array    # (max_hops,) deferred events by the route
                                 #   hop that refused them
    max_in_flight_by_phase: jax.Array  # (ndim,) peak occupancy per ring
                                 #   phase (X, Y, Z)
    parked_events: jax.Array     # events of my rows NEWLY parked mid-route
                                 #   this window (custody moved into the
                                 #   fabric's transit buffers)
    unparked_events: jax.Array   # events of my parked rows that resumed
                                 #   and completed delivery this window
    in_fabric_events: jax.Array  # events of my rows parked at window END
                                 #   (the fabric occupancy I account for)
    parked_by_hop: jax.Array     # (max_hops,) my parked events by the
                                 #   route hop they currently wait at
                                 #   (window-end occupancy; index >= 1)
    queue_dwell_us: jax.Array    # () f32 total queueing dwell charged to
                                 #   my rows delivered this window (the
                                 #   congestion term of repro.wire.latency)
    rerouted: jax.Array          # events of my rows delivered via a
                                 #   fault detour this window (some ring
                                 #   walked the long way around a dead
                                 #   link); 0 on a healthy fabric
    stalled_by_link: jax.Array | None = None  # (K,) deferred events
                                 #   attributed to the physical egress
                                 #   link that refused them (global:
                                 #   replicated admission replay, same on
                                 #   every shard; sums to the GLOBAL
                                 #   deferred total).  Only populated when
                                 #   the transport is built with
                                 #   ``stall_attribution=True`` — the
                                 #   flight recorder's per-link congestion
                                 #   lane.  None keeps uninstrumented
                                 #   builds' stats pytree (and lowered
                                 #   HLO) bit-identical to before.


def zero_link_stats(max_hops: int = 0, ndim: int = 0) -> LinkStats:
    z = jnp.zeros((), jnp.int32)
    zh = jnp.zeros((max_hops,), jnp.int32)
    return LinkStats(z, z, z, z, z, z, z, z, z,
                     zh,
                     jnp.zeros((ndim,), jnp.int32),
                     z, z, z, zh, jnp.zeros((), jnp.float32), z)


def pack_payload(payload: jax.Array, counts: jax.Array) -> jax.Array:
    """Append the bitcast count column: (..., W) + (...,) -> (..., W+1) u32.

    Bitcast (not convert) keeps the i32 counts exact on the u32 wire.
    """
    cn = jax.lax.bitcast_convert_type(counts.astype(jnp.int32),
                                      jnp.uint32)[..., None]
    return jnp.concatenate([payload, cn], axis=-1)


def unpack_payload(buf: jax.Array):
    """Inverse of :func:`pack_payload` -> (payload, counts)."""
    counts = jax.lax.bitcast_convert_type(buf[..., -1], jnp.int32)
    return buf[..., :-1], counts


class TransportOut(NamedTuple):
    """Result of shipping one window through a transport backend.

    ``sent_mask`` is the custody bit: True rows have LEFT the sender —
    delivered this window or parked in the fabric's transit buffers —
    and must not be re-offered; False rows stay with the caller (deferred)
    and re-enter next window's aggregation.  ``sent_now`` narrows that to
    rows actually delivered this window (the latency digest weights).
    """

    state: LinkState           # advanced flow-control state
    recv_payload: jax.Array    # (n_shards, W) u32 — row s came from shard s
    recv_counts: jax.Array     # (n_shards,) i32 events per received row
    sent_mask: jax.Array       # (n_shards,) bool — False rows were deferred
    stats: LinkStats
    sent_now: jax.Array        # (n_shards,) bool — my offered rows fully
                               #   delivered this window (excludes parked)
    queue_us: jax.Array        # (n_shards, n_shards) f32 queueing dwell of
                               #   row (s, d) behind parked traffic on its
                               #   route (replicated; 0 when uncongested)
    unparked_now: jax.Array    # (n_shards,) i32 — events of MY parked rows
                               #   delivered from the fabric this window,
                               #   by destination (0 where none resumed)
    park_wait_us: jax.Array    # (n_shards, n_shards) f32 park-dwell charge
                               #   of rows delivered after parking: per
                               #   window parked, the serialization time of
                               #   one link credit budget draining ahead
                               #   (callers with real window timestamps —
                               #   the simulator's meta lane — use those
                               #   instead; one-shot exchanges use this)
    links_used: jax.Array | None = None  # (n_shards, n_shards) i32 links
                               #   of the route each row was DELIVERED
                               #   over this window (detours included), 0
                               #   for undelivered rows.  Only populated
                               #   under fault injection; healthy runs
                               #   leave it None and the latency model
                               #   keeps charging the static route_hops()
                               #   — so detour hops are charged honestly
                               #   without touching the healthy hot path.


class Transport:
    """Base class: a window-granular bucket mover over a shard_map axis.

    Subclasses implement :meth:`exchange`; ``init_state`` returns the
    flow-control pytree threaded through successive windows.
    """

    name: str = "base"

    def __init__(self, n_shards: int, *,
                 wire_format: str | wire_framing.WireFormat = "extoll"):
        self.n_shards = n_shards
        self.wire_fmt = get_profile(wire_format)

    def init_state(self, payload_width: int = 0) -> LinkState:
        """Fresh carried fabric state.

        ``payload_width`` is the u32 width of the payload rows the caller
        will offer (``0`` for backends/configurations that can never park
        a row mid-route — alltoall, unthrottled torus): the in-fabric
        transit buffers must be able to hold a full parked row.
        """
        from repro.core import flow_control as fc
        return init_fabric_state(fc.init_credits(0, 0, 1))

    def drain_fabric(self, state: LinkState, *, axis_name: str,
                     payload_width: int | None = None) -> TransportOut:
        """Walk the fabric's transit buffers until empty: every parked row
        resumes from its current hop and delivers, credits ignored (the
        end-of-run flush quiesces the fabric) with all held credits
        released into the notification delay line.  The base/crossbar
        backends never park, so the default is an empty delivery."""
        n = self.n_shards
        w = (state.parked_payload.shape[-1] if payload_width is None
             else payload_width)
        return TransportOut(
            state=state,
            recv_payload=jnp.zeros((n, w), jnp.uint32),
            recv_counts=jnp.zeros((n,), jnp.int32),
            sent_mask=jnp.ones((n,), bool),
            stats=zero_link_stats(),
            sent_now=jnp.ones((n,), bool),
            queue_us=jnp.zeros((n, n), jnp.float32),
            unparked_now=jnp.zeros((n,), jnp.int32),
            park_wait_us=jnp.zeros((n, n), jnp.float32),
        )

    def route_hops(self) -> jax.Array:
        """(n_shards, n_shards) i32 links traversed by a row s -> d.

        The wire-latency model charges serialization + switch latency per
        traversed link (``repro.wire.latency``).  Base/crossbar backends
        pay exactly one link for any off-shard row; the torus backends
        override this with the host model's per-pair hop counts.
        """
        n = self.n_shards
        return jnp.ones((n, n), jnp.int32) - jnp.eye(n, dtype=jnp.int32)

    def exchange(self, state: LinkState, payload: jax.Array,
                 counts: jax.Array, *, axis_name: str,
                 enforce_credits: bool = True) -> TransportOut:
        """Ship window: payload (n_shards, W) u32, counts (n_shards,) i32.

        Must be called inside ``shard_map`` over ``axis_name`` (axis size ==
        ``n_shards``).  ``enforce_credits=False`` flushes regardless of
        credit state (end-of-run drain).
        """
        raise NotImplementedError
