"""Transport API — how a flush window's buckets reach their owners.

A :class:`Transport` moves one flush window of per-destination bucket rows
between the shards of a 1-D ``shard_map`` axis.  The caller hands over an
opaque ``payload`` row per destination shard (packed u32 — events, or
events|guids; the transport never looks inside) plus the per-row event
``counts``, and receives the rows every other shard addressed to it, in
source order — the same contract as ``jax.lax.all_to_all(..., tiled=True)``
row semantics, which is exactly what the ``alltoall`` backend is.

Backends:

* ``alltoall`` (``repro.transport.alltoall``) — the packed single-collective
  path extracted from ``repro.core.exchange``: one global ``all_to_all``
  per window, no per-link model.
* ``torus2d`` / ``torus3d`` (``repro.transport.torus``) — torus-faithful:
  shards are mapped onto a 2-D (x, y) or 3-D (x, y, z) device torus and
  every window travels via dimension-ordered neighbor ``ppermute`` hops
  (X rings, then Y, then Z — the Z rings are the wafer axis) with
  store-and-forward buffers and hop-by-hop credit-based link flow
  control.  A route that crosses a congested link — first hop or any
  transit hop — *defers* the whole bucket row — ``sent_mask`` tells the
  caller which rows must be re-offered next window through the
  overflow-residue machinery.

All backends are pure functions of ``(state, payload, counts)`` so they
can live inside a jitted ``lax.scan`` carry; ``LinkState`` is the carried
per-link flow-control state (empty for ``alltoall``) and ``LinkStats`` the
per-window observability record ridden alongside ``WindowStats``.

Credit / notification-delay semantics (§2.1, shared with
``repro.core.flow_control`` — the authoritative statement of the
discipline): each directed egress link of each torus node holds
``link_credits`` credits; admitting a bucket row spends the row's event
count on EVERY link of its dimension-ordered route, and a spent credit
re-arms only ``notify_latency`` windows later, when the consumer-side
notification lands.  Credits never exceed their initial limit and
``credits + pending`` is conserved by every window, so back-pressure —
not data loss — is the only possible response to sustained overload.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.flow_control import CreditBank
from repro.wire import framing as wire_framing
from repro.wire.profiles import get_profile

# Carried per-link flow-control state.  ``alltoall`` uses a zero-link bank
# so the pytree structure is uniform across backends.
LinkState = CreditBank


class LinkStats(NamedTuple):
    """Per-window link-level observability (per shard; scalars are () i32).

    The conservation identity, per shard and window::

        offered_events == sent_events + deferred_events
        deferred_events == stalled_by_hop.sum()

    and globally (summed over the axis) ``sum(sent) == sum(delivered)`` —
    every admitted event arrives somewhere the same window; deferred events
    are re-offered by the caller, never silently buffered.  The two array
    fields are the hop-by-hop breakdowns: which hop of a stalled row's
    route refused it (hop 0 = the source's own egress link; hop h > 0 = a
    transit link h neighbor-steps downstream) and the peak
    store-and-forward occupancy of each dimension-ordered ring phase.
    Their lengths are backend-static (``max_hops`` / ``ndim`` for the
    torus backends, 0 for ``alltoall``).
    """

    offered_events: jax.Array    # events presented to the transport
    sent_events: jax.Array       # events admitted into the fabric
    deferred_events: jax.Array   # events credit-stalled (rows in sent_mask)
    delivered_events: jax.Array  # events received by this shard
    credit_stalls: jax.Array     # bucket rows deferred for lack of credits
    hops: jax.Array              # neighbor hops executed this window
    forwarded_bytes: jax.Array   # wire bytes shipped over links (all hops),
                                 #   legacy Extoll packet model (events.py)
    bytes_on_wire: jax.Array     # exact frame-level bytes per the backend's
                                 #   WireFormat profile (header+CRC+cell
                                 #   padding+min-frame+gap, every hop pays;
                                 #   see repro.wire.framing)
    max_in_flight: jax.Array     # peak store-and-forward buffer occupancy
    stalled_by_hop: jax.Array    # (max_hops,) deferred events by the route
                                 #   hop that refused them
    max_in_flight_by_phase: jax.Array  # (ndim,) peak occupancy per ring
                                 #   phase (X, Y, Z)


def zero_link_stats(max_hops: int = 0, ndim: int = 0) -> LinkStats:
    z = jnp.zeros((), jnp.int32)
    return LinkStats(z, z, z, z, z, z, z, z, z,
                     jnp.zeros((max_hops,), jnp.int32),
                     jnp.zeros((ndim,), jnp.int32))


def pack_payload(payload: jax.Array, counts: jax.Array) -> jax.Array:
    """Append the bitcast count column: (..., W) + (...,) -> (..., W+1) u32.

    Bitcast (not convert) keeps the i32 counts exact on the u32 wire.
    """
    cn = jax.lax.bitcast_convert_type(counts.astype(jnp.int32),
                                      jnp.uint32)[..., None]
    return jnp.concatenate([payload, cn], axis=-1)


def unpack_payload(buf: jax.Array):
    """Inverse of :func:`pack_payload` -> (payload, counts)."""
    counts = jax.lax.bitcast_convert_type(buf[..., -1], jnp.int32)
    return buf[..., :-1], counts


class TransportOut(NamedTuple):
    """Result of shipping one window through a transport backend."""

    state: LinkState           # advanced flow-control state
    recv_payload: jax.Array    # (n_shards, W) u32 — row s came from shard s
    recv_counts: jax.Array     # (n_shards,) i32 events per received row
    sent_mask: jax.Array       # (n_shards,) bool — False rows were deferred
    stats: LinkStats


class Transport:
    """Base class: a window-granular bucket mover over a shard_map axis.

    Subclasses implement :meth:`exchange`; ``init_state`` returns the
    flow-control pytree threaded through successive windows.
    """

    name: str = "base"

    def __init__(self, n_shards: int, *,
                 wire_format: str | wire_framing.WireFormat = "extoll"):
        self.n_shards = n_shards
        self.wire_fmt = get_profile(wire_format)

    def init_state(self) -> LinkState:
        from repro.core import flow_control as fc
        return fc.init_credits(0, 0, 1)

    def route_hops(self) -> jax.Array:
        """(n_shards, n_shards) i32 links traversed by a row s -> d.

        The wire-latency model charges serialization + switch latency per
        traversed link (``repro.wire.latency``).  Base/crossbar backends
        pay exactly one link for any off-shard row; the torus backends
        override this with the host model's per-pair hop counts.
        """
        n = self.n_shards
        return jnp.ones((n, n), jnp.int32) - jnp.eye(n, dtype=jnp.int32)

    def exchange(self, state: LinkState, payload: jax.Array,
                 counts: jax.Array, *, axis_name: str,
                 enforce_credits: bool = True) -> TransportOut:
        """Ship window: payload (n_shards, W) u32, counts (n_shards,) i32.

        Must be called inside ``shard_map`` over ``axis_name`` (axis size ==
        ``n_shards``).  ``enforce_credits=False`` flushes regardless of
        credit state (end-of-run drain).
        """
        raise NotImplementedError
