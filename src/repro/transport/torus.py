"""Torus-faithful transport: dimension-ordered neighbor hops with
hop-by-hop credit flow control (paper §1 + §2.1, on the jitted hot path).

The Extoll fabric is a 3-D torus with dimension-ordered routing — a packet
walks its X ring to the destination column, then the Y ring, then the
Z ring (the wafer axis), taking the shortest signed direction on each ring
(the same walk ``repro.core.torus.Torus.route`` enumerates on the host).
This module reproduces that on a device mesh: the ``n_shards`` shards of
the 1-D shard_map axis are laid onto an (n0, .., n_{d-1}) logical torus
(``shard s -> (c0 = s % n0, c1 = (s // n0) % n1, ...)``, matching
``Torus.coords`` with (x, y, z) = (c0, c1, c2)) and each flush window
travels exclusively via ``jax.lax.ppermute`` *neighbor* hops — the lowered
HLO contains only collective-permutes, never an all-to-all or all-gather.

Per ring phase the algorithm is a bidirectional store-and-forward rotate:
every node seeds two in-transit buffers (one per ring direction) indexed by
absolute target coordinate, each hop ships the whole buffer one neighbor
over, the arriving node absorbs the bundle addressed to it and forwards the
rest.  After ``floor(n/2)`` forward and ``floor((n-1)/2)`` backward hops
every bundle has been delivered via its shortest path, so hop counts equal
``Torus.hops`` and per-window wire bytes decompose into per-link terms —
the quantities ``core.torus.link_loads`` models on the host become
measurable (``LinkStats``) in the jitted path.

Flow control is the credit discipline of ``repro.core.flow_control``,
**hop by hop**: the carried :class:`~repro.core.flow_control.CreditBank`
holds per-link state for every egress link of every node (a vectorized
``n_shards * 2 * ndim`` bank — links ordered (x+, x-, y+, y-, z+, z-) per
node, the same direction columns as ``core.torus.link_loads``).  Admitting
a bucket row spends its event count on EVERY link of its dimension-ordered
route — first hop and all transit hops — and spent credits only return
``notify_latency`` windows later (the notification delay line).  A row
whose route crosses a link without enough credits — even a mid-route link
on some other node — is *stalled upstream*: it stays in the sender's
store-and-forward buffer and is reported through ``sent_mask`` so the
caller re-offers it via the overflow-residue machinery instead of
buffering unbounded data in the fabric.  ``LinkStats.stalled_by_hop``
records WHICH hop of the route refused each stalled row, and
``max_in_flight_by_phase`` the peak store-and-forward occupancy per ring
phase, so mid-route congestion is observable rather than averaged away.

Admission is computed identically on every shard (each shard carries the
same global bank): the per-shard offered counts are first replicated with
a dimension-wise ring all-gather built from the SAME neighbor ``ppermute``
rotations (nx-1 + ny-1 + nz-1 extra hops of a tiny (n, n) i32 matrix —
the Extoll notification traffic riding the data links), then every node
deterministically replays the same canonical-order admission, so the
distributed credit state never diverges.  When ``link_credits == 0`` the
fabric is unthrottled and the all-gather is compiled out entirely.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import aggregator
from repro.core import flow_control as fc
from repro.core.torus import Torus
from repro.transport import base
from repro.wire import framing as wire_framing

def default_shape(n_shards: int) -> tuple[int, int]:
    """Most-square (nx, ny) factorization with nx <= ny (8 -> (2, 4),
    matching the paper's 2x4 concentrator face per wafer)."""
    nx = max(int(math.isqrt(n_shards)), 1)
    while n_shards % nx:
        nx -= 1
    return nx, n_shards // nx


def default_shape3d(n_shards: int) -> tuple[int, int, int]:
    """Most-cubic (nx, ny, nz) factorization with nx <= ny <= nz
    (8 -> (2, 2, 2), 16 -> (2, 2, 4)).  Wafer-stacked setups that want the
    paper's (2, 4, n_wafers) arrangement pass nx/ny/nz explicitly."""
    best = (1, 1, n_shards)
    for nx in range(1, int(round(n_shards ** (1 / 3))) + 1):
        if n_shards % nx:
            continue
        ny, nz = default_shape(n_shards // nx)
        if ny >= nx:
            best = (nx, ny, nz)
    return best


class TorusTransport(base.Transport):
    """Dimension-ordered torus exchange with hop-by-hop per-link credits.

    ``prod(dims)`` must equal ``n_shards``.  ``link_credits=0`` disables
    throttling (links are provisioned far beyond any window's traffic);
    a positive value is the per-window event budget of EACH directed
    egress link in the fabric — injection *and* transit — replenished
    ``notify_latency`` windows after being spent.  Credits never exceed
    their initial limit, so ``link_credits`` must stay at or above the
    largest possible bucket row — a bigger row could never be admitted
    and would head-of-line-block its route forever.  Callers that know
    their row bound pass it as ``max_row_events`` (the bucket capacity;
    ``make_exchange`` and the simulator do) and construction fails fast
    on a livelock-able configuration.

    Admission discipline (canonical order, replayed identically on every
    node): rows are considered source-major, destination-minor, with the
    source order ROTATED by the bank's progress epoch (round-robin
    arbitration: the top-priority source advances one step on every
    window that spent credits, so two sources contending for the same
    saturated link alternate over progress rounds instead of the
    lower-index one winning forever — bounded starvation, worst-case
    ``n_shards`` progress rounds to reach top priority).  The epoch
    advances on progress rather than wall-clock windows so the rotation
    cannot phase-lock with the ``notify_latency`` refund cycle.  A row is
    admitted iff its source egress FIFO is not already blocked this window
    AND every link on its dimension-ordered route has ``count`` credits
    remaining.  A refused row blocks every later row on the same source
    egress link (a hardware link FIFO cannot reorder its queue), even if a
    smaller row would still fit — the same head-of-line semantics the
    first-hop-only model had, extended along the whole route.

    Memory note: the admission tables hold only the *active-route
    footprint* — the hop-ordered link sequence ``_link_seq`` of every
    (src, dst) pair, (n², max_hops) i32 with ``max_hops = sum(d // 2)``
    (~n^(1/ndim)) — NOT the dense (n², n·2·ndim) 0/1 route-incidence
    tensor an earlier revision materialized (cubic in shard count; the
    per-link need is recovered in-scan by gathering ``remaining`` at the
    route's links).  n=64 in 3-D is now 98 KiB instead of 3 MiB; a test
    pins the bound.  Thousand-node host-side studies still belong to
    ``core.torus.link_loads``.
    """

    name = "torus"

    def __init__(self, n_shards: int, dims: tuple[int, ...], *,
                 link_credits: int = 0, notify_latency: int = 2,
                 max_row_events: int = 0,
                 wire_format: str | wire_framing.WireFormat = "extoll"):
        super().__init__(n_shards, wire_format=wire_format)
        if 0 < link_credits < max_row_events:
            raise ValueError(
                f"link_credits ({link_credits}) must be >= the largest "
                f"bucket row ({max_row_events} events): credits never "
                f"exceed their initial limit, so an oversized row would "
                f"head-of-line-block its route forever")
        dims = tuple(int(d) for d in dims)
        if math.prod(dims) != n_shards:
            raise ValueError(f"mesh {dims} != n_shards {n_shards}")
        if not 1 <= len(dims) <= 3:
            raise ValueError(f"1..3 torus dimensions supported, got {dims}")
        self.dims = dims
        self.ndim = len(dims)
        self.n_links = 2 * self.ndim                  # per node
        self.link_credits = int(link_credits)
        self.notify_latency = int(notify_latency)
        # single source of truth for shard <-> coordinate mapping: the
        # host-side model (unused axes padded to 1) — the ppermute rings,
        # the credit routes and core.torus analysis can never disagree
        pad = dims + (1,) * (3 - self.ndim)
        self._host = Torus(nx=pad[0], ny=pad[1], nz=pad[2])
        self._perm = [
            (self._ring_perm(a, +1), self._ring_perm(a, -1))
            for a in range(self.ndim)
        ]
        self._build_routes()

    # -- static topology ---------------------------------------------------
    def _ring_perm(self, a: int, step: int):
        """(src, dst) pairs moving every shard one step along ring ``a``."""
        ids = np.arange(self.n_shards)
        c = list(self._host.coords(ids))
        c[a] = (c[a] + step) % self.dims[a]
        dst = self._host.node_id(*c)
        return list(zip(ids.tolist(), dst.astype(int).tolist()))

    def _build_routes(self):
        """Host-side precompute of the per-pair dimension-ordered routes.

        ``_link_seq[s*n+d]`` is the route s -> d as hop-ordered egress
        link ids (link id = node * n_links + direction, -1 pad; row 0 is
        all -1 for local rows) — the active-route footprint, (n²,
        max_hops) i32, which is ALL the admission scan needs: per-link
        credit needs are gathered/scattered at these ids instead of
        multiplying a dense (n², n·2·ndim) incidence tensor.  Derived
        from ``core.torus.Torus.route`` so the data path, the credit path
        and the host model can never disagree on a route.
        ``_hops_matrix`` is the host model's per-pair hop count, served
        to the wire-latency model via :meth:`route_hops`.
        """
        n, nl = self.n_shards, self.n_links
        host = self._host
        self.max_hops = max(sum(d // 2 for d in self.dims), 1)
        seq = np.full((n * n, self.max_hops), -1, np.int32)
        for s in range(n):
            for d in range(n):
                if s == d:
                    continue
                links = host.route_links(s, d)
                for h, (u, dir_) in enumerate(links):
                    seq[s * n + d, h] = u * nl + dir_
        self._link_seq = jnp.asarray(seq)
        ids = np.arange(n)
        self._hops_matrix = jnp.asarray(
            host.hops(ids[:, None], ids[None, :]).astype(np.int32))

    def route_hops(self) -> jax.Array:
        return self._hops_matrix

    # -- flow-control state ------------------------------------------------
    def init_state(self) -> base.LinkState:
        """Global bank: one entry per directed egress link of EVERY node.

        Replicated on each shard; stays consistent because admission is a
        deterministic function of the all-gathered counts (see module
        docstring)."""
        limit = self.link_credits if self.link_credits > 0 else 1 << 30
        return fc.init_credits(self.n_shards * self.n_links, limit,
                               self.notify_latency)

    # -- replicating the offered counts (neighbor permutes only) -----------
    def _allgather_counts(self, counts: jax.Array, me, axis_name: str):
        """(n,) per-shard offered counts -> (n, n) global matrix via a
        dimension-wise ring all-gather: pass-and-accumulate a token one
        neighbor over, ``size-1`` hops per ring phase — the notification
        side-channel of §2.1 riding the same links as the data."""
        n = self.n_shards
        acc = jnp.zeros((n, n), jnp.int32).at[me].set(counts)
        for a in range(self.ndim):
            token = acc
            perm_p, _ = self._perm[a]
            for _ in range(self.dims[a] - 1):
                token = lax.ppermute(token, axis_name, perm_p)
                acc = acc + token
        return acc

    # -- canonical hop-by-hop admission ------------------------------------
    def _admit_global(self, state: base.LinkState, counts_all: jax.Array):
        """Replay the canonical admission over the global counts matrix.

        Returns (admitted (n, n) bool, spent (K,) i32, stall_hop (n, n)
        i32 — index of the route hop that refused each stalled row, -1
        for admitted rows).  Pure function of (credits, epoch,
        counts_all): every shard computes the identical result, keeping
        the replicated bank consistent without any extra synchronization.
        The source-major order is rotated by ``state.epoch`` — round-robin
        arbitration over progress rounds (see class docstring).
        """
        n, K, H = self.n_shards, self.n_shards * self.n_links, self.max_hops
        flat = counts_all.reshape(-1)
        r_all = jnp.arange(n * n)
        rows = ((r_all // n + state.epoch) % n) * n + r_all % n

        def row(carry, r):
            remaining, blocked = carry
            c = flat[r]
            # active-route footprint: gather the route's links only — no
            # dense (K,) incidence row is ever materialized
            seq = self._link_seq[r]                      # (H,) hop-ordered
            valid = seq >= 0
            idx = jnp.maximum(seq, 0)
            rem_at = remaining[idx]                      # (H,)
            fl = seq[0]
            routed = (fl >= 0) & (c > 0)
            feasible = jnp.all(~valid | (rem_at >= c))
            hol = blocked[jnp.maximum(fl, 0)]
            admit = ~routed | (feasible & ~hol)
            # spend c on every link of the route (links are distinct, pads
            # contribute 0)
            spend = jnp.where(admit & routed & valid, c, 0)
            remaining = remaining.at[idx].add(-spend)
            # blocking hop: first route link short of credits (0 if only
            # the source FIFO head-of-line blocks an otherwise-fitting row)
            short = valid & (rem_at < c)
            h_short = jnp.min(jnp.where(short, jnp.arange(H), H))
            stall = jnp.where(admit, -1,
                              jnp.where(feasible, 0, h_short))
            blocked = blocked.at[jnp.maximum(fl, 0)].set(
                blocked[jnp.maximum(fl, 0)] | (routed & ~admit))
            return (remaining, blocked), (admit, stall)

        (remaining, _), (admit, stall) = lax.scan(
            row, (state.credits, jnp.zeros((K,), bool)), rows)
        spent = state.credits - remaining
        # un-rotate: scan outputs are in processing order, rows[i] -> i
        admit = jnp.zeros((n * n,), bool).at[rows].set(admit)
        stall = jnp.full((n * n,), -1, jnp.int32).at[rows].set(stall)
        return admit.reshape(n, n), spent, stall.reshape(n, n)

    # -- one bidirectional ring phase --------------------------------------
    def _ring_phase(self, bundles, axis_name, my_c, n, perm_p, perm_m,
                    acc: dict, phase: int):
        """Rotate (n, B, W1) count-packed bundles (indexed by target ring
        coordinate) to their owners; returns them indexed by *source* ring
        coordinate.  ``acc`` accumulates LinkStats terms across phases."""
        coord = jnp.arange(n)
        fwd = (coord - my_c) % n
        plus = (fwd >= 1) & (fwd <= n // 2)
        minus = fwd > n // 2
        vp = jnp.where(plus[:, None, None], bundles, jnp.uint32(0))
        vm = jnp.where(minus[:, None, None], bundles, jnp.uint32(0))
        recv = jnp.zeros_like(bundles)
        recv = recv.at[my_c].set(jnp.take(bundles, my_c, axis=0))

        def live_events(v):
            return jnp.sum(lax.bitcast_convert_type(v[:, :, -1], jnp.int32))

        def wire(v):
            cnt = lax.bitcast_convert_type(v[:, :, -1], jnp.int32)
            return aggregator.window_cost(cnt.reshape(-1)).bytes

        def owire(v):
            # exact frame-level bytes of this hop: every bundle row is one
            # frame train of the backend's WireFormat profile
            cnt = lax.bitcast_convert_type(v[:, :, -1], jnp.int32)
            return jnp.sum(wire_framing.frame_bytes(self.wire_fmt, cnt))

        for direction, v, perm, n_hops in (
            ("+", vp, perm_p, n // 2),
            ("-", vm, perm_m, (n - 1) // 2),
        ):
            for h in range(1, n_hops + 1):
                acc["bytes"] += wire(v)
                acc["owire"] += owire(v)
                v = lax.ppermute(v, axis_name, perm)
                src = (my_c - h) % n if direction == "+" else (my_c + h) % n
                recv = recv.at[src].set(jnp.take(v, my_c, axis=0))
                v = v.at[my_c].set(jnp.uint32(0))
                acc["hops"] += 1
                occ = live_events(v)
                acc["in_flight"] = jnp.maximum(acc["in_flight"], occ)
                acc["in_flight_phase"][phase] = jnp.maximum(
                    acc["in_flight_phase"][phase], occ)
        # everything within shortest distance has been absorbed
        return recv

    # -- phase reshapes ----------------------------------------------------
    # The (n, W1) buffer keeps a fixed layout: flattened index
    # c0 + n0*c1 + n0*n1*c2 where axis-a's coordinate is the DESTINATION
    # coordinate before phase a has run and the SOURCE coordinate after.
    def _phase_perm(self, a: int):
        nd = self.ndim
        lead = nd - 1 - a            # axis of dim ``a`` in the reshaped view
        perm = (lead, *(i for i in range(nd) if i != lead), nd)
        return perm, tuple(int(i) for i in np.argsort(perm))

    def _to_phase(self, buf: jax.Array, a: int) -> jax.Array:
        w1 = buf.shape[-1]
        t = buf.reshape(*reversed(self.dims), w1)
        perm, _ = self._phase_perm(a)
        return t.transpose(perm).reshape(self.dims[a], -1, w1)

    def _from_phase(self, recv: jax.Array, a: int) -> jax.Array:
        w1 = recv.shape[-1]
        perm, inv = self._phase_perm(a)
        other = [d for i, d in enumerate(reversed(self.dims))
                 if i != self.ndim - 1 - a]
        t = recv.reshape(self.dims[a], *other, w1).transpose(inv)
        return t.reshape(self.n_shards, w1)

    # -- the full window ---------------------------------------------------
    def exchange(self, state: base.LinkState, payload: jax.Array,
                 counts: jax.Array, *, axis_name: str,
                 enforce_credits: bool = True) -> base.TransportOut:
        n = self.n_shards
        me = lax.axis_index(axis_name)
        counts = counts.astype(jnp.int32)

        # 1. injection: hop-by-hop credit admission over the whole route
        #    (compiled out when unthrottled — no all-gather, no scan)
        throttled = enforce_credits and self.link_credits > 0
        if throttled:
            counts_all = self._allgather_counts(counts, me, axis_name)
            admit_all, spent, stall_all = self._admit_global(
                state, counts_all)
            admitted = admit_all[me]
            stall_hop = stall_all[me]
        else:
            admitted = jnp.ones((n,), bool)
            spent = jnp.zeros((n * self.n_links,), jnp.int32)
            stall_hop = jnp.full((n,), -1, jnp.int32)
        state = fc.credit_tick(state, spent)
        cnt_in = jnp.where(admitted, counts, 0)
        packed = base.pack_payload(
            jnp.where(admitted[:, None], payload, jnp.uint32(0)), cnt_in)

        acc = {"bytes": jnp.int32(0), "owire": jnp.int32(0), "hops": 0,
               "in_flight": jnp.int32(0),
               "in_flight_phase": [jnp.int32(0)] * self.ndim}

        # 2. dimension-ordered phases: rotate along each axis' rings
        my_c = self._coords_of(me)
        buf = packed
        for a in range(self.ndim):
            bundles = self._to_phase(buf, a)
            perm_p, perm_m = self._perm[a]
            recv = self._ring_phase(bundles, axis_name, my_c[a],
                                    self.dims[a], perm_p, perm_m, acc,
                                    phase=a)
            buf = self._from_phase(recv, a)
        recv_payload, recv_counts = base.unpack_payload(buf)

        # 3. stats: stalled rows histogrammed by their blocking hop
        stalled_by_hop = jnp.zeros((self.max_hops,), jnp.int32).at[
            jnp.clip(stall_hop, 0, self.max_hops - 1)
        ].add(jnp.where(stall_hop >= 0, counts, 0))
        offered = jnp.sum(counts).astype(jnp.int32)
        sent = jnp.sum(cnt_in).astype(jnp.int32)
        stats = base.LinkStats(
            offered_events=offered,
            sent_events=sent,
            deferred_events=offered - sent,
            delivered_events=jnp.sum(recv_counts).astype(jnp.int32),
            credit_stalls=jnp.sum(~admitted & (counts > 0)).astype(jnp.int32),
            hops=jnp.int32(acc["hops"]),
            forwarded_bytes=acc["bytes"].astype(jnp.int32),
            bytes_on_wire=acc["owire"].astype(jnp.int32),
            max_in_flight=acc["in_flight"].astype(jnp.int32),
            stalled_by_hop=stalled_by_hop,
            max_in_flight_by_phase=jnp.stack(acc["in_flight_phase"]),
        )
        return base.TransportOut(
            state=state,
            recv_payload=recv_payload,
            recv_counts=recv_counts,
            sent_mask=admitted,
            stats=stats,
        )

    def _coords_of(self, me):
        """Traced shard index -> per-dimension ring coordinates."""
        out = []
        for d in self.dims:
            out.append(me % d)
            me = me // d
        return out


class Torus2DTransport(TorusTransport):
    """(nx, ny) torus — the per-wafer concentrator face (2x4 for 8)."""

    name = "torus2d"

    def __init__(self, n_shards: int, *, nx: int = 0, ny: int = 0,
                 link_credits: int = 0, notify_latency: int = 2,
                 max_row_events: int = 0,
                 wire_format: str | wire_framing.WireFormat = "extoll"):
        if not nx and not ny:
            nx, ny = default_shape(n_shards)
        elif not ny:
            ny = n_shards // max(nx, 1)
        elif not nx:
            nx = n_shards // max(ny, 1)
        super().__init__(n_shards, (nx, ny), link_credits=link_credits,
                         notify_latency=notify_latency,
                         max_row_events=max_row_events,
                         wire_format=wire_format)
        self.nx, self.ny = nx, ny


class Torus3DTransport(TorusTransport):
    """(nx, ny, nz) torus — wafer faces stacked along the Z (wafer) axis,
    the paper's full Extoll arrangement (``core.torus.wafer_topology``)."""

    name = "torus3d"

    def __init__(self, n_shards: int, *, nx: int = 0, ny: int = 0,
                 nz: int = 0, link_credits: int = 0, notify_latency: int = 2,
                 max_row_events: int = 0,
                 wire_format: str | wire_framing.WireFormat = "extoll"):
        known = [d for d in (nx, ny, nz) if d]
        if not known:
            nx, ny, nz = default_shape3d(n_shards)
        elif len(known) == 1:
            # one axis pinned (typically nz = wafer count): most-square
            # factorization of the rest onto the remaining face
            rest = n_shards // known[0]
            if nz:
                nx, ny = default_shape(rest)
            elif ny:
                nx, nz = default_shape(rest)
            else:
                ny, nz = default_shape(rest)
        elif len(known) == 2:
            missing = n_shards // max(math.prod(known), 1)
            nx, ny, nz = (nx or missing, ny or missing, nz or missing)
        super().__init__(n_shards, (nx, ny, nz), link_credits=link_credits,
                         notify_latency=notify_latency,
                         max_row_events=max_row_events,
                         wire_format=wire_format)
        self.nx, self.ny, self.nz = nx, ny, nz
