"""Torus-faithful transport: dimension-ordered neighbor hops with
hop-by-hop credit flow control (paper §1 + §2.1, on the jitted hot path).

The Extoll fabric is a 3-D torus with dimension-ordered routing — a packet
walks its X ring to the destination column, then the Y ring, then the
Z ring (the wafer axis), taking the shortest signed direction on each ring
(the same walk ``repro.core.torus.Torus.route`` enumerates on the host).
This module reproduces that on a device mesh: the ``n_shards`` shards of
the 1-D shard_map axis are laid onto an (n0, .., n_{d-1}) logical torus
(``shard s -> (c0 = s % n0, c1 = (s // n0) % n1, ...)``, matching
``Torus.coords`` with (x, y, z) = (c0, c1, c2)) and each flush window
travels exclusively via ``jax.lax.ppermute`` *neighbor* hops — the lowered
HLO contains only collective-permutes, never an all-to-all or all-gather.

Per ring phase the algorithm is a bidirectional store-and-forward rotate:
every node seeds two in-transit buffers (one per ring direction) indexed by
absolute target coordinate, each hop ships the whole buffer one neighbor
over, the arriving node absorbs the bundle addressed to it and forwards the
rest.  After ``floor(n/2)`` forward and ``floor((n-1)/2)`` backward hops
every bundle has been delivered via its shortest path, so hop counts equal
``Torus.hops`` and per-window wire bytes decompose into per-link terms —
the quantities ``core.torus.link_loads`` models on the host become
measurable (``LinkStats``) in the jitted path.

Flow control is the credit discipline of ``repro.core.flow_control``,
**hop by hop**: the carried :class:`~repro.transport.base.FabricState`
holds a per-link credit bank for every egress link of every node (a
vectorized ``n_shards * 2 * ndim`` bank — links ordered (x+, x-, y+, y-,
z+, z-) per node, the same direction columns as ``core.torus.link_loads``)
plus bounded in-fabric **transit buffers**.  Admitting a bucket row spends
its event count on every link of its dimension-ordered route as it crosses
it, and spent credits only return ``notify_latency`` windows later (the
notification delay line).  A row that runs out of credits mid-route —
hop ``h >= 1`` — is NOT ejected back to the source: like a real Extoll
switch it **parks** in the store-and-forward buffer it already reached,
holding the arrival link's credit (``FabricState.parked_by_link``), and
the next window's admission drains parked rows *from their current hop*
ahead of every fresh offer.  Only a row refused at hop 0 — its own source
egress link — is *deferred*: reported through ``sent_mask`` so the caller
re-offers it via the overflow-residue machinery.  ``LinkStats`` separates
the two (``deferred/stalled_by_hop`` vs ``parked/unparked/parked_by_hop``)
and the conservation identities extend to
``offered == sent + deferred + parked`` per window and
``credits + pending + parked_by_link == limit`` per link, so mid-route
congestion is a measured, conserved quantity rather than averaged away.
Queueing dwell behind parked traffic feeds the wire-latency model
(``TransportOut.queue_us``, from ``repro.wire.latency.queueing_latency_us``).

Admission is computed identically on every shard (each shard carries the
same global bank): the per-shard offered counts are first replicated with
a dimension-wise ring all-gather built from the SAME neighbor ``ppermute``
rotations (nx-1 + ny-1 + nz-1 extra hops of a tiny (n, n) i32 matrix —
the Extoll notification traffic riding the data links), then every node
deterministically replays the same canonical-order admission, so the
distributed credit state never diverges.  When ``link_credits == 0`` the
fabric is unthrottled and the all-gather is compiled out entirely.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from typing import NamedTuple

from repro.core import aggregator
from repro.core import flow_control as fc
from repro.core.torus import Torus
from repro.transport import base
from repro.wire import framing as wire_framing
from repro.wire import latency as wire_latency


class AdmissionOut(NamedTuple):
    """Result of one window's deterministic admission replay (all shards
    compute the identical value from the replicated ``FabricState`` and
    the all-gathered counts matrix).  (n, n) fields are (src, dst)."""

    fresh_complete: jax.Array    # bool — fresh rows delivered this window
    fresh_park: jax.Array        # bool — fresh rows newly parked mid-route
    resumed_complete: jax.Array  # bool — parked rows that finished delivery
    resume_age: jax.Array        # i32 — windows the resumed rows had spent
                                 #   parked (0 for everything else)
    stall_hop: jax.Array         # i32 — blocking hop of DEFERRED rows, -1
    park_count: jax.Array        # i32 — post-window occupancy table
    park_hop: jax.Array          # i32 — post-window blocked-hop table
    park_age: jax.Array          # i32 — post-window ages (windows parked)
    parked_by_link: jax.Array    # (K,) i32 — post-window held units
    links_traversed: jax.Array   # i32 — links each row crossed THIS window
    spent: jax.Array             # (K,) i32 — subtracted from credits
    notify: jax.Array            # (K,) i32 — entering the delay line
    queue_events: jax.Array      # i32 — parked events queued ahead on the
                                 #   row's route at window start
    rerouted: jax.Array          # i32 — events delivered via a fault
                                 #   detour this window (0 when healthy)
    links_done: jax.Array        # i32 — route length (detours included)
                                 #   of rows DELIVERED this window, 0 else
                                 #   (the honest per-event hop charge)
    stalled_by_link: jax.Array | None = None  # (K,) i32 — deferred events
                                 #   per refusing PHYSICAL egress link
                                 #   (stall_attribution builds only; sums
                                 #   to the global deferred total)

def default_shape(n_shards: int) -> tuple[int, int]:
    """Most-square (nx, ny) factorization with nx <= ny (8 -> (2, 4),
    matching the paper's 2x4 concentrator face per wafer)."""
    nx = max(int(math.isqrt(n_shards)), 1)
    while n_shards % nx:
        nx -= 1
    return nx, n_shards // nx


def default_shape3d(n_shards: int) -> tuple[int, int, int]:
    """Most-cubic (nx, ny, nz) factorization with nx <= ny <= nz
    (8 -> (2, 2, 2), 16 -> (2, 2, 4)).  Wafer-stacked setups that want the
    paper's (2, 4, n_wafers) arrangement pass nx/ny/nz explicitly."""
    best = (1, 1, n_shards)
    for nx in range(1, int(round(n_shards ** (1 / 3))) + 1):
        if n_shards % nx:
            continue
        ny, nz = default_shape(n_shards // nx)
        if ny >= nx:
            best = (nx, ny, nz)
    return best


class TorusTransport(base.Transport):
    """Dimension-ordered torus exchange with hop-by-hop per-link credits.

    ``prod(dims)`` must equal ``n_shards``.  ``link_credits=0`` disables
    throttling (links are provisioned far beyond any window's traffic);
    a positive value is the per-window event budget of EACH directed
    egress link in the fabric — injection *and* transit — replenished
    ``notify_latency`` windows after being spent.  Credits never exceed
    their initial limit, so ``link_credits`` must stay at or above the
    largest possible bucket row — a bigger row could never be admitted
    and would head-of-line-block its route forever.  Callers that know
    their row bound pass it as ``max_row_events`` (the bucket capacity;
    ``make_exchange`` and the simulator do) and construction fails fast
    on a livelock-able configuration.

    Admission discipline (canonical order, replayed identically on every
    node): rows are considered source-major, destination-minor, with the
    source order ROTATED by the bank's progress epoch (round-robin
    arbitration: the top-priority source advances one step on every
    window that spent credits, so two sources contending for the same
    saturated link alternate over progress rounds instead of the
    lower-index one winning forever — bounded starvation, worst-case
    ``n_shards`` progress rounds to reach top priority).  The epoch
    advances on progress rather than wall-clock windows so the rotation
    cannot phase-lock with the ``notify_latency`` refund cycle.  Parked
    rows resume first (from their current hop — see ``_admit_global``);
    then a fresh row is admitted iff its (src, dst) transit slot is free,
    its source egress FIFO is not already blocked this window, and it can
    cross at least its first link — completing if every route link has
    ``count`` credits, parking at the first short transit link otherwise.
    A row refused at hop 0 blocks every later row on the same source
    egress link (a hardware link FIFO cannot reorder its queue), even if
    a smaller row would still fit — the same head-of-line semantics the
    first-hop-only model had.  Parked rows hold their arrival link's
    credits, so buffer occupancy is bounded by ``link_credits`` per link
    and sustained overload spreads back-pressure upstream hop by hop
    (tree saturation) instead of dropping or unboundedly buffering data.
    Dimension-ordered routing breaks cross-dimension cycles, but — as on
    real credit fabrics without virtual channels — held buffers on one
    ring can in principle form a cyclic wait; the end-of-run
    :meth:`drain_fabric` walk always clears the fabric regardless.

    Memory note: the admission tables hold only the *active-route
    footprint* — the hop-ordered link sequence ``_link_seq`` of every
    (src, dst) pair, (n², max_hops) i32 with ``max_hops = sum(d // 2)``
    (~n^(1/ndim)) — NOT the dense (n², n·2·ndim) 0/1 route-incidence
    tensor an earlier revision materialized (cubic in shard count; the
    per-link need is recovered in-scan by gathering ``remaining`` at the
    route's links).  n=64 in 3-D is now 98 KiB instead of 3 MiB; a test
    pins the bound.  Thousand-node host-side studies still belong to
    ``core.torus.link_loads``.
    """

    name = "torus"

    def __init__(self, n_shards: int, dims: tuple[int, ...], *,
                 link_credits: int = 0, notify_latency: int = 2,
                 max_row_events: int = 0,
                 wire_format: str | wire_framing.WireFormat = "extoll",
                 stall_attribution: bool = False):
        super().__init__(n_shards, wire_format=wire_format)
        # per-link deferred-demand attribution for the flight recorder
        # (repro.obs) — a python-level static flag: False compiles the
        # exact pre-observability program (LinkStats.stalled_by_link
        # stays None, so stats pytree and lowered HLO are unchanged)
        self.stall_attribution = bool(stall_attribution)
        if 0 < link_credits < max_row_events:
            raise ValueError(
                f"link_credits ({link_credits}) must be >= the largest "
                f"bucket row ({max_row_events} events): credits never "
                f"exceed their initial limit, so an oversized row would "
                f"head-of-line-block its route forever")
        dims = tuple(int(d) for d in dims)
        if math.prod(dims) != n_shards:
            raise ValueError(f"mesh {dims} != n_shards {n_shards}")
        if not 1 <= len(dims) <= 3:
            raise ValueError(f"1..3 torus dimensions supported, got {dims}")
        self.dims = dims
        self.ndim = len(dims)
        self.n_links = 2 * self.ndim                  # per node
        self.link_credits = int(link_credits)
        self.notify_latency = int(notify_latency)
        # single source of truth for shard <-> coordinate mapping: the
        # host-side model (unused axes padded to 1) — the ppermute rings,
        # the credit routes and core.torus analysis can never disagree
        pad = dims + (1,) * (3 - self.ndim)
        self._host = Torus(nx=pad[0], ny=pad[1], nz=pad[2])
        self._perm = [
            (self._ring_perm(a, +1), self._ring_perm(a, -1))
            for a in range(self.ndim)
        ]
        self._build_routes()

    # -- static topology ---------------------------------------------------
    def _ring_perm(self, a: int, step: int):
        """(src, dst) pairs moving every shard one step along ring ``a``."""
        ids = np.arange(self.n_shards)
        c = list(self._host.coords(ids))
        c[a] = (c[a] + step) % self.dims[a]
        dst = self._host.node_id(*c)
        return list(zip(ids.tolist(), dst.astype(int).tolist()))

    def _build_routes(self):
        """Host-side precompute of the per-pair dimension-ordered routes.

        ``_link_seq[s*n+d]`` is the route s -> d as hop-ordered egress
        link ids (link id = node * n_links + direction, -1 pad; row 0 is
        all -1 for local rows) — the active-route footprint, (n²,
        max_hops) i32, which is ALL the admission scan needs: per-link
        credit needs are gathered/scattered at these ids instead of
        multiplying a dense (n², n·2·ndim) incidence tensor.  Derived
        from ``core.torus.Torus.route`` so the data path, the credit path
        and the host model can never disagree on a route.
        ``_hops_matrix`` is the host model's per-pair hop count, served
        to the wire-latency model via :meth:`route_hops`.
        """
        n, nl = self.n_shards, self.n_links
        host = self._host
        self.max_hops = max(sum(d // 2 for d in self.dims), 1)
        seq = np.full((n * n, self.max_hops), -1, np.int32)
        for s in range(n):
            for d in range(n):
                if s == d:
                    continue
                links = host.route_links(s, d)
                for h, (u, dir_) in enumerate(links):
                    seq[s * n + d, h] = u * nl + dir_
        self._link_seq = jnp.asarray(seq)
        self._route_len = jnp.asarray((seq >= 0).sum(-1).astype(np.int32))
        ids = np.arange(n)
        self._hops_matrix = jnp.asarray(
            host.hops(ids[:, None], ids[None, :]).astype(np.int32))

        # fault detours: for every subset of axes walking their ring the
        # long way around (combo bit a set = axis a detours), the full
        # hop-ordered link sequence, plus each axis' short/long segment
        # link sets — what the per-window reroute decision (dirty short
        # arc & clean long arc -> flip that axis) gathers the dead-link
        # mask over.  A long arc is at most ``d - 1`` hops, so these
        # tables are 2^ndim * (H_alt / H) bigger than ``_link_seq`` —
        # still the active-route footprint, never a dense incidence
        # tensor (n=64 in 3-D: ~1.2 MiB).
        self.max_hops_alt = max(sum(d - 1 for d in self.dims), 1)
        n_combo = 1 << self.ndim
        alt = np.full((n_combo, n * n, self.max_hops_alt), -1, np.int32)
        seg_len = max(max(d - 1 for d in self.dims), 1)
        seg = np.full((self.ndim, 2, n * n, seg_len), -1, np.int32)
        pad3 = (False,) * (3 - self.ndim)
        for s in range(n):
            for d in range(n):
                if s == d:
                    continue
                for a in range(self.ndim):
                    for var in (0, 1):
                        links = host.axis_segment_links(s, d, a,
                                                        longway=bool(var))
                        for h, (u, dir_) in enumerate(links):
                            seg[a, var, s * n + d, h] = u * nl + dir_
                for combo in range(n_combo):
                    flips = tuple(bool(combo >> a & 1)
                                  for a in range(self.ndim)) + pad3
                    links = host.route_links_detour(s, d, flips)
                    for h, (u, dir_) in enumerate(links):
                        alt[combo, s * n + d, h] = u * nl + dir_
        self._link_seq_alt = jnp.asarray(alt)
        self._route_len_alt = jnp.asarray(
            (alt >= 0).sum(-1).astype(np.int32))
        self._seg_links = jnp.asarray(seg)

    def route_hops(self) -> jax.Array:
        return self._hops_matrix

    # -- flow-control state ------------------------------------------------
    def init_state(self, payload_width: int = 0) -> base.LinkState:
        """Global bank + empty transit buffers.

        The bank holds one entry per directed egress link of EVERY node,
        replicated on each shard; it stays consistent because admission
        is a deterministic function of the all-gathered counts (see
        module docstring).  The transit tables (``FabricState``) are
        likewise replicated — only ``parked_payload`` is per-shard (this
        shard's rows' wire words), so throttled callers must pass the u32
        ``payload_width`` of the rows they will offer."""
        limit = self.link_credits if self.link_credits > 0 else 1 << 30
        bank = fc.init_credits(self.n_shards * self.n_links, limit,
                               self.notify_latency)
        if self.link_credits <= 0:
            # unthrottled: nothing can ever park — zero-size tables
            return base.init_fabric_state(bank)
        return base.init_fabric_state(bank, self.n_shards, payload_width)

    # -- replicating the offered counts (neighbor permutes only) -----------
    def _allgather_counts(self, counts: jax.Array, me, axis_name: str):
        """(n,) per-shard offered counts -> (n, n) global matrix via a
        dimension-wise ring all-gather: pass-and-accumulate a token one
        neighbor over, ``size-1`` hops per ring phase — the notification
        side-channel of §2.1 riding the same links as the data."""
        n = self.n_shards
        acc = jnp.zeros((n, n), jnp.int32).at[me].set(counts)
        for a in range(self.ndim):
            token = acc
            perm_p, _ = self._perm[a]
            for _ in range(self.dims[a] - 1):
                token = lax.ppermute(token, axis_name, perm_p)
                acc = acc + token
        return acc

    # -- canonical hop-by-hop admission with transit buffers ---------------
    def _stall_attr(self, stall_hop_flat: jax.Array,
                    counts_flat: jax.Array) -> jax.Array | None:
        """(K,) deferred events per refusing PHYSICAL egress link — the
        flight recorder's per-link congestion lane — or None unless built
        with ``stall_attribution=True`` (None keeps uninstrumented stats
        pytrees unchanged).

        Every deferral is a hop-0 refusal under the transit-buffer model
        (transit shortfalls park instead of deferring), so the blame
        lands on the row's first egress link.  Computed from the
        replicated admission replay, so the table is global — identical
        on every shard and summing to the GLOBAL deferred total.  Row
        axes longer than n² (the tenant replay's ``T*n²``) map onto
        physical pairs modulo n².
        """
        if not self.stall_attribution:
            return None
        n2 = self.n_shards * self.n_shards
        K = self.n_shards * self.n_links
        pair = jnp.arange(stall_hop_flat.shape[0]) % n2
        fl = self._link_seq[:, 0][pair]
        return jnp.zeros((K,), jnp.int32).at[jnp.maximum(fl, 0)].add(
            jnp.where((stall_hop_flat >= 0) & (fl >= 0), counts_flat, 0))

    def _admit_global(self, state: base.FabricState,
                      counts_all: jax.Array) -> AdmissionOut:
        """Replay the canonical two-phase admission over the global state.

        Pure function of (FabricState, counts_all): every shard computes
        the identical result, keeping the replicated bank AND transit
        tables consistent without extra synchronization.  Both phases
        process rows source-major, rotated by ``bank.epoch`` (round-robin
        arbitration over progress rounds, see class docstring):

        **Phase A — drain the fabric first.**  Every parked row tries to
        resume from its blocked hop ``h``: it advances over hops whose
        links still have ``count`` credits, stopping at the first short
        one.  A row that reaches the end of its route *completes* (its
        source injects the custody payload into this window's rotation);
        one that advances but blocks again re-parks at the new hop —
        releasing the old arrival link's held credit into the delay line
        and holding the new one's; one that cannot move keeps holding.

        **Phase B — fresh offers.**  A routed row whose (src, dst) slot
        is free and whose source FIFO is not head-of-line blocked walks
        its route the same way: all links free → admitted and delivered;
        short at hop ``h >= 1`` → enters the fabric, crosses hops
        ``0..h-1`` and parks at ``h`` (the arrival link's credit is held,
        the earlier hops' spends enter the delay line normally); short at
        hop 0 → never enters the fabric: *deferred* at the sender
        (``stall_hop = 0``) and its egress FIFO head-of-line blocks every
        later row this window.
        """
        n, K, H = self.n_shards, self.n_shards * self.n_links, self.max_hops
        flat = counts_all.reshape(-1)
        pc0 = state.parked_count.reshape(-1)
        ph0 = state.parked_hop.reshape(-1)
        pa0 = state.parked_age.reshape(-1)
        r_all = jnp.arange(n * n)
        rows = ((r_all // n + state.bank.epoch) % n) * n + r_all % n
        hop_idx = jnp.arange(H)

        # congestion snapshot: events already parked in the buffers along
        # each row's REMAINING route at window start (the queueing-latency
        # term).  A parked row's gather starts at its blocked hop, which
        # excludes both its own held events (they sit on the arrival link
        # at hop h-1) and traffic parked behind it — a lone row resuming
        # through an otherwise empty fabric charges exactly zero.
        valid_all = self._link_seq >= 0
        idx_all = jnp.maximum(self._link_seq, 0)
        start_hop = jnp.where(pc0 > 0, ph0, 0)[:, None]       # (n², 1)
        queue_events = jnp.sum(
            jnp.where(valid_all & (jnp.arange(H)[None, :] >= start_hop),
                      state.parked_by_link[idx_all], 0),
            axis=-1).reshape(n, n)

        def resume(carry, r):
            remaining, notify, pbl = carry
            c, h = pc0[r], ph0[r]
            active = c > 0
            seq = self._link_seq[r]                     # (H,) hop-ordered
            idx = jnp.maximum(seq, 0)
            valid = seq >= 0
            L = self._route_len[r]
            rem_at = remaining[idx]
            short = valid & (hop_idx >= h) & (rem_at < c)
            h_new = jnp.min(jnp.where(short, hop_idx, H))
            complete = active & (h_new >= L)
            h_stop = jnp.maximum(jnp.where(complete, L, h_new), h)
            moved = active & (h_stop > h)
            trav = valid & (hop_idx >= h) & (hop_idx < h_stop) & active
            remaining = remaining.at[idx].add(-jnp.where(trav, c, 0))
            # the last traversed link becomes the new hold when re-parking
            new_hold = moved & ~complete
            at_hold = new_hold & (hop_idx == h_stop - 1)
            notify = notify.at[idx].add(jnp.where(trav & ~at_hold, c, 0))
            pbl = pbl.at[idx].add(jnp.where(at_hold, c, 0))
            # departing the old park spot releases its held arrival credit
            # (hop-0 parks — fault evictions that failed to retry — hold
            # nothing, so nothing to release)
            oh = jnp.maximum(seq[jnp.maximum(h - 1, 0)], 0)
            rel = jnp.where(moved & (h >= 1), c, 0)
            notify = notify.at[oh].add(rel)
            pbl = pbl.at[oh].add(-rel)
            out = (complete, jnp.where(complete, 0, c),
                   jnp.where(active & ~complete, h_stop, 0),
                   jnp.where(complete, pa0[r], 0),
                   jnp.where(active & ~complete, pa0[r] + 1, 0),
                   jnp.sum(trav.astype(jnp.int32)))
            return (remaining, notify, pbl), out

        carry = (state.bank.credits, jnp.zeros((K,), jnp.int32),
                 state.parked_by_link)
        carry, (res_c, pc_a, ph_a, age_res, age_a, trav_a) = lax.scan(
            resume, carry, rows)

        def offer(carry, r):
            remaining, notify, pbl, blocked = carry
            c = flat[r]
            seq = self._link_seq[r]
            idx = jnp.maximum(seq, 0)
            valid = seq >= 0
            L = self._route_len[r]
            fl = seq[0]
            routed = (fl >= 0) & (c > 0)
            slot_busy = pc0[r] > 0          # in-order per (src, dst) flow
            hol = blocked[jnp.maximum(fl, 0)]
            rem_at = remaining[idx]
            short = valid & (rem_at < c)
            h_block = jnp.min(jnp.where(short, hop_idx, H))
            ok = routed & ~slot_busy & ~hol
            admit_c = ok & (h_block >= L)
            admit_p = ok & (h_block < L) & (h_block >= 1)
            defer = routed & ~admit_c & ~admit_p
            h_stop = jnp.where(admit_c, L, jnp.where(admit_p, h_block, 0))
            trav = valid & (hop_idx < h_stop)
            remaining = remaining.at[idx].add(-jnp.where(trav, c, 0))
            at_hold = admit_p & (hop_idx == h_stop - 1)
            notify = notify.at[idx].add(jnp.where(trav & ~at_hold, c, 0))
            pbl = pbl.at[idx].add(jnp.where(at_hold, c, 0))
            blocked = blocked.at[jnp.maximum(fl, 0)].set(
                blocked[jnp.maximum(fl, 0)] | defer)
            # a deferred row never left the source: every deferral is a
            # hop-0 (egress FIFO) stall under the transit-buffer model
            out = (admit_c, admit_p, jnp.where(defer, 0, -1), h_stop,
                   jnp.sum(trav.astype(jnp.int32)))
            return (remaining, notify, pbl, blocked), out

        carry = (*carry, jnp.zeros((K,), bool))
        (remaining, notify, pbl, _), (adm_c, adm_p, stall, hp_b, trav_b) = \
            lax.scan(offer, carry, rows)

        # un-rotate: scan outputs are in processing order, rows[i] -> i
        def unrot(x, fill, dtype):
            return jnp.full((n * n,), fill, dtype).at[rows].set(x)

        fresh_complete = unrot(adm_c, False, bool)
        fresh_park = unrot(adm_p, False, bool)
        resumed_complete = unrot(res_c, False, bool)
        stall_hop = unrot(stall, -1, jnp.int32)
        hp_fresh = unrot(hp_b, 0, jnp.int32)
        park_count = jnp.where(fresh_park, flat, unrot(pc_a, 0, jnp.int32))
        park_hop = jnp.where(fresh_park, hp_fresh, unrot(ph_a, 0, jnp.int32))
        # a freshly parked row enters at age 1: by the earliest window it
        # can resume it will have waited one full window
        park_age = jnp.where(fresh_park, 1, unrot(age_a, 0, jnp.int32))
        links_traversed = (unrot(trav_a, 0, jnp.int32)
                           + unrot(trav_b, 0, jnp.int32))
        return AdmissionOut(
            fresh_complete=fresh_complete.reshape(n, n),
            fresh_park=fresh_park.reshape(n, n),
            resumed_complete=resumed_complete.reshape(n, n),
            resume_age=unrot(age_res, 0, jnp.int32).reshape(n, n),
            stall_hop=stall_hop.reshape(n, n),
            park_count=park_count.reshape(n, n),
            park_hop=park_hop.reshape(n, n),
            park_age=park_age.reshape(n, n),
            parked_by_link=pbl,
            links_traversed=links_traversed.reshape(n, n),
            spent=state.bank.credits - remaining,
            notify=notify,
            queue_events=queue_events,
            rerouted=jnp.zeros((n, n), jnp.int32),
            links_done=jnp.where(
                fresh_complete | resumed_complete,
                self._route_len, 0).astype(jnp.int32).reshape(n, n),
            stalled_by_link=self._stall_attr(stall_hop, flat),
        )

    # -- fault-aware admission ---------------------------------------------
    def _admit_global_faulted(self, state: base.FabricState,
                              counts_all: jax.Array,
                              link_down: jax.Array) -> AdmissionOut:
        """The canonical two-phase replay under a per-window dead-link
        mask.  Same deterministic structure as :meth:`_admit_global`,
        with three fault rules layered on:

        * **Reroute** — per row and axis, if the short ring arc crosses a
          dead link and the long arc is clean, that axis walks the long
          way around (``_link_seq_alt``); if both arcs are dead the row
          is *unroutable* this window (deferred without blocking its
          egress FIFO — it never reaches the link queue).  The per-axis
          flip rule keeps the decision local to each ring phase, so the
          rotation makes the identical choice from the same mask.
        * **Eviction** — a parked row whose REMAINING route touches a
          dead link, or whose held arrival link died under it, abandons
          its progress: the held credit releases into the delay line and
          the row retries from hop 0 on the current detour route (phase
          A, ahead of fresh offers).  A failed retry leaves it parked at
          hop 0 holding nothing; its custody payload stays in the fabric.
        * **All-or-nothing detours** — a detoured row (flip combo != 0)
          either completes or stays put: parking mid-route is only
          meaningful against the *default* route (the mask changes every
          window, so a detour hop index would dangle).  Rows on the
          default route park/resume exactly as in the healthy replay.

        Dead links admit nothing because every chosen route is clean by
        construction — no spend, hold or notify ever touches one, and
        eviction clears ``parked_by_link`` on a dying link the window it
        dies.
        """
        n, K = self.n_shards, self.n_shards * self.n_links
        H2 = self.max_hops_alt
        flat = counts_all.reshape(-1)
        pc0 = state.parked_count.reshape(-1)
        ph0 = state.parked_hop.reshape(-1)
        pa0 = state.parked_age.reshape(-1)
        r_all = jnp.arange(n * n)
        rows = ((r_all // n + state.bank.epoch) % n) * n + r_all % n
        hop_idx = jnp.arange(H2)
        down = link_down

        # per-pair reroute decision from the window's mask
        seg = self._seg_links                          # (ndim, 2, n², Hs)
        seg_dirty = (down[jnp.maximum(seg, 0)] & (seg >= 0)).any(-1)
        flip = seg_dirty[:, 0] & ~seg_dirty[:, 1]      # (ndim, n²)
        routable_all = ~(seg_dirty[:, 0] & seg_dirty[:, 1]).any(0)
        combo = jnp.sum(flip.astype(jnp.int32)
                        * (1 << jnp.arange(self.ndim))[:, None], axis=0)
        seq_eff_all = self._link_seq_alt[combo, r_all]  # (n², H2)
        len_eff_all = self._route_len_alt[combo, r_all]
        detour_all = combo != 0
        seq0_all = jnp.pad(self._link_seq,
                           ((0, 0), (0, H2 - self.max_hops)),
                           constant_values=-1)

        # eviction set: parked rows whose remaining default route died,
        # whose held arrival link died, or already sitting at hop 0 from
        # an earlier failed retry (they re-run the retry path each
        # window until credits + a clean route let them through)
        valid0 = seq0_all >= 0
        rem_dirty = (valid0 & (hop_idx[None, :] >= ph0[:, None])
                     & down[jnp.maximum(seq0_all, 0)]).any(-1)
        held_link = jnp.take_along_axis(
            seq0_all, jnp.maximum(ph0 - 1, 0)[:, None], axis=1)[:, 0]
        held_dead = (ph0 >= 1) & down[jnp.maximum(held_link, 0)]
        ev_all = (pc0 > 0) & ((ph0 == 0) | rem_dirty | held_dead)

        # congestion snapshot over the routes rows will actually take
        pbl0 = state.parked_by_link
        seq_q = jnp.where((pc0 > 0)[:, None], seq0_all, seq_eff_all)
        start_hop = jnp.where((pc0 > 0) & ~ev_all, ph0, 0)[:, None]
        queue_events = jnp.sum(
            jnp.where((seq_q >= 0) & (hop_idx[None, :] >= start_hop),
                      pbl0[jnp.maximum(seq_q, 0)], 0),
            axis=-1).reshape(n, n)

        def resume(carry, r):
            remaining, notify, pbl = carry
            c, h = pc0[r], ph0[r]
            active = c > 0
            ev = ev_all[r]
            # branch 1 — undisturbed resume on the default route
            seq = seq0_all[r]
            idx = jnp.maximum(seq, 0)
            valid = seq >= 0
            L = self._route_len[r]
            rem_at = remaining[idx]
            short = valid & (hop_idx >= h) & (rem_at < c)
            h_new = jnp.min(jnp.where(short, hop_idx, H2))
            act1 = active & ~ev
            complete1 = act1 & (h_new >= L)
            h_stop1 = jnp.maximum(jnp.where(complete1, L, h_new), h)
            moved1 = act1 & (h_stop1 > h)
            trav1 = valid & (hop_idx >= h) & (hop_idx < h_stop1) & act1
            remaining = remaining.at[idx].add(-jnp.where(trav1, c, 0))
            at_hold1 = moved1 & ~complete1 & (hop_idx == h_stop1 - 1)
            notify = notify.at[idx].add(jnp.where(trav1 & ~at_hold1, c, 0))
            pbl = pbl.at[idx].add(jnp.where(at_hold1, c, 0))
            # branch 2 — evicted retry from hop 0 on the detour route
            seq2 = seq_eff_all[r]
            idx2 = jnp.maximum(seq2, 0)
            valid2 = seq2 >= 0
            L2 = len_eff_all[r]
            act2 = active & ev & routable_all[r]
            short2 = valid2 & (remaining[idx2] < c)
            h_block = jnp.min(jnp.where(short2, hop_idx, H2))
            complete2 = act2 & (h_block >= L2)
            park2 = (act2 & ~detour_all[r] & (h_block < L2)
                     & (h_block >= 1))
            h_stop2 = jnp.where(complete2, L2, jnp.where(park2, h_block, 0))
            trav2 = valid2 & (hop_idx < h_stop2)
            remaining = remaining.at[idx2].add(-jnp.where(trav2, c, 0))
            at_hold2 = park2 & (hop_idx == h_stop2 - 1)
            notify = notify.at[idx2].add(jnp.where(trav2 & ~at_hold2, c, 0))
            pbl = pbl.at[idx2].add(jnp.where(at_hold2, c, 0))
            # departing (or being evicted from) the old park spot
            # releases its held arrival credit into the delay line
            oh = jnp.maximum(seq[jnp.maximum(h - 1, 0)], 0)
            rel = jnp.where(moved1 | (active & ev & (h >= 1)), c, 0)
            notify = notify.at[oh].add(rel)
            pbl = pbl.at[oh].add(-rel)
            complete = complete1 | complete2
            keep = active & ~complete
            h_keep = jnp.where(ev, jnp.where(park2, h_block, 0), h_stop1)
            out = (complete, jnp.where(complete, 0, c),
                   jnp.where(keep, h_keep, 0),
                   jnp.where(complete, pa0[r], 0),
                   jnp.where(keep, pa0[r] + 1, 0),
                   jnp.sum(trav1.astype(jnp.int32))
                   + jnp.sum(trav2.astype(jnp.int32)),
                   jnp.where(complete2 & detour_all[r], c, 0),
                   jnp.where(complete1, L, 0) + jnp.where(complete2, L2, 0))
            return (remaining, notify, pbl), out

        carry = (state.bank.credits, jnp.zeros((K,), jnp.int32),
                 state.parked_by_link)
        carry, (res_c, pc_a, ph_a, age_res, age_a, trav_a, rer_a,
                done_a) = lax.scan(resume, carry, rows)

        def offer(carry, r):
            remaining, notify, pbl, blocked = carry
            c = flat[r]
            seq = seq_eff_all[r]
            idx = jnp.maximum(seq, 0)
            valid = seq >= 0
            L = len_eff_all[r]
            rt = routable_all[r]
            fl = seq[0]
            routed = (fl >= 0) & (c > 0) & rt
            slot_busy = pc0[r] > 0
            hol = blocked[jnp.maximum(fl, 0)]
            rem_at = remaining[idx]
            short = valid & (rem_at < c)
            h_block = jnp.min(jnp.where(short, hop_idx, H2))
            ok = routed & ~slot_busy & ~hol
            admit_c = ok & (h_block >= L)
            # parking mid-route only against the default route; a
            # detoured offer is all-or-nothing
            admit_p = (ok & ~detour_all[r] & (h_block < L)
                       & (h_block >= 1))
            defer = ((fl >= 0) & (c > 0)) & ~admit_c & ~admit_p
            h_stop = jnp.where(admit_c, L, jnp.where(admit_p, h_block, 0))
            trav = valid & (hop_idx < h_stop)
            remaining = remaining.at[idx].add(-jnp.where(trav, c, 0))
            at_hold = admit_p & (hop_idx == h_stop - 1)
            notify = notify.at[idx].add(jnp.where(trav & ~at_hold, c, 0))
            pbl = pbl.at[idx].add(jnp.where(at_hold, c, 0))
            # an unroutable row never reaches its egress FIFO, so it
            # cannot head-of-line-block the rows behind it
            blocked = blocked.at[jnp.maximum(fl, 0)].set(
                blocked[jnp.maximum(fl, 0)] | (defer & rt))
            out = (admit_c, admit_p, jnp.where(defer, 0, -1), h_stop,
                   jnp.sum(trav.astype(jnp.int32)),
                   jnp.where(admit_c & detour_all[r], c, 0),
                   jnp.where(admit_c, L, 0))
            return (remaining, notify, pbl, blocked), out

        carry = (*carry, jnp.zeros((K,), bool))
        (remaining, notify, pbl, _), (adm_c, adm_p, stall, hp_b, trav_b,
                                      rer_b, done_b) = lax.scan(
            offer, carry, rows)

        def unrot(x, fill, dtype):
            return jnp.full((n * n,), fill, dtype).at[rows].set(x)

        fresh_complete = unrot(adm_c, False, bool)
        fresh_park = unrot(adm_p, False, bool)
        resumed_complete = unrot(res_c, False, bool)
        stall_hop = unrot(stall, -1, jnp.int32)
        hp_fresh = unrot(hp_b, 0, jnp.int32)
        park_count = jnp.where(fresh_park, flat, unrot(pc_a, 0, jnp.int32))
        park_hop = jnp.where(fresh_park, hp_fresh, unrot(ph_a, 0, jnp.int32))
        park_age = jnp.where(fresh_park, 1, unrot(age_a, 0, jnp.int32))
        links_traversed = (unrot(trav_a, 0, jnp.int32)
                           + unrot(trav_b, 0, jnp.int32))
        return AdmissionOut(
            fresh_complete=fresh_complete.reshape(n, n),
            fresh_park=fresh_park.reshape(n, n),
            resumed_complete=resumed_complete.reshape(n, n),
            resume_age=unrot(age_res, 0, jnp.int32).reshape(n, n),
            stall_hop=stall_hop.reshape(n, n),
            park_count=park_count.reshape(n, n),
            park_hop=park_hop.reshape(n, n),
            park_age=park_age.reshape(n, n),
            parked_by_link=pbl,
            links_traversed=links_traversed.reshape(n, n),
            spent=state.bank.credits - remaining,
            notify=notify,
            queue_events=queue_events,
            rerouted=(unrot(rer_a, 0, jnp.int32)
                      + unrot(rer_b, 0, jnp.int32)).reshape(n, n),
            links_done=(unrot(done_a, 0, jnp.int32)
                        + unrot(done_b, 0, jnp.int32)).reshape(n, n),
            stalled_by_link=self._stall_attr(stall_hop, flat),
        )

    # -- one bidirectional ring phase --------------------------------------
    def _ring_phase(self, bundles, axis_name, my_c, n, perm_p, perm_m,
                    acc: dict, phase: int,
                    count_cols: tuple[int, ...] = (-1,),
                    fault=None):
        """Rotate (n, B, W1) count-packed bundles (indexed by target ring
        coordinate) to their owners; returns them indexed by *source* ring
        coordinate.  ``acc`` accumulates LinkStats terms across phases.

        ``count_cols`` names the bitcast-i32 count columns inside each
        bundle row: a single-tenant row is one frame train with its count
        in the last column; a multi-tenant row concatenates one
        count-packed sub-row per tenant, each its own frame train on the
        wire (tenants are separate logical streams), so byte/occupancy
        accounting sums over every tenant's count column.

        ``fault`` is ``None`` on a healthy fabric (the unchanged fast
        path), or ``(down_plus, down_minus)`` — per ring coordinate, is
        that node's +/- link of this axis dead this window.  Each node
        then flips the bundles whose short arc crosses a dead link to
        the long way around (matching the admission replay's per-axis
        rule — same mask, same links, same decision), both direction
        loops extend to ``n - 1`` hops, and absorption accumulates
        (a source delivers via + or via -, never both).  A bundle slot
        crossing a dead link is zero by construction: any row still
        aboard at that hop has the dead link in its arc and was either
        flipped to the other direction or refused by admission.
        """
        coord = jnp.arange(n)
        fwd = (coord - my_c) % n
        short_plus = fwd <= n // 2
        if fault is None:
            plus = (fwd >= 1) & short_plus
            minus = fwd > n // 2
            hops_p, hops_m = n // 2, (n - 1) // 2
        else:
            down_p, down_m = fault
            # OR of the first k links walking +/- from my coordinate
            cum_p = jnp.cumsum(
                down_p[(my_c + coord) % n].astype(jnp.int32))
            cum_m = jnp.cumsum(
                down_m[(my_c - coord) % n].astype(jnp.int32))

            def arc_dirty(cum, k):
                return (k >= 1) & (cum[jnp.maximum(k - 1, 0)] > 0)

            dirty_p = arc_dirty(cum_p, fwd)
            dirty_m = arc_dirty(cum_m, (n - fwd) % n)
            short_dirty = jnp.where(short_plus, dirty_p, dirty_m)
            long_dirty = jnp.where(short_plus, dirty_m, dirty_p)
            flip = short_dirty & ~long_dirty
            use_plus = jnp.logical_xor(short_plus, flip)
            plus = (fwd >= 1) & use_plus
            minus = (fwd >= 1) & ~use_plus
            hops_p = hops_m = n - 1
        vp = jnp.where(plus[:, None, None], bundles, jnp.uint32(0))
        vm = jnp.where(minus[:, None, None], bundles, jnp.uint32(0))
        recv = jnp.zeros_like(bundles)
        recv = recv.at[my_c].set(jnp.take(bundles, my_c, axis=0))
        cols = jnp.asarray(
            np.asarray(count_cols, np.int32) % bundles.shape[-1])

        def bundle_counts(v):        # (n, B, n_cols) i32
            return lax.bitcast_convert_type(v[:, :, cols], jnp.int32)

        def live_events(v):
            return jnp.sum(bundle_counts(v))

        def wire(v):
            return aggregator.window_cost(bundle_counts(v).reshape(-1)).bytes

        def owire(v):
            # exact frame-level bytes of this hop: every count-packed
            # sub-row is one frame train of the backend's WireFormat
            return jnp.sum(wire_framing.frame_bytes(self.wire_fmt,
                                                    bundle_counts(v)))

        for direction, v, perm, n_hops in (
            ("+", vp, perm_p, hops_p),
            ("-", vm, perm_m, hops_m),
        ):
            for h in range(1, n_hops + 1):
                acc["bytes"] += wire(v)
                acc["owire"] += owire(v)
                v = lax.ppermute(v, axis_name, perm)
                src = (my_c - h) % n if direction == "+" else (my_c + h) % n
                got = jnp.take(v, my_c, axis=0)
                if fault is None:
                    recv = recv.at[src].set(got)
                else:
                    recv = recv.at[src].add(got)
                v = v.at[my_c].set(jnp.uint32(0))
                acc["hops"] += 1
                occ = live_events(v)
                acc["in_flight"] = jnp.maximum(acc["in_flight"], occ)
                acc["in_flight_phase"][phase] = jnp.maximum(
                    acc["in_flight_phase"][phase], occ)
        # everything within shortest (or detour) distance was absorbed
        return recv

    def _phase_fault(self, down, a: int, me, my_c):
        """Slice the (K,) dead-link mask into this node's axis-``a`` ring
        view: per ring coordinate, is that node's +/- link of the axis
        dead.  Node at ring coordinate c is ``me + (c - my_c) * stride``
        (only the axis-a digit of the flattened id changes)."""
        if down is None:
            return None
        stride = int(np.prod(self.dims[:a], dtype=np.int64)) if a else 1
        ring_nodes = me + (jnp.arange(self.dims[a]) - my_c) * stride
        return (down[ring_nodes * self.n_links + 2 * a],
                down[ring_nodes * self.n_links + 2 * a + 1])

    # -- phase reshapes ----------------------------------------------------
    # The (n, W1) buffer keeps a fixed layout: flattened index
    # c0 + n0*c1 + n0*n1*c2 where axis-a's coordinate is the DESTINATION
    # coordinate before phase a has run and the SOURCE coordinate after.
    def _phase_perm(self, a: int):
        nd = self.ndim
        lead = nd - 1 - a            # axis of dim ``a`` in the reshaped view
        perm = (lead, *(i for i in range(nd) if i != lead), nd)
        return perm, tuple(int(i) for i in np.argsort(perm))

    def _to_phase(self, buf: jax.Array, a: int) -> jax.Array:
        w1 = buf.shape[-1]
        t = buf.reshape(*reversed(self.dims), w1)
        perm, _ = self._phase_perm(a)
        return t.transpose(perm).reshape(self.dims[a], -1, w1)

    def _from_phase(self, recv: jax.Array, a: int) -> jax.Array:
        w1 = recv.shape[-1]
        perm, inv = self._phase_perm(a)
        other = [d for i, d in enumerate(reversed(self.dims))
                 if i != self.ndim - 1 - a]
        t = recv.reshape(self.dims[a], *other, w1).transpose(inv)
        return t.reshape(self.n_shards, w1)

    # -- the full window ---------------------------------------------------
    def exchange(self, state: base.LinkState, payload: jax.Array,
                 counts: jax.Array, *, axis_name: str,
                 enforce_credits: bool = True) -> base.TransportOut:
        n = self.n_shards
        me = lax.axis_index(axis_name)
        counts = counts.astype(jnp.int32)
        is_local = jnp.arange(n) == me
        zero_q = jnp.zeros((n, n), jnp.float32)
        down = state.link_down      # per-window fault mask (usually None)

        # 1. injection: hop-by-hop credit admission over the whole route,
        #    transit buffers drained first (compiled out when unthrottled
        #    — no all-gather, no scan, no tables)
        throttled = enforce_credits and self.link_credits > 0
        if down is not None and not throttled:
            raise ValueError(
                "fault injection (FabricState.link_down) requires credit "
                "flow control: an unthrottled fabric has no per-link "
                "admission to refuse at a dead link (set link_credits > 0)")
        if throttled:
            if state.parked_payload.shape != payload.shape:
                raise ValueError(
                    f"FabricState payload buffer {state.parked_payload.shape}"
                    f" != offered payload {payload.shape}: initialize with "
                    f"init_state(payload_width=W) so parked rows keep "
                    f"custody of their wire words")
            counts_all = self._allgather_counts(counts, me, axis_name)
            adm = (self._admit_global_faulted(state, counts_all, down)
                   if down is not None
                   else self._admit_global(state, counts_all))
            fresh_c = adm.fresh_complete[me]
            fresh_p = adm.fresh_park[me]
            resumed = adm.resumed_complete[me]
            stall_hop = adm.stall_hop[me]
            pc0_me = state.parked_count[me]
            # rotation rows: fresh completions ship the caller's payload,
            # resumed rows ship the fabric's custody copy (disjoint per
            # destination — a fresh row behind a parked one is deferred)
            ship_fresh = fresh_c | (is_local & (counts > 0))
            cnt_in = (jnp.where(ship_fresh, counts, 0)
                      + jnp.where(resumed, pc0_me, 0))
            row_payload = jnp.where(
                resumed[:, None], state.parked_payload,
                jnp.where(ship_fresh[:, None], payload, jnp.uint32(0)))
            # advance the carried fabric state: custody payload slots of
            # newly parked rows are overwritten, completed slots expire
            # with their zeroed counts
            bank = fc.credit_tick(state.bank, adm.spent, notify=adm.notify)
            state = base.FabricState(
                bank=bank,
                parked_count=adm.park_count,
                parked_hop=adm.park_hop,
                parked_age=adm.park_age,
                parked_by_link=adm.parked_by_link,
                parked_payload=jnp.where(fresh_p[:, None], payload,
                                         state.parked_payload),
                parked_hold_shared=jnp.zeros_like(adm.park_count),
            )
            sent_mask = fresh_c | fresh_p | is_local | (counts == 0)
            sent_now = fresh_c | is_local | (counts == 0)
            queue_us = wire_latency.queueing_latency_us(
                self.wire_fmt, adm.queue_events)
            # park dwell of the rows delivered from the fabric: per window
            # parked, one link credit budget had to drain ahead of them
            park_wait_us = wire_latency.queueing_latency_us(
                self.wire_fmt, adm.resume_age * self.link_credits)
        else:
            fresh_p = resumed = jnp.zeros((n,), bool)
            pc0_me = jnp.zeros((n,), jnp.int32)
            stall_hop = jnp.full((n,), -1, jnp.int32)
            cnt_in = counts
            row_payload = payload
            state = state._replace(bank=fc.credit_tick(
                state.bank, jnp.zeros_like(state.bank.credits)),
                link_down=None)
            sent_mask = sent_now = jnp.ones((n,), bool)
            queue_us = park_wait_us = zero_q
        packed = base.pack_payload(row_payload, cnt_in)

        acc = {"bytes": jnp.int32(0), "owire": jnp.int32(0), "hops": 0,
               "in_flight": jnp.int32(0),
               "in_flight_phase": [jnp.int32(0)] * self.ndim}

        # 2. dimension-ordered phases: rotate along each axis' rings
        my_c = self._coords_of(me)
        buf = packed
        for a in range(self.ndim):
            bundles = self._to_phase(buf, a)
            perm_p, perm_m = self._perm[a]
            recv = self._ring_phase(bundles, axis_name, my_c[a],
                                    self.dims[a], perm_p, perm_m, acc,
                                    phase=a,
                                    fault=self._phase_fault(down, a, me,
                                                            my_c[a]))
            buf = self._from_phase(recv, a)
        recv_payload, recv_counts = base.unpack_payload(buf)

        # 3. stats: deferred rows histogrammed by their blocking hop,
        #    parked rows by the hop they wait at
        stalled_by_hop = jnp.zeros((self.max_hops,), jnp.int32).at[
            jnp.clip(stall_hop, 0, self.max_hops - 1)
        ].add(jnp.where(stall_hop >= 0, counts, 0))
        offered = jnp.sum(counts).astype(jnp.int32)
        if throttled:
            sent = jnp.sum(jnp.where(sent_now, counts, 0)).astype(jnp.int32)
            parked = jnp.sum(jnp.where(fresh_p, counts, 0)).astype(jnp.int32)
            unparked = jnp.sum(
                jnp.where(resumed, pc0_me, 0)).astype(jnp.int32)
            pk_cnt, pk_hop = state.parked_count[me], state.parked_hop[me]
            parked_by_hop = jnp.zeros((self.max_hops,), jnp.int32).at[
                jnp.clip(pk_hop, 0, self.max_hops - 1)].add(pk_cnt)
            # frame-exact bytes: each row pays one frame-train
            # re-serialization per link it crossed THIS window, so across
            # park/resume windows every route link is counted exactly once
            c_row = jnp.where(resumed, pc0_me, counts)
            owire = jnp.sum(wire_framing.frame_bytes(self.wire_fmt, c_row)
                            * adm.links_traversed[me]).astype(jnp.int32)
            dwell = jnp.sum(jnp.where(
                fresh_c | resumed, queue_us[me] + park_wait_us[me],
                0.0)).astype(jnp.float32)
            rerouted = jnp.sum(adm.rerouted[me]).astype(jnp.int32)
        else:
            sent = jnp.sum(cnt_in).astype(jnp.int32)
            parked = unparked = jnp.zeros((), jnp.int32)
            parked_by_hop = jnp.zeros((self.max_hops,), jnp.int32)
            owire = acc["owire"].astype(jnp.int32)
            dwell = jnp.zeros((), jnp.float32)
            rerouted = jnp.zeros((), jnp.int32)
        stats = base.LinkStats(
            offered_events=offered,
            sent_events=sent,
            deferred_events=offered - sent - parked,
            delivered_events=jnp.sum(recv_counts).astype(jnp.int32),
            credit_stalls=jnp.sum(stall_hop >= 0).astype(jnp.int32),
            hops=jnp.int32(acc["hops"]),
            forwarded_bytes=acc["bytes"].astype(jnp.int32),
            bytes_on_wire=owire,
            max_in_flight=acc["in_flight"].astype(jnp.int32),
            stalled_by_hop=stalled_by_hop,
            max_in_flight_by_phase=jnp.stack(acc["in_flight_phase"]),
            parked_events=parked,
            unparked_events=unparked,
            in_fabric_events=jnp.sum(state.parked_count[me]).astype(
                jnp.int32) if throttled else jnp.zeros((), jnp.int32),
            parked_by_hop=parked_by_hop,
            queue_dwell_us=dwell,
            rerouted=rerouted,
            stalled_by_link=adm.stalled_by_link if throttled else None,
        )
        return base.TransportOut(
            state=state,
            recv_payload=recv_payload,
            recv_counts=recv_counts,
            sent_mask=sent_mask,
            stats=stats,
            sent_now=sent_now,
            queue_us=queue_us,
            unparked_now=jnp.where(resumed, pc0_me, 0),
            park_wait_us=park_wait_us,
            links_used=adm.links_done if down is not None else None,
        )

    # -- end-of-run fabric walk --------------------------------------------
    def drain_fabric(self, state: base.LinkState, *, axis_name: str,
                     payload_width: int | None = None) -> base.TransportOut:
        """Walk the transit buffers until the fabric is empty.

        Every parked row resumes from its blocked hop and completes —
        credits are ignored (the end-of-run flush quiesces the fabric, so
        downstream buffer space is guaranteed to free up) and every held
        credit is released into the notification delay line, restoring
        ``credits + pending == limit`` on every link.  With at most one
        parked row per (src, dst) pair a single rotation sweep delivers
        everything; the returned state has empty tables, which tests pin.
        Byte accounting charges each row's REMAINING links only, so a
        route is still counted exactly once across its lifetime.
        """
        n = self.n_shards
        me = lax.axis_index(axis_name)
        if state.parked_count.size == 0:    # unthrottled: nothing parked
            return super().drain_fabric(state, axis_name=axis_name,
                                        payload_width=payload_width)
        pc_me = state.parked_count[me]
        ph_me = state.parked_hop[me]
        packed = base.pack_payload(
            jnp.where((pc_me > 0)[:, None], state.parked_payload,
                      jnp.uint32(0)), pc_me)

        acc = {"bytes": jnp.int32(0), "owire": jnp.int32(0), "hops": 0,
               "in_flight": jnp.int32(0),
               "in_flight_phase": [jnp.int32(0)] * self.ndim}
        my_c = self._coords_of(me)
        buf = packed
        for a in range(self.ndim):
            bundles = self._to_phase(buf, a)
            perm_p, perm_m = self._perm[a]
            recv = self._ring_phase(bundles, axis_name, my_c[a],
                                    self.dims[a], perm_p, perm_m, acc,
                                    phase=a)
            buf = self._from_phase(recv, a)
        recv_payload, recv_counts = base.unpack_payload(buf)

        bank = fc.credit_tick(state.bank,
                              jnp.zeros_like(state.bank.credits),
                              notify=state.parked_by_link)
        new_state = base.FabricState(
            bank=bank,
            parked_count=jnp.zeros_like(state.parked_count),
            parked_hop=jnp.zeros_like(state.parked_hop),
            parked_age=jnp.zeros_like(state.parked_age),
            parked_by_link=jnp.zeros_like(state.parked_by_link),
            parked_payload=jnp.zeros_like(state.parked_payload),
            parked_hold_shared=jnp.zeros_like(state.parked_hold_shared),
        )
        remaining_links = jnp.maximum(self._hops_matrix[me] - ph_me, 0)
        owire = jnp.sum(
            wire_framing.frame_bytes(self.wire_fmt, pc_me)
            * jnp.where(pc_me > 0, remaining_links, 0)).astype(jnp.int32)
        unparked = jnp.sum(pc_me).astype(jnp.int32)
        stats = base.zero_link_stats(self.max_hops, self.ndim)._replace(
            delivered_events=jnp.sum(recv_counts).astype(jnp.int32),
            unparked_events=unparked,
            hops=jnp.int32(acc["hops"]),
            forwarded_bytes=acc["bytes"].astype(jnp.int32),
            bytes_on_wire=owire,
            max_in_flight=acc["in_flight"].astype(jnp.int32),
            max_in_flight_by_phase=jnp.stack(acc["in_flight_phase"]),
        )
        return base.TransportOut(
            state=new_state,
            recv_payload=recv_payload,
            recv_counts=recv_counts,
            sent_mask=jnp.ones((n,), bool),
            stats=stats,
            sent_now=jnp.ones((n,), bool),
            queue_us=jnp.zeros((n, n), jnp.float32),
            unparked_now=pc_me,
            park_wait_us=jnp.zeros((n, n), jnp.float32),
        )

    def _coords_of(self, me):
        """Traced shard index -> per-dimension ring coordinates."""
        out = []
        for d in self.dims:
            out.append(me % d)
            me = me // d
        return out


class Torus2DTransport(TorusTransport):
    """(nx, ny) torus — the per-wafer concentrator face (2x4 for 8)."""

    name = "torus2d"

    def __init__(self, n_shards: int, *, nx: int = 0, ny: int = 0,
                 link_credits: int = 0, notify_latency: int = 2,
                 max_row_events: int = 0,
                 wire_format: str | wire_framing.WireFormat = "extoll",
                 stall_attribution: bool = False):
        if not nx and not ny:
            nx, ny = default_shape(n_shards)
        elif not ny:
            ny = n_shards // max(nx, 1)
        elif not nx:
            nx = n_shards // max(ny, 1)
        super().__init__(n_shards, (nx, ny), link_credits=link_credits,
                         notify_latency=notify_latency,
                         max_row_events=max_row_events,
                         wire_format=wire_format,
                         stall_attribution=stall_attribution)
        self.nx, self.ny = nx, ny


class Torus3DTransport(TorusTransport):
    """(nx, ny, nz) torus — wafer faces stacked along the Z (wafer) axis,
    the paper's full Extoll arrangement (``core.torus.wafer_topology``)."""

    name = "torus3d"

    def __init__(self, n_shards: int, *, nx: int = 0, ny: int = 0,
                 nz: int = 0, link_credits: int = 0, notify_latency: int = 2,
                 max_row_events: int = 0,
                 wire_format: str | wire_framing.WireFormat = "extoll",
                 stall_attribution: bool = False):
        known = [d for d in (nx, ny, nz) if d]
        if not known:
            nx, ny, nz = default_shape3d(n_shards)
        elif len(known) == 1:
            # one axis pinned (typically nz = wafer count): most-square
            # factorization of the rest onto the remaining face
            rest = n_shards // known[0]
            if nz:
                nx, ny = default_shape(rest)
            elif ny:
                nx, nz = default_shape(rest)
            else:
                ny, nz = default_shape(rest)
        elif len(known) == 2:
            missing = n_shards // max(math.prod(known), 1)
            nx, ny, nz = (nx or missing, ny or missing, nz or missing)
        super().__init__(n_shards, (nx, ny, nz), link_credits=link_credits,
                         notify_latency=notify_latency,
                         max_row_events=max_row_events,
                         wire_format=wire_format,
                         stall_attribution=stall_attribution)
        self.nx, self.ny, self.nz = nx, ny, nz


# ---------------------------------------------------------------------------
# Multi-tenant torus: N concurrent experiments on one fabric with per-tenant
# QoS credit partitioning (the serving substrate of ``repro.serve``).
# ---------------------------------------------------------------------------

class TenantAdmissionOut(NamedTuple):
    """Tenant-axis admission replay result; (T, n, n) fields are
    (tenant, src, dst), slot arrays are ``(T+1)*K``."""

    fresh_complete: jax.Array
    fresh_park: jax.Array
    resumed_complete: jax.Array
    resume_age: jax.Array
    stall_hop: jax.Array
    park_count: jax.Array
    park_hop: jax.Array
    park_age: jax.Array
    hold_shared: jax.Array       # (T, n, n) post-window shared-pool holds
    parked_by_link: jax.Array    # ((T+1)*K,) post-window held units per slot
    links_traversed: jax.Array
    spent: jax.Array             # ((T+1)*K,)
    notify: jax.Array            # ((T+1)*K,)
    queue_events: jax.Array      # (T, n, n) parked events queued ahead
    rerouted: jax.Array          # (T, n, n) events delivered via detour
    links_done: jax.Array        # (T, n, n) delivered-route link counts
    stalled_by_link: jax.Array | None = None  # (K,) deferred events per
                                 #   refusing PHYSICAL link, all tenants
                                 #   pooled (stall_attribution builds)


class TenantTorusTransport(TorusTransport):
    """Torus exchange multiplexing T tenants with partitioned credits.

    Same fabric, same dimension-ordered routes, same store-and-forward
    ring phases — but every physical link's credit budget is split by a
    :class:`repro.core.flow_control.CreditPartition` into one guaranteed
    slice per tenant plus a shared best-effort pool, realised as a bank
    of ``(T+1) * K`` slots that ``credit_tick`` advances unmodified.

    Admission discipline on top of the single-tenant rules (see
    :class:`TorusTransport`):

    * **Reserved-first spending** — a row of tenant ``t`` crossing link
      ``l`` draws ``min(count, slice)`` from slot ``t*K + l`` and the
      remainder from the shared slot ``T*K + l``; it is admitted across a
      link iff slice + shared cover the full count.  No tenant can draw
      another tenant's slice, so tenant ``t`` is guaranteed
      ``reserve[t] // max(notify_latency, 1)`` events per link per window
      of sustained admission regardless of co-tenant congestion — the
      QoS floor ``BENCH_serve.json`` pins.
    * **(tenant, source) round-robin rotation** — the canonical order
      walks rows combined-index-major, ``(t*n + s)`` rotated by the
      bank's progress epoch, so priority alternates over tenants as well
      as sources: bounded starvation in both axes.
    * **Per-tenant egress FIFOs** — a deferred row head-of-line blocks
      only its OWN tenant's later rows on that egress link (each tenant
      has its own injection queue at the NIC, as with Extoll VPIDs); the
      co-tenant's traffic on the same link is judged purely on credits.
    * **Holds release to the right slot** — a parked row's held
      arrival-link credit remembers its reserved/shared split
      (``FabricState.parked_hold_shared``) and refunds accordingly on
      departure, so per-slot conservation
      ``credits + pending + parked_by_link == slot_limit`` holds for all
      ``(T+1)*K`` slots.

    Payloads/counts carry a leading tenant axis — ``payload (T, n, W)``,
    ``counts (T, n)`` — and every ``TransportOut`` field comes back with
    the same leading axis (``stats`` fields are per-tenant; fabric-level
    fields that have no per-tenant decomposition — hops, forwarded_bytes,
    max_in_flight — are attributed to tenant slot 0 so tenant-axis sums
    remain physical).  On the wire the T tenants' sub-rows of one
    destination travel in the same ring-phase bundle but as separate
    count-packed frame trains (separate logical streams).
    """

    name = "torus_tenant"

    def __init__(self, n_shards: int, dims: tuple[int, ...], *,
                 partition: fc.CreditPartition, notify_latency: int = 2,
                 max_row_events: int = 0,
                 wire_format: str | wire_framing.WireFormat = "extoll",
                 stall_attribution: bool = False):
        if partition.limit <= 0:
            raise ValueError("tenant partitioning needs link_credits > 0 "
                             "(an unthrottled fabric has nothing to split)")
        if max_row_events > 0:
            for t, r in enumerate(partition.reserve):
                if r + partition.shared < max_row_events:
                    raise ValueError(
                        f"tenant {t}: reserve ({r}) + shared "
                        f"({partition.shared}) < largest bucket row "
                        f"({max_row_events}): its biggest row could never "
                        f"be admitted and would head-of-line-block forever")
        super().__init__(n_shards, dims, link_credits=partition.limit,
                         notify_latency=notify_latency,
                         max_row_events=max_row_events,
                         wire_format=wire_format,
                         stall_attribution=stall_attribution)
        self.partition = partition
        self.n_tenants = partition.n_tenants

    # -- flow-control state ------------------------------------------------
    def init_state(self, payload_width: int = 0) -> base.LinkState:
        """Partitioned bank + tenant-axis transit tables."""
        T, n = self.n_tenants, self.n_shards
        K = n * self.n_links
        bank = fc.init_partitioned_credits(self.partition, K,
                                           self.notify_latency)
        return base.FabricState(
            bank=bank,
            parked_count=jnp.zeros((T, n, n), jnp.int32),
            parked_hop=jnp.zeros((T, n, n), jnp.int32),
            parked_age=jnp.zeros((T, n, n), jnp.int32),
            parked_by_link=jnp.zeros(((T + 1) * K,), jnp.int32),
            parked_payload=jnp.zeros((T, n, payload_width), jnp.uint32),
            parked_hold_shared=jnp.zeros((T, n, n), jnp.int32),
        )

    def _allgather_counts_mt(self, counts: jax.Array, me, axis_name: str):
        """(T, n) per-shard offered counts -> (T, n, n) global tensor,
        same dimension-wise ring all-gather as the single-tenant path."""
        n, T = self.n_shards, self.n_tenants
        acc = jnp.zeros((n, T, n), jnp.int32).at[me].set(counts)
        for a in range(self.ndim):
            token = acc
            perm_p, _ = self._perm[a]
            for _ in range(self.dims[a] - 1):
                token = lax.ppermute(token, axis_name, perm_p)
                acc = acc + token
        return acc.transpose(1, 0, 2)

    # -- tenant-aware canonical admission ----------------------------------
    def _admit_tenants(self, state: base.FabricState,
                       counts_all: jax.Array) -> TenantAdmissionOut:
        """Deterministic replay over ``T * n^2`` rows with reserved-first
        spending.  Same two phases as ``_admit_global`` — parked rows
        resume first, fresh offers second — with three per-tenant twists:
        availability on a link is ``slice + shared``, spends/holds are
        split reserved-first across the two slots, and the HOL ``blocked``
        array is per (tenant, egress link).
        """
        n, T, H = self.n_shards, self.n_tenants, self.max_hops
        K = n * self.n_links
        flat = counts_all.reshape(-1)                   # (T*n²,)
        pc0 = state.parked_count.reshape(-1)
        ph0 = state.parked_hop.reshape(-1)
        pa0 = state.parked_age.reshape(-1)
        hs0 = state.parked_hold_shared.reshape(-1)
        r_all = jnp.arange(T * n * n)
        # round-robin over the combined (tenant, source) index
        comb = (r_all // n + state.bank.epoch) % (T * n)
        rows = comb * n + r_all % n
        hop_idx = jnp.arange(H)

        # congestion snapshot over PHYSICAL links (a queued event delays
        # everyone crossing that link, whatever slot funded it)
        pbl_phys = state.parked_by_link.reshape(T + 1, K).sum(0)
        valid_all = self._link_seq >= 0                  # (n², H)
        idx_all = jnp.maximum(self._link_seq, 0)
        pair_all = jnp.arange(T * n * n) % (n * n)
        start_hop = jnp.where(pc0 > 0, ph0, 0)[:, None]
        queue_events = jnp.sum(
            jnp.where(valid_all[pair_all]
                      & (jnp.arange(H)[None, :] >= start_hop),
                      pbl_phys[idx_all[pair_all]], 0),
            axis=-1).reshape(T, n, n)

        def split_spend(remaining, t, idx, trav, c):
            """Reserved-first draw of ``c`` units at each traversed link;
            returns (remaining', take_r, take_s) with per-hop splits."""
            slot_r = t * K + idx
            slot_s = T * K + idx
            take_r = jnp.where(trav, jnp.minimum(c, remaining[slot_r]), 0)
            take_s = jnp.where(trav, c - take_r, 0)
            remaining = remaining.at[slot_r].add(-take_r)
            remaining = remaining.at[slot_s].add(-take_s)
            return remaining, take_r, take_s

        def resume(carry, r):
            remaining, notify, pbl = carry
            t = r // (n * n)
            pair = r % (n * n)
            c, h, hs = pc0[r], ph0[r], hs0[r]
            active = c > 0
            seq = self._link_seq[pair]
            idx = jnp.maximum(seq, 0)
            valid = seq >= 0
            L = self._route_len[pair]
            avail = remaining[t * K + idx] + remaining[T * K + idx]
            short = valid & (hop_idx >= h) & (avail < c)
            h_new = jnp.min(jnp.where(short, hop_idx, H))
            complete = active & (h_new >= L)
            h_stop = jnp.maximum(jnp.where(complete, L, h_new), h)
            moved = active & (h_stop > h)
            trav = valid & (hop_idx >= h) & (hop_idx < h_stop) & active
            remaining, take_r, take_s = split_spend(remaining, t, idx,
                                                    trav, c)
            new_hold = moved & ~complete
            at_hold = new_hold & (hop_idx == h_stop - 1)
            notify = notify.at[t * K + idx].add(
                jnp.where(at_hold, 0, take_r))
            notify = notify.at[T * K + idx].add(
                jnp.where(at_hold, 0, take_s))
            pbl = pbl.at[t * K + idx].add(jnp.where(at_hold, take_r, 0))
            pbl = pbl.at[T * K + idx].add(jnp.where(at_hold, take_s, 0))
            hs_new = jnp.sum(jnp.where(at_hold, take_s, 0))
            # departing the old park spot releases its held arrival
            # credit back to the slots that funded it (hop-0 parks from
            # fault evictions hold nothing)
            oh = jnp.maximum(seq[jnp.maximum(h - 1, 0)], 0)
            held = moved & (h >= 1)
            rel_s = jnp.where(held, hs, 0)
            rel_r = jnp.where(held, c, 0) - rel_s
            notify = notify.at[t * K + oh].add(rel_r)
            notify = notify.at[T * K + oh].add(rel_s)
            pbl = pbl.at[t * K + oh].add(-rel_r)
            pbl = pbl.at[T * K + oh].add(-rel_s)
            keep = active & ~complete
            out = (complete, jnp.where(complete, 0, c),
                   jnp.where(keep, h_stop, 0),
                   jnp.where(complete, pa0[r], 0),
                   jnp.where(keep, pa0[r] + 1, 0),
                   jnp.sum(trav.astype(jnp.int32)),
                   jnp.where(keep, jnp.where(moved, hs_new, hs), 0))
            return (remaining, notify, pbl), out

        S = (T + 1) * K
        carry = (state.bank.credits, jnp.zeros((S,), jnp.int32),
                 state.parked_by_link)
        carry, (res_c, pc_a, ph_a, age_res, age_a, trav_a, hs_a) = lax.scan(
            resume, carry, rows)

        def offer(carry, r):
            remaining, notify, pbl, blocked = carry
            t = r // (n * n)
            pair = r % (n * n)
            c = flat[r]
            seq = self._link_seq[pair]
            idx = jnp.maximum(seq, 0)
            valid = seq >= 0
            L = self._route_len[pair]
            fl = seq[0]
            routed = (fl >= 0) & (c > 0)
            slot_busy = pc0[r] > 0
            bl_idx = t * K + jnp.maximum(fl, 0)
            hol = blocked[bl_idx]
            avail = remaining[t * K + idx] + remaining[T * K + idx]
            short = valid & (avail < c)
            h_block = jnp.min(jnp.where(short, hop_idx, H))
            ok = routed & ~slot_busy & ~hol
            admit_c = ok & (h_block >= L)
            admit_p = ok & (h_block < L) & (h_block >= 1)
            defer = routed & ~admit_c & ~admit_p
            h_stop = jnp.where(admit_c, L, jnp.where(admit_p, h_block, 0))
            trav = valid & (hop_idx < h_stop)
            remaining, take_r, take_s = split_spend(remaining, t, idx,
                                                    trav, c)
            at_hold = admit_p & (hop_idx == h_stop - 1)
            notify = notify.at[t * K + idx].add(
                jnp.where(at_hold, 0, take_r))
            notify = notify.at[T * K + idx].add(
                jnp.where(at_hold, 0, take_s))
            pbl = pbl.at[t * K + idx].add(jnp.where(at_hold, take_r, 0))
            pbl = pbl.at[T * K + idx].add(jnp.where(at_hold, take_s, 0))
            blocked = blocked.at[bl_idx].set(hol | defer)
            out = (admit_c, admit_p, jnp.where(defer, 0, -1), h_stop,
                   jnp.sum(trav.astype(jnp.int32)),
                   jnp.sum(jnp.where(at_hold, take_s, 0)))
            return (remaining, notify, pbl, blocked), out

        carry = (*carry, jnp.zeros((T * K,), bool))
        (remaining, notify, pbl, _), \
            (adm_c, adm_p, stall, hp_b, trav_b, hs_b) = lax.scan(
                offer, carry, rows)

        def unrot(x, fill, dtype):
            return jnp.full((T * n * n,), fill, dtype).at[rows].set(x)

        fresh_complete = unrot(adm_c, False, bool)
        fresh_park = unrot(adm_p, False, bool)
        park_count = jnp.where(fresh_park, flat, unrot(pc_a, 0, jnp.int32))
        park_hop = jnp.where(fresh_park, unrot(hp_b, 0, jnp.int32),
                             unrot(ph_a, 0, jnp.int32))
        park_age = jnp.where(fresh_park, 1, unrot(age_a, 0, jnp.int32))
        hold_shared = jnp.where(fresh_park, unrot(hs_b, 0, jnp.int32),
                                unrot(hs_a, 0, jnp.int32))
        links_traversed = (unrot(trav_a, 0, jnp.int32)
                           + unrot(trav_b, 0, jnp.int32))
        shape3 = (T, n, n)
        return TenantAdmissionOut(
            fresh_complete=fresh_complete.reshape(shape3),
            fresh_park=fresh_park.reshape(shape3),
            resumed_complete=unrot(res_c, False, bool).reshape(shape3),
            resume_age=unrot(age_res, 0, jnp.int32).reshape(shape3),
            stall_hop=unrot(stall, -1, jnp.int32).reshape(shape3),
            park_count=park_count.reshape(shape3),
            park_hop=park_hop.reshape(shape3),
            park_age=park_age.reshape(shape3),
            hold_shared=hold_shared.reshape(shape3),
            parked_by_link=pbl,
            links_traversed=links_traversed.reshape(shape3),
            spent=state.bank.credits - remaining,
            notify=notify,
            queue_events=queue_events,
            rerouted=jnp.zeros(shape3, jnp.int32),
            links_done=jnp.where(
                fresh_complete.reshape(shape3)
                | unrot(res_c, False, bool).reshape(shape3),
                self._route_len.reshape(n, n)[None], 0).astype(jnp.int32),
            stalled_by_link=self._stall_attr(
                unrot(stall, -1, jnp.int32), flat),
        )

    def _admit_tenants_faulted(self, state: base.FabricState,
                               counts_all: jax.Array,
                               link_down: jax.Array) -> TenantAdmissionOut:
        """Tenant-axis admission under a dead-link mask: the fault rules
        of :meth:`_admit_global_faulted` (per-axis reroute, eviction back
        to hop 0, all-or-nothing detours) with the reserved-first
        spending and split-exact hold refunds of :meth:`_admit_tenants`.
        """
        n, T = self.n_shards, self.n_tenants
        K = n * self.n_links
        H2 = self.max_hops_alt
        flat = counts_all.reshape(-1)
        pc0 = state.parked_count.reshape(-1)
        ph0 = state.parked_hop.reshape(-1)
        pa0 = state.parked_age.reshape(-1)
        hs0 = state.parked_hold_shared.reshape(-1)
        r_all = jnp.arange(T * n * n)
        comb = (r_all // n + state.bank.epoch) % (T * n)
        rows = comb * n + r_all % n
        hop_idx = jnp.arange(H2)
        down = link_down
        pair_of = r_all % (n * n)

        # per-PAIR reroute decision (shared by every tenant: the mask is
        # physical, not per slot)
        seg = self._seg_links
        seg_dirty = (down[jnp.maximum(seg, 0)] & (seg >= 0)).any(-1)
        flip = seg_dirty[:, 0] & ~seg_dirty[:, 1]
        routable_pair = ~(seg_dirty[:, 0] & seg_dirty[:, 1]).any(0)
        combo = jnp.sum(flip.astype(jnp.int32)
                        * (1 << jnp.arange(self.ndim))[:, None], axis=0)
        pair_idx = jnp.arange(n * n)
        seq_eff_pair = self._link_seq_alt[combo, pair_idx]   # (n², H2)
        len_eff_pair = self._route_len_alt[combo, pair_idx]
        detour_pair = combo != 0
        seq0_pair = jnp.pad(self._link_seq,
                            ((0, 0), (0, H2 - self.max_hops)),
                            constant_values=-1)

        # eviction set over the (T, n, n) row tables
        seq0_rows = seq0_pair[pair_of]                       # (Tn², H2)
        rem_dirty = ((seq0_rows >= 0)
                     & (hop_idx[None, :] >= ph0[:, None])
                     & down[jnp.maximum(seq0_rows, 0)]).any(-1)
        held_link = jnp.take_along_axis(
            seq0_rows, jnp.maximum(ph0 - 1, 0)[:, None], axis=1)[:, 0]
        held_dead = (ph0 >= 1) & down[jnp.maximum(held_link, 0)]
        ev_all = (pc0 > 0) & ((ph0 == 0) | rem_dirty | held_dead)

        # congestion snapshot over PHYSICAL links on the actual routes
        pbl_phys = state.parked_by_link.reshape(T + 1, K).sum(0)
        seq_q = jnp.where((pc0 > 0)[:, None], seq0_rows,
                          seq_eff_pair[pair_of])
        start_hop = jnp.where((pc0 > 0) & ~ev_all, ph0, 0)[:, None]
        queue_events = jnp.sum(
            jnp.where((seq_q >= 0) & (hop_idx[None, :] >= start_hop),
                      pbl_phys[jnp.maximum(seq_q, 0)], 0),
            axis=-1).reshape(T, n, n)

        def split_spend(remaining, t, idx, trav, c):
            slot_r = t * K + idx
            slot_s = T * K + idx
            take_r = jnp.where(trav, jnp.minimum(c, remaining[slot_r]), 0)
            take_s = jnp.where(trav, c - take_r, 0)
            remaining = remaining.at[slot_r].add(-take_r)
            remaining = remaining.at[slot_s].add(-take_s)
            return remaining, take_r, take_s

        def resume(carry, r):
            remaining, notify, pbl = carry
            t = r // (n * n)
            pair = r % (n * n)
            c, h, hs = pc0[r], ph0[r], hs0[r]
            active = c > 0
            ev = ev_all[r]
            # branch 1 — undisturbed resume on the default route
            seq = seq0_pair[pair]
            idx = jnp.maximum(seq, 0)
            valid = seq >= 0
            L = self._route_len[pair]
            avail = remaining[t * K + idx] + remaining[T * K + idx]
            short = valid & (hop_idx >= h) & (avail < c)
            h_new = jnp.min(jnp.where(short, hop_idx, H2))
            act1 = active & ~ev
            complete1 = act1 & (h_new >= L)
            h_stop1 = jnp.maximum(jnp.where(complete1, L, h_new), h)
            moved1 = act1 & (h_stop1 > h)
            trav1 = valid & (hop_idx >= h) & (hop_idx < h_stop1) & act1
            remaining, take_r1, take_s1 = split_spend(remaining, t, idx,
                                                      trav1, c)
            at_hold1 = moved1 & ~complete1 & (hop_idx == h_stop1 - 1)
            notify = notify.at[t * K + idx].add(
                jnp.where(at_hold1, 0, take_r1))
            notify = notify.at[T * K + idx].add(
                jnp.where(at_hold1, 0, take_s1))
            pbl = pbl.at[t * K + idx].add(jnp.where(at_hold1, take_r1, 0))
            pbl = pbl.at[T * K + idx].add(jnp.where(at_hold1, take_s1, 0))
            hs_new1 = jnp.sum(jnp.where(at_hold1, take_s1, 0))
            # branch 2 — evicted retry from hop 0 on the detour route
            seq2 = seq_eff_pair[pair]
            idx2 = jnp.maximum(seq2, 0)
            valid2 = seq2 >= 0
            L2 = len_eff_pair[pair]
            act2 = active & ev & routable_pair[pair]
            avail2 = remaining[t * K + idx2] + remaining[T * K + idx2]
            short2 = valid2 & (avail2 < c)
            h_block = jnp.min(jnp.where(short2, hop_idx, H2))
            complete2 = act2 & (h_block >= L2)
            park2 = (act2 & ~detour_pair[pair] & (h_block < L2)
                     & (h_block >= 1))
            h_stop2 = jnp.where(complete2, L2,
                                jnp.where(park2, h_block, 0))
            trav2 = valid2 & (hop_idx < h_stop2)
            remaining, take_r2, take_s2 = split_spend(remaining, t, idx2,
                                                      trav2, c)
            at_hold2 = park2 & (hop_idx == h_stop2 - 1)
            notify = notify.at[t * K + idx2].add(
                jnp.where(at_hold2, 0, take_r2))
            notify = notify.at[T * K + idx2].add(
                jnp.where(at_hold2, 0, take_s2))
            pbl = pbl.at[t * K + idx2].add(jnp.where(at_hold2, take_r2, 0))
            pbl = pbl.at[T * K + idx2].add(jnp.where(at_hold2, take_s2, 0))
            hs_new2 = jnp.sum(jnp.where(at_hold2, take_s2, 0))
            # release the old hold: on normal advance OR on eviction
            oh = jnp.maximum(seq[jnp.maximum(h - 1, 0)], 0)
            release = moved1 | (active & ev & (h >= 1))
            rel_s = jnp.where(release, hs, 0)
            rel_r = jnp.where(release, c, 0) - rel_s
            notify = notify.at[t * K + oh].add(rel_r)
            notify = notify.at[T * K + oh].add(rel_s)
            pbl = pbl.at[t * K + oh].add(-rel_r)
            pbl = pbl.at[T * K + oh].add(-rel_s)
            complete = complete1 | complete2
            keep = active & ~complete
            h_keep = jnp.where(ev, jnp.where(park2, h_block, 0), h_stop1)
            hs_keep = jnp.where(
                ev, jnp.where(park2, hs_new2, 0),
                jnp.where(moved1, hs_new1, hs))
            out = (complete, jnp.where(complete, 0, c),
                   jnp.where(keep, h_keep, 0),
                   jnp.where(complete, pa0[r], 0),
                   jnp.where(keep, pa0[r] + 1, 0),
                   jnp.sum(trav1.astype(jnp.int32))
                   + jnp.sum(trav2.astype(jnp.int32)),
                   jnp.where(keep, hs_keep, 0),
                   jnp.where(complete2 & detour_pair[pair], c, 0),
                   jnp.where(complete1, L, 0) + jnp.where(complete2, L2, 0))
            return (remaining, notify, pbl), out

        S = (T + 1) * K
        carry = (state.bank.credits, jnp.zeros((S,), jnp.int32),
                 state.parked_by_link)
        carry, (res_c, pc_a, ph_a, age_res, age_a, trav_a, hs_a, rer_a,
                done_a) = lax.scan(resume, carry, rows)

        def offer(carry, r):
            remaining, notify, pbl, blocked = carry
            t = r // (n * n)
            pair = r % (n * n)
            c = flat[r]
            seq = seq_eff_pair[pair]
            idx = jnp.maximum(seq, 0)
            valid = seq >= 0
            L = len_eff_pair[pair]
            rt = routable_pair[pair]
            fl = seq[0]
            routed = (fl >= 0) & (c > 0) & rt
            slot_busy = pc0[r] > 0
            bl_idx = t * K + jnp.maximum(fl, 0)
            hol = blocked[bl_idx]
            avail = remaining[t * K + idx] + remaining[T * K + idx]
            short = valid & (avail < c)
            h_block = jnp.min(jnp.where(short, hop_idx, H2))
            ok = routed & ~slot_busy & ~hol
            admit_c = ok & (h_block >= L)
            admit_p = (ok & ~detour_pair[pair] & (h_block < L)
                       & (h_block >= 1))
            defer = ((fl >= 0) & (c > 0)) & ~admit_c & ~admit_p
            h_stop = jnp.where(admit_c, L, jnp.where(admit_p, h_block, 0))
            trav = valid & (hop_idx < h_stop)
            remaining, take_r, take_s = split_spend(remaining, t, idx,
                                                    trav, c)
            at_hold = admit_p & (hop_idx == h_stop - 1)
            notify = notify.at[t * K + idx].add(
                jnp.where(at_hold, 0, take_r))
            notify = notify.at[T * K + idx].add(
                jnp.where(at_hold, 0, take_s))
            pbl = pbl.at[t * K + idx].add(jnp.where(at_hold, take_r, 0))
            pbl = pbl.at[T * K + idx].add(jnp.where(at_hold, take_s, 0))
            # unroutable rows never reach the egress FIFO: no HOL block
            blocked = blocked.at[bl_idx].set(hol | (defer & rt))
            out = (admit_c, admit_p, jnp.where(defer, 0, -1), h_stop,
                   jnp.sum(trav.astype(jnp.int32)),
                   jnp.sum(jnp.where(at_hold, take_s, 0)),
                   jnp.where(admit_c & detour_pair[pair], c, 0),
                   jnp.where(admit_c, L, 0))
            return (remaining, notify, pbl, blocked), out

        carry = (*carry, jnp.zeros((T * K,), bool))
        (remaining, notify, pbl, _), \
            (adm_c, adm_p, stall, hp_b, trav_b, hs_b, rer_b,
             done_b) = lax.scan(offer, carry, rows)

        def unrot(x, fill, dtype):
            return jnp.full((T * n * n,), fill, dtype).at[rows].set(x)

        fresh_complete = unrot(adm_c, False, bool)
        fresh_park = unrot(adm_p, False, bool)
        park_count = jnp.where(fresh_park, flat, unrot(pc_a, 0, jnp.int32))
        park_hop = jnp.where(fresh_park, unrot(hp_b, 0, jnp.int32),
                             unrot(ph_a, 0, jnp.int32))
        park_age = jnp.where(fresh_park, 1, unrot(age_a, 0, jnp.int32))
        hold_shared = jnp.where(fresh_park, unrot(hs_b, 0, jnp.int32),
                                unrot(hs_a, 0, jnp.int32))
        links_traversed = (unrot(trav_a, 0, jnp.int32)
                           + unrot(trav_b, 0, jnp.int32))
        shape3 = (T, n, n)
        return TenantAdmissionOut(
            fresh_complete=fresh_complete.reshape(shape3),
            fresh_park=fresh_park.reshape(shape3),
            resumed_complete=unrot(res_c, False, bool).reshape(shape3),
            resume_age=unrot(age_res, 0, jnp.int32).reshape(shape3),
            stall_hop=unrot(stall, -1, jnp.int32).reshape(shape3),
            park_count=park_count.reshape(shape3),
            park_hop=park_hop.reshape(shape3),
            park_age=park_age.reshape(shape3),
            hold_shared=hold_shared.reshape(shape3),
            parked_by_link=pbl,
            links_traversed=links_traversed.reshape(shape3),
            spent=state.bank.credits - remaining,
            notify=notify,
            queue_events=queue_events,
            rerouted=(unrot(rer_a, 0, jnp.int32)
                      + unrot(rer_b, 0, jnp.int32)).reshape(shape3),
            links_done=(unrot(done_a, 0, jnp.int32)
                        + unrot(done_b, 0, jnp.int32)).reshape(shape3),
            stalled_by_link=self._stall_attr(
                unrot(stall, -1, jnp.int32), flat),
        )

    # -- tenant bundle packing ---------------------------------------------
    def _pack_tenants(self, row_payload: jax.Array,
                      cnt_in: jax.Array) -> jax.Array:
        """(T, n, W) payload + (T, n) counts -> (n, T*(W+1)) bundles:
        per destination row, T count-packed sub-rows side by side."""
        packed = base.pack_payload(row_payload, cnt_in)    # (T, n, W+1)
        n = packed.shape[1]
        return packed.transpose(1, 0, 2).reshape(n, -1)

    def _unpack_tenants(self, buf: jax.Array):
        """Inverse of :meth:`_pack_tenants` -> ((T, n, W), (T, n))."""
        n = buf.shape[0]
        T = self.n_tenants
        packed = buf.reshape(n, T, -1).transpose(1, 0, 2)
        return base.unpack_payload(packed)

    def _tenant_count_cols(self, width: int) -> tuple[int, ...]:
        return tuple(t * (width + 1) + width for t in range(self.n_tenants))

    def _ship_rotation(self, packed_bundles: jax.Array, me, axis_name: str,
                       acc: dict, count_cols: tuple[int, ...], down=None):
        my_c = self._coords_of(me)
        buf = packed_bundles
        for a in range(self.ndim):
            bundles = self._to_phase(buf, a)
            perm_p, perm_m = self._perm[a]
            recv = self._ring_phase(bundles, axis_name, my_c[a],
                                    self.dims[a], perm_p, perm_m, acc,
                                    phase=a, count_cols=count_cols,
                                    fault=self._phase_fault(down, a, me,
                                                            my_c[a]))
            buf = self._from_phase(recv, a)
        return self._unpack_tenants(buf)

    @staticmethod
    def _fresh_acc(ndim: int) -> dict:
        return {"bytes": jnp.int32(0), "owire": jnp.int32(0), "hops": 0,
                "in_flight": jnp.int32(0),
                "in_flight_phase": [jnp.int32(0)] * ndim}

    def _by_hop(self, hop: jax.Array, weight: jax.Array) -> jax.Array:
        """Scatter (T, n) weights into (T, max_hops) hop histograms."""
        T, H = self.n_tenants, self.max_hops
        return jnp.zeros((T, H), jnp.int32).at[
            jnp.arange(T)[:, None], jnp.clip(hop, 0, H - 1)
        ].add(weight)

    def _fabric_level(self, acc: dict):
        """Fabric-wide (non-decomposable) stats attributed to tenant 0 so
        tenant-axis sums stay physical."""
        T = self.n_tenants
        z = jnp.zeros((T,), jnp.int32)
        return (z.at[0].set(acc["hops"]),
                z.at[0].set(acc["bytes"].astype(jnp.int32)),
                z.at[0].set(acc["in_flight"].astype(jnp.int32)),
                jnp.zeros((T, self.ndim), jnp.int32).at[0].set(
                    jnp.stack(acc["in_flight_phase"])))

    # -- the full multi-tenant window --------------------------------------
    def exchange(self, state: base.LinkState, payload: jax.Array,
                 counts: jax.Array, *, axis_name: str,
                 enforce_credits: bool = True) -> base.TransportOut:
        """Ship one window for every tenant: ``payload (T, n, W)``,
        ``counts (T, n)``; every output field has a leading tenant axis."""
        T, n, H = self.n_tenants, self.n_shards, self.max_hops
        me = lax.axis_index(axis_name)
        counts = counts.astype(jnp.int32)
        if payload.shape[:2] != (T, n) or counts.shape != (T, n):
            raise ValueError(
                f"tenant transport wants payload (T={T}, n={n}, W) and "
                f"counts (T, n); got {payload.shape} / {counts.shape}")
        is_local = (jnp.arange(n) == me)[None, :]
        zero_q = jnp.zeros((T, n, n), jnp.float32)
        down = state.link_down
        if down is not None and not enforce_credits:
            raise ValueError("fault injection (FabricState.link_down) "
                             "requires credit flow control; "
                             "enforce_credits=False cannot reroute")

        if enforce_credits:
            if state.parked_payload.shape != payload.shape:
                raise ValueError(
                    f"FabricState payload buffer "
                    f"{state.parked_payload.shape} != offered payload "
                    f"{payload.shape}: initialize with "
                    f"init_state(payload_width=W)")
            counts_all = self._allgather_counts_mt(counts, me, axis_name)
            adm = (self._admit_tenants_faulted(state, counts_all, down)
                   if down is not None
                   else self._admit_tenants(state, counts_all))
            fresh_c = adm.fresh_complete[:, me]          # (T, n)
            fresh_p = adm.fresh_park[:, me]
            resumed = adm.resumed_complete[:, me]
            stall_hop = adm.stall_hop[:, me]
            pc0_me = state.parked_count[:, me]
            ship_fresh = fresh_c | (is_local & (counts > 0))
            cnt_in = (jnp.where(ship_fresh, counts, 0)
                      + jnp.where(resumed, pc0_me, 0))
            row_payload = jnp.where(
                resumed[..., None], state.parked_payload,
                jnp.where(ship_fresh[..., None], payload, jnp.uint32(0)))
            bank = fc.credit_tick(state.bank, adm.spent, notify=adm.notify)
            state = base.FabricState(
                bank=bank,
                parked_count=adm.park_count,
                parked_hop=adm.park_hop,
                parked_age=adm.park_age,
                parked_by_link=adm.parked_by_link,
                parked_payload=jnp.where(fresh_p[..., None], payload,
                                         state.parked_payload),
                parked_hold_shared=adm.hold_shared,
            )
            sent_mask = fresh_c | fresh_p | is_local | (counts == 0)
            sent_now = fresh_c | is_local | (counts == 0)
            queue_us = wire_latency.queueing_latency_us(
                self.wire_fmt, adm.queue_events)
            park_wait_us = wire_latency.queueing_latency_us(
                self.wire_fmt, adm.resume_age * self.link_credits)
        else:
            fresh_p = resumed = jnp.zeros((T, n), bool)
            pc0_me = jnp.zeros((T, n), jnp.int32)
            stall_hop = jnp.full((T, n), -1, jnp.int32)
            cnt_in = counts
            row_payload = payload
            state = state._replace(
                bank=fc.credit_tick(state.bank,
                                    jnp.zeros_like(state.bank.credits)),
                link_down=None)
            sent_mask = sent_now = jnp.ones((T, n), bool)
            queue_us = park_wait_us = zero_q

        acc = self._fresh_acc(self.ndim)
        w = payload.shape[-1]
        recv_payload, recv_counts = self._ship_rotation(
            self._pack_tenants(row_payload, cnt_in), me, axis_name, acc,
            self._tenant_count_cols(w), down=down)

        stalled_by_hop = self._by_hop(
            stall_hop, jnp.where(stall_hop >= 0, counts, 0))
        offered = jnp.sum(counts, axis=-1)
        if enforce_credits:
            sent = jnp.sum(jnp.where(sent_now, counts, 0), axis=-1)
            parked = jnp.sum(jnp.where(fresh_p, counts, 0), axis=-1)
            unparked = jnp.sum(jnp.where(resumed, pc0_me, 0), axis=-1)
            pk_cnt, pk_hop = state.parked_count[:, me], state.parked_hop[:, me]
            parked_by_hop = self._by_hop(pk_hop, pk_cnt)
            c_row = jnp.where(resumed, pc0_me, counts)
            owire = jnp.sum(
                wire_framing.frame_bytes(self.wire_fmt, c_row)
                * adm.links_traversed[:, me], axis=-1).astype(jnp.int32)
            dwell = jnp.sum(jnp.where(
                fresh_c | resumed,
                queue_us[:, me] + park_wait_us[:, me], 0.0),
                axis=-1).astype(jnp.float32)
            in_fabric = jnp.sum(pk_cnt, axis=-1).astype(jnp.int32)
            rerouted = jnp.sum(adm.rerouted[:, me], axis=-1).astype(
                jnp.int32)
        else:
            sent = jnp.sum(cnt_in, axis=-1)
            parked = unparked = jnp.zeros((T,), jnp.int32)
            parked_by_hop = jnp.zeros((T, H), jnp.int32)
            owire = jnp.zeros((T,), jnp.int32).at[0].set(
                acc["owire"].astype(jnp.int32))
            dwell = jnp.zeros((T,), jnp.float32)
            in_fabric = (jnp.sum(state.parked_count[:, me], axis=-1)
                         .astype(jnp.int32) if state.parked_count.size
                         else jnp.zeros((T,), jnp.int32))
            rerouted = jnp.zeros((T,), jnp.int32)
        hops_f, bytes_f, inflight_f, inflight_ph = self._fabric_level(acc)
        stats = base.LinkStats(
            offered_events=offered.astype(jnp.int32),
            sent_events=sent.astype(jnp.int32),
            deferred_events=(offered - sent - parked).astype(jnp.int32),
            delivered_events=jnp.sum(recv_counts, axis=-1).astype(jnp.int32),
            credit_stalls=jnp.sum(stall_hop >= 0, axis=-1).astype(jnp.int32),
            hops=hops_f,
            forwarded_bytes=bytes_f,
            bytes_on_wire=owire,
            max_in_flight=inflight_f,
            stalled_by_hop=stalled_by_hop,
            max_in_flight_by_phase=inflight_ph,
            parked_events=parked.astype(jnp.int32),
            unparked_events=unparked.astype(jnp.int32),
            in_fabric_events=in_fabric,
            parked_by_hop=parked_by_hop,
            queue_dwell_us=dwell,
            rerouted=rerouted,
            stalled_by_link=(adm.stalled_by_link if enforce_credits
                             else None),
        )
        return base.TransportOut(
            state=state,
            recv_payload=recv_payload,
            recv_counts=recv_counts,
            sent_mask=sent_mask,
            stats=stats,
            sent_now=sent_now,
            queue_us=queue_us,
            unparked_now=jnp.where(resumed, pc0_me, 0),
            park_wait_us=park_wait_us,
            links_used=adm.links_done if down is not None else None,
        )

    # -- end-of-run fabric walk --------------------------------------------
    def drain_fabric(self, state: base.LinkState, *, axis_name: str,
                     payload_width: int | None = None) -> base.TransportOut:
        """Tenant-axis fabric walk: every parked row of every tenant
        resumes from its blocked hop and completes, all held credits
        (reserved AND shared) release into their slots' delay lines —
        per-slot conservation ``credits + pending == slot_limit`` is
        restored and the returned tables are empty."""
        T, n, H = self.n_tenants, self.n_shards, self.max_hops
        me = lax.axis_index(axis_name)
        pc_me = state.parked_count[:, me]                 # (T, n)
        ph_me = state.parked_hop[:, me]
        row_payload = jnp.where((pc_me > 0)[..., None],
                                state.parked_payload, jnp.uint32(0))

        acc = self._fresh_acc(self.ndim)
        w = state.parked_payload.shape[-1]
        recv_payload, recv_counts = self._ship_rotation(
            self._pack_tenants(row_payload, pc_me), me, axis_name, acc,
            self._tenant_count_cols(w))

        bank = fc.credit_tick(state.bank,
                              jnp.zeros_like(state.bank.credits),
                              notify=state.parked_by_link)
        new_state = base.FabricState(
            bank=bank,
            parked_count=jnp.zeros_like(state.parked_count),
            parked_hop=jnp.zeros_like(state.parked_hop),
            parked_age=jnp.zeros_like(state.parked_age),
            parked_by_link=jnp.zeros_like(state.parked_by_link),
            parked_payload=jnp.zeros_like(state.parked_payload),
            parked_hold_shared=jnp.zeros_like(state.parked_hold_shared),
        )
        remaining_links = jnp.maximum(
            self._hops_matrix[me][None, :] - ph_me, 0)
        owire = jnp.sum(
            wire_framing.frame_bytes(self.wire_fmt, pc_me)
            * jnp.where(pc_me > 0, remaining_links, 0),
            axis=-1).astype(jnp.int32)
        hops_f, bytes_f, inflight_f, inflight_ph = self._fabric_level(acc)
        z = jnp.zeros((T,), jnp.int32)
        stats = base.LinkStats(
            offered_events=z, sent_events=z, deferred_events=z,
            delivered_events=jnp.sum(recv_counts, axis=-1).astype(jnp.int32),
            credit_stalls=z,
            hops=hops_f, forwarded_bytes=bytes_f, bytes_on_wire=owire,
            max_in_flight=inflight_f,
            stalled_by_hop=jnp.zeros((T, H), jnp.int32),
            max_in_flight_by_phase=inflight_ph,
            parked_events=z,
            unparked_events=jnp.sum(pc_me, axis=-1).astype(jnp.int32),
            in_fabric_events=z,
            parked_by_hop=jnp.zeros((T, H), jnp.int32),
            queue_dwell_us=jnp.zeros((T,), jnp.float32),
            rerouted=z,
        )
        return base.TransportOut(
            state=new_state,
            recv_payload=recv_payload,
            recv_counts=recv_counts,
            sent_mask=jnp.ones((T, n), bool),
            stats=stats,
            sent_now=jnp.ones((T, n), bool),
            queue_us=jnp.zeros((T, n, n), jnp.float32),
            unparked_now=pc_me,
            park_wait_us=jnp.zeros((T, n, n), jnp.float32),
        )
