"""Torus-faithful transport: dimension-ordered neighbor hops with credit
flow control (paper §1 + §2.1, applied to the jitted hot path).

The Extoll fabric is a torus with dimension-ordered routing — a packet
first walks its X ring to the destination column, then the Y ring to the
destination row, taking the shortest signed direction on each ring (the
same walk ``repro.core.torus.Torus.route`` enumerates on the host).  This
backend reproduces that on a device mesh: the ``n_shards`` shards of the
1-D shard_map axis are laid onto a 2-D (nx, ny) logical torus
(``shard s -> (x = s % nx, y = s // nx)``, matching ``Torus.coords``) and
each flush window travels exclusively via ``jax.lax.ppermute`` *neighbor*
hops — the lowered HLO contains only collective-permutes, never an
all-to-all.

Per ring phase the algorithm is a bidirectional store-and-forward rotate:
every node seeds two in-transit buffers (one per ring direction) indexed by
absolute target coordinate, each hop ships the whole buffer one neighbor
over, the arriving node absorbs the bundle addressed to it and forwards the
rest.  After ``floor(n/2)`` forward and ``floor((n-1)/2)`` backward hops
every bundle has been delivered via its shortest path, so hop counts equal
``Torus.hops`` and per-window wire bytes decompose into per-link terms —
the quantities ``core.torus.link_loads`` models on the host become
measurable (``LinkStats``) in the jitted path.

Flow control is the credit discipline of ``repro.core.flow_control``,
vectorized over the node's four egress links (+x, -x, +y, -y) as a
``CreditBank``: admitting a bucket row spends its event count on the
first-hop link of its dimension-ordered route, and spent credits only
return ``notify_latency`` windows later (the notification delay line).
Rows that do not get credits are *deferred* — reported through
``sent_mask`` so the caller re-offers them via the overflow-residue
machinery instead of buffering unbounded data in the fabric.  Downstream
links are modelled as provisioned store-and-forward buffers whose
occupancy is reported as ``max_in_flight``.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import aggregator
from repro.core import flow_control as fc
from repro.transport import base

# egress link indices
XP, XM, YP, YM = 0, 1, 2, 3
N_LINKS = 4


def default_shape(n_shards: int) -> tuple[int, int]:
    """Most-square (nx, ny) factorization with nx <= ny (8 -> (2, 4),
    matching the paper's 2x4 concentrator face per wafer)."""
    nx = max(int(math.isqrt(n_shards)), 1)
    while n_shards % nx:
        nx -= 1
    return nx, n_shards // nx


def _ring_perm(nx: int, ny: int, axis: str, step: int):
    """(src, dst) pairs moving every shard one step along its X or Y ring."""
    pairs = []
    for s in range(nx * ny):
        x, y = s % nx, s // nx
        if axis == "x":
            d = ((x + step) % nx) + y * nx
        else:
            d = x + ((y + step) % ny) * nx
        pairs.append((s, d))
    return pairs


class Torus2DTransport(base.Transport):
    """Dimension-ordered 2-D torus exchange with per-link credits.

    nx * ny must equal ``n_shards``.  ``link_credits=0`` disables
    throttling (links are provisioned far beyond any window's traffic);
    a positive value is the per-window event budget of each egress link,
    replenished ``notify_latency`` windows after being spent.  Credits
    never exceed their initial limit, so ``link_credits`` must stay at or
    above the largest possible bucket row — a bigger row could never be
    admitted and would head-of-line-block its link forever.  Callers that
    know their row bound pass it as ``max_row_events`` (the bucket
    capacity; ``make_exchange`` and the simulator do) and construction
    fails fast on a livelock-able configuration.
    """

    name = "torus2d"

    def __init__(self, n_shards: int, *, nx: int = 0, ny: int = 0,
                 link_credits: int = 0, notify_latency: int = 2,
                 max_row_events: int = 0):
        super().__init__(n_shards)
        if 0 < link_credits < max_row_events:
            raise ValueError(
                f"link_credits ({link_credits}) must be >= the largest "
                f"bucket row ({max_row_events} events): credits never "
                f"exceed their initial limit, so an oversized row would "
                f"head-of-line-block its egress link forever")
        if not nx and not ny:
            nx, ny = default_shape(n_shards)
        elif not ny:
            ny = n_shards // nx
        elif not nx:
            nx = n_shards // ny
        if nx * ny != n_shards:
            raise ValueError(f"mesh ({nx}, {ny}) != n_shards {n_shards}")
        self.nx, self.ny = nx, ny
        self.link_credits = int(link_credits)
        self.notify_latency = int(notify_latency)
        self._perm = {
            "xp": _ring_perm(nx, ny, "x", +1),
            "xm": _ring_perm(nx, ny, "x", -1),
            "yp": _ring_perm(nx, ny, "y", +1),
            "ym": _ring_perm(nx, ny, "y", -1),
        }

    # -- flow-control state ----------------------------------------------
    def init_state(self) -> base.LinkState:
        limit = self.link_credits if self.link_credits > 0 else 1 << 30
        return fc.init_credits(N_LINKS, limit, self.notify_latency)

    def _first_hop_link(self, my_x, my_y):
        """Egress link of each destination row's dimension-ordered route
        (-1 for the local row)."""
        d = jnp.arange(self.n_shards)
        fx = (d % self.nx - my_x) % self.nx
        fy = (d // self.nx - my_y) % self.ny
        lx = jnp.where(fx == 0, -1, jnp.where(fx <= self.nx // 2, XP, XM))
        ly = jnp.where(fy == 0, -1, jnp.where(fy <= self.ny // 2, YP, YM))
        return jnp.where(lx >= 0, lx, ly)

    def _admit(self, state, counts, link):
        """In-order (FIFO) whole-bucket admission per egress link.

        Rows are admitted in destination order while the link's running
        total stays within its credits; a row that does not fit blocks
        every later row on the same link (head-of-line blocking — a
        hardware link FIFO cannot reorder its queue), even if a smaller
        row would still fit the remaining credits.
        """
        admitted = jnp.ones_like(link, dtype=bool)
        spent = []
        for l in range(N_LINKS):
            on = link == l
            csum = jnp.cumsum(jnp.where(on, counts, 0))
            ok = csum <= state.credits[l]
            admitted = jnp.where(on, ok, admitted)
            spent.append(jnp.sum(jnp.where(on & ok, counts, 0)))
        return admitted, jnp.stack(spent).astype(jnp.int32)

    # -- one bidirectional ring phase -------------------------------------
    def _ring_phase(self, bundles, axis_name, my_c, n, perm_p, perm_m,
                    acc: dict):
        """Rotate (n, B, W1) count-packed bundles (indexed by target ring
        coordinate) to their owners; returns them indexed by *source* ring
        coordinate.  ``acc`` accumulates LinkStats terms across phases."""
        coord = jnp.arange(n)
        fwd = (coord - my_c) % n
        plus = (fwd >= 1) & (fwd <= n // 2)
        minus = fwd > n // 2
        vp = jnp.where(plus[:, None, None], bundles, jnp.uint32(0))
        vm = jnp.where(minus[:, None, None], bundles, jnp.uint32(0))
        recv = jnp.zeros_like(bundles)
        recv = recv.at[my_c].set(jnp.take(bundles, my_c, axis=0))

        def live_events(v):
            return jnp.sum(lax.bitcast_convert_type(v[:, :, -1], jnp.int32))

        def wire(v):
            cnt = lax.bitcast_convert_type(v[:, :, -1], jnp.int32)
            return aggregator.window_cost(cnt.reshape(-1)).bytes

        for direction, v, perm, n_hops in (
            ("+", vp, perm_p, n // 2),
            ("-", vm, perm_m, (n - 1) // 2),
        ):
            for h in range(1, n_hops + 1):
                acc["bytes"] += wire(v)
                v = lax.ppermute(v, axis_name, perm)
                src = (my_c - h) % n if direction == "+" else (my_c + h) % n
                recv = recv.at[src].set(jnp.take(v, my_c, axis=0))
                v = v.at[my_c].set(jnp.uint32(0))
                acc["hops"] += 1
                acc["in_flight"] = jnp.maximum(acc["in_flight"],
                                               live_events(v))
        # everything within shortest distance has been absorbed
        return recv

    # -- the full window ---------------------------------------------------
    def exchange(self, state: base.LinkState, payload: jax.Array,
                 counts: jax.Array, *, axis_name: str,
                 enforce_credits: bool = True) -> base.TransportOut:
        nx, ny, n = self.nx, self.ny, self.n_shards
        w = payload.shape[1]
        me = lax.axis_index(axis_name)
        my_x, my_y = me % nx, me // nx
        counts = counts.astype(jnp.int32)

        # 1. injection: credit admission on the first-hop egress link
        link = self._first_hop_link(my_x, my_y)
        if enforce_credits:
            admitted, spent = self._admit(state, counts, link)
        else:
            admitted = jnp.ones((n,), bool)
            spent = jnp.zeros((N_LINKS,), jnp.int32)
        state = fc.credit_tick(state, spent)
        cnt_in = jnp.where(admitted, counts, 0)
        packed = base.pack_payload(
            jnp.where(admitted[:, None], payload, jnp.uint32(0)), cnt_in)

        acc = {"bytes": jnp.int32(0), "hops": 0,
               "in_flight": jnp.int32(0)}

        # 2. X rings: bundle rows by destination column, rotate along x
        bx = packed.reshape(ny, nx, w + 1).transpose(1, 0, 2)   # [dx, dy]
        xrecv = self._ring_phase(bx, axis_name, my_x, nx,
                                 self._perm["xp"], self._perm["xm"], acc)
        # xrecv[sx, dy]: from source (sx, my_y), for destination (my_x, dy)

        # 3. Y rings: regroup by destination row, rotate along y
        by = xrecv.transpose(1, 0, 2)                           # [dy, sx]
        yrecv = self._ring_phase(by, axis_name, my_y, ny,
                                 self._perm["yp"], self._perm["ym"], acc)
        # yrecv[sy, sx]: from source (sx, sy), for me

        recv_payload, recv_counts = base.unpack_payload(
            yrecv.reshape(n, w + 1))

        offered = jnp.sum(counts).astype(jnp.int32)
        sent = jnp.sum(cnt_in).astype(jnp.int32)
        stats = base.LinkStats(
            offered_events=offered,
            sent_events=sent,
            deferred_events=offered - sent,
            delivered_events=jnp.sum(recv_counts).astype(jnp.int32),
            credit_stalls=jnp.sum(~admitted & (counts > 0)).astype(jnp.int32),
            hops=jnp.int32(acc["hops"]),
            forwarded_bytes=acc["bytes"].astype(jnp.int32),
            max_in_flight=acc["in_flight"].astype(jnp.int32),
        )
        return base.TransportOut(
            state=state,
            recv_payload=recv_payload,
            recv_counts=recv_counts,
            sent_mask=admitted,
            stats=stats,
        )
