"""Pluggable flush-window transports for the spike-exchange fabric.

``create("alltoall" | "torus2d" | "torus3d", n_shards=..., **opts)``
returns a :class:`~repro.transport.base.Transport`; see ``base`` for the
contract, ``alltoall`` for the packed single-collective backend and
``torus`` for the dimension-ordered neighbor-hop backends with hop-by-hop
credit-based link flow control (``torus3d`` adds the wafer Z axis).
"""
from __future__ import annotations

from repro.transport.base import (FabricState, LinkState, LinkStats,
                                  Transport, TransportOut,
                                  init_fabric_state, zero_link_stats)

BACKENDS = ("alltoall", "torus2d", "torus3d")


def create(name: str, *, n_shards: int, **opts) -> Transport:
    """Instantiate a transport backend by config key.

    Options (all backends): ``wire_format`` — a
    :class:`~repro.wire.framing.WireFormat` or profile name
    (``"extoll"`` default, ``"ethernet"``) governing the frame-level
    ``bytes_on_wire`` accounting and the wire-latency charges.
    Options (torus2d / torus3d): ``nx``/``ny``[/``nz``] mesh shape (0 =
    most-square / most-cubic factorization), ``link_credits`` per-window
    event budget of EVERY directed egress link in the fabric (0 =
    unthrottled; admission spends on each hop of the dimension-ordered
    route), ``notify_latency`` windows before spent credits return,
    ``max_row_events`` largest bucket row the caller can offer (fails
    fast if ``link_credits`` could never admit one).
    """
    if name == "alltoall":
        from repro.transport.alltoall import AllToAllTransport
        extra = set(opts) - {"wire_format"}
        if extra:
            raise TypeError(f"alltoall takes no options beyond wire_format, "
                            f"got {sorted(extra)}")
        return AllToAllTransport(n_shards, **opts)
    if name == "torus2d":
        from repro.transport.torus import Torus2DTransport
        return Torus2DTransport(n_shards, **opts)
    if name == "torus3d":
        from repro.transport.torus import Torus3DTransport
        return Torus3DTransport(n_shards, **opts)
    raise ValueError(f"unknown transport {name!r} (want one of {BACKENDS})")
