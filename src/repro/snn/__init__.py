"""Spiking-network substrate: the paper's workload (wafer-scale LIF
networks, Potjans-Diesmann cortical microcircuit) running over the
bucket-exchange fabric."""
from repro.snn import lif, microcircuit, network, simulator  # noqa: F401
