"""Windowed multi-shard SNN simulation over the bucket-exchange fabric.

The simulation advances in *flush windows* of ``window`` dt-steps, with
``window <= min axonal delay`` so every spike generated inside a window can
still reach its destination before its timestamp deadline — this is exactly
the deadline-flush condition of the paper's buckets, applied at the system
level (the same trick NEST/SpiNNaker use: communicate every min-delay).

The window loop is a **software-pipelined ``lax.scan``**: the carry holds,
besides the neuron/ring state, the *pending* aggregated buckets of the
previous window, a double-buffered overflow **residue**, and the transport
backend's link flow-control state.  Iteration k:

  1. exchange+decode window k-1's pending buckets through the configured
     transport (``cfg.transport``: ``"alltoall"`` ships ONE packed
     collective per window; ``"torus2d"`` / ``"torus3d"`` walk
     dimension-ordered neighbor ``ppermute`` hops over a 2-D / 3-D device
     torus under hop-by-hop credit-based link flow control — see
     ``repro.transport``) and scatter their weighted input
     into the delay ring; this happens at the same systemtime as the
     unpipelined formulation (the start of window k == the end of window
     k-1), so deadline semantics are unchanged.  Bucket rows refused at
     their source egress link are *deferred*: their events re-enter this
     window's aggregation ahead of everything else.  Rows refused at a
     TRANSIT link park in the fabric's transit buffers (``FabricState``)
     and resume from their current hop in a later window — the fabric,
     not the caller, keeps custody of their wire words,
  2. ``lax.scan`` the LIF dynamics ``window`` steps off the ring,
  3. compact spikes into packed events, append the transport-deferred
     events and the residue deferred from window k-1 (the FPGA's
     back-pressure on the HICANN links), and run the fused route+aggregate
     kernel (``repro.kernels.fused_route_bucket``); the new buckets +
     residue become the pending half of the carry.

Because stage 3 of window k is data-independent of stage 1's collective
result, the route/aggregate of window k can overlap the decode of window
k-1 on hardware with async collectives.  After the scan, one drain step
flushes the final window's buckets.

Conservation (no spike lost, none applied at the wrong step) is asserted in
tests against a monolithic single-device reference simulation; the residue
chain is externally checkable from ``WindowStats`` (see the identity in
``tests``: sum(offered) - re-offered == sum(sent) + final deferred +
dropped).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import transport as tp
from repro import wire
from repro.core import aggregator, events as ev
from repro.fabric import faults as fabric_faults
from repro.obs import recorder as obs_recorder
from repro.core.routing import RoutingTables
from repro.snn import lif, network


class SimConfig(NamedTuple):
    n_shards: int
    per_shard: int            # neurons per shard
    max_fan: int              # max destination shards per source
    window: int = 8           # dt steps per flush window (<= min delay)
    ring_len: int = 32        # delay ring slots (> max delay + window)
    e_max: int = 512          # spike-compaction buffer per window
    capacity: int = 256       # bucket capacity (events per dest per window)
    params: lif.LIFParams = lif.LIFParams()
    residue: int = 256        # deferred-event carry buffer (re-offered)
    transport: str = "alltoall"   # flush-window backend (see repro.transport)
    torus_nx: int = 0         # torus mesh shape (0 = auto-factorize)
    torus_ny: int = 0
    torus_nz: int = 0         # wafer (Z) axis — torus3d only
    link_credits: int = 0     # per-window events per egress link (0 = off;
                              #   spent on EVERY hop of a row's route)
    notify_latency: int = 2   # windows before spent link credits return
    wire_format: str = "extoll"   # frame/latency profile (repro.wire:
                              #   "extoll" | "ethernet") for bytes_on_wire
                              #   and the per-event latency model
    step_us: float = 0.1      # wall-clock per dt step on the accelerated
                              #   substrate (BrainScaleS ~1000x: 0.1 ms
                              #   biological -> 0.1 us hardware); converts
                              #   window-quantized waiting into the wire
                              #   latency unit


class ShardState(NamedTuple):
    neuron: lif.LIFState      # per-shard neurons
    ring_exc: jax.Array       # (ring_len, per) scheduled exc current
    ring_inh: jax.Array       # (ring_len, per) scheduled inh current
    t: jax.Array              # () i32 global step
    key: jax.Array            # PRNG for background drive


class PendingWindow(NamedTuple):
    """The pipelined half of the scan carry: window k's aggregated buckets,
    exchanged+decoded at the start of iteration k+1, plus the deferred
    events re-offered into window k+1's aggregation.

    ``meta``/``residue_meta`` carry each event's *injection systemtime
    step* alongside it — through the buckets, the 64-bit wire words of
    the exchange, transport deferral and residue re-offers — so the
    decode side can charge exact waiting time (``WindowStats.latency``).
    """

    data: jax.Array           # (n_shards, capacity) u32 bucketed events
    meta: jax.Array           # (n_shards, capacity) i32 injection steps
    counts: jax.Array         # (n_shards,) i32 accepted per destination
    residue: jax.Array        # (residue,) u32 deferred events (INVALID pad)
    residue_meta: jax.Array   # (residue,) i32 their injection steps


class WindowStats(NamedTuple):
    spikes: jax.Array         # () i32 local spikes this window
    events_sent: jax.Array    # () i32 events shipped (incl. replicas)
    overflow: jax.Array       # () i32 events dropped (compaction + residue)
    wire_bytes: jax.Array     # () i32 Extoll bytes of THIS window's fresh
                              # buckets, single-shipment crossbar model
                              # (re-offered deferrals count again; for the
                              # torus per-hop wire model of what actually
                              # crossed links, read link.forwarded_bytes)
    deadline_miss: jax.Array  # () i32 events landing past their deadline;
                              # NOTE pipelining shifts attribution: row k
                              # counts the decode of window k-1's buckets
                              # (row 0 is always 0, the final window's
                              # misses land on the last row via the drain).
                              # Totals over a run are exact.
    offered: jax.Array        # () i32 routed events offered (incl. re-offers)
    deferred: jax.Array       # () i32 events carried to the next window
    link: tp.LinkStats        # transport-level stats for the exchange run
                              # at the START of this iteration (window k-1's
                              # buckets; same one-row shift as deadline_miss;
                              # its deferred_events re-enter THIS row's
                              # `offered`)
    latency: wire.LatencySummary  # per-event wire latency of the events
                              # DELIVERED by that same exchange (window
                              # k-1's buckets; row 0 is zero, the drain's
                              # deliveries are discarded like `link`):
                              # window-quantized waiting since each event's
                              # injection step (deferral/residue rounds
                              # accumulate) + per traversed link one switch
                              # latency + one frame-train serialization of
                              # the row (repro.wire.latency)


def _simulate_steps(state: ShardState, cfg: SimConfig, bg_rate: jax.Array,
                    bg_w: float):
    """Run `window` LIF steps off the delay ring; returns spikes (w, per)."""

    def step(carry, _):
        st = carry
        slot = st.t % cfg.ring_len
        key, sub = jax.random.split(st.key)
        exc_in = st.ring_exc[slot] + lif.poisson_input(
            sub, cfg.per_shard, bg_rate, bg_w, cfg.params.dt)
        inh_in = st.ring_inh[slot]
        neuron, spk = lif.step(st.neuron, cfg.params, exc_in, inh_in)
        # clear the consumed slot so the ring can be reused
        ring_exc = st.ring_exc.at[slot].set(0.0)
        ring_inh = st.ring_inh.at[slot].set(0.0)
        st = ShardState(neuron, ring_exc, ring_inh, st.t + 1, key)
        return st, spk

    state, spikes = jax.lax.scan(step, state, None, length=cfg.window)
    return state, spikes


def _spikes_to_events(spikes: jax.Array, t0: jax.Array, delays: jax.Array,
                      cfg: SimConfig):
    """Compact (window, per) spike raster into <= e_max packed event words.

    Each spike yields `max_fan` replica events (addr = id*fan + k); invalid
    replicas are dropped by the routing LUT (NO_ROUTE).  Also returns each
    replica's absolute injection step (``t0 + step``, un-wrapped i32) — the
    meta value the wire layer threads to the decode side for the latency
    model.
    """
    w, per = spikes.shape
    flat = spikes.reshape(-1)                                 # (w*per,)
    step_of = jnp.repeat(jnp.arange(w), per)
    id_of = jnp.tile(jnp.arange(per), w)
    # stable compaction: spiking slots first, original order preserved
    order = jnp.argsort(~flat, stable=True)[: cfg.e_max]
    sel = flat[order]
    sel_step = step_of[order]
    sel_id = id_of[order]
    lost = jnp.maximum(jnp.sum(flat) - cfg.e_max, 0)
    ts = (t0 + sel_step + delays[sel_id]) & ev.TS_MASK
    # replicate per fan slot
    k = jnp.arange(cfg.max_fan)
    addr = (sel_id[:, None] * cfg.max_fan + k[None, :]).reshape(-1)
    words = ev.pack(addr, jnp.repeat(ts, cfg.max_fan),
                    valid=jnp.repeat(sel, cfg.max_fan))
    inject = jnp.repeat((t0 + sel_step).astype(jnp.int32), cfg.max_fan)
    return words, inject, lost.astype(jnp.int32)


def _apply_events(state: ShardState, words: jax.Array, counts: jax.Array,
                  w_cols_exc: jax.Array, w_cols_inh: jax.Array,
                  cfg: SimConfig, src_shard: jax.Array):
    """Scatter weighted input of received events into the delay ring.

    words: (n_shards, C) events from each source shard; counts (n_shards,).
    w_cols_*: (per, n_total) local weight rows split by source sign.
    Returns (state, deadline_misses).
    """
    S, C = words.shape
    slot_idx = jnp.arange(C)[None, :]
    live = slot_idx < counts[:, None]
    addr = ev.address(words).astype(jnp.int32)
    ts = ev.timestamp(words).astype(jnp.int32)
    src_local = addr // cfg.max_fan
    src_global = src_shard[:, None] * cfg.per_shard + src_local   # (S, C)
    # deadline check: event must land at ts >= current time
    slack = ev.ts_slack(ts, state.t & ev.TS_MASK)
    miss = jnp.sum(jnp.where(live & (slack < 0), 1, 0))
    slot = (state.t + jnp.maximum(slack, 0)) % cfg.ring_len        # (S, C)

    flat_live = live.reshape(-1)
    flat_src = jnp.where(flat_live, src_global.reshape(-1), 0)
    flat_slot = slot.reshape(-1)
    # one-hot over ring slots x gathered weight columns
    exc_cols = w_cols_exc[:, flat_src] * flat_live[None, :]       # (per, S*C)
    inh_cols = w_cols_inh[:, flat_src] * flat_live[None, :]
    onehot = jax.nn.one_hot(flat_slot, cfg.ring_len, dtype=exc_cols.dtype)
    ring_exc = state.ring_exc + jnp.einsum("el,pe->lp", onehot, exc_cols)
    ring_inh = state.ring_inh + jnp.einsum("el,pe->lp", onehot, inh_cols)
    return state._replace(ring_exc=ring_exc, ring_inh=ring_inh), miss


def make_pipeline_fns(cfg: SimConfig, *, axis_name: str | None,
                      fault_schedule: fabric_faults.FaultSchedule | None
                      = None, recorder=None):
    """Build the pipelined per-window machinery (axis_name=None -> single
    shard, no collective).

    ``fault_schedule`` (torus + credits only) injects link/node failures:
    each window's exchange runs with that window's dead-link mask stamped
    onto the fabric state (``FabricState.link_down``), so the transport
    reroutes around failures and the latency model charges each delivered
    event its ACTUAL traversed links (detours included) instead of the
    static shortest-route hop count — see ``docs/architecture.md``.

    ``recorder`` (a ``repro.obs.RecorderConfig``) enables the device-side
    flight recorder: the scan carry gains a ``TelemetryRing`` 4th element
    and each window appends its window index, LinkStats deltas, credit /
    parked_by_link occupancy and latency-histogram delta.  Credited torus
    backends are additionally built with ``stall_attribution=True`` so
    the ring's per-link congestion lane is populated.  ``None`` (the
    default) compiles the EXACT pre-observability program — carry pytree
    and HLO are pinned bit-identical by ``tests/test_obs.py``.

    Returns ``(init_pending, init_link, body, drain, init_ring)``:
      init_pending()          -> empty PendingWindow carry half
      init_link()             -> transport flow-control state carry half
      body((state, pending, link[, ring]), ...)
                              -> ((state, pending', link'[, ring']),
                                  WindowStats)
      drain(state, pending, link, ...)  -> (state, deadline_misses) flushing
                                            the final window's buckets after
                                            the scan (credits bypassed: the
                                            fabric quiesces).
      init_ring               -> empty TelemetryRing carry element, or
                                 None when the recorder is disabled
    """
    if axis_name is not None:
        opts = {"wire_format": cfg.wire_format}
        if cfg.transport in ("torus2d", "torus3d"):
            opts.update(nx=cfg.torus_nx, ny=cfg.torus_ny,
                        link_credits=cfg.link_credits,
                        notify_latency=cfg.notify_latency,
                        max_row_events=cfg.capacity)  # livelock guard
            if cfg.transport == "torus3d":
                opts["nz"] = cfg.torus_nz
            if recorder is not None and cfg.link_credits > 0:
                opts["stall_attribution"] = True
        backend = tp.create(cfg.transport, n_shards=cfg.n_shards, **opts)
    else:
        backend = tp.Transport(cfg.n_shards, wire_format=cfg.wire_format)
        # state-only stub (no collective; crossbar route_hops)
    # can the transport ever refuse a bucket?  (static: gates the
    # deferred-word re-offer plumbing out of the alltoall/uncredited path)
    can_defer = (axis_name is not None
                 and cfg.transport in ("torus2d", "torus3d")
                 and cfg.link_credits > 0)
    if fault_schedule is not None and not can_defer:
        raise ValueError(
            "fault injection needs a credit-throttled torus transport "
            "(transport='torus2d'/'torus3d' with link_credits > 0): an "
            "uncredited fabric has no admission point to reroute at")

    def init_pending() -> PendingWindow:
        return PendingWindow(
            data=jnp.zeros((cfg.n_shards, cfg.capacity), jnp.uint32),
            meta=jnp.zeros((cfg.n_shards, cfg.capacity), jnp.int32),
            counts=jnp.zeros((cfg.n_shards,), jnp.int32),
            residue=jnp.full((cfg.residue,), ev.INVALID_EVENT),
            residue_meta=jnp.zeros((cfg.residue,), jnp.int32),
        )

    def init_link() -> tp.LinkState:
        # the wire payload is lane-planar 64-bit words: 2 u32 per bucket
        # slot (repro.wire.codec) — the width the in-fabric transit
        # buffers must hold to keep custody of a parked row
        return backend.init_state(2 * cfg.capacity)

    def _exchange(pend: PendingWindow, lstate: tp.LinkState, *,
                  enforce_credits: bool):
        """Ship window k-1's buckets through the transport backend.

        Each (event, injection-step) pair travels as one 64-bit wire word
        (``repro.wire.codec``), lane-planar in the u32 payload.  The last
        tuple element is the queueing-dwell column of the rows delivered
        to this shard (the congestion term of the latency model).
        """
        if axis_name is None:
            full = jnp.ones((cfg.n_shards,), bool)
            return (pend.data, pend.meta, pend.counts, full,
                    tp.zero_link_stats(), lstate,
                    jnp.zeros((cfg.n_shards,), jnp.float32), None)
        payload = wire.encode_planar(pend.data, pend.meta)
        out = backend.exchange(lstate, payload, pend.counts,
                               axis_name=axis_name,
                               enforce_credits=enforce_credits)
        recv_events, recv_meta = wire.decode_planar(out.recv_payload)
        me = jax.lax.axis_index(axis_name)
        links_row = (out.links_used[:, me]
                     if out.links_used is not None else None)
        return (recv_events, recv_meta, out.recv_counts, out.sent_mask,
                out.stats, out.state, out.queue_us[:, me], links_row)

    def _decode(state: ShardState, recv, counts, w_exc, w_inh):
        src_shard = jnp.arange(cfg.n_shards)
        return _apply_events(state, recv, counts, w_exc, w_inh, cfg,
                             src_shard)

    fmt = backend.wire_fmt

    def _window_latency(state: ShardState, recv_meta, counts, queue_us,
                        links_row=None):
        """Wire latency of the events just delivered: waiting since each
        event's injection step (state.t == the decoded window's end, so
        deferral, residue AND in-fabric park rounds accumulate whole
        windows) + the row's per-link switch + frame-serialization
        charges + the queueing dwell behind traffic parked along its
        route (the congestion term; zero on an uncontended fabric).

        ``links_row`` (fault injection only) is the per-source count of
        links each delivered row ACTUALLY traversed — detour hops are
        charged honestly instead of assuming the shortest route."""
        me = (jax.lax.axis_index(axis_name) if axis_name is not None
              else jnp.int32(0))
        slot = jnp.arange(cfg.capacity)[None, :]
        live = slot < counts[:, None]
        wait_us = (state.t - recv_meta).astype(jnp.float32) * cfg.step_us
        hops_row = (backend.route_hops()[me] if links_row is None
                    else links_row)
        hop_us = wire.hop_latency_us(fmt, counts, hops_row) + queue_us
        lat = jnp.maximum(wait_us, 0.0) + hop_us[:, None]
        return wire.summarize_latency(lat, live.astype(jnp.int32))

    def body(carry, tables: RoutingTables, w_exc, w_inh, delays, bg_rate,
             bg_w):
        if recorder is not None:
            state, pend, lstate, ring = carry
            # the exchange below ships window k-1's buckets: at entry
            # state.t sits at window k's start, so the record is stamped
            # with the EXCHANGED window's absolute index (row 0 is the
            # empty bootstrap exchange, index -1 — the same one-row shift
            # WindowStats carries)
            win_rec = state.t // cfg.window - 1
        else:
            state, pend, lstate = carry
        # 1. exchange + decode window k-1 (same systemtime as unpipelined:
        #    state.t here == that window's end); the route/aggregate below
        #    never reads the collective's result, so the two can overlap.
        #    Under fault injection, stamp this window's dead-link mask on
        #    the fabric state first (exchange resets it to None, so the
        #    scan carry stays structurally stable).
        if fault_schedule is not None:
            lstate = lstate._replace(link_down=fabric_faults.mask_at(
                fault_schedule, state.t // cfg.window))
        recv, rmeta, counts, sent_mask, lstats, lstate, qcol, lrow = \
            _exchange(pend, lstate, enforce_credits=True)
        latency = _window_latency(state, rmeta, counts, qcol, lrow)
        state, miss = _decode(state, recv, counts, w_exc, w_inh)
        # 2. simulate window k
        t0 = state.t
        state, spikes = _simulate_steps(state, cfg, bg_rate, bg_w)
        # 3. fused route+aggregate of window k's spikes + deferred events;
        #    transport-deferred buckets go FIRST, then the residue, then
        #    fresh spikes — oldest deadlines win bucket slots (FIFO
        #    back-pressure, no starvation under sustained overflow).  Each
        #    event's injection step rides along as i32 meta (the guids
        #    operand) so latency accumulates across re-offers.
        words, inject, lost = _spikes_to_events(spikes, t0, delays, cfg)
        if can_defer:
            slot = jnp.arange(cfg.capacity)[None, :]
            held = (~sent_mask[:, None]) & (slot < pend.counts[:, None])
            deferred_words = jnp.where(held, pend.data,
                                       ev.INVALID_EVENT).reshape(-1)
            deferred_meta = jnp.where(held, pend.meta, 0).reshape(-1)
            words = jnp.concatenate([deferred_words, pend.residue, words])
            inject = jnp.concatenate([deferred_meta, pend.residue_meta,
                                      inject])
        else:
            words = jnp.concatenate([pend.residue, words])
            inject = jnp.concatenate([pend.residue_meta, inject])
        from repro.kernels import fused_route_bucket as frb
        addr = ev.address(words).astype(jnp.int32)
        dest = jnp.take(tables.dest_of_addr,
                        jnp.minimum(addr, tables.dest_of_addr.shape[0] - 1))
        fw = frb.fused_aggregate(
            words, dest, inject, cfg.n_shards, cfg.capacity,
            residue_len=cfg.residue, with_residue_meta=True)
        b = fw.buckets
        if axis_name is not None:
            my = jax.lax.axis_index(axis_name)
            off = jnp.where(jnp.arange(cfg.n_shards) == my, 0, b.counts)
        else:
            off = jnp.zeros_like(b.counts)
        cost = aggregator.window_cost(off)
        stats = WindowStats(
            spikes=jnp.sum(spikes).astype(jnp.int32),
            events_sent=jnp.sum(b.counts),
            overflow=(lost + fw.dropped).astype(jnp.int32),
            wire_bytes=cost.bytes,
            deadline_miss=miss.astype(jnp.int32),
            offered=fw.offered,
            deferred=fw.deferred,
            link=lstats,
            latency=latency,
        )
        pend_out = PendingWindow(b.data, b.guids, b.counts, fw.residue,
                                 fw.residue_meta)
        if recorder is not None:
            ring = obs_recorder.record(ring, win_rec, lstats, lstate,
                                       latency.hist)
            return (state, pend_out, lstate, ring), stats
        return (state, pend_out, lstate), stats

    def drain(state: ShardState, pend: PendingWindow, lstate: tp.LinkState,
              w_exc, w_inh):
        """Flush the fabric AND the last window's buckets (their decode
        slot is the step after the scan ends; the final residue stays
        deferred and is reported via the last window's ``deferred``).
        The walk order matches event age: first ``drain_fabric`` delivers
        every row still parked in an in-fabric transit buffer (resuming
        from its current hop, held credits released), then the final
        uncredited exchange ships the pending buckets — so no event is
        stranded mid-route or in a stalled bucket.  The drain exchanges'
        LinkStats and latency digests are intentionally discarded:
        folding them into the last row would break the per-row identities
        (offered_k == events_sent_{k-1}, offered == sent + deferred +
        parked) that tests pin, so per-run link totals cover the
        n_windows scanned exchanges only (deadline misses, a pure
        accumulator with no such identity, ARE folded in)."""
        miss_total = jnp.zeros((), jnp.int32)
        if can_defer:       # implies axis_name is not None
            fab = backend.drain_fabric(lstate, axis_name=axis_name)
            recv_f, _ = wire.decode_planar(fab.recv_payload)
            state, miss_f = _decode(state, recv_f, fab.recv_counts,
                                    w_exc, w_inh)
            miss_total = miss_total + miss_f.astype(jnp.int32)
            lstate = fab.state
        recv, _, counts, _, _, _, _, _ = _exchange(pend, lstate,
                                                   enforce_credits=False)
        state, miss = _decode(state, recv, counts, w_exc, w_inh)
        return state, miss_total + miss.astype(jnp.int32)

    if recorder is not None:
        def init_ring():
            lst = init_link()
            return obs_recorder.ring_init(
                recorder.depth, lst, (), (wire.N_LATENCY_BINS,),
                lst.bank.credits.shape[0])
    else:
        init_ring = None

    return init_pending, init_link, body, drain, init_ring


class SimCarry(NamedTuple):
    """Resumable between-segment state of a sharded simulation: everything
    the window pipeline threads through ``lax.scan`` — neuron/ring state,
    the pipelined pending buckets + residue, and the fabric's link
    flow-control state (credits, pending notifies, parked rows).  All
    leaves are stacked with a leading ``n_shards`` axis (``P(axis)``).

    ``ring`` is the flight recorder's telemetry ring — present only when
    the simulator is built with ``recorder=RecorderConfig(...)``; the
    default ``None`` is a leafless pytree node, so uninstrumented carries
    keep the exact pre-observability structure (pinned by
    ``tests/test_obs.py``)."""

    state: ShardState
    pending: PendingWindow
    link: tp.LinkState
    ring: obs_recorder.TelemetryRing | None = None


def build_sharded_segments(mesh, axis_name: str, cfg: SimConfig,
                           part: network.Partition, bg_rates: np.ndarray,
                           bg_weight: float = 87.8,
                           fault_schedule: fabric_faults.FaultSchedule |
                           None = None,
                           recorder=None):
    """Segment-granular jitted simulator over a device mesh.

    The whole-run scan of :func:`build_sharded_sim` is a special case of
    this entry point; the serving engine is the general one — it needs to
    run *bounded segments* of windows with the pipeline state resumable
    between dispatches (so the host can overlap staging/ingestion with
    device work and decide, between segments, whether to keep serving or
    quiesce).

    Returns ``(init, run_segment, finish)``:
      init(seed)                    -> SimCarry (fresh neurons, empty
                                       buckets, full credits)
      run_segment(carry, n_windows) -> (SimCarry, stacked WindowStats) —
                                       compiled once per distinct
                                       ``n_windows`` and cached
      finish(carry)                 -> (stacked ShardState, (n_shards,)
                                       deadline misses) — drains parked
                                       fabric rows and flushes the final
                                       pending buckets via the transport's
                                       ``drain_fabric`` + one uncredited
                                       exchange; no event is lost between
                                       segment end and shutdown
    """
    from jax.experimental.shard_map import shard_map

    S, per = cfg.n_shards, cfg.per_shard
    n_tot = part.n_neurons
    w_local, _fan, delay_local = network.shard_arrays(part)
    is_inh = part.is_inh
    w_exc = jnp.asarray(np.where(~is_inh[None, :], w_local, 0.0).reshape(S, per, n_tot))
    w_inh = jnp.asarray(np.where(is_inh[None, :], w_local, 0.0).reshape(S, per, n_tot))
    delays = jnp.asarray(delay_local)
    tabs = [network.routing_tables_for_shard(part, s) for s in range(S)]
    # pad per-shard tables to a common size before stacking
    na = max(t.dest_of_addr.shape[0] for t in tabs)
    ng = max(t.mcast_of_guid.shape[0] for t in tabs)
    dest_t = jnp.stack([jnp.pad(t.dest_of_addr, (0, na - t.dest_of_addr.shape[0]),
                                constant_values=-1) for t in tabs])
    guid_t = jnp.stack([jnp.pad(t.guid_of_addr, (0, na - t.guid_of_addr.shape[0]))
                        for t in tabs])
    mcast_t = jnp.stack([jnp.pad(t.mcast_of_guid, (0, ng - t.mcast_of_guid.shape[0]))
                         for t in tabs])
    bg = jnp.asarray(np.pad(bg_rates, (0, n_tot - len(bg_rates))).reshape(S, per))

    init_pending, init_link, body, drain, init_ring = make_pipeline_fns(
        cfg, axis_name=axis_name, fault_schedule=fault_schedule,
        recorder=recorder)

    def seg_fn(carry: SimCarry, dest, guid, mcast, w_e, w_i, dl, bgr,
               n_windows):
        tables = RoutingTables(dest[0], guid[0], mcast[0])
        c0 = jax.tree_util.tree_map(lambda x: x[0], carry)

        def win(c, _):
            return body(c, tables, w_e[0], w_i[0], dl[0], bgr[0],
                        bg_weight)

        if recorder is not None:
            scanned, stats = jax.lax.scan(
                win, (c0.state, c0.pending, c0.link, c0.ring), None,
                length=n_windows)
        else:
            scanned, stats = jax.lax.scan(
                win, (c0.state, c0.pending, c0.link), None,
                length=n_windows)
        return (jax.tree_util.tree_map(lambda x: x[None],
                                       SimCarry(*scanned)),
                jax.tree_util.tree_map(lambda x: x[None], stats))

    def fin_fn(carry: SimCarry, w_e, w_i):
        c0 = jax.tree_util.tree_map(lambda x: x[0], carry)
        st, miss_d = drain(c0.state, c0.pending, c0.link, w_e[0], w_i[0])
        return (jax.tree_util.tree_map(lambda x: x[None], st),
                miss_d[None])

    spec = P(axis_name)

    @functools.lru_cache(maxsize=None)
    def _compiled_segment(n_windows: int):
        fn = shard_map(
            functools.partial(seg_fn, n_windows=n_windows),
            mesh=mesh, in_specs=(spec,) * 8, out_specs=(spec, spec),
            check_rep=False)
        return jax.jit(fn)

    def run_segment(carry: SimCarry, n_windows: int):
        return _compiled_segment(n_windows)(
            carry, dest_t, guid_t, mcast_t, w_exc, w_inh, delays, bg)

    fin = jax.jit(shard_map(fin_fn, mesh=mesh, in_specs=(spec,) * 3,
                            out_specs=(spec, spec), check_rep=False))

    def finish(carry: SimCarry):
        return fin(carry, w_exc, w_inh)

    def init(seed: int = 0) -> SimCarry:
        keys = jax.random.split(jax.random.PRNGKey(seed), S)
        neuron = jax.vmap(lambda k: lif.init_state(per, cfg.params, k))(keys)
        state = ShardState(
            neuron=neuron,
            ring_exc=jnp.zeros((S, cfg.ring_len, per), jnp.float32),
            ring_inh=jnp.zeros((S, cfg.ring_len, per), jnp.float32),
            t=jnp.zeros((S,), jnp.int32),
            key=jax.vmap(jax.random.PRNGKey)(jnp.arange(S) + seed * 1000 + 7),
        )
        # pending/link start identical on every shard: broadcast host-side
        bcast = lambda a: jnp.broadcast_to(a[None], (S,) + a.shape)
        return SimCarry(state,
                        jax.tree_util.tree_map(bcast, init_pending()),
                        jax.tree_util.tree_map(bcast, init_link()),
                        (jax.tree_util.tree_map(bcast, init_ring())
                         if init_ring is not None else None))

    return init, run_segment, finish


def build_sharded_sim(mesh, axis_name: str, cfg: SimConfig, part: network.Partition,
                      bg_rates: np.ndarray, bg_weight: float = 87.8,
                      fault_schedule: fabric_faults.FaultSchedule |
                      None = None,
                      recorder=None):
    """Jitted multi-window simulator over a device mesh (whole-run form,
    composed from :func:`build_sharded_segments`: one segment + finish).

    Returns (init_fn(seed) -> stacked ShardState, run_fn(state, n_windows)
    -> (state, stacked WindowStats over windows)).  With
    ``recorder=RecorderConfig(...)`` the run additionally returns the
    final flight-recorder ring: ``run`` yields ``(state, stats, ring)``
    (leading shard axis on every ring lane; decode with
    ``repro.obs.ring_shard`` + ``ring_rows``).
    """
    seg_init, run_segment, finish = build_sharded_segments(
        mesh, axis_name, cfg, part, bg_rates, bg_weight, fault_schedule,
        recorder=recorder)
    fresh = seg_init(0)        # pending/link halves are seed-independent

    def init(seed: int = 0):
        return seg_init(seed).state

    def run(state, n_windows: int):
        carry, stats = run_segment(
            SimCarry(state, fresh.pending, fresh.link, fresh.ring),
            n_windows)
        state, miss_d = finish(carry)
        if n_windows > 0:
            # the final flush's deadline misses land on the last window
            stats = stats._replace(
                deadline_miss=stats.deadline_miss.at[:, -1].add(miss_d))
        if recorder is not None:
            return state, stats, carry.ring
        return state, stats

    return init, run
