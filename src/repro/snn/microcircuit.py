"""Potjans-Diesmann cortical microcircuit — the paper's named target
workload ("One of the first multi-wafer networks will be a full scale
cortical microcircuit model" [8, 9]).

Eight populations over four layers; the standard connectivity map from
Potjans & Diesmann (2014), Table 5.  A ``scale`` parameter shrinks neuron
counts (and compensates in-degrees) so the same code runs full scale on a
wafer system and at 1e-3 scale in CPU tests.
"""
from __future__ import annotations

import dataclasses

import numpy as np

POPULATIONS = ("L23E", "L23I", "L4E", "L4I", "L5E", "L5I", "L6E", "L6I")

# full-scale neuron counts (77,169 total)
FULL_SIZES = np.array([20683, 5834, 21915, 5479, 4850, 1065, 14395, 2948])

# connection probabilities C[target, source] (Potjans & Diesmann, Table 5)
CONN_PROB = np.array([
    [0.1009, 0.1689, 0.0437, 0.0818, 0.0323, 0.0000, 0.0076, 0.0000],
    [0.1346, 0.1371, 0.0316, 0.0515, 0.0755, 0.0000, 0.0042, 0.0000],
    [0.0077, 0.0059, 0.0497, 0.1350, 0.0067, 0.0003, 0.0453, 0.0000],
    [0.0691, 0.0029, 0.0794, 0.1597, 0.0033, 0.0000, 0.1057, 0.0000],
    [0.1004, 0.0622, 0.0505, 0.0057, 0.0831, 0.3726, 0.0204, 0.0000],
    [0.0548, 0.0269, 0.0257, 0.0022, 0.0600, 0.3158, 0.0086, 0.0000],
    [0.0156, 0.0066, 0.0211, 0.0166, 0.0572, 0.0197, 0.0396, 0.2252],
    [0.0364, 0.0010, 0.0034, 0.0005, 0.0277, 0.0080, 0.0658, 0.1443],
])

# background Poisson in-degrees (x 8 Hz per connection)
BG_INDEGREE = np.array([1600, 1500, 2100, 1900, 2000, 1900, 2900, 2100])
BG_RATE_HZ = 8.0

W_EXC_PA = 87.8          # mean excitatory PSC amplitude
W_REL_SD = 0.1
G_INH = -4.0             # inhibitory weight ratio
W_L4E_L23E = 2.0         # doubled L4E -> L23E projection
DELAY_EXC_MS = 1.5
DELAY_INH_MS = 0.75


@dataclasses.dataclass(frozen=True)
class MicrocircuitSpec:
    scale: float = 1.0
    seed: int = 42

    @property
    def sizes(self) -> np.ndarray:
        return np.maximum((FULL_SIZES * self.scale).astype(int), 4)

    @property
    def n_neurons(self) -> int:
        return int(self.sizes.sum())

    def offsets(self) -> np.ndarray:
        return np.concatenate([[0], np.cumsum(self.sizes)])

    def weight_matrix(self) -> tuple[np.ndarray, np.ndarray]:
        """Dense (N, N) weight [pA] + delay-is-inhibitory masks.

        At reduced scale, connection probability is kept and weights are NOT
        rescaled (we test communication, not dynamics fidelity); the full
        wafer system realizes the same spec sparsely.
        Returns (weights, is_inh_source).
        """
        rng = np.random.default_rng(self.seed)
        sizes = self.sizes
        off = self.offsets()
        n = self.n_neurons
        w = np.zeros((n, n), np.float32)
        is_inh = np.zeros((n,), bool)
        for j, src in enumerate(POPULATIONS):
            inh = src.endswith("I")
            is_inh[off[j]:off[j + 1]] = inh
            for i, _tgt in enumerate(POPULATIONS):
                p = CONN_PROB[i, j]
                if p <= 0:
                    continue
                mask = rng.random((sizes[i], sizes[j])) < p
                base = W_EXC_PA * (G_INH if inh else 1.0)
                if i == 0 and j == 2:        # L4E -> L23E doubled
                    base = base * W_L4E_L23E
                ww = rng.normal(base, abs(base) * W_REL_SD,
                                (sizes[i], sizes[j])).astype(np.float32)
                w[off[i]:off[i + 1], off[j]:off[j + 1]] = np.where(mask, ww, 0.0)
        return w, is_inh

    def bg_rates(self) -> np.ndarray:
        """Per-neuron background Poisson rate [Hz]."""
        sizes = self.sizes
        return np.repeat(BG_INDEGREE * BG_RATE_HZ, sizes).astype(np.float32)

    def population_of(self) -> np.ndarray:
        return np.repeat(np.arange(8), self.sizes)
