"""Multi-wafer partitioning of a spiking network (paper Fig. 1 topology).

Neurons are assigned contiguously to shards ("wafer-FPGA groups"); the
host-side builder derives, per source neuron, the list of destination
shards whose neurons it synapses onto — each spike becomes one Extoll event
*per destination shard* (the paper's unicast-to-FPGA + local GUID multicast
scheme: inter-wafer fan-out is realized by sending one event per target
FPGA, intra-FPGA fan-out by the destination's multicast mask).

Also computes the routing tables (`repro.core.routing`) and the traffic
matrix used by the torus link-load benchmark.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import routing as rt


@dataclasses.dataclass
class Partition:
    """Host-side partition plan for S shards over N neurons."""

    n_shards: int
    n_neurons: int
    per_shard: int                 # neurons per shard (padded equal split)
    fanout: np.ndarray             # (N, max_fanout) destination shards, -1 pad
    weights: np.ndarray            # (N, N) dense synaptic matrix [pA]
    is_inh: np.ndarray             # (N,) inhibitory-source flag
    delays_steps: np.ndarray       # (N,) axonal delay in dt steps per source

    def local_slice(self, shard: int) -> slice:
        return slice(shard * self.per_shard, (shard + 1) * self.per_shard)


def build_partition(weights: np.ndarray, is_inh: np.ndarray, n_shards: int,
                    delay_exc_steps: int = 15, delay_inh_steps: int = 8) -> Partition:
    n = weights.shape[0]
    per = -(-n // n_shards)                   # ceil split
    n_pad = per * n_shards
    if n_pad != n:
        wpad = np.zeros((n_pad, n_pad), weights.dtype)
        wpad[:n, :n] = weights
        weights = wpad
        is_inh = np.pad(is_inh, (0, n_pad - n))
    shard_of = np.arange(n_pad) // per
    # fanout: shards having any nonzero weight from source j
    nz = weights != 0.0
    max_fan = 1
    fan_lists = []
    for j in range(n_pad):
        tgt = np.unique(shard_of[nz[:, j]])
        fan_lists.append(tgt)
        max_fan = max(max_fan, len(tgt))
    fanout = np.full((n_pad, max_fan), -1, np.int32)
    for j, t in enumerate(fan_lists):
        fanout[j, : len(t)] = t
    delays = np.where(is_inh, delay_inh_steps, delay_exc_steps).astype(np.int32)
    return Partition(
        n_shards=n_shards, n_neurons=n_pad, per_shard=per,
        fanout=fanout, weights=weights.astype(np.float32),
        is_inh=is_inh.astype(bool), delays_steps=delays,
    )


def shard_arrays(p: Partition):
    """Per-shard device arrays, stacked over a leading shard dim:

    w_local   (S, per, N)        rows owned by each shard
    fan_local (S, per, F)        destination shards per local source neuron
    delay_local (S, per)
    """
    S, per, n = p.n_shards, p.per_shard, p.n_neurons
    w_local = p.weights.reshape(S, per, n)
    fan_local = p.fanout.reshape(S, per, -1)
    delay_local = p.delays_steps.reshape(S, per)
    return w_local, fan_local, delay_local


def traffic_matrix(p: Partition, rates_hz: np.ndarray, event_bytes: int = 4):
    """(S, S) expected bytes/s between shards for given per-neuron rates."""
    S = p.n_shards
    m = np.zeros((S, S))
    shard_of = np.arange(p.n_neurons) // p.per_shard
    for j in range(min(len(rates_hz), p.n_neurons)):
        s = shard_of[j]
        for d in p.fanout[j]:
            if d >= 0 and d != s:
                m[s, d] += rates_hz[j] * event_bytes
    return m


def routing_tables_for_shard(p: Partition, shard: int, n_links: int = 8):
    """Paper-faithful tables: one projection per (local source, dest shard).

    A source with fan-out to k shards emits k events; the replica index is
    folded into the event address (addr = local_id * max_fan + replica,
    fitting the 14-bit address field — the paper's 12-bit pulse address +
    link id).  The destination multicast mask replays the event on local
    'HICANN link' (src global id mod n_links), standing in for the wafer's
    8 links.
    """
    per = p.per_shard
    max_fan = p.fanout.shape[1]
    projs = []
    for a in range(per):
        g = shard * per + a
        for k, d in enumerate(p.fanout[g]):
            if d >= 0:
                addr = a * max_fan + k
                projs.append(rt.Projection(addr, addr + 1, int(d), [g % n_links]))
    return rt.build_tables(per * max_fan,
                           projs or [rt.Projection(0, 0, 0, [0])],
                           n_guid=max(len(projs), 1))
