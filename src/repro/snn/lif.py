"""Leaky integrate-and-fire dynamics (current-based, exponential PSCs).

This is the neuron model of the paper's target workload — the full-scale
cortical microcircuit [Potjans & Diesmann 2014], i.e. NEST's
``iaf_psc_exp`` with separate excitatory/inhibitory synaptic currents:

    tau_m dV/dt = -(V - E_L) + R_m (I_e + I_i + I_ext)
    tau_s dI/dt = -I          (+= w on presynaptic spike)

Exact exponential integration per dt step; absolute refractory period by a
countdown register.  All state is a flat pytree so the update vmaps/shards
trivially, and the fused update also exists as a Pallas kernel
(`repro.kernels.lif_step`).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class LIFParams(NamedTuple):
    """Potjans-Diesmann defaults (mV, ms, pA, pF)."""

    tau_m: float = 10.0
    tau_syn: float = 0.5
    c_m: float = 250.0
    e_l: float = -65.0
    v_th: float = -50.0
    v_reset: float = -65.0
    t_ref: float = 2.0
    dt: float = 0.1


class LIFState(NamedTuple):
    v: jax.Array         # (N,) membrane potential [mV]
    i_exc: jax.Array     # (N,) excitatory synaptic current [pA]
    i_inh: jax.Array     # (N,) inhibitory synaptic current [pA]
    refrac: jax.Array    # (N,) remaining refractory steps [int32]


def init_state(n: int, p: LIFParams, key: jax.Array | None = None) -> LIFState:
    if key is None:
        v = jnp.full((n,), p.e_l, jnp.float32)
    else:
        # randomized initial potentials avoid startup synchrony artifacts
        v = p.e_l + (p.v_th - p.e_l) * jax.random.uniform(key, (n,))
    return LIFState(
        v=v.astype(jnp.float32),
        i_exc=jnp.zeros((n,), jnp.float32),
        i_inh=jnp.zeros((n,), jnp.float32),
        refrac=jnp.zeros((n,), jnp.int32),
    )


def propagators(p: LIFParams):
    """Exact-integration propagator constants for one dt step."""
    pm = jnp.exp(-p.dt / p.tau_m)
    ps = jnp.exp(-p.dt / p.tau_syn)
    # current -> voltage coupling over one step (exact for tau_m != tau_syn)
    tau_r = p.tau_syn * p.tau_m / (p.tau_m - p.tau_syn)
    pv = (tau_r / p.c_m) * (pm - ps)
    ref_steps = int(round(p.t_ref / p.dt))
    return pm, ps, pv, ref_steps


def step(state: LIFState, p: LIFParams, exc_in: jax.Array, inh_in: jax.Array,
         i_ext: jax.Array | float = 0.0):
    """One dt of exact-integration LIF. Returns (state, spikes:bool (N,))."""
    pm, ps, pv, ref_steps = propagators(p)
    active = state.refrac <= 0
    i_tot = state.i_exc + state.i_inh
    v = jnp.where(
        active,
        p.e_l + (state.v - p.e_l) * pm + pv * i_tot
        + (p.tau_m / p.c_m) * (1.0 - pm) * i_ext,
        state.v,
    )
    i_exc = state.i_exc * ps + exc_in
    i_inh = state.i_inh * ps + inh_in
    spikes = active & (v >= p.v_th)
    v = jnp.where(spikes, p.v_reset, v)
    refrac = jnp.where(spikes, ref_steps, jnp.maximum(state.refrac - 1, 0))
    return LIFState(v, i_exc, i_inh, refrac), spikes


def poisson_input(key: jax.Array, n: int, rate_hz: jax.Array, weight: float,
                  dt_ms: float) -> jax.Array:
    """Background drive: Poisson spike count x weight per step (pA)."""
    lam = rate_hz * (dt_ms * 1e-3)
    counts = jax.random.poisson(key, lam, (n,))
    return counts.astype(jnp.float32) * weight
