"""Batched serving engine: continuous batching over fixed decode slots.

The request/response path reuses the paper's disciplines:

* requests are admitted into a bounded queue with **credit accounting**
  (``core.flow_control`` semantics — the engine never over-commits its
  decode slots), and
* finished responses are written to a **response ring** the client drains.

Decode runs one jitted step for the whole slot batch; finished sequences
are swapped out and their slot refilled from the queue (prefill on
admission), which is continuous batching in its simplest honest form.

.. deprecated::
    This is the legacy token-serving engine, kept for the LLM-side
    launch tooling.  Spike-stream serving (the paper's workload) lives in
    ``repro.serve.spike_engine.SpikeEngine``, which owns the streaming
    ingest/device thread pattern, tenancy QoS and the observability
    integration; new serving work should build there.  This engine only
    carries the shared span API (``tracer=``) so its waves show up on the
    same Perfetto timeline.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.models.transformer import Runtime
from repro.obs import spans as obs_spans


@dataclasses.dataclass
class ServeConfig:
    slots: int = 4                # concurrent sequences (decode batch)
    max_len: int = 256            # cache capacity
    max_new_tokens: int = 32
    temperature: float = 0.0      # 0 = greedy
    eos_id: int = 2


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    extras: dict | None = None    # enc_frames / vision stubs


class Engine:
    def __init__(self, model: Model, cfg: ServeConfig,
                 rt: Runtime | None = None, seed: int = 0,
                 tracer: obs_spans.Tracer | None = None):
        self.model = model
        self.cfg = cfg
        self.rt = rt or Runtime()
        self.tracer = tracer if tracer is not None else obs_spans.NULL
        self.key = jax.random.PRNGKey(seed)
        self._decode = jax.jit(
            lambda p, c, t: model.decode(p, c, t, self.rt))
        self._prefill = jax.jit(
            lambda p, b, c: model.prefill(p, b, c, self.rt))

    def _sample(self, logits):
        if self.cfg.temperature <= 0:
            return jnp.argmax(logits[:, -1, :], axis=-1)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(
            sub, logits[:, -1, :] / self.cfg.temperature)

    def generate_batch(self, params, requests: list) -> dict:
        """Serve a list of requests through fixed decode slots.

        Simplification vs a full paged server: requests are grouped into
        waves of ``slots`` with a shared prompt length per wave (padding);
        each wave prefis once and decodes until every member finishes.
        Returns {rid: np.ndarray(generated tokens)}.
        """
        out: dict = {}
        waves = [requests[i:i + self.cfg.slots]
                 for i in range(0, len(requests), self.cfg.slots)]
        for wave in waves:
            B = len(wave)
            S = max(len(r.prompt) for r in wave)
            toks = np.zeros((B, S), np.int32)
            for j, r in enumerate(wave):
                toks[j, S - len(r.prompt):] = r.prompt    # left-pad
            batch = {"tokens": jnp.asarray(toks)}
            for r in wave:
                if r.extras:
                    batch.update({k: jnp.asarray(v)
                                  for k, v in r.extras.items()})
            caches = self.model.init_caches(B, self.cfg.max_len)
            with self.tracer.span("serve/prefill", track="serve",
                                  batch=B, prompt_len=S):
                h, caches = self._prefill(params, batch, caches)
                logits = self.model.logits(params, h[:, -1:, :], self.rt)
            tok = self._sample(logits)
            gen = [tok]
            done = np.zeros((B,), bool)
            with self.tracer.span("serve/decode", track="serve",
                                  batch=B) as sp:
                for _ in range(self.cfg.max_new_tokens - 1):
                    logits, caches = self._decode(params, caches,
                                                  tok[:, None])
                    tok = self._sample(logits)
                    gen.append(tok)
                    done |= np.asarray(tok) == self.cfg.eos_id
                    if done.all():
                        break
                sp.args["tokens"] = len(gen)
            g = np.stack([np.asarray(t) for t in gen], axis=1)
            for j, r in enumerate(wave):
                seq = g[j]
                stop = np.where(seq == self.cfg.eos_id)[0]
                out[r.rid] = seq[: stop[0] + 1] if len(stop) else seq
        return out
