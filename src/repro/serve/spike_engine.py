"""Streaming multi-tenant spike serving engine.

Turns the batch simulator's fabric mechanics into a *serving system*: a
host-side ingestion thread feeds pinned double buffers, the device runs a
continuously repeating windowed ``lax.scan`` segment, and JAX's async
dispatch overlaps the two — the host encodes/stages segment ``k+1`` while
the device still exchanges segment ``k`` (the thread/queue/slot pattern
of MLPerf-style offline inference engines, applied to spike streams).

Data path, per flush window and tenant::

    ingest thread                     device (shard_map over the wafer axis)
    ─────────────                     ──────────────────────────────────────
    loadgen / client                  backlog-first merge -> bucket rows
      │  fill staging slot              │ encode_planar(words, inject-window)
      ▼                                 ▼
    staged queue (depth 2) ──asarray──> TenantTorusTransport.exchange
      ▲                                 │ deferred rows -> backlog carry
      └── free-slot queue <──────────── ▼ per-tenant latency digests

The engine is loss-accountable end to end: every generated event is
``delivered``, sitting in the ``backlog`` carry, parked ``in_fabric``, or
counted as ``shed`` (fresh arrivals beyond the bounded per-row backlog —
the open-loop overload response, measured instead of silently dropped).
``stop(drain=True)`` quiesces by running zero-traffic segments until
backlog and fabric empty (credits refund, parked rows resume), then a
final walk that reuses ``drain_fabric`` plus one uncredited flush — after
which ``injected == delivered + shed`` holds per tenant, i.e. no event is
lost across engine stop.  Latency attribution runs on the receiver from
the injection-window meta lane each event carries, so deferral, backlog
dwell and park windows all show up in the per-tenant digests.
"""
from __future__ import annotations

import queue
import threading
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.fabric import faults as fabric_faults
from repro.obs import recorder as obs_recorder
from repro.obs import spans as obs_spans
from repro.serve import tenancy
from repro.wire import codec
from repro.wire import latency as wire_latency


class EngineConfig(NamedTuple):
    """Static engine parameters.

    capacity:      C — max events per (tenant, dst) bucket row per window;
                   also the per-row backlog bound (one deferred row)
    link_credits:  per-link credit budget split by the tenant partition
    notify_latency: windows before a spent credit re-arms
    window_us:     modeled wall-clock per flush window (latency unit)
    seg_windows:   windows per dispatched device segment
    queue_depth:   staging slots (2 = classic double buffer)
    max_drain_segments: zero-traffic segments allowed before the final
                   uncredited walk (bounds shutdown under pathology)
    """

    capacity: int = 128
    link_credits: int = 64
    notify_latency: int = 2
    window_us: float = 100.0
    seg_windows: int = 8
    nx: int = 0
    ny: int = 0
    nz: int = 0
    wire_format: str = "extoll"
    queue_depth: int = 2
    max_drain_segments: int = 64


class WindowServeStats(NamedTuple):
    """Per-window, per-tenant device-side serving stats (all (T,) except
    the nested latency summary, whose fields lead with (T,))."""

    offered: jax.Array
    sent: jax.Array
    deferred: jax.Array
    parked: jax.Array
    unparked: jax.Array
    delivered: jax.Array
    shed: jax.Array
    latency: wire_latency.LatencySummary


class EngineReport(NamedTuple):
    """What a bounded run (or a stop) hands back."""

    tenants: list                 # list[tenancy.TenantDigest]
    injected: np.ndarray          # (T,) events staged to the device
    delivered: np.ndarray         # (T,) events that reached their owners
    shed: np.ndarray              # (T,) fresh events beyond backlog bound
    clipped: np.ndarray           # (T,) generator-side over-capacity drop
    windows: int                  # served windows (excl. drain)
    drain_windows: int            # zero-traffic windows run to quiesce
    wall_s: float                 # ingest start -> last absorb
    events_per_s: float           # delivered.sum() / wall_s
    conservation_checked: bool    # True iff drained and ledger verified


class SpikeEngine:
    """Multi-tenant streaming engine over one credit-partitioned fabric.

    ``source`` must provide ``next_window(window) -> WindowTraffic``
    (``repro.serve.loadgen.PoissonLoadGen`` is the reference); tenants
    and QoS come from ``tenancy.TenantSpec``.  Use :meth:`run` for a
    bounded number of segments or :meth:`start`/:meth:`stop` for
    continuous serving.
    """

    def __init__(self, mesh, axis_name: str,
                 tenants: Sequence[tenancy.TenantSpec],
                 cfg: EngineConfig, source,
                 fault_schedule: fabric_faults.FaultSchedule | None = None,
                 recorder: obs_recorder.RecorderConfig | None = None,
                 tracer: obs_spans.Tracer | None = None):
        self.mesh = mesh
        self.axis_name = axis_name
        self.tenants = tuple(tenants)
        self.cfg = cfg
        self.source = source
        self.fault_schedule = fault_schedule
        # Observability is strictly opt-in: with recorder=None the device
        # carry is the same 4-tuple (and lowers to the same HLO) as an
        # uninstrumented build; the NULL tracer appends nothing.
        self.recorder = recorder
        self.tracer = tracer if tracer is not None else obs_spans.NULL
        S = int(np.prod([mesh.shape[a] for a in mesh.shape]))
        T = len(self.tenants)
        if getattr(source, "n_tenants", T) != T:
            raise ValueError(f"source generates {source.n_tenants} "
                             f"tenants, engine serves {T}")
        if getattr(source, "capacity", cfg.capacity) != cfg.capacity:
            raise ValueError("source row capacity != engine capacity")
        if getattr(source, "n_shards", S) != S:
            raise ValueError("source n_shards != mesh size")
        self.n_shards, self.n_tenants = S, T
        self.transport = tenancy.build_fabric(
            S, self.tenants, link_credits=cfg.link_credits,
            notify_latency=cfg.notify_latency, nx=cfg.nx, ny=cfg.ny,
            nz=cfg.nz, max_row_events=cfg.capacity,
            wire_format=cfg.wire_format,
            stall_attribution=recorder is not None)
        self.ledger = tenancy.TenantLedger([t.name for t in self.tenants])
        self._build_device_fns()
        self._reset_runtime()

    # -- device functions --------------------------------------------------
    def _build_device_fns(self):
        S, T, C = self.n_shards, self.n_tenants, self.cfg.capacity
        nw = self.cfg.seg_windows
        ax = self.axis_name
        transport, cfg = self.transport, self.cfg
        fmt = transport.wire_fmt
        hops = transport.route_hops()                      # (n, n) const
        sched = self.fault_schedule
        pos = jnp.arange(C)[None, None, :]

        def attribute(out, win_abs):
            """Receiver-side per-event latency for one window's arrivals:
            whole-window waiting from the injection meta lane (covers
            deferral, backlog dwell AND park windows) + per-row wire time
            + queueing dwell behind parked traffic on the route.  Under
            fault injection, rows are charged the links they ACTUALLY
            traversed (detours included), not the shortest route."""
            me = lax.axis_index(ax)
            _, r_meta = codec.decode_planar(out.recv_payload)
            live = pos < out.recv_counts[..., None]        # (T, n, C)
            wait = ((win_abs - r_meta).astype(jnp.float32)
                    * jnp.float32(cfg.window_us))
            hops_row = (hops[:, me][None, :]
                        if out.links_used is None
                        else out.links_used[:, :, me])     # (T, n)
            row_us = (wire_latency.hop_latency_us(
                fmt, out.recv_counts, hops_row)
                + out.queue_us[:, :, me])                  # (T, n)
            lat = wait + row_us[..., None]
            summary = jax.vmap(wire_latency.summarize_latency)(
                lat.reshape(T, -1), live.reshape(T, -1).astype(jnp.int32))
            return summary, jnp.sum(out.recv_counts, axis=-1)

        rec = self.recorder is not None

        def seg_fn(state, bw, bm, bc, *rest):
            # rest is (ring, fw, fc_, win0) when the flight recorder rides
            # the carry, (fw, fc_, win0) otherwise — recorder=None keeps
            # the traced arity (and the lowered HLO) of an uninstrumented
            # build.
            state = jax.tree.map(lambda a: a[0], state)
            bw, bm, bc = bw[0], bm[0], bc[0]
            ring = jax.tree.map(lambda a: a[0], rest[0]) if rec else None
            fw, fc_ = rest[-3][0], rest[-2][0]  # (nw, T, n, C) / (nw, T, n)
            win0 = rest[-1]

            def window(carry, x):
                if rec:
                    state, bw, bm, bc, ring = carry
                else:
                    state, bw, bm, bc = carry
                fw_w, fc_w, i = x
                win_abs = win0 + i
                # FIFO merge: backlog (last window's deferred row) first,
                # fresh arrivals behind it, overflow beyond C is shed
                b = bc[..., None]
                sel_b = pos < b
                fw_g = jnp.take_along_axis(
                    fw_w, jnp.clip(pos - b, 0, C - 1), axis=-1)
                take_f = ~sel_b & (pos - b < fc_w[..., None])
                words = jnp.where(sel_b, bw,
                                  jnp.where(take_f, fw_g, jnp.uint32(0)))
                meta = jnp.where(sel_b, bm,
                                 jnp.where(take_f, win_abs, 0))
                cnt = jnp.minimum(bc + fc_w, C)
                shed = bc + fc_w - cnt
                payload = codec.encode_planar(words,
                                              meta.astype(jnp.int32))
                if sched is not None:
                    state = state._replace(
                        link_down=fabric_faults.mask_at(sched, win_abs))
                out = transport.exchange(state, payload, cnt,
                                         axis_name=ax)
                keep = ~out.sent_mask
                carry = (out.state,
                         jnp.where(keep[..., None], words, jnp.uint32(0)),
                         jnp.where(keep[..., None], meta, 0),
                         jnp.where(keep, cnt, 0))
                summary, delivered = attribute(out, win_abs)
                st = out.stats
                if rec:
                    carry = carry + (obs_recorder.record(
                        ring, win_abs, st, out.state, summary.hist),)
                ws = WindowServeStats(
                    offered=st.offered_events, sent=st.sent_events,
                    deferred=st.deferred_events,
                    parked=st.parked_events, unparked=st.unparked_events,
                    delivered=delivered.astype(jnp.int32),
                    shed=jnp.sum(shed, axis=-1).astype(jnp.int32),
                    latency=summary)
                return carry, ws

            init = (state, bw, bm, bc) + ((ring,) if rec else ())
            carry, ws = lax.scan(window, init, (fw, fc_, jnp.arange(nw)))
            lift = lambda t: jax.tree.map(lambda a: a[None], t)
            return lift(carry), lift(ws)

        def drain_fn(state, bw, bm, bc, win0):
            """Final walk: one uncredited flush of the backlog plus the
            transit-buffer drain — reuses ``drain_fabric`` so nothing the
            fabric still holds is lost across engine stop."""
            state = jax.tree.map(lambda a: a[0], state)
            bw, bm, bc = bw[0], bm[0], bc[0]
            payload = codec.encode_planar(bw, bm.astype(jnp.int32))
            out1 = transport.exchange(state, payload, bc, axis_name=ax,
                                      enforce_credits=False)
            s1, d1 = attribute(out1, win0)
            out2 = transport.drain_fabric(out1.state, axis_name=ax)
            s2, d2 = attribute(out2, win0)
            lift = lambda t: jax.tree.map(lambda a: a[None], t)
            return (lift(out2.state),
                    lift((s1, d1.astype(jnp.int32),
                          s2, d2.astype(jnp.int32))))

        spec = P(ax)
        n_carry = 5 if rec else 4
        self._seg = jax.jit(shard_map(
            seg_fn, mesh=self.mesh,
            in_specs=(spec,) * n_carry + (spec, spec, P()),
            out_specs=(spec, spec), check_rep=False))
        self._drain_walk = jax.jit(shard_map(
            drain_fn, mesh=self.mesh,
            in_specs=(spec, spec, spec, spec, P()),
            out_specs=(spec, spec), check_rep=False))

    # -- runtime state -----------------------------------------------------
    def _reset_runtime(self):
        S, T, C = self.n_shards, self.n_tenants, self.cfg.capacity
        nw, depth = self.cfg.seg_windows, self.cfg.queue_depth
        W = 2 * C                        # planar wire words per row
        state0 = self.transport.init_state(W)
        bcast = lambda a: jnp.broadcast_to(a[None], (S,) + a.shape)
        self._carry = (jax.tree.map(bcast, state0),
                       jnp.zeros((S, T, S, C), jnp.uint32),
                       jnp.zeros((S, T, S, C), jnp.int32),
                       jnp.zeros((S, T, S), jnp.int32))
        if self.recorder is not None:
            # the flight-recorder ring rides as the 5th carry element;
            # credit lanes carry partition slots ((T+1)*K), the stall
            # lane stays physical (K directed links)
            ring0 = obs_recorder.ring_init(
                self.recorder.depth, state0, (T,),
                (T, wire_latency.N_LATENCY_BINS),
                S * self.transport.n_links)
            self._carry = self._carry + (jax.tree.map(bcast, ring0),)
        # pinned staging pair: preallocated, filled in place by the
        # ingestion thread, handed to the device via jnp.asarray (the
        # host->device copy; on accelerators device_put from these fixed
        # host buffers is the pinned-staging path)
        self._words_buf = np.zeros((depth, S, nw, T, S, C), np.uint32)
        self._counts_buf = np.zeros((depth, S, nw, T, S), np.int32)
        self._zero_fw = jnp.zeros((S, nw, T, S, C), jnp.uint32)
        self._zero_fc = jnp.zeros((S, nw, T, S), jnp.int32)
        self._free_q: queue.Queue = queue.Queue()
        for i in range(depth):
            self._free_q.put(i)
        self._staged_q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop_evt = threading.Event()
        self._ingest_t = self._device_t = None
        self._max_segments = None
        self._win = 0
        self._windows = 0
        self._drain_windows = 0
        self._t0 = self._t1 = 0.0

    # -- host threads ------------------------------------------------------
    def _fill_segment(self, slot: int, seg: int):
        nw = self.cfg.seg_windows
        wbuf, cbuf = self._words_buf[slot], self._counts_buf[slot]
        inj = np.zeros((self.n_tenants,), np.int64)
        clip = np.zeros((self.n_tenants,), np.int64)
        with self.tracer.span("ingest/fill", track="spike-ingest",
                              seg=seg, win0=seg * nw):
            for i in range(nw):
                tr = self.source.next_window(seg * nw + i)
                # shard s offers rows (tenant, dst) = traffic[:, s, :]
                cbuf[:, i] = tr.counts.transpose(1, 0, 2)
                wbuf[:, i] = tr.words.transpose(1, 0, 2, 3)
                inj += tr.counts.astype(np.int64).sum((1, 2))
                clip += tr.clipped
        return inj, clip

    def _ingest_loop(self):
        seg = 0
        try:
            while not self._stop_evt.is_set():
                if (self._max_segments is not None
                        and seg >= self._max_segments):
                    break
                t0 = self.tracer.now_us()
                try:
                    slot = self._free_q.get(timeout=0.05)
                except queue.Empty:
                    continue
                self.tracer.complete("ingest/slot_wait", t0,
                                     self.tracer.now_us() - t0,
                                     track="spike-ingest", cat="host",
                                     slot=slot)
                inj, clip = self._fill_segment(slot, seg)
                self._staged_q.put((slot, inj, clip))
                seg += 1
        finally:
            self._staged_q.put(None)

    def _device_loop(self):
        prev = None
        while True:
            with self.tracer.span("device/staged_wait",
                                  track="spike-device"):
                item = self._staged_q.get()
            if item is None:
                break
            slot, inj, clip = item
            # copy=True matters: zero-copy host->device aliasing would
            # let the ingest thread overwrite the slot mid-read
            with self.tracer.span("device/h2d", track="spike-device",
                                  slot=slot):
                fw = jnp.array(self._words_buf[slot], copy=True)
                fc_ = jnp.array(self._counts_buf[slot], copy=True)
            self._free_q.put(slot)       # staging slot reusable: the
            #                              host->device copy is done
            win0 = self._win
            with self.tracer.span("device/dispatch", track="spike-device",
                                  win0=win0):
                self._carry, ws = self._seg(*self._carry, fw, fc_,
                                            jnp.int32(self._win))
            self._win += self.cfg.seg_windows
            self._windows += self.cfg.seg_windows
            self.ledger.add_injected(inj, clip)
            if prev is not None:         # absorb k-1 while k runs
                self._absorb(*prev)
            prev = (ws, win0)
        if prev is not None:
            self._absorb(*prev)
        self._t1 = self.tracer.now_us()

    def _absorb(self, ws: WindowServeStats, win0: int | None = None):
        t0 = self.tracer.now_us()
        ws = jax.tree.map(np.asarray, ws)        # blocks until ready
        self.ledger.add_windows(ws.delivered, ws.shed, ws.latency.hist,
                                ws.latency.max_us, ws.latency.mean_us)
        if self.tracer.enabled and win0 is not None:
            # the absorb block is where the host observes the async
            # segment completing; its bounds stand in for the device
            # segment on the trace, and the per-window instants carry
            # the same absolute window indices the wire words' meta lane
            # (and the flight-recorder ring) are stamped with
            nw = self.cfg.seg_windows
            self.tracer.complete("device/segment", t0,
                                 self.tracer.now_us() - t0, track="device",
                                 win0=win0, windows=nw)
            delivered = ws.delivered.sum(axis=(0, 2))      # (nw,)
            for i in range(nw):
                self.tracer.instant("window", track="device", cat="device",
                                    window=win0 + i,
                                    delivered=int(delivered[i]))

    # -- lifecycle ---------------------------------------------------------
    def start(self, max_segments: int | None = None):
        """Spawn the ingestion + device threads (continuous serving when
        ``max_segments`` is None)."""
        if self._ingest_t is not None:
            raise RuntimeError("engine already started")
        self._max_segments = max_segments
        self._t0 = self.tracer.now_us()
        self._ingest_t = threading.Thread(target=self._ingest_loop,
                                          name="spike-ingest", daemon=True)
        self._device_t = threading.Thread(target=self._device_loop,
                                          name="spike-device", daemon=True)
        self._ingest_t.start()
        self._device_t.start()

    def warmup(self) -> None:
        """Compile the segment + drain-walk functions with a zero-traffic
        dry run (both are pure; engine state is not mutated) so a bench's
        sustained-rate window excludes JIT time."""
        out = self._seg(*self._carry, self._zero_fw, self._zero_fc,
                        jnp.int32(0))
        jax.tree_util.tree_leaves(out)[0].block_until_ready()
        out = self._drain_walk(*self._carry[:4], jnp.int32(0))
        jax.tree_util.tree_leaves(out)[0].block_until_ready()

    def backlog_events(self) -> int:
        return int(np.asarray(self._carry[3]).sum())

    def in_fabric_events(self) -> int:
        pc = np.asarray(self._carry[0].parked_count)
        return int(pc[0].sum()) if pc.size else 0

    def recorder_rows(self, shard: int | None = None) -> list[dict]:
        """Decode the flight-recorder ring (requires ``recorder=``).

        ``shard=None`` returns global per-window rows (counter/hist lanes
        summed across shards); an integer returns that shard's raw view.
        """
        if self.recorder is None:
            raise RuntimeError("engine was built without a flight "
                               "recorder (pass recorder=RecorderConfig())")
        ring = self._carry[4]
        if shard is None:
            return obs_recorder.global_rows(ring, self.n_shards)
        return obs_recorder.ring_rows(obs_recorder.ring_shard(ring, shard))

    def _drain(self):
        """Quiesce: zero-traffic segments until backlog and fabric empty
        (bounded), then the final uncredited walk via ``drain_fabric``."""
        nw = self.cfg.seg_windows
        for _ in range(self.cfg.max_drain_segments):
            if self.backlog_events() == 0 and self.in_fabric_events() == 0:
                break
            win0 = self._win
            self._carry, ws = self._seg(*self._carry, self._zero_fw,
                                        self._zero_fc, jnp.int32(self._win))
            self._win += nw
            self._drain_windows += nw
            self._absorb(ws, win0)
        with self.tracer.span("drain/walk", track="spike-device",
                              win0=self._win):
            state, (s1, d1, s2, d2) = self._drain_walk(*self._carry[:4],
                                                       jnp.int32(self._win))
            zero = np.zeros_like(np.asarray(d1))
            for s, d in ((s1, d1), (s2, d2)):
                self.ledger.add_windows(np.asarray(d), zero,
                                        np.asarray(s.hist),
                                        np.asarray(s.max_us),
                                        np.asarray(s.mean_us))
        # the flight-recorder ring (carry[4:], when enabled) survives the
        # reset so post-run decoding sees the full served history
        self._carry = (state,
                       jnp.zeros_like(self._carry[1]),
                       jnp.zeros_like(self._carry[2]),
                       jnp.zeros_like(self._carry[3])) + self._carry[4:]

    def stop(self, drain: bool = True, timeout: float = 120.0
             ) -> EngineReport:
        """Graceful shutdown: stop ingestion, finish staged segments,
        drain the fabric, verify per-tenant conservation, report."""
        if self._ingest_t is None:
            raise RuntimeError("engine not started")
        self._stop_evt.set()
        self._ingest_t.join(timeout)
        self._device_t.join(timeout)
        if self._ingest_t.is_alive() or self._device_t.is_alive():
            raise RuntimeError("engine threads failed to stop in time "
                               "(ingest alive=%s device alive=%s)" % (
                                   self._ingest_t.is_alive(),
                                   self._device_t.is_alive()))
        if drain:
            self._drain()
            self.ledger.check_conservation()
        wall = max((self._t1 - self._t0) / 1e6, 1e-9)
        report = EngineReport(
            tenants=self.ledger.digests(),
            injected=self.ledger.injected.copy(),
            delivered=self.ledger.delivered.copy(),
            shed=self.ledger.shed.copy(),
            clipped=self.ledger.clipped.copy(),
            windows=self._windows,
            drain_windows=self._drain_windows,
            wall_s=wall,
            events_per_s=float(self.ledger.delivered.sum()) / wall,
            conservation_checked=bool(drain),
        )
        self._ingest_t = self._device_t = None
        return report

    def run(self, n_segments: int, drain: bool = True,
            timeout: float = 300.0) -> EngineReport:
        """Bounded serving run: ``n_segments`` segments, then stop."""
        self.start(max_segments=n_segments)
        self._device_t.join(timeout)
        return self.stop(drain=drain, timeout=timeout)
