"""Seeded open-loop traffic generation for the spike serving engine.

Open-loop means the arrival process never reacts to the system under
test: window ``k``'s traffic is a pure function of ``(seed, tenant, k)``,
drawn whether or not the fabric kept up — the discipline the off-wafer
pulse-communication characterization uses to measure *sustained* delivery
rather than the self-throttled rate a closed loop would settle into.
Overload therefore shows up where it belongs: as deferred rows, parked
rows and (beyond the engine's bounded backlog) *measured shed*, never as
a quietly slowed generator.

This module is also the repo's single audited source of random traffic:
:func:`traffic_rng` / :func:`draw_counts` / :func:`draw_payload` are
shared with the fabric fuzz tests (``tests/test_fabric_fuzz.py``), so the
load generator and the invariant fuzzers exercise the transports with one
code path for randomness instead of two quietly diverging ones.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import numpy as np

from repro.core import events as ev


def traffic_rng(seed: int, *stream: int) -> np.random.Generator:
    """The one seeding path for generated traffic.

    ``stream`` keys substreams — e.g. ``traffic_rng(seed, tenant,
    window)`` — so a tenant's window-``k`` traffic is identical across
    runs regardless of what other tenants or windows were drawn (this is
    what lets the QoS tests compare a quiet tenant solo against the same
    quiet tenant next to a saturating co-tenant, event for event).
    """
    return np.random.default_rng((int(seed) * 7919 + 13,
                                  *(int(s) for s in stream)))


def draw_counts(rng: np.random.Generator, shape, hi: int,
                lo: int = 0) -> np.ndarray:
    """Uniform bucket-row event counts in ``[lo, hi]`` (i32)."""
    return rng.integers(lo, hi + 1, size=shape).astype(np.int32)


def draw_payload(rng: np.random.Generator, shape) -> np.ndarray:
    """Opaque u32 payload words (any bit pattern is legal on the wire)."""
    return rng.integers(0, 1 << 32, size=shape, dtype=np.uint64).astype(
        np.uint32)


def draw_events(rng: np.random.Generator, shape) -> np.ndarray:
    """Valid spike event words: random address + timestamp, valid bit set
    (the numpy mirror of ``repro.core.events.pack``)."""
    addr = rng.integers(0, ev.ADDR_MASK + 1, size=shape,
                        dtype=np.uint64).astype(np.uint32)
    ts = rng.integers(0, ev.TS_MASK + 1, size=shape,
                      dtype=np.uint64).astype(np.uint32)
    word = ((addr & ev.ADDR_MASK) << ev.TS_BITS) | (ts & ev.TS_MASK)
    return (word | np.uint32(ev.VALID_BIT)).astype(np.uint32)


class TenantProfile(NamedTuple):
    """Open-loop rate/burst profile of one tenant's arrival process.

    rate_epw:     mean events per window across the whole fabric
                  (split evenly over the off-diagonal (src, dst) pairs)
    burst_factor: rate multiplier during a burst window
    burst_prob:   per-window probability of bursting (Bernoulli, from the
                  tenant's own substream)
    """

    name: str
    rate_epw: float
    burst_factor: float = 1.0
    burst_prob: float = 0.0


class WindowTraffic(NamedTuple):
    """One window of generated traffic for all tenants.

    counts:  (T, S, S) i32 events per (tenant, src, dst) bucket row,
             clipped to the row capacity
    words:   (T, S, S, C) u32 event words (slots >= count are invalid)
    clipped: (T,) i64 events beyond row capacity discarded at GENERATION
             (over-offered load the engine never saw; reported separately
             from engine-side shed so neither hides the other)
    """

    counts: np.ndarray
    words: np.ndarray
    clipped: np.ndarray


class PoissonLoadGen:
    """Seeded open-loop Poisson generator with per-tenant profiles.

    Each tenant's per-window fabric-wide rate ``rate_epw`` (optionally
    burst-modulated) is split evenly across the ``S*(S-1)`` off-diagonal
    (src, dst) pairs and drawn per pair as an independent Poisson count —
    the superposition of many sparse spike streams.  Rows are clipped to
    the bucket capacity ``C`` with the clipped remainder *counted*, so
    offered load is exact even at absurd over-subscription.
    """

    def __init__(self, seed: int, profiles: Sequence[TenantProfile],
                 n_shards: int, capacity: int):
        if not profiles:
            raise ValueError("need at least one tenant profile")
        self.seed = int(seed)
        self.profiles = tuple(profiles)
        self.n_shards = int(n_shards)
        self.capacity = int(capacity)

    @property
    def n_tenants(self) -> int:
        return len(self.profiles)

    def next_window(self, window: int) -> WindowTraffic:
        T, S, C = self.n_tenants, self.n_shards, self.capacity
        counts = np.zeros((T, S, S), np.int32)
        words = np.zeros((T, S, S, C), np.uint32)
        clipped = np.zeros((T,), np.int64)
        n_pairs = max(S * (S - 1), 1)
        for t, prof in enumerate(self.profiles):
            rng = traffic_rng(self.seed, t, window)
            lam = prof.rate_epw
            if prof.burst_prob > 0 and rng.random() < prof.burst_prob:
                lam *= prof.burst_factor
            raw = rng.poisson(lam / n_pairs, size=(S, S)).astype(np.int64)
            if S > 1:
                np.fill_diagonal(raw, 0)
            clip = np.minimum(raw, C)
            clipped[t] = int((raw - clip).sum())
            counts[t] = clip.astype(np.int32)
            row_words = draw_events(rng, (S, S, C))
            slot = np.arange(C)[None, None, :]
            words[t] = np.where(slot < clip[..., None], row_words, 0)
        return WindowTraffic(counts=counts, words=words, clipped=clipped)
