"""Multi-tenant QoS policy: tenant specs -> partitioned fabric + digests.

N concurrent experiments share ONE fabric (the BrainScaleS-2 inter-chip
demonstrator shape: independent pulse streams live on the same EXTOLL
links).  Isolation comes from per-tenant credit partitioning
(``repro.core.flow_control.CreditPartition``) enforced inside the torus
admission (``repro.transport.torus.TenantTorusTransport``): each tenant
owns a guaranteed credit slice per link plus access to a shared
best-effort pool, and the admission rotation round-robins over (tenant,
source) so priority is starvation-bounded in both axes.

Credit-partition math (what a ``reserve`` buys):

* Per link and window, tenant ``t`` can always admit up to
  ``reserve[t]`` events from its own slice — no co-tenant can draw it.
* A spent reserved credit returns ``notify_latency`` windows later, so
  the *sustained* guaranteed admission rate is
  ``reserve[t] / max(notify_latency, 1)`` events per link per window
  (:func:`guaranteed_epw`); burst absorption above that comes from the
  shared pool, first come first served.
* Congestion coupling that remains is physical and bounded: a saturating
  co-tenant can fill the in-fabric transit buffers, adding queueing dwell
  of at most one link credit budget per crossed link (microseconds),
  never whole deferred windows — that is the bound the QoS tests pin.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import numpy as np

from repro.core import flow_control as fc
from repro.transport.torus import TenantTorusTransport, default_shape3d
from repro.wire import latency as wire_latency


class TenantSpec(NamedTuple):
    """One tenant's QoS contract on the shared fabric.

    reserve:  guaranteed credits per link (its slice of every link's
              budget; 0 = pure best-effort tenant)
    rate_epw: nominal offered load in events per window (advisory — used
              by load-generator builders and capacity checks, not
              enforced by the fabric)
    """

    name: str
    reserve: int
    rate_epw: float = 0.0


def credit_partition(tenants: Sequence[TenantSpec],
                     link_credits: int) -> fc.CreditPartition:
    """Partition each link's ``link_credits`` by the tenants' reserves;
    the remainder becomes the shared best-effort pool."""
    return fc.make_partition(link_credits,
                             [t.reserve for t in tenants])


def guaranteed_epw(spec: TenantSpec, notify_latency: int) -> float:
    """Sustained guaranteed admission, events per link per window."""
    return spec.reserve / max(notify_latency, 1)


def build_fabric(n_shards: int, tenants: Sequence[TenantSpec], *,
                 link_credits: int, notify_latency: int = 2,
                 nx: int = 0, ny: int = 0, nz: int = 0,
                 max_row_events: int = 0,
                 wire_format: str = "extoll",
                 stall_attribution: bool = False) -> TenantTorusTransport:
    """Build the shared 3-D torus with per-tenant credit partitioning.

    Dimensions default to the most-cubic factorization of ``n_shards``
    (the paper's wafer-stack arrangement passes nx/ny/nz explicitly).
    ``stall_attribution`` opts into the per-link deferred-demand table
    the flight recorder snapshots (``LinkStats.stalled_by_link``).
    """
    dims = (nx, ny, nz)
    if not all(dims):
        if any(dims):
            raise ValueError(
                "pass all of nx/ny/nz or none; partial specs are ambiguous "
                f"for the tenant fabric (got {dims})")
        dims = default_shape3d(n_shards)
    return TenantTorusTransport(
        n_shards, dims,
        partition=credit_partition(tenants, link_credits),
        notify_latency=notify_latency,
        max_row_events=max_row_events,
        wire_format=wire_format,
        stall_attribution=stall_attribution)


class TenantDigest(NamedTuple):
    """Run-level per-tenant latency/throughput attribution.

    p50/p99 are estimated from the merged log-bin histogram (upper bin
    edge — a conservative over-estimate, exact-ish at 2x bin
    granularity); max/mean are exact.
    """

    name: str
    delivered: int
    p50_us: float
    p99_us: float
    max_us: float
    mean_us: float
    hist: np.ndarray           # (N_LATENCY_BINS,) merged event histogram


class TenantLedger:
    """Per-tenant conservation + latency accounting across windows.

    Feeds on the per-window device outputs of the serve engine and
    answers the two questions a multi-tenant operator has: *did every
    event land somewhere accountable* (``check_conservation``: injected
    == delivered + shed after drain, per tenant) and *what latency did
    each tenant actually see* (``digests``).
    """

    def __init__(self, names: Sequence[str]):
        self.names = tuple(names)
        T = len(self.names)
        self.injected = np.zeros((T,), np.int64)
        self.clipped = np.zeros((T,), np.int64)
        self.delivered = np.zeros((T,), np.int64)
        self.shed = np.zeros((T,), np.int64)
        self.hist = np.zeros((T, wire_latency.N_LATENCY_BINS), np.int64)
        self.max_us = np.zeros((T,), np.float64)
        self._lat_weighted = np.zeros((T,), np.float64)

    def add_injected(self, counts: np.ndarray, clipped=None) -> None:
        self.injected += np.asarray(counts, np.int64)
        if clipped is not None:
            self.clipped += np.asarray(clipped, np.int64)

    def add_windows(self, delivered, shed, hist, max_us, mean_us) -> None:
        """Absorb stacked per-window per-tenant device stats (any number
        of leading axes before the tenant axis)."""
        delivered = np.asarray(delivered, np.int64)
        lead = tuple(range(delivered.ndim - 1))
        self.delivered += delivered.sum(axis=lead)
        self.shed += np.asarray(shed, np.int64).sum(axis=lead)
        # hist has one trailing bin axis after the tenant axis
        self.hist += np.asarray(hist, np.int64).sum(axis=lead)
        mx = np.asarray(max_us, np.float64)
        self.max_us = np.maximum(self.max_us,
                                 mx.max(axis=lead) if lead else mx)
        self._lat_weighted += (np.asarray(mean_us, np.float64)
                               * delivered).sum(axis=lead)

    def check_conservation(self) -> None:
        total = self.delivered + self.shed
        if not np.array_equal(self.injected, total):
            raise AssertionError(
                f"per-tenant event conservation violated: injected "
                f"{self.injected.tolist()} != delivered+shed "
                f"{total.tolist()}")

    def digests(self) -> list[TenantDigest]:
        out = []
        for t, name in enumerate(self.names):
            d = int(self.delivered[t])
            out.append(TenantDigest(
                name=name,
                delivered=d,
                p50_us=wire_latency.percentile_from_hist(self.hist[t], .5),
                p99_us=wire_latency.percentile_from_hist(self.hist[t], .99),
                max_us=float(self.max_us[t]),
                mean_us=float(self._lat_weighted[t] / d) if d else 0.0,
                hist=self.hist[t].copy(),
            ))
        return out

    def export_metrics(self, registry) -> None:
        """Feed the run-level per-tenant ledger into an
        ``repro.obs.metrics.Registry`` (delivered/injected/shed counters,
        the latency histogram, and a p99 gauge per tenant)."""
        from repro.obs import metrics as obs_metrics
        obs_metrics.export_tenant_digests(registry, self.digests())
        inj = registry.counter(
            "tenant_injected_events_total",
            "Events staged to the device, per tenant.",
            labels=("tenant",))
        shed = registry.counter(
            "tenant_shed_events_total",
            "Fresh events dropped beyond the backlog bound, per tenant.",
            labels=("tenant",))
        for t, name in enumerate(self.names):
            inj.inc(int(self.injected[t]), tenant=name)
            shed.inc(int(self.shed[t]), tenant=name)


def tenant_rows(specs: Sequence[TenantSpec], ledger: TenantLedger,
                notify_latency: int) -> list[dict]:
    """JSON-serializable per-tenant rows (the run directory's
    ``tenants.jsonl``): QoS contract + conservation ledger + latency
    digest side by side, so the observability report can render SLO
    burn (offered vs guaranteed rate) next to the measured p99."""
    rows = []
    for spec, d in zip(specs, ledger.digests()):
        t = ledger.names.index(spec.name)
        rows.append({
            "tenant": spec.name,
            "reserve": int(spec.reserve),
            "rate_epw": float(spec.rate_epw),
            "guaranteed_epw": guaranteed_epw(spec, notify_latency),
            "injected": int(ledger.injected[t]),
            "delivered": d.delivered,
            "shed": int(ledger.shed[t]),
            "clipped": int(ledger.clipped[t]),
            "p50_us": d.p50_us,
            "p99_us": d.p99_us,
            "max_us": d.max_us,
            "mean_us": d.mean_us,
            "hist": d.hist.astype(int).tolist(),
        })
    return rows
