"""Serving: batched engine with continuous slots + credit accounting."""
from repro.serve import engine  # noqa: F401
