"""Serving subsystems.

* :mod:`repro.serve.engine` — batched request/response engine with
  continuous slots + credit accounting (model serving).
* :mod:`repro.serve.spike_engine` — streaming multi-tenant spike serving
  over one credit-partitioned fabric (ingest thread, pinned double
  buffers, windowed device segments, graceful drain).
* :mod:`repro.serve.tenancy` — tenant QoS specs, credit partitioning and
  per-tenant conservation/latency ledgers.
* :mod:`repro.serve.loadgen` — seeded open-loop Poisson traffic.
"""
from repro.serve import engine  # noqa: F401
from repro.serve import loadgen  # noqa: F401
from repro.serve import spike_engine  # noqa: F401
from repro.serve import tenancy  # noqa: F401
