"""Mamba2-2.7B [arXiv:2405.21060; unverified] — attention-free SSD.
d_inner = 2*d_model = 5120, 80 heads x 64, d_state 128.
Sub-quadratic: runs the long_500k cell."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=80, n_kv_heads=0, head_dim=64,
    d_ff=0, vocab=50280,
    rms_eps=1e-5, act="silu", tie_embeddings=True,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                  n_groups=1, chunk=256),
    subquadratic=True,
)
