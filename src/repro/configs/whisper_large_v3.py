"""Whisper large-v3 [arXiv:2212.04356; unverified] — encoder-decoder,
32+32 layers, d_model 1280, MHA, GELU; conv frontend is a STUB (the
assignment provides precomputed frame embeddings; enc_ctx=1500 frames)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="audio",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20, head_dim=64,
    d_ff=5120, vocab=51866,
    enc_layers=32, enc_ctx=1500, act="gelu", tie_embeddings=True,
)
