"""Qwen2-VL-7B [arXiv:2409.12191; hf] — dense GQA backbone with M-RoPE
(temporal/height/width sections 16/24/24); vision patch embeddings arrive
as a precomputed stub per the assignment (dynamic resolution not modelled)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, head_dim=128,
    d_ff=18944, vocab=152064,
    rope_theta=1_000_000.0, qkv_bias=True, mrope_sections=(16, 24, 24),
    vision_tokens=256, rms_eps=1e-6, act="silu",
)
