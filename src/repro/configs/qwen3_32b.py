"""Qwen3-32B [hf:Qwen/Qwen3-32B family; hf] — dense GQA with qk-norm."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=25600, vocab=151936,
    rope_theta=1_000_000.0, qk_norm=True, rms_eps=1e-6, act="silu",
)
