"""The paper's own system: BrainScaleS wafer modules on an Extoll torus.

48 FPGAs/wafer gathered at 8 concentrator torus nodes (6 FPGAs each),
8 HICANNs/FPGA, 124-event packet buckets.  Used by the SNN examples and
benchmarks; not an LM architecture."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class BrainScaleSConfig:
    n_wafers: int = 4
    fpgas_per_wafer: int = 48
    concentrators_per_wafer: int = 8
    hicanns_per_fpga: int = 8
    bucket_capacity: int = 124       # 496 B / 4 B events
    n_buckets: int = 16              # physical buckets per FPGA
    flush_margin: int = 64           # systemtime slack
    fpga_clock_mhz: float = 210.0
    microcircuit_scale: float = 1.0


CONFIG = BrainScaleSConfig()
