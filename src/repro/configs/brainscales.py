"""The paper's own system: BrainScaleS wafer modules on an Extoll torus.

48 FPGAs/wafer gathered at 8 concentrator torus nodes (6 FPGAs each),
8 HICANNs/FPGA, 124-event packet buckets.  Used by the SNN examples and
benchmarks; not an LM architecture."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class BrainScaleSConfig:
    n_wafers: int = 4
    fpgas_per_wafer: int = 48
    concentrators_per_wafer: int = 8
    hicanns_per_fpga: int = 8
    bucket_capacity: int = 124       # 496 B / 4 B events
    n_buckets: int = 16              # physical buckets per FPGA
    flush_margin: int = 64           # systemtime slack
    fpga_clock_mhz: float = 210.0
    microcircuit_scale: float = 1.0
    # flush-window transport (repro.transport): "alltoall" ships one global
    # collective per window; "torus2d" / "torus3d" walk dimension-ordered
    # neighbor hops over a (torus_nx, torus_ny[, torus_nz]) device torus
    # with hop-by-hop credit-based link flow control (link_credits
    # events/window per directed egress link, spent on every hop of a
    # row's route, 0 = off).  torus3d's Z rings are the wafer-stacking
    # axis — the paper's full arrangement is (2, 4, n_wafers).
    transport: str = "alltoall"
    torus_nx: int = 0                # 0 = most-square/cubic factorization
    torus_ny: int = 0
    torus_nz: int = 0                # wafer axis (torus3d only)
    link_credits: int = 0
    notify_latency: int = 2
    # wire protocol profile (repro.wire): "extoll" (64 B cells, low header
    # tax, sub-us switches) or "ethernet" (1500 B MTU, full Eth+IP+UDP
    # stack, GbE timing) — governs frame-exact bytes_on_wire and the
    # per-event latency model; step_us converts systemtime steps to wire
    # microseconds (BrainScaleS ~1000x acceleration).
    wire_format: str = "extoll"
    step_us: float = 0.1

    def transport_fields(self) -> dict:
        """The transport-selection kwargs of ``snn.simulator.SimConfig``
        (pass as ``SimConfig(..., **cfg.transport_fields())``)."""
        return dict(transport=self.transport, torus_nx=self.torus_nx,
                    torus_ny=self.torus_ny, torus_nz=self.torus_nz,
                    link_credits=self.link_credits,
                    notify_latency=self.notify_latency,
                    wire_format=self.wire_format, step_us=self.step_us)


CONFIG = BrainScaleSConfig()
