"""The paper's own system: BrainScaleS wafer modules on an Extoll torus.

48 FPGAs/wafer gathered at 8 concentrator torus nodes (6 FPGAs each),
8 HICANNs/FPGA, 124-event packet buckets.  Used by the SNN examples and
benchmarks; not an LM architecture."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class BrainScaleSConfig:
    n_wafers: int = 4
    fpgas_per_wafer: int = 48
    concentrators_per_wafer: int = 8
    hicanns_per_fpga: int = 8
    bucket_capacity: int = 124       # 496 B / 4 B events
    n_buckets: int = 16              # physical buckets per FPGA
    flush_margin: int = 64           # systemtime slack
    fpga_clock_mhz: float = 210.0
    microcircuit_scale: float = 1.0
    # flush-window transport (repro.transport): "alltoall" ships one global
    # collective per window; "torus2d" walks dimension-ordered neighbor
    # hops over a (torus_nx, torus_ny) device torus with credit-based link
    # flow control (link_credits events/window/egress-link, 0 = off).
    transport: str = "alltoall"
    torus_nx: int = 0                # 0 = most-square auto factorization
    torus_ny: int = 0
    link_credits: int = 0
    notify_latency: int = 2

    def transport_fields(self) -> dict:
        """The transport-selection kwargs of ``snn.simulator.SimConfig``
        (pass as ``SimConfig(..., **cfg.transport_fields())``)."""
        return dict(transport=self.transport, torus_nx=self.torus_nx,
                    torus_ny=self.torus_ny, link_credits=self.link_credits,
                    notify_latency=self.notify_latency)


CONFIG = BrainScaleSConfig()
