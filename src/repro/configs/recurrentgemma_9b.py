"""RecurrentGemma-9B (Griffin) [arXiv:2402.19427; unverified] — RG-LRU
recurrent blocks + local attention, 2:1 pattern, window 2048, MQA.
Sub-quadratic: runs the long_500k cell."""
from repro.configs.base import ModelConfig, RecurrentConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, head_dim=256,
    d_ff=12288, vocab=256000,
    rope_theta=10000.0, sliding_window=2048, tie_embeddings=True,
    rms_eps=1e-6, act="gelu_tanh",
    recurrent=RecurrentConfig(lru_width=4096, conv_width=4,
                              block_pattern=("rglru", "rglru", "attn")),
    subquadratic=True,
)
