"""Gemma2-9B [arXiv:2408.00118; hf] — alternating local/global attention,
logit softcaps, post-norms, unit-offset RMSNorm."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b", family="dense",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=14336, vocab=256000,
    rope_theta=10000.0, attn_softcap=50.0, logit_softcap=30.0,
    query_scale=256.0 ** -0.5, sliding_window=4096, alt_local_global=True,
    post_norm=True, tie_embeddings=True, act="gelu_tanh", rms_eps=1e-6,
)
