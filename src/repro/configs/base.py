"""Config dataclasses for the model zoo, shapes and meshes.

Every assigned architecture is a ``ModelConfig`` instance in its own module
(``repro/configs/<id>.py``); ``repro.configs.get_config(name)`` resolves it.
``reduced()`` shrinks any config to a CPU-testable size while keeping the
family's structure (same block kinds, same routing, tiny dims).
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Sequence

Family = Literal["dense", "moe", "hybrid", "ssm", "vlm", "audio", "snn"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0              # shared (always-on) experts
    expert_ff: int = 0             # per-expert hidden dim
    first_dense: int = 0           # leading dense layers (deepseek-moe)
    dense_ff: int = 0              # hidden of those dense layers
    parallel_dense_ff: int = 0     # arctic: dense MLP residual in parallel
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class RecurrentConfig:
    lru_width: int = 0             # RG-LRU width (0 -> d_model)
    conv_width: int = 4
    block_pattern: Sequence[str] = ()   # e.g. ("rglru","rglru","attn")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    # attention variants
    rope_theta: float = 10000.0
    qk_norm: bool = False
    qkv_bias: bool = False
    logit_softcap: float = 0.0          # gemma2 final-logit softcap
    attn_softcap: float = 0.0           # gemma2 attention softcap
    query_scale: float | None = None    # override 1/sqrt(head_dim)
    sliding_window: int = 0             # local attention window
    alt_local_global: bool = False      # gemma2: alternate local/global
    mrope_sections: Sequence[int] = ()  # qwen2-vl M-RoPE (t, h, w)
    # residual/embedding scaling (minicpm WSD-style muP scaling)
    scale_emb: float = 1.0
    scale_depth: float = 0.0            # residual scale = scale_depth/sqrt(L)
    logit_scale: float = 1.0
    tie_embeddings: bool = False
    # substructures
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    recurrent: RecurrentConfig | None = None
    # enc-dec (whisper)
    enc_layers: int = 0
    enc_ctx: int = 0                    # encoder frames (conv-stub output)
    # vlm
    vision_tokens: int = 0              # patch-embedding stub length
    # norms
    rms_eps: float = 1e-6
    post_norm: bool = False             # gemma2 post-attn/ffn extra norms
    act: str = "silu"                   # silu | gelu
    # applicability of the paper's technique (bucketed sparse dispatch)
    uses_bucket_dispatch: bool = False
    # long-context admissibility (sub-quadratic path exists)
    subquadratic: bool = False

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def reduced(cfg: ModelConfig, *, layers: int = 2) -> ModelConfig:
    """Shrink a config for CPU smoke tests, preserving family structure."""
    if cfg.recurrent:
        layers = max(layers, 4)       # >= one (r, r, attn) super-block + tail
    kw: dict = dict(
        n_layers=layers,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads > 1 else 1,
        head_dim=16,
        d_ff=128,
        vocab=256,
        sliding_window=min(cfg.sliding_window, 16) if cfg.sliding_window else 0,
        enc_layers=min(cfg.enc_layers, layers),
        enc_ctx=min(cfg.enc_ctx, 24) if cfg.enc_ctx else 0,
        vision_tokens=min(cfg.vision_tokens, 8) if cfg.vision_tokens else 0,
    )
    if cfg.moe:
        kw["moe"] = dataclasses.replace(
            cfg.moe,
            n_experts=min(cfg.moe.n_experts, 8),
            top_k=min(cfg.moe.top_k, 2),
            expert_ff=32,
            dense_ff=64 if cfg.moe.dense_ff else 0,
            parallel_dense_ff=64 if cfg.moe.parallel_dense_ff else 0,
            first_dense=min(cfg.moe.first_dense, 1),
        )
    if cfg.ssm:
        kw["ssm"] = dataclasses.replace(
            cfg.ssm, d_state=16, head_dim=8, chunk=16)
    if cfg.recurrent:
        pat = tuple(cfg.recurrent.block_pattern) or ("rglru", "rglru", "attn")
        kw["recurrent"] = dataclasses.replace(
            cfg.recurrent, lru_width=64, block_pattern=pat)
    if cfg.mrope_sections:
        kw["mrope_sections"] = (4, 6, 6)    # sums to head_dim/2 = 8? adjusted below
    out = dataclasses.replace(cfg, **kw)
    if out.mrope_sections:
        # sections must sum to head_dim // 2
        h = out.head_dim // 2
        a = h // 3
        out = dataclasses.replace(out, mrope_sections=(h - 2 * a, a, a))
    return out
