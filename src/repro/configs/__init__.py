"""Config registry: ``get_config(name)`` / ``list_configs()``.

One module per assigned architecture (exact published configs, source tags
in each file) plus the paper's own BrainScaleS system config.
"""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    ModelConfig, MoEConfig, RecurrentConfig, SSMConfig, ShapeConfig,
    SHAPES, reduced,
)

ARCHS = (
    "qwen3_32b",
    "qwen15_4b",
    "gemma2_9b",
    "minicpm_2b",
    "deepseek_moe_16b",
    "arctic_480b",
    "recurrentgemma_9b",
    "mamba2_27b",
    "qwen2_vl_7b",
    "whisper_large_v3",
)

_ALIAS = {a.replace("_", "-"): a for a in ARCHS}
_ALIAS.update({
    "qwen3-32b": "qwen3_32b",
    "qwen1.5-4b": "qwen15_4b",
    "gemma2-9b": "gemma2_9b",
    "minicpm-2b": "minicpm_2b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "arctic-480b": "arctic_480b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "mamba2-2.7b": "mamba2_27b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "whisper-large-v3": "whisper_large_v3",
})


def get_config(name: str) -> ModelConfig:
    mod = _ALIAS.get(name, name).replace("-", "_").replace(".", "")
    m = importlib.import_module(f"repro.configs.{mod}")
    return m.CONFIG


def list_configs():
    return list(ARCHS)
