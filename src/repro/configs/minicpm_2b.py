"""MiniCPM-2B [arXiv:2404.06395; hf] — llama-like with muP-style scaling
(scale_emb=12, scale_depth=1.4, logit scale d_model/256) and WSD schedule
(set in train config)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b", family="dense",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36, head_dim=64,
    d_ff=5760, vocab=122753,
    rope_theta=10000.0, scale_emb=12.0, scale_depth=1.4,
    logit_scale=1.0 / (2304 / 256), tie_embeddings=True,
    rms_eps=1e-5, act="silu",
)
