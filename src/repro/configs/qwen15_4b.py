"""Qwen1.5-4B [hf:Qwen/Qwen1.5-4B family; hf] — dense MHA with QKV bias."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b", family="dense",
    n_layers=40, d_model=2560, n_heads=20, n_kv_heads=20, head_dim=128,
    d_ff=6912, vocab=151936,
    rope_theta=5_000_000.0, qkv_bias=True, rms_eps=1e-6, act="silu",
)
