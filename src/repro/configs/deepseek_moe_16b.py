"""DeepSeekMoE-16B [arXiv:2401.06066; hf] — fine-grained MoE: 64 routed
experts top-6 + 2 shared experts, first layer dense (d_ff 10944).
The paper's bucket dispatch applies DIRECTLY here (experts=destinations)."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, vocab=102400,
    rope_theta=10000.0, rms_eps=1e-6, act="silu",
    moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, expert_ff=1408,
                  first_dense=1, dense_ff=10944, capacity_factor=1.25),
    uses_bucket_dispatch=True,
)
