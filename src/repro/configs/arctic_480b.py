"""Snowflake Arctic 480B [hf:Snowflake/snowflake-arctic-base; hf] —
dense-MoE hybrid: a dense residual MLP in parallel with a 128-expert top-2
MoE per layer. Bucket dispatch applies (128 destinations)."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=4864, vocab=32000,
    rope_theta=10000.0, rms_eps=1e-5, act="silu",
    moe=MoEConfig(n_experts=128, top_k=2, expert_ff=4864,
                  parallel_dense_ff=4864, capacity_factor=1.25),
    uses_bucket_dispatch=True,
)
