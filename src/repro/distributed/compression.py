"""Gradient compression: int8 error-feedback all-reduce.

Distributed-optimization trick for the DP gradient sync: quantize each
gradient tensor to int8 with a per-tensor scale, all-reduce the int8
payload (8x fewer bytes on the wire than f32; 4x vs bf16), dequantize, and
keep the quantization residual as *error feedback* added to the next
step's gradient — which preserves convergence (Karimireddy et al., 2019).

Implemented with ``shard_map`` + ``psum`` so the collective payload is
explicitly int (visible in the HLO for the roofline's collective term).
Used as an opt-in wrapper around the gradient tree in the train step; the
§Perf log quantifies the collective-bytes reduction on the train cells.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


def quantize(g, err):
    """(g + err) -> int8 payload, scale, new residual."""
    g32 = g.astype(jnp.float32) + err
    scale = jnp.max(jnp.abs(g32)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, g32 - deq


def dequantize(q_sum, scale_sum, n_parties):
    """Average of per-party dequantized tensors.

    Parties share one scale (max-of-scales via psum of per-party scale /
    n — approximation: we all-reduce scales too and use the mean, applied
    to the int32 sum; bias is absorbed by error feedback)."""
    return q_sum.astype(jnp.float32) * (scale_sum / n_parties) / n_parties


def compressed_psum(g, err, axis_names):
    """Error-feedback int8 psum over ``axis_names``. Call inside shard_map.

    Returns (g_reduced_mean, new_err).
    """
    # axis size via psum of a unit (jax.lax has no static axis-size query
    # inside shard_map in this JAX version); only used in float math below
    n = 1
    for a in axis_names:
        n *= jax.lax.psum(1, a)
    q, scale, new_err = quantize(g, err)
    q_sum = q.astype(jnp.int32)
    s_sum = scale
    for a in axis_names:
        q_sum = jax.lax.psum(q_sum, a)
        s_sum = jax.lax.psum(s_sum, a)
    return dequantize(q_sum, s_sum, n).astype(g.dtype), new_err


def make_compressed_allreduce(mesh, axis_names=("pod",)):
    """Jittable tree-level wrapper: (grads, err_tree) -> (grads, err_tree).

    Meant for the *cross-pod* gradient sync (the slow links): within-pod
    reduction stays full precision via GSPMD; the pod axis all-reduce is
    int8.  This mirrors the paper's economy: compress what crosses the
    expensive fabric.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def one(g, e):
        fn = shard_map(
            partial(compressed_psum, axis_names=axis_names),
            mesh=mesh,
            in_specs=(P(), P()), out_specs=(P(), P()),
            check_rep=False)
        return fn(g, e)

    def apply(grads, errs):
        flat_g, tdef = jax.tree_util.tree_flatten(grads)
        flat_e, _ = jax.tree_util.tree_flatten(errs)
        out = [one(g, e) for g, e in zip(flat_g, flat_e)]
        gs = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
        es = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
        return gs, es

    return apply


def init_error_feedback(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
