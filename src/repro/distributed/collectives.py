"""Explicit collective patterns used by the optimized (§Perf) paths.

* ``split_kv_decode_attention`` — flash-decoding over a sequence-sharded KV
  cache: each shard computes partial attention with local max/sum, then one
  pair of tiny psums combines the partials (logsumexp merge).  This replaces
  GSPMD's all-gather-the-cache baseline for decode_32k, cutting the
  collective term from O(cache) to O(B x H x D).
* ``pipelined_all_to_all`` — chunked a2a with interleaved compute for
  overlap: splits the payload on the capacity dim and issues chunk i+1's
  a2a while chunk i is consumed.  XLA can overlap across the scan steps
  (async collectives); structurally it bounds the live buffer to 1/k of
  the payload either way.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

NEG_INF = -2.3819763e38


def split_kv_partial(q, k_shard, v_shard, *, scale, valid,
                     softcap: float = 0.0):
    """Per-shard partial attention.

    q: (B, 1, Hkv, G, D); k_shard/v_shard: (B, T_loc, Hkv, D);
    valid: (B, T_loc) mask. Returns (m, l, acc) partials.
    """
    s = jnp.einsum("bqhgd,bkhd->bqhgk", q.astype(jnp.float32),
                   k_shard.astype(jnp.float32)) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)                                  # (B,1,Hkv,G)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bqhgk,bkhd->bqhgd", p, v_shard.astype(jnp.float32))
    return m, l, acc


def split_kv_combine(m, l, acc, axis_name):
    """LogSumExp-combine partials across the KV shards."""
    m_max = jax.lax.pmax(m, axis_name)
    corr = jnp.exp(m - m_max)
    l_sum = jax.lax.psum(l * corr, axis_name)
    acc_sum = jax.lax.psum(acc * corr[..., None], axis_name)
    return acc_sum / jnp.maximum(l_sum[..., None], 1e-37)


def split_kv_decode_attention(q, k, v, cache_len, *, axis_name, scale=None,
                              window=0, softcap: float = 0.0):
    """Call inside shard_map with k/v sharded on their seq dim.

    q: (B, 1, Hq, D) replicated; k, v: (B, T_loc, Hkv, D) local shard of a
    cache whose global length is T_loc * axis_size; cache_len: () valid
    global prefix; window: optional (traced ok) sliding window (0=full).
    Returns (B, 1, Hq, D).
    """
    B, _, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    scale = (1.0 / D ** 0.5) if scale is None else scale
    t_loc = k.shape[1]
    idx = jax.lax.axis_index(axis_name)
    pos = idx * t_loc + jnp.arange(t_loc)                    # global positions
    ok = pos < cache_len
    if not (isinstance(window, int) and window == 0):
        w = jnp.asarray(window)
        ok &= (pos >= cache_len - w) | (w <= 0)
    valid = jnp.broadcast_to(ok[None, :], (B, t_loc))
    qg = q.reshape(B, 1, Hkv, G, D)
    m, l, acc = split_kv_partial(qg, k, v, scale=scale, valid=valid,
                                 softcap=softcap)
    out = split_kv_combine(m, l, acc, axis_name)
    return out.reshape(B, 1, Hq, D).astype(q.dtype)


def pipelined_all_to_all(x, axis_name, n_chunks: int):
    """a2a over dim 0 (= axis size), chunked along dim 1 via scan."""
    S, C = x.shape[0], x.shape[1]
    assert C % n_chunks == 0
    xc = x.reshape(S, n_chunks, C // n_chunks, *x.shape[2:])
    xc = jnp.moveaxis(xc, 1, 0)

    def step(_, chunk):
        return None, jax.lax.all_to_all(chunk, axis_name, 0, 0, tiled=True)

    _, out = jax.lax.scan(step, None, xc)
    out = jnp.moveaxis(out, 0, 1).reshape(S, C, *x.shape[2:])
    return out
