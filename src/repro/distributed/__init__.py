"""Distribution: sharding rules, explicit collectives, compression."""
from repro.distributed import collectives, compression, sharding  # noqa: F401
