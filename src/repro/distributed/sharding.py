"""Logical-axis sharding rules -> NamedSharding trees (t5x-style).

One rules table maps logical parameter axes to (tuples of) mesh axes; a
fallback pass hands unused mesh axes to alternative dims (e.g. when
``n_kv_heads`` isn't divisible by the model axis, the kv projection shards
its ``head_dim`` instead of replicating — the divisibility logic lives HERE
and nowhere else, so §Perf sharding experiments are one-table edits).

The same machinery shards parameters, optimizer moments (same tree),
activations/inputs, and decode caches.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.modules import ParamSpec, is_spec, tree_map_specs

# default parallelism plan: FSDP over "data", TP/EP over "model",
# pure DP over "pod" (params replicated across pods).
DEFAULT_RULES: dict = {
    "vocab": ("model",),
    "embed": ("data",),          # ZeRO-3: shard params over the data axis
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (),              # only via fallback
    "mlp": ("model",),
    "expert": ("model",),
    "layers": (),                # scan dim, never sharded
    "batch": ("pod", "data"),
    "seq": (),
    "state": (),
    None: (),
}

# when a mesh axis goes unused in a param, try these logical dims (in order).
# NOTE deliberately NO "head_dim" fallback: sharding a QKV projection's
# head_dim while Q is head-sharded forces GSPMD to all-gather K/V inside
# the attention loop (measured: +0.5 GB/chunk-step on qwen3) — kv
# projections with n_kv % model != 0 stay replicated over "model" instead
# (they are small), and attention still shards via Q heads / Q sequence.
# "seq" fallback on the model axis: KV caches whose head counts don't
# divide the model axis (gemma2 kv=8, minicpm kv=36, whisper kv=20, ...)
# shard their sequence dim instead — decode attention then runs split-KV
# (each rank scans its cache slice; GSPMD combines) and a 32k x 128 cache
# drops from ~90 GB/chip (batch-only) to ~5 GB/chip.
FALLBACKS: dict = {
    "model": ("mlp", "vocab", "seq"),
    "data": ("mlp", "vocab", "seq"),
    "pod": (),
}

# Inference layout (§Perf hillclimb 1): weights stay RESIDENT — no ZeRO
# over "data" (training amortizes the per-layer weight all-gather over a
# 65k-token batch; decode re-pays it every token, which measured as 30k x
# more collective time than compute).  Weights replicate over "data"
# unless they are too big (MoE experts pick up "data" on the ff dim via
# the fallback, giving arctic 3.7 GB/chip with no per-step gather).
SERVE_RULES: dict = dict(DEFAULT_RULES)
SERVE_RULES["embed"] = ()


def spec_to_pspec(spec: ParamSpec, mesh: Mesh,
                  rules: Mapping | None = None) -> P:
    return axes_to_pspec(spec.axes, spec.shape, mesh, rules)


def axes_to_pspec(axes: Sequence, shape: Sequence[int], mesh: Mesh,
                  rules: Mapping | None = None) -> P:
    rules = rules or DEFAULT_RULES
    msize = dict(zip(mesh.axis_names, mesh.devices.shape))
    used: set = set()
    out: list = [None] * len(shape)

    def try_assign(i: int, mesh_axes) -> None:
        take = []
        cap = shape[i]
        for m in mesh_axes:
            if m not in msize or m in used:
                continue
            if cap % msize[m] == 0 and cap >= msize[m]:
                take.append(m)
                cap //= msize[m]
                used.add(m)
        if take:
            out[i] = tuple(take) if len(take) > 1 else take[0]

    # pass 1: direct rules
    for i, ax in enumerate(axes):
        try_assign(i, rules.get(ax, ()))
    # pass 2: fallbacks for unused mesh axes
    for m, fb_axes in FALLBACKS.items():
        if m in used or m not in msize:
            continue
        for ax in fb_axes:
            i = next((j for j, a in enumerate(axes)
                      if a == ax and out[j] is None), None)
            if i is not None:
                cap = shape[i]
                if cap % msize[m] == 0 and cap >= msize[m]:
                    out[i] = m
                    used.add(m)
                    break
    return P(*out)


def param_shardings(spec_tree, mesh: Mesh, rules: Mapping | None = None):
    """ParamSpec tree -> NamedSharding tree (same structure)."""
    return tree_map_specs(
        lambda s: NamedSharding(mesh, spec_to_pspec(s, mesh, rules)),
        spec_tree)


def param_pspecs(spec_tree, mesh: Mesh, rules: Mapping | None = None):
    return tree_map_specs(lambda s: spec_to_pspec(s, mesh, rules), spec_tree)


def like_tree(shardings, abstract):
    """Re-associate a sharding tree with an identically-structured value
    tree (e.g. optimizer moments mirroring params)."""
    return jax.tree_util.tree_map(lambda _, s: s, abstract, shardings)


def array_sharding(axes: Sequence, shape: Sequence[int], mesh: Mesh,
                   rules: Mapping | None = None) -> NamedSharding:
    return NamedSharding(mesh, axes_to_pspec(axes, shape, mesh, rules))


def bytes_per_device(tree_of_sds, shardings) -> int:
    """Host-side estimate of per-device bytes for a ShapeDtypeStruct tree
    with the given shardings (used by the dry-run report)."""
    total = 0
    flat_v, _ = jax.tree_util.tree_flatten(tree_of_sds)
    flat_s, _ = jax.tree_util.tree_flatten(
        shardings, is_leaf=lambda x: isinstance(x, NamedSharding))
    for v, s in zip(flat_v, flat_s):
        n = int(np.prod(v.shape)) * v.dtype.itemsize
        shards = 1
        spec = s.spec
        msize = dict(zip(s.mesh.axis_names, s.mesh.devices.shape))
        for entry in spec:
            if entry is None:
                continue
            for m in (entry if isinstance(entry, tuple) else (entry,)):
                shards *= msize[m]
        total += n // max(shards, 1)
    return total
