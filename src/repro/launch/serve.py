"""Serving launcher CLI: batched generation through the engine.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch gemma2_9b --reduced \\
      --requests 8 --max-new 16
"""
import argparse
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--fake-devices", type=int, default=0)
    from repro.obs import log as obs_log
    obs_log.add_log_args(ap)
    args = ap.parse_args()
    log = obs_log.setup_logging("INFO", quiet=args.quiet,
                                verbose=args.verbose)

    if args.fake_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.fake_devices} "
            + os.environ.get("XLA_FLAGS", ""))

    import jax
    import numpy as np

    from repro.configs import get_config, reduced as reduce_cfg
    from repro.models import build
    from repro.serve.engine import Engine, Request, ServeConfig

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, ServeConfig(slots=args.slots, max_len=args.max_len,
                                    max_new_tokens=args.max_new,
                                    temperature=args.temperature))
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        extras = {}
        if cfg.family == "audio":
            extras["enc_frames"] = rng.normal(
                size=(1, cfg.enc_ctx, cfg.d_model)).astype(np.float32)
        reqs.append(Request(
            rid=i,
            prompt=rng.integers(3, cfg.vocab,
                                size=int(rng.integers(4, 12))).astype(np.int32),
            extras=extras or None))
    out = eng.generate_batch(params, reqs)
    for rid in sorted(out):
        log.info("req %d: %d tokens -> %s",
                 rid, len(out[rid]), list(out[rid][:10]))
    return 0


if __name__ == "__main__":
    sys.exit(main())
