"""Generate EXPERIMENTS.md from the dry-run report JSONs + the static
hillclimb log (kept here so the document regenerates with fresh numbers:
``PYTHONPATH=src python -m repro.launch.experiments_md > EXPERIMENTS.md``).
"""
from __future__ import annotations

import json
import os

from repro.launch.report import dryrun_table, roofline_table

RDIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "reports")
RDIR = os.path.abspath(os.path.join(os.path.dirname(__file__),
                                    "../../..", "reports"))


def load(name):
    with open(os.path.join(RDIR, name)) as f:
        return json.load(f)


def cell(reports, arch, shape):
    for r in reports:
        if r["arch"] == arch and r["shape"] == shape and r["status"] == "ok":
            return r
    return None


def fmt_cell(r):
    t = r["roofline"]
    c = r["collectives"]["bytes_by_kind"]
    return (f"t_comp={t['t_compute']*1e3:.1f}ms t_mem={t['t_memory']*1e3:.1f}ms "
            f"t_coll={t['t_collective']*1e3:.1f}ms frac={t['roofline_fraction']:.3f} "
            f"[AG={c['all-gather']/1e9:.1f} AR={c['all-reduce']/1e9:.1f} "
            f"A2A={c['all-to-all']/1e9:.1f} GB]")


HEADER = """# EXPERIMENTS — BrainScaleS/Extoll spike communication on JAX/TPU

All numbers in this file regenerate from committed artifacts:
`reports/dryrun_*.json` (produced by `python -m repro.launch.dryrun`) and
`python -m benchmarks.run`.  Hardware model: TPU v5e — 197 TFLOP/s bf16,
819 GB/s HBM, 50 GB/s/link ICI, 16 GiB HBM per chip; meshes 16x16
(single pod, 256 chips) and 2x16x16 (two pods, 512 chips).

## §Paper-claims validation (the faithful reproduction)

The paper's quantitative content and what the implementation measures
(benchmarks: `python -m benchmarks.run`, tests: `tests/test_core.py`):

| Paper claim | Our measurement | Status |
|---|---|---|
| single 30-bit events shift out at 1 event / 2 clocks (header overhead) | cycle model, all-distinct destinations: **0.5025 events/clock** delivered (`aggregation/model/unaggregated`) ; analytic `wire_cycles(1) == 2` | reproduced |
| events arrive at up to 1 / clock from 8 HICANNs -> un-aggregated path cannot keep up | 48.6% of offered events stall at the un-aggregated port | reproduced |
| max Extoll payload 496 B = 124 events | `PACKET_MAX_EVENTS == 124`; 124-event packet = 32 clocks = 3.875 events/clock drain headroom | reproduced |
| aggregation abates the shortcoming | same offered load, aggregatable destinations: **0.89 events/clock** delivered, zero stalls (1.77x; bounded by the 4-dest random traffic, not the port) | reproduced |
| bucket renaming (map table + free list, evict most urgent) with B << 2^16 destinations | 2 physical buckets serving 32 active destinations: zero lost events, conservation exact (`test_bucket_renaming_pressure`, `test_bucket_conservation`) | verified |
| deadline flush (timestamp = arrival deadline) | windowed exchange keeps deadline misses at **0** for admissible traffic; misses appear only when margins are made impossibly tight (`renaming/margin` sweep) | verified |
| concurrent flush + aggregation (two-counter swap) | bucket accepts new events in the same cycle a flush drains (cycle model; throughput test would halve without it) | verified |
| ring-buffer credit flow control (FPGA->host) | closed loop: throughput = min(1, slots/latency) exactly; producer never overruns (`ringbuffer/*`) | verified |
| full-scale cortical microcircuit as target workload | 4-shard reduced-scale Potjans-Diesmann over the bucket fabric: 0 deadline misses, aggregation saves 5.4x wire bytes (`examples/multiwafer_microcircuit.py`) | runs |

"""

DRYRUN_INTRO = """## §Dry-run

Every (architecture x shape) cell is lowered and compiled against the full
production mesh with `jax.ShapeDtypeStruct` inputs (no allocation):
`train_4k` lowers `train_step` (fwd+bwd+optimizer, donated state),
`prefill_32k` lowers cache-filling prefill, `decode_*` lower `serve_step`
(one token against a seq_len KV cache).  `long_500k` runs for the two
sub-quadratic architectures (mamba2, recurrentgemma) and is skipped for the
eight full-attention architectures per the assignment (noted in DESIGN.md
§5).  Whisper (enc-dec) runs decode shapes against its decoder with the
1500-frame encoder-context stub.

Columns: compile wall-time (1 CPU core), per-chip resident state from the
sharding plan (params + optimizer + caches), and per-chip collective bytes
by kind parsed from the compiled HLO (while-loop bodies scaled by trip
count).

Memory-fit note: `memory_analysis().temp_size` on the XLA:CPU backend
includes f32 copies of bf16 weights (CPU has no native bf16 matmul and
legalizes `dot(bf16)` to f32, hoisting whole-stack converts out of the
layer loop). These copies do not exist on TPU; the fits-in-HBM criterion
is therefore per-chip resident state + analytic activation bounds (both
reported), and every cell passes it.
"""


def perf_section(base_s, opt_s):
    qd_b = cell(base_s, "qwen3_32b", "decode_32k")
    qd_o = cell(opt_s, "qwen3_32b", "decode_32k")
    dt_b = cell(base_s, "deepseek_moe_16b", "train_4k")
    dt_o = cell(opt_s, "deepseek_moe_16b", "train_4k")
    qt_b = cell(base_s, "qwen3_32b", "train_4k")
    qt_o = cell(opt_s, "qwen3_32b", "train_4k")

    def row(r):
        return fmt_cell(r) if r else "n/a"

    return f"""## §Perf — hillclimbing log (hypothesis -> change -> measure)

Three cells selected per the assignment: the *worst-roofline family*
(decode: every decode cell sat at frac ~0.000, 5,000-65,000x
collective-over-compute), the *most paper-representative* (deepseek-moe
train: token->expert dispatch IS the paper's bucket aggregation), and the
*most collective-bound large train cell* (qwen3-32b train).  Baselines are
the first coherent full sweep (`reports/dryrun_*_baseline.json`); the
optimized run is `reports/dryrun_*_optimized.json`.

### Cell 1 — qwen3-32b x decode_32k (collective-bound decode)

| iteration | hypothesis | measured |
|---|---|---|
| baseline (FSDP layout) | — | {row(qd_b)} |
| 1. resident-weight serve layout (`SERVE_RULES`: no ZeRO over data at inference) | training amortizes per-layer weight AG over 65k tokens; decode re-pays it per token -> dropping FSDP removes ~500 GB/step of AG at +4.1 GB/chip resident bf16 params | AG 508 GB -> 71 GB/chip-step; still cache-AG bound |
| 2. split-KV flash-decoding (`--split-kv`): cache stays seq-sharded, per-rank partial attention + logsumexp-combine psum | remaining 71 GB = per-layer cache gather (64 x 1.1 GB); split-KV replaces it with a (B,1,H) psum ~ 17 MB | {row(qd_o)} |

Outcome: collective bytes **508 GB -> 0.05 GB per token-step (~10,000x)**;
the cell is now memory-bound exactly at its HBM floor (params + cache read
once per token), which is the decode roofline.  The same two changes apply
to every decode cell in the optimized sweep (all moved from
collective-bound to memory-bound).  CONFIRMED both iterations.

### Cell 2 — deepseek-moe-16b x train_4k (the paper's technique)

| iteration | hypothesis | measured |
|---|---|---|
| baseline (GSPMD `local` dispatch) | — | {row(dt_b)} |
| 1. bucket dispatch (`--moe-impl bucket`): capacity-binned buckets + explicit all_to_all over the EP axis (the paper's aggregate-then-route) | shipping tokens (top-6 x 2048 x bf16) beats GSPMD's gather-heavy dispatch | frac 0.014 -> 0.022, but A2A measured 659 GB — 16x the napkin estimate |
| 2. **seq-shard tokens into the dispatch** (in_specs `P(batch, "model", None)`) | 659/41 ~ 16 = the EP axis size: every model-rank was routing ALL tokens and the a2a carried 16 identical copies; binding tokens to their SP shard removes the redundancy | {row(dt_o)} |

Outcome: **frac 0.014 -> 0.118 (8.4x)**; A2A 659 -> 41 GB; temp memory 39.8
-> 9.5 GB/chip.  Iteration 1's hypothesis was only half right (the
mechanism was good, the layout wasted it) — the refutation localized the
real bug.  CONFIRMED after refinement.

### Cell 3 — qwen3-32b x train_4k (collective-bound dense train)

| iteration | hypothesis | measured |
|---|---|---|
| early baseline (head-grouped GQA + naive loss layout) | — | frac 0.114, temp 27.3 GB (did not fit) |
| 1. context-parallel attention + chunk-level remat: Q seq-sharded, KV replicated, `(Hkv,G)` grouped math, `jax.checkpoint` on the flash chunk step | the (Hkv,G) reshape is harmless once heads are replicated per rank; rematting the chunk drops the stored f32 p-matrices (4.3 GB/layer) | frac 0.114 -> **0.294**; temp 27.3 -> 14.8 GB (fits); in-loop dK AR shrank 8x |
| 2. pin gradients to param shardings (hoping AR -> reduce-scatter) | XLA ARs full dW tuples in the backward loop; constraining the stacked grads should legalize RS | **REFUTED** — zero change: the ARs originate inside the loop body where the constraint does not reach |
| 3. project K/V from the LOCAL sequence slice, then reshard K/V (not the residual) | GSPMD gathered full-seq h (0.67 GB bf16 / 1.3 GB as f32) per layer to build replicated K/V; gathering K/V instead moves 5x less (B,S,Hkv,Dh) | frac 0.294 -> **0.361**; AG 475 -> 338 GB | {row(qt_o)}

Remaining gap analysis (napkin): of the ~338 GB AG + 286 GB AR left, ~40%
is the XLA:CPU f32-legalization artifact (collectives carry f32 where TPU
would move bf16 — a free 2x on hardware, pushing the modeled frac to
~0.55); the rest is the per-layer dW all-reduce that GSPMD declines to
reduce-scatter inside the loop (identified, logged as future work — a
manual shard_map backward for the MLP would force it).

### Follow-on: the cell-2 fix generalized to MoE prefill

The seq-sharded bucket dispatch was then applied to the `prefill_32k`
shapes (prefill is token-heavy like training): deepseek-moe prefill
**frac 0.008 -> 0.160 (20x)**, arctic prefill 0.023 -> 0.277 (12x) single-pod —
visible in the optimized roofline table above.  This is the hillclimb
methodology paying out: one localized hypothesis (copies across the EP
axis) fixed four cells.

### Beyond-paper optimizations (in the framework, measured above)

* split-KV flash-decoding over seq-sharded caches (cell 1) — also what
  makes gemma2/minicpm/whisper 32k decode fit HBM at all.
* context-parallel flash attention with chunk-level remat (cell 3).
* resident-weight inference layout vs ZeRO training layout, one rules-table
  switch (`distributed/sharding.py: SERVE_RULES`).
* bucket-a2a MoE dispatch (cell 2) — the paper's mechanism as EP.
* int8 error-feedback gradient compression for the cross-pod axis
  (`distributed/compression.py`, tested; reduces the pod-axis gradient
  all-reduce bytes 4x vs bf16 — applies to the multi-pod mesh's slowest
  links, exactly the paper's economy).
* Adafactor + bf16 momentum for arctic-480b (full Adam moments cannot fit
  one pod); WSD schedule for minicpm; sequence-parallel residual stream.
"""


def main():
    base_s = load("dryrun_single_pod_baseline.json")
    base_m = load("dryrun_multi_pod_baseline.json")
    opt_s = load("dryrun_single_pod_optimized.json")
    opt_m = load("dryrun_multi_pod_optimized.json")

    print(HEADER)
    print(DRYRUN_INTRO)
    print("### Single pod 16x16 (baseline layout)\n")
    print(dryrun_table(base_s))
    print("\n### Multi-pod 2x16x16 (baseline layout) — proves the pod axis "
          "shards\n")
    print(dryrun_table(base_m))
    print("""
## §Roofline

Terms per chip per step, seconds: compute = FLOPs/197e12, memory =
HBM bytes/819e9, collective = bytes/50e9 (methodology + caveats in
`launch/roofline.py`; MODEL_FLOPS = 6·N·D dense / 6·N_active·D MoE; the
`useful` column is MODEL_FLOPS/HLO_FLOPs).  `roofline frac` =
(MODEL_FLOPS/peak) / dominant term — the score the perf loop drives up.
Decode cells are intrinsically tiny-frac (one token amortizes nothing);
for them the meaningful target is the memory term reaching the
params+cache read floor, which the optimized cells do.

### Baseline, single pod
""")
    print(roofline_table(base_s))
    print("\n### Optimized (serve rules + split-KV + bucket EP), single pod\n")
    print(roofline_table(opt_s))
    print("\n### Optimized, multi-pod 2x16x16\n")
    print(roofline_table(opt_m))
    print("\n¹ long_500k requires a sub-quadratic path; the eight "
          "full-attention architectures are excluded per the assignment "
          "(DESIGN.md §5) — mamba2 (SSM state) and recurrentgemma "
          "(RG-LRU + 2048-window ring cache) run it.\n")
    print(perf_section(base_s, opt_s))


if __name__ == "__main__":
    main()
