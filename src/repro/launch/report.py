"""Render EXPERIMENTS.md tables from dry-run report JSONs."""
from __future__ import annotations

import json
import sys


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def roofline_table(reports):
    hdr = ("| arch | shape | mesh | t_compute | t_memory | t_collective | "
           "bottleneck | useful(6ND/HLO) | roofline frac | GB/chip |")
    sep = "|" + "---|" * 10
    rows = [hdr, sep]
    for r in reports:
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"— | — | — | skipped¹ | — | — | — |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"ERROR | | | | | | |")
            continue
        t = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{fmt_s(t['t_compute'])} | {fmt_s(t['t_memory'])} | "
            f"{fmt_s(t['t_collective'])} | {t['bottleneck']} | "
            f"{t['useful_ratio']:.2f} | {t['roofline_fraction']:.3f} | "
            f"{r['per_chip_state_bytes'] / 1e9:.2f} |")
    return "\n".join(rows)


def dryrun_table(reports):
    hdr = ("| arch | shape | mesh | compile | GB/chip state | fits HBM | "
           "AG GB | AR GB | A2A GB | CP GB |")
    sep = "|" + "---|" * 10
    rows = [hdr, sep]
    for r in reports:
        if r["status"] != "ok":
            continue
        c = r["collectives"]["bytes_by_kind"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['compile_s']}s | {r['per_chip_state_bytes'] / 1e9:.2f} | "
            f"{'yes' if r['fits_hbm'] else 'NO'} | "
            f"{c['all-gather'] / 1e9:.1f} | {c['all-reduce'] / 1e9:.1f} | "
            f"{c['all-to-all'] / 1e9:.1f} | "
            f"{c['collective-permute'] / 1e9:.1f} |")
    return "\n".join(rows)


def main():
    out = []
    for path in sys.argv[1:]:
        with open(path) as f:
            reports = json.load(f)
        out.append(f"### {path}\n")
        out.append(roofline_table(reports))
        out.append("")
    print("\n".join(out))


if __name__ == "__main__":
    main()
