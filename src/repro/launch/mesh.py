"""Production mesh construction.

The physical analogy is direct: a TPU v5e pod's ICI is a torus exactly like
the paper's Extoll fabric; ``("data", "model")`` maps DP/FSDP onto long
torus dimensions and TP onto the short ones, and the ``pod`` axis is the
inter-pod DCN — the BrainScaleS wafer-to-wafer hop (paper Fig. 1).

The spike fabric runs on a 1-D ``"wafer"`` axis
(:func:`make_wafer_mesh`); how a flush window crosses it is the
*transport* choice (``repro.transport``): ``"alltoall"`` treats the axis
as a crossbar (one global collective), ``"torus2d"`` / ``"torus3d"`` fold
it onto (nx, ny[, nz]) rings (:func:`wafer_torus_shape`) and ship
neighbor ``ppermute`` hops with hop-by-hop credit-based link flow
control — the same coordinates ``core.torus`` reasons about on the host
(``torus3d``'s Z rings are the wafer-stacking axis).

NOTE: functions, not module constants — importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_data: int = 2, n_model: int = 4, pods: int = 0):
    """Small mesh for CPU tests (requires forced host device count)."""
    if pods:
        return jax.make_mesh((pods, n_data, n_model),
                             ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def batch_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def make_wafer_mesh(n_shards: int, axis: str = "wafer"):
    """1-D mesh for the spike-exchange fabric (one device per shard)."""
    return jax.make_mesh((n_shards,), (axis,))


def wafer_torus_shape(n_shards: int, ndim: int = 2) -> tuple:
    """The rings a torus transport folds ``n_shards`` onto.

    ``ndim=2``: most-square (nx, ny); 8 shards -> (2, 4), the paper's
    per-wafer concentrator face.  ``ndim=3``: most-cubic (nx, ny, nz);
    8 shards -> (2, 2, 2).  Wafer-stacked deployments that want the
    paper's (2, 4, n_wafers) arrangement pass the shape explicitly via
    ``torus_nx``/``ny``/``nz`` instead.
    """
    from repro.transport.torus import default_shape, default_shape3d
    if ndim == 3:
        return default_shape3d(n_shards)
    return default_shape(n_shards)


def wafer_wire_format(profile: str = "extoll"):
    """The wire protocol profile of the wafer fabric's links.

    The physical analogy again: the ICI torus is the Extoll fabric
    (``"extoll"``: 64-byte cells, ~16 B/frame tax, sub-µs cut-through
    hops), the DCN pod hop is the commodity comparison (``"ethernet"``:
    full Eth+IP+UDP stack, minimum frames, store-and-forward switches).
    Returns the :class:`repro.wire.framing.WireFormat` used by the
    transports' frame-exact ``bytes_on_wire`` accounting and the
    per-event latency model.
    """
    from repro.wire import get_profile
    return get_profile(profile)
