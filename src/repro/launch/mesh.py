"""Production mesh construction.

The physical analogy is direct: a TPU v5e pod's ICI is a torus exactly like
the paper's Extoll fabric; ``("data", "model")`` maps DP/FSDP onto long
torus dimensions and TP onto the short ones, and the ``pod`` axis is the
inter-pod DCN — the BrainScaleS wafer-to-wafer hop (paper Fig. 1).

NOTE: functions, not module constants — importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_data: int = 2, n_model: int = 4, pods: int = 0):
    """Small mesh for CPU tests (requires forced host device count)."""
    if pods:
        return jax.make_mesh((pods, n_data, n_model),
                             ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def batch_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
