"""Launchers: production mesh, multi-pod dry-run, roofline, train/serve CLIs.
NOTE: dryrun must be run as its own process (it forces 512 host devices)."""
