"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds:

  compute    = HLO_FLOPs / (chips x 197e12 bf16 FLOP/s)
  memory     = HLO_bytes / (chips x 819e9 B/s HBM)
  collective = collective_bytes / (chips x 50e9 B/s ICI link)

Sources & caveats (documented, not hidden):

* ``compiled.cost_analysis()`` supplies flops/bytes where the backend
  reports them.  XLA:CPU counts a while-loop body ONCE, so scanned-layer
  models under-report by ~n_layers; we therefore also compute analytic
  MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) and scale loop bodies.
* collective bytes are parsed from ``compiled.as_text()``: every
  all-gather / all-reduce / reduce-scatter / all-to-all /
  collective-permute contributes its result-shape bytes; ops inside a
  while-body computation are multiplied by the loop's trip count, taken
  from XLA's ``known_trip_count`` annotation when present, else from the
  caller-supplied default (= n_layers for the layer scan).
* per-chip collective bytes: HLO shapes are already per-partition under
  SPMD, so the parsed bytes are what one chip moves; ICI serialization is
  approximated as bytes / link_bw (one link active per op — conservative).
"""
from __future__ import annotations

import dataclasses
import json
import re

import numpy as np

PEAK_FLOPS = 197e12          # TPU v5e bf16
HBM_BW = 819e9               # B/s
ICI_BW = 50e9                # B/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0,
}

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_RE = re.compile(r"^\s*%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*{\s*$")
_WHILE_RE = re.compile(r"while\(")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count=\{n=(\d+)\}|"known_trip_count":\{"n":"(\d+)"\}')


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str, default_trip: int = 1):
    """-> dict: per-op-kind bytes (trip-count scaled), plus total.

    Strategy: split the module into computations; find while ops and their
    body computations + trip counts; bytes of collectives inside a while
    body are multiplied by that loop's trip count (nested loops multiply).
    """
    # computation name -> its text block
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY") or (line.startswith("%")
                                        and line.rstrip().endswith("{")):
            m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)", line)
            cur = m.group(1) if m else None
            comps[cur] = []
        elif cur is not None:
            comps[cur].append(line)
        if line.rstrip() == "}":
            cur = None

    # while body -> trip count, and computation -> caller multiplier
    body_trip: dict[str, int] = {}
    callers: dict[str, list[str]] = {}
    for name, lines in comps.items():
        for ln in lines:
            if _WHILE_RE.search(ln):
                bm = _BODY_RE.search(ln)
                if not bm:
                    continue
                body = bm.group(1)
                tm = _TRIP_RE.search(ln)
                trip = int(next(g for g in tm.groups() if g)) if tm \
                    else default_trip
                body_trip[body] = trip
                callers.setdefault(body, []).append(name)

    def multiplier(comp: str, seen=()) -> int:
        if comp in seen:
            return 1
        m = body_trip.get(comp, 1)
        for parent in callers.get(comp, []):
            m *= multiplier(parent, seen + (comp,))
        return m

    out = {k: 0 for k in ("all-gather", "all-reduce", "reduce-scatter",
                          "all-to-all", "collective-permute")}
    counts = dict(out)
    for name, lines in comps.items():
        mult = multiplier(name)
        for ln in lines:
            m = _COLL_RE.search(ln)
            if not m or (m.group(3) == "-done"):
                continue
            b = _shape_bytes(m.group(1))
            out[m.group(2)] += b * mult
            counts[m.group(2)] += mult
    total = sum(out.values())
    return {"bytes_by_kind": out, "count_by_kind": counts,
            "total_bytes": total}


@dataclasses.dataclass
class RooflineTerms:
    flops: float                 # per-chip FLOPs (best estimate)
    hbm_bytes: float             # per-chip bytes accessed
    coll_bytes: float            # per-chip collective bytes
    model_flops: float           # analytic 6*N*D (global, per chip below)
    chips: int

    @property
    def t_compute(self):
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self):
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self):
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self):
        t = {"compute": self.t_compute, "memory": self.t_memory,
             "collective": self.t_collective}
        return max(t, key=t.get)

    @property
    def useful_ratio(self):
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self):
        """useful-FLOPs time / dominant term = achievable MFU bound."""
        t_star = max(self.t_compute, self.t_memory, self.t_collective)
        return (self.model_flops / PEAK_FLOPS) / t_star if t_star else 0.0

    def to_dict(self):
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes, "model_flops": self.model_flops,
            "chips": self.chips, "t_compute": self.t_compute,
            "t_memory": self.t_memory, "t_collective": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops_estimate(cfg, shape, n_layers_scale: bool = True) -> float:
    """Analytic 6*N*D (+ attention quadratic term) global FLOPs.

    N counts *active* parameters for MoE.  For decode, D = new tokens
    (batch x 1) and attention reads the whole cache (memory-bound anyway).
    """
    from repro.models.modules import param_count

    def active_params():
        from repro.models.model import build
        specs = build(cfg).specs()
        total = param_count(specs)
        if cfg.moe:
            n_moe_layers = cfg.n_layers - cfg.moe.first_dense
            per_expert = 3 * cfg.d_model * cfg.moe.expert_ff
            routed_total = n_moe_layers * cfg.moe.n_experts * per_expert
            routed_active = n_moe_layers * cfg.moe.top_k * per_expert
            total = total - routed_total + routed_active
        # embeddings don't matmul in the forward (gather)
        total -= cfg.vocab * cfg.d_model
        return total

    n = active_params()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        base = 6.0 * n * tokens
        # attention scores+values: 12 * L * H*Dh * S^2 * B (fwd+bwd ~3x fwd)
        attn = 12.0 * cfg.n_layers * cfg.n_heads * cfg.head_dim \
            * shape.seq_len ** 2 * shape.global_batch if cfg.n_kv_heads else 0
        return base + attn
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        base = 2.0 * n * tokens
        attn = 4.0 * cfg.n_layers * cfg.n_heads * cfg.head_dim \
            * shape.seq_len ** 2 * shape.global_batch if cfg.n_kv_heads else 0
        return base + attn
    # decode: one token per sequence
    tokens = shape.global_batch
    base = 2.0 * n * tokens
    attn = 4.0 * cfg.n_layers * cfg.n_heads * cfg.head_dim \
        * shape.seq_len * shape.global_batch if cfg.n_kv_heads else 0
    return base + attn


def terms_from_compiled(compiled, cfg, shape, chips: int,
                        default_trip: int | None = None) -> RooflineTerms:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0] if cost else {}
    flops_reported = float(cost.get("flops", 0.0))
    bytes_reported = float(cost.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    coll = parse_collectives(hlo, default_trip or cfg.n_layers)
    mf_global = model_flops_estimate(cfg, shape)
    mf_chip = mf_global / chips
    # reported flops are per-partition post-SPMD but count loop bodies once;
    # trust max(reported, analytic-per-chip) as the compute estimate.
    flops = max(flops_reported, mf_chip)
    return RooflineTerms(
        flops=flops,
        hbm_bytes=bytes_reported,
        coll_bytes=float(coll["total_bytes"]),
        model_flops=mf_chip,
        chips=chips,
    ), coll
