import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input shape) cell and both production meshes
(single-pod 16x16 and multi-pod 2x16x16 = 512 chips), this driver:

  1. builds the jitted step (train_step / prefill / serve_step) with the
     full-size config — inputs are ``jax.ShapeDtypeStruct`` stand-ins, so
     nothing is allocated,
  2. ``.lower(...).compile()`` — any sharding mismatch, non-divisible
     partition, unsupported collective or compile-time OOM fails the cell,
  3. records ``memory_analysis()`` (fits-in-HBM proof), ``cost_analysis()``
     and the parsed collective schedule into a JSON report consumed by
     EXPERIMENTS.md §Dry-run / §Roofline and the perf loop.

Usage:
  python -m repro.launch.dryrun --arch qwen3_32b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out report.json]
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config, list_configs
from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed import sharding as shd
from repro.launch.mesh import batch_axes, make_production_mesh
from repro.launch import roofline as rf
from repro.models.model import build
from repro.models.modules import param_bytes
from repro.models.transformer import Runtime
from repro.obs import log as obs_log
from repro.train import optimizer as opt_lib
from repro.train import step as step_lib

HBM_PER_CHIP = 16 * 1024 ** 3          # TPU v5e


# ---------------------------------------------------------------------------
# per-arch training policy (what a job config would set)
# ---------------------------------------------------------------------------

def train_policy(cfg: ModelConfig):
    if cfg.name == "arctic-480b":
        # 468B params: f32 Adam moments can't fit one pod -> Adafactor,
        # bf16 params + bf16 momentum (documented in DESIGN.md §4).
        ocfg = opt_lib.OptimizerConfig(kind="adafactor",
                                       momentum_dtype="bfloat16")
        return step_lib.TrainConfig(optimizer=ocfg), jnp.bfloat16
    return step_lib.TrainConfig(), jnp.float32


def make_runtime(mesh, *, train: bool, moe_impl: str = "local",
                 seq_axis=None, split_kv: bool = False) -> Runtime:
    return Runtime(
        mesh=mesh,
        batch_axes=batch_axes(mesh),
        moe_impl=moe_impl,
        remat=train,
        seq_axis=("model" if train else None) if seq_axis is None else seq_axis,
        split_kv_axis="model" if split_kv else None,
        attn_chunk=1024,
        logits_chunk=512,
    )


# ---------------------------------------------------------------------------
# abstract inputs (ShapeDtypeStruct stand-ins; never allocated)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Abstract model inputs for one cell (tokens/labels + modality stubs)."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        batch = {"tokens": _sds((B, 1), jnp.int32)}
    else:
        batch = {"tokens": _sds((B, S), jnp.int32)}
        if shape.kind == "train":
            batch["labels"] = _sds((B, S), jnp.int32)
    if cfg.family == "vlm" and shape.kind != "decode":
        batch["positions3"] = _sds((3, B, S), jnp.int32)
        batch["vision_embeds"] = _sds((B, cfg.vision_tokens, cfg.d_model),
                                      jnp.bfloat16)
    if cfg.family == "audio" and shape.kind != "decode":
        batch["enc_frames"] = _sds((B, cfg.enc_ctx, cfg.d_model),
                                   jnp.bfloat16)
    return batch


_BATCH_AXES_MAP = {
    "tokens": ("batch", "seq"),
    "labels": ("batch", "seq"),
    "positions3": (None, "batch", "seq"),
    "vision_embeds": ("batch", None, "embed"),
    "enc_frames": ("batch", None, None),
}


def batch_shardings(batch, mesh):
    out = {}
    for k, v in batch.items():
        axes = _BATCH_AXES_MAP[k]
        out[k] = shd.array_sharding(axes[: len(v.shape)], v.shape, mesh)
    return out


def cache_shardings(caches_abs, mesh):
    """Heuristic logical axes for cache arrays by position/name."""
    def one(path, v):
        nd = len(v.shape)
        if nd == 0 or v.shape == ():
            return NamedSharding(mesh, P())
        # stacked (L, B, T, H, D) / (L, B, H, P, N) / (L, B, K, W) etc:
        axes = [None] * nd
        axes[0] = "layers"
        if nd >= 2:
            axes[1] = "batch"
        if nd == 5:
            axes[2], axes[3], axes[4] = "seq", "kv_heads", "head_dim"
        elif nd == 4:
            axes[2], axes[3] = None, "mlp"
        elif nd == 3:
            axes[2] = "mlp"
        return shd.array_sharding(tuple(axes), v.shape, mesh)

    return jax.tree_util.tree_map_with_path(
        lambda p, v: one(p, v), caches_abs)


# ---------------------------------------------------------------------------
# cell builders: (fn, abstract args, in_shardings)
# ---------------------------------------------------------------------------

def build_train_cell(cfg: ModelConfig, shape: ShapeConfig, mesh,
                     moe_impl: str = "local", seq_axis=None,
                     grad_rs: bool = False):
    model = build(cfg)
    tcfg, pdtype = train_policy(cfg)
    rt = make_runtime(mesh, train=True, moe_impl=moe_impl, seq_axis=seq_axis)
    pspecs = shd.param_shardings(model.specs(), mesh)
    if grad_rs:
        import dataclasses as _dc
        rt = _dc.replace(rt, grad_specs=pspecs)
    train_step = step_lib.make_train_step(model, tcfg, rt)
    state_abs = step_lib.abstract_train_state(model, tcfg, pdtype)
    # moments / master share the param tree's shardings leaf-for-leaf
    state_sh = {
        "params": jax.tree_util.tree_map(
            lambda s, _: s, pspecs, state_abs["params"]),
        "opt": _opt_shardings(state_abs["opt"], pspecs, mesh),
        "step": NamedSharding(mesh, P()),
    }
    batch = input_specs(cfg, shape)
    bsh = batch_shardings(batch, mesh)
    return (train_step, (state_abs, batch), (state_sh, bsh), model)


def _opt_shardings(opt_abs, pspecs, mesh):
    """Moments mirror params; factored stats replicate their reduced dim."""
    rep = NamedSharding(mesh, P())

    def walk(abs_node, spec_node):
        if isinstance(abs_node, dict):
            return {k: walk(abs_node[k], spec_node[k]) for k in abs_node}
        if hasattr(abs_node, "shape"):
            if (hasattr(spec_node, "spec")
                    and len(abs_node.shape) == len(spec_node.spec)):
                return spec_node
            return rep
        return rep

    if hasattr(opt_abs, "_fields"):      # AdamWState / AdafactorState
        reps = {}
        for f in opt_abs._fields:
            sub = getattr(opt_abs, f)
            if f in ("m", "v") and isinstance(sub, dict):
                reps[f] = walk(sub, pspecs)
            elif isinstance(sub, dict):
                reps[f] = jax.tree_util.tree_map(lambda _: rep, sub)
            else:
                reps[f] = rep
        return type(opt_abs)(**reps)
    return jax.tree_util.tree_map(lambda _: rep, opt_abs)


def build_prefill_cell(cfg: ModelConfig, shape: ShapeConfig, mesh,
                       moe_impl: str = "local", rules=None):
    model = build(cfg)
    # prefill is long-sequence: sequence-parallel residual + (for MoE)
    # seq-sharded bucket dispatch apply just as in training
    rt = make_runtime(mesh, train=False, moe_impl=moe_impl,
                      seq_axis="model")
    pspecs = shd.param_shardings(model.specs(), mesh, rules)
    params_abs = model.abstract(jnp.bfloat16)
    caches_abs = jax.eval_shape(
        lambda: model.init_caches(shape.global_batch, shape.seq_len))
    csh = cache_shardings(caches_abs, mesh)
    batch = input_specs(cfg, shape)
    bsh = batch_shardings(batch, mesh)
    fn = lambda p, b, c: model.prefill(p, b, c, rt)
    return (fn, (params_abs, batch, caches_abs), (pspecs, bsh, csh), model)


def build_decode_cell(cfg: ModelConfig, shape: ShapeConfig, mesh,
                      moe_impl: str = "local", rules=None,
                      split_kv: bool = False):
    model = build(cfg)
    rt = make_runtime(mesh, train=False, moe_impl=moe_impl,
                      split_kv=split_kv)
    pspecs = shd.param_shardings(model.specs(), mesh, rules)
    params_abs = model.abstract(jnp.bfloat16)
    caches_abs = jax.eval_shape(
        lambda: model.init_caches(shape.global_batch, shape.seq_len))
    csh = cache_shardings(caches_abs, mesh)
    tokens = _sds((shape.global_batch, 1), jnp.int32)
    tsh = shd.array_sharding(("batch", None), tokens.shape, mesh)
    fn = lambda p, c, t: model.decode(p, c, t, rt)
    return (fn, (params_abs, caches_abs, tokens), (pspecs, csh, tsh), model)


# ---------------------------------------------------------------------------

def cell_skip_reason(cfg: ModelConfig, shape: ShapeConfig) -> str | None:
    if shape.name == "long_500k" and not cfg.subquadratic:
        return ("skip: pure full-attention arch at 524288-token KV — "
                "quadratic-attention cell excluded per assignment; see "
                "DESIGN.md §5")
    return None


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             moe_impl: str = "local", seq_axis=None, verbose: bool = True,
             serve_rules: bool = False, split_kv: bool = False,
             grad_rs: bool = False):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    skip = cell_skip_reason(cfg, shape)
    report = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "moe_impl": moe_impl,
    }
    if skip:
        report["status"] = "skipped"
        report["reason"] = skip
        return report

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(mesh.devices.shape))
    t0 = time.time()
    rules = shd.SERVE_RULES if serve_rules else None
    # bucket EP dispatch applies to token-heavy shapes; decode payloads
    # are tiny and the serve layout already avoids weight motion
    if shape.kind == "decode" and moe_impl == "bucket":
        moe_impl = "local"
    if shape.kind == "train":
        fn, args, shardings, model = build_train_cell(
            cfg, shape, mesh, moe_impl, seq_axis, grad_rs=grad_rs)
    elif shape.kind == "prefill":
        fn, args, shardings, model = build_prefill_cell(
            cfg, shape, mesh, moe_impl, rules)
    else:
        fn, args, shardings, model = build_decode_cell(
            cfg, shape, mesh, moe_impl, rules, split_kv)

    with mesh:   # Mesh is its own context manager (no jax.set_mesh here)
        # donate the mutable state: train state / KV caches update in place
        donate = {"train": (0,), "prefill": (2,), "decode": (1,)}[shape.kind]
        jitted = jax.jit(fn, in_shardings=shardings, donate_argnums=donate)
        lowered = jitted.lower(*args)
        compiled = lowered.compile()

    mem = compiled.memory_analysis()
    mem_d = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            mem_d[k] = int(v)
    # per-chip state bytes from the sharding plan (host-side truth)
    args_bytes = shd.bytes_per_device(args, shardings)
    terms, coll = rf.terms_from_compiled(compiled, cfg, shape, chips)
    # analytic HBM floor: one pass over resident per-chip state
    terms.hbm_bytes = max(terms.hbm_bytes, float(args_bytes))

    report.update(
        status="ok",
        compile_s=round(time.time() - t0, 1),
        chips=chips,
        per_chip_state_bytes=int(args_bytes),
        fits_hbm=bool(args_bytes < HBM_PER_CHIP),
        memory_analysis=mem_d,
        collectives=coll,
        roofline=terms.to_dict(),
    )
    if verbose:
        log = obs_log.get_logger(__name__)
        log.info("[%s] %s x %s: OK (%ss compile, %.2f GB/chip state, "
                 "bottleneck=%s, frac=%.3f)",
                 report["mesh"], arch, shape_name, report["compile_s"],
                 args_bytes / 1e9, terms.bottleneck,
                 terms.roofline_fraction)
        log.info("  memory_analysis: %s", mem_d)
        log.info("  collective bytes: %s", coll["bytes_by_kind"])
    del compiled, lowered, jitted
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--moe-impl", default="local")
    ap.add_argument("--serve-rules", action="store_true",
                    help="resident-weight inference sharding (hillclimb 1)")
    ap.add_argument("--split-kv", action="store_true",
                    help="flash-decoding over seq-sharded cache (hillclimb)")
    ap.add_argument("--grad-rs", action="store_true",
                    help="constrain grads to param sharding (RS not AR)")
    ap.add_argument("--out", default=None)
    obs_log.add_log_args(ap)
    args = ap.parse_args()
    obs_log.setup_logging("INFO", quiet=args.quiet, verbose=args.verbose)

    cells = []
    if args.all:
        for a in list_configs():
            for s in SHAPES:
                cells.append((a, s))
    else:
        cells = [(args.arch, args.shape)]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    reports = []
    for mp in meshes:
        for a, s in cells:
            try:
                reports.append(run_cell(a, s, multi_pod=mp,
                                        moe_impl=args.moe_impl,
                                        serve_rules=args.serve_rules,
                                        split_kv=args.split_kv,
                                        grad_rs=args.grad_rs))
            except Exception as e:                       # noqa: BLE001
                traceback.print_exc()
                reports.append({"arch": a, "shape": s,
                                "mesh": "2x16x16" if mp else "16x16",
                                "status": "error", "error": repr(e)})
    if args.out:
        with open(args.out, "w") as f:
            json.dump(reports, f, indent=1)
    ok = sum(r["status"] == "ok" for r in reports)
    sk = sum(r["status"] == "skipped" for r in reports)
    err = sum(r["status"] == "error" for r in reports)
    obs_log.get_logger(__name__).info(
        "dry-run: %d ok, %d skipped, %d errors / %d cells",
        ok, sk, err, len(reports))
    return 1 if err else 0


if __name__ == "__main__":
    raise SystemExit(main())
