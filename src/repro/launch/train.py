"""Training launcher CLI.

On a real cluster every host runs this under its own process index with
``jax.distributed.initialize()`` picking up the coordinator from the
environment; on this container ``--fake-devices N`` forces N host devices
so the full mesh/sharding path is exercised.

Examples:
  # tiny smoke run, 1 device
  PYTHONPATH=src python -m repro.launch.train --arch minicpm_2b --reduced \\
      --steps 20

  # sharded run on 8 fake devices (2x4 data x model mesh)
  PYTHONPATH=src python -m repro.launch.train --arch qwen3_32b --reduced \\
      --steps 10 --fake-devices 8 --mesh 2x4
"""
import argparse
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized config of the same family")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--schedule", default="wsd",
                    choices=["wsd", "cosine", "constant"])
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--moe-impl", default="local")
    ap.add_argument("--mesh", default=None, help="e.g. 2x4 (data x model)")
    ap.add_argument("--fake-devices", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    from repro.obs import log as obs_log
    obs_log.add_log_args(ap)
    args = ap.parse_args()
    # progress defaults to INFO on stderr (a launcher's progress is not a
    # machine protocol; --quiet silences it)
    log = obs_log.setup_logging("INFO", quiet=args.quiet,
                                verbose=args.verbose)

    if args.fake_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.fake_devices} "
            + os.environ.get("XLA_FLAGS", ""))

    import jax

    from repro.configs import get_config, reduced as reduce_cfg
    from repro.data.pipeline import DataConfig
    from repro.distributed import sharding as shd
    from repro.launch.mesh import batch_axes
    from repro.models import build
    from repro.models.transformer import Runtime
    from repro.train.optimizer import OptimizerConfig, ScheduleConfig
    from repro.train.step import TrainConfig
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    model = build(cfg)

    mesh = None
    state_sh = None
    rt = Runtime()
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = jax.make_mesh((d, m), ("data", "model"))
        rt = Runtime(mesh=mesh, batch_axes=batch_axes(mesh),
                     moe_impl=args.moe_impl, remat=True)

    tcfg = TrainConfig(
        optimizer=OptimizerConfig(schedule=ScheduleConfig(
            kind=args.schedule, peak_lr=args.lr,
            warmup_steps=max(args.steps // 10, 1),
            total_steps=args.steps)),
        microbatch=args.microbatch,
    )
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch)
    trainer = Trainer(model, tcfg, dcfg,
                      TrainerConfig(steps=args.steps,
                                    ckpt_dir=args.ckpt_dir,
                                    ckpt_every=args.ckpt_every,
                                    log_every=max(args.steps // 10, 1)),
                      rt=rt, mesh=mesh, state_shardings=state_sh)
    state, history = trainer.run(seed=0)
    for h in history:
        log.info("step %5d loss %.4f lr %.2e dt %.0fms stalls %d",
                 h["step"], h["loss"], h["lr"], h["dt"] * 1e3,
                 h["producer_stalls"])
    log.info("done: %d steps; straggler events: %d",
             args.steps, trainer.straggler_events)
    return 0


if __name__ == "__main__":
    sys.exit(main())
