"""Mamba-2 SSD (state-space duality) blocks — chunked train/prefill scan and
O(1)-state decode.  Pure JAX; the chunked form is the TPU-friendly one
(dense matmuls inside chunks, one small recurrence across chunks).

Shapes: B batch, Lseq length, H heads, Pd head_dim, N d_state, G groups.
Block layout follows mamba2: in_proj -> [z | x | B | C | dt], causal
depthwise conv over [x|B|C], SSD, gated RMSNorm, out_proj.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L


class SSMCache(NamedTuple):
    conv: jax.Array       # (B, K-1, conv_dim) conv left-context
    state: jax.Array      # (B, H, Pd, N) SSD recurrent state


def dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    return d_inner, n_heads, conv_dim


def ssd_chunked(x, dt, A, B_, C_, chunk: int):
    """Chunked SSD scan.

    x:  (B, Lq, H, Pd)   inputs (already conv'd/activated)
    dt: (B, Lq, H)       positive step sizes
    A:  (H,)             negative decay rates
    B_, C_: (B, Lq, G, N)
    Returns y (B, Lq, H, Pd) and final state (B, H, Pd, N).
    """
    Bb, Lq, H, Pd = x.shape
    G, N = B_.shape[2], B_.shape[3]
    nc = Lq // chunk
    rep = H // G

    # chunk-major layout for the scan: (nc, B, chunk, ...)
    xc = jnp.moveaxis(x.reshape(Bb, nc, chunk, H, Pd), 1, 0)
    dtc = jnp.moveaxis(dt.reshape(Bb, nc, chunk, H), 1, 0)
    Bc = jnp.moveaxis(B_.reshape(Bb, nc, chunk, G, N), 1, 0)
    Cc = jnp.moveaxis(C_.reshape(Bb, nc, chunk, G, N), 1, 0)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))

    def chunk_step(S, xs):
        """One chunk: quadratic intra-chunk + carried inter-chunk state.

        Scanning keeps the (chunk x chunk) decay tensor at one-chunk size,
        which is what bounds memory at 32k/500k sequence lengths.
        """
        xj, dtj, Bj, Cj = xs
        Bh = jnp.repeat(Bj, rep, axis=2)                  # (B,c,H,N)
        Ch = jnp.repeat(Cj, rep, axis=2)
        da = dtj * A[None, None, :]                       # (B,c,H) negative
        cum = jnp.cumsum(da, axis=1)
        seg_end = cum[:, -1, :]                           # (B,H)

        diff = cum[:, :, None, :] - cum[:, None, :, :]    # (B,i,j,H)
        decay = jnp.where(causal[None, :, :, None], jnp.exp(diff), 0.0)
        scores = jnp.einsum("bihn,bjhn->bijh", Ch, Bh)
        y_diag = jnp.einsum("bijh,bijh,bjh,bjhp->bihp",
                            scores, decay, dtj, xj)
        # inter-chunk contribution from carried state
        y_off = jnp.einsum("bihn,bhpn,bih->bihp",
                           Ch, S.astype(Ch.dtype), jnp.exp(cum))
        # update state: S <- exp(seg_end) S + sum_j exp(seg_end-cum_j) dt x B
        w = jnp.exp(seg_end[:, None, :] - cum) * dtj      # (B,c,H)
        S_loc = jnp.einsum("bjh,bjhp,bjhn->bhpn", w, xj, Bh)
        S = S * jnp.exp(seg_end)[..., None, None].astype(S.dtype) \
            + S_loc.astype(S.dtype)
        return S, y_diag + y_off

    s0 = jnp.zeros((Bb, H, Pd, N), jnp.float32)
    s_final, yc = jax.lax.scan(chunk_step, s0, (xc, dtc, Bc, Cc))
    y = jnp.moveaxis(yc, 0, 1).reshape(Bb, Lq, H, Pd)
    return y, s_final


def ssd_decode_step(state, x_t, dt_t, A, B_t, C_t):
    """One-token SSD update.

    state: (B,H,Pd,N); x_t: (B,H,Pd); dt_t: (B,H); B_t,C_t: (B,G,N).
    """
    H = x_t.shape[1]
    G = B_t.shape[1]
    rep = H // G
    Bh = jnp.repeat(B_t, rep, axis=1)                     # (B,H,N)
    Ch = jnp.repeat(C_t, rep, axis=1)
    decay = jnp.exp(dt_t * A[None, :])[..., None, None]   # (B,H,1,1)
    upd = jnp.einsum("bh,bhp,bhn->bhpn", dt_t, x_t, Bh)
    state = state * decay + upd
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch)
    return state, y


def mamba2_block(params, x, cfg: ModelConfig, cache: SSMCache | None = None):
    """Full block. x: (B, Lq, d_model). Returns (y, new_cache)."""
    s = cfg.ssm
    d_inner, H, conv_dim = dims(cfg)
    G, N, Pd = s.n_groups, s.d_state, s.head_dim
    Bb, Lq, _ = x.shape

    zxbcdt = x @ params["in_proj"].astype(x.dtype)        # (B,L,·)
    z, xbc, dt = jnp.split(
        zxbcdt, [d_inner, d_inner + conv_dim], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,L,H) f32

    conv_prev = cache.conv if cache is not None else None
    xbc, conv_new = L.causal_conv1d(xbc, params["conv_w"].astype(xbc.dtype), conv_prev)
    xbc = jax.nn.silu(xbc + params["conv_b"].astype(xbc.dtype))
    xs, B_, C_ = jnp.split(xbc, [d_inner, d_inner + G * N], axis=-1)
    xs = xs.reshape(Bb, Lq, H, Pd)
    B_ = B_.reshape(Bb, Lq, G, N)
    C_ = C_.reshape(Bb, Lq, G, N)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))     # (H,)

    if cache is None or Lq > 1:
        pad = (-Lq) % s.chunk
        if pad:
            padded = lambda t: jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
            y, st = ssd_chunked(padded(xs), padded(dt), A, padded(B_),
                                padded(C_), s.chunk)
            y = y[:, :Lq]
        else:
            y, st = ssd_chunked(xs, dt, A, B_, C_, s.chunk)
    else:
        st0 = cache.state
        st, y = ssd_decode_step(st0, xs[:, 0], dt[:, 0], A, B_[:, 0], C_[:, 0])
        y = y[:, None]
    y = y.astype(jnp.float32) + xs.astype(jnp.float32) * params["D"][None, None, :, None]
    y = y.reshape(Bb, Lq, d_inner).astype(x.dtype)
    y = L.rms_norm(y * jax.nn.silu(z), params["norm_w"], cfg.rms_eps)
    out = y @ params["out_proj"].astype(y.dtype)
    new_cache = SSMCache(conv=conv_new, state=st.astype(jnp.float32)) \
        if cache is not None else None
    return out, new_cache
