"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Real-Gated Linear Recurrent Unit:
    r_t = sigmoid(W_a x_t + b_a)                      (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)                      (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t)            (per-channel decay)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Train/prefill uses ``lax.associative_scan`` over the length axis (the pair
composition (a1,b1)∘(a2,b2) = (a1·a2, a2·b1 + b2)); decode is the one-step
update.  The full residual block is Griffin's: linear in, temporal conv,
RG-LRU, multiplicative GeLU gate, linear out.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L

C_RGLRU = 8.0


class RGLRUCache(NamedTuple):
    conv: jax.Array      # (B, K-1, W) conv left-context
    h: jax.Array         # (B, W) recurrent state (f32)


def _gates(params, x):
    w_a = params["w_a"].astype(x.dtype)
    w_x = params["w_x"].astype(x.dtype)
    r = jax.nn.sigmoid(x @ w_a + params["b_a"].astype(x.dtype))
    i = jax.nn.sigmoid(x @ w_x + params["b_x"].astype(x.dtype))
    log_a = -C_RGLRU * jax.nn.softplus(params["lam"]) * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    gated = (i * x).astype(jnp.float32) * jnp.sqrt(
        jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, gated


def rglru_scan(params, x, h0=None):
    """x: (B, Lq, W). Returns (y, h_final). Associative scan over L."""
    a, b = _gates(params, x)                     # (B,L,W) f32
    if h0 is not None:
        # fold initial state in as a virtual step 0
        a = jnp.concatenate([jnp.ones_like(a[:, :1]), a], axis=1)
        b = jnp.concatenate([h0[:, None, :], b], axis=1)

    def comb(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    aa, hh = jax.lax.associative_scan(comb, (a, b), axis=1)
    if h0 is not None:
        hh = hh[:, 1:]
    return hh.astype(x.dtype), hh[:, -1]


def rglru_step(params, x_t, h):
    """x_t: (B, W); h: (B, W) f32."""
    a, b = _gates(params, x_t[:, None, :])
    h = a[:, 0] * h + b[:, 0]
    return h.astype(x_t.dtype), h


def recurrent_block(params, x, cfg: ModelConfig,
                    cache: RGLRUCache | None = None):
    """Griffin recurrent residual block. x: (B, Lq, d_model)."""
    w = cfg.recurrent.lru_width or cfg.d_model
    Bb, Lq, _ = x.shape
    # two branches from d_model
    gate = jax.nn.gelu(x @ params["w_gate"].astype(x.dtype))   # (B,L,W)
    xr = x @ params["w_in"].astype(x.dtype)
    conv_prev = cache.conv if cache is not None else None
    xr, conv_new = L.causal_conv1d(xr, params["conv_w"].astype(x.dtype), conv_prev)
    xr = xr + params["conv_b"].astype(x.dtype)
    if cache is None or Lq > 1:
        h0 = cache.h if cache is not None else None
        y, h_last = rglru_scan(params, xr, h0)
    else:
        y, h_last = rglru_step(params, xr[:, 0], cache.h)
        y = y[:, None]
    out = ((y * gate) @ params["w_out"].astype(gate.dtype)).astype(x.dtype)
    new_cache = RGLRUCache(conv=conv_new, h=h_last) if cache is not None else None
    return out, new_cache
