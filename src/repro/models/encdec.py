"""Whisper-style encoder-decoder backbone (assignment: conv frontend is a
STUB — ``input_specs()`` provides precomputed frame embeddings directly).

Encoder: bidirectional self-attention blocks over ``enc_ctx`` frames with
fixed sinusoidal positions.  Decoder: causal self-attention + cross
attention into the encoder output.  LayerNorm (not RMS), GELU MLPs, learned
decoder positions — matching the Whisper family.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.modules import ParamSpec


def _attn_ln_specs(cfg: ModelConfig, n: int, pre: str) -> dict:
    d, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        pre + "wq": ParamSpec((n, d, H, Dh), ("layers", "embed", "heads", "head_dim")),
        pre + "wk": ParamSpec((n, d, Hkv, Dh), ("layers", "embed", "kv_heads", "head_dim")),
        pre + "wv": ParamSpec((n, d, Hkv, Dh), ("layers", "embed", "kv_heads", "head_dim")),
        pre + "wo": ParamSpec((n, H, Dh, d), ("layers", "heads", "head_dim", "embed")),
        pre + "ln_w": ParamSpec((n, d), ("layers", "embed"), init="ones"),
        pre + "ln_b": ParamSpec((n, d), ("layers", "embed"), init="zeros"),
    }


def _mlp_ln_specs(cfg: ModelConfig, n: int) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "m_w1": ParamSpec((n, d, f), ("layers", "embed", "mlp")),
        "m_b1": ParamSpec((n, f), ("layers", "mlp"), init="zeros"),
        "m_w2": ParamSpec((n, f, d), ("layers", "mlp", "embed")),
        "m_b2": ParamSpec((n, d), ("layers", "embed"), init="zeros"),
        "m_ln_w": ParamSpec((n, d), ("layers", "embed"), init="ones"),
        "m_ln_b": ParamSpec((n, d), ("layers", "embed"), init="zeros"),
    }


def whisper_param_specs(cfg: ModelConfig, max_dec_pos: int = 4096) -> dict:
    ne, nd = cfg.enc_layers, cfg.n_layers
    d = cfg.d_model
    return {
        "embed": ParamSpec((cfg.vocab, d), ("vocab", "embed"), init="embed"),
        "dec_pos": ParamSpec((max_dec_pos, d), (None, "embed"), init="small"),
        "enc": {**_attn_ln_specs(cfg, ne, "sa_"), **_mlp_ln_specs(cfg, ne)},
        "enc_ln_w": ParamSpec((d,), ("embed",), init="ones"),
        "enc_ln_b": ParamSpec((d,), ("embed",), init="zeros"),
        "dec": {**_attn_ln_specs(cfg, nd, "sa_"),
                **_attn_ln_specs(cfg, nd, "xa_"), **_mlp_ln_specs(cfg, nd)},
        "dec_ln_w": ParamSpec((d,), ("embed",), init="ones"),
        "dec_ln_b": ParamSpec((d,), ("embed",), init="zeros"),
    }


def _mha(p, pre, xq, xkv, cfg, rt, *, causal, cache=None, positions=None):
    """LayerNorm attention sub-block (no RoPE; Whisper uses absolute pos)."""
    h = L.layer_norm(xq, p[pre + "ln_w"], p[pre + "ln_b"])
    hk = xkv if xkv is not None else h
    q = jnp.einsum("bsd,dhk->bshk", h, p[pre + "wq"].astype(h.dtype))
    k = jnp.einsum("bsd,dhk->bshk", hk, p[pre + "wk"].astype(hk.dtype))
    v = jnp.einsum("bsd,dhk->bshk", hk, p[pre + "wv"].astype(hk.dtype))
    if cache is not None:
        cache = A.cache_update(cache, k, v)
        if xq.shape[1] == 1:
            o = A.decode_attention(q, cache)
        else:
            o = A.flash_attention(q, cache.k, cache.v, causal=causal,
                                  kv_len=cache.length, chunk=rt.attn_chunk)
    else:
        o = A.flash_attention(q, k, v, causal=causal, chunk=rt.attn_chunk)
    o = jnp.einsum("bshk,hkd->bsd", o, p[pre + "wo"].astype(o.dtype))
    return xq + o, cache


def _mlp_res(p, x, cfg):
    h = L.layer_norm(x, p["m_ln_w"], p["m_ln_b"])
    return x + L.mlp(h, p["m_w1"].astype(h.dtype), p["m_w2"].astype(h.dtype),
                     p["m_b1"].astype(h.dtype), p["m_b2"].astype(h.dtype),
                     act="gelu")


def encode(params, frames, cfg: ModelConfig, rt: T.Runtime | None = None):
    """frames: (B, enc_ctx, d_model) — precomputed conv-frontend embeddings
    (stub per assignment). Returns encoder hidden states."""
    rt = rt or T.Runtime()
    x = frames.astype(jnp.bfloat16)
    x = x + L.sinusoidal_positions(x.shape[1], cfg.d_model).astype(x.dtype)
    x = rt.wsc(x, P(rt.batch_axes, None, None))

    def body(x, p):
        p = T.cast_params(p)
        x, _ = _mha(p, "sa_", x, None, cfg, rt, causal=False)
        x = _mlp_res(p, x, cfg)
        return rt.wsc(x, P(rt.batch_axes, None, None)), None

    x, _ = jax.lax.scan(body, x, params["enc"])
    return L.layer_norm(x, params["enc_ln_w"], params["enc_ln_b"])


class WhisperCaches(NamedTuple):
    self_kv: A.KVCache       # stacked (L, ...)
    cross_kv: A.KVCache      # stacked; length set once at prefill


def decode(params, tokens, enc_out, cfg: ModelConfig,
           rt: T.Runtime | None = None, caches: WhisperCaches | None = None,
           positions=None):
    """Decoder forward. Returns (hidden, new_caches)."""
    rt = rt or T.Runtime()
    B, Sq = tokens.shape
    if positions is None:
        off = caches.self_kv.length[0] if caches is not None else 0
        positions = off + jnp.arange(Sq)
    x = params["embed"].astype(jnp.bfloat16)[tokens]
    x = x + params["dec_pos"][positions].astype(x.dtype)
    x = rt.wsc(x, P(rt.batch_axes, None, None))

    if caches is None:
        def body(x, p):
            p = T.cast_params(p)
            x, _ = _mha(p, "sa_", x, None, cfg, rt, causal=True)
            x, _ = _mha(p, "xa_", x, enc_out, cfg, rt, causal=False)
            x = _mlp_res(p, x, cfg)
            return rt.wsc(x, P(rt.batch_axes, None, None)), None

        x, _ = jax.lax.scan(body, x, params["dec"])
        new = None
    else:
        def body(x, xs):
            p, (sk, sv, sl), (xk, xv, xl) = xs
            p = T.cast_params(p)
            s_kv = A.KVCache(sk, sv, sl)
            x_kv = A.KVCache(xk, xv, xl)
            x, s_kv = _mha(p, "sa_", x, None, cfg, rt, causal=True,
                           cache=s_kv)
            # cross attention reads the (already filled) encoder cache
            h = L.layer_norm(x, p["xa_ln_w"], p["xa_ln_b"])
            q = jnp.einsum("bsd,dhk->bshk", h, p["xa_wq"].astype(h.dtype))
            if Sq == 1:
                o = A.decode_attention(q, x_kv)
            else:
                o = A.flash_attention(q, x_kv.k, x_kv.v, causal=False,
                                      kv_len=x_kv.length, chunk=rt.attn_chunk)
            o = jnp.einsum("bshk,hkd->bsd", o, p["xa_wo"].astype(o.dtype))
            x = x + o
            x = _mlp_res(p, x, cfg)
            return x, ((s_kv.k, s_kv.v, s_kv.length), (xk, xv, xl))

        xs = (params["dec"],
              (caches.self_kv.k, caches.self_kv.v, caches.self_kv.length),
              (caches.cross_kv.k, caches.cross_kv.v, caches.cross_kv.length))
        x, (s_new, x_new) = jax.lax.scan(body, x, xs)
        new = WhisperCaches(A.KVCache(*s_new), A.KVCache(*x_new))

    x = L.layer_norm(x, params["dec_ln_w"], params["dec_ln_b"])
    return x, new


def whisper_init_caches(cfg: ModelConfig, batch: int, max_len: int,
                        dtype=jnp.bfloat16):
    nl = cfg.n_layers

    def mk(T_):
        return A.KVCache(
            k=jnp.zeros((nl, batch, T_, cfg.n_kv_heads, cfg.head_dim), dtype),
            v=jnp.zeros((nl, batch, T_, cfg.n_kv_heads, cfg.head_dim), dtype),
            length=jnp.zeros((nl,), jnp.int32))

    return WhisperCaches(self_kv=mk(max_len), cross_kv=mk(cfg.enc_ctx))


def fill_cross_cache(params, enc_out, caches: WhisperCaches,
                     cfg: ModelConfig) -> WhisperCaches:
    """Project encoder output into every decoder layer's cross KV cache."""
    def per_layer(p_k, p_v):
        k = jnp.einsum("bsd,dhk->bshk", enc_out, p_k.astype(enc_out.dtype))
        v = jnp.einsum("bsd,dhk->bshk", enc_out, p_v.astype(enc_out.dtype))
        return k, v

    k, v = jax.vmap(per_layer)(params["dec"]["xa_wk"], params["dec"]["xa_wv"])
    ln = jnp.full((cfg.n_layers,), enc_out.shape[1], jnp.int32)
    return WhisperCaches(
        self_kv=caches.self_kv,
        cross_kv=A.KVCache(k.astype(caches.cross_kv.k.dtype),
                           v.astype(caches.cross_kv.v.dtype), ln))
