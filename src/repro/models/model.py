"""Unified model interface: build(config) -> Model.

One object per architecture family exposing the same surface:

  specs()                         parameter ParamSpec tree
  init(key)                       materialized params
  hidden(params, batch, rt)       full-seq forward -> (hidden, aux_loss)
  logits(params, hidden, rt)      lm head
  init_caches(batch, max_len)     decode state
  prefill(params, batch, caches)  fill caches, return last hidden
  decode(params, caches, tokens)  one-token step -> (logits, caches)

``batch`` is a dict: tokens, and per-family extras (positions3,
vision_embeds, enc_frames).  This is the single entry point used by the
trainer, the serving engine and the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec as E
from repro.models import hybrid as H
from repro.models import transformer as T
from repro.models.modules import abstract_params, init_params


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    specs: Callable[[], dict]
    hidden: Callable
    init_caches: Callable
    decode: Callable
    prefill: Callable

    def init(self, key, param_dtype=None):
        return init_params(self.specs(), key, param_dtype)

    def abstract(self, param_dtype=None):
        return abstract_params(self.specs(), param_dtype)

    def logits(self, params, hidden, rt=None):
        return T.logits_fn(params, hidden, self.cfg, rt)


# ---------------------------------------------------------------------------

def _build_transformer(cfg: ModelConfig) -> Model:
    def hidden(params, batch, rt=None):
        return T.forward(params, batch["tokens"], cfg, rt,
                         positions3=batch.get("positions3"),
                         vision_embeds=batch.get("vision_embeds"))

    def init_caches(batch, max_len, dtype=jnp.bfloat16):
        return T.init_caches(cfg, batch, max_len, dtype)

    def prefill_with_cache(params, batch, caches, rt=None):
        rt = rt or T.Runtime()
        tokens = batch["tokens"]
        B, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        x = T.embed_tokens(params, tokens, cfg, rt,
                           batch.get("vision_embeds"))
        windows = jnp.asarray(T._layer_windows(cfg))
        ring = T.ring_caches(cfg)

        def body(x, xs):
            p, win, ck, cv, clen = xs
            cache = T.A.KVCache(ck, cv, clen)
            x, cache = T.attn_block(p, x, cfg, rt, window=win,
                                    positions=positions,
                                    positions3=batch.get("positions3"),
                                    cache=cache, ring=ring)
            if "router" in p:
                x, _ = T.moe_block(p, x, cfg, rt)
            else:
                x = T.ffn_block(p, x, cfg, rt)
            x = rt.wsc(x, T.P(rt.batch_axes, None, None))
            return x, (cache.k, cache.v, cache.length)

        new = dict(caches)
        if cfg.moe and cfg.moe.first_dense:
            nd = cfg.moe.first_dense
            c = caches["dense"]
            x, kv = jax.lax.scan(body, x, (params["dense_blocks"],
                                           windows[:nd], c.k, c.v, c.length))
            new["dense"] = T.A.KVCache(*kv)
            c = caches["blocks"]
            x, kv = jax.lax.scan(body, x, (params["blocks"], windows[nd:],
                                           c.k, c.v, c.length))
            new["blocks"] = T.A.KVCache(*kv)
        else:
            c = caches["blocks"]
            x, kv = jax.lax.scan(body, x, (params["blocks"], windows,
                                           c.k, c.v, c.length))
            new["blocks"] = T.A.KVCache(*kv)
        x = T._norm(cfg)(x, params["final_norm"])
        return x, new

    def decode(params, caches, tokens, rt=None, positions3=None):
        return T.decode_step(params, caches, tokens, cfg, rt,
                             positions3=positions3)

    return Model(cfg=cfg, specs=lambda: T.param_specs(cfg), hidden=hidden,
                 init_caches=init_caches, decode=decode,
                 prefill=prefill_with_cache)


def _build_recurrentgemma(cfg: ModelConfig) -> Model:
    def hidden(params, batch, rt=None):
        h, aux, _ = H.rg_forward(params, batch["tokens"], cfg, rt)
        return h, aux

    def init_caches(batch, max_len, dtype=jnp.bfloat16):
        return H.rg_init_caches(cfg, batch, dtype)

    def prefill(params, batch, caches, rt=None):
        h, _, new = H.rg_forward(params, batch["tokens"], cfg, rt, caches)
        return h, new

    def decode(params, caches, tokens, rt=None, **_):
        h, _, new = H.rg_forward(params, tokens, cfg, rt, caches)
        logits = T.logits_fn(params, h, cfg, rt)
        return logits, new

    return Model(cfg=cfg, specs=lambda: H.rg_param_specs(cfg), hidden=hidden,
                 init_caches=init_caches, decode=decode, prefill=prefill)


def _build_mamba2(cfg: ModelConfig) -> Model:
    def hidden(params, batch, rt=None):
        h, aux, _ = H.mamba2_forward(params, batch["tokens"], cfg, rt)
        return h, aux

    def init_caches(batch, max_len, dtype=jnp.bfloat16):
        return H.mamba2_init_caches(cfg, batch, dtype)

    def prefill(params, batch, caches, rt=None):
        h, _, new = H.mamba2_forward(params, batch["tokens"], cfg, rt, caches)
        return h, new

    def decode(params, caches, tokens, rt=None, **_):
        h, _, new = H.mamba2_forward(params, tokens, cfg, rt, caches)
        logits = T.logits_fn(params, h, cfg, rt)
        return logits, new

    return Model(cfg=cfg, specs=lambda: H.mamba2_param_specs(cfg),
                 hidden=hidden, init_caches=init_caches, decode=decode,
                 prefill=prefill)


def _build_whisper(cfg: ModelConfig) -> Model:
    def hidden(params, batch, rt=None):
        enc = E.encode(params, batch["enc_frames"], cfg, rt)
        h, _ = E.decode(params, batch["tokens"], enc, cfg, rt)
        return h, jnp.zeros((), jnp.float32)

    def init_caches(batch, max_len, dtype=jnp.bfloat16):
        return E.whisper_init_caches(cfg, batch, max_len, dtype)

    def prefill(params, batch, caches, rt=None):
        enc = E.encode(params, batch["enc_frames"], cfg, rt)
        caches = E.fill_cross_cache(params, enc, caches, cfg)
        h, caches = E.decode(params, batch["tokens"], None, cfg, rt, caches)
        return h, caches

    def decode(params, caches, tokens, rt=None, **_):
        h, new = E.decode(params, tokens, None, cfg, rt, caches)
        logits = T.logits_fn(params, h, cfg, rt)
        return logits, new

    return Model(cfg=cfg, specs=lambda: E.whisper_param_specs(cfg),
                 hidden=hidden, init_caches=init_caches, decode=decode,
                 prefill=prefill)


def build(cfg: ModelConfig) -> Model:
    if cfg.family == "ssm":
        return _build_mamba2(cfg)
    if cfg.family == "hybrid":
        return _build_recurrentgemma(cfg)
    if cfg.family == "audio":
        return _build_whisper(cfg)
    return _build_transformer(cfg)     # dense | moe | vlm
