"""Mixture-of-Experts with the paper's bucket dispatch as a first-class path.

Token->expert dispatch is *exactly* the Extoll event-aggregation problem:
many small payloads (tokens) addressed to sparse destinations (experts) must
be binned into capacity-bounded buckets and shipped in one collective.  The
two implementations mirror the §Perf baseline/optimized pair:

* ``impl="gspmd"``  — capacity-binned dispatch buffers with sharding
  constraints; XLA/GSPMD chooses the collectives (baseline; typically
  all-gathers the dispatch buffer across the expert axis).
* ``impl="bucket"`` — explicit shard_map expert parallelism: per-device
  bucket aggregation (same positions logic as ``core.aggregator``) followed
  by a single ``all_to_all`` over the ``model`` axis, expert compute on
  local experts, and the inverse ``all_to_all``.  This is the paper's
  aggregate-then-route strategy on TPU ICI.

Both paths share the router and the capacity/overflow semantics, so tests
can assert they agree bit-for-bit (up to reduction order) on one device.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import MoEConfig
from repro.models import layers as L


class MoEStats(NamedTuple):
    aux_loss: jax.Array        # load-balance loss
    router_z: jax.Array        # router z-loss
    dropped: jax.Array         # fraction of (token, k) assignments dropped


def router_probs(x, w_router, jitter_key=None, jitter=0.0):
    """x: (T, d) -> probs (T, E), logits f32."""
    logits = (x @ w_router).astype(jnp.float32)
    if jitter_key is not None and jitter > 0:
        logits += jax.random.uniform(jitter_key, logits.shape, minval=-jitter,
                                     maxval=jitter)
    return jax.nn.softmax(logits, -1), logits


def _positions(dest, n_dest):
    """Slot of each assignment within its destination (window order)."""
    oh = jax.nn.one_hot(dest, n_dest, dtype=jnp.int32)
    pos = jnp.cumsum(oh, axis=0) - oh
    return jnp.sum(pos * oh, axis=1), jnp.sum(oh, axis=0)


def _capacity(n_tokens: int, top_k: int, n_experts: int, factor: float,
              multiple: int = 4) -> int:
    c = int(n_tokens * top_k / n_experts * factor) + 1
    return max(-(-c // multiple) * multiple, multiple)


def expert_glu(xe, wg, wu, wd, act="silu"):
    """xe: (E, C, d); weights (E, d, f)/(E, f, d)."""
    wg, wu, wd = (w.astype(xe.dtype) for w in (wg, wu, wd))
    h = L.act_fn(act)(jnp.einsum("ecd,edf->ecf", xe, wg))
    h = h * jnp.einsum("ecd,edf->ecf", xe, wu)
    return jnp.einsum("ecf,efd->ecd", h, wd)


def _route(x, w_router, moe: MoEConfig, key):
    T = x.shape[0]
    probs, logits = router_probs(x, w_router, key, moe.router_jitter)
    gate, experts = jax.lax.top_k(probs, moe.top_k)        # (T, k)
    # load-balance aux (Switch/GShard): E * mean(frac_tokens) . mean(prob)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jax.nn.one_hot(experts[:, 0], moe.n_experts, dtype=jnp.float32), 0)
    aux = moe.n_experts * jnp.sum(me * ce)
    zl = jnp.mean(jax.nn.logsumexp(logits, -1) ** 2)
    return gate, experts, MoEStats(aux, zl, jnp.float32(0.0))


def moe_layer_local(x, params, moe: MoEConfig, *, act="silu", key=None,
                    capacity: int | None = None, wsc=None):
    """Single-device / GSPMD path. x: (T, d).

    ``wsc(tensor, spec)`` — optional sharding-constraint hook injected by the
    runtime (keeps this module mesh-agnostic for CPU tests).
    """
    wsc = wsc or (lambda t, _spec: t)
    T, d = x.shape
    gate, experts, stats = _route(x, params["router"], moe, key)
    C = capacity or _capacity(T, moe.top_k, moe.n_experts, moe.capacity_factor)
    flat_e = experts.reshape(-1)                           # (T*k,)
    pos, counts = _positions(flat_e, moe.n_experts)
    keep = pos < C
    e_idx = jnp.where(keep, flat_e, moe.n_experts)         # drop -> OOB
    p_idx = jnp.where(keep, pos, 0)
    tok = jnp.repeat(jnp.arange(T), moe.top_k)
    buf = jnp.zeros((moe.n_experts, C, d), x.dtype).at[e_idx, p_idx].set(
        x[tok], mode="drop")
    buf = wsc(buf, P("model", None, None))
    y_e = expert_glu(buf, params["w_gate"], params["w_up"], params["w_down"],
                     act)
    y = y_e[jnp.minimum(e_idx, moe.n_experts - 1), p_idx]  # (T*k, d)
    y = jnp.where(keep[:, None], y, 0.0)
    y = (y.reshape(T, moe.top_k, d)
         * gate[..., None].astype(y.dtype)).sum(1)
    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
    return y, stats._replace(dropped=dropped)


def moe_layer_bucket(x, params, moe: MoEConfig, *, axis: str = "model",
                     act="silu", key=None, capacity: int | None = None):
    """Explicit EP path — call *inside* shard_map. x: (T_local, d).

    Expert weights arrive pre-sliced over ``axis``: (E/ep, d, f).
    Router weights arrive full (replicated).
    """
    T, d = x.shape
    E = moe.n_experts
    # EP degree from the pre-sliced expert weights: reshape sizes must be
    # static, and jax.lax has no static axis-size query inside shard_map.
    e_loc = params["w_gate"].shape[0]
    ep = E // e_loc
    gate, experts, stats = _route(x, params["router"], moe, key)
    C = capacity or _capacity(T, moe.top_k, E, moe.capacity_factor)
    flat_e = experts.reshape(-1)
    pos, _counts = _positions(flat_e, E)
    keep = pos < C
    e_idx = jnp.where(keep, flat_e, E)
    p_idx = jnp.where(keep, pos, 0)
    tok = jnp.repeat(jnp.arange(T), moe.top_k)
    # bucket aggregation by destination expert (paper §3.1, tokens as events)
    buf = jnp.zeros((E, C, d), x.dtype).at[e_idx, p_idx].set(
        x[tok], mode="drop")
    # ship buckets to their owner device: one all_to_all over the EP axis
    recv = jax.lax.all_to_all(buf.reshape(ep, e_loc, C, d), axis, 0, 0,
                              tiled=True).reshape(ep, e_loc, C, d)
    # compute local experts on ep*C rows each
    xe = jnp.moveaxis(recv, 0, 1).reshape(e_loc, ep * C, d)
    y_e = expert_glu(xe, params["w_gate"], params["w_up"], params["w_down"],
                     act)
    # inverse route
    back = jnp.moveaxis(y_e.reshape(e_loc, ep, C, d), 1, 0)
    y_buf = jax.lax.all_to_all(back, axis, 0, 0, tiled=True)
    y_buf = y_buf.reshape(E, C, d)
    y = y_buf[jnp.minimum(e_idx, E - 1), p_idx]
    y = jnp.where(keep[:, None], y, 0.0)
    y = (y.reshape(T, moe.top_k, d) * gate[..., None].astype(y.dtype)).sum(1)
    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
    return y, stats._replace(dropped=dropped)
