"""Hybrid recurrent/attention models (RecurrentGemma-style, 2:1 pattern)
and the pure-SSM Mamba-2 stack.

RecurrentGemma's repeating pattern (rglru, rglru, local-attn) is scanned as
*super-blocks* of three layers so every scan step has identical structure;
a remainder of r = n_layers mod 3 leading recurrent layers is applied
un-scanned.  Both families have O(1)-per-token decode state, so they are the
two architectures that run the ``long_500k`` cell.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import rglru as R
from repro.models import ssm as S
from repro.models import transformer as T
from repro.models.modules import ParamSpec


# ---------------------------------------------------------------------------
# RecurrentGemma
# ---------------------------------------------------------------------------

def _rglru_specs(cfg: ModelConfig, n: int) -> dict:
    d = cfg.d_model
    w = cfg.recurrent.lru_width or d
    k = cfg.recurrent.conv_width
    return {
        "w_in": ParamSpec((n, d, w), ("layers", "embed", "mlp")),
        "w_gate": ParamSpec((n, d, w), ("layers", "embed", "mlp")),
        "w_out": ParamSpec((n, w, d), ("layers", "mlp", "embed")),
        "conv_w": ParamSpec((n, k, w), ("layers", None, "mlp"), init="small"),
        "conv_b": ParamSpec((n, w), ("layers", "mlp"), init="zeros"),
        "w_a": ParamSpec((n, w, w), ("layers", "mlp", None), init="small"),
        "b_a": ParamSpec((n, w), ("layers", "mlp"), init="zeros"),
        "w_x": ParamSpec((n, w, w), ("layers", "mlp", None), init="small"),
        "b_x": ParamSpec((n, w), ("layers", "mlp"), init="zeros"),
        "lam": ParamSpec((n, w), ("layers", "mlp"), init="ones"),
        "ln": ParamSpec((n, d), ("layers", "embed"), init="ones"),
    }


def _idx(tree, i):
    return jax.tree_util.tree_map(lambda x: x[i], tree)


def rg_param_specs(cfg: ModelConfig) -> dict:
    ns = cfg.n_layers // 3            # super-blocks (r, r, attn)
    rem = cfg.n_layers % 3            # leading extra recurrent layers
    specs = {
        "embed": ParamSpec((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                           init="embed"),
        "final_norm": ParamSpec((cfg.d_model,), ("embed",), init="ones"),
        "super": {
            "r0": _rglru_specs(cfg, ns),
            "r1": _rglru_specs(cfg, ns),
            "attn": {**T._attn_specs(cfg, ns), **T._norm_specs(cfg, ns)},
            "mlp0": T._mlp_specs(cfg, ns, cfg.d_ff),
            "mlp1": T._mlp_specs(cfg, ns, cfg.d_ff),
            "mlp2": T._mlp_specs(cfg, ns, cfg.d_ff),
            "mln0": ParamSpec((ns, cfg.d_model), ("layers", "embed"), init="ones"),
            "mln1": ParamSpec((ns, cfg.d_model), ("layers", "embed"), init="ones"),
            "mln2": ParamSpec((ns, cfg.d_model), ("layers", "embed"), init="ones"),
        },
    }
    if rem:
        specs["tail"] = {
            f"r{i}": _rglru_specs(cfg, 1) for i in range(rem)
        }
        specs["tail"].update({
            f"mlp{i}": T._mlp_specs(cfg, 1, cfg.d_ff) for i in range(rem)
        })
        specs["tail"].update({
            f"mln{i}": ParamSpec((1, cfg.d_model), ("layers", "embed"),
                                 init="ones") for i in range(rem)
        })
    if not cfg.tie_embeddings:
        specs["lm_head"] = ParamSpec((cfg.d_model, cfg.vocab),
                                     ("embed", "vocab"))
    return specs


class RGCaches(NamedTuple):
    r0: R.RGLRUCache
    r1: R.RGLRUCache
    attn: A.KVCache
    tail: tuple


def _recurrent_residual(p, x, cfg, cache):
    p = T.cast_params(p)
    h = L.rms_norm(x, p["ln"], cfg.rms_eps)
    o, cache = R.recurrent_block(p, h, cfg, cache)
    return x + o, cache


def _mlp_residual(p, ln, x, cfg, prefix=""):
    p = T.cast_params(p)
    h = L.rms_norm(x, ln, cfg.rms_eps)
    return x + L.glu_mlp(h, p[prefix + "wg"].astype(h.dtype),
                         p[prefix + "wu"].astype(h.dtype),
                         p[prefix + "wd"].astype(h.dtype), cfg.act)


def rg_forward(params, tokens, cfg: ModelConfig, rt: T.Runtime | None = None,
               caches: RGCaches | None = None, positions=None):
    """RecurrentGemma forward (train/prefill, or decode when S==1 with
    caches). Returns (hidden, aux(=0), new_caches)."""
    rt = rt or T.Runtime()
    B, Sq = tokens.shape
    if positions is None:
        has_attn = caches is not None and caches.attn.length.shape[0] > 0
        off = caches.attn.length[0] if has_attn else 0
        positions = jnp.broadcast_to(off + jnp.arange(Sq), (B, Sq)).astype(jnp.int32)
    x = T.embed_tokens(params, tokens, cfg, rt)
    x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    win = cfg.sliding_window
    rem = cfg.n_layers % 3

    if caches is None:
        # train / stateless prefill: windowed flash attention, no caches
        def super_body(x, p):
            x, _ = _recurrent_residual(p["r0"], x, cfg, None)
            x = _mlp_residual(p["mlp0"], p["mln0"], x, cfg)
            x, _ = _recurrent_residual(p["r1"], x, cfg, None)
            x = _mlp_residual(p["mlp1"], p["mln1"], x, cfg)
            x, _ = T.attn_block(p["attn"], x, cfg, rt, window=win,
                                positions=positions)
            x = _mlp_residual(p["mlp2"], p["mln2"], x, cfg)
            return rt.wsc(x, rt.aspec()), None

        x, _ = jax.lax.scan(super_body, x, params["super"])
        if "tail" in params:
            for i in range(rem):
                p = params["tail"]
                x, _ = _recurrent_residual(_idx(p[f"r{i}"], 0), x, cfg, None)
                x = _mlp_residual(_idx(p[f"mlp{i}"], 0), p[f"mln{i}"][0], x, cfg)
        x = L.rms_norm(x, params["final_norm"], cfg.rms_eps)
        return x, jnp.zeros((), jnp.float32), None

    def super_body(carry, xs):
        x = carry
        p, (c_r0, c_r1, (ck, cv, clen)) = xs
        kv = A.KVCache(ck, cv, clen)
        x, c_r0 = _recurrent_residual(p["r0"], x, cfg, c_r0)
        x = _mlp_residual(p["mlp0"], p["mln0"], x, cfg)
        x, c_r1 = _recurrent_residual(p["r1"], x, cfg, c_r1)
        x = _mlp_residual(p["mlp1"], p["mln1"], x, cfg)
        x, kv = T.attn_block(p["attn"], x, cfg, rt, window=win,
                             positions=positions, cache=kv, ring=True)
        x = _mlp_residual(p["mlp2"], p["mln2"], x, cfg)
        x = rt.wsc(x, rt.aspec())
        return x, (c_r0, c_r1, (kv.k, kv.v, kv.length))

    sup = (caches.r0, caches.r1,
           (caches.attn.k, caches.attn.v, caches.attn.length))
    x, (c0, c1, (ck, cv, cl)) = jax.lax.scan(super_body, x,
                                             (params["super"], sup))
    new_tail = []
    if "tail" in params:
        for i in range(len(caches.tail)):
            p = params["tail"]
            x, ci = _recurrent_residual(_idx(p[f"r{i}"], 0), x, cfg,
                                        caches.tail[i])
            x = _mlp_residual(_idx(p[f"mlp{i}"], 0), p[f"mln{i}"][0], x, cfg)
            new_tail.append(ci)
    x = L.rms_norm(x, params["final_norm"], cfg.rms_eps)
    new = RGCaches(c0, c1, A.KVCache(ck, cv, cl), tuple(new_tail))
    return x, jnp.zeros((), jnp.float32), new


def rg_init_caches(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    ns = cfg.n_layers // 3
    rem = cfg.n_layers % 3
    w = cfg.recurrent.lru_width or cfg.d_model
    k = cfg.recurrent.conv_width
    win = cfg.sliding_window
    mk_r = lambda: R.RGLRUCache(
        conv=jnp.zeros((ns, batch, k - 1, w), dtype),
        h=jnp.zeros((ns, batch, w), jnp.float32))
    kv = A.KVCache(
        k=jnp.zeros((ns, batch, win, cfg.n_kv_heads, cfg.head_dim), dtype),
        v=jnp.zeros((ns, batch, win, cfg.n_kv_heads, cfg.head_dim), dtype),
        length=jnp.zeros((ns,), jnp.int32))
    tail = tuple(
        R.RGLRUCache(conv=jnp.zeros((batch, k - 1, w), dtype),
                     h=jnp.zeros((batch, w), jnp.float32))
        for _ in range(rem))
    return RGCaches(mk_r(), mk_r(), kv, tail)


# ---------------------------------------------------------------------------
# Mamba-2
# ---------------------------------------------------------------------------

def mamba2_param_specs(cfg: ModelConfig) -> dict:
    n = cfg.n_layers
    d = cfg.d_model
    d_inner, H, conv_dim = S.dims(cfg)
    g, ns = cfg.ssm.n_groups, cfg.ssm.d_state
    in_dim = 2 * d_inner + 2 * g * ns + H
    specs = {
        "embed": ParamSpec((cfg.vocab, d), ("vocab", "embed"), init="embed"),
        "final_norm": ParamSpec((d,), ("embed",), init="ones"),
        "blocks": {
            "in_proj": ParamSpec((n, d, in_dim), ("layers", "embed", "mlp")),
            "conv_w": ParamSpec((n, cfg.ssm.d_conv, conv_dim),
                                ("layers", None, "mlp"), init="small"),
            "conv_b": ParamSpec((n, conv_dim), ("layers", "mlp"), init="zeros"),
            "dt_bias": ParamSpec((n, H), ("layers", "heads"), init="zeros"),
            "A_log": ParamSpec((n, H), ("layers", "heads"), init="zeros"),
            "D": ParamSpec((n, H), ("layers", "heads"), init="ones"),
            "norm_w": ParamSpec((n, d_inner), ("layers", "mlp"), init="ones"),
            "out_proj": ParamSpec((n, d_inner, d), ("layers", "mlp", "embed")),
            "ln": ParamSpec((n, d), ("layers", "embed"), init="ones"),
        },
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = ParamSpec((d, cfg.vocab), ("embed", "vocab"))
    return specs


def mamba2_forward(params, tokens, cfg: ModelConfig,
                   rt: T.Runtime | None = None, caches=None, positions=None):
    """Returns (hidden, aux(=0), new_caches). caches: stacked SSMCache."""
    rt = rt or T.Runtime()
    B, Sq = tokens.shape
    x = T.embed_tokens(params, tokens, cfg, rt)
    d_inner, H, conv_dim = S.dims(cfg)

    if caches is None:
        def body(x, p):
            p = T.cast_params(p)
            h = L.rms_norm(x, p["ln"], cfg.rms_eps)
            o, _ = S.mamba2_block(p, h, cfg, None)
            return rt.wsc(x + o, P(rt.batch_axes, None, None)), None

        x, _ = jax.lax.scan(body, x, params["blocks"])
        new = None
    else:
        def body(x, xs):
            p, cache = xs
            p = T.cast_params(p)
            c = S.SSMCache(*cache)
            h = L.rms_norm(x, p["ln"], cfg.rms_eps)
            o, c_new = S.mamba2_block(p, h, cfg, c)
            x = rt.wsc(x + o, rt.aspec())
            return x, tuple(c_new)

        x, new = jax.lax.scan(body, x, (params["blocks"], tuple(caches)))
        new = S.SSMCache(*new)
    x = L.rms_norm(x, params["final_norm"], cfg.rms_eps)
    return x, jnp.zeros((), jnp.float32), new


def mamba2_init_caches(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    d_inner, H, conv_dim = S.dims(cfg)
    n = cfg.n_layers
    return S.SSMCache(
        conv=jnp.zeros((n, batch, cfg.ssm.d_conv - 1, conv_dim), dtype),
        state=jnp.zeros((n, batch, H, cfg.ssm.head_dim, cfg.ssm.d_state),
                        jnp.float32),
    )
