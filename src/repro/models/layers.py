"""Shared building blocks for the model zoo (pure functions over pytrees)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x, w, eps: float = 1e-6, *, unit_offset: bool = False):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    w = w.astype(jnp.float32)
    scale = (1.0 + w) if unit_offset else w     # gemma stores w-1
    return (x * scale).astype(dt)


def layer_norm(x, w, b, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps) * w + b).astype(dt)


def softcap(x, cap: float):
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True)}[name]


# --------------------------------------------------------------------------
# Rotary embeddings (standard + M-RoPE)
# --------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (B, S, H, D); positions: (B, S) int."""
    d = x.shape[-1]
    inv = jnp.asarray(rope_freqs(d, theta), jnp.float32)          # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * inv           # (B,S,D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, sections, theta: float = 10000.0):
    """Qwen2-VL multimodal RoPE.

    positions3: (3, B, S) — temporal / height / width position ids.
    sections: per-axis frequency budget, sum == head_dim // 2.
    Frequency slot j uses the position id of the axis owning slot j.
    """
    d = x.shape[-1]
    inv = jnp.asarray(rope_freqs(d, theta), jnp.float32)           # (D/2,)
    owner = jnp.asarray(
        np.repeat(np.arange(len(sections)), np.asarray(sections)), jnp.int32)
    # pick each slot's position id: (B, S, D/2)
    pos = jnp.take(positions3, owner, axis=0)                      # (D/2,B,S)
    pos = jnp.moveaxis(pos, 0, -1).astype(jnp.float32)
    ang = pos * inv
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int):
    """Whisper-style fixed sinusoidal table (n, d)."""
    pos = np.arange(n)[:, None]
    dim = np.arange(d // 2)[None, :]
    ang = pos / (10000 ** (dim / max(d // 2 - 1, 1)))
    return jnp.asarray(
        np.concatenate([np.sin(ang), np.cos(ang)], -1), jnp.float32)


# --------------------------------------------------------------------------
# GLU MLP
# --------------------------------------------------------------------------

def glu_mlp(x, wg, wu, wd, act: str = "silu"):
    h = act_fn(act)(x @ wg) * (x @ wu)
    return h @ wd


def mlp(x, w1, w2, b1=None, b2=None, act: str = "gelu"):
    h = x @ w1
    if b1 is not None:
        h = h + b1
    h = act_fn(act)(h)
    h = h @ w2
    if b2 is not None:
        h = h + b2
    return h


# --------------------------------------------------------------------------
# Chunked causal conv (mamba2 / recurrentgemma temporal conv)
# --------------------------------------------------------------------------

def causal_conv1d(x, w, prev: jax.Array | None = None):
    """Depthwise causal conv along time. x: (B, L, C); w: (K, C).

    prev: optional (B, K-1, C) left context (decode/prefill chunking).
    Returns (y, new_prev) where new_prev is the trailing K-1 inputs.
    """
    k = w.shape[0]
    if prev is None:
        prev = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prev, x], axis=1)
    y = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(k))
    new_prev = xp[:, -(k - 1):, :] if k > 1 else jnp.zeros_like(prev)
    return y, new_prev
