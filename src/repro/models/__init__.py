"""Model zoo: the 10 assigned architectures behind one interface."""
from repro.models.model import Model, build  # noqa: F401
