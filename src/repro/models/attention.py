"""Attention: GQA/MQA/MHA with the zoo's variants, memory-bounded.

Training/prefill attention is *flash-style*: an online-softmax ``lax.scan``
over KV chunks, so the (S, S) score matrix is never materialized — at 32k
prefill the naive scores would be tens of GB per device, so this is what
makes the dry-run (and real hardware) fit.  Masking (causal + sliding
window) is computed from absolute indices inside each chunk.

GQA grouping is implemented by *expanding* K/V to the query-head count
with a static gather (``head -> head // group``) rather than reshaping Q
to (Hkv, G, D): a reshape would destroy the tensor-parallel head sharding
(64 heads sharded 16-way cannot be viewed as (8, 8)), forcing GSPMD to
replicate attention per chip — the gather keeps every einsum sharded on
the head axis, and the expanded K/V only ever exists chunk-sized.

Variants covered (per assigned architecture):
  * grouped KV heads (GQA/MQA), qk-norm (qwen3), QKV bias (qwen1.5)
  * attention logit softcap + query scale override (gemma2)
  * sliding-window local attention (gemma2 alternating, recurrentgemma)
  * decode with KV cache (+ ring cache for windowed layers)
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

NEG_INF = -2.3819763e38     # flash-attention convention


def _kv_head_map(hq: int, hkv: int):
    """Static gather indices expanding kv heads to query heads."""
    g = hq // hkv
    return jnp.arange(hq) // g


def _scores(q, k, scale, cap):
    # q: (B, Sq, H, D) k: (B, Ck, H, D) -> (B, Sq, H, Ck), f32
    s = jnp.einsum("bqhd,bkhd->bqhk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if cap:
        s = cap * jnp.tanh(s / cap)
    return s


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, scale: float | None = None,
                    q_offset=0, kv_len: jax.Array | None = None,
                    chunk: int = 1024, gqa: str = "expand"):
    """Online-softmax attention.

    q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D); Hq % Hkv == 0.
    q_offset: absolute index of q[0] (prefill continuation / decode).
    kv_len: optional () — valid KV prefix length (rest masked).

    gqa:
      "expand" — K/V expanded to Hq heads with a static gather.  Use when
        Q is head-sharded (TP decode/prefill): the gather keeps every
        einsum sharded on heads.
      "group"  — Q viewed as (Hkv, G); K/V never expand, so the backward
        dK/dV stays Hkv-sized (8x smaller on qwen3).  Use when Q's heads
        are replicated per rank (context-parallel training), where the
        (Hkv, G) reshape cannot break a head sharding.

    Each chunk step is wrapped in ``jax.checkpoint``: the backward pass
    recomputes the (.., chunk) probability block instead of storing one
    per chunk — without this, training at 4k x 64 layers stores ~4 GB of
    f32 p-matrices per layer and defeats the point of flash attention.
    """
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = (1.0 / D ** 0.5) if scale is None else scale
    chunk = min(chunk, Skv)
    nc = -(-Skv // chunk)
    pad = nc * chunk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, nc, chunk, Hkv, D)
    vc = v.reshape(B, nc, chunk, Hkv, D)
    q_idx = q_offset + jnp.arange(Sq)
    if gqa == "group":
        qg = q.reshape(B, Sq, Hkv, G, D)
    else:
        hmap = _kv_head_map(Hq, Hkv)

    def mask_for(j):
        kv_idx = j * chunk + jnp.arange(chunk)
        mask = jnp.ones((Sq, chunk), bool)
        if causal:
            mask &= q_idx[:, None] >= kv_idx[None, :]
        if not (isinstance(window, int) and window == 0):
            w = jnp.asarray(window)            # may be traced (per-layer)
            mask &= (q_idx[:, None] - kv_idx[None, :] < w) | (w <= 0)
        mask &= (kv_idx < Skv)[None, :]
        if kv_len is not None:
            mask &= (kv_idx < kv_len)[None, :]
        return mask

    @jax.checkpoint
    def step(carry, xs):
        m, l, acc = carry
        kj, vj, j = xs
        mask = mask_for(j)
        if gqa == "group":
            s = jnp.einsum("bqhgd,bkhd->bqhgk", qg.astype(jnp.float32),
                           kj.astype(jnp.float32)) * scale
            if softcap:
                s = softcap * jnp.tanh(s / softcap)
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bqhgk,bkhd->bqhgd", p, vj.astype(jnp.float32))
        else:
            kj = jnp.take(kj, hmap, axis=2)    # (B, C, Hq, D) chunk-sized
            vj = jnp.take(vj, hmap, axis=2)
            s = _scores(q, kj, scale, softcap)     # (B, Sq, Hq, C)
            s = jnp.where(mask[None, :, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bqhk,bkhd->bqhd", p, vj.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    hshape = (B, Sq, Hkv, G) if gqa == "group" else (B, Sq, Hq)
    m0 = jnp.full(hshape, NEG_INF, jnp.float32)
    l0 = jnp.zeros(hshape, jnp.float32)
    a0 = jnp.zeros(hshape + (D,), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), jnp.arange(nc)))
    out = acc / jnp.maximum(l[..., None], 1e-37)
    return out.reshape(B, Sq, Hq, D).astype(q.dtype)


# --------------------------------------------------------------------------
# Decode path with KV cache
# --------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: jax.Array          # (B, T, Hkv, D)  (T = window for local layers)
    v: jax.Array          # (B, T, Hkv, D)
    length: jax.Array     # () tokens already in cache


def init_cache(batch: int, max_len: int, n_kv: int, head_dim: int,
               dtype=jnp.bfloat16) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
        v=jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
        length=jnp.zeros((), jnp.int32),
    )


def cache_update(cache: KVCache, k_new, v_new, *, ring: bool = False) -> KVCache:
    """Append S_new tokens. ring=True wraps (sliding-window layers)."""
    T = cache.k.shape[1]
    s = k_new.shape[1]
    if ring:
        if s >= T:
            # long prefill: only the trailing window survives; slot of
            # absolute position p is p % T, each slot written exactly once
            k_new, v_new = k_new[:, -T:], v_new[:, -T:]
            start = cache.length + s - T
            s_eff = T
        else:
            start = cache.length
            s_eff = s
        idx = (start + jnp.arange(s_eff)) % T
        k = cache.k.at[:, idx].set(k_new.astype(cache.k.dtype))
        v = cache.v.at[:, idx].set(v_new.astype(cache.v.dtype))
    else:
        k = jax.lax.dynamic_update_slice(
            cache.k, k_new.astype(cache.k.dtype), (0, cache.length, 0, 0))
        v = jax.lax.dynamic_update_slice(
            cache.v, v_new.astype(cache.v.dtype), (0, cache.length, 0, 0))
    return KVCache(k, v, cache.length + s)


def decode_attention(q, cache: KVCache, *, window: int = 0,
                     softcap: float = 0.0, scale: float | None = None,
                     ring: bool = False, chunk: int = 4096):
    """Single-step attention against the cache, scanned over cache chunks
    (bounds the expanded-KV working set at long context). q: (B,1,Hq,D)."""
    B, _, Hq, D = q.shape
    T, Hkv = cache.k.shape[1], cache.k.shape[2]
    scale = (1.0 / D ** 0.5) if scale is None else scale
    hmap = _kv_head_map(Hq, Hkv)
    cur = cache.length          # index of the token being produced
    pos = jnp.arange(T)
    static_nowin = isinstance(window, int) and window == 0
    if ring:
        age = (cur - 1 - pos) % T
        if static_nowin:
            ok = age < jnp.minimum(cur, T)
        else:
            w = jnp.asarray(window)
            ok = jnp.where(w > 0, age < w, age < jnp.minimum(cur, T))
    else:
        ok = pos < cur
        if not static_nowin:
            w = jnp.asarray(window)
            ok &= (pos >= cur - w) | (w <= 0)

    chunk = min(chunk, T)
    nc = -(-T // chunk)
    padT = nc * chunk - T
    kc = jnp.pad(cache.k, ((0, 0), (0, padT), (0, 0), (0, 0)))
    vc = jnp.pad(cache.v, ((0, 0), (0, padT), (0, 0), (0, 0)))
    okc = jnp.pad(ok, (0, padT))
    kc = jnp.moveaxis(kc.reshape(B, nc, chunk, Hkv, D), 1, 0)
    vc = jnp.moveaxis(vc.reshape(B, nc, chunk, Hkv, D), 1, 0)
    okc = okc.reshape(nc, 1, 1, chunk)

    def step(carry, xs):
        m, l, acc = carry
        kj, vj, okj = xs
        kj = jnp.take(kj, hmap, axis=2)
        vj = jnp.take(vj, hmap, axis=2)
        s = _scores(q, kj, scale, softcap)[:, 0]          # (B, Hq, C)
        s = jnp.where(okj, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhk,bkhd->bhd", p, vj.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hq), jnp.float32)
    a0 = jnp.zeros((B, Hq, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kc, vc, okc))
    out = acc / jnp.maximum(l[..., None], 1e-37)
    return out[:, None].astype(q.dtype)
