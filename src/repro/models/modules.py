"""Minimal pytree parameter system (flax/optax are unavailable offline).

Single source of truth per architecture is a nested dict of ``ParamSpec``
(shape, dtype, logical axes, initializer).  From it we derive:

* materialized parameters            (``init_params``)       — tests/examples
* ``jax.ShapeDtypeStruct`` skeleton  (``abstract_params``)   — dry-run
* ``NamedSharding`` tree             (``repro.distributed.sharding``)

Logical axis names used across the model zoo:

  vocab, embed, heads (fused q heads x head_dim), kv_heads, mlp (ffn hidden),
  expert, layers (stacked scan dim), conv, state, seq — mapping to mesh axes
  lives in one rules table, so changing the parallelism plan is a one-line
  edit per experiment (this is where the §Perf sharding hillclimbs happen).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple
    axes: tuple                    # logical axis name per dim (None ok)
    dtype: Any = jnp.float32
    init: str = "normal"           # normal | zeros | ones | embed | small
    scale: float | None = None     # stddev override

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_paths(tree, prefix=()):
    """Yield (path_tuple, ParamSpec) leaves of a nested-dict spec tree."""
    if is_spec(tree):
        yield prefix, tree
        return
    for k in sorted(tree):
        yield from tree_paths(tree[k], prefix + (k,))


def tree_map_specs(fn: Callable, tree):
    if is_spec(tree):
        return fn(tree)
    return {k: tree_map_specs(fn, v) for k, v in tree.items()}


def _fan_in(spec: ParamSpec) -> int:
    """Fan-in = product of input dims; leading stack axes (layers/expert)
    don't contribute.  Convention: last axis is the output dim."""
    dims = [d for d, a in zip(spec.shape[:-1], spec.axes[:-1])
            if a not in ("layers", "expert")]
    return int(np.prod(dims)) if dims else max(spec.shape[-1], 1)


def _initializer(spec: ParamSpec, key, dtype):
    shape = spec.shape
    if spec.init == "zeros":
        return jnp.zeros(shape, dtype)
    if spec.init == "ones":
        return jnp.ones(shape, dtype)
    if spec.init in ("normal", "embed", "small"):
        if spec.scale is not None:
            std = spec.scale
        elif spec.init == "embed":
            std = 1.0
        elif spec.init == "small":
            std = 0.02
        else:
            fan = _fan_in(spec)
            std = 1.0 / np.sqrt(max(fan, 1))
        return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)
    raise ValueError(f"unknown init {spec.init!r}")


def init_params(spec_tree, key, param_dtype=None):
    """Materialize parameters. Per-leaf keys are derived from the path so
    adding/removing parameters never reshuffles other leaves."""

    def leaf(path, spec):
        h = np.uint32(abs(hash("/".join(map(str, path)))) % (2**31 - 1))
        k = jax.random.fold_in(key, int(h))
        return _initializer(spec, k, param_dtype or spec.dtype)

    def rec(tree, prefix=()):
        if is_spec(tree):
            return leaf(prefix, tree)
        return {k: rec(v, prefix + (k,)) for k, v in tree.items()}

    return rec(spec_tree)


def abstract_params(spec_tree, param_dtype=None):
    return tree_map_specs(
        lambda s: jax.ShapeDtypeStruct(s.shape, param_dtype or s.dtype),
        spec_tree,
    )


def param_count(spec_tree) -> int:
    return sum(int(np.prod(s.shape)) for _, s in tree_paths(spec_tree))


def param_bytes(spec_tree, param_dtype=None) -> int:
    def nbytes(s: ParamSpec):
        dt = np.dtype(param_dtype or s.dtype)
        return int(np.prod(s.shape)) * dt.itemsize
    return sum(nbytes(s) for _, s in tree_paths(spec_tree))
