"""Decoder-only transformer assembly covering the dense / MoE / VLM
architectures of the zoo, config-driven, with scanned layers.

Layers are *stacked* (leading ``layers`` dim) and applied with ``lax.scan``
so the HLO stays one-block-sized regardless of depth — this is what keeps
the 512-device dry-run compile tractable and is also how the big frameworks
do it (MaxText et al.).

A ``Runtime`` carries mesh context (sharding-constraint hook, MoE dispatch
impl); models stay mesh-agnostic for CPU tests by passing ``Runtime()``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models.modules import ParamSpec


@dataclasses.dataclass(frozen=True)
class Runtime:
    """Mesh-dependent hooks; default is single-device-safe no-ops."""

    mesh: Any = None
    batch_axes: tuple = ("data",)       # mesh axes the batch is split over
    model_axis: str = "model"
    moe_impl: str = "local"             # local | bucket
    remat: bool = False
    attn_chunk: int = 1024
    logits_chunk: int = 512
    seq_axis: Any = None                # Megatron-style sequence parallelism:
                                        # residual stream sharded over this
                                        # mesh axis between blocks
    split_kv_axis: Any = None           # decode: KV cache sharded on seq
                                        # over this axis -> flash-decoding
                                        # (logsumexp-combine), no cache AG
    grad_specs: Any = None              # param-sharding tree; constrains
                                        # grads so XLA reduce-scatters the
                                        # FSDP gradients instead of AR

    def wsc(self, t, spec):
        if self.mesh is None:
            return t
        return jax.lax.with_sharding_constraint(
            t, jax.sharding.NamedSharding(self.mesh, spec))

    def aspec(self):
        """Residual-activation PartitionSpec (B, S, d)."""
        return P(self.batch_axes, self.seq_axis, None)


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def _attn_specs(cfg: ModelConfig, n: int) -> dict:
    d, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    s: dict = {
        "wq": ParamSpec((n, d, H, Dh), ("layers", "embed", "heads", "head_dim")),
        "wk": ParamSpec((n, d, Hkv, Dh), ("layers", "embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((n, d, Hkv, Dh), ("layers", "embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((n, H, Dh, d), ("layers", "heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        s["bq"] = ParamSpec((n, H, Dh), ("layers", "heads", "head_dim"), init="zeros")
        s["bk"] = ParamSpec((n, Hkv, Dh), ("layers", "kv_heads", "head_dim"), init="zeros")
        s["bv"] = ParamSpec((n, Hkv, Dh), ("layers", "kv_heads", "head_dim"), init="zeros")
    if cfg.qk_norm:
        s["q_norm"] = ParamSpec((n, Dh), ("layers", "head_dim"), init="ones")
        s["k_norm"] = ParamSpec((n, Dh), ("layers", "head_dim"), init="ones")
    return s


def _mlp_specs(cfg: ModelConfig, n: int, ff: int, prefix: str = "") -> dict:
    d = cfg.d_model
    return {
        prefix + "wg": ParamSpec((n, d, ff), ("layers", "embed", "mlp")),
        prefix + "wu": ParamSpec((n, d, ff), ("layers", "embed", "mlp")),
        prefix + "wd": ParamSpec((n, ff, d), ("layers", "mlp", "embed")),
    }


def _moe_specs(cfg: ModelConfig, n: int) -> dict:
    m = cfg.moe
    d, f = cfg.d_model, m.expert_ff
    s = {
        "router": ParamSpec((n, d, m.n_experts), ("layers", "embed", None),
                            init="small"),
        "w_gate": ParamSpec((n, m.n_experts, d, f),
                            ("layers", "expert", "embed", "mlp")),
        "w_up": ParamSpec((n, m.n_experts, d, f),
                          ("layers", "expert", "embed", "mlp")),
        "w_down": ParamSpec((n, m.n_experts, f, d),
                            ("layers", "expert", "mlp", "embed")),
    }
    if m.n_shared:
        s.update(_mlp_specs(cfg, n, m.n_shared * f, prefix="sh_"))
    if m.parallel_dense_ff:
        s.update(_mlp_specs(cfg, n, m.parallel_dense_ff, prefix="pd_"))
    return s


def _norm_specs(cfg: ModelConfig, n: int) -> dict:
    d = cfg.d_model
    init = "zeros" if cfg.post_norm else "ones"   # gemma stores w-1
    s = {
        "ln1": ParamSpec((n, d), ("layers", "embed"), init=init),
        "ln2": ParamSpec((n, d), ("layers", "embed"), init=init),
    }
    if cfg.post_norm:
        s["ln1b"] = ParamSpec((n, d), ("layers", "embed"), init=init)
        s["ln2b"] = ParamSpec((n, d), ("layers", "embed"), init=init)
    return s


def param_specs(cfg: ModelConfig) -> dict:
    nl = cfg.n_layers
    n_moe = 0
    specs: dict = {
        "embed": ParamSpec((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                           init="embed"),
        "final_norm": ParamSpec((cfg.d_model,), ("embed",),
                                init="zeros" if cfg.post_norm else "ones"),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = ParamSpec((cfg.d_model, cfg.vocab),
                                     ("embed", "vocab"))
    if cfg.moe:
        n_dense = cfg.moe.first_dense
        n_moe = nl - n_dense
        block = {**_attn_specs(cfg, n_moe), **_moe_specs(cfg, n_moe),
                 **_norm_specs(cfg, n_moe)}
        specs["blocks"] = block
        if n_dense:
            dense = {**_attn_specs(cfg, n_dense),
                     **_mlp_specs(cfg, n_dense, cfg.moe.dense_ff or cfg.d_ff),
                     **_norm_specs(cfg, n_dense)}
            specs["dense_blocks"] = dense
    else:
        specs["blocks"] = {**_attn_specs(cfg, nl),
                           **_mlp_specs(cfg, nl, cfg.d_ff),
                           **_norm_specs(cfg, nl)}
    return specs


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _res_scale(cfg: ModelConfig) -> float:
    return float(cfg.scale_depth / np.sqrt(cfg.n_layers)) if cfg.scale_depth else 1.0


def _scaled(o, cfg: ModelConfig):
    s = _res_scale(cfg)
    return o if s == 1.0 else o * jnp.asarray(s, o.dtype)


def _norm(cfg):
    return partial(L.rms_norm, eps=cfg.rms_eps, unit_offset=cfg.post_norm)


def _project_qkv(p, h, cfg: ModelConfig):
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"].astype(h.dtype))
    k = jnp.einsum("bsd,dhk->bshk", h, p["wk"].astype(h.dtype))
    v = jnp.einsum("bsd,dhk->bshk", h, p["wv"].astype(h.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(h.dtype)
        k = k + p["bk"].astype(h.dtype)
        v = v + p["bv"].astype(h.dtype)
    if cfg.qk_norm:
        q = L.rms_norm(q, p["q_norm"], cfg.rms_eps)
        k = L.rms_norm(k, p["k_norm"], cfg.rms_eps)
    return q, k, v


def _rope(cfg: ModelConfig, x, positions, positions3=None):
    if cfg.mrope_sections and positions3 is not None:
        return L.apply_mrope(x, positions3, cfg.mrope_sections, cfg.rope_theta)
    return L.apply_rope(x, positions, cfg.rope_theta)


def cast_params(p, dtype=jnp.bfloat16):
    """Cast a block's f32 params to compute dtype BEFORE any collective:
    FSDP all-gathers then move bf16 on the wire (2x fewer bytes) and the
    backward cast boundary keeps master params f32."""
    return jax.tree_util.tree_map(
        lambda t: t.astype(dtype) if t.dtype == jnp.float32 else t, p)


def attn_block(p, x, cfg: ModelConfig, rt: Runtime, *, window: int,
               positions, positions3=None, cache: A.KVCache | None = None,
               ring: bool = False):
    """Pre/post-norm attention residual. Returns (x, new_cache)."""
    p = cast_params(p)
    norm = _norm(cfg)
    h = norm(x, p["ln1"])
    q, k, v = _project_qkv(p, h, cfg)
    q = _rope(cfg, q, positions, positions3)
    k = _rope(cfg, k, positions, positions3)
    if rt.mesh is not None and rt.seq_axis is not None and cache is None:
        # context-parallel attention (train): queries stay sequence-sharded
        # over the model axis, K/V replicate across it — every flash-chunk
        # step is then communication-free; only dK/dV pay one all-reduce.
        # K/V are constrained seq-sharded FIRST so the projection runs on
        # the local sequence slice and the all-gather moves K/V
        # (B,S,Hkv,D — 5x smaller than gathering the d_model residual).
        q = rt.wsc(q, P(rt.batch_axes, rt.seq_axis, None, None))
        k = rt.wsc(k, P(rt.batch_axes, rt.seq_axis, None, None))
        v = rt.wsc(v, P(rt.batch_axes, rt.seq_axis, None, None))
        k = rt.wsc(k, P(rt.batch_axes, None, None, None))
        v = rt.wsc(v, P(rt.batch_axes, None, None, None))
    scale = cfg.query_scale if cfg.query_scale else None
    cp = rt.mesh is not None and rt.seq_axis is not None and cache is None
    if cache is not None:
        cache = A.cache_update(cache, k, v, ring=ring)
        if x.shape[1] == 1:
            if rt.split_kv_axis is not None and not ring:
                o = _split_kv_decode(q, cache, rt, scale, window,
                                     cfg.attn_softcap)
            else:
                o = A.decode_attention(q, cache, window=window,
                                       softcap=cfg.attn_softcap, scale=scale,
                                       ring=ring)
        else:
            o = A.flash_attention(q, cache.k, cache.v, causal=True,
                                  window=window, softcap=cfg.attn_softcap,
                                  scale=scale, kv_len=cache.length,
                                  chunk=rt.attn_chunk)
    else:
        o = A.flash_attention(q, k, v, causal=True, window=window,
                              softcap=cfg.attn_softcap, scale=scale,
                              chunk=rt.attn_chunk,
                              gqa="group" if cp else "expand")
    o = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(o.dtype))
    if cfg.post_norm:
        o = norm(o, p["ln1b"])
    return x + _scaled(o, cfg), cache


def _split_kv_decode(q, cache, rt: Runtime, scale, window, softcap):
    """Flash-decoding over the seq-sharded cache (hillclimb: replaces the
    per-layer cache all-gather with one tiny logsumexp-combine psum)."""
    from functools import partial as _partial

    from jax.experimental.shard_map import shard_map

    from repro.distributed.collectives import split_kv_decode_attention

    ax = rt.split_kv_axis
    bspec = P(rt.batch_axes, None, None, None)
    kspec = P(rt.batch_axes, ax, None, None)
    fn = shard_map(
        lambda q_, k_, v_, ln_, w_: split_kv_decode_attention(
            q_, k_, v_, ln_, axis_name=ax, scale=scale if scale else None,
            softcap=softcap, window=w_),
        mesh=rt.mesh,
        in_specs=(bspec, kspec, kspec, P(), P()),
        out_specs=bspec,
        check_rep=False,
    )
    return fn(q, cache.k, cache.v, cache.length, jnp.asarray(window))


def ffn_block(p, x, cfg: ModelConfig, rt: Runtime, *, ff_prefix: str = ""):
    p = cast_params(p)
    norm = _norm(cfg)
    h = norm(x, p["ln2"])
    o = L.glu_mlp(h, p[ff_prefix + "wg"].astype(h.dtype),
                  p[ff_prefix + "wu"].astype(h.dtype),
                  p[ff_prefix + "wd"].astype(h.dtype), cfg.act)
    if cfg.post_norm:
        o = norm(o, p["ln2b"])
    return x + _scaled(o, cfg)


def moe_block(p, x, cfg: ModelConfig, rt: Runtime):
    """MoE residual (+ optional shared experts / parallel dense)."""
    p = cast_params(p)
    norm = _norm(cfg)
    h = norm(x, p["ln2"])
    B, S, d = h.shape
    flat = h.reshape(-1, d)
    mp = {k: p[k] for k in ("router", "w_gate", "w_up", "w_down")}
    if rt.moe_impl == "bucket" and rt.mesh is not None:
        o_flat, stats = _moe_bucket_sharded(flat, mp, cfg, rt, B, S)
    else:
        o_flat, stats = M.moe_layer_local(
            flat, mp, cfg.moe, act=cfg.act,
            wsc=(rt.wsc if rt.mesh is not None else None))
    o = o_flat.reshape(B, S, d)
    if cfg.moe.n_shared:
        o = o + L.glu_mlp(h, p["sh_wg"].astype(h.dtype),
                          p["sh_wu"].astype(h.dtype),
                          p["sh_wd"].astype(h.dtype), cfg.act)
    if cfg.moe.parallel_dense_ff:
        o = o + L.glu_mlp(h, p["pd_wg"].astype(h.dtype),
                          p["pd_wu"].astype(h.dtype),
                          p["pd_wd"].astype(h.dtype), cfg.act)
    return x + _scaled(o, cfg), stats


def _moe_bucket_sharded(flat, mp, cfg: ModelConfig, rt: Runtime, B, S):
    """shard_map EP dispatch (paper's bucket aggregation over the ICI)."""
    from jax.experimental.shard_map import shard_map

    d = flat.shape[-1]
    x3 = flat.reshape(B, S, d)
    # tokens enter the dispatch sequence-sharded over the EP axis: each
    # model-rank buckets ONLY its S/ep slice (without this, every rank
    # routes all tokens and the a2a carries ep identical copies — measured
    # 16x redundant bytes on deepseek train).
    bspec = P(rt.batch_axes, rt.seq_axis, None)
    espec = P(rt.model_axis, None, None)

    def body(xl, router, wg, wu, wd):
        # xl: (B_loc, S, d); experts pre-sliced over model axis; the mlp dim
        # may be FSDP-sharded over the batch axes -> gather it back first.
        wg = _regather(wg, rt)
        wu = _regather(wu, rt)
        wd = _regather_t(wd, rt)
        t = xl.reshape(-1, d)
        y, stats = M.moe_layer_bucket(
            t, {"router": router, "w_gate": wg, "w_up": wu, "w_down": wd},
            cfg.moe, axis=rt.model_axis, act=cfg.act)
        stats = jax.tree_util.tree_map(
            lambda s: jax.lax.pmean(s, rt.model_axis), stats)
        return y.reshape(xl.shape), stats

    fn = shard_map(
        body, mesh=rt.mesh,
        in_specs=(bspec, P(), espec, espec, espec),
        out_specs=(bspec, P()),
        check_rep=False,
    )
    y, stats = fn(x3, mp["router"],
                  mp["w_gate"], mp["w_up"], mp["w_down"])
    return y.reshape(-1, d), stats


def _regather(w, rt: Runtime):
    """No-op placeholder: expert mlp dim arrives full inside shard_map
    because in_specs only split the expert axis; kept as a hook for FSDP'd
    expert weights (arctic uses sliced mlp + all_gather here)."""
    return w


def _regather_t(w, rt: Runtime):
    return w


# ---------------------------------------------------------------------------
# Model: init / forward / decode
# ---------------------------------------------------------------------------

def _layer_windows(cfg: ModelConfig) -> np.ndarray:
    """Per-layer sliding window (0 = global)."""
    if cfg.alt_local_global and cfg.sliding_window:
        w = np.zeros(cfg.n_layers, np.int32)
        w[0::2] = cfg.sliding_window          # even layers local (gemma2)
        return w
    if cfg.sliding_window:
        return np.full(cfg.n_layers, cfg.sliding_window, np.int32)
    return np.zeros(cfg.n_layers, np.int32)


def embed_tokens(params, tokens, cfg: ModelConfig, rt: Runtime,
                 vision_embeds=None):
    x = params["embed"].astype(jnp.bfloat16)[tokens]
    if cfg.scale_emb != 1.0:
        x = x * cfg.scale_emb
    elif cfg.post_norm:                        # gemma convention
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    if vision_embeds is not None and cfg.vision_tokens:
        x = jax.lax.dynamic_update_slice(
            x, vision_embeds.astype(x.dtype), (0, 0, 0))
    return rt.wsc(x, rt.aspec())


def forward(params, tokens, cfg: ModelConfig, rt: Runtime | None = None,
            positions=None, positions3=None, vision_embeds=None):
    """Full-sequence forward -> final hidden states (B, S, d) bf16."""
    rt = rt or Runtime()
    B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    x = embed_tokens(params, tokens, cfg, rt, vision_embeds)
    windows = jnp.asarray(_layer_windows(cfg))
    aux = jnp.zeros((), jnp.float32)

    def make_scan(block_params, moe: bool, windows_slice):
        def body(carry, xs):
            x, aux = carry
            p, win = xs
            x, _ = attn_block(p, x, cfg, rt, window=win, positions=positions,
                              positions3=positions3)
            if moe:
                x, stats = moe_block(p, x, cfg, rt)
                aux = aux + stats.aux_loss
            else:
                x = ffn_block(p, x, cfg, rt)
            x = rt.wsc(x, rt.aspec())
            return (x, aux), None
        if rt.remat:
            body = jax.checkpoint(body,
                                  policy=jax.checkpoint_policies.nothing_saveable)
        return body

    if cfg.moe and cfg.moe.first_dense:
        nd = cfg.moe.first_dense
        (x, aux), _ = jax.lax.scan(
            make_scan(params["dense_blocks"], False, windows[:nd]),
            (x, aux), (params["dense_blocks"], windows[:nd]))
        (x, aux), _ = jax.lax.scan(
            make_scan(params["blocks"], True, windows[nd:]),
            (x, aux), (params["blocks"], windows[nd:]))
    else:
        (x, aux), _ = jax.lax.scan(
            make_scan(params["blocks"], bool(cfg.moe), windows),
            (x, aux), (params["blocks"], windows))

    x = _norm(cfg)(x, params["final_norm"])
    return x, aux


def logits_fn(params, hidden, cfg: ModelConfig, rt: Runtime | None = None):
    rt = rt or Runtime()
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = hidden @ w.astype(hidden.dtype)
    logits = logits * cfg.logit_scale
    logits = L.softcap(logits.astype(jnp.float32), cfg.logit_softcap)
    return rt.wsc(logits, P(rt.batch_axes, None, rt.model_axis))


# -- decode -----------------------------------------------------------------

def ring_caches(cfg: ModelConfig) -> bool:
    """Static: ring-buffer caches iff every layer is windowed."""
    w = _layer_windows(cfg)
    return bool(w.min() > 0)


def init_caches(cfg: ModelConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16):
    """Stacked per-layer KV caches; windowed layers get ring buffers."""
    windows = _layer_windows(cfg)
    # a single stacked cache sized max(window or max_len) keeps scan simple:
    # global layers use full length, local layers could use `window` — we
    # allocate full length per layer unless ALL layers are windowed.
    ring = ring_caches(cfg)
    T = int(windows.max()) if ring else max_len
    nl = cfg.n_layers

    def mk(n):
        return A.KVCache(
            k=jnp.zeros((n, batch, T, cfg.n_kv_heads, cfg.head_dim), dtype),
            v=jnp.zeros((n, batch, T, cfg.n_kv_heads, cfg.head_dim), dtype),
            length=jnp.zeros((n,), jnp.int32),
        )

    if cfg.moe and cfg.moe.first_dense:
        return {"dense": mk(cfg.moe.first_dense),
                "blocks": mk(nl - cfg.moe.first_dense)}
    return {"blocks": mk(nl)}


def decode_step(params, caches, tokens, cfg: ModelConfig,
                rt: Runtime | None = None, positions=None, positions3=None):
    """One token for every sequence. tokens: (B, 1). Returns (logits, caches)."""
    rt = rt or Runtime()
    B = tokens.shape[0]
    if positions is None:
        pos0 = caches["blocks"].length[0]
        positions = jnp.broadcast_to(pos0, (B, 1)).astype(jnp.int32)
    x = embed_tokens(params, tokens, cfg, rt)
    windows = jnp.asarray(_layer_windows(cfg))
    ring = ring_caches(cfg)

    def body(x, xs):
        p, win, ck, cv, clen = xs
        cache = A.KVCache(ck, cv, clen)
        x, cache = attn_block(p, x, cfg, rt, window=win, positions=positions,
                              positions3=positions3, cache=cache, ring=ring)
        if "router" in p:
            x, _ = moe_block(p, x, cfg, rt)
        elif "wg" in p:
            x = ffn_block(p, x, cfg, rt)
        return x, (cache.k, cache.v, cache.length)

    def run_scan(x, block_params, cache, win):
        c = caches[cache]
        xs = (block_params, win, c.k, c.v, c.length)
        x, (k, v, ln) = jax.lax.scan(body, x, xs)
        return x, A.KVCache(k, v, ln)

    if cfg.moe and cfg.moe.first_dense:
        nd = cfg.moe.first_dense
        x, cd = run_scan(x, params["dense_blocks"], "dense", windows[:nd])
        x, cb = run_scan(x, params["blocks"], "blocks", windows[nd:])
        new = {"dense": cd, "blocks": cb}
    else:
        x, cb = run_scan(x, params["blocks"], "blocks", windows)
        new = {"blocks": cb}

    x = _norm(cfg)(x, params["final_norm"])
    logits = logits_fn(params, x, cfg, rt)
    return logits, new
