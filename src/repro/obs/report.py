"""Observability report: one window timeline from a run directory.

A *run directory* is the on-disk form of one instrumented run — the
flight-recorder ring, the fault schedule's transition log, the per-tenant
QoS ledger and the run metadata, each in the boring-on-purpose format
below so every producer (serve engine, simulator benches, ad-hoc scripts)
writes the same thing and ``python -m repro.obs.report <run-dir>`` renders
any of them:

* ``recorder.jsonl``  — one ``repro.obs.recorder`` row per recorded
  window (global view: counter/hist lanes summed across shards)
* ``events.jsonl``    — ``repro.fabric.faults.transitions`` events
  (``{"window", "event": "link_down"|"link_up", "links": [...]}``)
* ``tenants.jsonl``   — ``repro.serve.tenancy.tenant_rows`` rows
  (QoS contract + conservation ledger + latency digest); absent for
  single-tenant runs
* ``meta.json``       — run shape: ``dims``, ``n_shards``, counts,
  ``window_us``, throughput — anything the producer wants rendered
* ``metrics.prom`` / ``metrics.jsonl`` / ``trace.json`` — optional
  Prometheus exposition, metrics snapshot and Perfetto trace riding along

:func:`build_report` merges the first four onto ONE window timeline —
which links were congested when, which windows a cable died or healed,
what each tenant's p99 was while it happened — and returns it as a plain
dict (the structured output the tests assert on); :func:`render` prints
it for humans; ``main`` is the CLI.
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Sequence

import numpy as np

from repro.fabric import faults as fabric_faults
from repro.wire import latency as wire_latency

#: timeline counters pulled from each recorder row (subset of
#: ``repro.obs.recorder.COUNTER_FIELDS`` that reads well per window)
_TIMELINE_FIELDS = ("offered_events", "sent_events", "deferred_events",
                    "delivered_events", "parked_events", "unparked_events",
                    "rerouted")


# -- writing ----------------------------------------------------------------

def _write_jsonl(path: str, rows: Sequence[dict]) -> None:
    with open(path, "w") as f:
        for row in rows:
            f.write(json.dumps(row) + "\n")


def write_run_dir(run_dir: str, *, meta: dict,
                  recorder_rows: Sequence[dict] | None = None,
                  fault_events: Sequence[dict] | None = None,
                  tenant_rows: Sequence[dict] | None = None,
                  registry=None, tracer=None) -> str:
    """Write one run's observability artifacts into ``run_dir``.

    ``meta`` is required (a report without run shape is unreadable);
    everything else is optional and simply omitted from the directory.
    ``registry`` (an ``repro.obs.metrics.Registry``) lands as BOTH
    ``metrics.prom`` and ``metrics.jsonl``; ``tracer`` as ``trace.json``.
    Returns ``run_dir``.
    """
    os.makedirs(run_dir, exist_ok=True)
    with open(os.path.join(run_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
        f.write("\n")
    if recorder_rows is not None:
        _write_jsonl(os.path.join(run_dir, "recorder.jsonl"), recorder_rows)
    if fault_events is not None:
        _write_jsonl(os.path.join(run_dir, "events.jsonl"), fault_events)
    if tenant_rows is not None:
        _write_jsonl(os.path.join(run_dir, "tenants.jsonl"), tenant_rows)
    if registry is not None:
        from repro.obs import metrics as obs_metrics
        with open(os.path.join(run_dir, "metrics.prom"), "w") as f:
            f.write(obs_metrics.prometheus_text(registry))
        obs_metrics.write_jsonl(
            os.path.join(run_dir, "metrics.jsonl"), registry)
    if tracer is not None and getattr(tracer, "enabled", False):
        tracer.write(os.path.join(run_dir, "trace.json"))
    return run_dir


def write_engine_run(run_dir: str, engine, report) -> str:
    """Assemble a run directory from a served ``SpikeEngine`` + its
    ``EngineReport`` (the post-``stop()`` pair) — recorder rows, fault
    transitions, tenant rows, ledger metrics and the trace, whichever
    the engine was built with."""
    from repro.obs import metrics as obs_metrics
    from repro.serve import tenancy
    cfg = engine.cfg
    dims = [int(d) for d in engine.transport.dims]
    meta = {
        "kind": "serve",
        "dims": dims,
        "n_shards": engine.n_shards,
        "n_tenants": engine.n_tenants,
        "window_us": float(cfg.window_us),
        "seg_windows": int(cfg.seg_windows),
        "link_credits": int(cfg.link_credits),
        "notify_latency": int(cfg.notify_latency),
        "windows": int(report.windows),
        "drain_windows": int(report.drain_windows),
        "wall_s": float(report.wall_s),
        "events_per_s": float(report.events_per_s),
    }
    reg = obs_metrics.Registry()
    engine.ledger.export_metrics(reg)
    reg.gauge("engine_events_per_s",
              "Delivered throughput of the run.").set(report.events_per_s)
    reg.gauge("engine_windows_served",
              "Flush windows served (excl. drain).").set(report.windows)
    return write_run_dir(
        run_dir, meta=meta,
        recorder_rows=(engine.recorder_rows()
                       if engine.recorder is not None else None),
        fault_events=(fabric_faults.transitions(engine.fault_schedule)
                      if engine.fault_schedule is not None else None),
        tenant_rows=tenancy.tenant_rows(
            engine.tenants, engine.ledger, cfg.notify_latency),
        registry=reg, tracer=engine.tracer)


# -- reading ----------------------------------------------------------------

def _read_jsonl(path: str) -> list[dict]:
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


def _counter(row: dict, field: str) -> int:
    """One recorder-row counter as a GLOBAL int (sums the tenant axis)."""
    return int(np.asarray(row["counters"][field], np.int64).sum())


def _label(dims, lid: int) -> str:
    if dims and len(dims) * 2 and lid < int(np.prod(dims)) * 2 * len(dims):
        return fabric_faults.link_label(dims, lid)
    return f"link{lid}"


def _p99s(row: dict, names: Sequence[str]) -> dict[str, float]:
    """Per-tenant (or overall) p99 of one recorder row's histogram delta."""
    hist = np.asarray(row["hist"], np.int64)
    if hist.ndim == 1:
        return {"all": wire_latency.percentile_from_hist(hist, 0.99)}
    return {(names[t] if t < len(names) else f"t{t}"):
            wire_latency.percentile_from_hist(hist[t], 0.99)
            for t in range(hist.shape[0])}


def build_report(run_dir: str) -> dict:
    """Merge a run directory into one structured report dict.

    Keys: ``meta``, ``timeline`` (one entry per recorded window, with
    counters, per-link stall attribution, fault events and per-tenant
    p99), ``top_links`` (ranked by total stalled demand), ``faults``,
    ``tenants`` (rows + SLO burn) and ``totals``.
    """
    meta_path = os.path.join(run_dir, "meta.json")
    if not os.path.exists(meta_path):
        raise FileNotFoundError(f"{run_dir!r} is not a run directory "
                                f"(missing meta.json)")
    with open(meta_path) as f:
        meta = json.load(f)
    dims = tuple(meta.get("dims") or ())
    rows = _read_jsonl(os.path.join(run_dir, "recorder.jsonl"))
    faults = _read_jsonl(os.path.join(run_dir, "events.jsonl"))
    tenants = _read_jsonl(os.path.join(run_dir, "tenants.jsonl"))
    names = [t["tenant"] for t in tenants]
    by_window: dict[int, list[dict]] = {}
    for ev in faults:
        by_window.setdefault(int(ev["window"]), []).append(ev)

    timeline, link_stall, link_windows = [], {}, {}
    for row in rows:
        w = int(row["window"])
        sbl = np.asarray(row["stalled_by_link"], np.int64)
        hot = np.flatnonzero(sbl)
        for lid in hot:
            link_stall[int(lid)] = link_stall.get(int(lid), 0) + int(sbl[lid])
            link_windows[int(lid)] = link_windows.get(int(lid), 0) + 1
        entry = {"window": w}
        entry.update({f: _counter(row, f) for f in _TIMELINE_FIELDS})
        entry["stalled_links"] = [
            {"link": int(l), "label": _label(dims, int(l)),
             "stalled": int(sbl[l])}
            for l in hot[np.argsort(-sbl[hot])][:3]]
        entry["events"] = [
            {"event": ev["event"], "links": ev["links"],
             "labels": [_label(dims, l) for l in ev["links"]]}
            for ev in by_window.get(w, [])]
        entry["p99_us"] = _p99s(row, names)
        timeline.append(entry)

    top_links = [
        {"link": lid, "label": _label(dims, lid),
         "stalled_events": link_stall[lid],
         "windows_congested": link_windows[lid]}
        for lid in sorted(link_stall, key=lambda l: -link_stall[l])[:10]]

    for t in tenants:
        g = float(t.get("guaranteed_epw", 0.0))
        offered = float(t.get("rate_epw", 0.0))
        t["slo"] = {
            "guaranteed_epw": g,
            "offered_epw": offered,
            # >1 means the tenant's own offered rate exceeds its
            # guaranteed admission — latency beyond the guarantee is
            # self-inflicted burst, not an isolation failure
            "overcommit": (offered / g) if g > 0 else float("inf"),
            "delivered_ratio": (t["delivered"] / t["injected"]
                                if t.get("injected") else 1.0),
        }

    totals = {}
    for f in _TIMELINE_FIELDS:
        totals[f] = int(sum(e[f] for e in timeline))
    return {"meta": meta, "timeline": timeline, "top_links": top_links,
            "faults": faults, "tenants": tenants, "totals": totals}


# -- rendering --------------------------------------------------------------

def render(report: dict) -> str:
    """Human-readable rendering of :func:`build_report`'s dict."""
    meta = report["meta"]
    out = [f"== run: kind={meta.get('kind', '?')} dims={meta.get('dims')} "
           f"shards={meta.get('n_shards')} "
           f"windows={meta.get('windows', len(report['timeline']))}"]
    if meta.get("events_per_s"):
        out.append(f"   throughput: {meta['events_per_s']:,.0f} events/s "
                   f"(wall {meta.get('wall_s', 0):.2f}s)")
    if report["top_links"]:
        out.append("-- top congested links (stalled demand) --")
        for l in report["top_links"]:
            out.append(f"   {l['label']:>10}  {l['stalled_events']:>8} "
                       f"events over {l['windows_congested']} windows")
    if report["tenants"]:
        out.append("-- tenants --")
        for t in report["tenants"]:
            slo = t["slo"]
            out.append(
                f"   {t['tenant']:>8}  delivered {t['delivered']:>8}  "
                f"shed {t['shed']:>6}  p50 {t['p50_us']:>8.1f}us  "
                f"p99 {t['p99_us']:>8.1f}us  "
                f"offered/guaranteed {slo['overcommit']:.2f}x")
    out.append("-- window timeline --")
    for e in report["timeline"]:
        marks = "".join(
            f"  [{ev['event']} {','.join(ev['labels'])}]"
            for ev in e["events"])
        stall = (" stall@" + ",".join(
            f"{s['label']}:{s['stalled']}" for s in e["stalled_links"])
            if e["stalled_links"] else "")
        p99 = " ".join(f"p99[{k}]={v:.0f}us"
                       for k, v in e["p99_us"].items())
        out.append(f"   w{e['window']:>4}  off {e['offered_events']:>6} "
                   f"dlv {e['delivered_events']:>6} "
                   f"def {e['deferred_events']:>5} "
                   f"rer {e['rerouted']:>4}  {p99}{stall}{marks}")
    t = report["totals"]
    out.append(f"-- totals: offered {t['offered_events']} delivered "
               f"{t['delivered_events']} deferred {t['deferred_events']} "
               f"rerouted {t['rerouted']}")
    return "\n".join(out)


def main(argv: Sequence[str] | None = None) -> None:
    from repro.obs import log as obs_log
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Render one run directory's window timeline.")
    ap.add_argument("run_dir", help="directory written by write_run_dir")
    ap.add_argument("--json", action="store_true",
                    help="emit the structured report as JSON")
    obs_log.add_log_args(ap)
    args = ap.parse_args(argv)
    obs_log.setup_logging_from_args(args)
    report = build_report(args.run_dir)
    if args.json:
        print(json.dumps(report, indent=1))
    else:
        print(render(report))


if __name__ == "__main__":
    main()
