"""Chrome-trace-event span tracing (Perfetto-loadable JSON).

A :class:`Tracer` collects complete spans (``ph: "X"``), instant events
(``ph: "i"``) and thread-name metadata into the Chrome Trace Event JSON
format — open the written file directly in https://ui.perfetto.dev or
``chrome://tracing``.  Timestamps are microseconds since the tracer's
creation (``time.perf_counter_ns`` based, so monotonic per process).

Design constraints (serving-engine hot path):

* **Cheap when disabled** — ``Tracer(enabled=False)`` (or the shared
  :data:`NULL` tracer) still *times* a ``span()`` body (two
  ``perf_counter_ns`` calls, exactly what the ad-hoc ``time.perf_counter``
  pairs it replaces cost) but records nothing, so callers can migrate
  wall-clock measurements onto the span API unconditionally.
* **Thread safe** — the ingest and device threads of the serving engine
  append concurrently; a single lock guards the event list.
* **Correlatable** — span ``args`` carry the absolute flush-window
  indices (``win0`` / ``win_abs``) the device-side flight recorder
  timestamps its ring rows with, so host spans and device windows line
  up on one timeline (see ``docs/observability.md``).

Span naming scheme: ``<component>/<stage>`` — e.g. ``ingest/fill``,
``device/dispatch``, ``drain/segment``, ``train/step``, ``serve/decode``.
"""
from __future__ import annotations

import json
import threading
import time
from contextlib import contextmanager


class SpanHandle:
    """Mutable view of one in-flight span: ``args`` may be updated inside
    the ``with`` body (e.g. once the window index is known); ``dur_us`` /
    ``dur_s`` are valid after the block exits — this is what lets the
    span API replace raw ``time.perf_counter`` pairs."""

    __slots__ = ("name", "t0_us", "dur_us", "args")

    def __init__(self, name: str, t0_us: float, args: dict):
        self.name = name
        self.t0_us = t0_us
        self.dur_us = 0.0
        self.args = args

    @property
    def dur_s(self) -> float:
        return self.dur_us * 1e-6


class Tracer:
    """Collects Chrome-trace events; one per process/run."""

    def __init__(self, enabled: bool = True, process_name: str = "repro"):
        self.enabled = enabled
        self.process_name = process_name
        self._t0_ns = time.perf_counter_ns()
        self._lock = threading.Lock()
        self._events: list[dict] = []
        self._tids: dict[str, int] = {}     # track name -> tid

    # -- clock -------------------------------------------------------------
    def now_us(self) -> float:
        """Microseconds since tracer creation (monotonic)."""
        return (time.perf_counter_ns() - self._t0_ns) / 1e3

    # -- tracks ------------------------------------------------------------
    def _tid(self, track: str | None) -> int:
        name = track or threading.current_thread().name
        with self._lock:
            if name not in self._tids:
                self._tids[name] = len(self._tids)
            return self._tids[name]

    # -- recording ---------------------------------------------------------
    @contextmanager
    def span(self, name: str, *, track: str | None = None,
             cat: str = "host", **args):
        """Time a block; record it as a complete span when enabled.

        The yielded :class:`SpanHandle` keeps timing even when the tracer
        is disabled, so ``sp.dur_s`` can feed existing wall-clock
        consumers (straggler checks, throughput math) unconditionally.
        """
        sp = SpanHandle(name, self.now_us(), dict(args))
        try:
            yield sp
        finally:
            sp.dur_us = self.now_us() - sp.t0_us
            if self.enabled:
                self._append({"name": name, "ph": "X", "cat": cat,
                              "ts": sp.t0_us, "dur": sp.dur_us,
                              "pid": 0, "tid": self._tid(track),
                              "args": sp.args})

    def complete(self, name: str, t0_us: float, dur_us: float, *,
                 track: str | None = None, cat: str = "device", **args):
        """Record a span with explicit timestamps (synthetic device-window
        spans reconstructed from dispatch/ready times + ring indices)."""
        if self.enabled:
            self._append({"name": name, "ph": "X", "cat": cat,
                          "ts": float(t0_us), "dur": max(float(dur_us), 0.0),
                          "pid": 0, "tid": self._tid(track), "args": args})

    def instant(self, name: str, *, track: str | None = None,
                cat: str = "host", ts_us: float | None = None, **args):
        if self.enabled:
            self._append({"name": name, "ph": "i", "cat": cat, "s": "t",
                          "ts": self.now_us() if ts_us is None
                          else float(ts_us),
                          "pid": 0, "tid": self._tid(track), "args": args})

    def _append(self, ev: dict) -> None:
        with self._lock:
            self._events.append(ev)

    # -- export ------------------------------------------------------------
    def to_dict(self) -> dict:
        with self._lock:
            events = list(self._events)
            tids = dict(self._tids)
        meta = [{"name": "process_name", "ph": "M", "pid": 0, "tid": 0,
                 "args": {"name": self.process_name}}]
        for name, tid in sorted(tids.items(), key=lambda kv: kv[1]):
            meta.append({"name": "thread_name", "ph": "M", "pid": 0,
                         "tid": tid, "args": {"name": name}})
        return {"traceEvents": meta + events, "displayTimeUnit": "ms"}

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f)
            f.write("\n")


#: Shared disabled tracer: times spans, records nothing.
NULL = Tracer(enabled=False)


def validate_trace(obj: dict | list) -> list[str]:
    """Validate a Chrome-trace JSON object; return problems (empty = OK).

    Checks what the CI ``trace-smoke`` job and the committed-artifact test
    rely on: the container parses as the Trace Event format, complete
    spans have non-negative durations, and per-track timestamps are
    monotonically non-decreasing (what Perfetto's track builder needs).
    """
    problems: list[str] = []
    events = obj.get("traceEvents") if isinstance(obj, dict) else obj
    if not isinstance(events, list) or not events:
        return ["no traceEvents list"]
    last_ts: dict[tuple, float] = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict) or "ph" not in ev or "name" not in ev:
            problems.append(f"event {i}: missing ph/name")
            continue
        if ev["ph"] == "M":
            continue
        if "ts" not in ev:
            problems.append(f"event {i} ({ev['name']}): missing ts")
            continue
        if ev["ph"] == "X" and ev.get("dur", 0) < 0:
            problems.append(f"event {i} ({ev['name']}): negative dur")
        key = (ev.get("pid", 0), ev.get("tid", 0))
        if ev["ts"] < last_ts.get(key, float("-inf")):
            problems.append(f"event {i} ({ev['name']}): ts not monotonic "
                            f"on track {key}")
        last_ts[key] = ev["ts"]
    return problems


def thread_names(obj: dict | list) -> dict[int, str]:
    """tid -> thread name from the trace's metadata events."""
    events = obj.get("traceEvents") if isinstance(obj, dict) else obj
    out: dict[int, str] = {}
    for ev in events or []:
        if ev.get("ph") == "M" and ev.get("name") == "thread_name":
            out[ev.get("tid", 0)] = ev.get("args", {}).get("name", "")
    return out
