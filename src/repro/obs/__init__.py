"""Unified observability: flight recorder, span tracing, metrics, report.

Three layers, one subsystem (ISSUE 9 — the instrumentation substrate the
scale-up and adaptive-routing work reports through):

* ``repro.obs.recorder`` — the device-side **flight recorder**: a
  static-shape per-window telemetry ring carried through the simulator /
  serving-engine ``lax.scan`` (off by default; the disabled path leaves
  the carry pytree and lowered HLO bit-identical to an uninstrumented
  build) and exported to the host at segment boundaries.
* ``repro.obs.spans`` — Chrome-trace-event / Perfetto JSON **span
  tracing** for host threads and device segments, correlated with the
  recorder timeline via the wire word's absolute-window meta lane.
* ``repro.obs.metrics`` — a small counter/gauge/histogram **registry**
  with Prometheus text exposition and JSONL snapshots, fed from
  ``LinkStats`` / ``WindowStats`` / engine ledgers.
* ``repro.obs.report`` — ``python -m repro.obs.report <run-dir>``:
  top-congested links, per-tenant latency/SLO burn and fault/reroute
  events merged onto one window timeline.
* ``repro.obs.log`` — the library-wide ``logging`` setup (stderr only:
  benchmark stdout stays machine-readable).
"""
from repro.obs.log import get_logger, setup_logging
from repro.obs.metrics import Registry, parse_prometheus, prometheus_text
from repro.obs.recorder import (COUNTER_FIELDS, RecorderConfig,
                                TelemetryRing, counter_totals, global_rows,
                                record, ring_init, ring_rows, ring_shard)
from repro.obs.spans import Tracer, validate_trace

__all__ = [
    "COUNTER_FIELDS", "RecorderConfig", "Registry", "TelemetryRing",
    "Tracer", "counter_totals", "get_logger", "global_rows",
    "parse_prometheus", "prometheus_text", "record", "ring_init",
    "ring_rows", "ring_shard", "setup_logging", "validate_trace",
]
