"""Library-wide logging setup.

One root logger (``repro``), one stderr handler, level from (in order of
precedence) an explicit ``setup_logging`` call, the ``REPRO_LOG_LEVEL``
environment variable, or the WARNING default.  Everything under
``repro.*`` and ``benchmarks`` logs through here; **stdout is never
touched** — benchmark CSV/JSON protocols stay machine-readable when
piped.

Usage::

    from repro.obs.log import get_logger
    log = get_logger(__name__)
    log.info("staged segment %d", seg)

CLI entry points call ``setup_logging(quiet=args.quiet,
verbose=args.verbose)`` (or ``add_log_args(parser)`` +
``setup_logging_from_args(args)``) once at startup.
"""
from __future__ import annotations

import logging
import os
import sys

ROOT = "repro"
ENV_VAR = "REPRO_LOG_LEVEL"

_configured = False


def get_logger(name: str = "") -> logging.Logger:
    """Logger under the ``repro`` hierarchy (idempotent lazy setup)."""
    _ensure_configured()
    if not name or name == ROOT:
        return logging.getLogger(ROOT)
    if name.startswith(ROOT + ".") or name == "benchmarks" \
            or name.startswith("benchmarks."):
        return logging.getLogger(name)
    return logging.getLogger(f"{ROOT}.{name}")


def setup_logging(level: int | str | None = None, *, quiet: bool = False,
                  verbose: bool = False) -> logging.Logger:
    """Configure the ``repro`` root logger (stderr handler, once).

    ``quiet`` wins over ``verbose`` wins over ``level`` wins over the
    ``REPRO_LOG_LEVEL`` env var wins over the WARNING default.
    """
    global _configured
    root = logging.getLogger(ROOT)
    if not _configured:
        handler = logging.StreamHandler(sys.stderr)
        handler.setFormatter(logging.Formatter(
            "%(asctime)s %(levelname)s %(name)s: %(message)s",
            datefmt="%H:%M:%S"))
        root.addHandler(handler)
        root.propagate = False
        logging.getLogger("benchmarks").addHandler(handler)
        logging.getLogger("benchmarks").propagate = False
        _configured = True
    if quiet:
        eff: int | str = logging.ERROR
    elif verbose:
        eff = logging.DEBUG
    elif level is not None:
        eff = level
    else:
        eff = os.environ.get(ENV_VAR, "WARNING").upper()
    root.setLevel(eff)
    logging.getLogger("benchmarks").setLevel(eff)
    return root


def _ensure_configured() -> None:
    if not _configured:
        setup_logging()


def add_log_args(parser) -> None:
    """Attach the standard ``--quiet`` / ``--verbose`` pair."""
    parser.add_argument("--quiet", action="store_true",
                        help="errors only (stderr)")
    parser.add_argument("--verbose", action="store_true",
                        help="debug logging (stderr)")


def setup_logging_from_args(args) -> logging.Logger:
    return setup_logging(quiet=getattr(args, "quiet", False),
                         verbose=getattr(args, "verbose", False))
