"""Device-side flight recorder: a static-shape per-window telemetry ring.

The fabric's end-of-run ``LinkStats`` aggregates answer *how much*
congestion a run saw; the scale-up and adaptive-routing work need to know
*when* and *where* it formed.  The flight recorder answers that without
leaving the device: a fixed-depth ring (:class:`TelemetryRing`) rides the
simulator / serving-engine ``lax.scan`` carry and, each flush window,
snapshots

* the absolute window index,
* the per-window deltas of the conservation-law counters
  (:data:`COUNTER_FIELDS` — offered/sent/deferred/delivered, credit
  stalls, park/unpark/in-fabric occupancy, reroutes),
* per-link credit occupancy (``FabricState.bank.credits``) and the
  ``parked_by_link`` hold table — the two sides of the per-link identity
  ``credits + pending + parked_by_link == limit``,
* per-link deferred-demand attribution (``LinkStats.stalled_by_link``,
  populated when the transport is built with ``stall_attribution=True``;
  an all-zero lane otherwise so the ring layout never varies),
* the latency-histogram delta of the window
  (``repro.wire.latency.N_LATENCY_BINS`` log-2 bins).

Everything is written with one dynamic-slot ``.at[slot].set`` per lane —
O(depth) memory, O(1) per window, shape-static, so the ring scans and
``shard_map``s like any other carry leaf.  Depth is configurable
(:class:`RecorderConfig`); a run longer than ``depth`` windows keeps the
most recent ``depth`` (true flight-recorder semantics — ``ring_rows``
reorders oldest→newest on the host and reports how many windows were
overwritten).

The recorder is **off by default**.  When disabled, nothing here is
imported into the scan body and the carry pytree / lowered HLO are
bit-identical to an uninstrumented build (pinned by
``tests/test_obs.py``).
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

#: LinkStats fields recorded per window — the subset that is uniformly
#: shaped across backends: scalar () in single-tenant stats, (T,) with a
#: leading tenant axis in the multi-tenant transport's stats.  (``hops``
#: and the byte counters are fabric-level in tenant stats, so they are
#: deliberately excluded; the metrics registry still exports their
#: run-level totals.)
COUNTER_FIELDS = (
    "offered_events",
    "sent_events",
    "deferred_events",
    "delivered_events",
    "credit_stalls",
    "parked_events",
    "unparked_events",
    "in_fabric_events",
    "rerouted",
)


class RecorderConfig(NamedTuple):
    """Flight-recorder knobs.  ``depth`` is the ring's window capacity —
    a run longer than ``depth`` windows keeps the most recent ``depth``."""

    depth: int = 64


class TelemetryRing(NamedTuple):
    """The carried ring.  ``cursor`` counts total records ever written;
    the write slot is ``cursor % depth``, so wrap-around is implicit and
    the host side can tell a partially-filled ring (``cursor < depth``)
    from a wrapped one.  ``window`` is initialized to -1: a slot still
    holding -1 was never written.

    Lane shapes (depth D, K directed links, C the counter shape — () or
    (T,) — and H the latency-histogram bins):

    * ``cursor``          ()          i32
    * ``window``          (D,)        i32  absolute flush-window index
    * ``counters``        (D, 9, *C)  i32  per-window COUNTER_FIELDS deltas
    * ``credits``         (D, *K')    i32  end-of-window credit occupancy
                                        (K' = partition slots when
                                        multi-tenant: ``(T+1)*K``)
    * ``parked_by_link``  (D, *K')    i32  end-of-window credit holds
    * ``stalled_by_link`` (D, K)      i32  deferred demand per physical
                                        egress link (zeros unless the
                                        transport attributes stalls)
    * ``hist``            (D, *H)     i32  latency-histogram delta
    """

    cursor: jax.Array
    window: jax.Array
    counters: jax.Array
    credits: jax.Array
    parked_by_link: jax.Array
    stalled_by_link: jax.Array
    hist: jax.Array

    @property
    def depth(self) -> int:
        return self.window.shape[-1]


def ring_init(depth: int, state, counter_shape: Sequence[int],
              hist_shape: Sequence[int], n_links: int) -> TelemetryRing:
    """Empty ring sized from a concrete ``FabricState``.

    ``counter_shape`` is the shape of one COUNTER_FIELDS entry (``()``
    single-tenant, ``(T,)`` multi-tenant), ``hist_shape`` the latency
    digest's histogram shape, ``n_links`` the PHYSICAL directed-link
    count K (the stall-attribution lane is always physical even when the
    credit lanes carry partition slots).
    """
    depth = int(depth)
    if depth < 1:
        raise ValueError(f"ring depth must be >= 1, got {depth}")
    kp = state.bank.credits.shape  # (K,) or ((T+1)*K,)
    return TelemetryRing(
        cursor=jnp.zeros((), jnp.int32),
        window=jnp.full((depth,), -1, jnp.int32),
        counters=jnp.zeros((depth, len(COUNTER_FIELDS), *counter_shape),
                           jnp.int32),
        credits=jnp.zeros((depth, *kp), jnp.int32),
        parked_by_link=jnp.zeros((depth, *kp), jnp.int32),
        stalled_by_link=jnp.zeros((depth, int(n_links)), jnp.int32),
        hist=jnp.zeros((depth, *hist_shape), jnp.int32),
    )


def record(ring: TelemetryRing, win, link_stats, state,
           hist) -> TelemetryRing:
    """Write one window's record at ``cursor % depth`` (jit/scan-safe).

    ``link_stats`` is the window's ``LinkStats`` delta, ``state`` the
    END-of-window ``FabricState`` (occupancy snapshot), ``hist`` the
    window's latency-histogram delta.
    """
    depth = ring.depth
    slot = jax.lax.rem(ring.cursor, jnp.int32(depth))
    counters = jnp.stack(
        [jnp.asarray(getattr(link_stats, f)).astype(jnp.int32)
         for f in COUNTER_FIELDS])
    sbl = getattr(link_stats, "stalled_by_link", None)
    if sbl is None:
        sbl = jnp.zeros(ring.stalled_by_link.shape[-1:], jnp.int32)
    return TelemetryRing(
        cursor=ring.cursor + 1,
        window=ring.window.at[slot].set(jnp.asarray(win, jnp.int32)),
        counters=ring.counters.at[slot].set(counters),
        credits=ring.credits.at[slot].set(
            state.bank.credits.astype(jnp.int32)),
        parked_by_link=ring.parked_by_link.at[slot].set(
            state.parked_by_link.astype(jnp.int32)),
        stalled_by_link=ring.stalled_by_link.at[slot].set(
            sbl.astype(jnp.int32)),
        hist=ring.hist.at[slot].set(jnp.asarray(hist).astype(jnp.int32)),
    )


def ring_shard(ring: TelemetryRing, s: int = 0) -> TelemetryRing:
    """Strip the leading shard axis ``shard_map``-returned rings carry.

    The descriptor lanes (credits, parked_by_link, stalled_by_link) are
    replicated global state, so any shard's view is THE view; the counter
    lanes are per-shard and callers wanting global totals sum them across
    shards before (or instead of) picking one.
    """
    return jax.tree_util.tree_map(lambda a: a[s], ring)


def ring_rows(ring: TelemetryRing) -> list[dict]:
    """Host-side decode: ordered oldest→newest, wrap-aware.

    Returns one JSON-serializable dict per recorded window::

        {"window": int, "counters": {field: int | [int, ...]},
         "credits": [...], "parked_by_link": [...],
         "stalled_by_link": [...], "hist": [...], "overwritten": int}

    ``overwritten`` (same on every row) is how many older windows the
    ring dropped; 0 means the full run is present.
    """
    cursor = int(np.asarray(ring.cursor))
    depth = ring.depth
    n = min(cursor, depth)
    overwritten = cursor - n
    window = np.asarray(ring.window)
    counters = np.asarray(ring.counters)
    credits = np.asarray(ring.credits)
    pbl = np.asarray(ring.parked_by_link)
    sbl = np.asarray(ring.stalled_by_link)
    hist = np.asarray(ring.hist)
    if cursor <= depth:
        order = list(range(n))
    else:
        start = cursor % depth
        order = [(start + i) % depth for i in range(depth)]
    rows = []
    for slot in order:
        rows.append({
            "window": int(window[slot]),
            "counters": {
                f: (int(counters[slot, i]) if counters.ndim == 2
                    else counters[slot, i].astype(int).tolist())
                for i, f in enumerate(COUNTER_FIELDS)},
            "credits": credits[slot].astype(int).tolist(),
            "parked_by_link": pbl[slot].astype(int).tolist(),
            "stalled_by_link": sbl[slot].astype(int).tolist(),
            "hist": hist[slot].astype(int).tolist(),
            "overwritten": overwritten,
        })
    return rows


def global_rows(ring: TelemetryRing, n_shards: int) -> list[dict]:
    """Decode a ``shard_map``-returned ring (leading shard axis) into
    GLOBAL per-window rows: the per-shard counter and latency-histogram
    lanes are summed across shards; the replicated descriptor lanes
    (credits / parked_by_link / stalled_by_link) come from shard 0.
    This is what the run directory's ``recorder.jsonl`` stores."""
    per = [ring_rows(ring_shard(ring, s)) for s in range(int(n_shards))]
    rows = per[0]
    for other in per[1:]:
        for r, o in zip(rows, other):
            for f in COUNTER_FIELDS:
                r["counters"][f] = (
                    np.asarray(r["counters"][f], np.int64)
                    + np.asarray(o["counters"][f], np.int64)).tolist()
            r["hist"] = (np.asarray(r["hist"], np.int64)
                         + np.asarray(o["hist"], np.int64)).tolist()
    return rows


def counter_totals(rows: list[dict]) -> dict[str, np.ndarray]:
    """Sum each COUNTER_FIELDS lane over a row list — the quantity the
    conservation tests compare bit-exactly against the end-of-run
    ``LinkStats`` totals (valid when ``overwritten == 0``)."""
    if rows and rows[0]["overwritten"]:
        raise ValueError("ring wrapped: totals would undercount "
                         f"({rows[0]['overwritten']} windows dropped)")
    out: dict[str, np.ndarray] = {}
    for f in COUNTER_FIELDS:
        vals = [np.asarray(r["counters"][f], np.int64) for r in rows]
        out[f] = (np.sum(vals, axis=0) if vals
                  else np.zeros((), np.int64))
    return out
