"""Metrics registry: counters / gauges / histograms, Prometheus + JSONL.

A deliberately small, dependency-free registry in the Prometheus data
model: every metric has a name, help text and optional label names; the
latency histograms reuse the repo's canonical log-2 bin edges
(``repro.wire.latency.LATENCY_BIN_EDGES_US``) so device-side digests
feed straight in via :meth:`Histogram.add_binned`.

Two exports:

* :func:`prometheus_text` — the text exposition format (scrape-able /
  ``promtool check metrics``-shaped); parsed back by
  :func:`parse_prometheus` so tests assert on values, not formatting.
* :meth:`Registry.snapshot` / :func:`write_jsonl` — one JSON object per
  sample for the run-dir artifact ``metrics.jsonl`` that
  ``repro.obs.report`` consumes.

Feeders for the repo's native stat records live here too:
:func:`export_link_stats` (``LinkStats`` totals) and
:func:`export_tenant_digests` (per-tenant latency digests from the
serving engine's ledger).
"""
from __future__ import annotations

import json
import math
import re
import time
from typing import Iterable, Sequence

import numpy as np

from repro.wire import latency as wire_latency

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")


def _label_key(labels: Sequence[str], kw: dict) -> tuple:
    if set(kw) != set(labels):
        raise ValueError(f"labels {sorted(kw)} != declared {sorted(labels)}")
    return tuple(str(kw[name]) for name in labels)


class _Metric:
    kind = "untyped"

    def __init__(self, name: str, help: str, labels: Sequence[str]):
        if not _NAME_RE.match(name):
            raise ValueError(f"bad metric name {name!r}")
        self.name = name
        self.help = help
        self.labels = tuple(labels)
        self._values: dict[tuple, float] = {}

    def _fmt_labels(self, key: tuple) -> str:
        if not self.labels:
            return ""
        pairs = ",".join(f'{n}="{v}"' for n, v in zip(self.labels, key))
        return "{" + pairs + "}"

    def samples(self) -> Iterable[tuple[str, str, float]]:
        for key, v in sorted(self._values.items()):
            yield self.name, self._fmt_labels(key), v

    def value(self, **kw) -> float:
        return self._values.get(_label_key(self.labels, kw), 0.0)


class Counter(_Metric):
    kind = "counter"

    def inc(self, amount: float = 1.0, **kw) -> None:
        if amount < 0:
            raise ValueError("counters only go up")
        key = _label_key(self.labels, kw)
        self._values[key] = self._values.get(key, 0.0) + float(amount)


class Gauge(_Metric):
    kind = "gauge"

    def set(self, value: float, **kw) -> None:
        self._values[_label_key(self.labels, kw)] = float(value)


class Histogram(_Metric):
    """Pre-binned histogram (the device side already bins latencies).

    ``edges`` are the inclusive upper bin edges; one overflow (+Inf)
    bucket is implicit.  ``_sum`` is tracked exactly when the caller
    provides it (``sum_value``), otherwise conservatively estimated from
    upper bin edges (documented in docs/observability.md).
    """

    kind = "histogram"

    def __init__(self, name: str, help: str, labels: Sequence[str],
                 edges: Sequence[float]):
        super().__init__(name, help, labels)
        self.edges = tuple(float(e) for e in edges)
        self._buckets: dict[tuple, np.ndarray] = {}
        self._sums: dict[tuple, float] = {}
        self._sum_exact: dict[tuple, bool] = {}

    def add_binned(self, counts, sum_value: float | None = None,
                   **kw) -> None:
        """Merge per-bin event counts (len == len(edges) or +1 with an
        explicit overflow bin)."""
        counts = np.asarray(counts, np.int64).reshape(-1)
        if counts.shape[0] == len(self.edges):
            counts = np.concatenate([counts, [0]])
        if counts.shape[0] != len(self.edges) + 1:
            raise ValueError(
                f"{self.name}: got {counts.shape[0]} bins, want "
                f"{len(self.edges)} (+1 overflow)")
        key = _label_key(self.labels, kw)
        self._buckets[key] = self._buckets.get(
            key, np.zeros(len(self.edges) + 1, np.int64)) + counts
        if sum_value is not None:
            self._sums[key] = self._sums.get(key, 0.0) + float(sum_value)
            self._sum_exact.setdefault(key, True)
        else:
            est = float(np.sum(counts[:-1] * np.asarray(self.edges)))
            self._sums[key] = self._sums.get(key, 0.0) + est
            self._sum_exact[key] = False

    def observe(self, value: float, **kw) -> None:
        idx = int(np.searchsorted(self.edges, value, side="left"))
        counts = np.zeros(len(self.edges) + 1, np.int64)
        counts[min(idx, len(self.edges))] = 1
        self.add_binned(counts, sum_value=value, **kw)

    def samples(self) -> Iterable[tuple[str, str, float]]:
        for key in sorted(self._buckets):
            counts = self._buckets[key]
            cum = 0
            for edge, c in zip(self.edges, counts[:-1]):
                cum += int(c)
                le = self._fmt_le(edge)
                yield (self.name + "_bucket",
                       self._with_extra(key, ("le", le)), float(cum))
            cum += int(counts[-1])
            yield (self.name + "_bucket",
                   self._with_extra(key, ("le", "+Inf")), float(cum))
            yield self.name + "_count", self._fmt_labels(key), float(cum)
            yield (self.name + "_sum", self._fmt_labels(key),
                   float(self._sums.get(key, 0.0)))

    @staticmethod
    def _fmt_le(edge: float) -> str:
        return repr(edge) if not math.isinf(edge) else "+Inf"

    def _with_extra(self, key: tuple, extra: tuple[str, str]) -> str:
        pairs = [f'{n}="{v}"' for n, v in zip(self.labels, key)]
        pairs.append(f'{extra[0]}="{extra[1]}"')
        return "{" + ",".join(pairs) + "}"

    def percentile(self, q: float, **kw) -> float:
        """Upper-edge quantile estimate (same semantics as
        ``repro.wire.latency.percentile_from_hist``: the upper edge of
        the bin holding the ceil(q*total)-th event; the open overflow
        bin reports twice the last edge; empty histogram 0)."""
        key = _label_key(self.labels, kw)
        counts = self._buckets.get(key)
        if counts is None:
            return 0.0
        total = int(counts.sum())
        if total == 0:
            return 0.0
        thresh = max(int(math.ceil(q * total)), 1)
        b = int(np.argmax(np.cumsum(counts) >= thresh))
        return (self.edges[b] if b < len(self.edges)
                else self.edges[-1] * 2)


class Registry:
    """Holds the run's metrics; one per process (or per run-dir)."""

    def __init__(self):
        self._metrics: dict[str, _Metric] = {}

    def _add(self, m: _Metric) -> _Metric:
        prev = self._metrics.get(m.name)
        if prev is not None:
            if type(prev) is not type(m) or prev.labels != m.labels:
                raise ValueError(f"metric {m.name!r} re-registered with a "
                                 f"different type/labels")
            return prev
        self._metrics[m.name] = m
        return m

    def counter(self, name: str, help: str = "",
                labels: Sequence[str] = ()) -> Counter:
        return self._add(Counter(name, help, labels))  # type: ignore

    def gauge(self, name: str, help: str = "",
              labels: Sequence[str] = ()) -> Gauge:
        return self._add(Gauge(name, help, labels))    # type: ignore

    def histogram(self, name: str, help: str = "",
                  labels: Sequence[str] = (),
                  edges: Sequence[float] = wire_latency.LATENCY_BIN_EDGES_US
                  ) -> Histogram:
        return self._add(Histogram(name, help, labels, edges))  # type: ignore

    def metrics(self) -> list[_Metric]:
        return [self._metrics[k] for k in sorted(self._metrics)]

    def snapshot(self, ts: float | None = None) -> list[dict]:
        """One dict per sample (the ``metrics.jsonl`` row shape)."""
        ts = time.time() if ts is None else ts
        out = []
        for m in self.metrics():
            for name, labels, value in m.samples():
                out.append({"ts": ts, "metric": name, "kind": m.kind,
                            "labels": labels, "value": value})
        return out


def prometheus_text(reg: Registry) -> str:
    """Prometheus text exposition format, rev 0.0.4."""
    lines = []
    for m in reg.metrics():
        if m.help:
            lines.append(f"# HELP {m.name} {m.help}")
        lines.append(f"# TYPE {m.name} {m.kind}")
        for name, labels, value in m.samples():
            lines.append(f"{name}{labels} {_fmt_value(value)}")
    return "\n".join(lines) + "\n"


def _fmt_value(v: float) -> str:
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(v)


_SAMPLE_RE = re.compile(
    r"^([a-zA-Z_:][a-zA-Z0-9_:]*)(\{[^}]*\})?\s+(\S+)$")
_LABEL_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="([^"]*)"')


def parse_prometheus(text: str) -> dict[str, dict[frozenset, float]]:
    """Parse the text exposition back: name -> {frozenset(label pairs):
    value}.  Raises ValueError on a malformed sample line — what the CI
    ``trace-smoke`` job uses to validate the exposition."""
    out: dict[str, dict[frozenset, float]] = {}
    types: dict[str, str] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line:
            continue
        if line.startswith("# TYPE "):
            _, _, name, kind = line.split(None, 3)
            types[name] = kind
            continue
        if line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if not m:
            raise ValueError(f"malformed sample line: {line!r}")
        name, labels, value = m.group(1), m.group(2) or "", m.group(3)
        key = frozenset(_LABEL_RE.findall(labels))
        out.setdefault(name, {})[key] = float(value)
    for name in out:
        base = name
        for suffix in ("_bucket", "_count", "_sum"):
            if name.endswith(suffix) and name[: -len(suffix)] in types:
                base = name[: -len(suffix)]
        if base not in types:
            raise ValueError(f"sample {name!r} has no # TYPE line")
    return out


def write_jsonl(path: str, reg: Registry, ts: float | None = None) -> None:
    with open(path, "w") as f:
        for row in reg.snapshot(ts):
            f.write(json.dumps(row) + "\n")


# -- feeders for the repo's native stat records ------------------------------

def export_link_stats(reg: Registry, link_stats, *, backend: str) -> None:
    """Fold (stacked or scalar) ``LinkStats`` totals into the registry.

    Works on a single window's record or a whole run's stacked stats
    (any leading axes) — everything is summed, matching the run-level
    conservation identities the tests pin.
    """
    fields = ("offered_events", "sent_events", "deferred_events",
              "delivered_events", "parked_events", "unparked_events",
              "rerouted", "credit_stalls", "hops", "bytes_on_wire")
    for f in fields:
        v = getattr(link_stats, f, None)
        if v is None:
            continue
        c = reg.counter(f"fabric_{f}_total",
                        f"sum of LinkStats.{f} over the run",
                        labels=("backend",))
        c.inc(float(np.asarray(v, np.float64).sum()), backend=backend)
    dw = getattr(link_stats, "queue_dwell_us", None)
    if dw is not None:
        reg.counter("fabric_queue_dwell_us_total",
                    "total queueing dwell charged to delivered rows (us)",
                    labels=("backend",)).inc(
            float(np.asarray(dw, np.float64).sum()), backend=backend)


def export_tenant_digests(reg: Registry, digests) -> None:
    """Per-tenant delivered counts + latency histograms from the serving
    engine's ledger digests (``repro.serve.tenancy.TenantDigest``)."""
    c = reg.counter("tenant_delivered_events_total",
                    "events delivered to each tenant", labels=("tenant",))
    g99 = reg.gauge("tenant_latency_p99_us",
                    "per-tenant p99 event latency (us, log-bin estimate)",
                    labels=("tenant",))
    h = reg.histogram("tenant_latency_us",
                      "per-tenant event latency (us)", labels=("tenant",))
    for d in digests:
        c.inc(float(d.delivered), tenant=d.name)
        g99.set(float(d.p99_us), tenant=d.name)
        h.add_binned(np.asarray(d.hist, np.int64),
                     sum_value=float(d.mean_us) * float(d.delivered),
                     tenant=d.name)
