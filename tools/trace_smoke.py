"""Trace smoke: serve two instrumented segments, validate every artifact.

Runs the streaming spike serving engine for ~2 segments with the full
observability stack on — flight-recorder ring in the device carry,
Perfetto span tracing on the host threads, Prometheus metrics — writes
the run directory, then validates what CI's ``trace-smoke`` job promises:

* ``trace.json`` parses as Chrome Trace Event JSON, per-track timestamps
  are monotonic, and every engine thread (``spike-ingest``,
  ``spike-device``, ``device``) contributed at least one span;
* host spans correlate to device windows: every ``window`` instant's
  absolute window index also appears in the flight-recorder rows;
* ``metrics.prom`` parses as Prometheus text exposition;
* ``python -m repro.obs.report`` builds a structured report from the
  directory (timeline rows + tenant SLO blocks present).

Exits non-zero with a reason on any failure.  ``--artifact PATH`` copies
the validated trace to PATH — how ``docs/observability_trace.json`` (the
committed example trace) is produced.

Usage: python tools/trace_smoke.py [--out-dir DIR] [--artifact PATH]
"""
from __future__ import annotations

import os
import sys

# must precede the jax import: the engine needs >1 host device
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=4 "
                           + os.environ.get("XLA_FLAGS", ""))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import json
import shutil

SEGMENTS = 2


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="/tmp/trace_smoke")
    ap.add_argument("--artifact", default=None,
                    help="copy the validated trace.json here (refreshes "
                         "docs/observability_trace.json)")
    args = ap.parse_args()

    import numpy as np
    import jax
    from jax.sharding import Mesh

    from repro.obs import metrics as obs_metrics
    from repro.obs import recorder as obs_recorder
    from repro.obs import report as obs_report
    from repro.obs import spans as obs_spans
    from repro.serve.loadgen import PoissonLoadGen, TenantProfile
    from repro.serve.spike_engine import EngineConfig, SpikeEngine
    from repro.serve.tenancy import TenantSpec

    mesh = Mesh(np.array(jax.devices()[:4]), ("w",))
    cfg = EngineConfig(capacity=8, link_credits=16, notify_latency=2,
                      window_us=100.0, seg_windows=3, nx=2, ny=2, nz=1)
    tenants = [TenantSpec("a", reserve=8, rate_epw=16.0),
               TenantSpec("b", reserve=4, rate_epw=8.0)]
    src = PoissonLoadGen(11, [TenantProfile("a", 16.0),
                              TenantProfile("b", 8.0)], 4, cfg.capacity)
    eng = SpikeEngine(mesh, "w", tenants, cfg, src,
                      recorder=obs_recorder.RecorderConfig(depth=32),
                      tracer=obs_spans.Tracer())
    eng.warmup()
    rep = eng.run(SEGMENTS)
    run_dir = obs_report.write_engine_run(args.out_dir, eng, rep)
    print(f"run dir: {run_dir} ({rep.windows} windows, "
          f"{int(rep.delivered.sum())} delivered)")

    failures: list[str] = []

    # -- trace.json: parses, monotonic, every engine thread present --------
    trace_path = os.path.join(run_dir, "trace.json")
    try:
        with open(trace_path) as f:
            trace = json.load(f)
    except (OSError, ValueError) as e:
        sys.exit(f"trace-smoke FAIL: trace.json unreadable: {e}")
    problems = obs_spans.validate_trace(trace)
    failures += [f"trace.json: {p}" for p in problems]
    names = obs_spans.thread_names(trace)
    spans_per_track: dict[str, int] = {}
    windows_in_trace: set[int] = set()
    for ev in trace["traceEvents"]:
        if ev.get("ph") in ("X", "i"):
            track = names.get(ev.get("tid", 0), "?")
            spans_per_track[track] = spans_per_track.get(track, 0) + 1
            if ev.get("name") == "window":
                windows_in_trace.add(int(ev["args"]["window"]))
    for track in ("spike-ingest", "spike-device", "device"):
        if spans_per_track.get(track, 0) < 1:
            failures.append(f"trace.json: no spans on thread {track!r} "
                            f"(have {spans_per_track})")

    # -- correlation: trace window indices exist in the recorder rows ------
    rec_windows = {int(r["window"])
                   for r in obs_report._read_jsonl(
                       os.path.join(run_dir, "recorder.jsonl"))}
    orphans = windows_in_trace - rec_windows
    if not windows_in_trace:
        failures.append("trace.json: no per-window device instants")
    if orphans:
        failures.append(f"correlation: trace windows {sorted(orphans)} "
                        f"missing from recorder.jsonl {sorted(rec_windows)}")

    # -- metrics.prom: valid Prometheus exposition -------------------------
    try:
        metrics = obs_metrics.parse_prometheus(
            open(os.path.join(run_dir, "metrics.prom")).read())
        if not metrics:
            failures.append("metrics.prom: empty exposition")
    except (OSError, ValueError) as e:
        failures.append(f"metrics.prom: {e}")

    # -- report: structured output builds ----------------------------------
    try:
        report = obs_report.build_report(run_dir)
        if not report["timeline"]:
            failures.append("report: empty window timeline")
        if not all("slo" in t for t in report["tenants"]):
            failures.append("report: tenant rows missing SLO block")
    except Exception as e:  # noqa: BLE001 - smoke gate, report any failure
        failures.append(f"report: build_report raised {e!r}")

    if failures:
        sys.exit("trace-smoke FAIL:\n  " + "\n  ".join(failures))

    if args.artifact:
        shutil.copyfile(trace_path, args.artifact)
        print(f"artifact: {args.artifact}")
    print(f"trace-smoke OK: {sum(spans_per_track.values())} events on "
          f"{len(spans_per_track)} tracks, {len(rec_windows)} recorded "
          f"windows, {len(metrics)} metric families")


if __name__ == "__main__":
    main()
