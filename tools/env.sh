# Benchmark environment tuning. Source before running benchmarks:
#
#     . tools/env.sh && PYTHONPATH=src python -m benchmarks.run
#
# Every setting is additive and gated, so sourcing this on a machine
# without the optional pieces (tcmalloc, OpenMP) is a no-op for them —
# benchmarks run fine without it, just with more allocator/logging noise
# in the timings.  POSIX sh; keep it bash-free.

# tcmalloc: faster malloc for the host-side staging path (pinned double
# buffers churn large numpy arrays every segment).  Only preload when the
# library actually exists — a dangling LD_PRELOAD breaks every child
# process, including the benchmark subprocesses.
for _lib in /usr/lib/x86_64-linux-gnu/libtcmalloc.so.4 \
            /usr/lib/libtcmalloc.so.4; do
    if [ -r "$_lib" ]; then
        LD_PRELOAD="$_lib${LD_PRELOAD:+:$LD_PRELOAD}"
        export LD_PRELOAD
        # silence per-allocation reports for the big staging buffers
        export TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD=60000000000
        break
    fi
done
unset _lib

# keep TF/XLA C++ chatter out of benchmark CSV output
export TF_CPP_MIN_LOG_LEVEL=4

# step markers at the outer while loop (vs entry): profiles attribute
# time per scanned flush window instead of per run.  Older XLA spelled
# this --xla_step_marker_location=1; current XLA takes the enum name.
# APPEND to any caller-set flags — benchmark subprocesses add their own
# --xla_force_host_platform_device_count on top of this variable.
XLA_FLAGS="--xla_step_marker_location=STEP_MARK_AT_TOP_LEVEL_WHILE_LOOP ${XLA_FLAGS:-}"
export XLA_FLAGS

# pin host threading: the serving engine runs its own ingest/device
# threads, and an unbounded OpenMP pool under them oversubscribes cores
# and adds run-to-run jitter to the sustained-rate rows.
if [ -z "${OMP_NUM_THREADS:-}" ]; then
    export OMP_NUM_THREADS=4
fi

# sentinel for benchmarks.run to report whether the env was sourced
export REPRO_BENCH_ENV=1
