"""Docs drift check: smoke-execute every quickstart command the docs show.

Every fenced ```bash block in README.md and docs/*.md is treated as a
sequence of shell commands the project promises will work. This script
executes each one from the repo root, so a README that drifts from the
actual CLI (renamed module, dropped flag, moved file) fails CI instead
of silently rotting:

* ``python -m pytest`` commands run with ``--collect-only -q`` appended
  (CI runs the full suite as its own step; collection still catches a
  broken command line, bad path or import error) and must collect at
  least one test.
* every other command runs exactly as written.

It also cross-checks that the README documents exactly the transport
backends the code registers (``repro.transport.BACKENDS``).

Usage: python tools/check_docs.py   (no arguments; exits non-zero on drift)
"""
from __future__ import annotations

import pathlib
import re
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
DOC_FILES = [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]
TIMEOUT_S = 1800

FENCE = re.compile(r"```bash\n(.*?)```", re.DOTALL)


def bash_blocks(path: pathlib.Path):
    for block in FENCE.findall(path.read_text()):
        cmds = [line.strip() for line in block.splitlines()
                if line.strip() and not line.strip().startswith("#")]
        if cmds:
            yield cmds


def run_cmd(cmd: str) -> None:
    shown = cmd
    if re.search(r"python -m pytest\b", cmd):
        cmd += " --collect-only"
    print(f"$ {shown}" + ("   [collect-only]" if cmd != shown else ""),
          flush=True)
    out = subprocess.run(cmd, shell=True, cwd=ROOT, capture_output=True,
                         text=True, timeout=TIMEOUT_S)
    if out.returncode != 0:
        sys.exit(f"DOCS DRIFT: command failed (rc={out.returncode}):\n"
                 f"  {shown}\n--- stdout ---\n{out.stdout[-4000:]}\n"
                 f"--- stderr ---\n{out.stderr[-4000:]}")
    if cmd != shown and not re.search(r"\d+ tests? collected", out.stdout):
        sys.exit(f"DOCS DRIFT: pytest command collected no tests:\n"
                 f"  {shown}\n{out.stdout[-2000:]}")


def check_backends() -> None:
    sys.path.insert(0, str(ROOT / "src"))
    from repro import transport
    text = (ROOT / "README.md").read_text()
    for name in transport.BACKENDS:
        if f"`{name}`" not in text:
            sys.exit(f"DOCS DRIFT: backend {name!r} (repro.transport."
                     f"BACKENDS) is not documented in README.md")


def main() -> None:
    n = 0
    for path in DOC_FILES:
        for cmds in bash_blocks(path):
            print(f"== {path.relative_to(ROOT)} ==", flush=True)
            for cmd in cmds:
                run_cmd(cmd)
                n += 1
    check_backends()
    print(f"docs OK: {n} commands executed, backend list in sync")


if __name__ == "__main__":
    main()
