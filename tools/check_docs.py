"""Docs drift check: smoke-execute every quickstart command the docs show.

Every fenced ```bash block in README.md and docs/*.md is treated as a
sequence of shell commands the project promises will work. This script
executes each one from the repo root, so a README that drifts from the
actual CLI (renamed module, dropped flag, moved file) fails CI instead
of silently rotting:

* ``python -m pytest`` commands run with ``--collect-only -q`` appended
  (CI runs the full suite as its own step; collection still catches a
  broken command line, bad path or import error) and must collect at
  least one test.
* every other command runs exactly as written.

It also cross-checks that the README documents exactly the transport
backends the code registers (``repro.transport.BACKENDS``) and every
wire protocol profile (``repro.wire.PROFILES``), and that the committed
``BENCH_*.json`` artifacts are full-shape runs: ``--smoke`` stamps its
rows ``"smoke": true`` (and older smoke artifacts are recognizable by
their shrunken shapes), and committing one would silently replace the
repo's perf trajectory with toy numbers.

Usage: python tools/check_docs.py   (no arguments; exits non-zero on drift)
"""
from __future__ import annotations

import json
import pathlib
import re
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
DOC_FILES = [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]
TIMEOUT_S = 1800

FENCE = re.compile(r"```bash\n(.*?)```", re.DOTALL)

def check_bench_artifacts() -> None:
    """Committed BENCH_*.json must be full-shape: reject any row stamped
    ``"smoke": true`` (Reporter does this for every --smoke row) and, as
    a belt for artifacts from before the flag, the shape fingerprints
    only a smoke run produces (transport/wire shrink to N=512 C=64;
    bench_kernels shrinks lif_step_ref to N4096 from N65536).  Every row
    must also carry its ``provenance`` block (git SHA, jax/jaxlib
    versions, device count/platform) — an artifact that cannot answer
    "what produced this number" is not diffable across PRs."""
    for path in sorted(ROOT.glob("BENCH_*.json")):
        rows = json.loads(path.read_text())
        for row in rows:
            where = f"{path.name}: row op={row.get('op')!r}"
            if row.get("smoke"):
                sys.exit(f"SMOKE ARTIFACT: {where} is from a --smoke run; "
                         f"refresh with a full `python -m benchmarks.run` "
                         f"before committing")
            prov = row.get("provenance")
            if not isinstance(prov, dict) or not {
                    "git_sha", "jax", "jaxlib", "devices",
                    "platform"} <= prov.keys():
                sys.exit(f"NO PROVENANCE: {where} lacks the provenance "
                         f"block (git_sha, jax/jaxlib, devices, platform); "
                         f"refresh with a current `python -m benchmarks.run`")
            shape = str(row.get("shape", ""))
            if "N=512 C=64" in shape:
                sys.exit(f"SMOKE ARTIFACT: {where} has smoke shape "
                         f"{shape!r}; refresh with a full run")
            if row.get("op") == "lif_step_ref" and shape == "N4096":
                sys.exit(f"SMOKE ARTIFACT: {where} is the smoke-sized "
                         f"lif_step_ref row; refresh with a full run")


def bash_blocks(path: pathlib.Path):
    for block in FENCE.findall(path.read_text()):
        cmds = [line.strip() for line in block.splitlines()
                if line.strip() and not line.strip().startswith("#")]
        if cmds:
            yield cmds


def run_cmd(cmd: str) -> None:
    shown = cmd
    if re.search(r"python -m pytest\b", cmd):
        cmd += " --collect-only"
    print(f"$ {shown}" + ("   [collect-only]" if cmd != shown else ""),
          flush=True)
    out = subprocess.run(cmd, shell=True, cwd=ROOT, capture_output=True,
                         text=True, timeout=TIMEOUT_S)
    if out.returncode != 0:
        sys.exit(f"DOCS DRIFT: command failed (rc={out.returncode}):\n"
                 f"  {shown}\n--- stdout ---\n{out.stdout[-4000:]}\n"
                 f"--- stderr ---\n{out.stderr[-4000:]}")
    if cmd != shown and not re.search(r"\d+ tests? collected", out.stdout):
        sys.exit(f"DOCS DRIFT: pytest command collected no tests:\n"
                 f"  {shown}\n{out.stdout[-2000:]}")


def check_backends() -> None:
    sys.path.insert(0, str(ROOT / "src"))
    from repro import transport, wire
    text = (ROOT / "README.md").read_text()
    for name in transport.BACKENDS:
        if f"`{name}`" not in text:
            sys.exit(f"DOCS DRIFT: backend {name!r} (repro.transport."
                     f"BACKENDS) is not documented in README.md")
    for name in wire.PROFILES:
        if f"`{name}`" not in text:
            sys.exit(f"DOCS DRIFT: wire profile {name!r} (repro.wire."
                     f"PROFILES) is not documented in README.md")


def main() -> None:
    check_bench_artifacts()
    n = 0
    for path in DOC_FILES:
        for cmds in bash_blocks(path):
            print(f"== {path.relative_to(ROOT)} ==", flush=True)
            for cmd in cmds:
                run_cmd(cmd)
                n += 1
    check_backends()
    # again AFTER executing the doc blocks: a quickstart command that
    # writes smoke artifacts into the repo root must fail here, not
    # silently clobber the committed full-shape numbers
    check_bench_artifacts()
    print(f"docs OK: {n} commands executed, backend + wire-profile lists "
          f"in sync, committed BENCH artifacts full-shape")


if __name__ == "__main__":
    main()
