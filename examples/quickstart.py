"""Quickstart: the paper's event-aggregation fabric in 60 seconds.

1. Build routing tables (source LUT + GUID multicast) for a toy 2-FPGA
   system, 2. push a window of spike events through the vectorized bucket
   aggregator, 3. run the same traffic through the cycle-accurate bucket
   model and watch the paper's header-overhead effect, 4. train a tiny LM
   for a few steps with the same framework stack.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregator, bucket, events as ev, routing as rt


def spike_aggregation_demo():
    print("=== paper §3.1: event aggregation ===")
    # events from 8 HICANN links, addressed to 4 destination FPGAs
    key = jax.random.PRNGKey(0)
    n = 256
    addr = jax.random.randint(key, (n,), 0, 64)
    deadline = jax.random.randint(jax.random.fold_in(key, 1), (n,), 50, 200)
    words = ev.pack(addr, deadline)
    dest = addr % 4

    b = aggregator.aggregate(words, dest, None, n_dest=4, capacity=124)
    cost = aggregator.window_cost(b.counts)
    naive = aggregator.unaggregated_cost(n)
    print(f"  {n} events -> buckets {list(np.asarray(b.counts))}")
    print(f"  aggregated: {int(cost.bytes)} wire bytes "
          f"(eff {float(cost.efficiency):.2f})")
    print(f"  unaggregated: {int(naive.bytes)} wire bytes "
          f"(eff {float(naive.efficiency):.2f})  "
          f"-> {int(naive.bytes) / int(cost.bytes):.1f}x saved")

    # the cycle-level model (the 'simulation model' the paper calls for)
    cfg = bucket.BucketConfig(n_buckets=4, capacity=124, n_dest=4,
                              flush_margin=8)
    T = 200
    tr_words = ev.pack(jnp.zeros((T, 1), jnp.int32),
                       (jnp.arange(T)[:, None] + 100) & ev.TS_MASK)
    tr_dest = jnp.zeros((T, 1), jnp.int32)
    st, out = bucket.run_trace(cfg, tr_words, tr_dest)
    sent = np.asarray(out.sent_count)
    print(f"  cycle model: {int(sent.sum())} events drained in {T} clocks, "
          f"packets of mean {sent[sent > 0].mean():.1f} events")


def routing_demo():
    print("=== paper §3: LUT routing + GUID multicast ===")
    tabs = rt.build_tables(16, [
        rt.Projection(0, 8, dest_node=3, dest_links=[0, 5]),
        rt.Projection(8, 16, dest_node=7, dest_links=[2]),
    ])
    words = ev.pack(jnp.arange(16), jnp.zeros(16, jnp.int32))
    dest, guid, ok = tabs.route(words)
    masks = tabs.multicast(guid)
    print(f"  sources 0-7  -> node {int(dest[0])}, multicast links "
          f"{[i for i in range(8) if int(masks[0]) >> i & 1]}")
    print(f"  sources 8-15 -> node {int(dest[8])}, multicast links "
          f"{[i for i in range(8) if int(masks[8]) >> i & 1]}")


def tiny_lm_demo():
    print("=== the LM stack on the same substrate ===")
    from repro.configs import get_config, reduced
    from repro.data.pipeline import DataConfig, synthetic_batch
    from repro.models import build
    from repro.models.transformer import Runtime
    from repro.train.optimizer import OptimizerConfig, ScheduleConfig
    from repro.train.step import TrainConfig, init_train_state, make_train_step

    cfg = reduced(get_config("qwen3_32b"))
    model = build(cfg)
    tcfg = TrainConfig(optimizer=OptimizerConfig(
        schedule=ScheduleConfig(kind="cosine", peak_lr=2e-3,
                                warmup_steps=3, total_steps=30)))
    state = init_train_state(model, jax.random.PRNGKey(0), tcfg)
    step = jax.jit(make_train_step(model, tcfg, Runtime()))
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8)
    for i in range(10):
        state, metrics = step(state, synthetic_batch(dcfg, i))
        if i % 3 == 0:
            print(f"  step {i}: loss {float(metrics['loss']):.3f} "
                  f"lr {float(metrics['lr']):.2e}")


if __name__ == "__main__":
    spike_aggregation_demo()
    routing_demo()
    tiny_lm_demo()
    print("done.")
