"""Serve a small LM with batched requests through the continuous-slot
engine (prefill + decode with KV caches, greedy sampling, EOS handling).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import build
from repro.serve.engine import Engine, Request, ServeConfig


def main():
    cfg = reduced(get_config("gemma2_9b"), layers=4)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"serving reduced {cfg.name}: {cfg.n_layers}L d{cfg.d_model} "
          f"(alternating local/global attention, softcaps active)")

    eng = Engine(model, ServeConfig(slots=4, max_len=128,
                                    max_new_tokens=24, eos_id=2))
    rng = np.random.default_rng(0)
    requests = [
        Request(rid=i,
                prompt=rng.integers(3, cfg.vocab, size=rng.integers(4, 12))
                .astype(np.int32))
        for i in range(10)
    ]
    t0 = time.perf_counter()
    out = eng.generate_batch(params, requests)
    dt = time.perf_counter() - t0
    total = sum(len(v) for v in out.values())
    for rid in sorted(out):
        print(f"  req {rid}: prompt {len(requests[rid].prompt):2d} tok "
              f"-> {len(out[rid]):2d} new: {list(out[rid][:8])}...")
    print(f"{len(requests)} requests, {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s on CPU)")


if __name__ == "__main__":
    main()
