"""End-to-end training driver: ~100M-parameter MiniCPM-family model for a
few hundred steps on CPU with the full production stack — ring-buffer data
pipeline, WSD schedule, gradient clipping, atomic checkpointing, restart.

Run:  PYTHONPATH=src python examples/train_100m.py [--steps 200]
(~100M params is heavy for 1 CPU core; --steps 30 gives a quick pass. The
default runs a few hundred steps as the assignment's end-to-end driver.)
"""
import argparse
import dataclasses
import time

import numpy as np

from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.models import build
from repro.train.optimizer import OptimizerConfig, ScheduleConfig
from repro.train.step import TrainConfig
from repro.train.trainer import Trainer, TrainerConfig


def config_100m():
    """MiniCPM-style ~100M: 12L x 512d x 8H, vocab 32k, muP scalings."""
    base = get_config("minicpm_2b")
    return dataclasses.replace(
        base, n_layers=12, d_model=512, n_heads=8, n_kv_heads=8, head_dim=64,
        d_ff=1536, vocab=32000, logit_scale=1.0 / (512 / 256),
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    cfg = config_100m()
    model = build(cfg)
    from repro.models.modules import param_count
    n = param_count(model.specs())
    print(f"model: {cfg.name}-100m  {n / 1e6:.1f}M params "
          f"({cfg.n_layers}L x {cfg.d_model}d)")

    tcfg = TrainConfig(
        optimizer=OptimizerConfig(
            schedule=ScheduleConfig(kind="wsd", peak_lr=6e-4,
                                    warmup_steps=20,
                                    total_steps=args.steps,
                                    decay_frac=0.2)),
        microbatch=0,
    )
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch)
    trainer = Trainer(model, tcfg, dcfg, TrainerConfig(
        steps=args.steps, ckpt_dir=args.ckpt_dir,
        ckpt_every=max(args.steps // 4, 10), log_every=10))

    t0 = time.perf_counter()
    state, history = trainer.run(seed=0)
    dt = time.perf_counter() - t0
    tok = args.steps * args.batch * args.seq
    print(f"\n{args.steps} steps / {tok} tokens in {dt:.1f}s "
          f"({tok / dt:.0f} tok/s CPU)")
    print("loss curve:",
          " -> ".join(f"{h['loss']:.2f}" for h in history[:: max(len(history) // 6, 1)]))
    first, last = history[0]["loss"], history[-1]["loss"]
    assert last < first, "loss should decrease"
    print(f"checkpoints in {args.ckpt_dir} "
          f"(resume by re-running the same command)")


if __name__ == "__main__":
    main()
