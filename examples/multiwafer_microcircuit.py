"""End-to-end driver: the paper's target workload — a (reduced-scale)
Potjans-Diesmann cortical microcircuit spread over 4 'wafer' shards, spikes
exchanged through the bucket-aggregated transport fabric.

Prints per-window communication stats (events, wire bytes, aggregation
efficiency, deadline misses) — the numbers the Extoll link budget cares
about — plus per-population firing rates.

NOTE: must run as its own process (forces 4 host devices).
Run:  PYTHONPATH=src python examples/multiwafer_microcircuit.py \
          [alltoall|torus2d|torus3d] [extoll|ethernet]
(first arg selects the transport backend; default "alltoall".  "torus2d"
walks dimension-ordered neighbor hops on a 2x2 device torus, "torus3d" on
a 1x2x2 torus whose Z rings are the wafer-stacking axis; both report the
link-level hop/forwarding stats with hop-by-hop credit flow control
available via the config's link_credits.  Second arg selects the wire
protocol profile (repro.wire): frame-exact bytes_on_wire and the
per-event latency percentiles are reported for it — run once with
"extoll" and once with "ethernet" to see the paper's protocol-tax and
switch-latency comparison.)
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import dataclasses
import sys

import jax
import numpy as np

from repro.configs import brainscales
from repro.core import aggregator
from repro.launch.mesh import (make_wafer_mesh, wafer_torus_shape,
                               wafer_wire_format)
from repro.snn import microcircuit as mc, network, simulator as sim


def main(transport: str = "alltoall", wire_format: str = "extoll"):
    spec = mc.MicrocircuitSpec(scale=0.004)
    w, is_inh = spec.weight_matrix()
    print(f"microcircuit: {spec.n_neurons} neurons, "
          f"{(w != 0).sum()} synapses (scale={spec.scale})")

    part = network.build_partition(w, is_inh, n_shards=4)
    print(f"partition: 4 wafer shards x {part.per_shard} neurons, "
          f"max fan-out {part.fanout.shape[1]} shards/source")

    bs = dataclasses.replace(brainscales.CONFIG, transport=transport,
                             wire_format=wire_format)
    cfg = sim.SimConfig(
        n_shards=4, per_shard=part.per_shard,
        max_fan=part.fanout.shape[1],
        window=8,                  # <= min axonal delay (deadline flush)
        ring_len=32, e_max=512, capacity=512,
        **bs.transport_fields(),
    )
    if transport == "torus2d":
        print(f"transport: {transport} {wafer_torus_shape(4)} torus")
    elif transport == "torus3d":
        print(f"transport: {transport} {wafer_torus_shape(4, ndim=3)} torus")
    else:
        print(f"transport: {transport}")
    mesh = make_wafer_mesh(4)
    init, run = sim.build_sharded_sim(mesh, "wafer", cfg, part,
                                      spec.bg_rates())
    state = init(seed=0)

    n_windows = 25                 # 25 x 8 x 0.1ms = 20 ms biological
    state, stats = run(state, n_windows)
    spikes = np.asarray(stats.spikes).sum(0)        # (windows,) per shard sum
    sent = np.asarray(stats.events_sent).sum()
    wire = np.asarray(stats.wire_bytes).sum()
    miss = np.asarray(stats.deadline_miss).sum()
    ovf = np.asarray(stats.overflow).sum()

    bio_ms = n_windows * cfg.window * cfg.params.dt
    total_spikes = int(np.asarray(stats.spikes).sum())
    print(f"\nsimulated {bio_ms:.1f} ms: {total_spikes} spikes, "
          f"mean rate {total_spikes / (spec.n_neurons * bio_ms * 1e-3):.1f} Hz")
    print(f"events shipped (incl. fan-out replicas): {int(sent)}")
    print(f"Extoll wire bytes: {int(wire)} "
          f"({int(wire) / max(int(sent), 1):.1f} B/event effective)")
    naive = aggregator.unaggregated_cost(int(sent))
    print(f"without aggregation: {int(naive.bytes)} bytes "
          f"-> bucket aggregation saves "
          f"{int(naive.bytes) / max(int(wire), 1):.1f}x")
    print(f"deadline misses: {int(miss)}   bucket overflows: {int(ovf)}")
    # frame-exact wire accounting + the per-event latency distribution of
    # the configured protocol profile (repro.wire); per-profile wire
    # EFFICIENCY needs the hop-weighted (src, dst) count matrix and lives
    # in BENCH_wire.json (benchmarks/bench_wire.py), not here
    fmt = wafer_wire_format(wire_format)
    on_wire = int(np.asarray(stats.link.bytes_on_wire).sum())
    lat = stats.latency
    n_win = np.asarray(lat.p50_us).shape[1]
    p50 = float(np.asarray(lat.p50_us)[:, 1:].mean()) if n_win > 1 else 0.0
    p99 = float(np.asarray(lat.p99_us).max())
    lmax = float(np.asarray(lat.max_us).max())
    print(f"wire profile '{fmt.name}': {on_wire} bytes on wire "
          f"(frame-exact; {fmt.header_bytes + fmt.crc_bytes} B/frame tax, "
          f"{fmt.gap_bytes} B gap, {fmt.cell_bytes} B cells)")
    print(f"event latency: p50 {p50:.2f} us (mean over windows), "
          f"p99 {p99:.2f} us, max {lmax:.2f} us")
    if transport in ("torus2d", "torus3d"):
        link = stats.link
        print(f"torus link stats: {int(np.asarray(link.hops)[0, 0])} "
              f"hops/window, "
              f"{int(np.asarray(link.forwarded_bytes).sum())} forwarded "
              f"bytes, max in-flight "
              f"{int(np.asarray(link.max_in_flight).max())} events, "
              f"{int(np.asarray(link.credit_stalls).sum())} credit stalls")
    assert miss == 0, "windowed exchange must respect timestamp deadlines"
    print("ok.")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "alltoall",
         sys.argv[2] if len(sys.argv) > 2 else "extoll")
