"""Core library tests: events, routing, bucket cycle model, aggregator,
flow control, torus — including the paper's §3.1 throughput claims."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregator as agg
from repro.core import bucket as bk
from repro.core import events as ev
from repro.core import flow_control as fc
from repro.core import routing as rt
from repro.core import torus

from prop import draw, given


# ---------------------------------------------------------------------------
# events
# ---------------------------------------------------------------------------

@given(addr=draw.array((64,), 0, 1 << 14), ts=draw.array((64,), 0, 1 << 15))
def test_event_pack_roundtrip(addr, ts):
    w = ev.pack(jnp.asarray(addr), jnp.asarray(ts))
    a, t, v = ev.unpack(w)
    assert (np.asarray(a) == addr).all()
    assert (np.asarray(t) == ts).all()
    assert np.asarray(v).all()


def test_event_invalid_flag():
    w = ev.pack(jnp.arange(4), jnp.arange(4), valid=jnp.array([1, 0, 1, 0], bool))
    assert (np.asarray(ev.is_valid(w)) == [True, False, True, False]).all()


def test_ts_wraparound_ordering():
    # deadline just past the wrap point is "before" one far in the future
    a = jnp.asarray(10)          # wrapped
    b = jnp.asarray(ev.TS_MASK - 5)
    assert bool(ev.ts_before(b, a))
    assert not bool(ev.ts_before(a, b))
    assert int(ev.ts_slack(a, b)) == 16


def test_packet_cost_paper_constants():
    """The paper's numbers: 496 B payload = 124 events; header overhead
    limits single events to one per two 210 MHz clocks."""
    assert ev.PACKET_MAX_EVENTS == 124
    assert int(ev.wire_cycles(1)) == 2          # 1 event / 2 clocks
    assert int(ev.wire_cycles(124)) == 32       # 3.875 events/clock drained
    assert abs(float(ev.wire_efficiency(124)) - 496 / 512) < 1e-6
    assert int(ev.packet_bytes(0)) == 0


# ---------------------------------------------------------------------------
# routing
# ---------------------------------------------------------------------------

def test_routing_tables_and_multicast():
    projs = [rt.Projection(0, 4, dest_node=7, dest_links=[0, 3]),
             rt.Projection(4, 8, dest_node=9, dest_links=[1])]
    tabs = rt.build_tables(16, projs)
    w = ev.pack(jnp.arange(10), jnp.zeros(10, jnp.int32))
    dest, guid, routed = tabs.route(w)
    assert (np.asarray(dest[:4]) == 7).all()
    assert (np.asarray(dest[4:8]) == 9).all()
    assert (np.asarray(dest[8:]) == rt.NO_ROUTE).all()
    assert not np.asarray(routed[8:]).any()
    masks = tabs.multicast(guid[:8])
    assert (np.asarray(masks[:4]) == 0b1001).all()
    assert (np.asarray(masks[4:8]) == 0b0010).all()


def test_multicast_expansion():
    w = ev.pack(jnp.arange(3), jnp.zeros(3, jnp.int32))
    masks = jnp.asarray([0b101, 0b010, 0b000], jnp.uint32)
    links = rt.expand_multicast(w, masks, n_links=3)
    valid = np.asarray(ev.is_valid(links))
    assert valid[0, 0] and not valid[1, 0] and valid[2, 0]
    assert not valid[0, 1] and valid[1, 1] and not valid[2, 1]
    assert not valid[:, 2].any()


# ---------------------------------------------------------------------------
# bucket cycle model (the paper's simulation model)
# ---------------------------------------------------------------------------

def _trace(cfg, T, E, n_dest, seed=0, rate=1.0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    addr = jax.random.randint(k1, (T, E), 0, 1 << 12)
    ts = (jnp.arange(T)[:, None] + 100 + jax.random.randint(
        k3, (T, E), 0, 50)) & ev.TS_MASK
    valid = jax.random.bernoulli(k2, rate, (T, E))
    words = ev.pack(addr, ts, valid)
    dests = jax.random.randint(jax.random.fold_in(k1, 9), (T, E), 0, n_dest)
    return words, dests


@pytest.mark.parametrize("n_buckets,n_dest", [(4, 4), (4, 16), (8, 64)])
def test_bucket_conservation(n_buckets, n_dest):
    """No event is lost: in == sent + queued + in-bucket + stalled."""
    cfg = bk.BucketConfig(n_buckets=n_buckets, capacity=16, n_dest=n_dest,
                          flush_margin=8)
    words, dests = _trace(cfg, 80, 2, n_dest)
    st, out = bk.run_trace(cfg, words, dests)
    n_in = int(np.asarray(ev.is_valid(words)).sum())
    sent = int(out.sent_count.sum())
    q = int(st.q_count.sum())
    fill = int(st.fill.sum())
    stalled = int(out.stalled.sum())
    assert sent + q + fill + stalled == n_in


def test_bucket_renaming_pressure():
    """More destinations than buckets must still work (paper: 2^16 dests,
    few buckets, map table + free list + urgent eviction)."""
    cfg = bk.BucketConfig(n_buckets=2, capacity=8, n_dest=32, flush_margin=4)
    words, dests = _trace(cfg, 60, 1, 32)
    st, out = bk.run_trace(cfg, words, dests)
    # every sent packet has a valid destination and consistent count
    sent_mask = np.asarray(out.sent_dest) >= 0
    counts = np.asarray(out.sent_count)[sent_mask]
    assert (counts > 0).all() and (counts <= 8).all()
    # the map table only binds existing buckets
    mt = np.asarray(st.map_table)
    assert ((mt == -1) | ((mt >= 0) & (mt < 2))).all()


def test_bucket_sent_events_match_destination():
    cfg = bk.BucketConfig(n_buckets=4, capacity=8, n_dest=8, flush_margin=8)
    # dest = addr % 8 so we can verify routing of flushed payloads
    T, E = 50, 2
    k = jax.random.PRNGKey(3)
    addr = jax.random.randint(k, (T, E), 0, 64)
    ts = (jnp.arange(T)[:, None] + 60) & ev.TS_MASK
    words = ev.pack(addr, jnp.broadcast_to(ts, (T, E)))
    dests = addr % 8
    st, out = bk.run_trace(cfg, words, dests)
    sd = np.asarray(out.sent_dest)
    se = np.asarray(out.sent_events)
    sc = np.asarray(out.sent_count)
    for t in range(T):
        if sd[t] < 0:
            continue
        payload = se[t][: sc[t]]
        a = (payload >> ev.TS_BITS) & ev.ADDR_MASK
        assert ((a % 8) == sd[t]).all()


def test_paper_claim_single_event_rate():
    """Un-aggregated traffic to all-different destinations drains at
    ~0.5 events/cycle (one event per two clocks, paper §3.1)."""
    cfg = bk.BucketConfig(n_buckets=8, capacity=124, n_dest=256,
                          flush_margin=10_000)   # deadline fires instantly
    T = 400
    addr = jnp.arange(T).reshape(T, 1) % 256
    ts = jnp.full((T, 1), 1, jnp.int32)          # already-urgent deadlines
    words = ev.pack(addr, ts)
    dests = addr                                  # every event its own dest
    st, out = bk.run_trace(cfg, words, dests)
    sent = int(out.sent_count.sum())
    rate = sent / T
    assert rate <= 0.55, f"single-event rate {rate} should be <= ~0.5"
    assert rate >= 0.3


def test_paper_claim_aggregated_rate():
    """Same-destination traffic aggregates into big packets and keeps up
    with one event/cycle input (the paper's fix)."""
    cfg = bk.BucketConfig(n_buckets=4, capacity=124, n_dest=4,
                          flush_margin=4, queue=8)
    T = 600
    addr = jnp.zeros((T, 1), jnp.int32)
    ts = (jnp.arange(T).reshape(T, 1) + 200) & ev.TS_MASK   # relaxed deadlines
    words = ev.pack(addr, ts)
    dests = jnp.zeros((T, 1), jnp.int32)
    st, out = bk.run_trace(cfg, words, dests)
    stalled = int(out.stalled.sum())
    sent = int(out.sent_count.sum()) + int(st.q_count.sum()) + int(st.fill.sum())
    assert stalled == 0, "aggregated stream should absorb 1 event/cycle"
    assert sent == T
    # and the packets are large (amortized headers)
    counts = np.asarray(out.sent_count)
    big = counts[counts > 0]
    assert big.mean() > 30


# ---------------------------------------------------------------------------
# aggregator
# ---------------------------------------------------------------------------

@given(n=draw.ints(1, 300), d=draw.ints(1, 70), c=draw.ints(1, 130),
       seed=draw.ints(0, 10_000))
def test_aggregate_impls_agree(n, d, c, seed):
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed), 4)
    words = ev.pack(jax.random.randint(k1, (n,), 0, 1 << 14),
                    jax.random.randint(k2, (n,), 0, 1 << 15),
                    valid=jax.random.bernoulli(k4, 0.85, (n,)))
    dest = jax.random.randint(k3, (n,), -2, d)
    guid = jax.random.randint(k4, (n,), 0, 100)
    b1 = agg.aggregate(words, dest, guid, d, c, impl="onehot")
    for impl in ("sort", "fused"):
        b2 = agg.aggregate(words, dest, guid, d, c, impl=impl)
        assert (b1.counts == b2.counts).all(), impl
        assert (b1.data == b2.data).all(), impl
        assert (b1.guids == b2.guids).all(), impl
        assert int(b1.overflow) == int(b2.overflow), impl
    # conservation: accepted + overflow == valid routed events
    valid = np.asarray(ev.is_valid(words) & (dest >= 0) & (dest < d))
    assert int(b1.counts.sum()) + int(b1.overflow) == valid.sum()


def test_aggregate_window_order():
    words = ev.pack(jnp.arange(6), jnp.arange(6))
    dest = jnp.asarray([1, 1, 0, 1, 0, 1])
    b = agg.aggregate(words, dest, None, 2, 3, impl="onehot")
    # destination 1 gets events 0,1,3 in order; 5 overflows
    a = (np.asarray(b.data[1]) >> ev.TS_BITS) & ev.ADDR_MASK
    assert list(a[:3]) == [0, 1, 3]
    assert int(b.overflow) == 1


def test_overflow_mask_matches_aggregate():
    words = ev.pack(jnp.arange(10), jnp.zeros(10, jnp.int32))
    dest = jnp.zeros(10, jnp.int32)
    m = agg.overflow_mask(words, dest, 4, 6)
    assert np.asarray(m).sum() == 4
    b = agg.aggregate(words, dest, None, 4, 6)
    assert int(b.overflow) == 4


def test_window_cost_model():
    c = agg.window_cost(jnp.asarray([124, 1, 0, 248]))
    assert int(c.packets) == 1 + 1 + 0 + 2
    un = agg.unaggregated_cost(125)
    assert int(un.cycles) == 125 * 2
    assert float(c.efficiency) > float(un.efficiency)


# ---------------------------------------------------------------------------
# flow control
# ---------------------------------------------------------------------------

@given(size=draw.ints(2, 64), lat=draw.ints(1, 16))
def test_ring_never_overruns(size, lat):
    cfg = fc.RingConfig(size=size, notify_latency=lat)
    st, stats = fc.run(cfg, 300, produce_rate=1.0, consume_rate=1)
    assert int(stats.produced) <= 300
    # rd never passes wr; credits never negative (invariants)
    assert int(st.rd) <= int(st.wr)
    assert int(st.credits) >= 0
    assert int(stats.produced) == int(stats.consumed) + (int(st.wr) - int(st.rd))


def test_ring_throughput_credit_limit():
    """Sustained throughput = min(1, size / notify_latency) (credit loop)."""
    full = fc.run(fc.RingConfig(size=32, notify_latency=8), 1000)[1]
    starved = fc.run(fc.RingConfig(size=4, notify_latency=8), 1000)[1]
    assert int(full.produced) >= 990
    ratio = int(starved.produced) / 1000
    assert 0.35 <= ratio <= 0.65, ratio     # ~ 4/8 with batching effects


def test_credit_bank_zero_initial_credits():
    """A bank that starts empty can never be spent from — the caller must
    defer everything (spent=0) and the bank stays empty forever: nothing
    is lost, nothing is created."""
    bank = fc.init_credits(4, 0, 2)
    for _ in range(5):
        bank = fc.credit_tick(bank, jnp.zeros((4,), jnp.int32))
        assert (np.asarray(bank.credits) == 0).all()
        assert (np.asarray(bank.pending) == 0).all()


def test_credit_bank_zero_notify_latency():
    """notify_latency=0 -> the refund is immediate: credit_tick with any
    legal spend leaves the bank unchanged (credits cap one window's
    traffic but nothing carries across windows)."""
    bank = fc.init_credits(3, 10, 0)
    assert bank.pending.shape == (3, 0)
    out = fc.credit_tick(bank, jnp.asarray([10, 3, 0], jnp.int32))
    assert (np.asarray(out.credits) == 10).all()
    # contrast: latency 1 delays the refund exactly one tick
    b1 = fc.init_credits(3, 10, 1)
    spent = jnp.asarray([10, 3, 0], jnp.int32)
    b1 = fc.credit_tick(b1, spent)
    assert list(np.asarray(b1.credits)) == [0, 7, 10]
    b1 = fc.credit_tick(b1, jnp.zeros((3,), jnp.int32))
    assert (np.asarray(b1.credits) == 10).all()


@given(lat=draw.ints(1, 6), seed=draw.ints(0, 1 << 16))
def test_credit_bank_conservation_invariant(lat, seed):
    """credits + pending.sum() is invariant under credit_tick for any
    legal spend sequence (spent <= credits), and credits never go
    negative — the identity the hop-by-hop transport banks rely on."""
    rng = np.random.default_rng(seed)
    limit = int(rng.integers(1, 50))
    bank = fc.init_credits(5, limit, lat)
    for _ in range(4 * lat):
        avail = np.asarray(bank.credits)
        spent = rng.integers(0, avail + 1).astype(np.int32)
        bank = fc.credit_tick(bank, jnp.asarray(spent))
        total = np.asarray(bank.credits) + np.asarray(bank.pending).sum(-1)
        assert (total == limit).all()
        assert (np.asarray(bank.credits) >= 0).all()


# ---------------------------------------------------------------------------
# torus
# ---------------------------------------------------------------------------

def test_torus_route_and_hops():
    t = torus.Torus(4, 4, 4)
    for (s, d) in [(0, 63), (5, 5), (1, 62), (17, 3)]:
        path = t.route(s, d)
        assert path[0] == s and path[-1] == d
        assert len(path) - 1 == int(t.hops(s, d))
        # consecutive nodes differ by one ring step
        for u, v in zip(path[:-1], path[1:]):
            assert int(t.hops(u, v)) == 1


def test_torus_hops_symmetric_and_wrap():
    t = torus.Torus(4, 2, 2)
    s = np.arange(t.n_nodes)
    for d0 in range(t.n_nodes):
        assert (t.hops(s, d0) == t.hops(d0, s)).all()
    # wrap: node 0 -> 3 on the x ring is 1 hop
    assert int(t.hops(0, 3)) == 1


def test_wafer_topology_paper_constants():
    assert torus.FPGAS_PER_WAFER == 48
    assert torus.CONCENTRATORS_PER_WAFER == 8
    assert torus.FPGAS_PER_CONCENTRATOR == 6
    assert abs(torus.LINK_GBYTES - 12.6) < 1e-9
    t = torus.wafer_topology(4)
    assert t.n_nodes == 32


def test_link_loads_conserve_traffic():
    t = torus.Torus(2, 2, 2)
    m = np.zeros((8, 8))
    m[0, 7] = 100.0
    loads = t.link_loads(m)
    assert sum(loads.values()) == 100.0 * t.hops(0, 7)


def test_link_loads_vectorized_matches_scalar_oracle():
    """The batched numpy link_loads must reproduce the per-pair routed
    oracle exactly — same links, same bytes — across ring shapes that
    exercise wraps, ties (even rings) and degenerate axes, in 2-D AND
    3-D: the Z-axis walk is the path the torus3d transport's credit
    accounting relies on, so Z-dominant shapes (long wafer stacks, odd
    and even Z rings for both tie-break branches) are covered
    explicitly."""
    rng = np.random.default_rng(7)
    z_exercised = 0
    for shape in [(2, 2, 2), (2, 4, 3), (1, 5, 1), (2, 4, 1), (3, 3, 3),
                  (4, 4, 2),
                  # Z-dominant: the wafer-stacking axis is the longest ring
                  (2, 2, 5), (1, 2, 6), (2, 4, 4), (1, 1, 7)]:
        t = torus.Torus(*shape)
        n = t.n_nodes
        m = rng.random((n, n)) * (rng.random((n, n)) < 0.4)
        np.fill_diagonal(m, 0)
        got = t.link_loads(m)
        want = t.link_loads_scalar(m)
        assert set(got) == set(want), shape
        for k in want:
            assert abs(got[k] - want[k]) < 1e-9, (shape, k)
        # every link is a single ring hop
        for (u, v) in got:
            assert int(t.hops(u, v)) == 1, (shape, u, v)
        # the Z axis really carried traffic (directions 4/5), both ways
        if shape[2] > 1:
            zdirs = {t.link_dir(u, v) for (u, v) in got
                     if t.link_dir(u, v) >= 4}
            z_exercised += len(zdirs)
    assert z_exercised >= 8, "Z-axis links barely exercised"


def test_route_links_matches_route():
    """route_links enumerates exactly the (node, direction) egress links
    of the dimension-ordered route — the credit-spending unit of the
    hop-by-hop torus transports."""
    t = torus.Torus(2, 4, 3)
    rng = np.random.default_rng(3)
    for _ in range(50):
        s, d = (int(v) for v in rng.integers(0, t.n_nodes, 2))
        links = t.route_links(s, d)
        path = t.route(s, d)
        assert len(links) == len(path) - 1 == int(t.hops(s, d))
        for (u, dir_), exp_u, exp_v in zip(links, path[:-1], path[1:]):
            assert u == exp_u
            # stepping u one hop along dir_ lands on the next path node
            x, y, z = (int(c) for c in t.coords(u))
            step = [(1, 0, 0), (-1, 0, 0), (0, 1, 0), (0, -1, 0),
                    (0, 0, 1), (0, 0, -1)][dir_]
            nxt = t.node_id((x + step[0]) % t.nx, (y + step[1]) % t.ny,
                            (z + step[2]) % t.nz)
            assert int(nxt) == exp_v


def test_link_loads_multiwafer_scale():
    """The vectorized path must handle a multi-wafer torus (the scale the
    scalar loop cannot): conservation of traffic-bytes x hops."""
    t = torus.wafer_topology(16)            # 2 x 4 x 16 = 128 nodes
    n = t.n_nodes
    m = torus.microcircuit_traffic(n, 1e6)
    loads = t.link_loads(m)
    ids = np.arange(n)
    s, d = np.meshgrid(ids, ids, indexing="ij")
    want = float((m * t.hops(s, d)).sum())
    assert abs(sum(loads.values()) - want) < 1e-6 * max(want, 1.0)
