"""Checkpointer (atomicity, retention, exact restore) and serving engine."""
import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_config, reduced
from repro.models import build
from repro.serve.engine import Engine, Request, ServeConfig


def _state():
    return {
        "params": {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
                   "b": {"c": np.asarray(2.5, np.float32)}},
        "opt": (np.ones((3,), np.int32), np.zeros((2,), np.float32)),
        "step": np.asarray(7, np.int32),
    }


def test_checkpoint_roundtrip_and_retention():
    d = tempfile.mkdtemp()
    try:
        ck = Checkpointer(d, keep=2)
        st = _state()
        for step in (10, 20, 30):
            st["step"] = np.asarray(step, np.int32)
            ck.save(step, st)
        # retention: only last 2 kept
        dirs = sorted(x for x in os.listdir(d) if x.startswith("step_"))
        assert len(dirs) == 2
        assert ck.latest_step() == 30
        got = ck.restore(_state())
        assert int(got["step"]) == 30
        np.testing.assert_array_equal(got["params"]["a"], st["params"]["a"])
        assert isinstance(got["opt"], tuple)
        np.testing.assert_array_equal(got["opt"][0], st["opt"][0])
    finally:
        shutil.rmtree(d, ignore_errors=True)


def test_checkpoint_atomic_no_partial_dirs():
    """A .tmp directory must never be picked up as a checkpoint."""
    d = tempfile.mkdtemp()
    try:
        ck = Checkpointer(d)
        ck.save(5, _state())
        os.makedirs(os.path.join(d, "step_0000000009.tmp"))
        assert ck.latest_step() == 5
    finally:
        shutil.rmtree(d, ignore_errors=True)


def test_checkpoint_restores_namedtuples():
    from repro.train.optimizer import OptimizerConfig, adamw_init
    from repro.train.step import TrainConfig, init_train_state
    cfg = reduced(get_config("minicpm_2b"))
    m = build(cfg)
    tcfg = TrainConfig()
    state = init_train_state(m, jax.random.PRNGKey(0), tcfg)
    d = tempfile.mkdtemp()
    try:
        ck = Checkpointer(d)
        ck.save(1, jax.device_get(state))
        got = ck.restore(jax.tree_util.tree_map(np.asarray,
                                                jax.device_get(state)))
        for a, b in zip(jax.tree_util.tree_leaves(state),
                        jax.tree_util.tree_leaves(got)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    finally:
        shutil.rmtree(d, ignore_errors=True)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def test_engine_greedy_deterministic():
    cfg = reduced(get_config("qwen15_4b"))
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    eng = Engine(m, ServeConfig(slots=2, max_len=64, max_new_tokens=8))
    reqs = [Request(rid=i, prompt=np.asarray([5, 6, 7 + i], np.int32))
            for i in range(3)]
    out1 = eng.generate_batch(params, reqs)
    out2 = eng.generate_batch(params, reqs)
    assert set(out1) == {0, 1, 2}
    for r in range(3):
        np.testing.assert_array_equal(out1[r], out2[r])
        assert 1 <= len(out1[r]) <= 8


def test_engine_eos_stops():
    cfg = reduced(get_config("qwen15_4b"))
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    eng = Engine(m, ServeConfig(slots=1, max_len=64, max_new_tokens=8,
                                eos_id=2))
    out = eng.generate_batch(params, [Request(0, np.asarray([1, 2, 3]))])
    seq = out[0]
    eos_pos = np.where(seq == 2)[0]
    if len(eos_pos):
        assert eos_pos[0] == len(seq) - 1        # truncated right after EOS
