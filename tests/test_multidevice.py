"""Multi-device integration tests (subprocess with forced host devices):
spike exchange, sharded microcircuit simulation, bucket-MoE vs local-MoE,
int8 error-feedback all-reduce, and a small-mesh dry-run of one cell.
"""
import pytest

from md_helper import run_md

pytestmark = pytest.mark.slow


def test_exchange_conservation_and_routing():
    out = run_md("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import events as ev, routing as rt
from repro.core.exchange import make_exchange
n_shards, N, C, n_addr = 8, 32, 16, 64
mesh = jax.make_mesh((n_shards,), ("wafer",))
tabs = []
for s in range(n_shards):
    projs = [rt.Projection(a, a+1, dest_node=a % n_shards, dest_links=[a % 3, 7])
             for a in range(n_addr)]
    tabs.append(rt.build_tables(n_addr, projs, n_guid=64))
stacked = rt.RoutingTables(
    dest_of_addr=jnp.stack([t.dest_of_addr for t in tabs]),
    guid_of_addr=jnp.stack([t.guid_of_addr for t in tabs]),
    mcast_of_guid=jnp.stack([t.mcast_of_guid for t in tabs]))
key = jax.random.PRNGKey(0)
addr = jax.random.randint(key, (n_shards, N), 0, n_addr)
ts = jax.random.randint(jax.random.PRNGKey(1), (n_shards, N), 0, 1000)
words = ev.pack(addr, ts)
run = make_exchange(mesh, "wafer", n_shards=n_shards, capacity=C,
                    n_addr_per_shard=n_addr)
out = run(words, stacked)
tot_sent = int(out.sent_counts.sum()); tot_recv = int(out.recv_counts.sum())
assert tot_sent == tot_recv
assert tot_sent + int(out.overflow.sum()) == n_shards * N
re = np.asarray(out.recv_events).reshape(n_shards, n_shards, C)
for s in range(n_shards):
    e = re[s][(re[s] & (1 << 29)) != 0]
    a = (e >> 15) & 0x3FFF
    assert ((a % n_shards) == s).all()
print("EXCHANGE_OK")
""")
    assert "EXCHANGE_OK" in out


def test_sharded_microcircuit_simulation():
    out = run_md("""
import jax, numpy as np
from repro.snn import microcircuit as mc, network, simulator as sim
spec = mc.MicrocircuitSpec(scale=0.003)
w, is_inh = spec.weight_matrix()
part = network.build_partition(w, is_inh, n_shards=4)
cfg = sim.SimConfig(n_shards=4, per_shard=part.per_shard,
                    max_fan=part.fanout.shape[1], window=8, ring_len=32,
                    e_max=256, capacity=512)
mesh = jax.make_mesh((4,), ("wafer",))
init, run = sim.build_sharded_sim(mesh, "wafer", cfg, part, spec.bg_rates())
st = init(0)
st, stats = run(st, 8)
spikes = int(np.asarray(stats.spikes).sum())
assert spikes > 0, "network is silent"
assert int(np.asarray(stats.overflow).sum()) == 0
assert int(np.asarray(stats.deadline_miss).sum()) == 0
print("SIM_OK", spikes)
""", n_devices=4)
    assert "SIM_OK" in out


def test_exchange_single_collective_hlo():
    """The packed exchange must lower to EXACTLY one all-to-all per flush
    window (the tentpole: data+guids+counts travel in a single buffer)."""
    out = run_md("""
import jax, jax.numpy as jnp
from repro.core import events as ev, routing as rt
from repro.core.exchange import make_exchange
n_shards, N, C, n_addr = 8, 32, 16, 64
mesh = jax.make_mesh((n_shards,), ("wafer",))
tabs = []
for s in range(n_shards):
    projs = [rt.Projection(a, a+1, dest_node=a % n_shards, dest_links=[a % 3])
             for a in range(n_addr)]
    tabs.append(rt.build_tables(n_addr, projs, n_guid=64))
stacked = rt.RoutingTables(
    dest_of_addr=jnp.stack([t.dest_of_addr for t in tabs]),
    guid_of_addr=jnp.stack([t.guid_of_addr for t in tabs]),
    mcast_of_guid=jnp.stack([t.mcast_of_guid for t in tabs]))
words = ev.pack(jnp.zeros((n_shards, N), jnp.int32),
                jnp.zeros((n_shards, N), jnp.int32))
run = make_exchange(mesh, "wafer", n_shards=n_shards, capacity=C,
                    n_addr_per_shard=n_addr)
txt = jax.jit(run).lower(words, stacked).as_text()
n_a2a = txt.count("all_to_all") + txt.count("all-to-all")
print("A2A_COUNT", n_a2a)
assert n_a2a == 1, txt.count("all_to_all")
print("SINGLE_COLLECTIVE_OK")
""")
    assert "SINGLE_COLLECTIVE_OK" in out


def test_moe_bucket_equals_local():
    """shard_map EP dispatch must reproduce the single-device result."""
    out = run_md("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.configs.base import MoEConfig
from repro.models import moe as M
mesh = jax.make_mesh((2, 4), ("data", "model"))
moe = MoEConfig(n_experts=8, top_k=2, expert_ff=16, capacity_factor=8.0)
d, T = 12, 32
key = jax.random.PRNGKey(0)
params = {
  "router": 0.3 * jax.random.normal(key, (d, 8)),
  "w_gate": jax.random.normal(jax.random.fold_in(key,1), (8, d, 16)) / np.sqrt(d),
  "w_up": jax.random.normal(jax.random.fold_in(key,2), (8, d, 16)) / np.sqrt(d),
  "w_down": jax.random.normal(jax.random.fold_in(key,3), (8, 16, d)) / 4.0,
}
x = jax.random.normal(jax.random.fold_in(key, 4), (T, d))
y_ref, stats_ref = M.moe_layer_local(x, params, moe, capacity=64)

def body(xl, router, wg, wu, wd):
    y, stats = M.moe_layer_bucket(
        xl.reshape(-1, d), {"router": router, "w_gate": wg, "w_up": wu,
                            "w_down": wd}, moe, axis="model", capacity=64)
    return y.reshape(xl.shape)

fn = shard_map(body, mesh=mesh,
               in_specs=(P("data", None), P(), P("model", None, None),
                         P("model", None, None), P("model", None, None)),
               out_specs=P("data", None), check_rep=False)
y2 = fn(x.reshape(2, T // 2, d).reshape(T, d),
        params["router"], params["w_gate"], params["w_up"], params["w_down"])
np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y2), rtol=2e-4, atol=2e-4)
print("MOE_OK")
""")
    assert "MOE_OK" in out


def test_compressed_allreduce():
    out = run_md("""
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.compression import make_compressed_allreduce, init_error_feedback
mesh = jax.make_mesh((4,), ("pod",))
ar = make_compressed_allreduce(mesh, ("pod",))
g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64, 32)), jnp.float32)}
e = init_error_feedback(g)
# replicated input -> mean over identical copies should ~= input
got, e2 = jax.jit(ar)(g, e)
err = np.abs(np.asarray(got["w"]) - np.asarray(g["w"])).max()
scale = np.abs(np.asarray(g["w"])).max()
assert err <= scale / 127.0 * 1.5 + 1e-6, err
# error feedback captures the residual
assert np.abs(np.asarray(e2["w"])).max() <= scale / 127.0 + 1e-6
print("COMPRESS_OK", float(err))
""", n_devices=4)
    assert "COMPRESS_OK" in out


def test_small_mesh_dryrun_cell():
    """Tiny-mesh version of the production dry-run machinery end-to-end."""
    out = run_md("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config, SHAPES, reduced
from repro.configs.base import ShapeConfig
from repro.distributed import sharding as shd
from repro.launch import dryrun as dr
mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = get_config("qwen3_32b")
import dataclasses
cfg = dataclasses.replace(cfg, n_layers=2)          # keep compile small
shape = ShapeConfig("train_small", 512, 8, "train")
fn, args, shardings, model = dr.build_train_cell(cfg, shape, mesh)
with mesh:
    compiled = jax.jit(fn, in_shardings=shardings).lower(*args).compile()
cost = compiled.cost_analysis()
if isinstance(cost, list):       # older jax returns one dict per computation
    cost = cost[0]
assert compiled.memory_analysis() is not None
print("DRYRUN_OK", int(cost.get("flops", 0)) > 0)
""", n_devices=8, timeout=900)
    assert "DRYRUN_OK" in out


def test_split_kv_decode_attention():
    out = run_md("""
import jax, jax.numpy as jnp, numpy as np
from functools import partial
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.distributed.collectives import split_kv_decode_attention
mesh = jax.make_mesh((4,), ("model",))
B, T, Hq, Hkv, D = 2, 64, 8, 2, 16
k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
q = jax.random.normal(k1, (B, 1, Hq, D))
k = jax.random.normal(k2, (B, T, Hkv, D))
v = jax.random.normal(k3, (B, T, Hkv, D))
clen = jnp.asarray(50)
fn = shard_map(
    partial(split_kv_decode_attention, axis_name="model"),
    mesh=mesh,
    in_specs=(P(), P(None, "model", None, None), P(None, "model", None, None), P()),
    out_specs=P(), check_rep=False)
o1 = fn(q, k, v, clen)
# reference: full attention over valid prefix
kk = jnp.repeat(k, Hq // Hkv, 2); vv = jnp.repeat(v, Hq // Hkv, 2)
s = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / np.sqrt(D)
s = jnp.where((jnp.arange(T) < 50)[None, None, None], s, -1e30)
p = jax.nn.softmax(s, -1)
o2 = jnp.einsum("bhqk,bkhd->bqhd", p, vv)
np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=2e-4, atol=2e-4)
print("SPLITKV_OK")
""", n_devices=4)
    assert "SPLITKV_OK" in out
