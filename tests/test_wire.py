"""Wire subsystem tests: 64-bit codec round-trip (both backends), frame
accounting vs a scalar Python oracle, latency-summary math vs numpy, the
extoll-vs-ethernet efficiency ordering, the active-route admission memory
bound, and the simulator's end-to-end latency digest.

Everything here is in-process and fast — this file is the CI `wire` job's
<1 min signal for codec/framing changes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import wire
from repro.core import events as ev
from repro.wire import codec, framing

from prop import draw, given


def _random_events(n, seed, p_valid=0.9):
    k = jax.random.PRNGKey(seed)
    return ev.pack(jax.random.randint(k, (n,), 0, 1 << 14),
                   jax.random.randint(jax.random.fold_in(k, 1), (n,),
                                      0, 1 << 15),
                   valid=jax.random.bernoulli(jax.random.fold_in(k, 2),
                                              p_valid, (n,)))


# ---------------------------------------------------------------------------
# codec
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 7, 100, 1000, 4096])
def test_codec_roundtrip_bit_exact_both_backends(n):
    """Acceptance bar: encode->decode is bit-exact on the XLA path AND the
    Pallas path (interpret mode on CPU), for any i32 meta bit pattern."""
    words = _random_events(n, n)
    meta = jax.random.randint(jax.random.PRNGKey(n + 1), (n,),
                              -2**31, 2**31 - 1, dtype=jnp.int32)
    outs = []
    for use_pallas in (False, True):
        lo, hi = wire.encode_words(words, meta, use_pallas=use_pallas,
                                   interpret=True)
        w2, m2 = wire.decode_words(lo, hi, use_pallas=use_pallas,
                                   interpret=True)
        assert (np.asarray(w2) == np.asarray(words)).all(), use_pallas
        assert (np.asarray(m2) == np.asarray(meta)).all(), use_pallas
        outs.append((np.asarray(lo), np.asarray(hi)))
    # the two backends produce identical wire words, not just round trips
    assert (outs[0][0] == outs[1][0]).all()
    assert (outs[0][1] == outs[1][1]).all()


def test_codec_fields_straddle_lane_boundary():
    """The default layout puts the meta field at bit 29 — it must straddle
    the lo/hi lane split (a pure-lo codec would be the old bitcast concat,
    not a 64-bit word)."""
    fmt = codec.DEFAULT_WORD
    assert fmt.ts_bits + fmt.label_bits < 32 < fmt.valid_bit
    word = ev.pack(jnp.asarray([0]), jnp.asarray([0]))
    lo0, hi0 = wire.encode_words(word, jnp.asarray([0], jnp.int32),
                                 use_pallas=False)
    lo1, hi1 = wire.encode_words(word, jnp.asarray([-1], jnp.int32),
                                 use_pallas=False)
    # flipping meta flips bits in BOTH lanes
    assert int(lo0[0]) != int(lo1[0]) and int(hi0[0]) != int(hi1[0])


@given(n_cases=12, n=draw.ints(1, 300), ts_bits=draw.ints(15, 20),
       label_bits=draw.ints(14, 18), meta_bits=draw.ints(0, 28),
       seed=draw.ints(0, 999))
def test_codec_custom_widths_roundtrip(n, ts_bits, label_bits, meta_bits,
                                       seed):
    """Any width config whose fields cover the source values round-trips
    (meta masked to meta_bits, so draw in range)."""
    if ts_bits + label_bits + meta_bits + 1 > 64:
        return
    fmt = codec.WireWordFormat(ts_bits, label_bits, meta_bits).validate()
    words = _random_events(n, seed)
    hi_meta = max((1 << meta_bits) - 1, 0)
    meta = jax.random.randint(jax.random.PRNGKey(seed), (n,), 0,
                              max(hi_meta, 1), dtype=jnp.int32)
    lo, hi = wire.encode_words(words, meta, fmt, use_pallas=False)
    w2, m2 = wire.decode_words(lo, hi, fmt, use_pallas=False)
    assert (np.asarray(w2) == np.asarray(words)).all()
    assert (np.asarray(m2) == np.asarray(meta)).all()


def test_codec_word_format_validation():
    with pytest.raises(ValueError):
        codec.WireWordFormat(32, 32, 32).validate()   # > 64 bits
    with pytest.raises(ValueError):
        codec.WireWordFormat(0, 14, 32).validate()


def test_codec_planar_layout():
    """encode_planar keeps the (…, 2C) opaque-u32 transport contract and
    invalid (all-zero) events stay all-zero on the wire."""
    words = _random_events(64, 3).reshape(4, 16)
    meta = jnp.arange(64, dtype=jnp.int32).reshape(4, 16)
    buf = wire.encode_planar(words, meta, use_pallas=False)
    assert buf.shape == (4, 32) and buf.dtype == jnp.uint32
    w2, m2 = wire.decode_planar(buf, use_pallas=False)
    assert (np.asarray(w2) == np.asarray(words)).all()
    assert (np.asarray(m2) == np.asarray(meta)).all()
    z = wire.encode_planar(jnp.zeros((2, 4), jnp.uint32),
                           jnp.zeros((2, 4), jnp.int32), use_pallas=False)
    assert (np.asarray(z) == 0).all()


# ---------------------------------------------------------------------------
# framing vs scalar oracle (satellite: property test)
# ---------------------------------------------------------------------------

def _oracle(fmt: framing.WireFormat, n_events: int):
    """Independent scalar model of the frame accounting: split events
    into MTU-bound frames, pad each to cells, clamp, add overheads."""
    frames, total, cell_padded, header_total = 0, 0, 0, 0
    left = n_events
    while left > 0:
        in_frame = min(left, fmt.mtu_payload // fmt.word_bytes)
        left -= in_frame
        payload = in_frame * fmt.word_bytes
        cells = -(-payload // fmt.cell_bytes) * fmt.cell_bytes
        on_wire = max(cells + fmt.header_bytes + fmt.crc_bytes,
                      fmt.min_frame_bytes) + fmt.gap_bytes
        frames += 1
        total += on_wire
        cell_padded += cells
        header_total += fmt.header_bytes + fmt.crc_bytes
    return frames, total, cell_padded, header_total


@given(n_cases=30, n=draw.ints(0, 5000), seed=draw.ints(0, 9999))
def test_frame_accounting_matches_scalar_oracle(n, seed):
    """For both WireFormat profiles and random event counts the jnp frame
    accounting equals the scalar oracle, and the satellite identities
    hold: frames * cell_size >= payload (the padded cells cover the
    payload) and header bytes == frames * header size."""
    del seed
    for fmt in (wire.EXTOLL, wire.ETHERNET):
        frames_o, total_o, cells_o, header_o = _oracle(fmt, n)
        frames = int(framing.frame_count(fmt, n))
        total = int(framing.frame_bytes(fmt, n))
        assert frames == frames_o, fmt.name
        assert total == total_o, fmt.name
        payload = n * fmt.word_bytes
        assert cells_o >= payload, fmt.name
        assert cells_o <= payload + frames * (fmt.cell_bytes - 1), fmt.name
        assert header_o == frames * (fmt.header_bytes + fmt.crc_bytes)
        assert int(framing.frame_overhead_bytes(fmt, n)) == total - payload
        eff = float(framing.wire_efficiency(fmt, n))
        assert (eff == 0.0) if n == 0 else (0.0 < eff <= 1.0), fmt.name


def test_extoll_dominates_ethernet_where_it_matters():
    """The paper's protocol-tax claim, stated exactly: over bucket-row
    sizes 1..4096 the extoll profile's wire efficiency is strictly higher
    than ethernet's everywhere except a small set (< 3%) of cell-padding
    dips — rows whose trailing 64 B cell is mostly padding — all of them
    small rows; every aggregated row past that and every full cell train
    dominates (see repro.wire.profiles)."""
    ns = np.arange(1, 4097)
    ee = np.asarray(framing.wire_efficiency(wire.EXTOLL, jnp.asarray(ns)))
    ge = np.asarray(framing.wire_efficiency(wire.ETHERNET, jnp.asarray(ns)))
    lose = ns[ee <= ge]
    assert len(lose) / len(ns) < 0.03, "cell-padding dips grew"
    assert lose.max() < 600, "a LARGE row lost to ethernet"
    pad = (-(lose * wire.EXTOLL.word_bytes)) % wire.EXTOLL.cell_bytes
    assert (pad >= 24).all(), "a well-filled row lost to ethernet"
    assert ee[0] > ge[0]                                  # the lone event
    full = np.arange(64, 4097, 64) - 1                    # full cell trains
    assert (ee[full] > ge[full]).all()
    # and the latency profile dominates EVERYWHERE: slower serialization
    # AND slower switches
    for n in (1, 9, 64, 65, 1000):
        for hops in (1, 3):
            le = float(wire.hop_latency_us(wire.EXTOLL, n, hops))
            lg = float(wire.hop_latency_us(wire.ETHERNET, n, hops))
            assert le < lg, (n, hops)


def test_wire_format_validation():
    with pytest.raises(ValueError):
        framing.WireFormat("bad", mtu_payload=100, cell_bytes=8,
                           header_bytes=0, crc_bytes=0, min_frame_bytes=0,
                           gap_bytes=0, bytes_per_us=1.0,
                           switch_latency_us=0.0).validate()   # mtu % word
    with pytest.raises(ValueError):
        wire.get_profile("token-ring")
    assert wire.get_profile("extoll") is wire.EXTOLL
    assert wire.get_profile(wire.ETHERNET) is wire.ETHERNET


# ---------------------------------------------------------------------------
# latency summary vs numpy oracle
# ---------------------------------------------------------------------------

@given(n_cases=20, r=draw.ints(1, 64), seed=draw.ints(0, 9999))
def test_latency_summary_matches_numpy_oracle(r, seed):
    rng = np.random.default_rng(seed)
    lat = rng.uniform(0.01, 5000.0, r).astype(np.float32)
    w = rng.integers(0, 40, r).astype(np.int32)
    s = wire.summarize_latency(jnp.asarray(lat), jnp.asarray(w))
    total = int(w.sum())
    assert int(s.hist.sum()) == total
    if total == 0:
        assert float(s.p50_us) == 0.0 and float(s.max_us) == 0.0
        return
    events = np.repeat(lat, w)                   # exact per-event expansion
    events.sort()
    p50_o = events[int(np.ceil(0.5 * total)) - 1]
    p99_o = events[int(np.ceil(0.99 * total)) - 1]
    assert float(s.p50_us) == pytest.approx(float(p50_o))
    assert float(s.p99_us) == pytest.approx(float(p99_o))
    assert float(s.max_us) == pytest.approx(float(events.max()))
    assert float(s.mean_us) == pytest.approx(float(events.mean()), rel=1e-5)
    # histogram bins partition the events
    edges = np.asarray(wire.LATENCY_BIN_EDGES_US)
    hist_o = np.zeros(len(edges) + 1, np.int64)
    for v, ww in zip(lat, w):
        hist_o[np.searchsorted(edges, v, side="right")] += ww
    assert (np.asarray(s.hist) == hist_o).all()


# ---------------------------------------------------------------------------
# admission tables: active-route footprint memory bound (satellite)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,n,opts", [
    ("torus2d", 64, dict(nx=8, ny=8)),
    ("torus3d", 64, dict(nx=4, ny=4, nz=4)),
])
def test_admission_tables_active_route_footprint(name, n, opts):
    """The admission scan's static tables must stay within the
    active-route footprint — (n², max_hops) i32 link sequences — instead
    of the dense (n², n·2·ndim) incidence tensor (cubic in n) an earlier
    revision materialized."""
    from repro import transport
    t = transport.create(name, n_shards=n, link_credits=1024,
                         notify_latency=2, max_row_events=64, **opts)
    assert not hasattr(t, "_incidence"), "dense incidence tensor is back"
    seq_bytes = t._link_seq.size * t._link_seq.dtype.itemsize
    bound = n * n * t.max_hops * 4
    assert seq_bytes <= bound, (seq_bytes, bound)
    dense_bytes = n * n * (n * 2 * t.ndim)          # i8 incidence
    assert seq_bytes * 4 <= dense_bytes, \
        "footprint no longer meaningfully below the dense tensor"
    # the tables still describe real routes: hop counts agree with the
    # host model served through route_hops()
    hops = np.asarray(t.route_hops())
    seq = np.asarray(t._link_seq).reshape(n, n, t.max_hops)
    assert ((seq >= 0).sum(-1) == hops).all()


# ---------------------------------------------------------------------------
# simulator: latency digest end to end (1 shard, in-process)
# ---------------------------------------------------------------------------

def _run_sim(wire_format, n_windows=10):
    from repro.snn import microcircuit as mc, network, simulator as sim
    spec = mc.MicrocircuitSpec(scale=0.003)
    w, is_inh = spec.weight_matrix()
    part = network.build_partition(w, is_inh, n_shards=1)
    cfg = sim.SimConfig(n_shards=1, per_shard=part.per_shard,
                        max_fan=part.fanout.shape[1], window=8, ring_len=32,
                        e_max=256, capacity=512, wire_format=wire_format)
    mesh = jax.make_mesh((1,), ("wafer",))
    init, run = sim.build_sharded_sim(mesh, "wafer", cfg, part,
                                      spec.bg_rates())
    _, stats = run(init(0), n_windows)
    return jax.tree_util.tree_map(lambda x: np.asarray(x)[0], stats), cfg


def test_simulator_latency_digest():
    """WindowStats.latency: row k digests the events delivered by the
    exchange at the start of iteration k (window k-1's buckets — the same
    one-row shift as `link`), so hist totals equal the delivered counts
    and waiting is window-quantized: every event waits at least one step
    and at most window + ring_len steps' worth of microseconds."""
    stats, cfg = _run_sim("extoll")
    assert stats.spikes.sum() > 0
    delivered = stats.link.delivered_events
    hist_total = stats.latency.hist.sum(-1)
    assert (hist_total == delivered).all()
    assert hist_total[0] == 0 and hist_total[1:].sum() > 0
    live = delivered > 0
    p50 = stats.latency.p50_us
    assert (p50[live] >= cfg.step_us).all()          # waited >= 1 dt step
    assert (stats.latency.max_us[live]
            <= (cfg.window + cfg.ring_len) * cfg.step_us + 1.0).all()
    assert (stats.latency.p99_us[live] >= p50[live]).all()
    assert (stats.latency.max_us[live] >= stats.latency.p99_us[live]).all()


@pytest.mark.slow
def test_exchange_bytes_on_wire_exact_and_profile_latency():
    """Multi-device pin of the acceptance bar: (1) ``bytes_on_wire`` is
    EXACT per profile — it equals the host-side oracle
    sum over admitted off-shard rows of hops(s,d) * frame_bytes(count) —
    for alltoall and torus3d under both profiles; (2) delivery is
    profile-independent (the codec/framing never touches payload);
    (3) the ethernet profile's exchange latency digest strictly dominates
    extoll's."""
    from md_helper import run_md
    out = run_md("""
import jax, jax.numpy as jnp, numpy as np
from repro import wire
from repro.core import events as ev, routing as rt
from repro.core.exchange import make_exchange
from repro.core.torus import Torus
n_shards, N, C, n_addr = 8, 256, 64, 256
mesh = jax.make_mesh((n_shards,), ("wafer",))
tabs = []
for s in range(n_shards):
    projs = [rt.Projection(a, a+1, dest_node=(a * 5 + s) % n_shards,
                           dest_links=[a % 3]) for a in range(n_addr)]
    tabs.append(rt.build_tables(n_addr, projs, n_guid=64))
stacked = rt.RoutingTables(
    dest_of_addr=jnp.stack([t.dest_of_addr for t in tabs]),
    guid_of_addr=jnp.stack([t.guid_of_addr for t in tabs]),
    mcast_of_guid=jnp.stack([t.mcast_of_guid for t in tabs]))
words = ev.pack(
    jax.random.randint(jax.random.PRNGKey(0), (n_shards, N), 0, n_addr),
    jax.random.randint(jax.random.PRNGKey(1), (n_shards, N), 0, 1000))
ids = np.arange(n_shards)
hops_of = {
    "alltoall": (ids[:, None] != ids[None, :]).astype(np.int64),
    "torus3d": Torus(2, 2, 2).hops(ids[:, None], ids[None, :]),
}
p50 = {}
ref_recv = None
for backend in ("alltoall", "torus3d"):
    for profile in ("extoll", "ethernet"):
        opts = {"nx": 2, "ny": 2, "nz": 2} if backend == "torus3d" else None
        run = make_exchange(mesh, "wafer", n_shards=n_shards, capacity=C,
                            n_addr_per_shard=n_addr, transport=backend,
                            transport_opts=opts, wire_format=profile)
        out = run(words, stacked)
        # (2) delivery identical across backends AND profiles
        if ref_recv is None:
            ref_recv = np.asarray(out.recv_events)
        assert (np.asarray(out.recv_events) == ref_recv).all()
        # (1) exact frame-level byte oracle
        fmt = wire.get_profile(profile)
        cnt = np.asarray(out.sent_counts).astype(np.int64)
        fb = np.asarray(wire.frame_bytes(fmt, jnp.asarray(cnt)))
        oracle = int((fb * hops_of[backend]).sum())
        got = int(np.asarray(out.link.bytes_on_wire).sum())
        assert got == oracle, (backend, profile, got, oracle)
        p50[backend, profile] = float(np.asarray(out.latency.p50_us).max())
# (3) ethernet latency dominates per backend
for backend in ("alltoall", "torus3d"):
    assert p50[backend, "ethernet"] > p50[backend, "extoll"] * 5
print("WIRE_EXCHANGE_OK")
""")
    assert "WIRE_EXCHANGE_OK" in out


def test_simulator_latency_ethernet_slower():
    """Same network, same seed: the ethernet profile's switch+serialization
    charges must dominate extoll's on every delivering window (1 shard =
    0 hops... so charge equality; re-run over the transportless stub is
    hop-free — instead pin that profile plumbing reaches the digest via
    equal waiting: identical hist totals and identical p50, since a
    single shard never crosses a link under either profile)."""
    se, _ = _run_sim("extoll")
    sg, _ = _run_sim("ethernet")
    assert (se.latency.hist == sg.latency.hist).all()
    assert (se.latency.p50_us == sg.latency.p50_us).all()
