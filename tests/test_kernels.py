"""Pallas kernel validation: shape/dtype sweeps against the ref.py oracles
(interpret mode on CPU; the kernels target TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregator as agg
from repro.core import events as ev
from repro.kernels import ops, ref
from repro.snn.lif import LIFParams, init_state

from prop import draw, given


@pytest.mark.parametrize("n,d,c", [
    (16, 3, 4), (64, 7, 5), (256, 16, 32), (1024, 64, 16),
    (128, 3, 124), (512, 8, 128), (100, 13, 7),
])
def test_bucket_scatter_matches_refs(n, d, c):
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(n * d + c), 4)
    words = ev.pack(jax.random.randint(k1, (n,), 0, 1 << 14),
                    jax.random.randint(k2, (n,), 0, 1 << 15),
                    valid=jax.random.bernoulli(k4, 0.9, (n,)))
    dests = jax.random.randint(k3, (n,), -1, d)
    guids = jax.random.randint(k4, (n,), 0, 50)
    got = ops.bucket_scatter(words, dests, guids, d, c)
    want = agg.aggregate(words, dests, guids, d, c, impl="sort")
    assert (got.data == want.data).all()
    assert (got.guids == want.guids).all()
    assert (got.counts == want.counts).all()
    assert int(got.overflow) == int(want.overflow)
    # independent oracle
    valid = ev.is_valid(words) & (dests >= 0) & (dests < d)
    dm = jnp.where(valid, dests, -1)
    rd, rg, rc = ref.bucket_scatter_ref(words, dm, guids, d, c)
    assert (got.data == rd).all()


@given(n_cases=10, n=draw.ints(1, 400), d=draw.ints(1, 40),
       c=draw.ints(1, 64), seed=draw.ints(0, 9999))
def test_bucket_scatter_prop(n, d, c, seed):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    words = ev.pack(jax.random.randint(k1, (n,), 0, 1 << 14),
                    jax.random.randint(k2, (n,), 0, 1 << 15))
    dests = jax.random.randint(k3, (n,), -2, d)
    guids = jnp.zeros((n,), jnp.int32)
    got = ops.bucket_scatter(words, dests, guids, d, c)
    want = agg.aggregate(words, dests, guids, d, c, impl="sort")
    assert (got.data == want.data).all()
    assert int(got.overflow) == int(want.overflow)


@pytest.mark.parametrize("n", [64, 100, 1024, 2048, 3000])
def test_lif_kernel_matches_oracle(n):
    p = LIFParams()
    st1 = init_state(n, p, jax.random.PRNGKey(1))
    st2 = st1
    total = 0
    for t in range(20):
        k = jax.random.PRNGKey(t)
        exc = jax.random.uniform(k, (n,)) * 2000
        inh = -jax.random.uniform(jax.random.fold_in(k, 1), (n,)) * 300
        st1, s1 = ops.lif_step(st1, p, exc, inh, 100.0)
        st2, s2 = ref.lif_step_ref(st2, p, exc, inh, 100.0)
        assert (np.asarray(s1) == np.asarray(s2)).all(), t
        np.testing.assert_allclose(np.asarray(st1.v), np.asarray(st2.v),
                                   rtol=2e-5, atol=1e-4)
        np.testing.assert_allclose(np.asarray(st1.i_exc),
                                   np.asarray(st2.i_exc), rtol=1e-6)
        assert (np.asarray(st1.refrac) == np.asarray(st2.refrac)).all()
        total += int(s1.sum())
    assert total > 0, "no spikes exercised the threshold path"


@pytest.mark.parametrize("dt,tau_m", [(0.1, 10.0), (0.05, 20.0), (0.2, 5.0)])
def test_lif_kernel_param_sweep(dt, tau_m):
    p = LIFParams(dt=dt, tau_m=tau_m)
    n = 1024
    st = init_state(n, p, jax.random.PRNGKey(0))
    exc = jnp.full((n,), 800.0)
    st1, s1 = ops.lif_step(st, p, exc, jnp.zeros(n), 0.0)
    st2, s2 = ref.lif_step_ref(st, p, exc, jnp.zeros(n), 0.0)
    np.testing.assert_allclose(np.asarray(st1.v), np.asarray(st2.v),
                               rtol=2e-5, atol=1e-4)
    assert (np.asarray(s1) == np.asarray(s2)).all()


def test_aggregate_pallas_impl_dispatch():
    """core.aggregator.aggregate(impl='pallas') routes through the kernel."""
    words = ev.pack(jnp.arange(32), jnp.zeros(32, jnp.int32))
    dests = jnp.arange(32) % 4
    b1 = agg.aggregate(words, dests, None, 4, 16, impl="pallas")
    b2 = agg.aggregate(words, dests, None, 4, 16, impl="onehot")
    assert (b1.data == b2.data).all()
    assert (b1.counts == b2.counts).all()
