"""Pallas kernel validation: shape/dtype sweeps against the ref.py oracles
(interpret mode on CPU; the kernels target TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregator as agg
from repro.core import events as ev
from repro.kernels import ops, ref
from repro.snn.lif import LIFParams, init_state

from prop import draw, given


# The aggregate_sort (jnp.argsort) oracle is ~10x slower than the
# multi-operand lax.sort hot path on CPU (see ROADMAP); tests that use it
# as the cross-check are marked slow — CI's fast tier runs -m "not slow",
# the slow tier and any plain local `python -m pytest` still run them.

@pytest.mark.slow
@pytest.mark.parametrize("n,d,c", [
    (16, 3, 4), (64, 7, 5), (256, 16, 32), (1024, 64, 16),
    (128, 3, 124), (512, 8, 128), (100, 13, 7),
])
def test_bucket_scatter_matches_refs(n, d, c):
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(n * d + c), 4)
    words = ev.pack(jax.random.randint(k1, (n,), 0, 1 << 14),
                    jax.random.randint(k2, (n,), 0, 1 << 15),
                    valid=jax.random.bernoulli(k4, 0.9, (n,)))
    dests = jax.random.randint(k3, (n,), -1, d)
    guids = jax.random.randint(k4, (n,), 0, 50)
    got = ops.bucket_scatter(words, dests, guids, d, c)
    want = agg.aggregate(words, dests, guids, d, c, impl="sort")
    assert (got.data == want.data).all()
    assert (got.guids == want.guids).all()
    assert (got.counts == want.counts).all()
    assert int(got.overflow) == int(want.overflow)
    # independent oracle
    valid = ev.is_valid(words) & (dests >= 0) & (dests < d)
    dm = jnp.where(valid, dests, -1)
    rd, rg, rc = ref.bucket_scatter_ref(words, dm, guids, d, c)
    assert (got.data == rd).all()


@pytest.mark.slow
@given(n_cases=10, n=draw.ints(1, 400), d=draw.ints(1, 40),
       c=draw.ints(1, 64), seed=draw.ints(0, 9999))
def test_bucket_scatter_prop(n, d, c, seed):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    words = ev.pack(jax.random.randint(k1, (n,), 0, 1 << 14),
                    jax.random.randint(k2, (n,), 0, 1 << 15))
    dests = jax.random.randint(k3, (n,), -2, d)
    guids = jnp.zeros((n,), jnp.int32)
    got = ops.bucket_scatter(words, dests, guids, d, c)
    want = agg.aggregate(words, dests, guids, d, c, impl="sort")
    assert (got.data == want.data).all()
    assert int(got.overflow) == int(want.overflow)


@pytest.mark.parametrize("n", [64, 100, 1024, 2048, 3000])
def test_lif_kernel_matches_oracle(n):
    p = LIFParams()
    st1 = init_state(n, p, jax.random.PRNGKey(1))
    st2 = st1
    total = 0
    for t in range(20):
        k = jax.random.PRNGKey(t)
        exc = jax.random.uniform(k, (n,)) * 2000
        inh = -jax.random.uniform(jax.random.fold_in(k, 1), (n,)) * 300
        st1, s1 = ops.lif_step(st1, p, exc, inh, 100.0)
        st2, s2 = ref.lif_step_ref(st2, p, exc, inh, 100.0)
        assert (np.asarray(s1) == np.asarray(s2)).all(), t
        np.testing.assert_allclose(np.asarray(st1.v), np.asarray(st2.v),
                                   rtol=2e-5, atol=1e-4)
        np.testing.assert_allclose(np.asarray(st1.i_exc),
                                   np.asarray(st2.i_exc), rtol=1e-6)
        assert (np.asarray(st1.refrac) == np.asarray(st2.refrac)).all()
        total += int(s1.sum())
    assert total > 0, "no spikes exercised the threshold path"


@pytest.mark.parametrize("dt,tau_m", [(0.1, 10.0), (0.05, 20.0), (0.2, 5.0)])
def test_lif_kernel_param_sweep(dt, tau_m):
    p = LIFParams(dt=dt, tau_m=tau_m)
    n = 1024
    st = init_state(n, p, jax.random.PRNGKey(0))
    exc = jnp.full((n,), 800.0)
    st1, s1 = ops.lif_step(st, p, exc, jnp.zeros(n), 0.0)
    st2, s2 = ref.lif_step_ref(st, p, exc, jnp.zeros(n), 0.0)
    np.testing.assert_allclose(np.asarray(st1.v), np.asarray(st2.v),
                               rtol=2e-5, atol=1e-4)
    assert (np.asarray(s1) == np.asarray(s2)).all()


def test_aggregate_pallas_impl_dispatch():
    """core.aggregator.aggregate(impl='pallas') routes through the kernel."""
    words = ev.pack(jnp.arange(32), jnp.zeros(32, jnp.int32))
    dests = jnp.arange(32) % 4
    b1 = agg.aggregate(words, dests, None, 4, 16, impl="pallas")
    b2 = agg.aggregate(words, dests, None, 4, 16, impl="onehot")
    assert (b1.data == b2.data).all()
    assert (b1.counts == b2.counts).all()


# ---------------------------------------------------------------------------
# fused route+aggregate kernel
# ---------------------------------------------------------------------------

@given(n_cases=12, n=draw.ints(1, 400), d=draw.ints(1, 40),
       c=draw.ints(1, 24), seed=draw.ints(0, 9999))
def test_fused_impls_agree_with_overflow(n, d, c, seed):
    """onehot vs sort vs fused-XLA vs fused-Pallas(interpret) across N/D/C
    sweeps; small capacities force the overflow path."""
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed), 4)
    words = ev.pack(jax.random.randint(k1, (n,), 0, 1 << 14),
                    jax.random.randint(k2, (n,), 0, 1 << 15),
                    valid=jax.random.bernoulli(k4, 0.85, (n,)))
    dests = jax.random.randint(k3, (n,), -2, d)
    guids = jax.random.randint(k4, (n,), 0, 100)
    want = agg.aggregate(words, dests, guids, d, c, impl="onehot")
    for impl in ("sort", "fused", "pallas"):
        got = agg.aggregate(words, dests, guids, d, c, impl=impl)
        assert (got.data == want.data).all(), impl
        assert (got.guids == want.guids).all(), impl
        assert (got.counts == want.counts).all(), impl
        assert int(got.overflow) == int(want.overflow), impl


@given(n_cases=8, n=draw.ints(1, 300), d=draw.ints(1, 16),
       c=draw.ints(1, 16), r=draw.ints(1, 64), seed=draw.ints(0, 9999))
def test_fused_residue_accounting(n, d, c, r, seed):
    """deferred + dropped == overflow; residue holds exactly the deferred
    events (valid, routable) and nothing else."""
    from repro.kernels import fused_route_bucket as frb
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    words = ev.pack(jax.random.randint(k1, (n,), 0, 1 << 14),
                    jax.random.randint(k2, (n,), 0, 1 << 15))
    dests = jax.random.randint(k3, (n,), 0, d)
    guids = jnp.zeros((n,), jnp.int32)
    fw = frb.fused_aggregate(words, dests, guids, d, c, residue_len=r,
                             use_pallas=False)
    assert int(fw.offered) == int(fw.buckets.counts.sum()) + int(fw.buckets.overflow)
    assert int(fw.deferred) + int(fw.dropped) == int(fw.buckets.overflow)
    assert fw.residue.shape == (r,)
    assert int(ev.is_valid(fw.residue).sum()) == int(fw.deferred)


def test_fused_route_aggregate_matches_ref():
    """LUT-routed fused kernel vs the O(N*D*C) oracle, both backends."""
    from repro.core import routing as rt
    from repro.kernels import fused_route_bucket as frb
    n_addr, d, c = 64, 8, 8
    projs = [rt.Projection(a, a + 1, dest_node=a % d, dest_links=[a % 3])
             for a in range(0, n_addr, 2)]       # half the addrs unrouted
    tabs = rt.build_tables(n_addr, projs, n_guid=64)
    for seed in range(4):
        k = jax.random.PRNGKey(seed)
        words = ev.pack(jax.random.randint(k, (200,), 0, 128),
                        jax.random.randint(jax.random.fold_in(k, 1),
                                           (200,), 0, 1000),
                        valid=jax.random.bernoulli(
                            jax.random.fold_in(k, 2), 0.9, (200,)))
        rd, rg, rc = ref.fused_route_aggregate_ref(
            words, tabs.dest_of_addr, tabs.guid_of_addr, d, c)
        for use_pallas in (False, True):
            fw = frb.fused_route_aggregate(
                words, tabs.dest_of_addr, tabs.guid_of_addr, d, c,
                use_pallas=use_pallas, interpret=True)
            assert (fw.buckets.data == rd).all(), use_pallas
            assert (fw.buckets.guids == rg).all(), use_pallas
            assert (fw.buckets.counts == jnp.minimum(rc, c)).all()


@pytest.mark.slow
@pytest.mark.parametrize("n,d,c", [
    (1000, 7, 33),          # the ROADMAP-named ragged case
    (257, 13, 19),
    (129, 5, 31),
    (1000, 9, 124),
    (63, 7, 1),
])
def test_fused_pallas_interpret_parity_ragged_shapes(n, d, c):
    """Interpret-mode Pallas placement vs fused-XLA vs the sort oracle on
    odd / non-power-of-two (N, D, C): destination tiling pads D to the
    tile and the per-row `pl.ds` loads start at arbitrary offsets, so
    ragged shapes are exactly where lane-alignment bugs would surface."""
    from repro.kernels import fused_route_bucket as frb
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(n + d * c), 4)
    words = ev.pack(jax.random.randint(k1, (n,), 0, 1 << 14),
                    jax.random.randint(k2, (n,), 0, 1 << 15),
                    valid=jax.random.bernoulli(k4, 0.9, (n,)))
    dests = jax.random.randint(k3, (n,), -1, d)
    guids = jax.random.randint(k4, (n,), 0, 64)
    want = agg.aggregate(words, dests, guids, d, c, impl="sort")
    got_xla = frb.fused_aggregate(words, dests, guids, d, c,
                                  use_pallas=False)
    got_pl = frb.fused_aggregate(words, dests, guids, d, c,
                                 use_pallas=True, interpret=True)
    for got in (got_xla, got_pl):
        assert (got.buckets.data == want.data).all()
        assert (got.buckets.guids == want.guids).all()
        assert (got.buckets.counts == want.counts).all()
        assert int(got.buckets.overflow) == int(want.overflow)


@pytest.mark.parametrize("n,d,c", [(1000, 7, 33), (200, 11, 13)])
def test_fused_route_pallas_interpret_parity_ragged_shapes(n, d, c):
    """Same ragged-shape pin for the LUT-routed variant, whose guid gather
    runs *inside* the Pallas kernel over the accepted rows only."""
    from repro.core import routing as rt
    from repro.kernels import fused_route_bucket as frb
    n_addr = 96
    projs = [rt.Projection(a, a + 1, dest_node=a % d, dest_links=[a % 3])
             for a in range(0, n_addr, 2)]       # half the addrs unrouted
    tabs = rt.build_tables(n_addr, projs, n_guid=64)
    k = jax.random.PRNGKey(n * c)
    words = ev.pack(jax.random.randint(k, (n,), 0, n_addr + 16),
                    jax.random.randint(jax.random.fold_in(k, 1), (n,),
                                       0, 1 << 15),
                    valid=jax.random.bernoulli(
                        jax.random.fold_in(k, 2), 0.9, (n,)))
    fw_xla = frb.fused_route_aggregate(
        words, tabs.dest_of_addr, tabs.guid_of_addr, d, c, use_pallas=False)
    fw_pl = frb.fused_route_aggregate(
        words, tabs.dest_of_addr, tabs.guid_of_addr, d, c, use_pallas=True,
        interpret=True)
    assert (fw_pl.buckets.data == fw_xla.buckets.data).all()
    assert (fw_pl.buckets.guids == fw_xla.buckets.guids).all()
    assert (fw_pl.buckets.counts == fw_xla.buckets.counts).all()
    assert int(fw_pl.buckets.overflow) == int(fw_xla.buckets.overflow)


def test_multiwindow_residue_carry_conservation():
    """Drive the fused kernel across windows re-offering the residue each
    time: every valid event is eventually accepted, dropped, or left in the
    final residue — none vanish, none duplicate."""
    from repro.kernels import fused_route_bucket as frb
    d, c, r, n_new = 4, 6, 32, 48
    key = jax.random.PRNGKey(7)
    residue = jnp.full((r,), ev.INVALID_EVENT)
    total_new = 0
    total_sent = 0
    total_dropped = 0
    for w in range(6):
        key, k1, k2, k3 = jax.random.split(key, 4)
        fresh = ev.pack(jax.random.randint(k1, (n_new,), 0, 1 << 14),
                        jax.random.randint(k2, (n_new,), 0, 1 << 15),
                        valid=jax.random.bernoulli(k3, 0.8, (n_new,)))
        dests_of = lambda ww: (ev.address(ww) % d).astype(jnp.int32)
        words = jnp.concatenate([fresh, residue])
        dest = jnp.where(ev.is_valid(words), dests_of(words), -1)
        fw = frb.fused_aggregate(words, dest, jnp.zeros_like(dest), d, c,
                                 residue_len=r, use_pallas=False)
        total_new += int(ev.is_valid(fresh).sum())
        total_sent += int(fw.buckets.counts.sum())
        total_dropped += int(fw.dropped)
        residue = fw.residue
    left = int(ev.is_valid(residue).sum())
    assert total_sent > 0 and left + total_dropped > 0, "overflow unexercised"
    assert total_new == total_sent + total_dropped + left
