"""Transport subsystem tests (subprocess with forced host devices):

* torus2d AND torus3d deliver bit-identical buckets to the alltoall
  backend (on a (2, 4) and a (2, 2, 2) torus of 8 shards), and their
  lowered HLO contains ONLY neighbor collective-permutes (no all-to-all,
  no all-gather) — the acceptance bar of the torus transport PRs.  With
  credits enabled the count grows by exactly the dimension-wise ring
  all-gather hops and stays permute-only.
* Hop-by-hop credit flow control conserves events for random traffic and
  tiny random credit budgets across many seeds: offered == sent +
  deferred per shard/window, deferred == stalled_by_hop.sum() (every
  stall attributed to the route hop that refused it), and globally
  sum(sent) == sum(delivered).  The replicated global CreditBank stays
  bit-identical across shards and satisfies credits + pending == limit
  on every link after every window (credit-unit conservation), including
  across a multi-window run ended by a drain.
* CreditBank edge case at transport level: a zero-credit bank defers
  every off-node row (nothing lost — local rows still deliver).
* The sharded simulator over torus2d/torus3d reproduces the alltoall
  spike train exactly when uncongested, and under congestion the
  transport-deferral / residue re-offer chain balances window by window.
"""
import pytest

from md_helper import run_md

pytestmark = pytest.mark.slow


def test_torus_matches_alltoall_and_neighbor_only_hlo():
    out = run_md("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import events as ev, routing as rt
from repro.core.exchange import make_exchange
n_shards, N, C, n_addr = 8, 64, 16, 96
mesh = jax.make_mesh((n_shards,), ("wafer",))
tabs = []
for s in range(n_shards):
    projs = [rt.Projection(a, a+1, dest_node=(a * 5 + s) % n_shards,
                           dest_links=[a % 3, 7]) for a in range(n_addr)]
    tabs.append(rt.build_tables(n_addr, projs, n_guid=64))
stacked = rt.RoutingTables(
    dest_of_addr=jnp.stack([t.dest_of_addr for t in tabs]),
    guid_of_addr=jnp.stack([t.guid_of_addr for t in tabs]),
    mcast_of_guid=jnp.stack([t.mcast_of_guid for t in tabs]))
addr = jax.random.randint(jax.random.PRNGKey(0), (n_shards, N), 0, n_addr)
ts = jax.random.randint(jax.random.PRNGKey(1), (n_shards, N), 0, 1000)
words = ev.pack(addr, ts)

def hlo_counts(run):
    txt = jax.jit(run).lower(words, stacked).as_text()
    return (txt.count("all_to_all") + txt.count("all-to-all"),
            txt.count("all_gather") + txt.count("all-gather"),
            txt.count("collective_permute") + txt.count("collective-permute"))

run_a = make_exchange(mesh, "wafer", n_shards=n_shards, capacity=C,
                      n_addr_per_shard=n_addr, transport="alltoall")
ref = run_a(words, stacked)
assert hlo_counts(run_a)[0] == 1

# data-phase permutes: sum over rings of (n//2 fwd + (n-1)//2 bwd);
# credited runs add the (n-1)-hop-per-ring counts all-gather, still
# permute-only (hop-by-hop admission needs the global offered matrix)
for backend, opts, exp_cp in [
    ("torus2d", {"nx": 2, "ny": 4}, 1 + 3),
    ("torus3d", {"nx": 2, "ny": 2, "nz": 2}, 1 + 1 + 1),
    ("torus2d", {"nx": 2, "ny": 4, "link_credits": 1 << 20}, 4 + 1 + 3),
    ("torus3d", {"nx": 2, "ny": 2, "nz": 2, "link_credits": 1 << 20},
     3 + 1 + 1 + 1),
]:
    run = make_exchange(mesh, "wafer", n_shards=n_shards, capacity=C,
                        n_addr_per_shard=n_addr, transport=backend,
                        transport_opts=opts)
    t = run(words, stacked)
    # bit-identical delivered event multisets (in fact identical buffers)
    assert (np.asarray(ref.recv_events) == np.asarray(t.recv_events)).all()
    assert (np.asarray(ref.recv_guids) == np.asarray(t.recv_guids)).all()
    assert (np.asarray(ref.recv_counts) == np.asarray(t.recv_counts)).all()
    assert (np.asarray(ref.link_events) == np.asarray(t.link_events)).all()
    assert np.asarray(t.sent_mask).all(), (backend, opts)
    # torus wire model: every hop pays -> forwarded bytes >= crossbar bytes
    assert int(np.asarray(t.link.forwarded_bytes).sum()) >= \\
        int(np.asarray(ref.link.forwarded_bytes).sum())
    n_a2a, n_ag, n_cp = hlo_counts(run)
    assert n_a2a == 0, f"{backend} must not lower an all-to-all ({n_a2a})"
    assert n_ag == 0, f"{backend} must not lower an all-gather ({n_ag})"
    assert n_cp == exp_cp, (backend, opts, n_cp, exp_cp)
print("TORUS_EQUIV_OK")
""")
    assert "TORUS_EQUIV_OK" in out


def test_torus_hop_by_hop_credit_conservation_property():
    """offered == sent + deferred per shard+window, stalled_by_hop sums
    to deferred, global sum(sent) == sum(delivered), for random traffic
    against tiny random per-link credit budgets, with the credit state
    threaded across windows; the replicated bank stays identical on
    every shard, never goes negative, and conserves credit units
    (credits + pending == limit per link) through the run AND through an
    end-of-run drain."""
    out = run_md("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro import transport
from repro.core import flow_control as fc

D, W = 8, 6
mesh = jax.make_mesh((D,), ("wafer",))
spec = P("wafer")

def make_fns(t):
    def body(lstate, p, c, enforce):
        lstate = jax.tree_util.tree_map(lambda x: x[0], lstate)
        out = t.exchange(lstate, p[0], c[0], axis_name="wafer",
                         enforce_credits=enforce)
        return jax.tree_util.tree_map(
            lambda x: x[None], (out.state, out.recv_counts, out.sent_mask,
                                out.stats))
    import functools
    mk = lambda enforce: jax.jit(shard_map(
        functools.partial(body, enforce=enforce), mesh=mesh,
        in_specs=(spec, spec, spec), out_specs=spec, check_rep=False))
    return mk(True), mk(False)

rng = np.random.default_rng(0)
for name, opts in [("torus2d", dict(nx=2, ny=4)),
                   ("torus3d", dict(nx=2, ny=2, nz=2))]:
    any_deferred = any_midroute = False
    for seed in range(8):
        limit = int(rng.integers(30, 120))
        t = transport.create(name, n_shards=D, link_credits=limit,
                             notify_latency=2, **opts)
        fn, fn_drain = make_fns(t)
        lstate = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (D,) + x.shape), t.init_state())
        held_counts = np.zeros((D, D), np.int64)
        for win in range(4):
            counts = jnp.asarray(rng.integers(0, 30, (D, D)), jnp.int32)
            payload = jnp.asarray(
                rng.integers(0, 1 << 31, (D, D, W)), jnp.uint32)
            lstate, rcnt, mask, st = fn(lstate, payload, counts)
            off = np.asarray(st.offered_events)
            sent = np.asarray(st.sent_events)
            defr = np.asarray(st.deferred_events)
            assert (off == sent + defr).all(), (name, seed, win)
            assert sent.sum() == np.asarray(st.delivered_events).sum()
            assert np.asarray(rcnt).sum() == sent.sum()
            # every stalled event is attributed to a route hop
            sbh = np.asarray(st.stalled_by_hop)
            assert (sbh.sum(-1) == defr).all(), (name, seed, win)
            any_midroute = any_midroute or sbh[:, 1:].sum() > 0
            # deferred rows really were withheld: mask rows account
            held = np.where(np.asarray(mask), 0, np.asarray(counts)).sum(1)
            assert (held == defr).all()
            cr = np.asarray(lstate.credits)
            pend = np.asarray(lstate.pending)
            assert (cr >= 0).all()
            # replicated bank identical on every shard
            assert (cr == cr[0]).all() and (pend == pend[0]).all()
            # credit-unit conservation on every link
            assert (cr[0] + pend[0].sum(-1) == limit).all()
            any_deferred = any_deferred or defr.sum() > 0
        # end-of-run drain: ships regardless of credits, spends none
        counts = jnp.asarray(rng.integers(0, 30, (D, D)), jnp.int32)
        payload = jnp.asarray(rng.integers(0, 1 << 31, (D, D, W)),
                              jnp.uint32)
        lstate, rcnt, mask, st = fn_drain(lstate, payload, counts)
        assert np.asarray(mask).all()
        assert np.asarray(rcnt).sum() == np.asarray(counts).sum()
        cr, pend = np.asarray(lstate.credits), np.asarray(lstate.pending)
        assert (cr[0] + pend[0].sum(-1) == limit).all()
    assert any_deferred, name + ": tiny credits never stalled a link"
    assert any_midroute, name + ": no stall ever attributed past hop 0"

# ample credits -> nothing deferred, everything delivered
t = transport.create("torus3d", n_shards=D, nx=2, ny=2, nz=2,
                     link_credits=1 << 20, notify_latency=2)
fn, _ = make_fns(t)
lstate = jax.tree_util.tree_map(
    lambda x: jnp.broadcast_to(x, (D,) + x.shape), t.init_state())
counts = jnp.asarray(rng.integers(0, 30, (D, D)), jnp.int32)
payload = jnp.asarray(rng.integers(0, 1 << 31, (D, D, W)), jnp.uint32)
_, rcnt, mask, st = fn(lstate, payload, counts)
assert np.asarray(mask).all()
assert np.asarray(st.deferred_events).sum() == 0
assert np.asarray(rcnt).sum() == np.asarray(counts).sum()

# zero-credit bank: every off-node row defers, local rows still deliver,
# nothing lost (offered == deferred + local)
t0 = transport.create("torus3d", n_shards=D, nx=2, ny=2, nz=2,
                      link_credits=64, notify_latency=2)
fn0, _ = make_fns(t0)
empty = t0.init_state()._replace(
    credits=jnp.zeros_like(t0.init_state().credits))
lstate = jax.tree_util.tree_map(
    lambda x: jnp.broadcast_to(x, (D,) + x.shape), empty)
counts = jnp.asarray(rng.integers(1, 30, (D, D)), jnp.int32)
payload = jnp.asarray(rng.integers(0, 1 << 31, (D, D, W)), jnp.uint32)
lstate, rcnt, mask, st = fn0(lstate, payload, counts)
local = np.diag(np.asarray(counts))
defr = np.asarray(st.deferred_events)
assert (np.asarray(st.offered_events) == defr + local).all()
assert (np.asarray(rcnt).sum(1) == local).all()
assert (np.asarray(lstate.credits) == 0).all()
print("CONSERVATION_OK")
""")
    assert "CONSERVATION_OK" in out


def test_admission_round_robin_no_starvation():
    """Two sources contending for the same saturated mid-route link must
    BOTH make progress: the canonical admission order rotates with the
    bank's progress epoch, so the lower-index shard cannot win every
    refund cycle.  Host-level (``_admit_global`` is collective-free) so
    the arbitration is pinned without a device mesh."""
    import jax.numpy as jnp
    import numpy as np
    from repro.core import flow_control as fc
    from repro.transport.torus import Torus2DTransport

    # (2, 4) torus; routes 0->5 and 1->5 share node (1,0).y+ / (1,1).y+,
    # each with exactly one full row of credits -> one winner per refund
    t = Torus2DTransport(8, nx=2, ny=4, link_credits=16, notify_latency=2,
                         max_row_events=16)
    state = t.init_state()
    counts = np.zeros((8, 8), np.int32)
    counts[0, 5] = counts[1, 5] = 16
    counts = jnp.asarray(counts)
    wins = np.zeros(8, np.int64)
    for _ in range(7 * 8):          # >= n_shards progress rounds
        admit, spent, _ = t._admit_global(state, counts)
        wins += np.asarray(admit)[:, 5]
        state = fc.credit_tick(state, spent)
        # at most one of the two contenders fits per window
        assert np.asarray(admit)[[0, 1], 5].sum() <= 1
    assert wins[0] > 0 and wins[1] > 0, wins[:2]


def test_simulator_torus_equivalence_and_backpressure():
    out = run_md("""
import jax, numpy as np
from repro.snn import microcircuit as mc, network, simulator as sim
spec = mc.MicrocircuitSpec(scale=0.003)
w, is_inh = spec.weight_matrix()
part = network.build_partition(w, is_inh, n_shards=4)
mesh = jax.make_mesh((4,), ("wafer",))

def run(transport, link_credits=0, capacity=512, n_windows=8, **kw):
    cfg = sim.SimConfig(n_shards=4, per_shard=part.per_shard,
                        max_fan=part.fanout.shape[1], window=8, ring_len=32,
                        e_max=256, capacity=capacity, transport=transport,
                        link_credits=link_credits, notify_latency=2, **kw)
    init, runf = sim.build_sharded_sim(mesh, "wafer", cfg, part,
                                       spec.bg_rates())
    st, stats = runf(init(0), n_windows)
    return jax.tree_util.tree_map(np.asarray, stats)

# 1. uncongested torus2d AND torus3d == alltoall, window for window
#    (torus3d on (1, 2, 2): the Z rings carry the second fold)
sa = run("alltoall")
st = run("torus2d")
s3 = run("torus3d", torus_nx=1, torus_ny=2, torus_nz=2)
assert sa.spikes.sum() > 0
for s in (st, s3):
    assert (sa.spikes == s.spikes).all()
    assert (sa.events_sent == s.events_sent).all()
    assert s.deadline_miss.sum() == 0
    assert s.link.credit_stalls.sum() == 0
    assert (s.link.hops > 0)[:, 1:].all()
assert sa.deadline_miss.sum() == 0
# wire-latency digest rides WindowStats for every backend: the histogram
# accounts exactly the delivered events, and the torus' multi-hop routes
# can only slow the median relative to the single-hop crossbar
for s in (sa, st, s3):
    assert (s.latency.hist.sum(-1) == s.link.delivered_events).all()
    assert (s.latency.p50_us[:, 1:] > 0).all()
    assert (s.latency.max_us >= s.latency.p99_us).all()
    assert (s.latency.p99_us >= s.latency.p50_us).all()
for s in (st, s3):
    assert (s.latency.p50_us >= sa.latency.p50_us).all()

# 2. tiny credits: back-pressure engages; the deferral chain balances
# (link_credits must stay >= capacity -- the admission invariant)
for transport, kw in [("torus2d", {}),
                      ("torus3d", dict(torus_nx=1, torus_ny=2, torus_nz=2))]:
    sc = run(transport, link_credits=40, capacity=32, n_windows=12, **kw)
    link = sc.link
    assert link.credit_stalls.sum() > 0, transport + ": unexercised"
    assert (link.offered_events ==
            link.sent_events + link.deferred_events).all()
    assert (link.sent_events.sum(0) == link.delivered_events.sum(0)).all()
    assert (link.stalled_by_hop.sum(-1) == link.deferred_events).all()
    # the exchange at iteration k ships window k-1's aggregated buckets
    assert (link.offered_events[:, 1:] == sc.events_sent[:, :-1]).all()
    assert (link.offered_events[:, 0] == 0).all()
    # transport-deferred events re-enter the same row's aggregation:
    # fresh_k = offered_k - residue_{k-1} - link_deferred_k >= 0
    defr_prev = np.concatenate(
        [np.zeros((4, 1), sc.deferred.dtype), sc.deferred[:, :-1]], axis=1)
    fresh = sc.offered - defr_prev - link.deferred_events
    assert (fresh >= 0).all()
    # aggregation-level identity still balances on every row
    assert (sc.offered == sc.events_sent + sc.deferred + sc.overflow).all()
    # latency digest stays exact under congestion: every delivered event
    # lands in the histogram (deferred events are counted on the window
    # that finally delivers them, waiting included)
    assert (sc.latency.hist.sum(-1) == sc.link.delivered_events).all()
print("SIM_TORUS_OK")
""", n_devices=4)
    assert "SIM_TORUS_OK" in out
