"""Transport subsystem tests (subprocess with forced host devices):

* torus2d AND torus3d deliver bit-identical buckets to the alltoall
  backend (on a (2, 4) and a (2, 2, 2) torus of 8 shards), and their
  lowered HLO contains ONLY neighbor collective-permutes (no all-to-all,
  no all-gather) — the acceptance bar of the torus transport PRs.  With
  credits enabled the count grows by exactly the dimension-wise ring
  all-gather hops and stays permute-only.
* Hop-by-hop credit flow control with in-fabric transit buffers
  conserves events for random traffic and tiny random credit budgets
  across many seeds: offered == sent + deferred + parked per
  shard/window, deferred == stalled_by_hop.sum() (every deferral is a
  hop-0 source-FIFO stall — mid-route shortages PARK in the fabric
  instead), and globally sum(sent) + sum(unparked) == sum(delivered).
  The replicated global FabricState stays bit-identical across shards
  and satisfies credits + pending + parked_by_link == limit on every
  link after every window (credit-unit conservation with held buffer
  credits), including across a multi-window run ended by a fabric-walk
  drain.
* Mid-route resume, deterministically: a row short of credits at hop 1
  of its 3-hop route parks there and resumes at hop 1 — not hop 0 —
  next window, each route link paid exactly once across the two windows.
* CreditBank edge case at transport level: a zero-credit bank defers
  every off-node row (nothing lost, nothing parked — local rows still
  deliver).
* The sharded simulator over torus2d/torus3d reproduces the alltoall
  spike train exactly when uncongested, and under congestion the
  transport-deferral / residue re-offer / park-resume chain balances
  window by window.
"""
import pytest

from md_helper import run_md

pytestmark = pytest.mark.slow


def test_torus_matches_alltoall_and_neighbor_only_hlo():
    out = run_md("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import events as ev, routing as rt
from repro.core.exchange import make_exchange
n_shards, N, C, n_addr = 8, 64, 16, 96
mesh = jax.make_mesh((n_shards,), ("wafer",))
tabs = []
for s in range(n_shards):
    projs = [rt.Projection(a, a+1, dest_node=(a * 5 + s) % n_shards,
                           dest_links=[a % 3, 7]) for a in range(n_addr)]
    tabs.append(rt.build_tables(n_addr, projs, n_guid=64))
stacked = rt.RoutingTables(
    dest_of_addr=jnp.stack([t.dest_of_addr for t in tabs]),
    guid_of_addr=jnp.stack([t.guid_of_addr for t in tabs]),
    mcast_of_guid=jnp.stack([t.mcast_of_guid for t in tabs]))
addr = jax.random.randint(jax.random.PRNGKey(0), (n_shards, N), 0, n_addr)
ts = jax.random.randint(jax.random.PRNGKey(1), (n_shards, N), 0, 1000)
words = ev.pack(addr, ts)

def hlo_counts(run):
    txt = jax.jit(run).lower(words, stacked).as_text()
    return (txt.count("all_to_all") + txt.count("all-to-all"),
            txt.count("all_gather") + txt.count("all-gather"),
            txt.count("collective_permute") + txt.count("collective-permute"))

run_a = make_exchange(mesh, "wafer", n_shards=n_shards, capacity=C,
                      n_addr_per_shard=n_addr, transport="alltoall")
ref = run_a(words, stacked)
assert hlo_counts(run_a)[0] == 1

# data-phase permutes: sum over rings of (n//2 fwd + (n-1)//2 bwd);
# credited runs add the (n-1)-hop-per-ring counts all-gather, still
# permute-only (hop-by-hop admission needs the global offered matrix)
for backend, opts, exp_cp in [
    ("torus2d", {"nx": 2, "ny": 4}, 1 + 3),
    ("torus3d", {"nx": 2, "ny": 2, "nz": 2}, 1 + 1 + 1),
    ("torus2d", {"nx": 2, "ny": 4, "link_credits": 1 << 20}, 4 + 1 + 3),
    ("torus3d", {"nx": 2, "ny": 2, "nz": 2, "link_credits": 1 << 20},
     3 + 1 + 1 + 1),
]:
    run = make_exchange(mesh, "wafer", n_shards=n_shards, capacity=C,
                        n_addr_per_shard=n_addr, transport=backend,
                        transport_opts=opts)
    t = run(words, stacked)
    # bit-identical delivered event multisets (in fact identical buffers)
    assert (np.asarray(ref.recv_events) == np.asarray(t.recv_events)).all()
    assert (np.asarray(ref.recv_guids) == np.asarray(t.recv_guids)).all()
    assert (np.asarray(ref.recv_counts) == np.asarray(t.recv_counts)).all()
    assert (np.asarray(ref.link_events) == np.asarray(t.link_events)).all()
    assert np.asarray(t.sent_mask).all(), (backend, opts)
    # torus wire model: every hop pays -> forwarded bytes >= crossbar bytes
    assert int(np.asarray(t.link.forwarded_bytes).sum()) >= \\
        int(np.asarray(ref.link.forwarded_bytes).sum())
    n_a2a, n_ag, n_cp = hlo_counts(run)
    assert n_a2a == 0, f"{backend} must not lower an all-to-all ({n_a2a})"
    assert n_ag == 0, f"{backend} must not lower an all-gather ({n_ag})"
    assert n_cp == exp_cp, (backend, opts, n_cp, exp_cp)
print("TORUS_EQUIV_OK")
""")
    assert "TORUS_EQUIV_OK" in out


def test_torus_hop_by_hop_credit_conservation_property():
    """offered == sent + deferred + parked per shard+window,
    stalled_by_hop sums to deferred, global sum(sent) + sum(unparked) ==
    sum(delivered), for random traffic against tiny random per-link
    credit budgets, with the fabric state threaded across windows; the
    replicated bank + transit tables stay identical on every shard,
    never go negative, and conserve credit units (credits + pending +
    parked_by_link == limit per link) through the run AND through an
    end-of-run fabric-walk drain."""
    out = run_md("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro import transport
from repro.core import flow_control as fc

D, W = 8, 6
mesh = jax.make_mesh((D,), ("wafer",))
spec = P("wafer")

def make_fns(t):
    def body(lstate, p, c, enforce):
        lstate = jax.tree_util.tree_map(lambda x: x[0], lstate)
        out = t.exchange(lstate, p[0], c[0], axis_name="wafer",
                         enforce_credits=enforce)
        return jax.tree_util.tree_map(
            lambda x: x[None], (out.state, out.recv_counts, out.sent_mask,
                                out.stats))
    def dbody(lstate):
        lstate = jax.tree_util.tree_map(lambda x: x[0], lstate)
        out = t.drain_fabric(lstate, axis_name="wafer")
        return jax.tree_util.tree_map(
            lambda x: x[None], (out.state, out.recv_counts, out.stats))
    import functools
    mk = lambda enforce: jax.jit(shard_map(
        functools.partial(body, enforce=enforce), mesh=mesh,
        in_specs=(spec, spec, spec), out_specs=spec, check_rep=False))
    walk = jax.jit(shard_map(dbody, mesh=mesh, in_specs=(spec,),
                             out_specs=spec, check_rep=False))
    return mk(True), mk(False), walk

rng = np.random.default_rng(0)
for name, opts in [("torus2d", dict(nx=2, ny=4)),
                   ("torus3d", dict(nx=2, ny=2, nz=2))]:
    any_deferred = any_parked = any_resumed = False
    for seed in range(8):
        limit = int(rng.integers(30, 120))
        t = transport.create(name, n_shards=D, link_credits=limit,
                             notify_latency=2, **opts)
        fn, fn_drain, fn_walk = make_fns(t)
        lstate = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (D,) + x.shape), t.init_state(W))
        for win in range(4):
            counts = jnp.asarray(rng.integers(0, 30, (D, D)), jnp.int32)
            payload = jnp.asarray(
                rng.integers(0, 1 << 31, (D, D, W)), jnp.uint32)
            lstate, rcnt, mask, st = fn(lstate, payload, counts)
            off = np.asarray(st.offered_events)
            sent = np.asarray(st.sent_events)
            defr = np.asarray(st.deferred_events)
            park = np.asarray(st.parked_events)
            unpark = np.asarray(st.unparked_events)
            assert (off == sent + defr + park).all(), (name, seed, win)
            assert (sent.sum() + unpark.sum()
                    == np.asarray(st.delivered_events).sum())
            assert np.asarray(rcnt).sum() == sent.sum() + unpark.sum()
            # every deferral is a hop-0 source-FIFO stall now (mid-route
            # shortages park in the fabric instead of re-entering)
            sbh = np.asarray(st.stalled_by_hop)
            assert (sbh.sum(-1) == defr).all(), (name, seed, win)
            assert sbh[:, 1:].sum() == 0
            # parked rows wait at a transit hop (>= 1), never at hop 0
            pbh = np.asarray(st.parked_by_hop)
            assert (pbh[:, 0] == 0).all()
            assert (pbh.sum(-1) == np.asarray(st.in_fabric_events)).all()
            # deferred rows really were withheld: mask rows account
            held = np.where(np.asarray(mask), 0, np.asarray(counts)).sum(1)
            assert (held == defr).all()
            cr = np.asarray(lstate.bank.credits)
            pend = np.asarray(lstate.bank.pending)
            pbl = np.asarray(lstate.parked_by_link)
            assert (cr >= 0).all() and (pbl >= 0).all()
            # replicated fabric state identical on every shard
            assert (cr == cr[0]).all() and (pend == pend[0]).all()
            pc = np.asarray(lstate.parked_count)
            assert (pc == pc[0]).all() and (pbl == pbl[0]).all()
            # credit-unit conservation on every link: available + in
            # flight as notification + held by a parked row == limit
            assert (cr[0] + pend[0].sum(-1) + pbl[0] == limit).all()
            any_deferred = any_deferred or defr.sum() > 0
            any_parked = any_parked or park.sum() > 0
            any_resumed = any_resumed or unpark.sum() > 0
        # end-of-run drain: walk the fabric empty, then ship the final
        # offers regardless of credits; all held credits return
        lstate, rcnt, st = fn_walk(lstate)
        assert (np.asarray(rcnt).sum()
                == np.asarray(st.unparked_events).sum())
        assert (np.asarray(lstate.parked_count) == 0).all()
        assert (np.asarray(lstate.parked_by_link) == 0).all()
        counts = jnp.asarray(rng.integers(0, 30, (D, D)), jnp.int32)
        payload = jnp.asarray(rng.integers(0, 1 << 31, (D, D, W)),
                              jnp.uint32)
        lstate, rcnt, mask, st = fn_drain(lstate, payload, counts)
        assert np.asarray(mask).all()
        assert np.asarray(rcnt).sum() == np.asarray(counts).sum()
        cr, pend = np.asarray(lstate.bank.credits), \
            np.asarray(lstate.bank.pending)
        assert (cr[0] + pend[0].sum(-1) == limit).all()
    assert any_deferred, name + ": tiny credits never stalled a source"
    assert any_parked, name + ": nothing ever parked mid-route"
    assert any_resumed, name + ": no parked row ever resumed"

# ample credits -> nothing deferred, nothing parked, all delivered
t = transport.create("torus3d", n_shards=D, nx=2, ny=2, nz=2,
                     link_credits=1 << 20, notify_latency=2)
fn, _, _ = make_fns(t)
lstate = jax.tree_util.tree_map(
    lambda x: jnp.broadcast_to(x, (D,) + x.shape), t.init_state(W))
counts = jnp.asarray(rng.integers(0, 30, (D, D)), jnp.int32)
payload = jnp.asarray(rng.integers(0, 1 << 31, (D, D, W)), jnp.uint32)
_, rcnt, mask, st = fn(lstate, payload, counts)
assert np.asarray(mask).all()
assert np.asarray(st.deferred_events).sum() == 0
assert np.asarray(st.parked_events).sum() == 0
assert np.asarray(rcnt).sum() == np.asarray(counts).sum()

# zero-credit bank: every off-node row defers at hop 0 (nothing can even
# enter the fabric, so nothing parks), local rows still deliver, nothing
# lost (offered == deferred + local)
t0 = transport.create("torus3d", n_shards=D, nx=2, ny=2, nz=2,
                      link_credits=64, notify_latency=2)
fn0, _, _ = make_fns(t0)
base0 = t0.init_state(W)
empty = base0._replace(bank=base0.bank._replace(
    credits=jnp.zeros_like(base0.bank.credits)))
lstate = jax.tree_util.tree_map(
    lambda x: jnp.broadcast_to(x, (D,) + x.shape), empty)
counts = jnp.asarray(rng.integers(1, 30, (D, D)), jnp.int32)
payload = jnp.asarray(rng.integers(0, 1 << 31, (D, D, W)), jnp.uint32)
lstate, rcnt, mask, st = fn0(lstate, payload, counts)
local = np.diag(np.asarray(counts))
defr = np.asarray(st.deferred_events)
assert (np.asarray(st.offered_events) == defr + local).all()
assert np.asarray(st.parked_events).sum() == 0
assert (np.asarray(rcnt).sum(1) == local).all()
assert (np.asarray(lstate.bank.credits) == 0).all()
assert (np.asarray(lstate.parked_count) == 0).all()
print("CONSERVATION_OK")
""")
    assert "CONSERVATION_OK" in out


def _advance(state, adm):
    """Apply one admission replay's bank/table updates host-side (the
    same sequence ``TorusTransport.exchange`` performs on device)."""
    from repro.core import flow_control as fc
    bank = fc.credit_tick(state.bank, adm.spent, notify=adm.notify)
    return state._replace(bank=bank, parked_count=adm.park_count,
                          parked_hop=adm.park_hop, parked_age=adm.park_age,
                          parked_by_link=adm.parked_by_link)


def test_admission_round_robin_no_starvation():
    """Two sources contending for the same saturated mid-route link must
    BOTH make progress: the canonical admission order rotates with the
    bank's progress epoch, so the lower-index shard cannot win every
    refund cycle (a delivery = completing fresh OR resuming from park).
    Host-level (``_admit_global`` is collective-free) so the arbitration
    is pinned without a device mesh."""
    import jax.numpy as jnp
    import numpy as np
    from repro.transport.torus import Torus2DTransport

    # (2, 4) torus; routes 0->5 and 1->5 share node (1,0).y+ / (1,1).y+,
    # each with exactly one full row of credits -> one winner per refund
    t = Torus2DTransport(8, nx=2, ny=4, link_credits=16, notify_latency=2,
                         max_row_events=16)
    state = t.init_state(payload_width=4)
    counts = np.zeros((8, 8), np.int32)
    counts[0, 5] = counts[1, 5] = 16
    counts = jnp.asarray(counts)
    wins = np.zeros(8, np.int64)
    for _ in range(7 * 8):          # >= n_shards progress rounds
        adm = t._admit_global(state, counts)
        done = (np.asarray(adm.fresh_complete)
                | np.asarray(adm.resumed_complete))
        wins += done[:, 5]
        state = _advance(state, adm)
    assert wins[0] > 0 and wins[1] > 0, wins[:2]


def test_midroute_park_and_resume_deterministic():
    """A row short of credits at hop 1 of its 3-hop route parks AT hop 1
    — having crossed its source egress link — and next window resumes
    from hop 1, not hop 0: the two windows together traverse each route
    link exactly once (links_traversed sums to the hop count, which is
    what makes ``bytes_on_wire`` charge every link once), the arrival
    link's credit is held while parked and released on departure."""
    import jax.numpy as jnp
    import numpy as np
    from repro.transport.torus import Torus2DTransport

    # (2, 4) torus: route 0 -> 5 is (0,0).x+ then (1,0).y+ then (1,1).y+
    # (3 hops).  Choke the hop-1 link (node 1, direction y+).
    t = Torus2DTransport(8, nx=2, ny=4, link_credits=32, notify_latency=2,
                         max_row_events=32)
    hop1_link = 1 * t.n_links + 2               # node 1, y+ (dirs x+x-y+y-)
    hop0_link = 0 * t.n_links + 0               # node 0, x+
    state = t.init_state(payload_width=4)
    state = state._replace(bank=state.bank._replace(
        credits=state.bank.credits.at[hop1_link].set(0)))
    counts = np.zeros((8, 8), np.int32)
    counts[0, 5] = 8
    counts = jnp.asarray(counts)

    # window 1: the row enters the fabric, crosses hop 0, parks at hop 1
    adm1 = t._admit_global(state, counts)
    assert bool(adm1.fresh_park[0, 5])
    assert not bool(adm1.fresh_complete[0, 5])
    assert int(adm1.stall_hop[0, 5]) == -1, "parked, not deferred"
    assert int(adm1.park_hop[0, 5]) == 1
    assert int(adm1.park_count[0, 5]) == 8
    assert int(adm1.links_traversed[0, 5]) == 1
    # the arrival link (hop 0) holds the row's credits while it waits
    assert int(adm1.parked_by_link[hop0_link]) == 8
    state = _advance(state, adm1)
    assert int(state.bank.credits[hop0_link]) == 32 - 8

    # window 2: un-choke hop 1; the row must resume at hop 1 (charging
    # hops 1 and 2 only) and complete — NOT re-enter at hop 0
    state = state._replace(bank=state.bank._replace(
        credits=state.bank.credits.at[hop1_link].set(32)))
    adm2 = t._admit_global(state, jnp.zeros((8, 8), jnp.int32))
    assert bool(adm2.resumed_complete[0, 5])
    assert int(adm2.resume_age[0, 5]) == 1, "delivered after 1 parked window"
    # a lone row resuming through an otherwise empty fabric is not queued
    # behind anything — least of all its own held events (the queueing
    # gather starts at the blocked hop, past its own arrival link)
    assert int(adm2.queue_events[0, 5]) == 0
    assert int(adm2.links_traversed[0, 5]) == 2
    assert int(adm2.park_count[0, 5]) == 0
    # each of the 3 route links paid exactly once across both windows
    total = int(adm1.links_traversed[0, 5]) + int(adm2.links_traversed[0, 5])
    assert total == int(t.route_hops()[0, 5]) == 3
    # hop 0's credit was NOT re-spent on resume: held 8 released, and no
    # fresh spend hits it in window 2
    assert int(adm2.spent[hop0_link]) == 0
    assert int(adm2.parked_by_link[hop0_link]) == 0
    state = _advance(state, adm2)
    # held credit finishes its notification round-trip: conservation
    cr = np.asarray(state.bank.credits)
    pend = np.asarray(state.bank.pending)
    pbl = np.asarray(state.parked_by_link)
    assert (cr + pend.sum(-1) + pbl == 32).all()


def test_simulator_torus_equivalence_and_backpressure():
    out = run_md("""
import jax, numpy as np
from repro.snn import microcircuit as mc, network, simulator as sim
spec = mc.MicrocircuitSpec(scale=0.003)
w, is_inh = spec.weight_matrix()
part = network.build_partition(w, is_inh, n_shards=4)
mesh = jax.make_mesh((4,), ("wafer",))

def run(transport, link_credits=0, capacity=512, n_windows=8, **kw):
    cfg = sim.SimConfig(n_shards=4, per_shard=part.per_shard,
                        max_fan=part.fanout.shape[1], window=8, ring_len=32,
                        e_max=256, capacity=capacity, transport=transport,
                        link_credits=link_credits, notify_latency=2, **kw)
    init, runf = sim.build_sharded_sim(mesh, "wafer", cfg, part,
                                       spec.bg_rates())
    st, stats = runf(init(0), n_windows)
    return jax.tree_util.tree_map(np.asarray, stats)

# 1. uncongested torus2d AND torus3d == alltoall, window for window
#    (torus3d on (1, 2, 2): the Z rings carry the second fold)
sa = run("alltoall")
st = run("torus2d")
s3 = run("torus3d", torus_nx=1, torus_ny=2, torus_nz=2)
assert sa.spikes.sum() > 0
for s in (st, s3):
    assert (sa.spikes == s.spikes).all()
    assert (sa.events_sent == s.events_sent).all()
    assert s.deadline_miss.sum() == 0
    assert s.link.credit_stalls.sum() == 0
    assert (s.link.hops > 0)[:, 1:].all()
assert sa.deadline_miss.sum() == 0
# wire-latency digest rides WindowStats for every backend: the histogram
# accounts exactly the delivered events, and the torus' multi-hop routes
# can only slow the median relative to the single-hop crossbar
for s in (sa, st, s3):
    assert (s.latency.hist.sum(-1) == s.link.delivered_events).all()
    assert (s.latency.p50_us[:, 1:] > 0).all()
    assert (s.latency.max_us >= s.latency.p99_us).all()
    assert (s.latency.p99_us >= s.latency.p50_us).all()
for s in (st, s3):
    assert (s.latency.p50_us >= sa.latency.p50_us).all()

# 2. tiny credits: back-pressure engages; the deferral + park/resume
# chain balances (link_credits must stay >= capacity -- the admission
# invariant)
for transport, kw in [("torus2d", {}),
                      ("torus3d", dict(torus_nx=1, torus_ny=2, torus_nz=2))]:
    sc = run(transport, link_credits=40, capacity=32, n_windows=12, **kw)
    link = sc.link
    assert link.credit_stalls.sum() > 0, transport + ": unexercised"
    assert (link.offered_events == link.sent_events
            + link.deferred_events + link.parked_events).all()
    assert ((link.sent_events + link.unparked_events).sum(0)
            == link.delivered_events.sum(0)).all()
    assert (link.stalled_by_hop.sum(-1) == link.deferred_events).all()
    # in-fabric occupancy balances window to window: parked events enter,
    # unparked events leave, per shard (rows are owned by their source)
    infab_prev = np.concatenate(
        [np.zeros((4, 1), np.int64),
         link.in_fabric_events.astype(np.int64)[:, :-1]], axis=1)
    assert (link.in_fabric_events ==
            infab_prev + link.parked_events - link.unparked_events).all()
    # the exchange at iteration k ships window k-1's aggregated buckets
    assert (link.offered_events[:, 1:] == sc.events_sent[:, :-1]).all()
    assert (link.offered_events[:, 0] == 0).all()
    # transport-deferred events re-enter the same row's aggregation:
    # fresh_k = offered_k - residue_{k-1} - link_deferred_k >= 0
    defr_prev = np.concatenate(
        [np.zeros((4, 1), sc.deferred.dtype), sc.deferred[:, :-1]], axis=1)
    fresh = sc.offered - defr_prev - link.deferred_events
    assert (fresh >= 0).all()
    # aggregation-level identity still balances on every row (parked
    # rows left the caller's custody, so they are "sent" here)
    assert (sc.offered == sc.events_sent + sc.deferred + sc.overflow).all()
    # latency digest stays exact under congestion: every delivered event
    # lands in the histogram (deferred AND parked events are counted on
    # the window that finally delivers them, waiting included)
    assert (sc.latency.hist.sum(-1) == sc.link.delivered_events).all()
print("SIM_TORUS_OK")
""", n_devices=4)
    assert "SIM_TORUS_OK" in out
