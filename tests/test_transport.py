"""Transport subsystem tests (subprocess with forced host devices):

* torus2d delivers bit-identical buckets to the alltoall backend on a
  (2, 4) torus of 8 shards, and its lowered HLO contains ONLY neighbor
  collective-permutes (no all-to-all) — the acceptance bar of the torus
  transport PR.
* Credit-based link flow control conserves events for random traffic and
  tiny random credit budgets across many seeds:
  offered == sent + deferred per shard/window, and globally
  sum(sent) == sum(delivered) — the LinkStats extension of the
  WindowStats identity in tests/test_pipeline.py.
* The sharded simulator over torus2d reproduces the alltoall spike train
  exactly when uncongested, and under congestion the transport-deferral /
  residue re-offer chain balances window by window.
"""
import pytest

from md_helper import run_md

pytestmark = pytest.mark.slow


def test_torus_matches_alltoall_and_neighbor_only_hlo():
    out = run_md("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import events as ev, routing as rt
from repro.core.exchange import make_exchange
n_shards, N, C, n_addr = 8, 64, 16, 96
mesh = jax.make_mesh((n_shards,), ("wafer",))
tabs = []
for s in range(n_shards):
    projs = [rt.Projection(a, a+1, dest_node=(a * 5 + s) % n_shards,
                           dest_links=[a % 3, 7]) for a in range(n_addr)]
    tabs.append(rt.build_tables(n_addr, projs, n_guid=64))
stacked = rt.RoutingTables(
    dest_of_addr=jnp.stack([t.dest_of_addr for t in tabs]),
    guid_of_addr=jnp.stack([t.guid_of_addr for t in tabs]),
    mcast_of_guid=jnp.stack([t.mcast_of_guid for t in tabs]))
addr = jax.random.randint(jax.random.PRNGKey(0), (n_shards, N), 0, n_addr)
ts = jax.random.randint(jax.random.PRNGKey(1), (n_shards, N), 0, 1000)
words = ev.pack(addr, ts)
runs = {}
for backend, opts in [("alltoall", None), ("torus2d", {"nx": 2, "ny": 4})]:
    run = make_exchange(mesh, "wafer", n_shards=n_shards, capacity=C,
                        n_addr_per_shard=n_addr, transport=backend,
                        transport_opts=opts)
    runs[backend] = (run, run(words, stacked))
a, t = runs["alltoall"][1], runs["torus2d"][1]
# bit-identical delivered event multisets (in fact identical buffers)
assert (np.asarray(a.recv_events) == np.asarray(t.recv_events)).all()
assert (np.asarray(a.recv_guids) == np.asarray(t.recv_guids)).all()
assert (np.asarray(a.recv_counts) == np.asarray(t.recv_counts)).all()
assert (np.asarray(a.link_events) == np.asarray(t.link_events)).all()
assert np.asarray(t.sent_mask).all()
# torus wire model: every hop pays -> forwarded bytes >= crossbar bytes
assert int(np.asarray(t.link.forwarded_bytes).sum()) >= \\
    int(np.asarray(a.link.forwarded_bytes).sum())
# HLO: torus lowers to neighbor collective-permutes ONLY, no all-to-all
txt = jax.jit(runs["torus2d"][0]).lower(words, stacked).as_text()
n_a2a = txt.count("all_to_all") + txt.count("all-to-all")
n_cp = txt.count("collective_permute") + txt.count("collective-permute")
assert n_a2a == 0, f"torus2d must not lower an all-to-all ({n_a2a})"
assert n_cp > 0, "torus2d must lower neighbor collective-permutes"
# dimension-ordered shortest-path hop count for a (2, 4) torus:
# x: 1 forward; y: 2 forward + 1 backward  ->  4 permutes
assert n_cp == 4, n_cp
txt_a = jax.jit(runs["alltoall"][0]).lower(words, stacked).as_text()
assert txt_a.count("all_to_all") + txt_a.count("all-to-all") == 1
print("TORUS_EQUIV_OK")
""")
    assert "TORUS_EQUIV_OK" in out


def test_torus_credit_conservation_property():
    """offered == sent + deferred per shard+window and global
    sum(sent) == sum(delivered), for random traffic against tiny random
    per-link credit budgets, with the credit state threaded across
    windows; credits never go negative."""
    out = run_md("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro import transport
from repro.core import flow_control as fc

D, W = 8, 6
mesh = jax.make_mesh((D,), ("wafer",))
t = transport.create("torus2d", n_shards=D, nx=2, ny=4, link_credits=1,
                     notify_latency=2)

def body(lstate, p, c):
    lstate = jax.tree_util.tree_map(lambda x: x[0], lstate)
    out = t.exchange(lstate, p[0], c[0], axis_name="wafer")
    return jax.tree_util.tree_map(
        lambda x: x[None], (out.state, out.recv_counts, out.sent_mask,
                            out.stats))

spec = P("wafer")
fn = jax.jit(shard_map(body, mesh=mesh, in_specs=(spec, spec, spec),
                       out_specs=spec, check_rep=False))

rng = np.random.default_rng(0)
any_deferred = False
for seed in range(12):
    limit = int(rng.integers(5, 80))
    credits = jnp.full((D, 4), limit, jnp.int32)
    pending = jnp.zeros((D, 4, 2), jnp.int32)
    lstate = fc.CreditBank(credits=credits, pending=pending)
    for win in range(4):
        counts = jnp.asarray(rng.integers(0, 30, (D, D)), jnp.int32)
        payload = jnp.asarray(
            rng.integers(0, 1 << 31, (D, D, W)), jnp.uint32)
        lstate, rcnt, mask, st = fn(lstate, payload, counts)
        off, sent = np.asarray(st.offered_events), np.asarray(st.sent_events)
        defr = np.asarray(st.deferred_events)
        assert (off == sent + defr).all(), (seed, win)
        assert sent.sum() == np.asarray(st.delivered_events).sum()
        assert np.asarray(rcnt).sum() == sent.sum()
        # deferred rows really were withheld: mask rows account for defr
        held = np.where(np.asarray(mask), 0, np.asarray(counts)).sum(1)
        assert (held == defr).all()
        assert (np.asarray(lstate.credits) >= 0).all()
        any_deferred = any_deferred or defr.sum() > 0
assert any_deferred, "tiny credits never stalled a link -- unexercised"
# ample credits -> nothing deferred, everything delivered
lstate = fc.CreditBank(credits=jnp.full((D, 4), 1 << 30, jnp.int32),
                       pending=jnp.zeros((D, 4, 2), jnp.int32))
counts = jnp.asarray(rng.integers(0, 30, (D, D)), jnp.int32)
payload = jnp.asarray(rng.integers(0, 1 << 31, (D, D, W)), jnp.uint32)
_, rcnt, mask, st = fn(lstate, payload, counts)
assert np.asarray(mask).all()
assert np.asarray(st.deferred_events).sum() == 0
assert np.asarray(rcnt).sum() == np.asarray(counts).sum()
print("CONSERVATION_OK")
""")
    assert "CONSERVATION_OK" in out


def test_simulator_torus_equivalence_and_backpressure():
    out = run_md("""
import jax, numpy as np
from repro.snn import microcircuit as mc, network, simulator as sim
spec = mc.MicrocircuitSpec(scale=0.003)
w, is_inh = spec.weight_matrix()
part = network.build_partition(w, is_inh, n_shards=4)
mesh = jax.make_mesh((4,), ("wafer",))

def run(transport, link_credits=0, capacity=512, n_windows=8):
    cfg = sim.SimConfig(n_shards=4, per_shard=part.per_shard,
                        max_fan=part.fanout.shape[1], window=8, ring_len=32,
                        e_max=256, capacity=capacity, transport=transport,
                        link_credits=link_credits, notify_latency=2)
    init, runf = sim.build_sharded_sim(mesh, "wafer", cfg, part,
                                       spec.bg_rates())
    st, stats = runf(init(0), n_windows)
    return jax.tree_util.tree_map(np.asarray, stats)

# 1. uncongested torus == alltoall, window for window
sa, st = run("alltoall"), run("torus2d")
assert sa.spikes.sum() > 0
assert (sa.spikes == st.spikes).all()
assert (sa.events_sent == st.events_sent).all()
assert sa.deadline_miss.sum() == 0 and st.deadline_miss.sum() == 0
assert st.link.credit_stalls.sum() == 0
assert (st.link.hops > 0)[:, 1:].all()

# 2. tiny credits: back-pressure engages; the deferral chain balances
# (link_credits must stay >= capacity -- the admission invariant)
sc = run("torus2d", link_credits=40, capacity=32, n_windows=12)
link = sc.link
assert link.credit_stalls.sum() > 0, "credit back-pressure unexercised"
assert (link.offered_events ==
        link.sent_events + link.deferred_events).all()
assert (link.sent_events.sum(0) == link.delivered_events.sum(0)).all()
# the exchange at iteration k ships window k-1's aggregated buckets
assert (link.offered_events[:, 1:] == sc.events_sent[:, :-1]).all()
assert (link.offered_events[:, 0] == 0).all()
# transport-deferred events re-enter the same row's aggregation:
# fresh_k = offered_k - residue_{k-1} - link_deferred_k >= 0
defr_prev = np.concatenate(
    [np.zeros((4, 1), sc.deferred.dtype), sc.deferred[:, :-1]], axis=1)
fresh = sc.offered - defr_prev - link.deferred_events
assert (fresh >= 0).all()
# aggregation-level identity still balances on every row
assert (sc.offered == sc.events_sent + sc.deferred + sc.overflow).all()
print("SIM_TORUS_OK")
""", n_devices=4)
    assert "SIM_TORUS_OK" in out
