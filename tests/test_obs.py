"""Observability stack tests: tracer, metrics, flight-recorder ring,
run-directory report, and the disabled-path pins.

The pins encode the PR's central contract: with the recorder off (the
default) the scan carry pytree and the lowered HLO of both the serving
engine's segment and the simulator's segment are EXACTLY the
uninstrumented program.  This was verified once against the
pre-observability tree (commit f1e89b0) via a git worktree — the
disabled-path ``jax.jit(...).lower(...).as_text()`` dumps were
byte-identical pre/post for both programs; the slow test below keeps the
in-tree halves of that promise honest (disabled arity/HLO stable,
enabled HLO differs).
"""
import json
import os
import threading
from typing import NamedTuple

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from md_helper import run_md
from repro.fabric import faults as fabric_faults
from repro.obs import metrics as obs_metrics
from repro.obs import recorder as obs_recorder
from repro.obs import report as obs_report
from repro.obs import spans as obs_spans

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TESTS_DIR = os.path.dirname(os.path.abspath(__file__))


# -- spans -------------------------------------------------------------------

def test_tracer_span_api():
    tr = obs_spans.Tracer()
    with tr.span("ingest/fill", track="spike-ingest", seg=0) as sp:
        sp.args["events"] = 17
    tr.complete("device/segment", 10.0, 25.0, track="device", win0=4)
    tr.instant("window", track="device", cat="device", window=4)

    def worker():
        with tr.span("device/dispatch", track="spike-device"):
            pass

    t = threading.Thread(target=worker)
    t.start()
    t.join()
    d = tr.to_dict()
    assert obs_spans.validate_trace(d) == []
    names = obs_spans.thread_names(d)
    assert set(names.values()) == {"spike-ingest", "device", "spike-device"}
    evs = {e["name"]: e for e in d["traceEvents"] if e["ph"] != "M"}
    assert evs["ingest/fill"]["args"] == {"seg": 0, "events": 17}
    assert evs["device/segment"]["dur"] == 25.0
    assert evs["window"]["ph"] == "i"


def test_tracer_disabled_still_times():
    tr = obs_spans.Tracer(enabled=False)
    with tr.span("train/step", track="train") as sp:
        x = sum(range(1000))
    assert x and sp.dur_s > 0.0
    assert tr.to_dict()["traceEvents"][1:] == []     # only process_name meta
    # the shared NULL tracer behaves the same and never accumulates
    with obs_spans.NULL.span("x") as sp:
        pass
    assert sp.dur_us >= 0.0


def test_validate_trace_detects_problems():
    bad = {"traceEvents": [
        {"name": "a", "ph": "X", "ts": 5.0, "dur": -1.0, "pid": 0, "tid": 0},
        {"name": "b", "ph": "X", "ts": 1.0, "dur": 2.0, "pid": 0, "tid": 0},
        {"name": "c", "ph": "i"},
    ]}
    problems = obs_spans.validate_trace(bad)
    assert any("negative dur" in p for p in problems)
    assert any("not monotonic" in p for p in problems)
    assert any("missing ts" in p for p in problems)
    assert obs_spans.validate_trace({}) == ["no traceEvents list"]


# -- metrics -----------------------------------------------------------------

def test_metrics_prometheus_roundtrip():
    reg = obs_metrics.Registry()
    c = reg.counter("fabric_sent_events_total", "Sent.",
                    labels=("backend",))
    c.inc(41, backend="torus3d")
    c.inc(1, backend="torus3d")
    reg.gauge("engine_events_per_s", "Throughput.").set(123.5)
    h = reg.histogram("tenant_latency_us", "Latency.", labels=("tenant",),
                      edges=(1.0, 2.0, 4.0))
    h.add_binned([0, 3, 1], tenant="quiet")
    assert h.percentile(0.5, tenant="quiet") == 2.0
    text = obs_metrics.prometheus_text(reg)
    parsed = obs_metrics.parse_prometheus(text)
    assert parsed["fabric_sent_events_total"][
        frozenset({("backend", "torus3d")})] == 42.0
    assert parsed["engine_events_per_s"][frozenset()] == 123.5
    assert parsed["tenant_latency_us_count"][
        frozenset({("tenant", "quiet")})] == 4.0


def test_metrics_parse_rejects_malformed():
    with pytest.raises(ValueError):
        obs_metrics.parse_prometheus("this is { not exposition\n")
    with pytest.raises(ValueError):
        # samples without a # TYPE declaration
        obs_metrics.parse_prometheus("orphan_metric 1.0\n")


def test_metrics_label_mismatch_raises():
    reg = obs_metrics.Registry()
    c = reg.counter("x_total", "X.", labels=("tenant",))
    with pytest.raises(ValueError):
        c.inc(1)                                     # missing label
    with pytest.raises(ValueError):
        reg.counter("x_total", "X.", labels=())      # redeclared differently


# -- flight-recorder ring ----------------------------------------------------

class _Bank(NamedTuple):
    credits: jax.Array


class _State(NamedTuple):
    bank: _Bank
    parked_by_link: jax.Array


class _Stats(NamedTuple):
    offered_events: int
    sent_events: int
    deferred_events: int
    delivered_events: int
    credit_stalls: int
    parked_events: int
    unparked_events: int
    in_fabric_events: int
    rerouted: int


def _fake_state(k=4):
    return _State(_Bank(jnp.full((k,), 7, jnp.int32)),
                  jnp.zeros((k,), jnp.int32))


def _write(ring, n):
    st = _fake_state()
    for w in range(n):
        ring = obs_recorder.record(
            ring, w, _Stats(*(w * 10 + i for i in range(9))), st,
            jnp.full((3,), w, jnp.int32))
    return ring


def test_ring_records_and_totals():
    ring = obs_recorder.ring_init(8, _fake_state(), (), (3,), 4)
    rows = obs_recorder.ring_rows(_write(ring, 6))
    assert [r["window"] for r in rows] == list(range(6))
    assert rows[0]["overwritten"] == 0
    assert rows[5]["counters"]["rerouted"] == 58
    assert rows[2]["credits"] == [7, 7, 7, 7]
    totals = obs_recorder.counter_totals(rows)
    assert totals["offered_events"] == sum(w * 10 for w in range(6))


def test_ring_wrap_keeps_newest():
    ring = obs_recorder.ring_init(4, _fake_state(), (), (3,), 4)
    rows = obs_recorder.ring_rows(_write(ring, 6))
    # flight-recorder semantics: the most recent `depth` windows survive
    assert [r["window"] for r in rows] == [2, 3, 4, 5]
    assert all(r["overwritten"] == 2 for r in rows)
    with pytest.raises(ValueError, match="wrapped"):
        obs_recorder.counter_totals(rows)


def test_ring_depth_validation():
    with pytest.raises(ValueError):
        obs_recorder.ring_init(0, _fake_state(), (), (3,), 4)


# -- faults -> events --------------------------------------------------------

def test_fault_transitions_and_labels():
    dims = (2, 2, 2)
    sched = fabric_faults.link_fault(dims, 12, 0, 0, start=4, stop=9)
    evs = fabric_faults.transitions(sched)
    downs = [e for e in evs if e["event"] == "link_down"]
    ups = [e for e in evs if e["event"] == "link_up"]
    assert downs and downs[0]["window"] == 4
    assert ups and ups[0]["window"] == 9
    lbl = fabric_faults.link_label(dims, downs[0]["links"][0])
    assert lbl[0] == "n" and lbl[-2] in "xyz" and lbl[-1] in "+-"


# -- run directory + report --------------------------------------------------

def _synthetic_run_dir(tmp_path):
    dims = (2, 1, 1)
    k = int(np.prod(dims)) * 2 * len(dims)
    ring = obs_recorder.ring_init(8, _fake_state(k), (2,), (2, 3), k)
    st = _fake_state(k)
    for w in range(6):
        ring = obs_recorder.record(
            ring, w,
            _Stats(*(jnp.full((2,), w + i, jnp.int32) for i in range(9))),
            st, jnp.full((2, 3), w, jnp.int32))
    sched = fabric_faults.link_fault(dims, 6, 0, 0, start=2, stop=5)
    tenants = [
        {"tenant": "quiet", "reserve": 8, "rate_epw": 10.0,
         "guaranteed_epw": 20.0, "injected": 100, "delivered": 100,
         "shed": 0, "clipped": 0, "p50_us": 2.0, "p99_us": 4.0,
         "max_us": 8.0, "mean_us": 2.5, "hist": [10, 80, 10]},
        {"tenant": "hot", "reserve": 4, "rate_epw": 100.0,
         "guaranteed_epw": 10.0, "injected": 500, "delivered": 420,
         "shed": 80, "clipped": 7, "p50_us": 64.0, "p99_us": 512.0,
         "max_us": 900.0, "mean_us": 120.0, "hist": [1, 200, 219]},
    ]
    reg = obs_metrics.Registry()
    reg.gauge("engine_events_per_s", "T.").set(1000.0)
    return obs_report.write_run_dir(
        str(tmp_path / "run"),
        meta={"kind": "serve", "dims": list(dims), "n_shards": 2,
              "windows": 6, "window_us": 100.0},
        recorder_rows=obs_recorder.ring_rows(ring),
        fault_events=fabric_faults.transitions(sched),
        tenant_rows=tenants, registry=reg)


def test_report_structured_output(tmp_path):
    run_dir = _synthetic_run_dir(tmp_path)
    rep = obs_report.build_report(run_dir)
    # the fault lands on the right timeline row
    by_w = {e["window"]: e for e in rep["timeline"]}
    assert any(ev["event"] == "link_down" for ev in by_w[2]["events"])
    assert any(ev["event"] == "link_up" for ev in by_w[5]["events"])
    assert all(lbl.startswith("n") for ev in by_w[2]["events"]
               for lbl in ev["labels"])
    # rerouted deliveries and per-tenant p99 ride the same rows
    assert by_w[3]["rerouted"] == (3 + 8) * 2      # _Stats field 8, T=2
    assert set(by_w[3]["p99_us"]) == {"quiet", "hot"}
    # tenants gain the SLO burn block
    slo = {t["tenant"]: t["slo"] for t in rep["tenants"]}
    assert slo["quiet"]["overcommit"] == pytest.approx(0.5)
    assert slo["hot"]["overcommit"] == pytest.approx(10.0)
    assert slo["hot"]["delivered_ratio"] == pytest.approx(420 / 500)
    assert rep["totals"]["rerouted"] == sum(
        e["rerouted"] for e in rep["timeline"])
    # and the human rendering mentions all of it
    text = obs_report.render(rep)
    assert "link_down" in text and "quiet" in text and "p99[hot]" in text


def test_report_cli_json(tmp_path, capsys):
    run_dir = _synthetic_run_dir(tmp_path)
    obs_report.main([run_dir, "--json"])
    rep = json.loads(capsys.readouterr().out)
    assert rep["meta"]["kind"] == "serve"
    assert len(rep["timeline"]) == 6
    obs_report.main([run_dir])
    assert "window timeline" in capsys.readouterr().out


def test_report_requires_meta(tmp_path):
    with pytest.raises(FileNotFoundError):
        obs_report.build_report(str(tmp_path))


# -- committed trace artifact ------------------------------------------------

def test_committed_trace_artifact_is_valid():
    """docs/observability_trace.json (written by tools/trace_smoke.py) must
    stay Perfetto-loadable: parses, monotonic per track, one span per
    engine thread, window instants carrying the device window indices the
    flight recorder stamps its rows with."""
    path = os.path.join(ROOT, "docs", "observability_trace.json")
    with open(path) as f:
        trace = json.load(f)
    assert obs_spans.validate_trace(trace) == []
    names = obs_spans.thread_names(trace)
    tracks = {}
    windows = []
    for ev in trace["traceEvents"]:
        if ev.get("ph") in ("X", "i"):
            tracks.setdefault(names.get(ev.get("tid", 0), "?"), 0)
            tracks[names[ev["tid"]]] += 1
            if ev.get("name") == "window":
                windows.append(ev["args"]["window"])
    for track in ("spike-ingest", "spike-device", "device"):
        assert tracks.get(track, 0) >= 1, (track, tracks)
    assert windows == sorted(windows) and len(windows) >= 2


# -- engine integration (1-shard, in-process) --------------------------------

def _make_instrumented_engine(seed=3):
    from jax.sharding import Mesh
    from repro.serve.loadgen import PoissonLoadGen, TenantProfile
    from repro.serve.spike_engine import EngineConfig, SpikeEngine
    from repro.serve.tenancy import TenantSpec
    mesh = Mesh(np.array(jax.devices()[:1]), ("w",))
    tenants = [TenantSpec("a", reserve=8, rate_epw=10.0),
               TenantSpec("b", reserve=4, rate_epw=30.0)]
    cfg = EngineConfig(capacity=8, link_credits=16, seg_windows=3,
                       nx=1, ny=1, nz=1)
    src = PoissonLoadGen(seed, [TenantProfile("a", 10.0),
                                TenantProfile("b", 30.0)], 1, cfg.capacity)
    return SpikeEngine(mesh, "w", tenants, cfg, src,
                       recorder=obs_recorder.RecorderConfig(depth=32),
                       tracer=obs_spans.Tracer())


@pytest.mark.timeout(300)
def test_engine_recorder_conserves_and_correlates(tmp_path):
    eng = _make_instrumented_engine()
    rep = eng.run(4)
    # ring totals == ledger totals, bit-exact per tenant
    totals = obs_recorder.counter_totals(eng.recorder_rows())
    assert np.array_equal(totals["delivered_events"], rep.delivered)
    assert totals["offered_events"].sum() >= totals["delivered_events"].sum()
    # the trace validates and the host spans carry the device windows
    trace = eng.tracer.to_dict()
    assert obs_spans.validate_trace(trace) == []
    win_in_trace = sorted(ev["args"]["window"]
                          for ev in trace["traceEvents"]
                          if ev.get("name") == "window")
    win_in_ring = [r["window"] for r in eng.recorder_rows()]
    assert set(win_in_trace) <= set(win_in_ring)
    assert len(win_in_trace) == rep.windows + rep.drain_windows
    # the assembled run directory reports the same story
    run_dir = obs_report.write_engine_run(str(tmp_path / "run"), eng, rep)
    built = obs_report.build_report(run_dir)
    assert built["totals"]["delivered_events"] == int(rep.delivered.sum())
    assert {t["tenant"] for t in built["tenants"]} == {"a", "b"}
    assert os.path.exists(os.path.join(run_dir, "trace.json"))
    parsed = obs_metrics.parse_prometheus(
        open(os.path.join(run_dir, "metrics.prom")).read())
    assert parsed["tenant_delivered_events_total"][
        frozenset({("tenant", "a")})] == float(rep.delivered[0])


@pytest.mark.timeout(300)
def test_engine_determinism_unchanged_by_recorder():
    """The instrumented engine serves the EXACT same traffic outcome as an
    uninstrumented one on the same seed — the recorder observes, it never
    perturbs."""
    from jax.sharding import Mesh
    from repro.serve.loadgen import PoissonLoadGen, TenantProfile
    from repro.serve.spike_engine import EngineConfig, SpikeEngine
    from repro.serve.tenancy import TenantSpec
    mesh = Mesh(np.array(jax.devices()[:1]), ("w",))
    tenants = [TenantSpec("a", reserve=8, rate_epw=10.0),
               TenantSpec("b", reserve=4, rate_epw=30.0)]
    cfg = EngineConfig(capacity=8, link_credits=16, seg_windows=3,
                       nx=1, ny=1, nz=1)

    def run(recorder):
        src = PoissonLoadGen(11, [TenantProfile("a", 10.0),
                                  TenantProfile("b", 30.0)], 1,
                             cfg.capacity)
        return SpikeEngine(mesh, "w", tenants, cfg, src,
                           recorder=recorder).run(3)

    plain = run(None)
    rec = run(obs_recorder.RecorderConfig(depth=32))
    assert np.array_equal(plain.injected, rec.injected)
    assert np.array_equal(plain.delivered, rec.delivered)
    assert np.array_equal(plain.shed, rec.shed)
    for d1, d2 in zip(plain.tenants, rec.tenants):
        assert np.array_equal(d1.hist, d2.hist)


# -- old batched engine span smoke -------------------------------------------

@pytest.mark.timeout(300)
def test_old_engine_emits_serve_spans():
    from repro.configs import get_config, reduced
    from repro.models import build
    from repro.serve.engine import Engine, Request, ServeConfig
    cfg = reduced(get_config("qwen15_4b"))
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    tr = obs_spans.Tracer()
    eng = Engine(m, ServeConfig(slots=2, max_len=64, max_new_tokens=4),
                 tracer=tr)
    out = eng.generate_batch(params, [
        Request(rid=0, prompt=np.array([5, 6, 7], np.int32)),
        Request(rid=1, prompt=np.array([9, 10], np.int32))])
    assert set(out) == {0, 1}
    d = tr.to_dict()
    assert obs_spans.validate_trace(d) == []
    names = [e["name"] for e in d["traceEvents"] if e["ph"] == "X"]
    assert "serve/prefill" in names and "serve/decode" in names
    dec = [e for e in d["traceEvents"] if e["name"] == "serve/decode"]
    assert all(e["args"]["tokens"] >= 0 for e in dec)


# -- disabled-path pins (subprocess: needs >1 device) ------------------------

def test_sim_carry_structure_disabled():
    from repro.snn.simulator import SimCarry
    # trailing ring=None is leafless: the disabled carry IS the 3-tuple
    assert (jax.tree_util.tree_structure(SimCarry(1, 2, 3))
            == jax.tree_util.tree_structure(SimCarry(1, 2, 3, None)))
    assert jax.tree_util.tree_leaves(SimCarry(1, 2, 3)) == [1, 2, 3]


@pytest.mark.slow
def test_disabled_path_hlo_pinned():
    out = run_md(r"""
import numpy as np, jax
from jax.sharding import Mesh
from repro.obs import recorder as obs_recorder
from repro.serve.loadgen import PoissonLoadGen, TenantProfile
from repro.serve.spike_engine import EngineConfig, SpikeEngine
from repro.serve.tenancy import TenantSpec

mesh = Mesh(np.array(jax.devices()[:4]), ("w",))
cfg = EngineConfig(capacity=8, link_credits=16, notify_latency=2,
                   window_us=100.0, seg_windows=3, nx=2, ny=2, nz=1)
tenants = [TenantSpec("a", reserve=8, rate_epw=16.0),
           TenantSpec("b", reserve=4, rate_epw=8.0)]

def build(recorder):
    src = PoissonLoadGen(3, [TenantProfile("a", 16.0),
                             TenantProfile("b", 8.0)], 4, cfg.capacity)
    return SpikeEngine(mesh, "w", tenants, cfg, src, recorder=recorder)

def hlo(eng):
    return eng._seg.lower(*eng._carry, eng._zero_fw, eng._zero_fc,
                          0).as_text()

off1, off2 = build(None), build(None)
assert len(off1._carry) == 4, "disabled carry grew"
txt1, txt2 = hlo(off1), hlo(off2)
assert txt1 == txt2, "disabled lowering is not deterministic"
assert "telemetry" not in txt1.lower()

on = build(obs_recorder.RecorderConfig(depth=16))
assert len(on._carry) == 5, "enabled carry must add exactly the ring"
assert hlo(on) != txt1, "recorder ring was DCE'd out of the program"
print("HLO_PIN_OK", len(txt1))
""", n_devices=4)
    assert "HLO_PIN_OK" in out


@pytest.mark.slow
def test_recorder_conservation_all_backends():
    """Ring counter totals must be bit-identical to the end-of-run
    ``LinkStats`` on every transport backend, and the instrumented run's
    stats must equal the uninstrumented run's (observer effect = 0)."""
    out = run_md(r"""
import jax, numpy as np
from repro import obs
from repro.snn import microcircuit as mc, network, simulator as sim

spec = mc.MicrocircuitSpec(scale=0.003)
w, is_inh = spec.weight_matrix()
part = network.build_partition(w, is_inh, n_shards=8)
mesh = jax.make_mesh((8,), ("wafer",))
N_WIN = 6
for transport in ("alltoall", "torus2d", "torus3d"):
    kw = {}
    if transport != "alltoall":
        kw = dict(torus_nx=2, torus_ny=4 if transport == "torus2d" else 2,
                  link_credits=32, notify_latency=2)
        if transport == "torus3d":
            kw.update(torus_ny=2, torus_nz=2)
    cfg = sim.SimConfig(n_shards=8, per_shard=part.per_shard,
                        max_fan=part.fanout.shape[1], window=8,
                        ring_len=32, e_max=512, capacity=32,
                        transport=transport, **kw)
    args = (mesh, "wafer", cfg, part, spec.bg_rates())
    init_p, run_p = sim.build_sharded_sim(*args)
    st_p, stats_p = run_p(init_p(0), N_WIN)
    init_r, run_r = sim.build_sharded_sim(
        *args, recorder=obs.RecorderConfig(depth=16))
    st_r, stats_r, ring = run_r(init_r(0), N_WIN)
    sp = jax.tree_util.tree_map(np.asarray, stats_p)
    sr = jax.tree_util.tree_map(np.asarray, stats_r)
    # zero observer effect: instrumented == uninstrumented, bit-exact
    for f in obs.COUNTER_FIELDS:
        assert (getattr(sp.link, f) == getattr(sr.link, f)).all(), \
            (transport, f)
    assert (np.asarray(st_p.neuron.v) == np.asarray(st_r.neuron.v)).all()
    # per-shard ring totals == per-shard LinkStats totals, bit-exact
    for s in range(8):
        tot = obs.counter_totals(
            obs.ring_rows(obs.ring_shard(ring, s)))
        for f in obs.COUNTER_FIELDS:
            want = int(getattr(sr.link, f)[s].sum())
            assert int(tot[f]) == want, (transport, s, f)
    # stall attribution sums to the global deferred total (torus+credits)
    rows = obs.global_rows(ring, 8)
    sbl = sum(int(np.asarray(r["stalled_by_link"]).sum()) for r in rows)
    defr = int(sr.link.deferred_events.sum())
    if transport != "alltoall":
        assert sbl == defr, (transport, sbl, defr)
    print(transport, "OK", defr)
print("CONSERVATION_OK")
""")
    assert "CONSERVATION_OK" in out
