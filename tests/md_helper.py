"""Run multi-device JAX snippets in a subprocess with forced host devices.

Tests must NOT set ``xla_force_host_platform_device_count`` globally (the
rest of the suite should see one device), so anything needing a mesh runs
through here.
"""
from __future__ import annotations

import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_md(code: str, n_devices: int = 8, timeout: int = 600) -> str:
    """Execute ``code`` with N fake devices; returns stdout; raises on rc!=0."""
    # append to (not clobber) caller flags so tools/env.sh tuning survives
    prelude = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = "
        f"'--xla_force_host_platform_device_count={n_devices} '"
        f" + os.environ.get('XLA_FLAGS', '')\n"
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", prelude + code],
        capture_output=True, text=True, timeout=timeout, env=env)
    if out.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}")
    return out.stdout
