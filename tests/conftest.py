import os
import sys

sys.path.insert(0, os.path.dirname(__file__))          # prop / md_helper

def pytest_configure(config):
    config.addinivalue_line("markers", "slow: multi-device subprocess tests")
