import faulthandler
import os
import sys

import pytest

sys.path.insert(0, os.path.dirname(__file__))          # prop / md_helper


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: multi-device subprocess tests and the aggregate_sort "
        "argsort cross-check oracles (CI fast tier runs -m 'not slow'; "
        "a plain local `python -m pytest` still runs everything)")
    config.addinivalue_line(
        "markers",
        "timeout(seconds): hard wall-clock limit for the test call. "
        "Required on every test that starts threads (the serve engine's "
        "ingest/device loops): a deadlocked queue join would otherwise "
        "hang the whole suite. Implemented with "
        "faulthandler.dump_traceback_later (pytest-timeout is not a "
        "dependency): on expiry every thread's traceback is dumped and "
        "the process exits hard.")


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_call(item):
    marker = item.get_closest_marker("timeout")
    if marker is not None:
        seconds = float(marker.args[0]) if marker.args else 300.0
        faulthandler.dump_traceback_later(seconds, exit=True)
    try:
        yield
    finally:
        if marker is not None:
            faulthandler.cancel_dump_traceback_later()
