import os
import sys

sys.path.insert(0, os.path.dirname(__file__))          # prop / md_helper

def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: multi-device subprocess tests and the aggregate_sort "
        "argsort cross-check oracles (CI fast tier runs -m 'not slow'; "
        "a plain local `python -m pytest` still runs everything)")
