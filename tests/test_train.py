"""Training stack tests: optimizer math, schedules, loss chunking,
microbatching, trainer fault tolerance, data pipeline."""
import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.data.pipeline import DataConfig, RingPrefetcher, synthetic_batch
from repro.models import build
from repro.models.transformer import Runtime
from repro.train import optimizer as opt
from repro.train.step import (TrainConfig, chunked_xent, init_train_state,
                              make_train_step)
from repro.train.trainer import Trainer, TrainerConfig

from prop import draw, given


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_matches_reference_math():
    cfg = opt.OptimizerConfig(
        b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0, clip_norm=1e9,
        schedule=opt.ScheduleConfig(kind="constant", peak_lr=0.1,
                                    warmup_steps=0))
    p = {"w": jnp.asarray([[1.0, 2.0]])}
    g = {"w": jnp.asarray([[0.5, -0.5]])}
    st = opt.adamw_init(p)
    newp, st, _ = opt.adamw_update(g, st, p, cfg)
    m = 0.1 * 0.5
    v = 0.01 * 0.25
    mh, vh = m / 0.1, v / 0.01
    want = 1.0 - 0.1 * mh / (np.sqrt(vh) + 1e-8)
    np.testing.assert_allclose(np.asarray(newp["w"])[0, 0], want, rtol=1e-5)


def test_adamw_converges_quadratic():
    cfg = opt.OptimizerConfig(
        weight_decay=0.0, clip_norm=10.0,
        schedule=opt.ScheduleConfig(kind="constant", peak_lr=0.05,
                                    warmup_steps=0))
    p = {"w": jnp.asarray([3.0, -2.0])}
    st = opt.adamw_init(p)
    for _ in range(300):
        g = {"w": 2 * p["w"]}
        p, st, _ = opt.adamw_update(g, st, p, cfg)
    assert float(jnp.abs(p["w"]).max()) < 1e-2


def test_adafactor_converges_quadratic():
    cfg = opt.OptimizerConfig(
        kind="adafactor", weight_decay=0.0, clip_norm=10.0,
        schedule=opt.ScheduleConfig(kind="constant", peak_lr=0.05,
                                    warmup_steps=0))
    p = {"w": jnp.ones((4, 3)) * 2.0}
    st = opt.adafactor_init(p, cfg)
    for _ in range(300):
        g = {"w": 2 * p["w"]}
        p, st, _ = opt.adafactor_update(g, st, p, cfg)
    assert float(jnp.abs(p["w"]).max()) < 5e-2


def test_adafactor_memory_is_factored():
    cfg = opt.OptimizerConfig(kind="adafactor", momentum_dtype="bfloat16")
    p = {"w": jnp.zeros((128, 64))}
    st = opt.adafactor_init(p, cfg)
    assert st.vr["w"].shape == (128,)
    assert st.vc["w"].shape == (64,)
    assert st.m["w"].dtype == jnp.bfloat16


def test_clip_by_global_norm():
    g = {"a": jnp.asarray([3.0, 4.0])}        # norm 5
    clipped, norm = opt.clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 5.0) < 1e-6
    np.testing.assert_allclose(np.asarray(clipped["a"]), [0.6, 0.8], rtol=1e-5)


def test_schedules():
    wsd = opt.ScheduleConfig(kind="wsd", peak_lr=1.0, warmup_steps=10,
                             total_steps=100, decay_frac=0.2, min_ratio=0.1)
    assert float(opt.learning_rate(wsd, 0)) == 0.0
    assert abs(float(opt.learning_rate(wsd, 10)) - 1.0) < 1e-6
    assert abs(float(opt.learning_rate(wsd, 50)) - 1.0) < 1e-6   # stable
    assert float(opt.learning_rate(wsd, 99)) < 0.2               # decaying
    cos = opt.ScheduleConfig(kind="cosine", peak_lr=1.0, warmup_steps=0,
                             total_steps=100, min_ratio=0.0)
    assert abs(float(opt.learning_rate(cos, 100))) < 1e-6


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

@given(n_cases=8, b=draw.ints(1, 3), s=draw.ints(2, 8), v=draw.ints(8, 64),
       seed=draw.ints(0, 1000))
def test_chunked_xent_equals_full(b, s, v, seed):
    s = s * 4                                   # divisible by chunk=4
    cfg = reduced(get_config("qwen3_32b"))
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    hidden = jax.random.normal(k1, (b, s, cfg.d_model), jnp.float32)
    labels = jax.random.randint(k2, (b, s), 0, cfg.vocab)
    params = {"lm_head": jax.random.normal(
        k3, (cfg.d_model, cfg.vocab)) * 0.02}
    nll, _ = chunked_xent(params, hidden, labels, cfg, Runtime(), chunk=4)
    logits = hidden @ params["lm_head"]
    lse = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    want = jnp.mean(lse - gold)
    np.testing.assert_allclose(float(nll), float(want), rtol=2e-4, atol=2e-4)


def test_chunked_xent_label_mask():
    cfg = reduced(get_config("qwen3_32b"))
    hidden = jnp.ones((1, 8, cfg.d_model))
    params = {"lm_head": jnp.ones((cfg.d_model, cfg.vocab)) * 0.01}
    labels = jnp.full((1, 8), -1)
    nll, _ = chunked_xent(params, hidden, labels.at[0, 0].set(3), cfg,
                          Runtime(), chunk=4)
    assert np.isfinite(float(nll))


def test_microbatch_equivalence():
    """Gradient accumulation over 2 microbatches == full-batch gradients."""
    cfg = reduced(get_config("minicpm_2b"))
    m = build(cfg)
    rt = Runtime()
    base = TrainConfig(microbatch=0)
    micro = TrainConfig(microbatch=2)
    state = init_train_state(m, jax.random.PRNGKey(0), base)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                          cfg.vocab)}
    batch["labels"] = batch["tokens"]
    s1, m1 = jax.jit(make_train_step(m, base, rt))(state, batch)
    state2 = init_train_state(m, jax.random.PRNGKey(0), base)
    s2, m2 = jax.jit(make_train_step(m, micro, rt))(state2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-3)
    a = jax.tree_util.tree_leaves(s1["params"])
    b = jax.tree_util.tree_leaves(s2["params"])
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=3e-2, atol=3e-4)


# ---------------------------------------------------------------------------
# trainer: loss goes down, crash/restart resumes exactly
# ---------------------------------------------------------------------------

def test_trainer_loss_decreases_and_resumes():
    cfg = reduced(get_config("minicpm_2b"))
    m = build(cfg)
    tcfg = TrainConfig(optimizer=opt.OptimizerConfig(
        schedule=opt.ScheduleConfig(kind="wsd", peak_lr=3e-3, warmup_steps=5,
                                    total_steps=40)))
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4)
    d = tempfile.mkdtemp()
    try:
        tr = Trainer(m, tcfg, dcfg,
                     TrainerConfig(steps=20, ckpt_dir=d, ckpt_every=10,
                                   log_every=5))
        state, hist = tr.run(seed=0)
        assert hist[-1]["loss"] < hist[0]["loss"]
        # crash at step 25 after checkpoint at 20
        tr2 = Trainer(m, tcfg, dcfg,
                      TrainerConfig(steps=40, ckpt_dir=d, ckpt_every=10,
                                    log_every=5, fail_at_step=25))
        with pytest.raises(RuntimeError):
            tr2.run(seed=0)
        # restart resumes from 20 and completes
        tr3 = Trainer(m, tcfg, dcfg,
                      TrainerConfig(steps=40, ckpt_dir=d, ckpt_every=10,
                                    log_every=5))
        state3, _ = tr3.run(seed=0)
        assert int(np.asarray(state3["step"])) == 40
    finally:
        shutil.rmtree(d, ignore_errors=True)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_synthetic_batch_deterministic():
    dc = DataConfig(vocab=100, seq_len=16, global_batch=2)
    b1 = synthetic_batch(dc, 7)
    b2 = synthetic_batch(dc, 7)
    assert (np.asarray(b1["tokens"]) == np.asarray(b2["tokens"])).all()
    b3 = synthetic_batch(dc, 8)
    assert not (np.asarray(b1["tokens"]) == np.asarray(b3["tokens"])).all()
    # labels are next-token shifted
    assert b1["tokens"].shape == (2, 16) and b1["labels"].shape == (2, 16)


def test_prefetcher_order_and_credits():
    dc = DataConfig(vocab=100, seq_len=8, global_batch=2, ring_slots=2)
    pf = RingPrefetcher(dc, start_step=5)
    try:
        steps = [pf.next()[0] for _ in range(6)]
        assert steps == [5, 6, 7, 8, 9, 10]
        st = pf.stats()
        assert st["consumed"] == 6
        assert st["in_flight"] <= 2               # credit bound respected
    finally:
        pf.close()
