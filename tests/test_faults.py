"""Fault-injection layer: schedules, host routing oracles, reroute-around.

Three tiers:

* **Host oracles** (plain numpy, no devices) — ``repro.fabric.faults``
  schedule constructors are deterministic and shaped right, and the
  ``core.torus`` detour helpers agree with the primary router:
  ``route_links_detour`` with no flips IS ``route_links``, and
  ``route_links_avoiding`` never routes through a dead link.
* **Fast-tier smoke** (4 devices, runs in the default ``not slow``
  tier) — one deterministic single-link-down case on a 2x2 torus:
  conservation holds, the fabric detours (``rerouted > 0``), the drain
  walks empty and every credit comes home.
* **Slow liveness property** (8 devices) — with one permanently-dead
  cable and ample credits, EVERY offered event is delivered the same
  window via a detour (none lost, none stuck), the dead cable is never
  spent, and ``rerouted > 0`` is pinned on both torus2d and torus3d.

The transport-level *chaos* sweep (a cable killed every window) lives
with the rest of the invariant fuzz in ``test_fabric_fuzz.py``; the
engine-level mid-segment link death is in ``test_serve_engine.py``.
"""
import numpy as np
import pytest

from md_helper import run_md


# -- host oracles (no devices) ----------------------------------------------

def test_fault_schedule_constructors():
    from repro.fabric import (chaos, healthy, link_fault, link_flap,
                              n_fabric_links, node_fault)
    dims = (2, 2, 2)
    K = n_fabric_links(dims)
    assert K == 8 * 6

    h = healthy(dims, 4)
    assert h.link_down.shape == (4, K) and not np.asarray(h.link_down).any()

    lf = np.asarray(link_fault(dims, 6, 0, 0, start=2, stop=5).link_down)
    assert (lf.sum(1) == [0, 0, 2, 2, 2, 0]).all()   # one cable = 2 links

    fl = np.asarray(link_flap(dims, 8, 0, 0, period=2).link_down)
    assert (fl.sum(1) == [2, 2, 0, 0, 2, 2, 0, 0]).all()

    nf = np.asarray(node_fault(dims, 4, 3, start=1).link_down)
    # 6 incident cables, each killing both directed channels
    assert nf[0].sum() == 0 and (nf[1:].sum(1) == 12).all()

    c1, c2 = chaos(dims, 16, seed=5), chaos(dims, 16, seed=5)
    assert (np.asarray(c1.link_down) == np.asarray(c2.link_down)).all()
    assert c1.link_down.shape == (16, K)
    # every window has at least the freshly-killed cable down
    assert (np.asarray(c1.link_down).sum(1) >= 2).all()
    # a different seed gives a different run
    assert (np.asarray(chaos(dims, 16, seed=6).link_down)
            != np.asarray(c1.link_down)).any()


def test_mask_at_clamps_to_schedule():
    import jax.numpy as jnp
    from repro.fabric import link_fault, mask_at
    sched = link_fault((2, 2), 4, 0, 0, start=3)
    assert not np.asarray(mask_at(sched, 0)).any()
    assert np.asarray(mask_at(sched, 3)).sum() == 2
    # windows past the table clamp to the last row: permanent stays dead
    assert np.asarray(mask_at(sched, jnp.int32(99))).sum() == 2


def test_cable_links_pairs_reverse_channel():
    from repro.fabric import cable_links, link_id
    dims = (2, 2, 2)
    for node in range(8):
        for direction in range(6):
            a, b = cable_links(dims, node, direction)
            assert a == link_id(dims, node, direction)
            # the cable is symmetric: the neighbor's reverse channel
            # names the same physical cable from the other end
            v, rdir = b // 6, b % 6
            assert cable_links(dims, v, rdir) == (b, a)


def test_route_links_detour_no_flips_is_primary_route():
    from repro.core.torus import Torus
    for torus, n in [(Torus(2, 4, 1), 8), (Torus(2, 2, 2), 8)]:
        for s in range(n):
            for d in range(n):
                assert (torus.route_links_detour(s, d)
                        == torus.route_links(s, d)), (s, d)


def test_route_links_avoiding_never_uses_dead_links():
    from repro.core.torus import Torus
    from repro.fabric import cable_links
    rng = np.random.default_rng(0)
    torus, dims = Torus(2, 2, 2), (2, 2, 2)
    found_detour = False
    for _ in range(200):
        down = set()
        for _ in range(int(rng.integers(0, 3))):
            node = int(rng.integers(0, 8))
            direction = int(rng.integers(0, 6))
            for l in cable_links(dims, node, direction):
                down.add((l // 6, l % 6))
        s, d = int(rng.integers(0, 8)), int(rng.integers(0, 8))
        got = torus.route_links_avoiding(s, d, down)
        if got is None:
            continue
        links, flips = got
        assert not any(l in down for l in links), (s, d, links)
        found_detour = found_detour or any(flips)
    assert found_detour, "sweep never exercised a long-way detour"


# -- fast-tier smoke: deterministic single link down (4 devices) -------------

def test_single_link_down_smoke():
    """One cable dies on a 2x2 torus at window 1: traffic detours the
    long way around its ring, conservation and the credit-unit identity
    hold every window, and the post-run drain leaves an empty fabric.
    Deterministic (fixed traffic seed + static schedule); runs in the
    fast tier as the belt for the slow chaos sweep."""
    out = run_md(r"""
import functools
import jax, jax.numpy as jnp, numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P
from repro import transport
from repro.fabric import link_fault, mask_at
from repro.serve.loadgen import traffic_rng, draw_counts

n, W, n_win, credits = 4, 4, 8, 8
t = transport.create("torus2d", n_shards=n, nx=2, ny=2,
                     link_credits=credits, notify_latency=2)
sched = link_fault((2, 2), n_win, 0, 0, start=1)
mesh = Mesh(np.array(jax.devices()[:n]), ("w",))

@functools.partial(shard_map, mesh=mesh, in_specs=(P("w"), P("w")),
                   out_specs=P("w"), check_rep=False)
def body(counts, win_ids):
    state = t.init_state(payload_width=W)
    def step(state, x):
        cnt, w = x
        st = state._replace(link_down=mask_at(sched, w))
        out = t.exchange(st, jnp.zeros((n, W), jnp.uint32), cnt,
                         axis_name="w")
        return out.state, out.stats
    state, stats = jax.lax.scan(step, state, (counts[0], win_ids[0]))
    dr = t.drain_fabric(state, axis_name="w")
    return jax.tree.map(lambda x: x[None],
                        (stats, dr.recv_counts, dr.state))

rng = traffic_rng(11)
counts = np.stack([draw_counts(rng, (n, n), 7) for _ in range(n_win)])
counts = jnp.asarray(counts.transpose(1, 0, 2))          # (n, n_win, n)
win_ids = jnp.tile(jnp.arange(n_win)[None], (n, 1))
stats, drc, dstate = jax.tree.map(np.asarray,
                                  jax.jit(body)(counts, win_ids))

assert (stats.offered_events == stats.sent_events
        + stats.deferred_events + stats.parked_events).all()
delivered = int(stats.delivered_events.sum()) + int(drc.sum())
sent_all = int(stats.sent_events.sum() + stats.unparked_events.sum()
               + drc.sum())
assert delivered == sent_all
assert int(stats.rerouted.sum()) > 0, "no detour around the dead cable"
assert (dstate.parked_count == 0).all()
assert (dstate.parked_by_link == 0).all()
assert (dstate.bank.credits[0] + dstate.bank.pending[0].sum(-1)
        == credits).all()
print("delivered=%d rerouted=%d" % (delivered, int(stats.rerouted.sum())))
print("SINGLE_LINK_DOWN_OK")
""", n_devices=4, timeout=600)
    assert "SINGLE_LINK_DOWN_OK" in out


# -- slow liveness property (8 devices, both backends) -----------------------

@pytest.mark.slow
def test_liveness_dead_link_ample_credits():
    """The reroute-around liveness claim: one permanently-dead cable +
    ample credits => every offered event is delivered in its own window
    via a detour — nothing defers, parks, or gets lost — the dead
    cable's credit slots are never touched, and ``rerouted > 0`` is
    pinned.  Both torus2d and torus3d."""
    out = run_md(r"""
import functools
import jax, jax.numpy as jnp, numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P
from repro import transport
from repro.fabric import cable_links, link_fault, mask_at
from repro.serve.loadgen import traffic_rng, draw_counts

D, W, n_win = 8, 4, 6
AMPLE = 1 << 16
mesh = Mesh(np.array(jax.devices()[:D]), ("w",))

for name, dims, opts in [("torus2d", (2, 4), dict(nx=2, ny=4)),
                         ("torus3d", (2, 2, 2), dict(nx=2, ny=2, nz=2))]:
    t = transport.create(name, n_shards=D, link_credits=AMPLE,
                         notify_latency=2, **opts)
    sched = link_fault(dims, n_win, 0, 0)
    dead = list(cable_links(dims, 0, 0))

    @functools.partial(shard_map, mesh=mesh, in_specs=(P("w"), P("w")),
                       out_specs=P("w"), check_rep=False)
    def body(counts, win_ids):
        state = t.init_state(payload_width=W)
        def step(state, x):
            cnt, w = x
            st = state._replace(link_down=mask_at(sched, w))
            out = t.exchange(st, jnp.zeros((D, W), jnp.uint32), cnt,
                             axis_name="w")
            return out.state, out.stats
        state, stats = jax.lax.scan(step, state, (counts[0], win_ids[0]))
        return jax.tree.map(lambda x: x[None], (stats, state))

    rng = traffic_rng(23)
    counts = np.stack([draw_counts(rng, (D, D), 15) for _ in range(n_win)])
    counts = jnp.asarray(counts.transpose(1, 0, 2))
    win_ids = jnp.tile(jnp.arange(n_win)[None], (D, 1))
    stats, state = jax.tree.map(np.asarray, jax.jit(body)(counts, win_ids))

    # liveness: with ample credits the detour admits everything — every
    # offered event is delivered the window it was offered
    assert (stats.sent_events == stats.offered_events).all()
    assert stats.deferred_events.sum() == 0
    assert stats.parked_events.sum() == 0
    assert (stats.delivered_events.sum(0)
            == stats.sent_events.sum(0)).all()
    rer = int(stats.rerouted.sum())
    assert rer > 0, name + ": no detours despite a dead cable"
    # the dead cable is never spent: its credit slots sit untouched
    assert (state.bank.credits[0, dead] == AMPLE).all()
    assert (state.bank.pending[0, dead] == 0).all()
    print("%s: delivered=%d rerouted=%d" %
          (name, int(stats.delivered_events.sum()), rer))
print("LIVENESS_OK")
""", timeout=1200)
    assert "LIVENESS_OK" in out
