"""Sharding-rule tests (host-side; no forced device count needed — we build
pspecs against a fake mesh description via jax.sharding.Mesh on 1 device is
impossible, so we exercise `axes_to_pspec` with a stub mesh object)."""
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd


class FakeMesh:
    """Duck-typed mesh: only axis_names + devices.shape are consulted."""

    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.empty(shape, dtype=object)


MESH = FakeMesh((16, 16), ("data", "model"))
MESH3 = FakeMesh((2, 16, 16), ("pod", "data", "model"))


def spec(axes, shape, mesh=MESH):
    return shd.axes_to_pspec(axes, shape, mesh)


def test_mlp_weight_fsdp_plus_tp():
    # (layers, embed, mlp): embed->data (ZeRO), mlp->model (TP)
    assert spec(("layers", "embed", "mlp"), (64, 5120, 25600)) == \
        P(None, "data", "model")


def test_attention_heads_divisible():
    assert spec(("layers", "embed", "heads", "head_dim"),
                (64, 5120, 64, 128)) == P(None, "data", "model", None)


def test_kv_heads_not_divisible_stays_replicated_on_model():
    # kv=8 over model=16: no head sharding, no head_dim fallback
    s = spec(("layers", "embed", "kv_heads", "head_dim"), (64, 5120, 8, 128))
    assert s == P(None, "data", None, None)


def test_heads_not_divisible_falls_back_cleanly():
    # qwen1.5: 20 heads over 16 -> attention weights data-sharded only
    s = spec(("layers", "embed", "heads", "head_dim"), (40, 2560, 20, 128))
    assert s == P(None, "data", None, None)


def test_embedding_vocab_model():
    assert spec(("vocab", "embed"), (151936, 5120)) == P("model", "data")


def test_expert_weights():
    s = spec(("layers", "expert", "embed", "mlp"), (28, 64, 2048, 1408))
    assert s == P(None, "model", "data", None)      # mlp 1408/16=88 ok too?
    # 1408 % 16 == 0, but "data" already used by embed; mlp unused axes none


def test_batch_over_pod_and_data():
    s = spec(("batch", "seq"), (256, 4096), MESH3)
    assert s[0] == ("pod", "data")


def test_cache_seq_fallback():
    # kv=8 not divisible by model -> seq picks up the model axis
    s = spec(("layers", "batch", "seq", "kv_heads", "head_dim"),
             (42, 128, 32768, 8, 256))
    assert s == P(None, "data", "model", None, None)


def test_non_divisible_never_sharded():
    s = spec(("batch", None), (1, 1))
    assert s == P(None, None)


def test_bytes_per_device():
    import jax
    import jax.numpy as jnp
    mesh = jax.make_mesh((1,), ("model",))
    sds = {"a": jax.ShapeDtypeStruct((8, 8), jnp.float32)}
    from jax.sharding import NamedSharding
    sh = {"a": NamedSharding(mesh, P("model", None))}
    assert shd.bytes_per_device(sds, sh) == 8 * 8 * 4
