"""Cross-backend fabric invariant fuzz (subprocess, 8 forced host devices).

The transit-buffer fabric (PR 5) has a full invariant set that must hold
for EVERY window of EVERY configuration — this file sweeps it over a
seeded random matrix of traffic, credit budgets and topologies (the seed
matrix is fixed, so CI failures reproduce exactly):

* **conservation with parked** — ``offered == sent + deferred + parked``
  per shard+window; globally ``sum(sent) + sum(unparked) ==
  sum(delivered)``; in-fabric occupancy balances window to window.
* **credit-unit invariance** — ``credits + pending + parked_by_link``
  equals its initial per-link total after every window, through
  ``notify_latency`` 0 and 2, a zero-credit bank, and the end-of-run
  fabric walk + uncredited drain.
* **deferral attribution** — ``deferred == stalled_by_hop.sum()`` with
  every deferral at hop 0 (mid-route shortages park, they never re-enter
  at the source), and parked rows only ever wait at transit hops >= 1.
* **payload custody** — a row delivered N windows after it parked arrives
  bit-exact (the fabric's custody copy, not a re-offer), checked against
  a host-side ledger of every parked row.
* **latency accounting** — the simulator's per-window digest histogram
  counts exactly the delivered events under congestion (waiting + hops +
  queueing), and the queueing term vanishes on an uncontended fabric.

Case generation draws all randomness through the repo's single audited
traffic source, ``repro.serve.loadgen`` (``traffic_rng`` substreams +
``draw_counts``/``draw_payload``) — the same helpers the serving
engine's open-loop load generator uses, so fuzzers and load generation
cannot quietly diverge.  The sweep runs >= 200 seeded cases: 10 fabric
configurations x 20 traffic seeds, plus the simulator-level congestion
runs and the cross-backend equivalence pin (ample credits + empty
buffers => torus2d/torus3d bit-identical to alltoall, latency digests
equal to the hop-only charges — the queueing term contributes exactly
nothing — under the new FabricState carry).

The multi-tenant fabric (``TenantTorusTransport``) gets its own sweep:
per-(tenant, window) conservation, partitioned credit-slot invariance
(reserved slices + shared pool), cross-shard replication and clean
drain; and the serving engine's QoS isolation claim is pinned end to
end (quiet tenant's p99 contended vs solo on identical traffic).

**Chaos mode** (``repro.fabric.faults``): the same invariant set
with a seeded ``chaos`` schedule killing one random physical cable
EVERY window (each dead cable revives next window with p=0.5).  Two
invariants are *adapted* for fault mode — hop-0 parks become legal (an
evicted row whose detour retry also stalls re-parks at its source
holding nothing) and deferral gains the unroutable case (both ring
arcs dirty) — and two are *added*: dead links are frozen (nothing
parked on a dead link after the window it dies) and parked holds
balance exactly (``parked_by_link.sum() == parked_count[hop >= 1]``).
Credit conservation, custody bit-exactness and the clean drain are
unchanged: a fault may delay or detour an event, never corrupt or
leak it.
"""
import os

import pytest

from md_helper import run_md

pytestmark = pytest.mark.slow

TESTS_DIR = os.path.dirname(os.path.abspath(__file__))


def test_fabric_invariant_fuzz_transport_level():
    out = run_md(f"""
import sys
sys.path.insert(0, {TESTS_DIR!r})
""" + r"""
import functools
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro import transport
from repro.serve.loadgen import traffic_rng, draw_counts, draw_payload

D, W, WINDOWS = 8, 6, 3
SEEDS = 20
mesh = jax.make_mesh((D,), ("wafer",))
spec = P("wafer")
counts_of = lambda rng: draw_counts(rng, (D, D), 31)
payload_of = lambda rng: draw_payload(rng, (D, D, W))

def make_fns(t):
    def body(lstate, p, c, enforce):
        lstate = jax.tree_util.tree_map(lambda x: x[0], lstate)
        out = t.exchange(lstate, p[0], c[0], axis_name="wafer",
                         enforce_credits=enforce)
        return jax.tree_util.tree_map(
            lambda x: x[None],
            (out.state, out.recv_payload, out.recv_counts, out.sent_mask,
             out.sent_now, out.stats))
    def dbody(lstate):
        lstate = jax.tree_util.tree_map(lambda x: x[0], lstate)
        out = t.drain_fabric(lstate, axis_name="wafer")
        return jax.tree_util.tree_map(
            lambda x: x[None],
            (out.state, out.recv_payload, out.recv_counts, out.stats))
    mk = lambda enforce: jax.jit(shard_map(
        functools.partial(body, enforce=enforce), mesh=mesh,
        in_specs=(spec, spec, spec), out_specs=spec, check_rep=False))
    walk = jax.jit(shard_map(dbody, mesh=mesh, in_specs=(spec,),
                             out_specs=spec, check_rep=False))
    return mk(True), mk(False), walk

def fuzz_case(fns, t, seed, zero_bank):
    fn, fn_drain, fn_walk = fns
    rng = traffic_rng(seed)
    st0 = t.init_state(W)
    if zero_bank:
        st0 = st0._replace(bank=st0.bank._replace(
            credits=jnp.zeros_like(st0.bank.credits)))
    tot0 = (np.asarray(st0.bank.credits)
            + np.asarray(st0.bank.pending).sum(-1)
            + np.asarray(st0.parked_by_link))
    lstate = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (D,) + x.shape), st0)
    ledger = {}                     # (s, d) -> custody payload row
    pc_prev = np.zeros((D, D), np.int64)
    for win in range(WINDOWS):
        counts = jnp.asarray(counts_of(rng))
        payload = jnp.asarray(payload_of(rng).astype(np.uint32))
        lstate, rp, rcnt, mask, snow, st = fn(lstate, payload, counts)
        off = np.asarray(st.offered_events)
        sent = np.asarray(st.sent_events)
        defr = np.asarray(st.deferred_events)
        park = np.asarray(st.parked_events)
        unpark = np.asarray(st.unparked_events)
        infab = np.asarray(st.in_fabric_events)
        cm, pm = np.asarray(counts), np.asarray(payload)
        # conservation with parked
        assert (off == sent + defr + park).all()
        assert sent.sum() + unpark.sum() == np.asarray(
            st.delivered_events).sum() == np.asarray(rcnt).sum()
        # deferral attribution: hop-0 only; parked rows at hops >= 1
        sbh = np.asarray(st.stalled_by_hop)
        pbh = np.asarray(st.parked_by_hop)
        assert (sbh.sum(-1) == defr).all() and sbh[:, 1:].sum() == 0
        assert (pbh[:, 0] == 0).all()
        assert (pbh.sum(-1) == infab).all()
        held = np.where(np.asarray(mask), 0, cm).sum(1)
        assert (held == defr).all()
        # credit-unit invariance + replication of the global tables
        cr = np.asarray(lstate.bank.credits)
        pend = np.asarray(lstate.bank.pending)
        pbl = np.asarray(lstate.parked_by_link)
        pc = np.asarray(lstate.parked_count)
        ph = np.asarray(lstate.parked_hop)
        assert (cr >= 0).all() and (pbl >= 0).all() and (pc >= 0).all()
        assert (cr == cr[0]).all() and (pend == pend[0]).all()
        assert (pc == pc[0]).all() and (pbl == pbl[0]).all()
        assert (cr[0] + pend[0].sum(-1) + pbl[0] == tot0).all()
        # occupancy balance: parked in, unparked out
        assert (pc[0].sum(1) == pc_prev.sum(1) + park - unpark).all()
        # payload custody: newly parked rows enter the ledger; rows the
        # fabric completed must arrive bit-exact from custody
        fresh_park = (pc[0] > 0) & (pc_prev == 0)
        resumed = (pc_prev > 0) & (pc[0] == 0)
        rp = np.asarray(rp)           # (D_dst, D_src, W)
        snow = np.asarray(snow)
        for s in range(D):
            for d in range(D):
                if fresh_park[s, d]:
                    ledger[(s, d)] = pm[s, d].copy()
                    assert ph[0, s, d] >= 1
                if resumed[s, d]:
                    exp = ledger.pop((s, d))
                    assert (rp[d, s] == exp).all(), (s, d, win)
                elif snow[s, d] and s != d and cm[s, d] > 0:
                    assert (rp[d, s] == pm[s, d]).all(), (s, d, win)
        pc_prev = pc[0].astype(np.int64)
    # end of run: walk the fabric empty, then an uncredited final flush
    lstate, rp, rcnt, st = fn_walk(lstate)
    rp = np.asarray(rp)
    for (s, d), exp in sorted(ledger.items()):
        assert (rp[d, s] == exp).all(), ("drain", s, d)
    assert np.asarray(rcnt).sum() == pc_prev.sum()
    assert (np.asarray(lstate.parked_count) == 0).all()
    assert (np.asarray(lstate.parked_by_link) == 0).all()
    counts = jnp.asarray(counts_of(rng))
    payload = jnp.asarray(payload_of(rng).astype(np.uint32))
    lstate, rp, rcnt, mask, snow, st = fn_drain(lstate, payload, counts)
    assert np.asarray(mask).all()
    assert np.asarray(rcnt).sum() == np.asarray(counts).sum()
    cr = np.asarray(lstate.bank.credits)
    pend = np.asarray(lstate.bank.pending)
    assert (cr[0] + pend[0].sum(-1) == tot0).all()

# fixed seed matrix: 10 fabric configurations x 20 traffic seeds = 200
# seeded cases (zero_bank rides the credits=64 configurations)
CONFIGS = []
for name, opts in [("torus2d", dict(nx=2, ny=4)),
                   ("torus3d", dict(nx=2, ny=2, nz=2))]:
    for credits, nl, zero_bank in [(36, 2, False), (96, 2, False),
                                   (40, 0, False),        # zero-latency
                                   (1 << 20, 2, False),   # ample
                                   (64, 2, True)]:        # zero-credit
        CONFIGS.append((name, opts, credits, nl, zero_bank))

cases = 0
for name, opts, credits, nl, zero_bank in CONFIGS:
    t = transport.create(name, n_shards=D, link_credits=credits,
                         notify_latency=nl, **opts)
    fns = make_fns(t)
    for seed in range(SEEDS):
        try:
            fuzz_case(fns, t, seed, zero_bank)
        except Exception:
            print(f"[fuzz] FAILED {name} credits={credits} nl={nl} "
                  f"zero_bank={zero_bank} seed={seed}")
            raise
        cases += 1
print(f"FUZZ_CASES={cases}")
assert cases >= 200
print("FABRIC_FUZZ_OK")
""", timeout=1200)
    assert "FABRIC_FUZZ_OK" in out


def test_fabric_chaos_fuzz():
    """Chaos mode: a pinned-seed ``chaos`` schedule kills one random
    cable every window (revive p=0.5) while the transport-level
    invariant fuzz runs.  Conservation, credit-unit invariance, payload
    custody and the clean end-of-run drain must all survive; dead links
    must be frozen (``parked_by_link[dead] == 0`` once the mask lands);
    and at least some traffic must actually detour (``rerouted > 0``
    across the sweep)."""
    out = run_md(r"""
import functools
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro import transport
from repro.fabric import chaos, mask_at
from repro.serve.loadgen import traffic_rng, draw_counts, draw_payload

D, W, WINDOWS = 8, 6, 6
SEEDS = 5
mesh = jax.make_mesh((D,), ("wafer",))
spec = P("wafer")

def make_fns(t):
    def body(lstate, p, c):
        lstate = jax.tree_util.tree_map(lambda x: x[0], lstate)
        out = t.exchange(lstate, p[0], c[0], axis_name="wafer",
                         enforce_credits=True)
        return jax.tree_util.tree_map(
            lambda x: x[None],
            (out.state, out.recv_payload, out.recv_counts, out.sent_mask,
             out.sent_now, out.stats))
    def dbody(lstate):
        lstate = jax.tree_util.tree_map(lambda x: x[0], lstate)
        out = t.drain_fabric(lstate, axis_name="wafer")
        return jax.tree_util.tree_map(
            lambda x: x[None],
            (out.state, out.recv_payload, out.recv_counts, out.stats))
    fn = jax.jit(shard_map(body, mesh=mesh, in_specs=(spec, spec, spec),
                           out_specs=spec, check_rep=False))
    walk = jax.jit(shard_map(dbody, mesh=mesh, in_specs=(spec,),
                             out_specs=spec, check_rep=False))
    return fn, walk

def chaos_case(fns, t, dims, seed):
    fn, fn_walk = fns
    rng = traffic_rng(seed)
    masks = np.asarray(chaos(dims, WINDOWS, seed).link_down)
    assert masks.any(), "chaos schedule killed nothing"
    st0 = t.init_state(W)
    tot0 = (np.asarray(st0.bank.credits)
            + np.asarray(st0.bank.pending).sum(-1)
            + np.asarray(st0.parked_by_link))
    lstate = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (D,) + x.shape), st0)
    ledger = {}
    pc_prev = np.zeros((D, D), np.int64)
    rer = 0
    for win in range(WINDOWS):
        counts = jnp.asarray(draw_counts(rng, (D, D), 31))
        payload = jnp.asarray(draw_payload(rng, (D, D, W)).astype(np.uint32))
        # stamp this window's fault mask; exchange resets it to None
        down = jnp.broadcast_to(jnp.asarray(masks[win]),
                                (D,) + masks[win].shape)
        lstate = lstate._replace(link_down=down)
        lstate, rp, rcnt, mask, snow, st = fn(lstate, payload, counts)
        off = np.asarray(st.offered_events)
        sent = np.asarray(st.sent_events)
        defr = np.asarray(st.deferred_events)
        park = np.asarray(st.parked_events)
        unpark = np.asarray(st.unparked_events)
        infab = np.asarray(st.in_fabric_events)
        rer += int(np.asarray(st.rerouted).sum())
        cm, pm = np.asarray(counts), np.asarray(payload)
        # conservation with parked — identical to the healthy fuzz
        assert (off == sent + defr + park).all()
        assert sent.sum() + unpark.sum() == np.asarray(
            st.delivered_events).sum() == np.asarray(rcnt).sum()
        # deferral attribution stays hop-0 only (unroutable rows defer at
        # the source, they never HOL-block); parked rows MAY now sit at
        # hop 0 — an evicted row whose detour retry stalled holds nothing
        sbh = np.asarray(st.stalled_by_hop)
        pbh = np.asarray(st.parked_by_hop)
        assert (sbh.sum(-1) == defr).all() and sbh[:, 1:].sum() == 0
        assert (pbh.sum(-1) == infab).all()
        held = np.where(np.asarray(mask), 0, cm).sum(1)
        assert (held == defr).all()
        # credit-unit invariance + replication, unchanged under faults
        cr = np.asarray(lstate.bank.credits)
        pend = np.asarray(lstate.bank.pending)
        pbl = np.asarray(lstate.parked_by_link)
        pc = np.asarray(lstate.parked_count)
        ph = np.asarray(lstate.parked_hop)
        assert (cr >= 0).all() and (pbl >= 0).all() and (pc >= 0).all()
        assert (cr == cr[0]).all() and (pend == pend[0]).all()
        assert (pc == pc[0]).all() and (pbl == pbl[0]).all()
        assert (cr[0] + pend[0].sum(-1) + pbl[0] == tot0).all()
        # dead links are frozen: any row parked on a link the mask just
        # killed was evicted this window, and no chosen route (default or
        # detour) may traverse a dead link
        assert (pbl[0][masks[win]] == 0).all(), win
        # parked holds balance exactly: each transit-parked row (hop >= 1)
        # holds its count on one arrival link, hop-0 parks hold nothing
        assert pbl[0].sum() == pc[0][ph[0] >= 1].sum()
        # occupancy balance: parked in, unparked out
        assert (pc[0].sum(1) == pc_prev.sum(1) + park - unpark).all()
        # payload custody stays bit-exact through eviction + re-park
        fresh_park = (pc[0] > 0) & (pc_prev == 0)
        resumed = (pc_prev > 0) & (pc[0] == 0)
        rp = np.asarray(rp)
        snow = np.asarray(snow)
        for s in range(D):
            for d in range(D):
                if fresh_park[s, d]:
                    ledger[(s, d)] = pm[s, d].copy()
                if resumed[s, d]:
                    exp = ledger.pop((s, d))
                    assert (rp[d, s] == exp).all(), (s, d, win)
                elif snow[s, d] and s != d and cm[s, d] > 0:
                    assert (rp[d, s] == pm[s, d]).all(), (s, d, win)
        pc_prev = pc[0].astype(np.int64)
    # the fabric walk ignores faults (a drained fabric is an operator
    # action): custody drains bit-exact, tables empty, credits conserve
    lstate, rp, rcnt, st = fn_walk(lstate)
    rp = np.asarray(rp)
    for (s, d), exp in sorted(ledger.items()):
        assert (rp[d, s] == exp).all(), ("drain", s, d)
    assert np.asarray(rcnt).sum() == pc_prev.sum()
    assert (np.asarray(lstate.parked_count) == 0).all()
    assert (np.asarray(lstate.parked_by_link) == 0).all()
    cr = np.asarray(lstate.bank.credits)
    pend = np.asarray(lstate.bank.pending)
    assert (cr[0] + pend[0].sum(-1) == tot0).all()
    return rer

cases, rerouted = 0, 0
for name, dims, opts in [("torus2d", (2, 4), dict(nx=2, ny=4)),
                         ("torus3d", (2, 2, 2),
                          dict(nx=2, ny=2, nz=2))]:
    for credits in (48, 96):
        t = transport.create(name, n_shards=D, link_credits=credits,
                             notify_latency=2, **opts)
        fns = make_fns(t)
        for seed in range(SEEDS):
            try:
                rerouted += chaos_case(fns, t, dims, seed)
            except Exception:
                print(f"[chaos] FAILED {name} credits={credits} "
                      f"seed={seed}")
                raise
            cases += 1
print(f"CHAOS_CASES={cases} rerouted={rerouted}")
assert cases >= 20
assert rerouted > 0, "chaos sweep never detoured a single event"
print("FABRIC_CHAOS_OK")
""", timeout=1200)
    assert "FABRIC_CHAOS_OK" in out


def test_fabric_fuzz_simulator_latency_invariants():
    """Congested simulator runs: the latency digest histogram counts
    exactly the delivered events of every window (waiting + hop charges
    + queueing), percentile ordering holds, and the park/resume fabric
    is actually exercised end to end."""
    out = run_md("""
import jax, numpy as np
from repro.snn import microcircuit as mc, network, simulator as sim
spec = mc.MicrocircuitSpec(scale=0.003)
w, is_inh = spec.weight_matrix()
part = network.build_partition(w, is_inh, n_shards=4)
mesh = jax.make_mesh((4,), ("wafer",))

for transport, kw in [("torus2d", {}),
                      ("torus3d", dict(torus_nx=1, torus_ny=2,
                                       torus_nz=2))]:
    cfg = sim.SimConfig(n_shards=4, per_shard=part.per_shard,
                        max_fan=part.fanout.shape[1], window=8,
                        ring_len=32, e_max=256, capacity=32,
                        transport=transport, link_credits=32,
                        notify_latency=2, **kw)
    init, runf = sim.build_sharded_sim(mesh, "wafer", cfg, part,
                                       spec.bg_rates())
    exercised = False
    for seed in (0, 1, 2):
        st, stats = runf(init(seed), 10)
        s = jax.tree_util.tree_map(np.asarray, stats)
        link = s.link
        assert (s.latency.hist.sum(-1) == link.delivered_events).all()
        assert (s.latency.max_us >= s.latency.p99_us).all()
        assert (s.latency.p99_us >= s.latency.p50_us).all()
        assert (link.offered_events == link.sent_events
                + link.deferred_events + link.parked_events).all()
        assert ((link.sent_events + link.unparked_events).sum(0)
                == link.delivered_events.sum(0)).all()
        assert (link.stalled_by_hop.sum(-1) == link.deferred_events).all()
        exercised = exercised or (link.parked_events.sum() > 0
                                  and link.unparked_events.sum() > 0)
    assert exercised, transport + ": fabric never parked+resumed"
print("SIM_FUZZ_OK")
""", n_devices=4, timeout=1200)
    assert "SIM_FUZZ_OK" in out


def test_cross_backend_equivalence_ample_credits():
    """With ample credits and empty transit buffers the torus backends
    remain bit-identical to ``alltoall`` — delivered events, guids,
    counts and multicast links — under the new FabricState carry, and
    their latency digests equal the hop-only charges exactly: the
    queueing term contributes nothing on an uncontended fabric."""
    out = run_md("""
import jax, jax.numpy as jnp, numpy as np
from repro import wire
from repro.core import events as ev, routing as rt
from repro.core.exchange import make_exchange
n_shards, N, C, n_addr = 8, 64, 16, 96
mesh = jax.make_mesh((n_shards,), ("wafer",))
tabs = []
for s in range(n_shards):
    projs = [rt.Projection(a, a+1, dest_node=(a * 5 + s) % n_shards,
                           dest_links=[a % 3, 7]) for a in range(n_addr)]
    tabs.append(rt.build_tables(n_addr, projs, n_guid=64))
stacked = rt.RoutingTables(
    dest_of_addr=jnp.stack([t.dest_of_addr for t in tabs]),
    guid_of_addr=jnp.stack([t.guid_of_addr for t in tabs]),
    mcast_of_guid=jnp.stack([t.mcast_of_guid for t in tabs]))
addr = jax.random.randint(jax.random.PRNGKey(0), (n_shards, N), 0, n_addr)
ts = jax.random.randint(jax.random.PRNGKey(1), (n_shards, N), 0, 1000)
words = ev.pack(addr, ts)

run_a = make_exchange(mesh, "wafer", n_shards=n_shards, capacity=C,
                      n_addr_per_shard=n_addr, transport="alltoall")
ref = run_a(words, stacked)

from repro.core.torus import Torus
ids = np.arange(n_shards)
for backend, opts, pad in [
    ("torus2d", {"nx": 2, "ny": 4, "link_credits": 1 << 20}, (2, 4, 1)),
    ("torus3d", {"nx": 2, "ny": 2, "nz": 2, "link_credits": 1 << 20},
     (2, 2, 2)),
]:
    run = make_exchange(mesh, "wafer", n_shards=n_shards, capacity=C,
                        n_addr_per_shard=n_addr, transport=backend,
                        transport_opts=opts)
    t = run(words, stacked)
    for field in ("recv_events", "recv_guids", "recv_counts",
                  "link_events"):
        assert (np.asarray(getattr(ref, field))
                == np.asarray(getattr(t, field))).all(), (backend, field)
    assert np.asarray(t.sent_mask).all()
    assert np.asarray(t.link.parked_events).sum() == 0
    assert np.asarray(t.link.in_fabric_events).sum() == 0
    # the carried FabricState leaves the run exactly as it entered:
    # empty tables, full credit conservation
    ls = t.link_state
    assert (np.asarray(ls.parked_count) == 0).all()
    assert (np.asarray(ls.parked_by_link) == 0).all()
    assert (np.asarray(ls.bank.credits)
            + np.asarray(ls.bank.pending).sum(-1) == 1 << 20).all()
    # latency digest == hop-only charges (queueing term exactly zero):
    # recompute the digest per shard from counts and the host hop model
    host = Torus(nx=pad[0], ny=pad[1], nz=pad[2])
    hops = host.hops(ids[:, None], ids[None, :]).astype(np.int64)
    fmt = wire.get_profile("extoll")
    for me in range(n_shards):
        cnt = jnp.asarray(np.asarray(t.sent_counts)[me])
        lat = wire.hop_latency_us(fmt, cnt, jnp.asarray(hops[me]))
        w8 = jnp.where(jnp.arange(n_shards) != me, cnt, 0)
        exp = wire.summarize_latency(lat, w8)
        got = jax.tree_util.tree_map(lambda x: x[me], t.latency)
        for a, b in zip(exp, got):
            assert (np.asarray(a) == np.asarray(b)).all(), (backend, me)
print("CROSS_BACKEND_OK")
""")
    assert "CROSS_BACKEND_OK" in out


def test_tenant_fabric_invariant_fuzz():
    """Multi-tenant torus: the single-tenant invariant set extended with
    tenant ids — per (tenant, shard, window) conservation, partitioned
    credit-slot invariance over the ``(T+1)*K`` bank (each tenant's
    reserved slice plus the shared pool balances independently), global
    per-tenant delivery accounting through park/resume, and a clean
    post-drain fabric.  Traffic comes from the shared ``loadgen`` RNG
    helpers, per-(tenant, window) substreams."""
    out = run_md(r"""
import numpy as np
import jax, jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import flow_control as fc
from repro.transport.torus import TenantTorusTransport
from repro.serve.loadgen import traffic_rng, draw_counts, draw_payload

n, W, WINDOWS, SEEDS = 8, 6, 8, 4
mesh = Mesh(np.array(jax.devices()[:n]), ("w",))

CONFIGS = [
    # (reserves, link_credits, notify) — incl. a pure best-effort tenant
    ((24, 8), 64, 2),
    ((16, 12, 0), 48, 2),   # shared pool >= max row: best-effort viable
    ((8, 8), 40, 0),
]

def run_case(part, notify, seed):
    T = part.n_tenants
    tr = TenantTorusTransport(n, (2, 2, 2), partition=part,
                              notify_latency=notify, max_row_events=20)
    counts = np.zeros((WINDOWS, T, n, n), np.int32)
    payload = np.zeros((WINDOWS, T, n, n, W), np.uint32)
    for w in range(WINDOWS):
        for t in range(T):
            rng = traffic_rng(seed, t, w)
            counts[w, t] = draw_counts(rng, (n, n), 20)
            payload[w, t] = draw_payload(rng, (n, n, W))
    state0 = tr.init_state(W)

    def shard_fn(cnts, pays):
        def body(st, x):
            c, p = x
            out = tr.exchange(st, p, c, axis_name="w")
            return out.state, (out.recv_counts, out.stats, out.state)
        st, outs = jax.lax.scan(body, state0, (cnts[0], pays[0]))
        dr = tr.drain_fabric(st, axis_name="w")
        lift = lambda t_: jax.tree.map(lambda a: a[None], t_)
        return lift(outs), lift((dr.state, dr.recv_counts, dr.stats))

    f = jax.jit(shard_map(shard_fn, mesh=mesh, in_specs=(P("w"), P("w")),
                          out_specs=(P("w"), P("w")), check_rep=False))
    cin = jnp.asarray(counts.transpose(2, 0, 1, 3))
    pin = jnp.asarray(payload.transpose(2, 0, 1, 3, 4))
    (rcnt, stats, states), (dstate, dcnt, dstats) = jax.tree.map(
        np.asarray, f(cin, pin))
    limits = np.asarray(fc.partition_limits(part, n * tr.n_links))

    # per (tenant, shard, window) conservation
    off = stats.offered_events       # (n, WINDOWS, T)
    assert (off == stats.sent_events + stats.deferred_events
            + stats.parked_events).all()
    assert (off.sum((0, 1)) == counts.sum((0, 2, 3))).all()
    # partitioned credit-slot invariance after EVERY window, replicated
    cr = states.bank.credits         # (n, WINDOWS, (T+1)K)
    pend = states.bank.pending
    pbl = states.parked_by_link
    assert (cr == cr[:1]).all() and (pbl == pbl[:1]).all()
    assert (cr[0] + pend[0].sum(-1) + pbl[0] == limits[None]).all()
    assert (cr >= 0).all() and (pbl >= 0).all()
    # shared-pool holds of parked rows never exceed their park counts
    hs = states.parked_hold_shared
    assert (hs >= 0).all()
    assert ((hs > 0) <= (states.parked_count > 0)).all()
    # global per-tenant delivery accounting through park/resume
    sent = stats.sent_events.sum((0, 1))
    unp = stats.unparked_events.sum((0, 1))
    deliv = rcnt.sum((0, 1, 3)) + dcnt.sum((0, 2))
    assert (sent + unp + dstats.unparked_events.sum(0) == deliv).all()
    # clean post-drain fabric: empty tables, every credit home or pending
    assert dstate.parked_count.sum() == 0
    assert dstate.parked_by_link.sum() == 0
    assert dstate.parked_hold_shared.sum() == 0
    assert (dstate.bank.credits[0]
            + dstate.bank.pending[0].sum(-1) == limits).all()

cases = 0
for reserves, credits, notify in CONFIGS:
    part = fc.make_partition(credits, reserves)
    for seed in range(SEEDS):
        try:
            run_case(part, notify, seed)
        except Exception:
            print(f"[tenant-fuzz] FAILED reserves={reserves} "
                  f"credits={credits} notify={notify} seed={seed}")
            raise
        cases += 1
print(f"TENANT_FUZZ_CASES={cases}")
print("TENANT_FUZZ_OK")
""", timeout=1200)
    assert "TENANT_FUZZ_OK" in out


@pytest.mark.timeout(1260)
def test_qos_isolation_engine_level():
    """The acceptance claim end to end: a quiet tenant with a burst-sized
    reserved slice, offered IDENTICAL traffic (per-(tenant, window) RNG
    substreams), sees its p99 latency degrade by at most the pinned
    factor when a saturating bursty co-tenant fills the fabric — and the
    co-tenant's overload lands in MEASURED shed, with both tenants'
    ledgers conserving exactly.  (Engine threads run in the subprocess;
    the pytest ``timeout`` marker is the outer belt, ``run_md``'s
    subprocess timeout the inner.)"""
    out = run_md(r"""
import numpy as np
import jax
from jax.sharding import Mesh

from repro.serve.loadgen import PoissonLoadGen, TenantProfile
from repro.serve.spike_engine import EngineConfig, SpikeEngine
from repro.serve.tenancy import TenantSpec

QOS_P99_BOUND = 4.0
n = 8
mesh = Mesh(np.array(jax.devices()[:n]), ("w",))
tenants = [TenantSpec("quiet", reserve=32, rate_epw=40.0),
           TenantSpec("hot", reserve=8, rate_epw=400.0)]
cfg = EngineConfig(capacity=16, link_credits=64, notify_latency=2,
                   window_us=100.0, seg_windows=4, nx=2, ny=2, nz=2)

def run(hot_rate):
    src = PoissonLoadGen(7, [TenantProfile("quiet", 40.0),
                             TenantProfile("hot", hot_rate,
                                           burst_factor=3.0,
                                           burst_prob=0.25)],
                         n, cfg.capacity)
    eng = SpikeEngine(mesh, "w", tenants, cfg, src)
    rep = eng.run(6)
    assert np.all(rep.injected == rep.delivered + rep.shed)
    return rep

solo = run(0.0)
cont = run(400.0)
# identical quiet traffic in both runs, event for event
assert solo.injected[0] == cont.injected[0] > 0
# the saturating co-tenant overloads measurably...
assert cont.shed[1] > 0
# ...but the quiet tenant keeps its guaranteed service: no shed, and
# p99 within the pinned factor of its solo baseline
assert cont.shed[0] == 0
p99_solo = solo.tenants[0].p99_us
p99_cont = cont.tenants[0].p99_us
assert p99_solo > 0
assert p99_cont <= QOS_P99_BOUND * p99_solo, (p99_cont, p99_solo)
print("p99 solo=%.1fus contended=%.1fus" % (p99_solo, p99_cont))
print("QOS_OK")
""", timeout=1200)
    assert "QOS_OK" in out


def test_recorder_conservation_under_chaos():
    """Flight-recorder conservation with the fabric under fire: a seeded
    ``chaos`` schedule (one random cable killed every window, p=0.5
    revival) on a credit-throttled torus3d, recorder ring in the carry.
    Per shard and per counter the ring's window deltas must sum
    bit-exactly to the end-of-run ``LinkStats`` — faults may defer,
    detour or park an event, but the recorder never miscounts one — and
    the per-link stall attribution lane must keep summing to the global
    deferred total while links die and heal."""
    out = run_md(r"""
import jax, numpy as np
from repro import obs
from repro.fabric import chaos
from repro.snn import microcircuit as mc, network, simulator as sim

spec = mc.MicrocircuitSpec(scale=0.003)
w, is_inh = spec.weight_matrix()
part = network.build_partition(w, is_inh, n_shards=8)
mesh = jax.make_mesh((8,), ("wafer",))
dims = (2, 2, 2)
N_WIN = 10
for seed in (0, 1, 2):
    sched = chaos(dims, N_WIN, seed)
    for credits in (16, 32):
        cfg = sim.SimConfig(n_shards=8, per_shard=part.per_shard,
                            max_fan=part.fanout.shape[1], window=8,
                            ring_len=32, e_max=512, capacity=16,
                            transport="torus3d", torus_nx=2, torus_ny=2,
                            torus_nz=2, link_credits=credits,
                            notify_latency=2)
        init, runf = sim.build_sharded_sim(
            mesh, "wafer", cfg, part, spec.bg_rates(),
            fault_schedule=sched,
            recorder=obs.RecorderConfig(depth=N_WIN + 4))
        st, stats, ring = runf(init(seed), N_WIN)
        s = jax.tree_util.tree_map(np.asarray, stats)
        for sh in range(8):
            tot = obs.counter_totals(
                obs.ring_rows(obs.ring_shard(ring, sh)))
            for f in obs.COUNTER_FIELDS:
                want = int(getattr(s.link, f)[sh].sum())
                assert int(tot[f]) == want, (seed, credits, sh, f)
        rows = obs.global_rows(ring, 8)
        sbl = sum(int(np.asarray(r["stalled_by_link"]).sum())
                  for r in rows)
        assert sbl == int(s.link.deferred_events.sum()), (seed, credits)
        # the chaos run actually rerouted (the schedule is not a no-op)
        assert int(s.link.rerouted.sum()) > 0 or seed > 0
print("CHAOS_RECORDER_OK")
""", timeout=1200)
    assert "CHAOS_RECORDER_OK" in out
