"""SNN substrate tests: LIF dynamics, microcircuit construction, partition."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.snn import lif, microcircuit as mc, network


def test_lif_subthreshold_decay():
    p = lif.LIFParams()
    st = lif.LIFState(v=jnp.asarray([-55.0]), i_exc=jnp.zeros(1),
                      i_inh=jnp.zeros(1), refrac=jnp.zeros(1, jnp.int32))
    st2, spk = lif.step(st, p, jnp.zeros(1), jnp.zeros(1))
    # decays toward E_L, no spike
    assert not bool(spk[0])
    assert float(st2.v[0]) < -55.0 + 1e-6
    assert float(st2.v[0]) > p.e_l


def test_lif_spike_and_refractory():
    p = lif.LIFParams(t_ref=1.0, dt=0.1)
    st = lif.LIFState(v=jnp.asarray([-50.01]), i_exc=jnp.asarray([5000.0]),
                      i_inh=jnp.zeros(1), refrac=jnp.zeros(1, jnp.int32))
    st, spk = lif.step(st, p, jnp.zeros(1), jnp.zeros(1))
    assert bool(spk[0])
    assert float(st.v[0]) == p.v_reset
    assert int(st.refrac[0]) == 10
    # refractory: voltage frozen regardless of input
    st2, spk2 = lif.step(st, p, jnp.zeros(1), jnp.zeros(1))
    assert not bool(spk2[0])
    assert float(st2.v[0]) == p.v_reset


def test_lif_rate_increases_with_drive():
    p = lif.LIFParams()
    n = 200

    def run(drive):
        st = lif.init_state(n, p, jax.random.PRNGKey(0))
        tot = 0
        for t in range(100):
            st, spk = lif.step(st, p, jnp.full((n,), drive), jnp.zeros(n))
            tot += int(spk.sum())
        return tot

    low, high = run(50.0), run(400.0)
    assert high > low


def test_microcircuit_structure():
    spec = mc.MicrocircuitSpec(scale=0.005, seed=1)
    w, is_inh = spec.weight_matrix()
    n = spec.n_neurons
    assert w.shape == (n, n)
    off = spec.offsets()
    # inhibitory columns are negative, excitatory positive
    for j, pop in enumerate(mc.POPULATIONS):
        cols = w[:, off[j]:off[j + 1]]
        nz = cols[cols != 0]
        if len(nz):
            assert (nz < 0).all() if pop.endswith("I") else (nz > 0).all()
    # connectivity tracks the probability table (loose check)
    p_l4e_l23e = mc.CONN_PROB[0, 2]
    blk = w[off[0]:off[1], off[2]:off[3]]
    got = (blk != 0).mean()
    assert abs(got - p_l4e_l23e) < 0.05
    # L4E -> L23E weights are doubled on average
    other = w[off[0]:off[1], off[0]:off[1]]
    if (blk != 0).any() and (other != 0).any():
        assert blk[blk != 0].mean() > 1.5 * other[other != 0].mean()


def test_partition_covers_all_fanout():
    spec = mc.MicrocircuitSpec(scale=0.003)
    w, is_inh = spec.weight_matrix()
    part = network.build_partition(w, is_inh, n_shards=4)
    shard_of = np.arange(part.n_neurons) // part.per_shard
    nz = w != 0
    for j in range(min(w.shape[1], 100)):
        targets = set(np.unique(shard_of[: nz.shape[0]][nz[:, j]]))
        listed = set(int(d) for d in part.fanout[j] if d >= 0)
        assert targets <= listed


def test_routing_tables_replicas():
    spec = mc.MicrocircuitSpec(scale=0.003)
    w, is_inh = spec.weight_matrix()
    part = network.build_partition(w, is_inh, n_shards=4)
    tabs = network.routing_tables_for_shard(part, shard=1)
    max_fan = part.fanout.shape[1]
    # replica k of local neuron a routes to fanout[global, k]
    for a in (0, 3, 7):
        g = part.per_shard + a
        for k, d in enumerate(part.fanout[g]):
            got = int(tabs.dest_of_addr[a * max_fan + k])
            assert got == (int(d) if d >= 0 else -1)


def test_traffic_matrix_no_self_traffic():
    spec = mc.MicrocircuitSpec(scale=0.003)
    w, is_inh = spec.weight_matrix()
    part = network.build_partition(w, is_inh, n_shards=4)
    rates = np.full(part.n_neurons, 5.0)
    m = network.traffic_matrix(part, rates)
    assert (np.diag(m) == 0).all()
    assert m.sum() > 0
