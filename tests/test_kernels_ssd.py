"""SSD-chunk Pallas kernel: sweep shapes/dtypes against the ref oracle and
against the model's chunked scan."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("bh,c,P,N", [
    (4, 16, 8, 4), (8, 32, 16, 8), (2, 64, 64, 128),
    (3, 128, 64, 32), (1, 8, 4, 4),
])
def test_ssd_chunk_matches_ref(bh, c, P, N):
    ks = jax.random.split(jax.random.PRNGKey(bh * c), 6)
    x = jax.random.normal(ks[0], (bh, c, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bh, c)))
    A = -jnp.exp(jax.random.normal(ks[2], (bh,)) * 0.3)
    B = jax.random.normal(ks[3], (bh, c, N)) * 0.3
    C = jax.random.normal(ks[4], (bh, c, N)) * 0.3
    S = jax.random.normal(ks[5], (bh, P, N)) * 0.1
    y1, s1 = ops.ssd_chunk(x, dt, A, B, C, S)
    y2, s2 = ref.ssd_chunk_ref(x, dt, A, B, C, S)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_chunk_dtypes(dtype):
    bh, c, P, N = 2, 32, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    x = jax.random.normal(ks[0], (bh, c, P)).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bh, c))).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[2], (bh,)) * 0.3)
    B = (jax.random.normal(ks[3], (bh, c, N)) * 0.3).astype(dtype)
    C = (jax.random.normal(ks[4], (bh, c, N)) * 0.3).astype(dtype)
    S = jax.random.normal(ks[5], (bh, P, N)) * 0.1
    y1, s1 = ops.ssd_chunk(x, dt, A, B, C, S)
    y2, s2 = ref.ssd_chunk_ref(x.astype(jnp.float32),
                               dt.astype(jnp.float32), A,
                               B.astype(jnp.float32),
                               C.astype(jnp.float32), S)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=tol, atol=tol)


def test_ssd_chunk_scan_equals_model():
    """Kernel-driven chunk scan == models.ssm.ssd_chunked end to end."""
    from repro.models.ssm import ssd_chunked
    Bb, L, H, P, N, chunk = 2, 64, 4, 8, 16, 16
    ks = jax.random.split(jax.random.PRNGKey(9), 5)
    x = jax.random.normal(ks[0], (Bb, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bb, L, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (Bb, L, 1, N)) * 0.3
    Cm = jax.random.normal(ks[4], (Bb, L, 1, N)) * 0.3
    y_ref, s_ref = ssd_chunked(x, dt, A, Bm, Cm, chunk)

    nc = L // chunk
    Bh = jnp.repeat(Bm, H, axis=2)
    Ch = jnp.repeat(Cm, H, axis=2)
    r = lambda t, d: t.reshape(Bb, nc, chunk, H, d).transpose(
        1, 0, 3, 2, 4).reshape(nc, Bb * H, chunk, d)
    xc, Bc, Cc = r(x, P), r(Bh, N), r(Ch, N)
    dtc = dt.reshape(Bb, nc, chunk, H).transpose(1, 0, 3, 2).reshape(
        nc, Bb * H, chunk)
    Af = jnp.tile(A, Bb)
    S = jnp.zeros((Bb * H, P, N))
    ys = []
    for i in range(nc):
        y, S = ops.ssd_chunk(xc[i], dtc[i], Af, Bc[i], Cc[i], S)
        ys.append(y)
    y_k = jnp.stack(ys).reshape(nc, Bb, H, chunk, P).transpose(
        1, 0, 3, 2, 4).reshape(Bb, L, H, P)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(S.reshape(Bb, H, P, N)),
                               np.asarray(s_ref), rtol=2e-4, atol=2e-4)
