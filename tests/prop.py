"""Mini property-based testing harness.

``hypothesis`` cannot be installed in this offline container, so this
module provides the equivalent discipline in ~40 lines: seeded random case
generation over declared strategies, many cases per property, and a
reproduction line printed on failure (the seed fully determines the case).
"""
from __future__ import annotations

import functools
import os

import numpy as np

N_CASES = int(os.environ.get("PROP_CASES", "25"))


class draw:
    """Strategy namespace: each returns fn(rng) -> value."""

    @staticmethod
    def ints(lo, hi):
        return lambda rng: int(rng.integers(lo, hi + 1))

    @staticmethod
    def floats(lo, hi):
        return lambda rng: float(rng.uniform(lo, hi))

    @staticmethod
    def choice(*options):
        return lambda rng: options[int(rng.integers(0, len(options)))]

    @staticmethod
    def array(shape_fn, lo, hi, dtype=np.int64):
        def gen(rng):
            shape = shape_fn(rng) if callable(shape_fn) else shape_fn
            if np.issubdtype(np.dtype(dtype), np.floating):
                return rng.uniform(lo, hi, shape).astype(dtype)
            return rng.integers(lo, hi, shape).astype(dtype)
        return gen


def given(n_cases: int | None = None, **strategies):
    """Decorator: run the test once per seeded random case."""

    def deco(fn):
        # NOTE: the wrapper must expose a ZERO-arg signature, otherwise
        # pytest mistakes the strategy parameters for fixtures.
        def wrapper():
            cases = n_cases or N_CASES
            for seed in range(cases):
                rng = np.random.default_rng(seed * 7919 + 13)
                drawn = {k: s(rng) for k, s in strategies.items()}
                try:
                    fn(**drawn)
                except Exception:
                    print(f"\n[prop] FAILED case seed={seed}: "
                          f"{ {k: _short(v) for k, v in drawn.items()} }")
                    raise
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper

    return deco


def _short(v):
    if isinstance(v, np.ndarray):
        return f"array{v.shape}:{v.dtype}"
    return v
