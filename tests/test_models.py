"""Per-architecture smoke tests (reduced configs): forward + train step +
decode on CPU, asserting shapes and finiteness — one per assigned arch."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_configs, reduced
from repro.models import build
from repro.models.transformer import Runtime
from repro.train.optimizer import OptimizerConfig, ScheduleConfig
from repro.train.step import TrainConfig, init_train_state, make_train_step

ARCHS = list_configs()


def make_batch(cfg, B=2, S=32, seed=0):
    k = jax.random.PRNGKey(seed)
    batch = {"tokens": jax.random.randint(k, (B, S), 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["positions3"] = jnp.broadcast_to(jnp.arange(S), (3, B, S))
        batch["vision_embeds"] = 0.01 * jax.random.normal(
            k, (B, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio":
        batch["enc_frames"] = 0.1 * jax.random.normal(
            k, (B, cfg.enc_ctx, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_smoke(arch):
    cfg = reduced(get_config(arch))
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 32
    h, aux = m.hidden(params, make_batch(cfg, B, S))
    logits = m.logits(params, h)
    assert h.shape == (B, S, cfg.d_model)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = reduced(get_config(arch))
    m = build(cfg)
    tcfg = TrainConfig(optimizer=OptimizerConfig(
        schedule=ScheduleConfig(peak_lr=1e-3, warmup_steps=2, total_steps=10)))
    state = init_train_state(m, jax.random.PRNGKey(0), tcfg)
    step = make_train_step(m, tcfg, Runtime())
    batch = make_batch(cfg, 2, 32)
    batch["labels"] = batch["tokens"]
    state, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(np.asarray(state["step"])) == 1
    # params actually changed
    flat0 = jax.tree_util.tree_leaves(
        init_train_state(m, jax.random.PRNGKey(0), tcfg)["params"])
    flat1 = jax.tree_util.tree_leaves(state["params"])
    assert any(not np.allclose(a, b) for a, b in zip(flat0, flat1))


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_smoke(arch):
    cfg = reduced(get_config(arch))
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 8
    batch = make_batch(cfg, B, S)
    caches = m.init_caches(B, 64)
    h, caches = m.prefill(params, batch, caches)
    assert h.shape[0] == B and bool(jnp.isfinite(h.astype(jnp.float32)).all())
    tok = jnp.zeros((B, 1), jnp.int32) + 3
    for _ in range(3):
        logits, caches = m.decode(params, caches, tok)
        assert logits.shape == (B, 1, cfg.vocab)
        assert bool(jnp.isfinite(logits).all())
        tok = jnp.argmax(logits, -1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ["qwen3_32b", "mamba2_27b",
                                  "recurrentgemma_9b", "gemma2_9b"])
def test_decode_matches_forward(arch):
    """Greedy continuation via decode == teacher-forced forward argmax."""
    cfg = reduced(get_config(arch))
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(1))
    B, S = 1, 12
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)
    # full forward logits at the last position
    h, _ = m.hidden(params, {"tokens": tokens})
    full_last = m.logits(params, h)[:, -1, :]
    # prefill on the same prompt
    caches = m.init_caches(B, 64)
    hp, caches = m.prefill(params, {"tokens": tokens}, caches)
    pre_last = m.logits(params, hp[:, -1:, :])[:, 0, :]
    np.testing.assert_allclose(np.asarray(full_last), np.asarray(pre_last),
                               rtol=5e-2, atol=5e-2)
    assert int(jnp.argmax(full_last)) == int(jnp.argmax(pre_last))


def test_gemma2_alternating_windows():
    from repro.models.transformer import _layer_windows
    cfg = get_config("gemma2_9b")
    w = _layer_windows(cfg)
    assert w[0] == 4096 and w[1] == 0 and w[2] == 4096
    assert (w[0::2] == 4096).all() and (w[1::2] == 0).all()


def test_minicpm_scaling_applied():
    cfg = get_config("minicpm_2b")
    assert cfg.scale_emb == 12.0
    from repro.models.transformer import _res_scale
    assert abs(_res_scale(cfg) - 1.4 / np.sqrt(40)) < 1e-9


def test_param_counts_match_reported_sizes():
    """Sanity: full-size param counts are in the right ballpark."""
    from repro.models.modules import param_count
    expect = {
        "qwen3_32b": (31e9, 36e9),
        "qwen15_4b": (3.5e9, 4.5e9),
        "gemma2_9b": (8.5e9, 11e9),
        "minicpm_2b": (2.2e9, 3.2e9),
        "deepseek_moe_16b": (15e9, 18e9),
        "arctic_480b": (430e9, 520e9),
        "recurrentgemma_9b": (8e9, 11e9),
        "mamba2_27b": (2.4e9, 3.0e9),
        "qwen2_vl_7b": (6.5e9, 8.5e9),
        "whisper_large_v3": (1.3e9, 1.8e9),
    }
    for arch, (lo, hi) in expect.items():
        n = param_count(build(get_config(arch)).specs())
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"


def test_mamba2_decode_equals_chunked_prefill():
    """SSD decode recurrence must match the chunked scan state."""
    cfg = reduced(get_config("mamba2_27b"))
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 1, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0, cfg.vocab)
    # path A: prefill all S+1 tokens
    cA = m.init_caches(B, 64)
    hA, cA = m.prefill(params, {"tokens": tokens}, cA)
    # path B: prefill S tokens then decode 1
    cB = m.init_caches(B, 64)
    hB, cB = m.prefill(params, {"tokens": tokens[:, :S]}, cB)
    logitsB, cB = m.decode(params, cB, tokens[:, S:])
    logitsA = m.logits(params, hA[:, -1:, :])
    np.testing.assert_allclose(np.asarray(logitsA), np.asarray(logitsB),
                               rtol=5e-2, atol=5e-2)
