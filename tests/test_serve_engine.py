"""In-process serve-engine tests (1-shard mesh — no subprocess, no forced
device count) covering the host-side machinery the fabric fuzz can't see:
ingestion/device thread handoff, staging-slot reuse, graceful drain, the
per-tenant conservation ledger and run-to-run determinism.

Every test here starts the engine's threads, so every test carries the
hard ``timeout`` marker (see ``conftest.py``): a queue deadlock must kill
the run with tracebacks, not hang CI.
"""
import numpy as np
import pytest

import jax
from jax.sharding import Mesh

from repro.serve.loadgen import PoissonLoadGen, TenantProfile, WindowTraffic
from repro.serve.spike_engine import EngineConfig, SpikeEngine
from repro.serve.tenancy import TenantLedger, TenantSpec


def make_engine(seed=3, rate_b=30.0, segments_cfg=None, **cfg_kw):
    mesh = Mesh(np.array(jax.devices()[:1]), ("w",))
    tenants = [TenantSpec("a", reserve=8, rate_epw=10.0),
               TenantSpec("b", reserve=4, rate_epw=rate_b)]
    kw = dict(capacity=8, link_credits=16, seg_windows=3, nx=1, ny=1, nz=1)
    kw.update(cfg_kw)
    cfg = EngineConfig(**kw)
    src = PoissonLoadGen(seed, [TenantProfile("a", 10.0),
                                TenantProfile("b", rate_b,
                                              burst_factor=2.0,
                                              burst_prob=0.3)],
                         1, cfg.capacity)
    return SpikeEngine(mesh, "w", tenants, cfg, src)


@pytest.mark.timeout(300)
def test_engine_conserves_every_tenant():
    eng = make_engine()
    rep = eng.run(5)
    assert rep.conservation_checked
    assert np.all(rep.injected == rep.delivered + rep.shed)
    assert rep.delivered.sum() > 0
    assert rep.windows == 5 * 3
    # post-drain the engine holds nothing back
    assert eng.backlog_events() == 0
    assert eng.in_fabric_events() == 0


@pytest.mark.timeout(300)
def test_engine_overload_is_counted_not_hidden():
    # rate far beyond row capacity: on a 1-shard fabric every row is
    # local (local rows never defer, so engine-side shed needs the
    # multi-shard QoS test in test_fabric_fuzz.py), but the generator
    # must report its over-capacity clipping and the ledger must still
    # balance exactly
    # capacity 32 >> the quiet tenant's single-row Poisson(10) tail, so
    # only the hot tenant clips
    eng = make_engine(rate_b=500.0, capacity=32, link_credits=40)
    rep = eng.run(4)
    assert rep.clipped[1] > 0
    assert np.all(rep.injected == rep.delivered + rep.shed)
    # the quiet tenant is not the one overloading
    assert rep.clipped[0] == 0 and rep.shed[0] == 0


@pytest.mark.timeout(300)
def test_engine_deterministic_across_runs():
    r1 = make_engine(seed=11).run(4)
    r2 = make_engine(seed=11).run(4)
    assert np.array_equal(r1.injected, r2.injected)
    assert np.array_equal(r1.delivered, r2.delivered)
    assert np.array_equal(r1.shed, r2.shed)
    for d1, d2 in zip(r1.tenants, r2.tenants):
        assert np.array_equal(d1.hist, d2.hist)
        assert d1.p99_us == d2.p99_us
    r3 = make_engine(seed=12).run(4)
    assert not np.array_equal(r1.injected, r3.injected)


@pytest.mark.timeout(300)
def test_engine_continuous_start_stop():
    # continuous mode: no segment bound; stop() must join both threads,
    # finish staged work and still conserve
    eng = make_engine()
    eng.start()
    import time
    time.sleep(1.0)
    rep = eng.stop()
    assert rep.conservation_checked
    assert np.all(rep.injected == rep.delivered + rep.shed)
    # threads are gone and the engine is reusable-safe (double stop raises)
    with pytest.raises(RuntimeError):
        eng.stop()


@pytest.mark.timeout(300)
def test_engine_latency_attribution_counts_delivered():
    eng = make_engine()
    rep = eng.run(5)
    for t, dig in enumerate(rep.tenants):
        assert dig.hist.sum() == rep.delivered[t]
        if dig.delivered:
            assert dig.p99_us >= dig.p50_us


@pytest.mark.timeout(300)
def test_engine_rejects_mismatched_source():
    src = PoissonLoadGen(0, [TenantProfile("a", 1.0)], 1, 8)
    mesh = Mesh(np.array(jax.devices()[:1]), ("w",))
    cfg = EngineConfig(capacity=8, link_credits=16, nx=1, ny=1, nz=1)
    with pytest.raises(ValueError):
        SpikeEngine(mesh, "w", [TenantSpec("a", 8), TenantSpec("b", 4)],
                    cfg, src)


def test_ledger_conservation_violation_raises():
    from repro.wire.latency import N_LATENCY_BINS
    led = TenantLedger(["a"])
    led.add_injected(np.array([5]))
    led.add_windows(np.array([[3]]), np.array([[1]]),
                    np.zeros((1, 1, N_LATENCY_BINS)), np.zeros((1, 1)),
                    np.zeros((1, 1)))
    with pytest.raises(AssertionError):
        led.check_conservation()
    led.add_windows(np.array([[1]]), np.array([[0]]),
                    np.zeros((1, 1, N_LATENCY_BINS)), np.zeros((1, 1)),
                    np.zeros((1, 1)))
    led.check_conservation()


@pytest.mark.slow
@pytest.mark.timeout(900)
def test_engine_link_death_mid_segment_conserves():
    """A cable dies mid-run (window 6 of a 4-segment serve) on the full
    8-shard 2x2x2 torus: the engine's per-tenant ledger must still
    balance exactly (``injected == delivered + shed``) — a fabric fault
    may delay or detour a tenant's events, it must never lose or
    double-count them.  Needs 8 devices, so the engine runs in a
    subprocess; the pytest ``timeout`` is the outer belt against a
    stalled device thread, ``run_md``'s subprocess timeout the inner."""
    from md_helper import run_md
    out = run_md(r"""
import numpy as np
import jax
from jax.sharding import Mesh

from repro.fabric import link_fault
from repro.serve.loadgen import PoissonLoadGen, TenantProfile
from repro.serve.spike_engine import EngineConfig, SpikeEngine
from repro.serve.tenancy import TenantSpec

n = 8
mesh = Mesh(np.array(jax.devices()[:n]), ("w",))
tenants = [TenantSpec("a", reserve=12, rate_epw=40.0),
           TenantSpec("b", reserve=10, rate_epw=20.0)]
cfg = EngineConfig(capacity=16, link_credits=32, notify_latency=2,
                   window_us=100.0, seg_windows=4, nx=2, ny=2, nz=2)
src = PoissonLoadGen(0, [TenantProfile("a", 40.0),
                         TenantProfile("b", 20.0)], n, cfg.capacity)
# the cable dies at absolute window 6 — mid-segment 2 of 4 — and stays dead
sched = link_fault((2, 2, 2), 64, 0, 0, start=6)
eng = SpikeEngine(mesh, "w", tenants, cfg, src,
                  fault_schedule=sched)
rep = eng.run(4)
assert rep.conservation_checked
assert np.all(rep.injected == rep.delivered + rep.shed), (
    rep.injected, rep.delivered, rep.shed)
assert rep.delivered.sum() > 0
assert rep.windows == 4 * 4
# both tenants kept receiving after the fault landed
for t, dig in enumerate(rep.tenants):
    assert dig.hist.sum() == rep.delivered[t]
print("injected=%s delivered=%s shed=%s" %
      (rep.injected.tolist(), rep.delivered.tolist(), rep.shed.tolist()))
print("ENGINE_FAULT_OK")
""", timeout=840)
    assert "ENGINE_FAULT_OK" in out


def test_loadgen_substreams_independent_of_cotenants():
    # tenant 0's window-k draw must not depend on other tenants' profiles
    a = PoissonLoadGen(5, [TenantProfile("q", 20.0),
                           TenantProfile("h", 0.0)], 4, 16)
    b = PoissonLoadGen(5, [TenantProfile("q", 20.0),
                           TenantProfile("h", 300.0, burst_factor=3.0,
                                         burst_prob=0.5)], 4, 16)
    for w in range(6):
        ta, tb = a.next_window(w), b.next_window(w)
        assert np.array_equal(ta.counts[0], tb.counts[0])
        assert np.array_equal(ta.words[0], tb.words[0])
    assert isinstance(ta, WindowTraffic)
