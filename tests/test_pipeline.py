"""Scan-pipelined simulator: residue carry-over, conservation, and timing
equivalence of the software-pipelined window loop (1-device mesh, so the
packed collective degenerates but the full carry machinery runs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.snn import microcircuit as mc, network, simulator as sim


def _build(capacity, residue, n_windows, scale=0.003, seed=0):
    spec = mc.MicrocircuitSpec(scale=scale, seed=seed)
    w, is_inh = spec.weight_matrix()
    part = network.build_partition(w, is_inh, n_shards=1)
    cfg = sim.SimConfig(n_shards=1, per_shard=part.per_shard,
                        max_fan=part.fanout.shape[1], window=8, ring_len=32,
                        e_max=256, capacity=capacity, residue=residue)
    mesh = jax.make_mesh((1,), ("wafer",))
    init, run = sim.build_sharded_sim(mesh, "wafer", cfg, part,
                                      spec.bg_rates())
    st = init(0)
    st, stats = run(st, n_windows)
    return jax.tree_util.tree_map(lambda x: np.asarray(x).ravel(), stats)


def test_pipelined_sim_no_overflow_is_lossless():
    stats = _build(capacity=512, residue=64, n_windows=8)
    assert stats.spikes.sum() > 0, "network is silent"
    assert stats.overflow.sum() == 0
    assert stats.deferred.sum() == 0
    assert stats.deadline_miss.sum() == 0
    # with no deferral every offered event is shipped the same window
    assert (stats.offered == stats.events_sent).all()


def test_pipelined_sim_residue_conservation_under_pressure():
    """Tiny capacity forces the residue path; the WindowStats chain must
    balance exactly: offered_k = sent_k + deferred_k + dropped_k and
    new_k = offered_k - deferred_{k-1} >= 0, summing to
    sum(new) == sum(sent) + sum(dropped) + deferred_last."""
    stats = _build(capacity=8, residue=64, n_windows=12)
    off, sent = stats.offered, stats.events_sent
    defr, drop = stats.deferred, stats.overflow
    assert defr.sum() > 0, "residue carry-over unexercised"
    assert (off == sent + defr + drop).all()
    new = off - np.concatenate([[0], defr[:-1]])
    assert (new >= 0).all()
    assert new.sum() == sent.sum() + drop.sum() + defr[-1]


def test_pipelined_sim_matches_unpipelined_timing():
    """The pipelined scan decodes window k at the same systemtime as the
    seed formulation (start of window k+1 == end of window k), so with
    ample capacity there are no deadline misses and dynamics stay live
    across many windows."""
    stats = _build(capacity=512, residue=64, n_windows=16)
    assert stats.deadline_miss.sum() == 0
    # spikes occur across the run, not only in the first windows (events
    # keep propagating through the pipelined exchange)
    assert stats.spikes[8:].sum() > 0
